//! Small self-contained utilities: deterministic PRNG, a property-testing
//! harness (the offline build has no `proptest`, so we ship a minimal
//! equivalent), and table formatting for the report generators.

pub mod prng;
pub mod proptest;
pub mod table;

pub use prng::Prng;
