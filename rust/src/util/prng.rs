//! Deterministic PRNG (splitmix64 + xoshiro256**) used everywhere random
//! data is needed: synthetic weights/activations, property-test generators,
//! workload generation. No external `rand` crate is available offline; this
//! implementation is the standard xoshiro256** reference algorithm.

/// xoshiro256** seeded via splitmix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire-style rejection-free-enough reduction; bias is negligible
        // for our n << 2^64 and irrelevant for test-data generation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Random boolean with probability `p` (0.0..=1.0).
    ///
    /// Integer-threshold compare: the draw is tested against
    /// `round(p * 2^64)` saturated to the `[0, 2^64]` range, so
    /// `p = 1.0` is always `true` and `p = 0.0` is always `false`.
    /// (The previous float compare `draw as f64 / u64::MAX as f64 < p`
    /// rounded draws near `u64::MAX` up to exactly 1.0, so `p = 1.0`
    /// could come up `false`.) Exactly one `next_u64` is consumed per
    /// call regardless of `p`, keeping downstream draw streams aligned.
    pub fn chance(&mut self, p: f64) -> bool {
        let draw = self.next_u64() as u128;
        let threshold = if p <= 0.0 {
            0u128
        } else if p >= 1.0 {
            1u128 << 64
        } else {
            (p * (1u128 << 64) as f64) as u128
        };
        draw < threshold
    }

    /// Random unsigned value of `bits` bits (0 ..= 2^bits - 1).
    pub fn bits_unsigned(&mut self, bits: u8) -> u32 {
        debug_assert!(bits >= 1 && bits <= 32);
        if bits == 32 { self.next_u32() } else { self.next_u32() & ((1u32 << bits) - 1) }
    }

    /// Random signed value of `bits` bits (-2^(bits-1) ..= 2^(bits-1) - 1).
    pub fn bits_signed(&mut self, bits: u8) -> i32 {
        debug_assert!(bits >= 1 && bits <= 32);
        let v = self.bits_unsigned(bits);
        let shift = 32 - bits as u32;
        ((v << shift) as i32) >> shift
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element from a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(7);
        for n in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(p.below(n) < n);
            }
        }
    }

    #[test]
    fn bits_signed_bounds() {
        let mut p = Prng::new(9);
        for bits in [2u8, 4, 8] {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            for _ in 0..500 {
                let v = p.bits_signed(bits);
                assert!(v >= lo && v <= hi, "v={v} out of [{lo},{hi}] for {bits} bits");
            }
        }
    }

    #[test]
    fn bits_unsigned_bounds() {
        let mut p = Prng::new(11);
        for bits in [2u8, 4, 8] {
            let hi = (1u32 << bits) - 1;
            for _ in 0..500 {
                assert!(p.bits_unsigned(bits) <= hi);
            }
        }
    }

    #[test]
    fn chance_edges_are_exact() {
        let mut p = Prng::new(0xC0FFEE);
        for _ in 0..4096 {
            assert!(p.chance(1.0), "p = 1.0 must always be true");
        }
        for _ in 0..4096 {
            assert!(!p.chance(0.0), "p = 0.0 must always be false");
        }
        // every call consumes exactly one draw regardless of p, so the
        // stream stays aligned with a raw-draw twin
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        a.chance(0.0);
        a.chance(1.0);
        b.next_u64();
        b.next_u64();
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn chance_mid_probability_is_roughly_fair() {
        let mut p = Prng::new(42);
        let hits = (0..10_000).filter(|_| p.chance(0.5)).count();
        assert!((4_000..6_000).contains(&hits), "p = 0.5 hit {hits}/10000");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
