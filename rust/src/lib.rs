//! # Flex-V — mixed-precision QNN inference on a RISC-V parallel cluster
//!
//! Reproduction of *"A 3 TOPS/W RISC-V Parallel Cluster for Inference of
//! Fine-Grain Mixed-Precision Quantized Neural Networks"* (Nadalini et al.,
//! cs.AR 2023).
//!
//! The paper's contribution is a hardware/software stack: the **Flex-V**
//! RISC-V core (fused Mac&Load mixed-precision dot-product instructions,
//! CSR-encoded operand formats, a Mac&Load address-generation controller and
//! a dedicated NN register file), an 8-core PULP cluster integrating it, a
//! PULP-NN-derived kernel library and a DORY-based memory-aware deployment
//! flow. Since the paper's artifact is silicon (GF22FDX), this crate builds
//! the whole system as a **cycle-approximate instruction-set simulator** plus
//! the full software stack on top of it (see DESIGN.md §2 for the
//! substitution table):
//!
//! - [`isa`] — instruction IR: RV32IMC + XpulpV2 + XpulpNN + MPIC + Flex-V
//!   extensions, CSR map, ISA capability matrix.
//! - [`sim`] — the PULP cluster model: RI5CY-style 4-stage core timing,
//!   SIMD/mixed-precision Dotp unit + MPC, Mac&Load controller + NN-RF,
//!   16-bank TCDM with cycle-true conflict arbitration, cluster DMA,
//!   hardware synchronization.
//! - [`qnn`] — quantized-NN substrate: sub-byte packed tensors, PULP-NN
//!   integer quantization math, layer/graph definitions and a golden
//!   (reference) integer executor.
//! - [`kernels`] — the optimized kernel library: per-ISA × per-precision
//!   MatMul / convolution instruction-stream generators reproducing the
//!   paper's assembly (Fig. 5), plus im2col and requantization phases.
//! - [`dory`] — the deployment flow: tiling solver with byte-alignment
//!   constraints, L3/L2/L1 memory manager, double-buffered DMA schedule;
//!   plus [`dory::autotune`], the simulator-in-the-loop autotuner that
//!   selects per-layer plans (tile shape, kernel lowering, core count)
//!   by measured cycles and feeds [`dory::deploy::deploy_tuned`].
//! - [`models`] — the end-to-end network zoo of the evaluation
//!   (MobileNetV1 8b / 8b4b, ResNet-20 4b2b).
//! - [`power`] — GF22FDX area/power/energy model calibrated to Table II.
//! - [`baselines`] — STM32H7 (CMix-NN) reference cost model.
//! - [`runtime`] — PJRT runtime loading AOT-lowered JAX/Pallas golden
//!   models (HLO text) for cross-validation of every simulated kernel.
//! - [`coordinator`] — end-to-end inference driver: executes a DORY plan
//!   (DMA + kernel dispatch) on the simulated cluster and collects metrics.
//! - [`serve`] — multi-cluster inference serving engine: trace-driven
//!   workload generator (steady/Poisson/bursty/diurnal arrivals, SLO
//!   classes with deadlines), bounded request queue with EDF ordering
//!   and load shedding, dynamic batching, compiled-plan cache keyed by
//!   [`dory::PlanKey`], elastic shard pool with model residency and
//!   autoscaling, per-class fleet metrics (workload → queue → batcher →
//!   shard pool → metrics; see `serve/README.md`).
//! - [`report`] — regenerates every table and figure of the paper's
//!   evaluation section (Tables I-IV, Fig. 7), and persists every
//!   number as machine-readable `BENCH_<suite>.json` artifacts
//!   ([`report::artifact`], [`report::bench`]) gated against committed
//!   baselines by [`report::regress`] (CLI `bench-report` / `regress`).
//! - [`trace`] — deterministic cycle-domain tracing and per-layer
//!   profiling: a recording sink on the simulated-cycle clock, a
//!   Perfetto-loadable Chrome trace-event exporter, the fleet-timeline
//!   builder for [`serve`], and the `profile` CLI report
//!   ([`trace::profile::NetworkProfile`]).
//!
//! `ARCHITECTURE.md` at the repository root maps each module to the
//! paper section/figure it reproduces and draws the data flow from
//! [`models::by_name`] through [`dory::deploy::deploy`] to
//! [`serve::Engine`].
//!
//! # Determinism
//!
//! Simulated results are a pure function of their inputs, never of the
//! host. Two host-side accelerators exist, both bit-exact and both
//! defeatable: the serving engine simulates shard batches on a thread
//! pool ([`serve::ServeConfig::workers`]) and merges completion events
//! by simulated cycle, and the simulator memoizes steady-state windows
//! ([`sim::fastpath`], enabled per cluster). `dory::deploy` itself runs
//! once per model via the [`serve::PlanCache`], keyed by
//! [`dory::PlanKey`] — the structural identity (network, precisions,
//! memory budget, ISA, core count) that also keys the per-tile timing
//! memo.
//!
//! # Quickstart
//!
//! Deploy a small quantized conv net and run one cycle-approximate,
//! functionally-exact inference:
//!
//! ```
//! use flexv::coordinator::Coordinator;
//! use flexv::dory::{deploy::deploy, MemBudget};
//! use flexv::isa::IsaVariant;
//! use flexv::qnn::{golden, Layer, Network, QTensor};
//! use flexv::util::Prng;
//!
//! let mut rng = Prng::new(1);
//! let mut net = Network::new("demo", [8, 8, 8], 8);
//! net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
//! net.validate().unwrap();
//! let input = QTensor::random(&[8, 8, 8], 8, false, &mut rng);
//!
//! let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
//! let mut coord = Coordinator::with_fastpath(flexv::CLUSTER_CORES);
//! let res = coord.run(&dep, &input);
//!
//! // bit-exact against the golden integer executor
//! assert_eq!(res.output, golden::run_network(&net, &input).last().unwrap().data);
//! assert!(res.macs_per_cycle() > 0.1);
//! ```
//!
//! For the serving layer (`flexv serve-bench` on the CLI), see
//! [`serve::Engine`] and the root `README.md`.

pub mod baselines;
pub mod coordinator;
pub mod dory;
pub mod isa;
pub mod kernels;
pub mod models;
pub mod power;
pub mod qnn;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;

/// Number of cores in the PULP cluster evaluated by the paper.
pub const CLUSTER_CORES: usize = 8;
/// TCDM (L1) size in bytes: 128 kB shared data scratchpad.
pub const TCDM_BYTES: usize = 128 * 1024;
/// Number of TCDM banks behind the logarithmic interconnect.
pub const TCDM_BANKS: usize = 16;
/// Fabric-controller-side memory size in bytes. The physical chip has a
/// 1.5 MB L2 backed by external L3 RAM; our DMA model folds L3→L2
/// streaming into one level (DESIGN.md §2), so this region is sized to
/// hold a whole network's weights + ping-pong activations.
pub const L2_BYTES: usize = 8 * 1024 * 1024;
