//! Kernels for the non-GEMM operators of the end-to-end networks
//! (Table IV): depthwise convolution, max/avg pooling, residual add,
//! and the fully-connected wrapper.
//!
//! These follow PULP-NN's HWC strategies: depthwise processes groups of
//! four channels with two-pixel unrolling (weights reordered to
//! `[kh, kw, C]` at deployment so a tap's channel group is contiguous);
//! pooling and add are element-wise sweeps parallelized over rows.

use super::matmul::{gen_matmul, MatMulTask};
use super::regalloc as ra;
use super::requant::{emit_requant_block, RequantCfg};
use crate::isa::{AluOp, Instr, IsaVariant, Program};
use crate::qnn::Precision;

/// Depthwise convolution task. Activations 8-bit (the evaluation networks
/// use depthwise only in MobileNetV1, a8); weights 2/4/8-bit signed in
/// deployment order `[kh, kw, C]`.
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct DwConvTask {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad_t: usize,
    pub pad_b: usize,
    pub pad_l: usize,
    pub pad_r: usize,
    pub w_bits: u8,
    pub in_base: u32,
    pub w_base: u32,
    pub out_base: u32,
    pub quant: RequantCfg,
}

impl DwConvTask {
    pub fn out_h(&self) -> usize {
        (self.h + self.pad_t + self.pad_b - self.kh) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.w + self.pad_l + self.pad_r - self.kw) / self.stride + 1
    }
    pub fn macs(&self) -> u64 {
        (self.out_h() * self.out_w() * self.c * self.kh * self.kw) as u64
    }
    fn in_addr(&self, y: usize, x: usize, ch: usize) -> u32 {
        self.in_base + ((y * self.w + x) * self.c + ch) as u32
    }
    fn w_addr(&self, ky: usize, kx: usize, ch: usize) -> u32 {
        self.w_base + (((ky * self.kw + kx) * self.c + ch) * self.w_bits as usize / 8) as u32
    }
    fn out_addr(&self, pix: usize, ch: usize) -> u32 {
        self.out_base + (pix * self.c + ch) as u32 * self.quant.out_bits as u32 / 8
    }
}

/// Generate the per-core depthwise program: output pixels split across
/// cores, channels processed in groups of 4 with the taps unrolled.
pub fn gen_dwconv(_isa: IsaVariant, t: &DwConvTask, core: usize, n_cores: usize) -> Program {
    assert!(t.c % 4 == 0, "depthwise channels must be a multiple of 4");
    let m = t.out_h() * t.out_w();
    let (lo, hi) = super::matmul::row_range(m, core, n_cores);
    let mut p = Program::new(format!("dwconv-c{core}"));
    for pix in lo..hi {
        let (oy, ox) = (pix / t.out_w(), pix % t.out_w());
        for ch in (0..t.c).step_by(4) {
            // acc(f) for f in 0..4 = the four channels of the group
            for f in 0..4 {
                p.push(Instr::Li { rd: ra::acc(f), imm: 0 });
            }
            for ky in 0..t.kh {
                let iy = (oy * t.stride + ky) as isize - t.pad_t as isize;
                if iy < 0 || iy >= t.h as isize {
                    continue; // zero padding contributes nothing
                }
                for kx in 0..t.kw {
                    let ix = (ox * t.stride + kx) as isize - t.pad_l as isize;
                    if ix < 0 || ix >= t.w as isize {
                        continue;
                    }
                    // activation word: 4 channels of (iy, ix)
                    p.push(Instr::Li {
                        rd: ra::A_PTR[0],
                        imm: t.in_addr(iy as usize, ix as usize, ch) as i32,
                    });
                    p.push(Instr::Lw { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 0 });
                    // weight group: 4 channels of tap (ky, kx), packed
                    p.push(Instr::Li { rd: ra::A_PTR[1], imm: t.w_addr(ky, kx, ch) as i32 });
                    match t.w_bits {
                        8 => {
                            p.push(Instr::Lw {
                                rd: ra::W_REG[0],
                                base: ra::A_PTR[1],
                                off: 0,
                                post_inc: 0,
                            });
                        }
                        _ => {
                            // 4 channels * w_bits <= 16 bits: byte loads
                            let bytes = (4 * t.w_bits as usize).div_ceil(8);
                            p.push(Instr::Lbu {
                                rd: ra::W_REG[0],
                                base: ra::A_PTR[1],
                                off: 0,
                                post_inc: 0,
                            });
                            if bytes == 2 {
                                p.push(Instr::Lbu {
                                    rd: ra::TMP[3],
                                    base: ra::A_PTR[1],
                                    off: 1,
                                    post_inc: 0,
                                });
                                p.push(Instr::AluI {
                                    op: AluOp::Sll,
                                    rd: ra::TMP[3],
                                    rs1: ra::TMP[3],
                                    imm: 8,
                                });
                                p.push(Instr::Alu {
                                    op: AluOp::Or,
                                    rd: ra::W_REG[0],
                                    rs1: ra::W_REG[0],
                                    rs2: ra::TMP[3],
                                });
                            }
                        }
                    }
                    // per-channel extract + MAC
                    for f in 0..4u8 {
                        p.push(Instr::ExtractU {
                            rd: ra::TMP[0],
                            rs1: ra::A_REG[0],
                            off: 8 * f,
                            len: 8,
                        });
                        p.push(Instr::Extract {
                            rd: ra::TMP[1],
                            rs1: ra::W_REG[0],
                            off: t.w_bits * f,
                            len: t.w_bits,
                        });
                        p.push(Instr::Mac { rd: ra::acc(f as usize), rs1: ra::TMP[0], rs2: ra::TMP[1] });
                    }
                }
            }
            emit_requant_block(&mut p, &t.quant, ch, 4, 1, |_| t.out_addr(pix, ch));
        }
    }
    p.push(Instr::Barrier);
    p.push(Instr::Halt);
    p
}

/// Fully-connected layer: a 1-row MatMul.
#[allow(clippy::too_many_arguments)]
pub fn gen_linear(
    isa: IsaVariant,
    prec: Precision,
    cin: usize,
    cout: usize,
    in_base: u32,
    w_base: u32,
    w_pitch: u32,
    out_base: u32,
    quant: RequantCfg,
    core: usize,
    n_cores: usize,
) -> Program {
    // Parallelize over output-channel groups by splitting the single GEMM
    // row across cores is useless; instead give each core a slice of
    // channels via a per-core sub-task.
    assert!(cout % 4 == 0);
    let groups = cout / 4;
    let per = groups.div_ceil(n_cores);
    let g_lo = (core * per).min(groups);
    let g_hi = ((core + 1) * per).min(groups);
    let lanes = 32 / prec.a_bits as usize;
    let t = MatMulTask {
        m: 1,
        n: (g_hi - g_lo) * 4,
        k: cin,
        prec,
        a_base: in_base,
        a_pitch: (cin.div_ceil(lanes) * 4) as u32,
        w_base: w_base + (g_lo * 4) as u32 * w_pitch,
        w_pitch,
        out_base: out_base + ((g_lo * 4) * quant.out_bits as usize / 8) as u32,
        out_pitch: (cout * quant.out_bits as usize / 8) as u32,
        quant: RequantCfg {
            mult_base: quant.mult_base + (g_lo * 16) as u32,
            bias_base: quant.bias_base + (g_lo * 16) as u32,
            ..quant
        },
    };
    if g_hi > g_lo {
        gen_matmul(isa, &t, 0, 1)
    } else {
        let mut p = Program::new(format!("linear-idle-c{core}"));
        p.push(Instr::Barrier);
        p.push(Instr::Halt);
        p
    }
}

/// Element-wise residual add: `out = clip((x1*m1 + x2*m2) >> shift)`,
/// 8-/4-bit unsigned operands, rows split across cores.
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct AddTask {
    /// Total elements (H*W*C).
    pub n: usize,
    pub bits: u8,
    pub out_bits: u8,
    pub m1: i32,
    pub m2: i32,
    pub shift: u8,
    pub x1_base: u32,
    pub x2_base: u32,
    pub out_base: u32,
}

pub fn gen_add(t: &AddTask, core: usize, n_cores: usize) -> Program {
    let lanes = 8 / t.bits as usize; // elements per byte
    let bytes = t.n / lanes;
    let per = (bytes.div_ceil(n_cores)).next_multiple_of(1);
    let lo = (core * per).min(bytes);
    let hi = ((core + 1) * per).min(bytes);
    let mut p = Program::new(format!("add-c{core}"));
    if hi > lo {
        p.push(Instr::Li { rd: ra::A_PTR[0], imm: (t.x1_base + lo as u32) as i32 });
        p.push(Instr::Li { rd: ra::A_PTR[1], imm: (t.x2_base + lo as u32) as i32 });
        p.push(Instr::Li { rd: ra::OUT_PTR, imm: (t.out_base + lo as u32) as i32 });
        p.push(Instr::Li { rd: ra::W_REG[0], imm: t.m1 });
        p.push(Instr::Li { rd: ra::W_REG[1], imm: t.m2 });
        let body_at = p.len();
        p.push(Instr::LpSetup { l: 0, count: (hi - lo) as u32, len: 0 });
        let start = p.len();
        p.push(Instr::Lbu { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 1 });
        p.push(Instr::Lbu { rd: ra::A_REG[1], base: ra::A_PTR[1], off: 0, post_inc: 1 });
        let out_reg = ra::TMP[2];
        p.push(Instr::Li { rd: out_reg, imm: 0 });
        for e in 0..lanes {
            let off = (e * t.bits as usize) as u8;
            p.push(Instr::ExtractU { rd: ra::TMP[0], rs1: ra::A_REG[0], off, len: t.bits });
            p.push(Instr::ExtractU { rd: ra::TMP[1], rs1: ra::A_REG[1], off, len: t.bits });
            // acc = x1*m1 + x2*m2 via two MACs into TMP[3]
            p.push(Instr::Li { rd: ra::TMP[3], imm: 0 });
            p.push(Instr::Mac { rd: ra::TMP[3], rs1: ra::TMP[0], rs2: ra::W_REG[0] });
            p.push(Instr::Mac { rd: ra::TMP[3], rs1: ra::TMP[1], rs2: ra::W_REG[1] });
            p.push(Instr::AluI { op: AluOp::Sra, rd: ra::TMP[3], rs1: ra::TMP[3], imm: t.shift as i32 });
            p.push(Instr::Clipu { rd: ra::TMP[3], rs1: ra::TMP[3], bits: t.out_bits });
            let out_off = (e * t.out_bits as usize) as u8;
            p.push(Instr::Insert { rd: out_reg, rs1: ra::TMP[3], off: out_off, len: t.out_bits });
        }
        // out_bits may differ from bits; store the produced bytes
        let out_bytes = lanes * t.out_bits as usize / 8;
        for byt in 0..out_bytes {
            if byt == 0 {
                p.push(Instr::Sb { rs: out_reg, base: ra::OUT_PTR, off: 0, post_inc: 0 });
            } else {
                p.push(Instr::AluI { op: AluOp::Srl, rd: ra::TMP[0], rs1: out_reg, imm: 8 * byt as i32 });
                p.push(Instr::Sb { rs: ra::TMP[0], base: ra::OUT_PTR, off: byt as i32, post_inc: 0 });
            }
        }
        p.push(Instr::AluI { op: AluOp::Add, rd: ra::OUT_PTR, rs1: ra::OUT_PTR, imm: out_bytes as i32 });
        let len = (p.len() - start) as u16;
        if let Instr::LpSetup { len: l, .. } = &mut p.instrs[body_at] {
            *l = len;
        }
    }
    p.push(Instr::Barrier);
    p.push(Instr::Halt);
    p
}

/// Channel-wise concatenation of two HWC tensors: per output pixel, `b1`
/// packed bytes from the first input followed by `b2` from the second —
/// pure data movement (no arithmetic), pixels split across cores. Works for
/// any element width whose per-pixel channel bytes are whole (the graph IR
/// enforces channel byte-alignment).
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct ConcatTask {
    /// Pixels (H*W) in this tile.
    pub pixels: usize,
    /// Packed bytes per pixel of the first input (`c1 * bits / 8`).
    pub b1: usize,
    /// Packed bytes per pixel of the second input (`c2 * bits / 8`).
    pub b2: usize,
    pub x1_base: u32,
    pub x2_base: u32,
    pub out_base: u32,
}

pub fn gen_concat(t: &ConcatTask, core: usize, n_cores: usize) -> Program {
    let (lo, hi) = super::matmul::row_range(t.pixels, core, n_cores);
    let bo = t.b1 + t.b2;
    let mut p = Program::new(format!("concat-c{core}"));
    if hi > lo {
        p.push(Instr::Li { rd: ra::A_PTR[0], imm: (t.x1_base + (lo * t.b1) as u32) as i32 });
        p.push(Instr::Li { rd: ra::A_PTR[1], imm: (t.x2_base + (lo * t.b2) as u32) as i32 });
        p.push(Instr::Li { rd: ra::OUT_PTR, imm: (t.out_base + (lo * bo) as u32) as i32 });
        let body_at = p.len();
        p.push(Instr::LpSetup { l: 0, count: (hi - lo) as u32, len: 0 });
        let start = p.len();
        for i in 0..t.b1 {
            p.push(Instr::Lbu { rd: ra::TMP[0], base: ra::A_PTR[0], off: i as i32, post_inc: 0 });
            p.push(Instr::Sb { rs: ra::TMP[0], base: ra::OUT_PTR, off: i as i32, post_inc: 0 });
        }
        for i in 0..t.b2 {
            p.push(Instr::Lbu { rd: ra::TMP[0], base: ra::A_PTR[1], off: i as i32, post_inc: 0 });
            p.push(Instr::Sb {
                rs: ra::TMP[0],
                base: ra::OUT_PTR,
                off: (t.b1 + i) as i32,
                post_inc: 0,
            });
        }
        p.push(Instr::AluI { op: AluOp::Add, rd: ra::A_PTR[0], rs1: ra::A_PTR[0], imm: t.b1 as i32 });
        p.push(Instr::AluI { op: AluOp::Add, rd: ra::A_PTR[1], rs1: ra::A_PTR[1], imm: t.b2 as i32 });
        p.push(Instr::AluI { op: AluOp::Add, rd: ra::OUT_PTR, rs1: ra::OUT_PTR, imm: bo as i32 });
        let len = (p.len() - start) as u16;
        if let Instr::LpSetup { len: l, .. } = &mut p.instrs[body_at] {
            *l = len;
        }
    }
    p.push(Instr::Barrier);
    p.push(Instr::Halt);
    p
}

/// Average pooling over a full feature map window (global or strided),
/// requantized. Channels split across cores (channel groups of 4 at 8 bit).
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct AvgPoolTask {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub k: usize,
    pub stride: usize,
    pub bits: u8,
    pub in_base: u32,
    pub out_base: u32,
    pub quant: RequantCfg,
}

pub fn gen_avgpool(t: &AvgPoolTask, core: usize, n_cores: usize) -> Program {
    let oh = (t.h - t.k) / t.stride + 1;
    let ow = (t.w - t.k) / t.stride + 1;
    let (c_lo, c_hi) = super::matmul::row_range(t.c, core, n_cores);
    let mut p = Program::new(format!("avgpool-c{core}"));
    let lanes = 8 / t.bits as usize;
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in c_lo..c_hi {
                p.push(Instr::Li { rd: ra::acc(0), imm: 0 });
                for ky in 0..t.k {
                    for kx in 0..t.k {
                        let (iy, ix) = (oy * t.stride + ky, ox * t.stride + kx);
                        let elem = (iy * t.w + ix) * t.c + ch;
                        let addr = t.in_base + (elem / lanes) as u32;
                        p.push(Instr::Li { rd: ra::A_PTR[0], imm: addr as i32 });
                        p.push(Instr::Lbu { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 0 });
                        p.push(Instr::ExtractU {
                            rd: ra::TMP[0],
                            rs1: ra::A_REG[0],
                            off: ((elem % lanes) * t.bits as usize) as u8,
                            len: t.bits,
                        });
                        p.push(Instr::Alu { op: AluOp::Add, rd: ra::acc(0), rs1: ra::acc(0), rs2: ra::TMP[0] });
                    }
                }
                // requant: (acc + bias) * mult >> shift, clip
                p.push(Instr::Li { rd: ra::Q_PTR, imm: (t.quant.mult_base + 4 * ch as u32) as i32 });
                p.push(Instr::Lw { rd: ra::TMP[1], base: ra::Q_PTR, off: 0, post_inc: 0 });
                p.push(Instr::Li { rd: ra::Q_PTR, imm: (t.quant.bias_base + 4 * ch as u32) as i32 });
                p.push(Instr::Lw { rd: ra::TMP[2], base: ra::Q_PTR, off: 0, post_inc: 0 });
                p.push(Instr::Alu { op: AluOp::Add, rd: ra::acc(0), rs1: ra::acc(0), rs2: ra::TMP[2] });
                p.push(Instr::Alu { op: AluOp::Mul, rd: ra::acc(0), rs1: ra::acc(0), rs2: ra::TMP[1] });
                p.push(Instr::AluI { op: AluOp::Sra, rd: ra::acc(0), rs1: ra::acc(0), imm: t.quant.shift as i32 });
                p.push(Instr::Clipu { rd: ra::acc(0), rs1: ra::acc(0), bits: t.quant.out_bits });
                // store (read-modify-write byte for sub-byte outputs)
                let out_lanes = 8 / t.quant.out_bits as usize;
                let oelem = (oy * ow + ox) * t.c + ch;
                let oaddr = t.out_base + (oelem / out_lanes) as u32;
                p.push(Instr::Li { rd: ra::OUT_PTR, imm: oaddr as i32 });
                if out_lanes == 1 {
                    p.push(Instr::Sb { rs: ra::acc(0), base: ra::OUT_PTR, off: 0, post_inc: 0 });
                } else {
                    p.push(Instr::Lbu { rd: ra::TMP[0], base: ra::OUT_PTR, off: 0, post_inc: 0 });
                    p.push(Instr::Insert {
                        rd: ra::TMP[0],
                        rs1: ra::acc(0),
                        off: ((oelem % out_lanes) * t.quant.out_bits as usize) as u8,
                        len: t.quant.out_bits,
                    });
                    p.push(Instr::Sb { rs: ra::TMP[0], base: ra::OUT_PTR, off: 0, post_inc: 0 });
                }
            }
        }
    }
    p.push(Instr::Barrier);
    p.push(Instr::Halt);
    p
}

/// Max pooling (8-bit activations), rows split across cores.
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct MaxPoolTask {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub k: usize,
    pub stride: usize,
    pub in_base: u32,
    pub out_base: u32,
}

pub fn gen_maxpool(t: &MaxPoolTask, core: usize, n_cores: usize) -> Program {
    let oh = (t.h - t.k) / t.stride + 1;
    let ow = (t.w - t.k) / t.stride + 1;
    let m = oh * ow;
    let (lo, hi) = super::matmul::row_range(m, core, n_cores);
    let mut p = Program::new(format!("maxpool-c{core}"));
    for pix in lo..hi {
        let (oy, ox) = (pix / ow, pix % ow);
        for ch in 0..t.c {
            p.push(Instr::Li { rd: ra::acc(0), imm: 0 });
            for ky in 0..t.k {
                for kx in 0..t.k {
                    let (iy, ix) = (oy * t.stride + ky, ox * t.stride + kx);
                    let addr = t.in_base + ((iy * t.w + ix) * t.c + ch) as u32;
                    p.push(Instr::Li { rd: ra::A_PTR[0], imm: addr as i32 });
                    p.push(Instr::Lbu { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 0 });
                    p.push(Instr::Alu { op: AluOp::Max, rd: ra::acc(0), rs1: ra::acc(0), rs2: ra::A_REG[0] });
                }
            }
            let oaddr = t.out_base + ((oy * ow + ox) * t.c + ch) as u32;
            p.push(Instr::Li { rd: ra::OUT_PTR, imm: oaddr as i32 });
            p.push(Instr::Sb { rs: ra::acc(0), base: ra::OUT_PTR, off: 0, post_inc: 0 });
        }
    }
    p.push(Instr::Barrier);
    p.push(Instr::Halt);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::{golden, QTensor, QuantParams};
    use crate::sim::{Cluster, TCDM_BASE};
    use crate::util::Prng;

    #[test]
    fn dwconv_matches_golden() {
        let mut rng = Prng::new(31);
        let (h, w, c) = (5, 5, 8);
        for w_bits in [8u8, 4] {
            let x = QTensor::random(&[h, w, c], 8, false, &mut rng);
            // weights in layer order [C, kh, kw, 1]
            let wt = QTensor::random(&[c, 3, 3, 1], w_bits, true, &mut rng);
            let q = QuantParams {
                mult: (0..c).map(|_| rng.range_i64(1, 4) as i32).collect(),
                shift: 5,
                bias: (0..c).map(|_| rng.range_i64(-32, 32) as i32).collect(),
                out_bits: 8,
            };
            // deployment order [kh, kw, C]
            let mut dep = vec![0i32; c * 9];
            for ch in 0..c {
                for ky in 0..3 {
                    for kx in 0..3 {
                        dep[(ky * 3 + kx) * c + ch] = wt.get_i(wt.flat(&[ch, ky, kx, 0]));
                    }
                }
            }
            let dep_t = QTensor::from_signed(&[9, c], w_bits, &dep);
            let in_base = TCDM_BASE;
            let w_base = in_base + 2048;
            let mult_base = w_base + 1024;
            let bias_base = mult_base + 256;
            let out_base = bias_base + 256;
            let t = DwConvTask {
                h,
                w,
                c,
                kh: 3,
                kw: 3,
                stride: 1,
                pad_t: 1,
                pad_b: 1,
                pad_l: 1,
                pad_r: 1,
                w_bits,
                in_base,
                w_base,
                out_base,
                quant: RequantCfg { mult_base, bias_base, shift: q.shift, out_bits: 8 },
            };
            let mut cl = Cluster::new(4);
            cl.mem.write_bytes(in_base, &x.data);
            cl.mem.write_bytes(w_base, &dep_t.data);
            for ch in 0..c {
                cl.mem.store_u32(mult_base + 4 * ch as u32, q.mult[ch] as u32);
                cl.mem.store_u32(bias_base + 4 * ch as u32, q.bias[ch] as u32);
            }
            cl.load_programs((0..4).map(|i| gen_dwconv(IsaVariant::FlexV, &t, i, 4)).collect());
            let stats = cl.run();
            assert_eq!(stats.total_macs(), t.macs() - padding_macs(&t, &x), "w{w_bits}");
            let want = golden::dwconv2d(&x, &wt, &q, 3, 3, 1, 1);
            assert_eq!(cl.mem.read_bytes(out_base, want.bytes()), want.data, "w{w_bits}");
        }
    }

    /// MACs skipped because the receptive field hangs over the padding
    /// (the kernel skips zero taps; golden counts only real MACs too).
    fn padding_macs(t: &DwConvTask, _x: &QTensor) -> u64 {
        let mut skipped = 0u64;
        for oy in 0..t.out_h() {
            for ox in 0..t.out_w() {
                for ky in 0..t.kh {
                    for kx in 0..t.kw {
                        let iy = (oy * t.stride + ky) as isize - t.pad_t as isize;
                        let ix = (ox * t.stride + kx) as isize - t.pad_l as isize;
                        if iy < 0 || iy >= t.h as isize || ix < 0 || ix >= t.w as isize {
                            skipped += t.c as u64;
                        }
                    }
                }
            }
        }
        skipped
    }

    #[test]
    fn add_matches_golden() {
        let mut rng = Prng::new(33);
        for bits in [8u8, 4] {
            let n = 64usize;
            let x1 = QTensor::random(&[n], bits, false, &mut rng);
            let x2 = QTensor::random(&[n], bits, false, &mut rng);
            let (m1, m2, shift) = (3, 2, 2u8);
            let t = AddTask {
                n,
                bits,
                out_bits: bits,
                m1,
                m2,
                shift,
                x1_base: TCDM_BASE,
                x2_base: TCDM_BASE + 256,
                out_base: TCDM_BASE + 512,
            };
            let mut cl = Cluster::new(3);
            cl.mem.write_bytes(t.x1_base, &x1.data);
            cl.mem.write_bytes(t.x2_base, &x2.data);
            cl.load_programs((0..3).map(|i| gen_add(&t, i, 3)).collect());
            cl.run();
            let q = QuantParams::scalar(1, shift, 0, bits, 1);
            let want = golden::run_add(&x1, &x2, m1, m2, &q);
            assert_eq!(cl.mem.read_bytes(t.out_base, want.bytes()), want.data, "bits={bits}");
        }
    }

    #[test]
    fn concat_matches_golden() {
        let mut rng = Prng::new(41);
        for (bits, c1, c2) in [(8u8, 8usize, 16usize), (4, 4, 8)] {
            let (h, w) = (3, 5);
            let x1 = QTensor::random(&[h, w, c1], bits, false, &mut rng);
            let x2 = QTensor::random(&[h, w, c2], bits, false, &mut rng);
            let t = ConcatTask {
                pixels: h * w,
                b1: c1 * bits as usize / 8,
                b2: c2 * bits as usize / 8,
                x1_base: TCDM_BASE,
                x2_base: TCDM_BASE + 1024,
                out_base: TCDM_BASE + 2048,
            };
            let mut cl = Cluster::new(4);
            cl.mem.write_bytes(t.x1_base, &x1.data);
            cl.mem.write_bytes(t.x2_base, &x2.data);
            cl.load_programs((0..4).map(|i| gen_concat(&t, i, 4)).collect());
            cl.run();
            let want = golden::concat(&x1, &x2);
            assert_eq!(cl.mem.read_bytes(t.out_base, want.bytes()), want.data, "bits={bits}");
        }
    }

    #[test]
    fn avgpool_matches_golden() {
        let mut rng = Prng::new(35);
        let (h, w, c, k) = (4, 4, 8, 4);
        let x = QTensor::random(&[h, w, c], 8, false, &mut rng);
        let q = QuantParams::scalar(1, 4, 0, 8, c); // /16 = >>4
        let t = AvgPoolTask {
            h,
            w,
            c,
            k,
            stride: k,
            bits: 8,
            in_base: TCDM_BASE,
            out_base: TCDM_BASE + 1024,
            quant: RequantCfg {
                mult_base: TCDM_BASE + 2048,
                bias_base: TCDM_BASE + 2304,
                shift: 4,
                out_bits: 8,
            },
        };
        let mut cl = Cluster::new(4);
        cl.mem.write_bytes(t.in_base, &x.data);
        for ch in 0..c {
            cl.mem.store_u32(t.quant.mult_base + 4 * ch as u32, 1);
            cl.mem.store_u32(t.quant.bias_base + 4 * ch as u32, 0);
        }
        cl.load_programs((0..4).map(|i| gen_avgpool(&t, i, 4)).collect());
        cl.run();
        let want = golden::avgpool(&x, &q, k, k);
        assert_eq!(cl.mem.read_bytes(t.out_base, want.bytes()), want.data);
    }

    #[test]
    fn maxpool_matches_golden() {
        let mut rng = Prng::new(37);
        let (h, w, c) = (6, 6, 4);
        let x = QTensor::random(&[h, w, c], 8, false, &mut rng);
        let t = MaxPoolTask {
            h,
            w,
            c,
            k: 2,
            stride: 2,
            in_base: TCDM_BASE,
            out_base: TCDM_BASE + 1024,
        };
        let mut cl = Cluster::new(2);
        cl.mem.write_bytes(t.in_base, &x.data);
        cl.load_programs((0..2).map(|i| gen_maxpool(&t, i, 2)).collect());
        cl.run();
        let want = golden::maxpool(&x, 2, 2);
        assert_eq!(cl.mem.read_bytes(t.out_base, want.bytes()), want.data);
    }

    #[test]
    fn linear_matches_golden() {
        let mut rng = Prng::new(39);
        let (cin, cout) = (32usize, 8usize);
        let prec = Precision::new(8, 8);
        let x = QTensor::random(&[1, 1, cin], 8, false, &mut rng);
        let wt = QTensor::random(&[cout, cin], 8, true, &mut rng);
        let q = QuantParams {
            mult: (0..cout).map(|_| rng.range_i64(1, 4) as i32).collect(),
            shift: 8,
            bias: (0..cout).map(|_| rng.range_i64(-64, 64) as i32).collect(),
            out_bits: 8,
        };
        let in_base = TCDM_BASE;
        let w_base = TCDM_BASE + 256;
        let mult_base = w_base + 2048;
        let bias_base = mult_base + 128;
        let out_base = bias_base + 128;
        let mut cl = Cluster::new(3);
        cl.mem.write_bytes(in_base, &x.data);
        cl.mem.write_bytes(w_base, &wt.data);
        for ch in 0..cout {
            cl.mem.store_u32(mult_base + 4 * ch as u32, q.mult[ch] as u32);
            cl.mem.store_u32(bias_base + 4 * ch as u32, q.bias[ch] as u32);
        }
        let quant = RequantCfg { mult_base, bias_base, shift: q.shift, out_bits: 8 };
        cl.load_programs(
            (0..3)
                .map(|i| gen_linear(IsaVariant::FlexV, prec, cin, cout, in_base, w_base, cin as u32, out_base, quant, i, 3))
                .collect(),
        );
        cl.run();
        let want = golden::linear(&x, &wt, &q);
        assert_eq!(cl.mem.read_bytes(out_base, want.bytes()), want.data);
    }
}
