//! The tentpole guarantee of the parallel serving engine: running shard
//! batches on a host thread pool AND replaying steady-state windows
//! through the simulator fast path change **wall-clock time only**.
//! Outputs, per-layer cycle counts, completion ordering, and every
//! fleet metric must be bit-identical to the sequential, no-fastpath
//! engine — the deterministic event-ordering reduction (merge per-shard
//! completions by simulated cycle, tie-break by shard id) makes the
//! completion stream a pure function of the trace.

use flexv::models::{resnet20, Profile};
use flexv::qnn::layer::Network;
use flexv::qnn::{Layer, QTensor};
use flexv::serve::{Completion, Engine, FleetMetrics, ServeConfig, TraceItem};
use flexv::util::Prng;

fn tiny(name: &str, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new(name, [10, 10, 8], 8);
    net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

/// Deterministic mixed-model trace: two tiny nets plus one ResNet-20
/// request, interleaved arrivals, mixed priorities, repeated inputs (so
/// the fast path sees both pure and functional replays).
fn mk_trace(tiny_a: usize, tiny_b: usize, resnet: usize) -> Vec<TraceItem> {
    let mut rng = Prng::new(40);
    let mut inputs: Vec<QTensor> =
        (0..4).map(|_| QTensor::random(&[10, 10, 8], 8, false, &mut rng)).collect();
    inputs.push(inputs[0].clone()); // exact repeat of the first payload
    let resnet_input = QTensor::random(&[32, 32, 4], 8, false, &mut rng);
    let mut trace = Vec::new();
    for (i, input) in inputs.into_iter().enumerate() {
        trace.push(TraceItem {
            at: i as u64 * 40,
            model: if i % 2 == 0 { tiny_a } else { tiny_b },
            class: 0,
            priority: (i % 3) as u8,
            deadline: None,
            input,
        });
    }
    trace.push(TraceItem {
        at: 90,
        model: resnet,
        class: 0,
        priority: 0,
        deadline: None,
        input: resnet_input,
    });
    trace
}

/// Run the standard fleet over the standard trace with the given
/// execution knobs; everything else is fixed.
fn run(workers: usize, fastpath: bool, exact: bool) -> (Vec<Completion>, FleetMetrics) {
    let cfg = ServeConfig { shards: 3, workers, fastpath, exact, ..ServeConfig::default() };
    let mut eng = Engine::new(cfg);
    let a = eng.register(tiny("par-a", 41));
    let b = eng.register(tiny("par-b", 42));
    let r = eng.register(resnet20(Profile::Mixed4a2w, 5));
    let m = eng.run_trace(mk_trace(a, b, r));
    (eng.completions().to_vec(), m)
}

fn assert_bit_identical(l: &(Vec<Completion>, FleetMetrics), r: &(Vec<Completion>, FleetMetrics)) {
    assert_eq!(l.0.len(), r.0.len(), "served counts differ");
    for (x, y) in l.0.iter().zip(&r.0) {
        assert_eq!(x.id, y.id, "completion order diverged");
        assert_eq!(x.model, y.model);
        assert_eq!(x.shard, y.shard, "shard assignment diverged (id {})", x.id);
        assert_eq!(x.start_cycle, y.start_cycle, "id {}", x.id);
        assert_eq!(x.finish_cycle, y.finish_cycle, "id {}", x.id);
        assert_eq!(x.exec_cycles, y.exec_cycles, "id {}", x.id);
        assert_eq!(x.switch_cycles, y.switch_cycles, "id {}", x.id);
        assert_eq!(x.batch_size, y.batch_size, "id {}", x.id);
        assert_eq!(x.macs, y.macs, "id {}", x.id);
        assert_eq!(x.layer_cycles, y.layer_cycles, "per-layer cycles diverged (id {})", x.id);
        assert_eq!(x.output, y.output, "outputs diverged (id {})", x.id);
        assert!(x.energy_pj == y.energy_pj, "energy diverged (id {})", x.id);
    }
    // fleet metrics are a pure function of the completions
    assert_eq!(l.1.served, r.1.served);
    assert_eq!(l.1.span_cycles, r.1.span_cycles);
    assert_eq!(l.1.p50_cycles, r.1.p50_cycles);
    assert_eq!(l.1.p99_cycles, r.1.p99_cycles);
    assert_eq!(l.1.model_switches, r.1.model_switches);
    assert_eq!(l.1.batches, r.1.batches);
    assert!(l.1.aggregate_macs_per_cycle == r.1.aggregate_macs_per_cycle);
}

/// Exact mode: the threaded, fast-path engine is bit-identical to the
/// sequential no-fastpath engine (outputs and simulated cycle counts).
#[test]
fn serve_parallel_determinism() {
    let reference = run(1, false, true);
    let parallel = run(0, true, true);
    assert_bit_identical(&reference, &parallel);
    // a worker cap exercises the chunked pool path; still identical
    let two_workers = run(2, true, true);
    assert_bit_identical(&reference, &two_workers);
}

/// Warm (timing-only) mode: same guarantee for the throughput
/// configuration the benches run.
#[test]
fn serve_parallel_determinism_warm_mode() {
    let reference = run(1, false, false);
    let parallel = run(0, true, false);
    assert_bit_identical(&reference, &parallel);
}
