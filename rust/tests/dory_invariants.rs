//! Integration properties of the deployment flow: every plan the solver
//! produces must keep tiles inside the memory map, byte-aligned, and
//! collectively covering each layer's output exactly.

use flexv::dory::deploy::{deploy, w_row_pitch};
use flexv::dory::tiler::{
    buf_bits, conv_tile_bytes, dma_cost, enumerate_conv_tilings, solve_conv_tiling,
    solve_dw_tiling,
};
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::kernels::im2col::ConvGeom;
use flexv::models::{mobilenet_v1, resnet20, Profile};
use flexv::qnn::layer::Network;
use flexv::qnn::Layer;
use flexv::sim::{L2_BASE, TCDM_BASE};
use flexv::util::proptest;
use flexv::util::Prng;

fn check_deployment(net: &Network, isa: IsaVariant, budget: MemBudget) -> Result<(), String> {
    let dep = deploy(net, isa, budget);
    let l1_end = TCDM_BASE + budget.l1 as u32;
    let l2_end = L2_BASE + budget.l2 as u32;
    for plan in &dep.plans {
        let mut out_bytes = 0u64;
        for tile in &plan.tiles {
            for r in tile.loads.iter().chain(tile.stores.iter()) {
                // TCDM side within the L1 budget
                let loc_last = r.loc + (r.rows - 1) * r.loc_stride + r.row_bytes;
                if r.loc < TCDM_BASE || loc_last > l1_end {
                    return Err(format!(
                        "{}: DMA L1 range {:#x}..{:#x} outside budget",
                        plan.name, r.loc, loc_last
                    ));
                }
                // L2 side mapped
                let ext_last = r.ext + (r.rows - 1) * r.ext_stride + r.row_bytes;
                if r.ext < L2_BASE || ext_last > l2_end {
                    return Err(format!("{}: DMA L2 range outside map", plan.name));
                }
            }
            out_bytes += tile.stores.iter().map(|s| s.total_bytes()).sum::<u64>();
        }
        // stores cover the node output exactly once
        let want = net.nodes[plan.node].layer.out_bytes() as u64;
        if out_bytes != want {
            return Err(format!(
                "{}: stores cover {out_bytes} B, layer output is {want} B",
                plan.name
            ));
        }
    }
    Ok(())
}

#[test]
fn evaluation_networks_deploy_cleanly_all_isas() {
    let nets = vec![
        mobilenet_v1(Profile::Uniform8, 0.75, 96, 1),
        mobilenet_v1(Profile::Mixed8a4w, 0.75, 96, 1),
        resnet20(Profile::Mixed4a2w, 2),
    ];
    for net in &nets {
        for isa in IsaVariant::ALL {
            check_deployment(net, isa, MemBudget::default())
                .unwrap_or_else(|e| panic!("{} on {isa}: {e}", net.name));
        }
    }
}

#[test]
fn prop_random_conv_chains_deploy_cleanly() {
    proptest::check(
        proptest::Config { cases: 24, base_seed: 0xD0_2E },
        |rng: &mut Prng| {
            let mut net = Network::new("rand", [rng.range(6, 20), 0, 0], 8);
            // square input
            net.input_shape[1] = net.input_shape[0];
            let cin = rng.range(1, 5) * 4;
            net.input_shape[2] = cin;
            let mut shape = net.input_shape;
            let n_layers = rng.range(1, 4);
            for i in 0..n_layers {
                let cout = rng.range(1, 5) * 4;
                let k = *rng.pick(&[1usize, 3]);
                let stride = if shape[0] >= 8 { *rng.pick(&[1usize, 2]) } else { 1 };
                let (a_bits, w_bits) = *rng.pick(&[(8u8, 8u8), (8, 4), (8, 2), (4, 4), (4, 2)]);
                let a_bits = if i == 0 { 8 } else { a_bits };
                let mut l = Layer::conv(
                    &format!("c{i}"),
                    shape,
                    cout,
                    k,
                    k,
                    stride,
                    k / 2,
                    a_bits,
                    w_bits,
                    a_bits, // out bits = next layer's a bits
                    rng,
                );
                // keep the chain's a_bits consistent
                if i + 1 == n_layers {
                    l.quant.out_bits = 8;
                }
                let prev_bits = if i == 0 { 8 } else { shape_bits(&net) };
                l.a_bits = prev_bits;
                shape = l.out_shape;
                net.push(l);
            }
            net
        },
        |net| {
            if net.validate().is_err() {
                return Ok(()); // generator made an inconsistent chain; skip
            }
            for isa in [IsaVariant::FlexV, IsaVariant::Ri5cy] {
                check_deployment(net, isa, MemBudget::default())?;
            }
            Ok(())
        },
    );
}

fn shape_bits(net: &Network) -> u8 {
    net.nodes.last().map(|n| n.layer.quant.out_bits).unwrap_or(net.input_bits)
}

/// Tiler invariants under *random L1 budgets* as well as random
/// geometries: every shape the analytic solver — and the autotuner's
/// candidate enumerator — emits must satisfy the double-buffered L1
/// working-set budget (including the per-core im2col scratch), the
/// channel-multiple-of-4 rule, and `chs * out_bits % 8 == 0`; the
/// enumerator must be analytic-cost-sorted with the solver's choice
/// first, and must be empty exactly when the solver finds nothing.
#[test]
fn prop_tiler_and_enumerator_respect_budget_and_alignment() {
    proptest::check(
        proptest::Config { cases: 64, base_seed: 0x71_E2 },
        |rng: &mut Prng| {
            let h = rng.range(4, 48);
            let cin = rng.range(1, 16) * 4;
            let cout = rng.range(1, 32) * 4;
            let a_bits = *rng.pick(&[2u8, 4, 8]);
            let w_bits = *rng.pick(&[2u8, 4, 8]);
            let out_bits = *rng.pick(&[2u8, 4, 8]);
            let k = *rng.pick(&[1usize, 3]);
            let isa = *rng.pick(&IsaVariant::ALL);
            let l1 = rng.range(8 * 1024, 128 * 1024);
            let g = ConvGeom::square(h, h, cin, cout, k, k, 1, k / 2, a_bits);
            (g, w_bits, out_bits, isa, l1)
        },
        |&(g, w_bits, out_bits, isa, l1)| {
            let w_pitch = w_row_pitch(g.k(), buf_bits(&g, isa), w_bits) as usize;
            let shapes = enumerate_conv_tilings(&g, isa, w_pitch, out_bits, l1, 8);
            let solved = solve_conv_tiling(&g, isa, w_pitch, out_bits, l1);
            match (solved, shapes.first()) {
                (None, None) => return Ok(()), // nothing fits: consistent
                (Some(s), Some(&first)) if s == first => {}
                (s, f) => return Err(format!("solver {s:?} != enumerator head {f:?}")),
            }
            let scratch = flexv::CLUSTER_CORES
                * isa.unroll().buffers
                * ((g.k() * buf_bits(&g, isa) as usize).div_ceil(32) * 4);
            let mut prev_cost = 0u64;
            for (i, &shape) in shapes.iter().enumerate() {
                if shape.chs % 4 != 0 || shape.chs * out_bits as usize % 8 != 0 {
                    return Err(format!("{shape:?} misaligned"));
                }
                if shape.rows > g.out_h() || shape.chs > g.cout {
                    return Err(format!("{shape:?} exceeds the layer"));
                }
                let tb = conv_tile_bytes(&g, w_pitch, out_bits, shape);
                let need = 2 * (tb.input + tb.weights + tb.output + tb.quant) + scratch;
                if need > l1 {
                    return Err(format!("{shape:?} needs {need} B of {l1} B budget"));
                }
                let cost = dma_cost(&g, w_pitch, out_bits, shape);
                if i > 0 && cost < prev_cost {
                    return Err(format!("candidates not cost-sorted at {i}: {cost} < {prev_cost}"));
                }
                prev_cost = cost;
            }
            Ok(())
        },
    );
}

/// The depthwise row-strip solver obeys the same budget rule (its
/// working set is double-buffered by `l1_layout` too).
#[test]
fn prop_dw_solver_respects_budget() {
    proptest::check(
        proptest::Config { cases: 48, base_seed: 0xD_0E5 },
        |rng: &mut Prng| {
            let h = rng.range(4, 64);
            let c = rng.range(1, 32) * 4;
            let a_bits = *rng.pick(&[2u8, 4, 8]);
            let w_bits = *rng.pick(&[2u8, 4, 8]);
            let stride = *rng.pick(&[1usize, 2]);
            let l1 = rng.range(4 * 1024, 128 * 1024);
            (h, c, a_bits, w_bits, stride, l1)
        },
        |&(h, c, a_bits, w_bits, stride, l1)| {
            let oh = (h + 2 - 3) / stride + 1;
            match solve_dw_tiling(h, h, c, 3, stride, a_bits, w_bits, a_bits, oh, l1) {
                None => Ok(()),
                Some(rows) => {
                    if rows == 0 || rows > oh {
                        return Err(format!("rows {rows} outside 1..={oh}"));
                    }
                    let in_rows = (rows - 1) * stride + 3;
                    let input = in_rows * h * c * a_bits as usize / 8;
                    let weights = 9 * c * w_bits as usize / 8;
                    let output = rows * h * c * a_bits as usize / 8;
                    let need = 2 * (input + weights + output + c * 8) + 64;
                    if need > l1 {
                        return Err(format!("rows {rows} needs {need} B of {l1} B"));
                    }
                    Ok(())
                }
            }
        },
    );
}
