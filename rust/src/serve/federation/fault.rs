//! Seeded, deterministic fault plans for the federation layer.
//!
//! A [`FaultPlan`] is a list of [`FaultEvent`]s pinned to **simulated
//! cycles** — shard failures (down for a window, in-flight work
//! retracted and re-queued) and stragglers (a shard runs N× slower for
//! a window). Plans come from three equivalent sources: constructed in
//! code, generated from a seed ([`FaultPlan::generate`]), or parsed
//! from the CLI spec mini-language ([`FaultPlan::parse`]):
//!
//! ```text
//! fail@CYCLE:rR.sS+DUR       shard S of region R fails at CYCLE for DUR cycles
//! slow@CYCLE:rR.sSxF+DUR     shard S of region R runs F× slower for DUR cycles
//! throttle@CYCLE:rR.sS+DUR   shard S of region R is thermally throttled for DUR
//! auto:K                     K seeded events over the plan span
//! ```
//!
//! (comma-separated, e.g. `fail@1000:r0.s1+5000,slow@2000:r1.s0x3+8000`).
//!
//! Because every event is pinned to a simulated cycle and applied by the
//! sequential federation event loop, the fault timeline — and everything
//! downstream of it (completions, re-queues, metrics, the exported
//! trace) — is part of the determinism contract: the same plan + seed
//! produces bit-identical results for any worker count or fast-path
//! setting (`rust/tests/federation_determinism.rs`).

use crate::util::Prng;

/// What goes wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The shard goes down for `down_cycles`: in-flight work is
    /// retracted and re-queued ([`crate::serve::Engine::fail_shard`]),
    /// and the shard recovers cold at the end of the window.
    ShardFail { region: usize, shard: usize, down_cycles: u64 },
    /// Batches starting on the shard during the window run `factor`×
    /// slower (timing overlay only — outputs, MACs and energy are
    /// untouched; see [`crate::serve::Shard::slow`]).
    Straggler { region: usize, shard: usize, factor: u64, slow_cycles: u64 },
    /// The shard hits its thermal limit: batches starting during the
    /// window are clamped to the efficiency operating point regardless
    /// of DVFS policy (slower but cooler; see
    /// [`crate::serve::Engine::throttle_shard`]).
    ThermalThrottle { region: usize, shard: usize, hot_cycles: u64 },
}

/// One planned fault at an absolute simulated cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub at: u64,
    pub kind: FaultKind,
}

/// What the federation actually did at a cycle — the *applied* fault
/// timeline ([`FaultPlan::timeline`] expands failures into an explicit
/// fail + recover pair). Part of the run's fingerprint: rendered in the
/// federation report and exported as trace instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultRecord {
    pub at: u64,
    pub region: usize,
    pub shard: usize,
    pub action: FaultAction,
}

/// The applied half of [`FaultKind`] (recovery is its own record so the
/// event loop — and the trace — see it as a first-class instant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    Fail { until: u64 },
    Recover,
    Slow { factor: u64, until: u64 },
    Throttle { until: u64 },
}

/// A deterministic fault-injection schedule.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Planned events; [`FaultPlan::timeline`] orders them.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan (no faults — the default).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Seeded plan: `n` events over `[span/8, 7*span/8)`, mixing
    /// failures, stragglers and thermal throttles by a three-way draw.
    /// Same seed, same plan.
    pub fn generate(seed: u64, regions: usize, shards: usize, n: usize, span: u64) -> Self {
        assert!(regions >= 1 && shards >= 1, "need at least one region and shard");
        let span = span.max(8);
        let mut rng = Prng::new(seed);
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let at = span / 8 + rng.below((span * 3 / 4).max(1));
            let region = rng.below(regions as u64) as usize;
            let shard = rng.below(shards as u64) as usize;
            let window = span / 8 + rng.below((span / 4).max(1));
            let kind = match rng.below(3) {
                0 => FaultKind::ShardFail { region, shard, down_cycles: window },
                1 => {
                    let factor = 2 + rng.below(3);
                    FaultKind::Straggler { region, shard, factor, slow_cycles: window }
                }
                _ => FaultKind::ThermalThrottle { region, shard, hot_cycles: window },
            };
            events.push(FaultEvent { at, kind });
        }
        FaultPlan { events }
    }

    /// Parse the CLI spec mini-language (see module docs). `seed` and
    /// `span` feed `auto:K` tokens; explicit tokens are validated
    /// against `regions`/`shards`.
    pub fn parse(
        spec: &str,
        seed: u64,
        regions: usize,
        shards: usize,
        span: u64,
    ) -> Result<Self, String> {
        let mut plan = FaultPlan::none();
        for token in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            if let Some(k) = token.strip_prefix("auto:") {
                let n: usize =
                    k.parse().map_err(|_| format!("bad auto count in `{token}`"))?;
                plan.events.extend(FaultPlan::generate(seed, regions, shards, n, span).events);
                continue;
            }
            let (tag, rest) = if let Some(r) = token.strip_prefix("fail@") {
                ('f', r)
            } else if let Some(r) = token.strip_prefix("slow@") {
                ('s', r)
            } else if let Some(r) = token.strip_prefix("throttle@") {
                ('t', r)
            } else {
                return Err(format!(
                    "bad fault token `{token}` (want fail@C:rR.sS+D, slow@C:rR.sSxF+D, \
                     throttle@C:rR.sS+D, or auto:K)"
                ));
            };
            let (at_s, loc) = rest
                .split_once(':')
                .ok_or_else(|| format!("missing `:` in `{token}`"))?;
            let at: u64 = at_s.parse().map_err(|_| format!("bad cycle in `{token}`"))?;
            let (loc, dur_s) = loc
                .split_once('+')
                .ok_or_else(|| format!("missing `+DUR` in `{token}`"))?;
            let dur: u64 = dur_s.parse().map_err(|_| format!("bad duration in `{token}`"))?;
            let (rs, rest) = loc
                .strip_prefix('r')
                .and_then(|l| l.split_once(".s"))
                .ok_or_else(|| format!("bad location in `{token}` (want rR.sS)"))?;
            let region: usize = rs.parse().map_err(|_| format!("bad region in `{token}`"))?;
            let kind = if tag == 's' {
                let (ss, fs) = rest
                    .split_once('x')
                    .ok_or_else(|| format!("missing `xF` in `{token}`"))?;
                let shard: usize = ss.parse().map_err(|_| format!("bad shard in `{token}`"))?;
                let factor: u64 = fs.parse().map_err(|_| format!("bad factor in `{token}`"))?;
                FaultKind::Straggler { region, shard, factor, slow_cycles: dur }
            } else {
                let shard: usize = rest.parse().map_err(|_| format!("bad shard in `{token}`"))?;
                if tag == 'f' {
                    FaultKind::ShardFail { region, shard, down_cycles: dur }
                } else {
                    FaultKind::ThermalThrottle { region, shard, hot_cycles: dur }
                }
            };
            let (r, s) = match kind {
                FaultKind::ShardFail { region, shard, .. }
                | FaultKind::Straggler { region, shard, .. }
                | FaultKind::ThermalThrottle { region, shard, .. } => (region, shard),
            };
            if r >= regions || s >= shards {
                return Err(format!(
                    "fault `{token}` out of range (have {regions} regions x {shards} shards)"
                ));
            }
            plan.events.push(FaultEvent { at, kind });
        }
        Ok(plan)
    }

    /// Expand into the applied-event timeline the federation loop walks:
    /// every failure contributes an explicit recovery record at the end
    /// of its window, and the whole list is stably ordered by cycle (so
    /// same-cycle events apply in plan order).
    pub fn timeline(&self) -> Vec<FaultRecord> {
        let mut out = Vec::new();
        for e in &self.events {
            match e.kind {
                FaultKind::ShardFail { region, shard, down_cycles } => {
                    let until = e.at.saturating_add(down_cycles);
                    out.push(FaultRecord {
                        at: e.at,
                        region,
                        shard,
                        action: FaultAction::Fail { until },
                    });
                    out.push(FaultRecord { at: until, region, shard, action: FaultAction::Recover });
                }
                FaultKind::Straggler { region, shard, factor, slow_cycles } => {
                    out.push(FaultRecord {
                        at: e.at,
                        region,
                        shard,
                        action: FaultAction::Slow {
                            factor,
                            until: e.at.saturating_add(slow_cycles),
                        },
                    });
                }
                FaultKind::ThermalThrottle { region, shard, hot_cycles } => {
                    out.push(FaultRecord {
                        at: e.at,
                        region,
                        shard,
                        action: FaultAction::Throttle {
                            until: e.at.saturating_add(hot_cycles),
                        },
                    });
                }
            }
        }
        out.sort_by_key(|r| r.at);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic_and_in_bounds() {
        let a = FaultPlan::generate(7, 2, 4, 16, 1_000_000);
        let b = FaultPlan::generate(7, 2, 4, 16, 1_000_000);
        assert_eq!(a, b, "same seed must produce the same plan");
        assert_eq!(a.len(), 16);
        for e in &a.events {
            assert!(e.at >= 125_000 && e.at < 875_000, "at {} out of span", e.at);
            match e.kind {
                FaultKind::ShardFail { region, shard, down_cycles } => {
                    assert!(region < 2 && shard < 4 && down_cycles > 0);
                }
                FaultKind::Straggler { region, shard, factor, slow_cycles } => {
                    assert!(region < 2 && shard < 4 && slow_cycles > 0);
                    assert!((2..5).contains(&factor));
                }
                FaultKind::ThermalThrottle { region, shard, hot_cycles } => {
                    assert!(region < 2 && shard < 4 && hot_cycles > 0);
                }
            }
        }
        assert_ne!(a, FaultPlan::generate(8, 2, 4, 16, 1_000_000), "seed must matter");
    }

    #[test]
    fn parse_round_trips_all_kinds_and_auto() {
        let plan = FaultPlan::parse(
            "fail@1000:r0.s1+5000, slow@2000:r1.s0x3+8000, throttle@3000:r1.s1+4000",
            1,
            2,
            2,
            100,
        )
        .unwrap();
        assert_eq!(
            plan.events,
            vec![
                FaultEvent {
                    at: 1000,
                    kind: FaultKind::ShardFail { region: 0, shard: 1, down_cycles: 5000 },
                },
                FaultEvent {
                    at: 2000,
                    kind: FaultKind::Straggler {
                        region: 1,
                        shard: 0,
                        factor: 3,
                        slow_cycles: 8000,
                    },
                },
                FaultEvent {
                    at: 3000,
                    kind: FaultKind::ThermalThrottle { region: 1, shard: 1, hot_cycles: 4000 },
                },
            ]
        );
        let auto = FaultPlan::parse("auto:5", 42, 2, 4, 1_000_000).unwrap();
        assert_eq!(auto.events, FaultPlan::generate(42, 2, 4, 5, 1_000_000).events);
    }

    #[test]
    fn parse_rejects_malformed_and_out_of_range() {
        for bad in [
            "nonsense",
            "fail@x:r0.s0+10",
            "fail@5:r0.s0",
            "slow@5:r0.s0+10", // missing xF
            "fail@5:r9.s0+10",     // region out of range
            "fail@5:r0.s9+10",     // shard out of range
            "throttle@5:r0.s9+10", // shard out of range
            "throttle@5:r0.s0x2+10", // throttle takes no factor
        ] {
            assert!(FaultPlan::parse(bad, 0, 2, 2, 100).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn timeline_pairs_failures_with_recoveries_in_cycle_order() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent {
                    at: 500,
                    kind: FaultKind::ShardFail { region: 1, shard: 0, down_cycles: 100 },
                },
                FaultEvent {
                    at: 200,
                    kind: FaultKind::Straggler {
                        region: 0,
                        shard: 1,
                        factor: 2,
                        slow_cycles: 50,
                    },
                },
            ],
        };
        let tl = plan.timeline();
        assert_eq!(tl.len(), 3, "fail expands to fail + recover");
        assert_eq!(tl[0].at, 200);
        assert_eq!(tl[0].action, FaultAction::Slow { factor: 2, until: 250 });
        assert_eq!(tl[1].action, FaultAction::Fail { until: 600 });
        assert_eq!(tl[2], FaultRecord {
            at: 600,
            region: 1,
            shard: 0,
            action: FaultAction::Recover,
        });
    }
}
