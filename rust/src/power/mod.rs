//! GF22FDX area / timing / energy model (§V-A, Table II).
//!
//! The paper's silicon numbers are the calibration anchors; our simulator
//! supplies the per-instruction-class activity. The model is deliberately
//! simple and fully documented:
//!
//! - **Area & fmax**: taken directly from Table II for RI5CY and Flex-V;
//!   MPIC and XpulpNN cores are placed between them using the overheads
//!   their own papers report (MPIC ~+11% vs RI5CY, XpulpNN ~+19%).
//! - **Energy**: `E_cycle = E_static + Σ_class E_class · activity_class`,
//!   with per-class energies fitted once so that (a) the 8-bit MatMul
//!   cluster power matches Table II (12.3→12.6 mW at 250 MHz typical) and
//!   (b) the Flex-V efficiency column of Table III is approached at the
//!   paper's efficiency corner. The same class energies are used for all
//!   four cores — variant differences come from their instruction mixes
//!   plus the small leakage deltas of Table II.
//!
//! TOPS/W for a kernel = `2 · MAC/cycle / E_cycle`, frequency-independent
//! apart from the leakage share, evaluated at the efficiency corner.

use crate::isa::IsaVariant;
use crate::sim::ClusterStats;

/// Table II anchors and derived constants for one core variant.
#[derive(Clone, Copy, Debug)]
pub struct VariantPhys {
    /// Max cluster frequency [MHz] (worst-case corner).
    pub fmax_mhz: f64,
    /// Core area [µm²].
    pub core_area_um2: f64,
    /// Cluster area [µm²] (8 cores + memories + interconnect).
    pub cluster_area_um2: f64,
    /// Cluster leakage power [mW].
    pub leak_mw: f64,
}

/// Baseline (RI5CY) cluster area minus its 8 cores = shared logic+SRAM.
const SHARED_AREA_UM2: f64 = 518_227.0 - 8.0 * 13_721.0;

/// Physical constants per variant.
pub fn phys(v: IsaVariant) -> VariantPhys {
    let (fmax, core, leak) = match v {
        // Table II, measured columns.
        IsaVariant::Ri5cy => (472.0, 13_721.0, 0.613),
        IsaVariant::FlexV => (463.0, 17_816.0, 0.710),
        // Interpolated from the MPIC [15] and XpulpNN [14] papers' reported
        // overheads over RI5CY (see DESIGN.md §2).
        IsaVariant::Mpic => (468.0, 15_230.0, 0.650),
        IsaVariant::XpulpNn => (466.0, 16_330.0, 0.680),
    };
    // Flex-V's cluster area is a measured Table II value (547211 µm²,
    // +5.59%); synthesis absorbs part of the core growth at cluster level,
    // so derived variants scale the core delta by the same absorption
    // factor observed between the two measured points.
    let absorption = (547_211.0 - 518_227.0) / (8.0 * (17_816.0 - 13_721.0));
    let cluster = match v {
        IsaVariant::Ri5cy => 518_227.0,
        IsaVariant::FlexV => 547_211.0,
        _ => SHARED_AREA_UM2 + 8.0 * 13_721.0 + 8.0 * (core - 13_721.0) * absorption,
    };
    VariantPhys {
        fmax_mhz: fmax,
        core_area_um2: core,
        cluster_area_um2: cluster,
        leak_mw: leak,
    }
}

/// Per-instruction-class energies [pJ], cluster-wide shared overheads
/// included via `shared_pj_per_cycle`. Fitted to the Table II / Table III
/// anchors (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Core issue/fetch/decode/RF per active cycle [pJ].
    pub base_pj: f64,
    /// Extra energy of a SIMD dotp by element width of the wider operand.
    pub dotp8_pj: f64,
    pub dotp4_pj: f64,
    pub dotp2_pj: f64,
    /// TCDM access (interconnect + bank) [pJ].
    pub mem_pj: f64,
    /// Mac&Load WB-load adder [pJ].
    pub macload_pj: f64,
    /// Shared cluster logic (icache, interconnect clocking, FC share) per
    /// cycle [pJ].
    pub shared_pj_per_cycle: f64,
    /// Clock-gated (barrier/idle) core cycle [pJ].
    pub gated_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Fit notes: with the Flex-V a8w8 MatMul mix (≈0.80 dotp/cycle/core,
        // ≈0.33 TCDM access/cycle/core) the cluster at 250 MHz must draw
        // ≈12.6 mW ⇒ ≈50 pJ/cycle; the sub-byte dotp energies then set the
        // Table III efficiency spread.
        EnergyModel {
            base_pj: 2.1,
            dotp8_pj: 2.6,
            dotp4_pj: 2.0,
            dotp2_pj: 1.6,
            mem_pj: 2.6,
            macload_pj: 0.5,
            shared_pj_per_cycle: 8.0,
            gated_pj: 0.25,
        }
    }
}

impl EnergyModel {
    /// Energy of one simulated window [pJ], activity-based.
    pub fn energy_pj(&self, v: IsaVariant, stats: &ClusterStats, dotp_bits: u8) -> f64 {
        let dotp_pj = match dotp_bits {
            8 => self.dotp8_pj,
            4 => self.dotp4_pj,
            2 => self.dotp2_pj,
            16 => self.dotp8_pj * 1.6,
            _ => self.dotp8_pj,
        };
        let mut e = stats.cycles as f64 * self.shared_pj_per_cycle;
        for c in &stats.cores {
            let active = c.cycles.saturating_sub(c.barrier_cycles) as f64;
            e += active * self.base_pj;
            e += c.barrier_cycles as f64 * self.gated_pj;
            e += c.dotp_instrs as f64 * dotp_pj;
            e += c.tcdm_accesses as f64 * self.mem_pj;
            e += c.macload_instrs as f64 * self.macload_pj;
        }
        // Leakage share at the 250 MHz typical corner.
        let leak_pj_per_cycle = phys(v).leak_mw * 1e-3 / 250e6 * 1e12;
        e += stats.cycles as f64 * leak_pj_per_cycle;
        e
    }

    /// Average cluster power [mW] at frequency `f_mhz` for a window.
    pub fn power_mw(&self, v: IsaVariant, stats: &ClusterStats, dotp_bits: u8, f_mhz: f64) -> f64 {
        let e_per_cycle = self.energy_pj(v, stats, dotp_bits) / stats.cycles.max(1) as f64;
        e_per_cycle * 1e-12 * f_mhz * 1e6 * 1e3
    }

    /// Energy efficiency [TOPS/W] = ops per joule (1 MAC = 2 ops).
    /// Frequency-independent except the leakage term already folded in.
    pub fn tops_per_watt(&self, v: IsaVariant, stats: &ClusterStats, dotp_bits: u8) -> f64 {
        let ops = 2.0 * stats.total_macs() as f64;
        let e_j = self.energy_pj(v, stats, dotp_bits) * 1e-12;
        ops / e_j / 1e12
    }
}

/// GOP/s of a kernel window at `f_mhz`.
pub fn gops(stats: &ClusterStats, f_mhz: f64) -> f64 {
    2.0 * stats.macs_per_cycle() * f_mhz * 1e6 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::CoreStats;

    fn synthetic_stats(dotp_per_core: u64, cycles: u64) -> ClusterStats {
        ClusterStats {
            cycles,
            cores: vec![
                CoreStats {
                    cycles,
                    instrs: cycles,
                    macs: dotp_per_core * 4,
                    dotp_instrs: dotp_per_core,
                    macload_instrs: dotp_per_core / 2,
                    tcdm_accesses: cycles / 3,
                    ..Default::default()
                };
                8
            ],
            ..Default::default()
        }
    }

    #[test]
    fn area_overheads_match_table2() {
        let r = phys(IsaVariant::Ri5cy);
        let f = phys(IsaVariant::FlexV);
        let core_ovh = (f.core_area_um2 - r.core_area_um2) / r.core_area_um2;
        assert!((core_ovh - 0.298).abs() < 0.01, "core overhead {core_ovh}");
        let cl_ovh = (f.cluster_area_um2 - r.cluster_area_um2) / r.cluster_area_um2;
        assert!((cl_ovh - 0.0559).abs() < 0.005, "cluster overhead {cl_ovh}");
        // fmax degradation ≈ 2%
        assert!((1.0 - f.fmax_mhz / r.fmax_mhz - 0.019).abs() < 0.01);
    }

    #[test]
    fn cluster_power_8b_matmul_near_table2() {
        // ~0.8 dotp/cycle/core on the 8b kernel.
        let stats = synthetic_stats(800, 1000);
        let m = EnergyModel::default();
        let p = m.power_mw(IsaVariant::FlexV, &stats, 8, 250.0);
        assert!(
            (10.0..16.0).contains(&p),
            "8b MatMul cluster power {p:.1} mW should be near Table II's 12.6"
        );
        // Flex-V draws slightly more than RI5CY (leakage delta)
        let pr = m.power_mw(IsaVariant::Ri5cy, &stats, 8, 250.0);
        assert!(p > pr && (p - pr) / pr < 0.05, "{p} vs {pr}");
    }

    #[test]
    fn efficiency_increases_with_narrower_formats() {
        let m = EnergyModel::default();
        let stats2 = {
            let mut s = synthetic_stats(900, 1000);
            for c in &mut s.cores {
                c.macs = c.dotp_instrs * 16; // a2w2: 16 MACs per sdotp
            }
            s
        };
        let stats8 = synthetic_stats(900, 1000);
        let e2 = m.tops_per_watt(IsaVariant::FlexV, &stats2, 2);
        let e8 = m.tops_per_watt(IsaVariant::FlexV, &stats8, 8);
        assert!(e2 > 2.0 * e8, "a2w2 {e2} should dwarf a8w8 {e8}");
        assert!(e2 > 2.0 && e2 < 6.0, "a2w2 eff {e2} out of plausible range");
    }

    #[test]
    fn barrier_cycles_cost_less_than_active() {
        let m = EnergyModel::default();
        let mut idle = synthetic_stats(0, 1000);
        for c in &mut idle.cores {
            c.tcdm_accesses = 0;
            c.barrier_cycles = 900;
        }
        let mut busy = synthetic_stats(0, 1000);
        for c in &mut busy.cores {
            c.tcdm_accesses = 0;
        }
        let ei = m.energy_pj(IsaVariant::FlexV, &idle, 8);
        let eb = m.energy_pj(IsaVariant::FlexV, &busy, 8);
        assert!(ei < eb);
    }
}
