//! Differential validation of the two core timing tiers
//! ([`flexv::sim::CoreFidelity`]): random kernel programs across the
//! ISA-variant × mixed-precision grid, plus end-to-end networks, run
//! under both the flat-cost fast tier and the 4-stage pipeline tier.
//!
//! The contract under test is structural (see `flexv::sim::pipeline`):
//! the pipeline tier charges its extra hazards — Mac&Load write-back
//! port contention and sub-word realignment — as retire-time cycle
//! charges, never as simulation ticks. Therefore
//!
//! 1. **all architectural state is bit-identical** across tiers
//!    (registers, NN-RF, CSRs, TCDM contents, network outputs), and
//! 2. **every other counter is identical too**: a pipeline-tier core's
//!    stats reduce exactly to the fast-tier stats after subtracting its
//!    `wbport_stalls + align_stalls` from `cycles`, and the cluster's
//!    wall cycles grow by exactly the slowest core's extra charges.
//!
//! The Table III anchor cells get the same treatment in
//! `report::workloads` (`pipeline_tier_never_speeds_up_table3`); this
//! suite covers the randomized grid and the end-to-end models.

use flexv::coordinator::Coordinator;
use flexv::dory::deploy::{deploy, w_row_pitch};
use flexv::dory::MemBudget;
use flexv::isa::{Csr, Instr, IsaVariant, MlChannel, Program, SimdFmt};
use flexv::kernels::matmul::{gen_matmul, MatMulTask};
use flexv::kernels::requant::RequantCfg;
use flexv::qnn::layer::Network;
use flexv::qnn::{Precision, QTensor};
use flexv::sim::{Cluster, ClusterStats, CoreFidelity, CoreStats, TCDM_BASE};
use flexv::util::{proptest, Prng};

/// Architectural state of one core after a run (everything the ISA
/// exposes; timing micro-state is deliberately excluded).
type CoreSnap = ([u32; 32], [u32; 6], [u32; 16], usize);

/// Everything one tier produces for the differential comparison.
struct TierRun {
    stats: ClusterStats,
    out: Vec<u8>,
    cores: Vec<CoreSnap>,
}

/// A pipeline-tier core's stats with its tier-specific charges removed.
/// If the retire-time model is implemented correctly this equals the
/// fast-tier stats of the same run *exactly* — one `assert_eq!` then
/// covers instrs, MACs, TCDM accesses, and every shared stall category.
fn without_pipeline_charges(mut s: CoreStats) -> CoreStats {
    s.cycles -= s.wbport_stalls + s.align_stalls;
    s.wbport_stalls = 0;
    s.align_stalls = 0;
    s
}

/// Random-but-valid MatMul workload in the Table III layout: packed A
/// rows, packed W rows (pitch from the deploy-side rule), per-channel
/// requant tables, 8 cores splitting the output rows.
#[derive(Debug)]
struct MatMulCase {
    isa: IsaVariant,
    prec: Precision,
    m: usize,
    n: usize,
    k: usize,
    seed: u64,
}

fn run_matmul_tier(c: &MatMulCase, fid: CoreFidelity) -> TierRun {
    let MatMulCase { isa, prec, m, n, k, seed } = *c;
    let mut rng = Prng::new(seed);
    // Effective kernel width decides W padding (see kernels::matmul).
    let e_bits = if isa.native_fmts().contains(&SimdFmt::from_bits(prec.a_bits)) {
        prec.a_bits
    } else {
        8
    };
    let a_pitch = (k.div_ceil(32 / prec.a_bits as usize) * 4) as u32;
    let w_pitch = w_row_pitch(k, e_bits, prec.w_bits);
    let a_base = TCDM_BASE;
    let w_base = a_base + m as u32 * a_pitch;
    let mult_base = w_base + n as u32 * w_pitch;
    let bias_base = mult_base + 4 * n as u32;
    let out_base = bias_base + 4 * n as u32;
    assert!(
        (out_base - TCDM_BASE) as usize + m * n <= flexv::TCDM_BYTES,
        "generated workload must fit TCDM"
    );
    let mut cl = Cluster::with_fidelity(8, fid);
    let a = QTensor::random(
        &[m, a_pitch as usize * 8 / prec.a_bits as usize],
        prec.a_bits,
        false,
        &mut rng,
    );
    let w = QTensor::random(
        &[n, w_pitch as usize * 8 / prec.w_bits as usize],
        prec.w_bits,
        true,
        &mut rng,
    );
    cl.mem.write_bytes(a_base, &a.data);
    cl.mem.write_bytes(w_base, &w.data);
    for ch in 0..n {
        cl.mem.store_u32(mult_base + 4 * ch as u32, 1 + (ch as u32 % 3));
        cl.mem.store_u32(bias_base + 4 * ch as u32, ch as u32);
    }
    let task = MatMulTask {
        m,
        n,
        k,
        prec,
        a_base,
        a_pitch,
        w_base,
        w_pitch,
        out_base,
        out_pitch: n as u32,
        quant: RequantCfg { mult_base, bias_base, shift: 10, out_bits: 8 },
    };
    cl.load_programs((0..8).map(|core| gen_matmul(isa, &task, core, 8)).collect());
    let stats = cl.run();
    let out = (0..m * n).map(|i| cl.mem.load_u8(out_base + i as u32)).collect();
    let cores = cl.cores.iter().map(|c| (c.regs, c.nnrf, c.csrs, c.pc)).collect();
    TierRun { stats, out, cores }
}

/// The full differential contract between one fast-tier and one
/// pipeline-tier run of the same workload.
fn assert_tiers_agree(f: &TierRun, p: &TierRun, what: &str) -> Result<(), String> {
    if f.out != p.out {
        return Err(format!("{what}: output bytes diverge across tiers"));
    }
    if f.cores != p.cores {
        return Err(format!("{what}: core architectural state diverges across tiers"));
    }
    for (i, (fc, pc)) in f.stats.cores.iter().zip(&p.stats.cores).enumerate() {
        if fc.wbport_stalls != 0 || fc.align_stalls != 0 {
            return Err(format!("{what}: core {i} charged pipeline stalls on the fast tier"));
        }
        let reduced = without_pipeline_charges(*pc);
        if reduced != *fc {
            return Err(format!(
                "{what}: core {i} pipeline stats don't reduce to fast stats: {pc:?} vs {fc:?}"
            ));
        }
    }
    // Wall cycles grow by exactly the slowest core's extra charges
    // (single window, no DMA in these runs).
    let max_extra = p
        .stats
        .cores
        .iter()
        .map(|c| c.wbport_stalls + c.align_stalls)
        .max()
        .unwrap_or(0);
    if p.stats.cycles != f.stats.cycles + max_extra {
        return Err(format!(
            "{what}: pipeline wall {} != fast wall {} + max core extra {}",
            p.stats.cycles, f.stats.cycles, max_extra
        ));
    }
    Ok(())
}

#[test]
fn prop_random_matmuls_bit_identical_across_tiers() {
    proptest::check(
        proptest::Config { cases: 12, base_seed: 0xF1DE_17 },
        |rng: &mut Prng| {
            let grid = Precision::grid();
            MatMulCase {
                isa: *rng.pick(&IsaVariant::ALL),
                prec: *rng.pick(&grid),
                m: rng.range(1, 5) * 8,
                n: rng.range(1, 5) * 4,
                k: rng.range(1, 4) * 16,
                seed: rng.below(1u64 << 32),
            }
        },
        |case| {
            let f = run_matmul_tier(case, CoreFidelity::Fast);
            let p = run_matmul_tier(case, CoreFidelity::Pipeline);
            let what = format!(
                "{:?} {} m={} n={} k={}",
                case.isa, case.prec, case.m, case.n, case.k
            );
            assert_tiers_agree(&f, &p, &what)
        },
    );
}

/// A handcrafted program in which both pipeline-only hazard classes
/// provably fire: an NN-RF write-back load followed cycle-adjacent by a
/// GP-LSU word load (WB-port contention), then a sub-word load feeding
/// its consumer directly (realignment). The fast tier must charge
/// neither; the pipeline tier must charge exactly one of each, and the
/// architectural results must still match bit-for-bit.
#[test]
fn adversarial_hazard_program_fires_both_stall_classes() {
    let run = |fid: CoreFidelity| {
        let mut cl = Cluster::with_fidelity(1, fid);
        cl.mem.store_u32(TCDM_BASE, 0x0102_0304); // NN-RF weight stream
        cl.mem.store_u32(TCDM_BASE + 64, 7); // word operand
        cl.mem.store_u8(TCDM_BASE + 68, 9); // sub-word operand
        let mut p = Program::new("hazards");
        p.push(Instr::CsrW { csr: Csr::WStride, imm: 4 });
        p.push(Instr::CsrW { csr: Csr::WBase, imm: TCDM_BASE });
        p.push(Instr::Li { rd: 1, imm: (TCDM_BASE + 64) as i32 });
        p.push(Instr::NnLoad { ch: MlChannel::Wgt, slot: 0 });
        p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 }); // wbport
        p.push(Instr::Lbu { rd: 3, base: 1, off: 4, post_inc: 0 });
        p.push(Instr::Alu {
            op: flexv::isa::AluOp::Add,
            rd: 4,
            rs1: 3,
            rs2: 2,
        }); // load-use + align
        p.push(Instr::Halt);
        cl.load_programs(vec![p]);
        let stats = cl.run();
        let c = &cl.cores[0];
        ((c.regs, c.nnrf), stats)
    };
    let (fa, fs) = run(CoreFidelity::Fast);
    let (pa, ps) = run(CoreFidelity::Pipeline);
    assert_eq!(fa, pa, "architectural state must not depend on the tier");
    assert_eq!(fa.0[4], 16, "9 + 7 through both hazards");
    assert_eq!((fs.cores[0].wbport_stalls, fs.cores[0].align_stalls), (0, 0));
    assert_eq!((ps.cores[0].wbport_stalls, ps.cores[0].align_stalls), (1, 1));
    assert_eq!(fs.cores[0].loaduse_stalls, ps.cores[0].loaduse_stalls);
    assert_eq!(ps.cycles, fs.cycles + 2, "one wbport + one align charge");
}

/// Deploy + run `net` end-to-end on both tiers with the same input and
/// assert the strongest cross-tier statement the coordinator exposes:
/// every node output bit-identical, every per-layer cycle count ordered
/// pipeline ≥ fast.
fn e2e_crosscheck(net: &Network, isa: IsaVariant, input_seed: u64) {
    let dep = deploy(net, isa, MemBudget::default());
    let mut rng = Prng::new(input_seed);
    let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
    let mut cf = Coordinator::new(8);
    let rf = cf.run(&dep, &input);
    let mut cp = Coordinator::with_fidelity(8, CoreFidelity::Pipeline);
    let rp = cp.run(&dep, &input);
    assert_eq!(rf.output, rp.output, "{}: final output diverges", net.name);
    assert_eq!(rf.node_outputs, rp.node_outputs, "{}: node outputs diverge", net.name);
    for (i, (lf, lp)) in rf.layers.iter().zip(&rp.layers).enumerate() {
        assert!(
            lp.stats.cycles >= lf.stats.cycles,
            "{}: layer {i} ({}) pipeline {} < fast {}",
            net.name,
            lf.name,
            lp.stats.cycles,
            lf.stats.cycles
        );
    }
    assert!(rp.total_cycles() >= rf.total_cycles());
    assert!(rf.total_cycles() > 0);
}

#[test]
fn resnet20_e2e_bit_identical_across_tiers() {
    let net = flexv::models::resnet20(flexv::models::Profile::Mixed4a2w, 5);
    e2e_crosscheck(&net, IsaVariant::FlexV, 0xCC_01);
}

#[test]
fn mnv1_e2e_bit_identical_across_tiers() {
    // Reduced input resolution keeps the depthwise/pointwise chain
    // (every kernel kind MNV1 exercises) at test-friendly cycle counts.
    let net = flexv::models::by_name("mnv1-8b4b", 32).expect("model zoo");
    e2e_crosscheck(&net, IsaVariant::FlexV, 0xCC_02);
}
