//! The four benchmark-artifact suites (`bench-report --suite …`).
//!
//! Every suite draws its rows from the *same* measurement path the
//! pretty-printed tables and the `benches/*.rs` harnesses use
//! ([`super::table3_cells`]-style cell functions, [`table4_cells`],
//! [`tune_network`], [`crate::serve::Engine::run_trace`]), wrapped in
//! [`MetricSource`]s — so a bench, a table, and a `BENCH_<suite>.json`
//! artifact can never disagree about a number:
//!
//! - **kernels** — the Table III MatMul grid and the Fig. 7 conv grid,
//!   every ISA × precision: cycles, MACs, MAC/cycle (exact) and TOPS/W
//!   (analog), with the paper's Flex-V Table III anchors attached;
//! - **e2e** — Table IV end-to-end networks on RI5CY/XpulpNN/Flex-V:
//!   per-inference cycles, MACs, MAC/cycle (exact, paper anchors
//!   attached) plus model footprints, and one Flex-V row per extension
//!   zoo model (`crate::models::ZOO_NAMES` beyond Table IV — no paper
//!   anchors);
//! - **autotune** — the simulator-in-the-loop tuner over the model zoo:
//!   measured default vs tuned cycle totals and improved-layer counts
//!   (all exact — tuning is deterministic);
//! - **serve** — one bursty 3-tier SLO trace on an autoscaled 4-shard
//!   fleet: every simulated [`crate::serve::FleetMetrics`] field
//!   (latency percentiles in cycles, MAC/cycle, µJ/request, per-class
//!   miss/shed counts…), plus a 2-region federated scenario with a
//!   pinned shard failure, straggler window and live rollout
//!   ([`federation_scenario`]: per-region, failure-mode and rollout
//!   rows). Host-side knobs ([`BenchOptions::workers`]) change
//!   wall-clock time only; the emitted rows are bit-identical for any
//!   value — CI's perf gate runs the suite at `--workers 1` and
//!   `--workers 4` and diffs the artifacts byte-for-byte.

use super::artifact::{BenchArtifact, MetricRow, MetricSource, RunMeta};
use super::workloads::{conv_fig7_stats_fid, matmul_table3_stats_fid};
use super::{table4_cells, E2eCell};
use crate::dory::autotune::{tune_network, TuneConfig, TunedModelMetrics};
use crate::dory::MemBudget;
use crate::isa::IsaVariant;
use crate::power::EnergyModel;
use crate::qnn::Precision;
use crate::serve::{
    standard_mix, AutoscaleConfig, Engine, ServeConfig, SloClass, TraceShape, WorkloadSpec,
};
use crate::sim::{ClusterStats, CoreFidelity};

/// The suites `bench-report` / `regress` know, in canonical order.
pub const SUITE_NAMES: [&str; 4] = ["kernels", "e2e", "autotune", "serve"];

/// Knobs of one artifact run. Only `full` changes simulated numbers
/// (input resolutions / trace sizes); `workers` is host-side
/// parallelism and must never move a row.
#[derive(Clone, Copy, Debug)]
pub struct BenchOptions {
    /// Full-size inputs (224×224 MobileNet, larger traces) instead of
    /// the quick CI defaults.
    pub full: bool,
    /// Host threads for the serve suite (0 = auto). Wall-clock only.
    pub workers: usize,
    /// Core timing tier of the kernels suite's clusters
    /// ([`crate::sim::CoreFidelity`]): MAC counts are tier-independent,
    /// cycle rows are not. The default fast tier keeps the artifact
    /// byte-identical to the committed baselines; the pipeline tier's
    /// artifact is compared across worker counts, never against the
    /// fast baseline.
    pub fidelity: CoreFidelity,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { full: false, workers: 0, fidelity: CoreFidelity::Fast }
    }
}

/// Stable lowercase id token of an ISA (the CLI spelling,
/// [`IsaVariant::from_name`]-compatible).
pub fn isa_id(isa: IsaVariant) -> &'static str {
    match isa {
        IsaVariant::Ri5cy => "ri5cy",
        IsaVariant::Mpic => "mpic",
        IsaVariant::XpulpNn => "xpulpnn",
        IsaVariant::FlexV => "flexv",
    }
}

/// `git rev-parse --short=12 HEAD` of the working tree, `unknown`
/// outside a repository. Metadata only — `regress` never compares it.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn meta(seed: u64, opts: &BenchOptions) -> RunMeta {
    RunMeta {
        git_rev: git_rev(),
        seed,
        quick: !opts.full,
        sim: format!(
            "{} cores, {} kB TCDM, {} banks",
            crate::CLUSTER_CORES,
            crate::TCDM_BYTES / 1024,
            crate::TCDM_BANKS
        ),
    }
}

/// Run one suite and persist its artifact to `path` — the `--artifact`
/// mode of every `benches/*.rs` harness. The bench prints its human
/// tables, then calls this to re-measure through the shared suite
/// builder, so the persisted rows are byte-identical to what
/// `bench-report` emits (the simulator is deterministic, so the same
/// workloads produce the same numbers both times).
pub fn write_artifact(suite: &str, opts: &BenchOptions, path: &str) -> Result<usize, String> {
    let art = run_suite(suite, opts).ok_or_else(|| format!("unknown suite '{suite}'"))?;
    std::fs::write(path, art.to_json()).map_err(|e| format!("cannot write {path}: {e}"))?;
    Ok(art.rows.len())
}

/// Scan the process arguments for `--artifact FILE` and, when present,
/// persist `suite` through [`write_artifact`] — the single entry point
/// behind every bench harness's `--artifact` mode. Panics on a missing
/// path or write failure (a bench run that asked for an artifact and
/// silently produced none would defeat the gate).
pub fn write_artifact_from_args(suite: &str, opts: &BenchOptions) {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--artifact") {
        let path = args.get(i + 1).expect("--artifact needs a file path");
        let n = write_artifact(suite, opts, path).unwrap_or_else(|e| panic!("{e}"));
        println!("artifact: {suite} suite, {n} metrics -> {path}");
    }
}

/// Run one suite by name (`None` for an unknown name).
pub fn run_suite(name: &str, opts: &BenchOptions) -> Option<BenchArtifact> {
    match name {
        "kernels" => Some(kernels_suite(opts)),
        "e2e" => Some(e2e_suite(opts)),
        "autotune" => Some(autotune_suite(opts)),
        "serve" => Some(serve_suite(opts)),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Paper anchors (Tables III / IV).
// ---------------------------------------------------------------------------

/// Table III Flex-V anchors: `(a_bits, w_bits, MAC/cycle, TOPS/W)`.
pub const PAPER_TABLE3_FLEXV: [(u8, u8, f64, f64); 6] = [
    (2, 2, 91.5, 3.26),
    (4, 2, 51.9, 1.87),
    (4, 4, 50.6, 1.71),
    (8, 2, 27.8, 1.01),
    (8, 4, 27.6, 0.96),
    (8, 8, 26.9, 0.87),
];

/// Table III XpulpNN a4w2 anchor — the mixed-precision collapse the
/// paper contrasts Flex-V against.
pub const PAPER_TABLE3_XPULPNN_A4W2: f64 = 7.62;

/// Table IV end-to-end MAC/cycle anchors, `(isa id, [MNV1-8b,
/// MNV1-8b4b, ResNet20-4b2b])` in [`crate::models::MODEL_NAMES`] order.
pub const PAPER_TABLE4: [(&str, [f64; 3]); 3] = [
    ("ri5cy", [5.6, 3.2, 4.8]),
    ("xpulpnn", [6.0, 2.7, 4.4]),
    ("flexv", [6.0, 5.8, 11.2]),
];

/// Paper anchors for one kernel-grid cell (MatMul cells only — the
/// paper's Fig. 7 conv numbers are chart-read, not tabulated).
fn paper_kernel_refs(
    kernel: &str,
    isa: IsaVariant,
    prec: Precision,
) -> (Option<f64>, Option<f64>) {
    if kernel != "matmul" {
        return (None, None);
    }
    if isa == IsaVariant::FlexV {
        for (a, w, mac, eff) in PAPER_TABLE3_FLEXV {
            if a == prec.a_bits && w == prec.w_bits {
                return (Some(mac), Some(eff));
            }
        }
    }
    if isa == IsaVariant::XpulpNn && prec.a_bits == 4 && prec.w_bits == 2 {
        return (Some(PAPER_TABLE3_XPULPNN_A4W2), None);
    }
    (None, None)
}

// ---------------------------------------------------------------------------
// kernels
// ---------------------------------------------------------------------------

/// One kernel-grid measurement (a Table III / Fig. 7 cell) as a metric
/// source.
pub struct KernelCellSource {
    /// `"matmul"` (Table III) or `"conv"` (Fig. 7).
    pub kernel: &'static str,
    pub isa: IsaVariant,
    pub prec: Precision,
    pub stats: ClusterStats,
    pub tops_per_watt: f64,
    pub paper_macs: Option<f64>,
    pub paper_eff: Option<f64>,
}

impl MetricSource for KernelCellSource {
    fn metric_rows(&self) -> Vec<MetricRow> {
        let p = format!("kernels/{}/{}/{}", self.kernel, isa_id(self.isa), self.prec);
        let mut mac =
            MetricRow::exact(format!("{p}/mac_per_cycle"), self.stats.macs_per_cycle(), "MAC/cycle");
        if let Some(v) = self.paper_macs {
            mac = mac.with_paper(v);
        }
        let mut eff =
            MetricRow::analog(format!("{p}/tops_per_watt"), self.tops_per_watt, "TOPS/W");
        if let Some(v) = self.paper_eff {
            eff = eff.with_paper(v);
        }
        vec![
            MetricRow::exact(format!("{p}/cycles"), self.stats.cycles as f64, "cycles"),
            MetricRow::exact(format!("{p}/macs"), self.stats.total_macs() as f64, "MACs"),
            mac,
            eff,
        ]
    }
}

/// The kernel grids of Table III (MatMul) and Fig. 7 (conv): every ISA
/// × precision, 48 short cluster simulations.
pub fn kernels_suite(opts: &BenchOptions) -> BenchArtifact {
    let em = EnergyModel::default();
    let mut run_meta = meta(0x7AB3, opts);
    // Mark non-default tiers in the metadata only: the default fast
    // artifact must stay byte-identical to the committed baselines.
    if opts.fidelity != CoreFidelity::Fast {
        run_meta.sim = format!("{}, {} core tier", run_meta.sim, opts.fidelity);
    }
    let mut art = BenchArtifact::new("kernels", run_meta);
    for kernel in ["matmul", "conv"] {
        for isa in IsaVariant::ALL {
            for prec in Precision::grid() {
                let stats = if kernel == "matmul" {
                    matmul_table3_stats_fid(isa, prec, opts.fidelity)
                } else {
                    conv_fig7_stats_fid(isa, prec, opts.fidelity)
                };
                let tops_per_watt = em.tops_per_watt(isa, &stats, prec.a_bits.max(prec.w_bits));
                let (paper_macs, paper_eff) = paper_kernel_refs(kernel, isa, prec);
                art.push_source(&KernelCellSource {
                    kernel,
                    isa,
                    prec,
                    stats,
                    tops_per_watt,
                    paper_macs,
                    paper_eff,
                });
            }
        }
    }
    art
}

// ---------------------------------------------------------------------------
// e2e
// ---------------------------------------------------------------------------

/// One Table IV cell as a metric source.
pub struct E2eCellSource {
    pub cell: E2eCell,
    pub paper_macs: Option<f64>,
}

impl MetricSource for E2eCellSource {
    fn metric_rows(&self) -> Vec<MetricRow> {
        let p = format!("e2e/{}/{}", self.cell.model, isa_id(self.cell.isa));
        let mut mac =
            MetricRow::exact(format!("{p}/mac_per_cycle"), self.cell.macs_per_cycle(), "MAC/cycle");
        if let Some(v) = self.paper_macs {
            mac = mac.with_paper(v);
        }
        vec![
            MetricRow::exact(format!("{p}/cycles"), self.cell.cycles as f64, "cycles"),
            MetricRow::exact(format!("{p}/macs"), self.cell.macs as f64, "MACs"),
            mac,
            MetricRow::analog(format!("{p}/energy_uj"), self.cell.energy_pj * 1e-6, "uJ/inf"),
            MetricRow::analog(format!("{p}/tops_per_watt"), self.cell.tops_per_watt(), "TOPS/W"),
        ]
    }
}

/// A model's static footprint (Table IV's memory rows).
pub struct ModelFootprintSource {
    pub model: &'static str,
    pub bytes: usize,
}

impl MetricSource for ModelFootprintSource {
    fn metric_rows(&self) -> Vec<MetricRow> {
        vec![MetricRow::exact(
            format!("e2e/{}/model_kb", self.model),
            self.bytes as f64 / 1024.0,
            "kB",
        )]
    }
}

/// Table IV end-to-end networks ([`table4_cells`]) plus model
/// footprints. Quick mode (the default) uses 96×96 MobileNet inputs
/// like the CI table run — MAC/cycle is input-size-insensitive.
pub fn e2e_suite(opts: &BenchOptions) -> BenchArtifact {
    let quick = !opts.full;
    let hw = if quick { 96 } else { 224 };
    let mut art = BenchArtifact::new("e2e", meta(0xE2E, opts));
    for model in crate::models::MODEL_NAMES {
        let net = crate::models::by_name(model, hw).expect("registry model");
        art.push_source(&ModelFootprintSource { model, bytes: net.model_bytes() });
    }
    for cell in table4_cells(quick) {
        let paper_macs = PAPER_TABLE4
            .iter()
            .find(|(id, _)| *id == isa_id(cell.isa))
            .and_then(|(_, vals)| {
                crate::models::MODEL_NAMES
                    .iter()
                    .position(|m| *m == cell.model)
                    .map(|i| vals[i])
            });
        art.push_source(&E2eCellSource { cell, paper_macs });
    }
    // Extension zoo (the committed .qir models beyond Table IV):
    // footprint plus one Flex-V cell each — there are no paper anchors
    // for these, so `paper_macs` stays empty and regress treats the
    // rows as repo-only metrics.
    for &model in crate::models::ZOO_NAMES.iter() {
        if crate::models::MODEL_NAMES.contains(&model) {
            continue;
        }
        let net = crate::models::by_name(model, hw).expect("zoo model");
        art.push_source(&ModelFootprintSource { model, bytes: net.model_bytes() });
        let (cycles, macs, energy_pj) = super::workloads::e2e_stats(IsaVariant::FlexV, &net);
        let cell = E2eCell { model, isa: IsaVariant::FlexV, cycles, macs, energy_pj };
        art.push_source(&E2eCellSource { cell, paper_macs: None });
    }
    art
}

// ---------------------------------------------------------------------------
// autotune
// ---------------------------------------------------------------------------

/// The simulator-in-the-loop autotuner over the model zoo: measured
/// default vs tuned per-inference cycle totals. Quick mode tunes the
/// two mixed-precision networks CI smoke-tests; `--full` tunes all
/// three at 224×224.
pub fn autotune_suite(opts: &BenchOptions) -> BenchArtifact {
    let models: &[&str] = if opts.full {
        &crate::models::MODEL_NAMES
    } else {
        &["mnv1-8b4b", "resnet20-4b2b"]
    };
    let hw = if opts.full { 224 } else { 96 };
    let mut art = BenchArtifact::new("autotune", meta(0, opts));
    for &model in models {
        let net = crate::models::by_name(model, hw).expect("registry model");
        let tuning = tune_network(
            &net,
            IsaVariant::FlexV,
            MemBudget::default(),
            crate::CLUSTER_CORES,
            &TuneConfig::default(),
        );
        art.push_source(&TunedModelMetrics { model, tuning: &tuning });
    }
    art
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

/// The serve suite's scenario: a bursty 3-tier SLO trace over the
/// standard 3-model mix on an autoscaled 1..=4-shard fleet (the same
/// shape `benches/serve_throughput.rs` stresses). Returns the fleet
/// report; every simulated field is a pure function of the spec.
pub fn serve_scenario(opts: &BenchOptions) -> crate::serve::FleetMetrics {
    let hw = if opts.full { 224 } else { 96 };
    let requests = if opts.full { 48 } else { 24 };
    let mut ac = AutoscaleConfig::range(1, 4);
    // Park quickly relative to the trace's mean gap so valleys show in
    // the occupancy metrics (mirrors the throughput bench's scenario).
    ac.idle_cycles_down = 20_000_000;
    ac.cooldown_cycles = 2_000_000;
    let cfg = ServeConfig {
        shards: 4,
        workers: opts.workers,
        autoscale: Some(ac),
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(cfg);
    for net in standard_mix(hw) {
        eng.register(net);
    }
    let mut spec = WorkloadSpec::new(TraceShape::Bursty, requests, 1_500_000, 3);
    spec.mix = vec![0.45, 0.30, 0.25];
    spec.classes = SloClass::standard_tiers(40_000_000);
    spec.seed = SERVE_SUITE_SEED;
    let trace = eng.workload_trace(&spec);
    eng.run_trace(trace)
}

/// Seed of the serve suite's workload spec.
pub const SERVE_SUITE_SEED: u64 = 0x51EBE;

/// The serve suite's federation scenario: the same 3-model mix spread
/// over 2 least-loaded regions of 2 shards, with one mid-trace shard
/// failure (in-flight work re-queued), one straggler window, and a live
/// rollout of tuned plans onto region 1 — the source of the
/// `serve/region*`, `serve/faults/*` and `serve/rollout/*` rows. Every
/// fault cycle is pinned, so the report is a pure function of the spec
/// (byte-identical across `opts.workers`, like [`serve_scenario`]).
pub fn federation_scenario(opts: &BenchOptions) -> crate::serve::FederationMetrics {
    use crate::serve::{FaultPlan, Federation, FederationConfig, RolloutPlan, RouterPolicy};
    let hw = if opts.full { 224 } else { 96 };
    let requests = if opts.full { 48 } else { 24 };
    let cfg = ServeConfig { shards: 2, workers: opts.workers, ..ServeConfig::default() };
    let span = 1_500_000u64 * requests as u64;
    let fault_spec = format!(
        "fail@{}:r0.s0+{},slow@{}:r1.s0x3+{}",
        span / 8,
        span / 4,
        span / 4,
        span / 4,
    );
    let faults = FaultPlan::parse(&fault_spec, SERVE_SUITE_SEED, 2, 2, span)
        .expect("static fault spec parses");
    let fed_cfg = FederationConfig {
        regions: 2,
        engine: cfg,
        policy: RouterPolicy::LeastLoaded,
        faults,
        rollout: Some(RolloutPlan { at: span * 3 / 4, canary: 1 }),
    };
    let mut fed = Federation::new(fed_cfg);
    for net in standard_mix(hw) {
        fed.register(net);
    }
    let mut spec = WorkloadSpec::new(TraceShape::Bursty, requests, 1_500_000, 3);
    spec.mix = vec![0.45, 0.30, 0.25];
    spec.classes = SloClass::standard_tiers(40_000_000);
    spec.seed = SERVE_SUITE_SEED;
    let trace = fed.workload_trace(&spec);
    fed.run_trace(trace)
}

/// The serve suite's power-capped scenario: the federation shape of
/// [`federation_scenario`] (minus faults and rollout) under the `slo`
/// DVFS policy and a fleet power cap sized to fund ~3 of the 4 shards
/// at the efficiency point — the source of the capped `serve/capped/*`
/// rows (energy/request, fleet average power ≤ cap, fleet TOPS/W).
pub fn power_capped_scenario(opts: &BenchOptions) -> crate::serve::FederationMetrics {
    use crate::power::{operating_points, DvfsPolicy, EnergyModel, OP_EFFICIENCY};
    use crate::serve::{FaultPlan, Federation, FederationConfig, RouterPolicy};
    let hw = if opts.full { 224 } else { 96 };
    let requests = if opts.full { 48 } else { 24 };
    let isa = ServeConfig::default().isa;
    let shard_floor_mw = EnergyModel::default().busy_power_bound_mw(
        isa,
        ServeConfig::default().n_cores,
        &operating_points(isa)[OP_EFFICIENCY],
    );
    // Fleet cap for 3 of 2x2 shards at the efficiency floor, split
    // evenly across the two regions (the serve-bench CLI does the same).
    let cap_per_region = 1.5 * shard_floor_mw;
    let cfg = ServeConfig {
        shards: 2,
        workers: opts.workers,
        power_cap_mw: Some(cap_per_region),
        dvfs: DvfsPolicy::Slo,
        ..ServeConfig::default()
    };
    let fed_cfg = FederationConfig {
        regions: 2,
        engine: cfg,
        policy: RouterPolicy::LeastLoaded,
        faults: FaultPlan::none(),
        rollout: None,
    };
    let mut fed = Federation::new(fed_cfg);
    for net in standard_mix(hw) {
        fed.register(net);
    }
    let mut spec = WorkloadSpec::new(TraceShape::Bursty, requests, 1_500_000, 3);
    spec.mix = vec![0.45, 0.30, 0.25];
    spec.classes = SloClass::standard_tiers(40_000_000);
    spec.seed = SERVE_SUITE_SEED;
    let trace = fed.workload_trace(&spec);
    fed.run_trace(trace)
}

/// Re-id a source's rows under a prefix (`serve/region0/...` →
/// `capped/serve/region0/...`) so two scenarios emitting the same row
/// schema can share one artifact without colliding on ids.
pub struct PrefixSource<'a> {
    pub prefix: &'static str,
    pub inner: &'a dyn MetricSource,
}

impl MetricSource for PrefixSource<'_> {
    fn metric_rows(&self) -> Vec<MetricRow> {
        let mut rows = self.inner.metric_rows();
        for r in &mut rows {
            r.id = format!("{}/{}", self.prefix, r.id);
        }
        rows
    }
}

/// The serve fleet under a bursty SLO workload, serialized through
/// [`crate::serve::FleetMetrics`]'s [`MetricSource`] impl (simulated
/// fields only — fast-path counters and wall-clock never appear), plus
/// the federated scenario's per-region / failure-mode / rollout rows
/// ([`federation_scenario`]) and the power-capped DVFS scenario's
/// energy rows under the `capped/` id prefix
/// ([`power_capped_scenario`]).
pub fn serve_suite(opts: &BenchOptions) -> BenchArtifact {
    let m = serve_scenario(opts);
    let mut art = BenchArtifact::new("serve", meta(SERVE_SUITE_SEED, opts));
    art.push_source(&m);
    art.push_source(&federation_scenario(opts));
    let capped = power_capped_scenario(opts);
    art.push_source(&PrefixSource { prefix: "capped", inner: &capped });
    art
}
