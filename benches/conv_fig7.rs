//! Bench: Fig. 7 — full convolution layers (im2col + MatMul + requant)
//! across the precision grid and all cores, with speedup ratios.
//!
//! Pass `--artifact FILE` to also persist the `kernels` benchmark
//! artifact (via the shared `report::bench` suite builder, so these
//! numbers and `flexv bench-report` can never diverge).
//!
//!     cargo bench --bench conv_fig7 [-- --artifact BENCH_kernels.json]

use flexv::isa::IsaVariant;
use flexv::power::EnergyModel;
use flexv::qnn::Precision;
use flexv::report::workloads::conv_fig7_stats;
use std::time::Instant;

fn main() {
    let em = EnergyModel::default();
    println!("Fig. 7 regeneration (conv 64x3x3x32 @ 16x16x32; paper: Flex-V up to 38.2 MAC/cyc,");
    println!("speedups up to 1.4x/4.5x/8.5x vs MPIC/XpulpNN/XpulpV2)");
    for prec in Precision::grid() {
        let t0 = Instant::now();
        let cells: Vec<(IsaVariant, _)> = IsaVariant::ALL
            .iter()
            .map(|&isa| (isa, conv_fig7_stats(isa, prec)))
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        let get = |i: usize| cells[i].1.macs_per_cycle();
        println!("\n{prec}: (row simulated in {wall:.1}s)");
        for (isa, stats) in &cells {
            println!(
                "  {:<8} {:>6.1} MAC/cyc  {:>5.2} TOPS/W  ({} cycles)",
                isa.name(),
                stats.macs_per_cycle(),
                em.tops_per_watt(*isa, stats, prec.a_bits.max(prec.w_bits)),
                stats.cycles
            );
        }
        println!(
            "  Flex-V speedup: {:.1}x vs RI5CY, {:.1}x vs MPIC, {:.1}x vs XpulpNN",
            get(3) / get(0), get(3) / get(1), get(3) / get(2)
        );
    }
    flexv::report::bench::write_artifact_from_args(
        "kernels",
        &flexv::report::bench::BenchOptions::default(),
    );
}
