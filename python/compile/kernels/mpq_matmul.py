"""L1 — Pallas kernel: fine-grain mixed-precision quantized MatMul.

The compute hot-spot of the paper — `out[m][n] = requant(sum_k a[m][k] *
w[n][k])` with unsigned `a_bits` activations and signed `w_bits` weights
packed sub-byte into 32-bit words — re-thought for a tiled scratchpad
target (DESIGN.md §Hardware-Adaptation):

- the paper's Mac&Load + MLC machinery keeps the dotp unit fed from the
  TCDM scratchpad; here the `BlockSpec` grid expresses the same
  HBM->VMEM schedule over (pixel-tile x channel-tile) output blocks;
- the paper's MPC Slicer&Router becomes vectorized shift/mask sub-word
  extraction of the packed weight words (bit-for-bit the little-endian
  layout of `rust/src/qnn/packing.rs`);
- the paper's `mix_skip` weight-reuse is the kernel's inner contraction
  loop reusing each unpacked weight block across the whole pixel tile.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; correctness is checked against `ref.py` by pytest and, after
AOT lowering, against the Rust simulator (three-way, bit-exact).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile sizes (output pixels x output channels). VMEM footprint per
# block = TM*K*4 + TN*ceil(K*w_bits/32)*4 + TM*TN*4 bytes — documented in
# DESIGN.md §Perf.
TM = 8
TN = 8


def _unpack_weights(w_words, w_bits, k):
    """Slicer&Router: unpack `k` signed `w_bits` values from int32 words.

    w_words: (TN, KW) int32, little-endian packed.
    returns: (TN, k) int32, sign-extended.
    """
    lanes = 32 // w_bits
    kk = jnp.arange(k)
    word_idx = kk // lanes
    bit_off = (kk % lanes) * w_bits
    # gather the word for each k, shift and mask
    words = w_words[:, word_idx]  # (TN, k)
    raw = jnp.right_shift(words, bit_off[None, :]) & ((1 << w_bits) - 1)
    # sign-extend from w_bits
    sign = 1 << (w_bits - 1)
    return jnp.where(raw >= sign, raw - (1 << w_bits), raw)


def _kernel(a_ref, w_ref, mult_ref, bias_ref, o_ref, *, w_bits, k, shift, out_bits):
    a = a_ref[...].astype(jnp.int32)  # (TM, K) unpacked activations
    w = _unpack_weights(w_ref[...], w_bits, k)  # (TN, K) signed
    acc = jax.lax.dot_general(
        a,
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # (TM, TN)
    # PULP-NN requantization: one MAC, one shift, one clip.
    acc = acc + bias_ref[...][None, :]
    scaled = jnp.right_shift(acc * mult_ref[...][None, :], shift)
    o_ref[...] = jnp.clip(scaled, 0, (1 << out_bits) - 1)


@partial(jax.jit, static_argnames=("a_bits", "w_bits", "shift", "out_bits"))
def mpq_matmul(a, w_words, mult, bias, *, a_bits, w_bits, shift, out_bits):
    """Mixed-precision quantized MatMul via a Pallas kernel.

    a:        (M, K) int32, unpacked unsigned activations in [0, 2^a_bits)
    w_words:  (N, KW) int32, packed signed weights (little-endian sub-words)
    mult:     (N,) int32 per-channel multiplier
    bias:     (N,) int32 per-channel bias
    returns:  (M, N) int32 requantized outputs in [0, 2^out_bits)
    """
    del a_bits  # activations arrive unpacked; the width bounds their range
    m, k = a.shape
    n, kw = w_words.shape
    assert m % TM == 0 and n % TN == 0, (m, n)
    grid = (m // TM, n // TN)
    return pl.pallas_call(
        partial(_kernel, w_bits=w_bits, k=k, shift=shift, out_bits=out_bits),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((TN, kw), lambda i, j: (j, 0)),
            pl.BlockSpec((TN,), lambda i, j: (j,)),
            pl.BlockSpec((TN,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j: (i, j)),
        interpret=True,
    )(a, w_words, mult, bias)


def pack_weights(w, w_bits):
    """Pack signed (N, K) weights into little-endian int32 words (N, KW).

    Must agree bit-for-bit with rust/src/qnn/packing.rs.
    """
    import numpy as np

    w = np.asarray(w)
    n, k = w.shape
    lanes = 32 // w_bits
    kw = -(-k // lanes)
    words = np.zeros((n, kw), dtype=np.uint32)
    mask = (1 << w_bits) - 1
    for kk in range(k):
        vals = (w[:, kk].astype(np.int64) & mask).astype(np.uint32)
        words[:, kk // lanes] |= vals << ((kk % lanes) * w_bits)
    return jnp.asarray(words.astype(np.int32))
