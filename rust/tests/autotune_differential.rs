//! Differential golden-model sweep for the autotuner: for random conv
//! geometries × mixed-precision (2/4/8-bit) grids, every autotuned
//! plan's fully-simulated output must be **bit-identical** to the
//! [`flexv::qnn::golden`] integer executor — tuning may move cycles,
//! never bits — and the same harness asserts the tuner's measured
//! per-layer contract: tuned-plan cycles ≤ analytic-plan cycles (the
//! analytic default is always a candidate and survives ties).

use flexv::coordinator::Coordinator;
use flexv::dory::autotune::{tune_network, NetworkTuning, TuneConfig};
use flexv::dory::deploy::{deploy, deploy_tuned};
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::qnn::layer::Network;
use flexv::qnn::{golden, Layer, QTensor};
use flexv::util::{proptest, Prng};

/// Per-layer measured contract of a tuning.
fn assert_never_worse(t: &NetworkTuning, net: &Network) -> Result<(), String> {
    for (i, l) in t.layers.iter().enumerate() {
        if l.tuned_cycles > l.default_cycles {
            return Err(format!(
                "layer {i} ({}): tuned {} cycles > analytic {} cycles",
                net.nodes[i].layer.name, l.tuned_cycles, l.default_cycles
            ));
        }
    }
    if t.total_tuned_cycles() > t.total_default_cycles() {
        return Err("tuned total exceeds analytic total".to_string());
    }
    Ok(())
}

/// Tune `net` for `target`, deploy the tuned plan, run it with full
/// functional simulation, and diff every node output against golden.
fn check_tuned_bit_exact(
    net: &Network,
    target: IsaVariant,
    input_seed: u64,
) -> Result<(), String> {
    let budget = MemBudget::default();
    let tuning = tune_network(net, target, budget, 8, &TuneConfig::default());
    assert_never_worse(&tuning, net)?;
    let mut rng = Prng::new(input_seed);
    let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
    let golden_outs = golden::run_network(net, &input);
    let dep = deploy_tuned(net, target, budget, &tuning);
    let mut coord = Coordinator::new(8);
    let res = coord.run(&dep, &input);
    for (i, g) in golden_outs.iter().enumerate() {
        if res.node_outputs[i] != g.data {
            return Err(format!(
                "{target}: tuned node {i} ({}) diverges from golden",
                net.nodes[i].layer.name
            ));
        }
    }
    // The untuned deployment computes the same bits (sanity: tuning is
    // purely a scheduling/lowering decision).
    let dep0 = deploy(net, target, budget);
    let mut coord0 = Coordinator::new(8);
    if coord0.run(&dep0, &input).output != res.output {
        return Err(format!("{target}: tuned and analytic outputs diverge"));
    }
    Ok(())
}

#[test]
fn prop_tuned_random_conv_grids_match_golden_and_never_measure_worse() {
    proptest::check(
        proptest::Config { cases: 12, base_seed: 0xA0_70 },
        |rng: &mut Prng| {
            // Random 1-2 layer conv chain over the mixed 2/4/8-bit grid.
            let h = rng.range(6, 14);
            let cin = rng.range(1, 4) * 4;
            let cout = rng.range(1, 5) * 4;
            let k = *rng.pick(&[1usize, 3]);
            // (mid-chain activation bits, first-layer weight bits)
            let (a2, w1) = *rng.pick(&[(8u8, 8u8), (8, 4), (8, 2), (4, 4), (4, 2)]);
            let mut net = Network::new("diff", [h, h, cin], 8);
            net.push(Layer::conv("c0", [h, h, cin], cout, k, k, 1, k / 2, 8, w1, a2, rng));
            if rng.chance(0.6) {
                let cout2 = rng.range(1, 4) * 4;
                let w2 = if a2 == 8 { *rng.pick(&[8u8, 4, 2]) } else { *rng.pick(&[4u8, 2]) };
                net.push(Layer::conv("c1", [h, h, cout], cout2, 1, 1, 1, 0, a2, w2, 8, rng));
            }
            let target =
                if rng.chance(0.5) { IsaVariant::FlexV } else { IsaVariant::XpulpNn };
            (net, target)
        },
        |(net, target)| {
            if net.validate().is_err() {
                return Ok(()); // generator made an inconsistent chain; skip
            }
            check_tuned_bit_exact(net, *target, 0xD1FF)
        },
    );
}

/// The real mid-size workload: ResNet-20 4b2b (residual adds, mixed
/// per-layer precisions, pooling, classifier) tuned end-to-end stays
/// bit-identical to golden, and the tuning obeys the per-layer
/// measured contract.
#[test]
fn resnet20_tuned_bit_exact_and_never_worse() {
    let net = flexv::models::resnet20(flexv::models::Profile::Mixed4a2w, 5);
    check_tuned_bit_exact(&net, IsaVariant::FlexV, 0x2E5).unwrap();
}
