//! The optimized QNN kernel library ("PULP-NN-Flex").
//!
//! Generators that emit, per ISA variant × precision configuration, the
//! instruction streams of the paper's optimized kernels:
//!
//! - [`matmul`] — the MatMul phase (§II-B) with the per-core register
//!   blocking of each ISA: non-Mac&Load "4×2" (RI5CY / MPIC, PULP-NN
//!   style), Mac&Load "4×2" (XpulpNN uniform), and the Flex-V Mac&Load
//!   "4×4" of Fig. 5 with MLC-generated addressing;
//! - [`unpack`] — the software pack/unpack sequences (p.extract/p.insert)
//!   that ISAs *without* native support must insert (§I: "massive software
//!   overhead"), reproducing the XpulpNN/RI5CY collapse on mixed precision;
//! - [`requant`] — the Quantization phase: one MAC, one shift, one clip per
//!   output plus sub-byte repacking;
//! - [`im2col`] — the im2col phase building per-output-pixel buffers;
//! - [`conv`] — full convolution kernels (im2col + MatMul + requant),
//!   parallelized over output pixels across the 8 cores;
//! - [`layers`] — the remaining operators of the end-to-end networks
//!   (depthwise conv, linear, max/avg pool, residual add).
//!
//! Every generator returns plain [`Program`]s executed by
//! [`crate::sim::Cluster`]; outputs are validated bit-exactly against
//! [`crate::qnn::golden`].

pub mod conv;
pub mod im2col;
pub mod layers;
pub mod matmul;
pub mod regalloc;
pub mod requant;
pub mod unpack;

pub use conv::ConvTask;
pub use matmul::MatMulTask;
pub use requant::RequantCfg;

use crate::isa::IsaVariant;
use crate::qnn::Precision;

/// How a given (ISA, precision) pair executes the MatMul inner loop —
/// the qualitative story of Table III.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum InnerLoopKind {
    /// Flex-V: mixed-precision Mac&Load, 4×4 blocking, MLC addressing.
    MacLoad4x4,
    /// XpulpNN on uniform formats: Mac&Load, 4×2 blocking.
    MacLoad4x2,
    /// MPIC (and uniform-native cases without Mac&Load): explicit loads,
    /// 4×2 blocking, hardware mixed-precision sdotp.
    Plain4x2,
    /// Software weight-unpacking before each sdotp (RI5CY sub-byte,
    /// XpulpNN mixed): the collapse cases of Table III.
    SwUnpack4x2,
}

/// Classify the inner loop used for `(isa, prec)`.
pub fn inner_loop_kind(isa: IsaVariant, prec: Precision) -> InnerLoopKind {
    if isa.supports_natively(prec) {
        match isa {
            IsaVariant::FlexV => InnerLoopKind::MacLoad4x4,
            IsaVariant::XpulpNn => InnerLoopKind::MacLoad4x2,
            _ => InnerLoopKind::Plain4x2,
        }
    } else {
        InnerLoopKind::SwUnpack4x2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inner_loop_classification_matches_paper_story() {
        use IsaVariant::*;
        let a8w8 = Precision::new(8, 8);
        let a8w4 = Precision::new(8, 4);
        let a2w2 = Precision::new(2, 2);
        assert_eq!(inner_loop_kind(FlexV, a8w4), InnerLoopKind::MacLoad4x4);
        assert_eq!(inner_loop_kind(XpulpNn, a2w2), InnerLoopKind::MacLoad4x2);
        assert_eq!(inner_loop_kind(XpulpNn, a8w4), InnerLoopKind::SwUnpack4x2);
        assert_eq!(inner_loop_kind(Mpic, a8w4), InnerLoopKind::Plain4x2);
        assert_eq!(inner_loop_kind(Ri5cy, a8w8), InnerLoopKind::Plain4x2);
        assert_eq!(inner_loop_kind(Ri5cy, a8w4), InnerLoopKind::SwUnpack4x2);
    }
}
