//! PULP-NN integer re-quantization (§II-B "Quantization" phase).
//!
//! Each 32-bit accumulator is brought back to the low-bitwidth unsigned
//! output format with exactly the operation sequence the paper describes:
//! **one MAC** (accumulator × multiplier + rounding offset), **one shift**
//! (arithmetic right shift by `d`), **one clip** (to `[0, 2^bits - 1]`).
//! This is the fixed-point affine requantization used by DORY-deployed
//! networks; multipliers may be per-output-channel (HAWQ-style) or scalar.

/// Per-layer requantization parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantParams {
    /// Fixed-point multiplier, one per output channel (or a single scalar
    /// broadcast to all channels).
    pub mult: Vec<i32>,
    /// Arithmetic right-shift amount (the `d` of PULP-NN).
    pub shift: u8,
    /// Per-output-channel bias added to the accumulator before scaling.
    pub bias: Vec<i32>,
    /// Output activation bit-width (output is unsigned in `[0, 2^bits - 1]`).
    pub out_bits: u8,
}

impl QuantParams {
    /// Scalar multiplier/bias, broadcast over `ch` channels.
    pub fn scalar(mult: i32, shift: u8, bias: i32, out_bits: u8, ch: usize) -> Self {
        QuantParams { mult: vec![mult; ch], shift, bias: vec![bias; ch], out_bits }
    }

    /// The clip upper bound `2^out_bits - 1`.
    pub fn clip_hi(&self) -> i32 {
        (1i32 << self.out_bits) - 1
    }

    /// Requantize one accumulator for output channel `ch`:
    /// `clip( (acc + bias[ch]) * mult[ch] >> shift , 0, 2^bits-1 )`.
    ///
    /// The multiply is widened to i64 exactly like the hardware's 32×32→64
    /// MAC path; the shift is arithmetic.
    #[inline]
    pub fn requant(&self, acc: i32, ch: usize) -> u32 {
        let biased = acc.wrapping_add(self.bias[ch]) as i64;
        let scaled = (biased * self.mult[ch] as i64) >> self.shift;
        scaled.clamp(0, self.clip_hi() as i64) as u32
    }

    /// Number of channels these parameters cover.
    pub fn channels(&self) -> usize {
        self.mult.len()
    }

    /// Byte footprint of the quantization parameters (DORY accounts for
    /// these when sizing L1 tiles: 4 B mult + 4 B bias per channel).
    pub fn bytes(&self) -> usize {
        self.mult.len() * 4 + self.bias.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Prng};

    #[test]
    fn requant_basic() {
        let q = QuantParams::scalar(1, 0, 0, 8, 1);
        assert_eq!(q.requant(100, 0), 100);
        assert_eq!(q.requant(300, 0), 255); // clipped hi
        assert_eq!(q.requant(-5, 0), 0); // clipped lo
    }

    #[test]
    fn requant_shift_and_mult() {
        // (acc + 10) * 3 >> 4
        let q = QuantParams::scalar(3, 4, 10, 4, 2);
        assert_eq!(q.requant(22, 0), 6); // (32*3)>>4 = 6
        assert_eq!(q.requant(1000, 1), 15); // clip to 2^4-1
    }

    #[test]
    fn clip_bounds_per_bits() {
        for bits in [2u8, 4, 8] {
            let q = QuantParams::scalar(1, 0, 0, bits, 1);
            assert_eq!(q.clip_hi(), (1 << bits) - 1);
        }
    }

    #[test]
    fn prop_output_always_in_range() {
        proptest::check_default(
            |rng: &mut Prng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let q = QuantParams::scalar(
                    rng.range_i64(1, 1 << 16) as i32,
                    rng.range(0, 31) as u8,
                    rng.range_i64(-(1 << 20), 1 << 20) as i32,
                    bits,
                    1,
                );
                let acc = rng.range_i64(i32::MIN as i64 / 2, i32::MAX as i64 / 2) as i32;
                (q, acc)
            },
            |(q, acc)| {
                let out = q.requant(*acc, 0);
                if out <= q.clip_hi() as u32 {
                    Ok(())
                } else {
                    Err(format!("out {out} exceeds clip {}", q.clip_hi()))
                }
            },
        );
    }

    #[test]
    fn prop_monotone_in_acc() {
        // Requantization must be monotone non-decreasing in the accumulator
        // (multiplier is positive) — a property DORY's calibration relies on.
        proptest::check_default(
            |rng: &mut Prng| {
                let q = QuantParams::scalar(
                    rng.range_i64(1, 1 << 12) as i32,
                    rng.range(0, 24) as u8,
                    rng.range_i64(-1000, 1000) as i32,
                    *rng.pick(&[2u8, 4, 8]),
                    1,
                );
                let a = rng.range_i64(-100_000, 100_000) as i32;
                let b = rng.range_i64(-100_000, 100_000) as i32;
                (q, a.min(b), a.max(b))
            },
            |(q, lo, hi)| {
                if q.requant(*lo, 0) <= q.requant(*hi, 0) {
                    Ok(())
                } else {
                    Err("not monotone".into())
                }
            },
        );
    }
}
