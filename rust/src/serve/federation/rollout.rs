//! Live model rollout: canary a tuned deployment without dropping work.
//!
//! The controller ([`crate::serve::Federation`]'s event loop) walks a
//! three-phase state machine per [`RolloutPlan`]:
//!
//! 1. **Drain** — from `plan.at`, the canary region stops receiving new
//!    arrivals (router eligibility mask); queued and in-flight requests
//!    finish normally. Nothing is cancelled, so "zero dropped requests"
//!    holds by construction, not by recovery.
//! 2. **Switch** — the first cycle the canary is idle
//!    ([`crate::serve::Engine::is_idle`]), the new version is compiled
//!    **off-path** ([`stage_tuned_caches`]: autotune + [`deploy_tuned`]
//!    per model into staging caches) and installed warm
//!    ([`crate::serve::Engine::warm_caches`] + `set_tuned(true)`).
//!    Tuned and default deployments share a [`PlanKey`], so overwriting
//!    the cache entry *is* the version switch — the first post-switch
//!    batch hits a warm tuned plan, no cold compile on the serving path.
//! 3. **Live** — the canary rejoins the router; its post-switch
//!    completions run tuned plans while the other regions stay on the
//!    default, giving the canary-vs-default cycle accounting in
//!    [`RolloutReport`].
//!
//! Every phase edge is pinned to a simulated cycle, so rollouts are as
//! deterministic as everything else in the federation.
//!
//! [`PlanKey`]: crate::dory::PlanKey
//! [`deploy_tuned`]: crate::dory::deploy::deploy_tuned

use crate::dory::autotune::{self, TuneCache, TuneConfig};
use crate::dory::deploy::deploy_tuned;
use crate::serve::{Engine, PlanCache};
use crate::sim::CoreFidelity;

/// A live-rollout request (`serve-bench --rollout`): canary `canary`
/// onto tuned deployments, starting the drain at cycle `at`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RolloutPlan {
    /// Simulated cycle at which the canary starts draining.
    pub at: u64,
    /// Region index that canaries the tuned version.
    pub canary: usize,
}

/// Where the rollout stands (controller state).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum RolloutPhase {
    /// Before `plan.at` (or no plan at all).
    Pending,
    /// Canary excluded from routing, waiting for it to go idle.
    Draining { since: u64 },
    /// Switched at `switched`; canary serves the tuned version.
    Live { switched: u64 },
}

/// What the rollout did — rendered in the federation report and part of
/// the deterministic fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RolloutReport {
    pub canary: usize,
    /// Cycle the canary left the router.
    pub drain_started: u64,
    /// Cycle the warm caches were installed and routing resumed.
    pub switched_at: u64,
    /// Models compiled into the staging caches.
    pub models_migrated: usize,
    /// Σ exec cycles of canary completions dispatched pre-switch
    /// (default plans).
    pub canary_default_exec: u64,
    /// Σ exec cycles of canary completions dispatched post-switch
    /// (tuned plans). Filled when the report is read
    /// ([`crate::serve::Federation::metrics`]).
    pub canary_tuned_exec: u64,
}

impl RolloutReport {
    /// Cycles the canary spent out of the router.
    pub fn drain_cycles(&self) -> u64 {
        self.switched_at - self.drain_started
    }
}

/// Compile the tuned version of every registered model into fresh
/// staging caches, off the serving path. Deterministic: the tuner
/// configuration mirrors the engine's own tuned-dispatch path
/// (fast-tier search, confirmed at the fleet's fidelity when non-fast),
/// so a rollout lands the exact plans `ServeConfig::tuned` would have.
pub(crate) fn stage_tuned_caches(engine: &Engine) -> (PlanCache, TuneCache) {
    let cfg = engine.cfg;
    let tune_cfg = TuneConfig {
        confirm_fidelity: (cfg.fidelity != CoreFidelity::Fast).then_some(cfg.fidelity),
        ..TuneConfig::default()
    };
    let mut plans = PlanCache::new();
    let mut tunes = TuneCache::new();
    for m in 0..engine.model_count() {
        let (net, key) = engine.model_entry(m);
        let tuning = tunes.get_or_tune(key, || {
            autotune::tune_network(net, cfg.isa, cfg.budget, cfg.n_cores, &tune_cfg)
        });
        plans.get_or_build(key, || deploy_tuned(net, cfg.isa, cfg.budget, tuning));
    }
    (plans, tunes)
}
