//! Per-layer profile report: where the cycles of one inference went.
//!
//! Condenses a [`RunResult`] into one [`LayerProfile`] row per layer —
//! cycles, MAC/cycle, a stall breakdown (TCDM conflicts, load-use
//! hazards, taken-branch bubbles, Mac&Load write-back port contention
//! and sub-word realignment under the pipeline tier — see
//! [`crate::sim::pipeline`] — and barrier waits) as percentages of the
//! layer's aggregate core-cycle budget, DMA overlap, and the kernel
//! lowering the layer actually ran. This is the table the paper reasons
//! with when explaining MAC/cycle gaps (§V: Mac&Load inner loops vs.
//! load-use stalls), and the `profile --tuned` report pairs two of them
//! to explain each autotuned win.
//!
//! # Percentage denominators
//!
//! Stall percentages divide by `layer cycles × cores running the layer`
//! — the layer's total core-cycle budget — never by a single core's
//! `cycles` counter. Per-core stall counters are summed across serial
//! tile windows while wall cycles accumulate in
//! [`ClusterStats::cycles`], so this is the one denominator under which
//! each breakdown (and their sum) is guaranteed ≤ 100%; see
//! [`crate::sim::stats::CoreStats::merge_parallel`] for the merge
//! semantics behind that invariant.

use crate::coordinator::RunResult;
use crate::dory::deploy::Deployment;
use crate::util::table::{f, Table};

/// Profile of one executed layer.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    /// Layer name from the deployment plan.
    pub name: String,
    /// Kernel lowering the layer ran (the plan's exec override, else the
    /// deployment-wide target).
    pub isa: String,
    /// Cores the layer's programs were generated for.
    pub n_cores: usize,
    /// Wall cycles of the layer window.
    pub cycles: u64,
    /// MAC operations of the layer.
    pub macs: u64,
    /// MACs per wall cycle.
    pub macs_per_cycle: f64,
    /// Cycles lost to TCDM bank conflicts, % of the core-cycle budget.
    pub conflict_pct: f64,
    /// Cycles lost to load-use hazards, % of the core-cycle budget.
    pub loaduse_pct: f64,
    /// Cycles lost to taken-branch bubbles, % of the core-cycle budget.
    pub branch_pct: f64,
    /// Cycles lost to Mac&Load write-back port contention, % of the
    /// core-cycle budget (always 0 on the fast tier).
    pub wbport_pct: f64,
    /// Cycles lost to sub-word load realignment, % of the core-cycle
    /// budget (always 0 on the fast tier).
    pub align_pct: f64,
    /// Cycles spent waiting at barriers, % of the core-cycle budget.
    pub barrier_pct: f64,
    /// DMA busy cycles overlapped with the layer window, % of the window.
    pub dma_overlap_pct: f64,
}

impl LayerProfile {
    /// Sum of the six stall breakdowns (≤ 100 by construction).
    pub fn total_stall_pct(&self) -> f64 {
        self.conflict_pct
            + self.loaduse_pct
            + self.branch_pct
            + self.wbport_pct
            + self.align_pct
            + self.barrier_pct
    }
}

/// Per-layer profiles of one inference, in plan order.
#[derive(Clone, Debug)]
pub struct NetworkProfile {
    pub layers: Vec<LayerProfile>,
}

impl NetworkProfile {
    /// Build the profile by pairing a run's measured layer stats with the
    /// deployment that produced them. `default_cores` is the cluster
    /// width (layers without an exec override ran on all of it).
    pub fn from_run(res: &RunResult, dep: &Deployment, default_cores: usize) -> NetworkProfile {
        let layers = res
            .layers
            .iter()
            .zip(&dep.plans)
            .map(|(l, plan)| {
                // Same override resolution as `execute_deployment`.
                let (isa, nc) = plan
                    .exec
                    .map_or((dep.isa, default_cores), |e| (e.isa, e.n_cores.min(default_cores)));
                let budget = (l.stats.cycles * nc as u64) as f64;
                let pct = |counter: fn(&crate::sim::CoreStats) -> u64| {
                    if budget == 0.0 {
                        0.0
                    } else {
                        l.stats.cores.iter().map(counter).sum::<u64>() as f64 / budget * 100.0
                    }
                };
                let dma_overlap_pct = if l.stats.cycles == 0 {
                    0.0
                } else {
                    l.stats.dma_busy_cycles.min(l.stats.cycles) as f64 / l.stats.cycles as f64
                        * 100.0
                };
                LayerProfile {
                    name: l.name.clone(),
                    isa: isa.to_string(),
                    n_cores: nc,
                    cycles: l.stats.cycles,
                    macs: l.macs,
                    macs_per_cycle: l.macs_per_cycle(),
                    conflict_pct: pct(|c| c.conflict_stalls),
                    loaduse_pct: pct(|c| c.loaduse_stalls),
                    branch_pct: pct(|c| c.branch_stalls),
                    wbport_pct: pct(|c| c.wbport_stalls),
                    align_pct: pct(|c| c.align_stalls),
                    barrier_pct: pct(|c| c.barrier_cycles),
                    dma_overlap_pct,
                }
            })
            .collect();
        NetworkProfile { layers }
    }

    /// Σ wall cycles over layers.
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    /// Render as an aligned text table with a TOTAL row.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(title).header(&[
            "layer", "lowering", "cores", "cycles", "MAC/cyc", "conflict%", "loaduse%",
            "branch%", "wbport%", "align%", "barrier%", "dma-ovl%",
        ]);
        for l in &self.layers {
            t.row(vec![
                l.name.clone(),
                l.isa.clone(),
                l.n_cores.to_string(),
                l.cycles.to_string(),
                f(l.macs_per_cycle, 2),
                f(l.conflict_pct, 1),
                f(l.loaduse_pct, 1),
                f(l.branch_pct, 1),
                f(l.wbport_pct, 1),
                f(l.align_pct, 1),
                f(l.barrier_pct, 1),
                f(l.dma_overlap_pct, 1),
            ]);
        }
        let total_cycles = self.total_cycles();
        let total_macs: u64 = self.layers.iter().map(|l| l.macs).sum();
        let mpc = if total_cycles == 0 { 0.0 } else { total_macs as f64 / total_cycles as f64 };
        t.row(vec![
            "TOTAL".to_string(),
            String::new(),
            String::new(),
            total_cycles.to_string(),
            f(mpc, 2),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;
    use crate::dory::deploy::deploy;
    use crate::dory::MemBudget;
    use crate::isa::IsaVariant;
    use crate::qnn::layer::{Layer, Network};
    use crate::qnn::QTensor;
    use crate::util::Prng;

    #[test]
    fn percentages_are_bounded_on_a_real_layer() {
        let mut rng = Prng::new(0x9F0);
        let mut net = Network::new("prof", [10, 10, 8], 8);
        net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net.validate().unwrap();
        let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let mut coord = Coordinator::new(4);
        let res = coord.run(&dep, &input);
        let prof = NetworkProfile::from_run(&res, &dep, 4);
        assert_eq!(prof.layers.len(), 2);
        for l in &prof.layers {
            assert!(l.cycles > 0 && l.macs_per_cycle > 0.0, "{l:?}");
            for p in [
                l.conflict_pct,
                l.loaduse_pct,
                l.branch_pct,
                l.wbport_pct,
                l.align_pct,
                l.barrier_pct,
            ] {
                assert!((0.0..=100.0).contains(&p), "{l:?}");
            }
            assert!(l.total_stall_pct() <= 100.0 + 1e-9, "{l:?}");
            assert!((0.0..=100.0).contains(&l.dma_overlap_pct), "{l:?}");
            assert_eq!(l.isa, IsaVariant::FlexV.to_string());
            assert_eq!(l.n_cores, 4);
            // fast tier: the pipeline-only categories stay zero
            assert_eq!((l.wbport_pct, l.align_pct), (0.0, 0.0), "{l:?}");
        }
        assert_eq!(prof.total_cycles(), res.total_cycles());
        let table = prof.render("test profile");
        assert!(table.contains("c1") && table.contains("TOTAL"));
    }

    #[test]
    fn exec_overrides_show_in_the_profile() {
        use crate::dory::autotune::{LayerTuning, NetworkTuning};
        use crate::dory::deploy::deploy_tuned;
        let mut rng = Prng::new(0x9F1);
        let mut net = Network::new("prof-ovr", [10, 10, 8], 8);
        net.push(Layer::conv("c1", [10, 10, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.validate().unwrap();
        let tuning = NetworkTuning {
            layers: vec![LayerTuning {
                isa: IsaVariant::Ri5cy,
                n_cores: 4,
                shape: None,
                tuned_cycles: 0,
                default_cycles: 0,
            }],
        };
        let dep = deploy_tuned(&net, IsaVariant::FlexV, MemBudget::default(), &tuning);
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let mut coord = Coordinator::new(8);
        let res = coord.run(&dep, &input);
        let prof = NetworkProfile::from_run(&res, &dep, 8);
        assert_eq!(prof.layers[0].isa, IsaVariant::Ri5cy.to_string());
        assert_eq!(prof.layers[0].n_cores, 4);
    }
}
