//! Execution statistics collected by the simulator — the raw material for
//! every table and figure of the evaluation (MAC/cycle, utilization,
//! stall breakdowns, per-instruction-class activity for the energy model).

/// Per-core counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoreStats {
    /// Instructions retired.
    pub instrs: u64,
    /// Cycles this core was active (from reset to halt).
    pub cycles: u64,
    /// MAC operations performed (SIMD dotp lanes + scalar macs).
    pub macs: u64,
    /// sdotp/mlsdotp instructions retired (dotp-unit activations,
    /// feeds the energy model).
    pub dotp_instrs: u64,
    /// Mac&Load instructions retired (of which WB loads).
    pub macload_instrs: u64,
    /// TCDM data accesses performed.
    pub tcdm_accesses: u64,
    /// Cycles lost to TCDM bank conflicts.
    pub conflict_stalls: u64,
    /// Cycles lost to load-use hazards.
    pub loaduse_stalls: u64,
    /// Cycles lost to taken-branch bubbles.
    pub branch_stalls: u64,
    /// Cycles lost to Mac&Load write-back port contention (pipeline
    /// fidelity tier only; see [`super::pipeline`]).
    pub wbport_stalls: u64,
    /// Cycles lost to sub-word load realignment (the second load-use
    /// cycle of an `lbu` consumer; pipeline fidelity tier only).
    pub align_stalls: u64,
    /// Cycles spent waiting at barriers (clock-gated).
    pub barrier_cycles: u64,
    /// CSR writes (MLC/MPC setup overhead).
    pub csr_writes: u64,
}

impl CoreStats {
    /// Merge counters from a run that *overlapped in time* with this one
    /// (the same core across serial tile windows of one layer, or
    /// per-tile representatives replayed into a layer total): event
    /// counters sum, but `cycles` is **max-reduced** — the merged value
    /// answers "how long was this core's longest single window", not
    /// "how long did it run in total" (wall time lives in
    /// [`ClusterStats::cycles`]).
    ///
    /// The asymmetry is deliberate and load-bearing: [`crate::power`]
    /// derives a core's active cycles as `cycles - barrier_cycles`, and
    /// percentage consumers must divide stall counters by
    /// `ClusterStats::cycles × n_cores` (as [`crate::trace::profile`]
    /// does) — never by this field, which summed counters can exceed.
    /// Use [`CoreStats::accumulate`] when concatenating disjoint runs
    /// where `cycles` should sum too.
    pub fn merge_parallel(&mut self, o: &CoreStats) {
        self.instrs += o.instrs;
        self.cycles = self.cycles.max(o.cycles);
        self.macs += o.macs;
        self.dotp_instrs += o.dotp_instrs;
        self.macload_instrs += o.macload_instrs;
        self.tcdm_accesses += o.tcdm_accesses;
        self.conflict_stalls += o.conflict_stalls;
        self.loaduse_stalls += o.loaduse_stalls;
        self.branch_stalls += o.branch_stalls;
        self.wbport_stalls += o.wbport_stalls;
        self.align_stalls += o.align_stalls;
        self.barrier_cycles += o.barrier_cycles;
        self.csr_writes += o.csr_writes;
    }

    /// Σ of every stall category (barrier waits excluded — those are
    /// clock-gated idling, not pipeline bubbles). On a single
    /// uninterrupted run, `cycles == instrs + stall_cycles() +
    /// barrier_cycles` holds exactly — the identity the profile report's
    /// percentages and the stats proptests below rely on.
    pub fn stall_cycles(&self) -> u64 {
        self.conflict_stalls
            + self.loaduse_stalls
            + self.branch_stalls
            + self.wbport_stalls
            + self.align_stalls
    }

    /// Sum *every* counter, `cycles` included — sequential concatenation
    /// of runs that did not overlap in time. Counterpart of
    /// [`CoreStats::merge_parallel`]; see its docs for when each applies.
    pub fn accumulate(&mut self, o: &CoreStats) {
        self.instrs += o.instrs;
        self.cycles += o.cycles;
        self.macs += o.macs;
        self.dotp_instrs += o.dotp_instrs;
        self.macload_instrs += o.macload_instrs;
        self.tcdm_accesses += o.tcdm_accesses;
        self.conflict_stalls += o.conflict_stalls;
        self.loaduse_stalls += o.loaduse_stalls;
        self.branch_stalls += o.branch_stalls;
        self.wbport_stalls += o.wbport_stalls;
        self.align_stalls += o.align_stalls;
        self.barrier_cycles += o.barrier_cycles;
        self.csr_writes += o.csr_writes;
    }
}

/// Whole-cluster result of a simulation run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterStats {
    /// Wall-clock cycles of the run (max over cores, incl. DMA tail).
    pub cycles: u64,
    /// Per-core counters.
    pub cores: Vec<CoreStats>,
    /// Cycles the DMA engine was busy moving data.
    pub dma_busy_cycles: u64,
    /// Bytes moved by the DMA.
    pub dma_bytes: u64,
}

impl ClusterStats {
    /// Total MACs across cores.
    pub fn total_macs(&self) -> u64 {
        self.cores.iter().map(|c| c.macs).sum()
    }

    /// Total instructions across cores.
    pub fn total_instrs(&self) -> u64 {
        self.cores.iter().map(|c| c.instrs).sum()
    }

    /// The paper's headline metric: MACs per cluster cycle.
    pub fn macs_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.total_macs() as f64 / self.cycles as f64
        }
    }

    /// MAC-unit utilization relative to a peak of `peak_macs_per_cycle`
    /// (§I claims >80% "ASIC-like" utilization for Flex-V).
    pub fn utilization(&self, peak_macs_per_cycle: f64) -> f64 {
        self.macs_per_cycle() / peak_macs_per_cycle
    }

    /// Merge another run sequentially after this one (tile loops).
    pub fn extend_serial(&mut self, o: &ClusterStats) {
        self.cycles += o.cycles;
        if self.cores.len() < o.cores.len() {
            self.cores.resize(o.cores.len(), CoreStats::default());
        }
        // Per-core `cycles` stays max-reduced (longest single window):
        // wall time accumulates in `self.cycles` above, and the energy
        // model's `cycles - barrier_cycles` stays meaningful per window.
        for (a, b) in self.cores.iter_mut().zip(&o.cores) {
            a.merge_parallel(b);
        }
        self.dma_busy_cycles += o.dma_busy_cycles;
        self.dma_bytes += o.dma_bytes;
    }

    /// Scale this run's counters by `n` repetitions (tile memoization —
    /// exact because kernel timing is data-independent; see DESIGN.md §7).
    pub fn repeat(&self, n: u64) -> ClusterStats {
        let mut out = self.clone();
        out.cycles *= n;
        out.dma_busy_cycles *= n;
        out.dma_bytes *= n;
        for c in &mut out.cores {
            c.instrs *= n;
            c.cycles *= n;
            c.macs *= n;
            c.dotp_instrs *= n;
            c.macload_instrs *= n;
            c.tcdm_accesses *= n;
            c.conflict_stalls *= n;
            c.loaduse_stalls *= n;
            c.branch_stalls *= n;
            c.wbport_stalls *= n;
            c.align_stalls *= n;
            c.barrier_cycles *= n;
            c.csr_writes *= n;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_per_cycle() {
        let s = ClusterStats {
            cycles: 100,
            cores: vec![CoreStats { macs: 500, ..Default::default() }; 8],
            ..Default::default()
        };
        assert!((s.macs_per_cycle() - 40.0).abs() < 1e-9);
        assert!((s.utilization(64.0) - 0.625).abs() < 1e-9);
    }

    #[test]
    fn repeat_scales_linearly() {
        let s = ClusterStats {
            cycles: 10,
            cores: vec![CoreStats { macs: 7, instrs: 3, ..Default::default() }],
            dma_bytes: 4,
            ..Default::default()
        };
        let r = s.repeat(5);
        assert_eq!(r.cycles, 50);
        assert_eq!(r.cores[0].macs, 35);
        assert_eq!(r.dma_bytes, 20);
        assert!((r.macs_per_cycle() - s.macs_per_cycle()).abs() < 1e-12);
    }

    #[test]
    fn merge_parallel_maxes_cycles_and_sums_events() {
        let mut a = CoreStats { cycles: 100, conflict_stalls: 10, macs: 50, ..Default::default() };
        let b = CoreStats { cycles: 60, conflict_stalls: 7, macs: 5, ..Default::default() };
        a.merge_parallel(&b);
        assert_eq!(a.cycles, 100, "cycles must max-reduce");
        assert_eq!(a.conflict_stalls, 17);
        assert_eq!(a.macs, 55);
    }

    #[test]
    fn accumulate_sums_everything_including_cycles() {
        let mut a = CoreStats { cycles: 100, conflict_stalls: 10, macs: 50, ..Default::default() };
        let b = CoreStats { cycles: 60, conflict_stalls: 7, macs: 5, ..Default::default() };
        a.accumulate(&b);
        assert_eq!(a.cycles, 160, "cycles must sum");
        assert_eq!(a.conflict_stalls, 17);
        assert_eq!(a.macs, 55);
    }

    /// The invariant behind the profile report's percentages: across a
    /// serial merge, a core's summed stall counters stay bounded by the
    /// accumulated wall cycles (each window's stalls fit in that window).
    #[test]
    fn serial_merge_keeps_stalls_bounded_by_wall() {
        let windows = [
            ClusterStats {
                cycles: 40,
                cores: vec![CoreStats {
                    cycles: 40,
                    conflict_stalls: 12,
                    barrier_cycles: 8,
                    ..Default::default()
                }],
                ..Default::default()
            },
            ClusterStats {
                cycles: 25,
                cores: vec![CoreStats {
                    cycles: 25,
                    conflict_stalls: 5,
                    barrier_cycles: 20,
                    ..Default::default()
                }],
                ..Default::default()
            },
            ClusterStats {
                cycles: 70,
                cores: vec![CoreStats {
                    cycles: 70,
                    conflict_stalls: 1,
                    barrier_cycles: 2,
                    ..Default::default()
                }],
                ..Default::default()
            },
        ];
        let mut total = ClusterStats::default();
        for w in &windows {
            total.extend_serial(w);
        }
        let c = &total.cores[0];
        assert_eq!(total.cycles, 135);
        assert_eq!(c.cycles, 70, "per-core cycles is the longest window, not the sum");
        // Stall counters summed across all three windows (12+5+1 and
        // 8+20+2) against a max-reduced `c.cycles` — mixing those two in
        // one ratio is exactly the >100% bug the split methods prevent.
        assert_eq!((c.conflict_stalls, c.barrier_cycles), (18, 30));
        assert!(c.conflict_stalls + c.barrier_cycles <= total.cycles);
    }

    use crate::util::{proptest, Prng};

    /// One randomly drawn core run, built by injecting the same events
    /// the ISS charges: retires, each stall category (including the
    /// pipeline tier's WB-port and realignment charges), barrier waits.
    fn random_run(rng: &mut Prng) -> CoreStats {
        let mut s = CoreStats::default();
        for _ in 0..rng.range(1, 200) {
            match rng.range(0, 7) {
                0 => {
                    // plain retire
                    s.cycles += 1;
                    s.instrs += 1;
                }
                1 => {
                    // TCDM conflict stall tick
                    s.cycles += 1;
                    s.conflict_stalls += 1;
                }
                2 => {
                    // word load-use stall tick
                    s.cycles += 1;
                    s.loaduse_stalls += 1;
                }
                3 => {
                    // sub-word load-use: shared stall tick + realign charge
                    s.cycles += 2;
                    s.loaduse_stalls += 1;
                    s.align_stalls += 1;
                }
                4 => {
                    // taken branch: retire + two bubble ticks
                    s.cycles += 3;
                    s.instrs += 1;
                    s.branch_stalls += 2;
                }
                5 => {
                    // GP-LSU retire behind an NN-RF WB load: retire + charge
                    s.cycles += 2;
                    s.instrs += 1;
                    s.wbport_stalls += 1;
                }
                _ => {
                    // clock-gated barrier wait
                    s.cycles += 1;
                    s.barrier_cycles += 1;
                }
            }
        }
        s
    }

    /// Per-category stall cycles sum to `total - active` under random
    /// stall injection: every non-retire, non-barrier cycle is claimed
    /// by exactly one stall category — with the pipeline tier's new
    /// categories included.
    #[test]
    fn prop_stall_categories_sum_to_total_minus_active() {
        proptest::check_default(random_run, |s| {
            let active = s.instrs + s.barrier_cycles;
            if s.cycles - active == s.stall_cycles() {
                Ok(())
            } else {
                Err(format!(
                    "cycles {} - active {} != stalls {}",
                    s.cycles,
                    active,
                    s.stall_cycles()
                ))
            }
        });
    }

    /// `accumulate` preserves the accounting identity exactly, and a
    /// serial merge (`extend_serial` → `merge_parallel` per core) keeps
    /// every core's stall + barrier cycles within the accumulated wall
    /// budget — the ≤100% invariant the profile percentages divide by.
    #[test]
    fn prop_merge_and_accumulate_preserve_stall_bound() {
        proptest::check_default(
            |rng| {
                (0..rng.range(1, 8))
                    .map(|_| {
                        let cores: Vec<CoreStats> =
                            (0..4).map(|_| random_run(rng)).collect();
                        ClusterStats {
                            cycles: cores.iter().map(|c| c.cycles).max().unwrap(),
                            cores,
                            ..Default::default()
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |windows| {
                let mut acc = CoreStats::default();
                for w in windows {
                    acc.accumulate(&w.cores[0]);
                }
                if acc.cycles - (acc.instrs + acc.barrier_cycles) != acc.stall_cycles() {
                    return Err("accumulate broke the stall identity".into());
                }
                let mut total = ClusterStats::default();
                for w in windows {
                    total.extend_serial(w);
                }
                for (i, c) in total.cores.iter().enumerate() {
                    if c.stall_cycles() + c.barrier_cycles > total.cycles {
                        return Err(format!(
                            "core {i}: stalls {} + barrier {} exceed wall {}",
                            c.stall_cycles(),
                            c.barrier_cycles,
                            total.cycles
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn extend_serial_accumulates() {
        let a = ClusterStats {
            cycles: 10,
            cores: vec![CoreStats { macs: 5, ..Default::default() }],
            ..Default::default()
        };
        let mut b = a.clone();
        b.extend_serial(&a);
        assert_eq!(b.cycles, 20);
        assert_eq!(b.cores[0].macs, 10);
    }
}
