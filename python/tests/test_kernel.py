"""pytest: the Pallas kernel vs the pure-jnp oracle — the core correctness
signal of the compile path. Hypothesis sweeps shapes × the paper's
precision grid; everything is exact integer arithmetic so comparisons are
bit-exact (assert_array_equal, not allclose)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.mpq_matmul import mpq_matmul, pack_weights, TM, TN
from compile.kernels.ref import mpq_matmul_ref

GRID = [(2, 2), (4, 2), (4, 4), (8, 2), (8, 4), (8, 8)]


def random_case(rng, m, n, k, a_bits, w_bits):
    a = rng.integers(0, 1 << a_bits, size=(m, k), dtype=np.int64).astype(np.int32)
    w = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1), size=(n, k), dtype=np.int64).astype(
        np.int32
    )
    mult = rng.integers(1, 8, size=(n,), dtype=np.int64).astype(np.int32)
    bias = rng.integers(-100, 100, size=(n,), dtype=np.int64).astype(np.int32)
    return a, w, mult, bias


@pytest.mark.parametrize("a_bits,w_bits", GRID)
def test_kernel_matches_ref_grid(a_bits, w_bits):
    rng = np.random.default_rng(a_bits * 10 + w_bits)
    m, n, k = 2 * TM, 2 * TN, 40
    a, w, mult, bias = random_case(rng, m, n, k, a_bits, w_bits)
    want = mpq_matmul_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(mult), jnp.asarray(bias),
                          shift=7, out_bits=8)
    got = mpq_matmul(jnp.asarray(a), pack_weights(w, w_bits), jnp.asarray(mult),
                     jnp.asarray(bias), a_bits=a_bits, w_bits=w_bits, shift=7, out_bits=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("out_bits", [2, 4, 8])
def test_subbyte_outputs_clip(out_bits):
    rng = np.random.default_rng(out_bits)
    a, w, mult, bias = random_case(rng, TM, TN, 16, 8, 4)
    got = np.asarray(
        mpq_matmul(jnp.asarray(a), pack_weights(w, 4), jnp.asarray(mult), jnp.asarray(bias),
                   a_bits=8, w_bits=4, shift=2, out_bits=out_bits)
    )
    assert got.min() >= 0 and got.max() <= (1 << out_bits) - 1
    want = np.asarray(
        mpq_matmul_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(mult), jnp.asarray(bias),
                       shift=2, out_bits=out_bits)
    )
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    k=st.integers(1, 96),
    prec=st.sampled_from(GRID),
    shift=st.integers(0, 15),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(mt, nt, k, prec, shift, seed):
    a_bits, w_bits = prec
    rng = np.random.default_rng(seed)
    m, n = mt * TM, nt * TN
    a, w, mult, bias = random_case(rng, m, n, k, a_bits, w_bits)
    want = mpq_matmul_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(mult), jnp.asarray(bias),
                          shift=shift, out_bits=8)
    got = mpq_matmul(jnp.asarray(a), pack_weights(w, w_bits), jnp.asarray(mult),
                     jnp.asarray(bias), a_bits=a_bits, w_bits=w_bits, shift=shift, out_bits=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_weights_little_endian():
    # nibbles [1, -1, 7, -8] -> word 0x...8F1 pattern, matching the Rust
    # packing (rust/src/qnn/packing.rs tests).
    w = np.array([[1, -1, 7, -8]], dtype=np.int32)
    words = np.asarray(pack_weights(w, 4))
    assert words.shape == (1, 1)
    assert words[0, 0] & 0xFFFF == 0x8F71 or True  # explicit check below
    raw = words[0, 0].astype(np.uint32) if hasattr(words[0, 0], "astype") else words[0, 0]
    vals = [(int(raw) >> (4 * i)) & 0xF for i in range(4)]
    assert vals == [1, 0xF, 7, 8]
