//! The end-to-end network zoo of the evaluation (§V-C, Table IV):
//! MobileNetV1 (8-bit and mixed 8b4b) and ResNet-20 (mixed 4b2b).
//!
//! Weights are synthetic (seeded): performance and memory footprint depend
//! only on topology and per-layer precision, not on learned values
//! (DESIGN.md §2). Top-1 accuracies in Table IV are therefore *cited* from
//! the paper, not re-measured.
//!
//! Precision assignments:
//! - **MNV1 8b**: a8w8 everywhere.
//! - **MNV1 8b4b** ("fully mixed-precision"): 8-bit activations, 4-bit
//!   weights on every layer except the first convolution (w8), halving the
//!   weight footprint (the paper's −47%).
//! - **ResNet-20 4b2b** (HAWQ-style [18]): 4-bit activations; 2-bit
//!   weights in stages 1-2, 4-bit in stage 3 (where the parameters
//!   concentrate), 8-bit first conv and classifier — reproducing the
//!   ~142 kB footprint of Table IV.

use crate::qnn::layer::{Layer, LayerKind, Network};
use crate::qnn::{QTensor, QuantParams};
use crate::util::Prng;

/// Precision profile of a network build.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Profile {
    /// Uniform 8-bit.
    Uniform8,
    /// Mixed 8-bit activations / 4-bit weights.
    Mixed8a4w,
    /// Aggressive mixed 4-bit activations / 2-4-bit weights.
    Mixed4a2w,
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Uniform8 => "8b",
            Profile::Mixed8a4w => "8b4b",
            Profile::Mixed4a2w => "4b2b",
        }
    }
}

/// Benign requant parameters keeping activations well-distributed for the
/// synthetic weights (shift balances the accumulation growth).
fn quant_for(k: usize, a_bits: u8, w_bits: u8, out_bits: u8, ch: usize) -> QuantParams {
    let acc_bits = (a_bits as u32 + w_bits as u32 - 1)
        + (k.max(1).next_power_of_two().trailing_zeros());
    let shift = (acc_bits as i32 - out_bits as i32 - 1).clamp(0, 31) as u8;
    QuantParams::scalar(1, shift, 0, out_bits, ch)
}

#[allow(clippy::too_many_arguments)]
fn conv(
    name: String,
    in_shape: [usize; 3],
    cout: usize,
    k: usize,
    stride: usize,
    a_bits: u8,
    w_bits: u8,
    out_bits: u8,
    rng: &mut Prng,
) -> Layer {
    let [h, w, cin] = in_shape;
    let pad = k / 2;
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    Layer {
        name,
        kind: LayerKind::Conv2d { kh: k, kw: k, stride, pad },
        in_shape,
        out_shape: [oh, ow, cout],
        a_bits,
        w_bits,
        weights: Some(QTensor::random(&[cout, k, k, cin], w_bits, true, rng)),
        quant: quant_for(k * k * cin, a_bits, w_bits, out_bits, cout),
    }
}

fn dwconv(
    name: String,
    in_shape: [usize; 3],
    stride: usize,
    a_bits: u8,
    w_bits: u8,
    rng: &mut Prng,
) -> Layer {
    let [h, w, c] = in_shape;
    let oh = (h + 2 - 3) / stride + 1;
    let ow = (w + 2 - 3) / stride + 1;
    Layer {
        name,
        kind: LayerKind::DwConv2d { kh: 3, kw: 3, stride, pad: 1 },
        in_shape,
        out_shape: [oh, ow, c],
        a_bits,
        w_bits,
        weights: Some(QTensor::random(&[c, 3, 3, 1], w_bits, true, rng)),
        quant: quant_for(9, a_bits, w_bits, a_bits, c),
    }
}

/// MobileNetV1 with width multiplier `alpha` (default 0.75 — the
/// CMix-NN/STM32H7 comparison point; the paper's 1.9 MB model size points
/// to a reduced-width variant, see EXPERIMENTS.md).
pub fn mobilenet_v1(profile: Profile, alpha: f64, input_hw: usize, seed: u64) -> Network {
    assert!(profile != Profile::Mixed4a2w, "MNV1 profiles are 8b / 8b4b");
    let mut rng = Prng::new(seed);
    let w4 = profile == Profile::Mixed8a4w;
    let ch = |c: usize| (((c as f64 * alpha) / 8.0).round() as usize * 8).max(8);
    let mut net = Network::new(
        &format!("MobileNetV1-{}(a{alpha})", profile.name()),
        [input_hw, input_hw, 4],
        8,
    );
    // Stem: the 3-channel RGB input is zero-padded to 4 channels at
    // deployment (DORY byte-alignment; the pad channel is zero so the
    // extra MACs are value-neutral but counted as in the paper's k=27+).
    let mut shape = [input_hw, input_hw, 4];
    let stem = conv("conv1".into(), shape, ch(32), 3, 2, 8, 8, 8, &mut rng);
    shape = stem.out_shape;
    net.push(stem);
    // 13 depthwise-separable blocks.
    let cfg: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(cout, stride)) in cfg.iter().enumerate() {
        let dw = dwconv(
            format!("dw{}", i + 1),
            shape,
            stride,
            8,
            if w4 { 4 } else { 8 },
            &mut rng,
        );
        shape = dw.out_shape;
        net.push(dw);
        let pw = conv(
            format!("pw{}", i + 1),
            shape,
            ch(cout),
            1,
            1,
            8,
            if w4 { 4 } else { 8 },
            8,
            &mut rng,
        );
        shape = pw.out_shape;
        net.push(pw);
    }
    // Global average pool + classifier.
    let [h, _, c] = shape;
    net.push(Layer {
        name: "avgpool".into(),
        kind: LayerKind::AvgPool { k: h, stride: h },
        in_shape: shape,
        out_shape: [1, 1, c],
        a_bits: 8,
        w_bits: 8,
        weights: None,
        // divide by h*h: mult/shift approximating 1/49 etc.
        quant: QuantParams::scalar(
            ((1i64 << 16) / (h * h) as i64) as i32,
            16,
            0,
            8,
            c,
        ),
    });
    let classes = 1000usize;
    let mut rng2 = Prng::new(seed ^ 0xFC);
    net.push(Layer {
        name: "fc".into(),
        kind: LayerKind::Linear,
        in_shape: [1, 1, c],
        out_shape: [1, 1, classes],
        a_bits: 8,
        w_bits: if w4 { 4 } else { 8 },
        weights: Some(QTensor::random(&[classes, c], if w4 { 4 } else { 8 }, true, &mut rng2)),
        quant: quant_for(c, 8, if w4 { 4 } else { 8 }, 8, classes),
    });
    net
}

/// ResNet-20 for CIFAR-10 (32×32 input), HAWQ-style mixed 4b2b profile
/// (or uniform 8b for the degradation baseline).
pub fn resnet20(profile: Profile, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let (a_bits, w_early, w_late): (u8, u8, u8) = match profile {
        Profile::Uniform8 => (8, 8, 8),
        Profile::Mixed4a2w => (4, 2, 4),
        Profile::Mixed8a4w => (8, 4, 4),
    };
    let mut net = Network::new(
        &format!("ResNet20-{}", profile.name()),
        [32, 32, 4],
        8,
    );
    // Stem (RGB padded to 4 channels, 8-bit I/O then quantized down).
    let stem = conv("conv1".into(), [32, 32, 4], 16, 3, 1, 8, 8, a_bits, &mut rng);
    let mut shape = stem.out_shape;
    let mut prev = net.push(stem);
    // 3 stages × 3 basic blocks.
    let stage_ch = [16usize, 32, 64];
    for (s, &c) in stage_ch.iter().enumerate() {
        for b in 0..3 {
            // HAWQ-style assignment: the two widest blocks (stage 3,
            // blocks 1-2) carry most parameters and the most Hessian
            // sensitivity -> 4-bit; everything else 2-bit.
            let wb = if s == 2 && b > 0 { w_late } else { w_early };
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let c1 = conv(
                format!("s{s}b{b}c1"),
                shape,
                c,
                3,
                stride,
                a_bits,
                wb,
                a_bits,
                &mut rng,
            );
            let c1_shape = c1.out_shape;
            let id1 = net.push_with_inputs(c1, vec![prev]);
            let c2 = conv(format!("s{s}b{b}c2"), c1_shape, c, 3, 1, a_bits, wb, a_bits, &mut rng);
            let c2_shape = c2.out_shape;
            let id2 = net.push_with_inputs(c2, vec![id1]);
            // Shortcut: identity, or 1×1/s2 projection on stage entry.
            let short = if stride != 1 || shape[2] != c {
                let proj = conv(
                    format!("s{s}b{b}proj"),
                    shape,
                    c,
                    1,
                    stride,
                    a_bits,
                    wb,
                    a_bits,
                    &mut rng,
                );
                net.push_with_inputs(proj, vec![prev])
            } else {
                prev
            };
            let add = Layer {
                name: format!("s{s}b{b}add"),
                kind: LayerKind::Add { m1: 1, m2: 1 },
                in_shape: c2_shape,
                out_shape: c2_shape,
                a_bits,
                w_bits: 8,
                weights: None,
                quant: QuantParams::scalar(1, 1, 0, a_bits, c),
            };
            prev = net.push_with_inputs(add, vec![id2, short]);
            shape = c2_shape;
        }
    }
    // Global average pool + 10-class (padded to 12) classifier.
    let [h, _, c] = shape;
    net.push_with_inputs(
        Layer {
            name: "avgpool".into(),
            kind: LayerKind::AvgPool { k: h, stride: h },
            in_shape: shape,
            out_shape: [1, 1, c],
            a_bits,
            w_bits: 8,
            weights: None,
            quant: QuantParams::scalar(
                ((1i64 << 16) / (h * h) as i64) as i32,
                16,
                0,
                8,
                c,
            ),
        },
        vec![prev],
    );
    net.push(Layer {
        name: "fc".into(),
        kind: LayerKind::Linear,
        in_shape: [1, 1, c],
        out_shape: [1, 1, 12], // 10 classes padded to a multiple of 4
        a_bits: 8,
        w_bits: 8,
        weights: Some(QTensor::random(&[12, c], 8, true, &mut rng)),
        quant: quant_for(c, 8, 8, 8, 12),
    });
    net
}

/// Look up an evaluation network by its CLI name (`mnv1-8b`,
/// `mnv1-8b4b`, `resnet20-4b2b`). `input_hw` sets the MobileNet input
/// resolution (ResNet-20 is fixed at 32×32). Seeds match the `run-net`
/// subcommand and the Table IV generators, so every consumer (CLI,
/// report, serve engine) builds bit-identical networks — which is what
/// lets the serve plan cache key them structurally.
pub fn by_name(name: &str, input_hw: usize) -> Option<Network> {
    match name {
        "mnv1-8b" => Some(mobilenet_v1(Profile::Uniform8, 0.75, input_hw, 11)),
        "mnv1-8b4b" => Some(mobilenet_v1(Profile::Mixed8a4w, 0.75, input_hw, 11)),
        "resnet20-4b2b" => Some(resnet20(Profile::Mixed4a2w, 12)),
        _ => None,
    }
}

/// The CLI names accepted by [`by_name`].
pub const MODEL_NAMES: [&str; 3] = ["mnv1-8b", "mnv1-8b4b", "resnet20-4b2b"];

/// Table IV's cited accuracies (not re-measured; weights are synthetic).
pub fn cited_accuracy(net_name: &str) -> Option<f64> {
    if net_name.starts_with("MobileNetV1-8b4b") {
        Some(66.0)
    } else if net_name.starts_with("MobileNetV1-8b") {
        Some(69.3)
    } else if net_name.starts_with("ResNet20-4b2b") {
        Some(90.2)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::layer::NET_INPUT;

    #[test]
    fn mnv1_8b_validates_and_counts() {
        let net = mobilenet_v1(Profile::Uniform8, 0.75, 224, 1);
        net.validate().expect("MNV1 invalid");
        // 27 conv/dw layers + pool + fc = 29 nodes
        assert_eq!(net.nodes.len(), 29);
        // MACs in the hundreds of millions at 224x224
        let m = net.total_macs();
        assert!(m > 200e6 as u64 && m < 800e6 as u64, "MACs {m}");
    }

    #[test]
    fn mnv1_mixed_halves_weight_footprint() {
        let full = mobilenet_v1(Profile::Uniform8, 0.75, 224, 1);
        let mixed = mobilenet_v1(Profile::Mixed8a4w, 0.75, 224, 1);
        let (a, b) = (full.model_bytes() as f64, mixed.model_bytes() as f64);
        let saved = 1.0 - b / a;
        // paper: 47% saved
        assert!(saved > 0.40 && saved < 0.55, "saved {saved}");
    }

    #[test]
    fn resnet20_4b2b_footprint_near_table4() {
        let net = resnet20(Profile::Mixed4a2w, 2);
        net.validate().expect("ResNet20 invalid");
        let kb = net.model_bytes() as f64 / 1024.0;
        // Table IV: 142 kB
        assert!(kb > 100.0 && kb < 180.0, "footprint {kb} kB");
        let full = resnet20(Profile::Uniform8, 2);
        let saved = 1.0 - net.model_bytes() as f64 / full.model_bytes() as f64;
        // paper: 63% saved
        assert!(saved > 0.55 && saved < 0.72, "saved {saved}");
    }

    #[test]
    fn resnet20_has_residual_adds() {
        let net = resnet20(Profile::Mixed4a2w, 2);
        let adds = net
            .nodes
            .iter()
            .filter(|n| matches!(n.layer.kind, LayerKind::Add { .. }))
            .count();
        assert_eq!(adds, 9);
        // at least one node consumes the network input
        assert!(net.nodes.iter().any(|n| n.inputs.contains(&NET_INPUT)));
    }

    #[test]
    fn by_name_covers_the_zoo_deterministically() {
        for name in MODEL_NAMES {
            let a = by_name(name, 96).expect(name);
            let b = by_name(name, 96).expect(name);
            a.validate().expect(name);
            assert_eq!(a.name, b.name);
            assert_eq!(a.model_bytes(), b.model_bytes());
        }
        assert!(by_name("nope", 96).is_none());
    }

    #[test]
    fn channel_counts_stay_byte_aligned() {
        for net in [
            mobilenet_v1(Profile::Mixed8a4w, 0.75, 224, 1),
            resnet20(Profile::Mixed4a2w, 2),
        ] {
            for node in &net.nodes {
                let l = &node.layer;
                assert_eq!(
                    l.out_shape[2] * l.quant.out_bits as usize % 8,
                    0,
                    "{} misaligned",
                    l.name
                );
            }
        }
    }
}
