//! Bench: simulator throughput (the §Perf L3 metric) — simulated
//! instructions and cycles per wall-second on the Table III workload.
//!
//!     cargo bench --bench sim_speed

use flexv::isa::IsaVariant;
use flexv::qnn::Precision;
use flexv::report::workloads::matmul_table3_stats;
use std::time::Instant;

fn main() {
    // warmup + measure
    let mut total_instr = 0u64;
    let mut total_core_cycles = 0u64;
    let t0 = Instant::now();
    let mut reps = 0;
    while t0.elapsed().as_secs_f64() < 3.0 {
        let stats = matmul_table3_stats(IsaVariant::FlexV, Precision::new(8, 8));
        total_instr += stats.total_instrs();
        total_core_cycles += stats.cycles * stats.cores.len() as u64;
        reps += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("simulated {reps} Table III a8w8 kernels in {wall:.2}s:");
    println!("  {:>10.1} M instr/s", total_instr as f64 / wall / 1e6);
    println!("  {:>10.1} M core-cycles/s", total_core_cycles as f64 / wall / 1e6);
    println!("  (§Perf target: >= 50 M instr/s so Table IV regenerates in minutes)");
}
