//! Software pack/unpack sequences for ISAs without native sub-byte or
//! mixed-precision support (§I, §V-B).
//!
//! When a core must execute a dot product whose weight format is narrower
//! than what its SIMD unit accepts, the kernel expands a slice of the
//! packed weight word into a full SIMD word of the wider format using the
//! XpulpV2 bit-manipulation instructions (`p.extract` sign-extending +
//! `p.insert`). This is the "massive software overhead" that collapses
//! XpulpNN and RI5CY on mixed-precision kernels (Table III: a8w2 drops to
//! ~6 MAC/cycle) — reproduced here instruction by instruction.

use crate::isa::{Instr, Program, Reg};

/// Emit the expansion of subgroup `sub` of a packed `src_bits` word in
/// `src` into a word of `dst_bits` elements in `dst` (sign-extending, for
/// weights). Produces `32/dst_bits` elements = `2*(dst_bits/src_bits)`
/// instructions (one extract + one insert per element).
///
/// Returns the number of instructions emitted.
pub fn emit_unpack_signed(
    p: &mut Program,
    dst: Reg,
    src: Reg,
    src_bits: u8,
    dst_bits: u8,
    sub: u8,
) -> usize {
    assert!(src_bits < dst_bits, "unpack requires narrower source");
    let lanes = 32 / dst_bits as usize;
    let before = p.len();
    for e in 0..lanes {
        let src_off = (sub as usize * lanes + e) * src_bits as usize;
        // sign-extending extract into dst's lane position via insert
        p.push(Instr::Extract {
            rd: crate::kernels::regalloc::TMP[3],
            rs1: src,
            off: src_off as u8,
            len: src_bits,
        });
        p.push(Instr::Insert {
            rd: dst,
            rs1: crate::kernels::regalloc::TMP[3],
            off: (e * dst_bits as usize) as u8,
            len: dst_bits,
        });
    }
    p.len() - before
}

/// Same for unsigned (activations expanded during pre-pass / im2col).
pub fn emit_unpack_unsigned(
    p: &mut Program,
    dst: Reg,
    src: Reg,
    src_bits: u8,
    dst_bits: u8,
    sub: u8,
) -> usize {
    assert!(src_bits < dst_bits);
    let lanes = 32 / dst_bits as usize;
    let before = p.len();
    for e in 0..lanes {
        let src_off = (sub as usize * lanes + e) * src_bits as usize;
        p.push(Instr::ExtractU {
            rd: crate::kernels::regalloc::TMP[3],
            rs1: src,
            off: src_off as u8,
            len: src_bits,
        });
        p.push(Instr::Insert {
            rd: dst,
            rs1: crate::kernels::regalloc::TMP[3],
            off: (e * dst_bits as usize) as u8,
            len: dst_bits,
        });
    }
    p.len() - before
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::packing;
    use crate::sim::{ClusterMem, Core};
    use crate::util::{proptest, Prng};

    fn run_unpack(src_word: u32, src_bits: u8, dst_bits: u8, sub: u8, signed: bool) -> u32 {
        let mut p = Program::new("u");
        if signed {
            emit_unpack_signed(&mut p, 5, 6, src_bits, dst_bits, sub);
        } else {
            emit_unpack_unsigned(&mut p, 5, 6, src_bits, dst_bits, sub);
        }
        p.push(Instr::Halt);
        let mut c = Core::new(0);
        c.load_program(p);
        c.regs[6] = src_word;
        let mut mem = ClusterMem::new();
        while !c.halted() {
            let g = c.mem_request().is_some();
            c.tick(&mut mem, g);
        }
        c.regs[5]
    }

    #[test]
    fn unpack_w4_to_w8_signed() {
        // nibbles [1, -1, 7, -8] (sub 0) and [2, -2, 3, -3] (sub 1)
        let vals = [1i32, -1, 7, -8, 2, -2, 3, -3];
        let packed_bytes = packing::pack_signed(&vals, 4);
        let word = u32::from_le_bytes([
            packed_bytes[0],
            packed_bytes[1],
            packed_bytes[2],
            packed_bytes[3],
        ]);
        let out0 = run_unpack(word, 4, 8, 0, true);
        let got0: Vec<i32> = (0..4)
            .map(|i| (((out0 >> (8 * i)) & 0xFF) as u8 as i8) as i32)
            .collect();
        assert_eq!(got0, vec![1, -1, 7, -8]);
        let out1 = run_unpack(word, 4, 8, 1, true);
        let got1: Vec<i32> = (0..4)
            .map(|i| (((out1 >> (8 * i)) & 0xFF) as u8 as i8) as i32)
            .collect();
        assert_eq!(got1, vec![2, -2, 3, -3]);
    }

    #[test]
    fn prop_unpack_matches_packing_roundtrip() {
        proptest::check_default(
            |rng: &mut Prng| {
                let (src_bits, dst_bits) = *rng.pick(&[(2u8, 8u8), (4, 8), (2, 4)]);
                let word = rng.next_u32();
                let reuse = dst_bits / src_bits;
                let sub = rng.range(0, reuse as usize) as u8;
                (src_bits, dst_bits, word, sub)
            },
            |&(src_bits, dst_bits, word, sub)| {
                let out = run_unpack(word, src_bits, dst_bits, sub, true);
                let lanes = 32 / dst_bits as usize;
                for e in 0..lanes {
                    let src_off = (sub as usize * lanes + e) * src_bits as usize;
                    let raw = (word >> src_off) & ((1 << src_bits) - 1);
                    let sh = 32 - src_bits as u32;
                    let want = ((raw << sh) as i32) >> sh;
                    let got_raw = (out >> (e * dst_bits as usize)) & ((1u32 << dst_bits) - 1);
                    let sh2 = 32 - dst_bits as u32;
                    let got = ((got_raw << sh2) as i32) >> sh2;
                    if got != want {
                        return Err(format!("lane {e}: got {got} want {want}"));
                    }
                }
                Ok(())
            },
        );
    }
}
