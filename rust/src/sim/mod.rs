//! The PULP-cluster simulator.
//!
//! A cycle-approximate, functionally-exact model of the system in Fig. 1 of
//! the paper: eight RI5CY-class cores (parameterized by
//! [`crate::isa::IsaVariant`]) sharing a 16-bank 128 kB TCDM through a
//! one-cycle logarithmic interconnect, a non-blocking cluster DMA moving
//! data between L2 and TCDM, and a hardware synchronization unit providing
//! low-overhead barriers.
//!
//! Timing model (RI5CY 4-stage in-order single-issue pipeline):
//! - 1 instruction issued per cycle per core;
//! - 1-cycle load-use penalty (consumer immediately after a load);
//! - TCDM bank conflicts stall the losing cores (round-robin arbitration,
//!   one request per bank per cycle; DMA has lowest priority);
//! - taken branches cost 2 bubble cycles; hardware loops are free;
//! - fused Mac&Load issues the sdotp and performs its NN-RF load in the
//!   write-back stage (one issue slot, one TCDM port use);
//! - barriers clock-gate waiting cores and release one cycle after the
//!   last core arrives.
//!
//! Timing fidelity is tiered ([`CoreFidelity`], module [`pipeline`]):
//! the default fast tier charges the flat costs above; the pipeline
//! tier refines them with an explicit IF/ID/EX/WB model adding Mac&Load
//! write-back port contention and sub-word realignment stalls. The two
//! tiers are bit-identical on all architectural state by construction
//! and differ only in cycle accounting.
//!
//! Functional model: exact integer semantics for every instruction — kernel
//! outputs are compared bit-exactly against [`crate::qnn::golden`] and
//! against the AOT JAX/Pallas artifacts through [`crate::runtime`].
//!
//! Steady-state fast path ([`fastpath`], [`Cluster::enable_fastpath`]):
//! windows whose instruction trace, DMA schedule and arbiter phase have
//! been seen before are replayed from a memo (timing always, functional
//! effects either from the recorded delta or via fast straight-line
//! re-execution) instead of being re-simulated cycle by cycle — outputs
//! and cycle counts stay bit-identical, and a cross-check mode
//! re-simulates every replayed window in tests.
//!
//! Cycles vs. wall time: the simulator counts **core clock cycles**,
//! which are frequency-independent — a kernel costs the same number of
//! cycles at every DVFS operating point. Conversion to time (and hence
//! to power and energy) happens one layer up: [`crate::power`] defines
//! the GF22FDX operating points (Table II), each with its own clock
//! period, and [`crate::power::OperatingPoint::fleet_ticks`] rescales a
//! core-cycle count into ticks of the serving fleet's nominal clock.
//! Nothing in this module depends on the chosen point, which is what
//! lets the serving layer change frequency per batch without touching
//! simulated results.

pub mod cluster;
pub mod core;
pub mod dma;
pub mod fastpath;
pub mod mem;
pub mod mlc;
pub mod pipeline;
pub mod stats;

pub use cluster::Cluster;
pub use core::Core;
pub use dma::{Dma, DmaRequest};
pub use fastpath::{FastPath, WindowCache};
pub use mem::{AccessTrace, ClusterMem, L2_BASE, TCDM_BASE};
pub use mlc::MlcChannel;
pub use pipeline::CoreFidelity;
pub use stats::{ClusterStats, CoreStats};
