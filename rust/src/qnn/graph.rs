//! Explicit graph IR for quantized networks.
//!
//! [`Graph`] separates *topology* from *execution order*: nodes are
//! quantized ops ([`OpNode`]), edges are tensors ([`TensorDef`]) carrying
//! shape, bit-width and the producing op's [`QuantParams`]. A deterministic
//! topological scheduler ([`Graph::schedule`]) lowers the graph back to the
//! linear [`Network`] that `dory::deploy` and the coordinator consume —
//! for graphs authored in execution order (every builder and every
//! canonical `.qir` file) the schedule is the identity, so lowering is
//! bit-identical to hand-constructing the `Network` directly.
//!
//! Weights are synthetic and seeded (the determinism contract of
//! `models/mod.rs` and `docs/QIR_FORMAT.md`): ops with weights draw them in
//! *definition order* from one shared PRNG stream seeded with
//! [`Graph::seed`], except where an op carries its own `seed` override,
//! which starts a fresh stream for that op alone. Lowering the same graph
//! twice therefore yields byte-identical weight tensors, which is what lets
//! the serve plan cache and the autotune cache key networks structurally.

use super::layer::{Layer, LayerKind, Network, NET_INPUT};
use super::{check_bits, QTensor, QuantParams};
use crate::util::Prng;

/// Index into [`Graph::tensors`].
pub type TensorId = usize;

/// One edge of the graph: a named activation tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorDef {
    pub name: String,
    /// `[H, W, C]`, HWC layout.
    pub shape: [usize; 3],
    /// Unsigned element bit-width (2/4/8).
    pub bits: u8,
    /// Requantization parameters of the producing op; `None` only for the
    /// graph input.
    pub quant: Option<QuantParams>,
}

/// Operator kind carried by an [`OpNode`]. Mirrors [`LayerKind`] but lives
/// on the graph side so the IR can evolve independently of the lowered form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Conv2d { kh: usize, kw: usize, stride: usize, pad: usize },
    DwConv2d { kh: usize, kw: usize, stride: usize, pad: usize },
    Linear,
    MaxPool { k: usize, stride: usize },
    AvgPool { k: usize, stride: usize },
    Add { m1: i32, m2: i32 },
    Concat,
}

impl OpKind {
    /// The `.qir` keyword for this op.
    pub fn token(&self) -> &'static str {
        match self {
            OpKind::Conv2d { .. } => "conv",
            OpKind::DwConv2d { .. } => "dwconv",
            OpKind::Linear => "linear",
            OpKind::MaxPool { .. } => "maxpool",
            OpKind::AvgPool { .. } => "avgpool",
            OpKind::Add { .. } => "add",
            OpKind::Concat => "concat",
        }
    }

    /// True for ops that carry a weight tensor.
    pub fn weighted(&self) -> bool {
        matches!(self, OpKind::Conv2d { .. } | OpKind::DwConv2d { .. } | OpKind::Linear)
    }

    /// Number of input tensors the op consumes.
    pub fn arity(&self) -> usize {
        match self {
            OpKind::Add { .. } | OpKind::Concat => 2,
            _ => 1,
        }
    }

    fn to_layer_kind(self) -> LayerKind {
        match self {
            OpKind::Conv2d { kh, kw, stride, pad } => LayerKind::Conv2d { kh, kw, stride, pad },
            OpKind::DwConv2d { kh, kw, stride, pad } => {
                LayerKind::DwConv2d { kh, kw, stride, pad }
            }
            OpKind::Linear => LayerKind::Linear,
            OpKind::MaxPool { k, stride } => LayerKind::MaxPool { k, stride },
            OpKind::AvgPool { k, stride } => LayerKind::AvgPool { k, stride },
            OpKind::Add { m1, m2 } => LayerKind::Add { m1, m2 },
            OpKind::Concat => LayerKind::Concat,
        }
    }
}

/// One node of the graph: a quantized op reading input tensors and
/// producing exactly one output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct OpNode {
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
    /// Signed weight bit-width for weighted ops; 8 (don't-care, matches the
    /// hand-coded builders) otherwise.
    pub w_bits: u8,
    /// Per-op weight stream override: `Some(s)` draws this op's weights
    /// from a fresh `Prng::new(s)` instead of the graph's shared stream.
    pub seed: Option<u64>,
}

/// A quantized network as an explicit DAG.
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    pub name: String,
    /// Seed of the shared synthetic-weight stream.
    pub seed: u64,
    /// The network input tensor.
    pub input: TensorId,
    pub tensors: Vec<TensorDef>,
    pub ops: Vec<OpNode>,
}

impl Graph {
    /// Fresh graph with a single input tensor named `input`.
    pub fn new(name: &str, input_shape: [usize; 3], input_bits: u8, seed: u64) -> Graph {
        Graph {
            name: name.into(),
            seed,
            input: 0,
            tensors: vec![TensorDef {
                name: "input".into(),
                shape: input_shape,
                bits: input_bits,
                quant: None,
            }],
            ops: vec![],
        }
    }

    /// Tensor id by name.
    pub fn tensor(&self, name: &str) -> Option<TensorId> {
        self.tensors.iter().position(|t| t.name == name)
    }

    /// Append an op, creating its output tensor (named after the op) from
    /// `out_shape` and `quant`. Returns the output tensor id.
    #[allow(clippy::too_many_arguments)]
    pub fn op(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: &[TensorId],
        w_bits: u8,
        out_shape: [usize; 3],
        quant: QuantParams,
        seed: Option<u64>,
    ) -> TensorId {
        let out = self.tensors.len();
        self.tensors.push(TensorDef {
            name: name.into(),
            shape: out_shape,
            bits: quant.out_bits,
            quant: Some(quant),
        });
        self.ops.push(OpNode {
            name: name.into(),
            kind,
            inputs: inputs.to_vec(),
            output: out,
            w_bits,
            seed,
        });
        out
    }

    /// Shape of the weight tensor an op draws, if any.
    fn weight_shape(&self, op: &OpNode) -> Option<Vec<usize>> {
        let in_shape = self.tensors[op.inputs[0]].shape;
        let out_shape = self.tensors[op.output].shape;
        match op.kind {
            OpKind::Conv2d { kh, kw, .. } => Some(vec![out_shape[2], kh, kw, in_shape[2]]),
            OpKind::DwConv2d { kh, kw, .. } => Some(vec![in_shape[2], kh, kw, 1]),
            OpKind::Linear => {
                Some(vec![out_shape[2], in_shape.iter().product()])
            }
            _ => None,
        }
    }

    /// Structural validation: names, arities, bit-widths, per-op output
    /// geometry, quantization coverage and byte alignment. Returns a
    /// description of the first inconsistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.input >= self.tensors.len() {
            return Err("input tensor id out of range".into());
        }
        if self.tensors[self.input].quant.is_some() {
            return Err("input tensor must not carry quant params".into());
        }
        for (i, t) in self.tensors.iter().enumerate() {
            if t.name.is_empty() || t.name.contains(char::is_whitespace) {
                return Err(format!("tensor {i} has invalid name {:?}", t.name));
            }
            if self.tensors.iter().filter(|o| o.name == t.name).count() != 1 {
                return Err(format!("duplicate tensor name {:?}", t.name));
            }
            if !check_bits(t.bits) {
                return Err(format!("tensor {} has unsupported bits {}", t.name, t.bits));
            }
            if t.shape.iter().any(|&d| d == 0) {
                return Err(format!("tensor {} has zero dim {:?}", t.name, t.shape));
            }
            if t.shape[2] * t.bits as usize % 8 != 0 {
                return Err(format!(
                    "tensor {}: {} channels x {} bits not byte-aligned",
                    t.name, t.shape[2], t.bits
                ));
            }
            if let Some(q) = &t.quant {
                if q.out_bits != t.bits {
                    return Err(format!(
                        "tensor {}: quant out_bits {} != tensor bits {}",
                        t.name, q.out_bits, t.bits
                    ));
                }
                if q.channels() != t.shape[2] {
                    return Err(format!(
                        "tensor {}: quant covers {} channels, tensor has {}",
                        t.name,
                        q.channels(),
                        t.shape[2]
                    ));
                }
            }
        }
        let mut producer = vec![usize::MAX; self.tensors.len()];
        for (i, op) in self.ops.iter().enumerate() {
            if op.name.is_empty() || op.name.contains(char::is_whitespace) {
                return Err(format!("op {i} has invalid name {:?}", op.name));
            }
            if self.ops.iter().filter(|o| o.name == op.name).count() != 1 {
                return Err(format!("duplicate op name {:?}", op.name));
            }
            if op.output >= self.tensors.len() {
                return Err(format!("op {} output tensor out of range", op.name));
            }
            if op.output == self.input {
                return Err(format!("op {} writes the graph input", op.name));
            }
            if producer[op.output] != usize::MAX {
                return Err(format!(
                    "tensor {} produced twice",
                    self.tensors[op.output].name
                ));
            }
            producer[op.output] = i;
            if self.tensors[op.output].quant.is_none() {
                return Err(format!(
                    "op {} output tensor {} lacks quant params",
                    op.name, self.tensors[op.output].name
                ));
            }
            if op.inputs.len() != op.kind.arity() {
                return Err(format!(
                    "op {} has {} inputs, wants {}",
                    op.name,
                    op.inputs.len(),
                    op.kind.arity()
                ));
            }
            if op.inputs.iter().any(|&t| t >= self.tensors.len()) {
                return Err(format!("op {} input tensor out of range", op.name));
            }
            if op.kind.weighted() && !check_bits(op.w_bits) {
                return Err(format!("op {}: unsupported w_bits {}", op.name, op.w_bits));
            }
            self.check_geometry(op)?;
        }
        for (t, &p) in producer.iter().enumerate() {
            if p == usize::MAX && t != self.input {
                return Err(format!("tensor {} has no producer", self.tensors[t].name));
            }
        }
        Ok(())
    }

    /// Output-shape/bits consistency for one op.
    fn check_geometry(&self, op: &OpNode) -> Result<(), String> {
        let i0 = &self.tensors[op.inputs[0]];
        let out = &self.tensors[op.output];
        let [h, w, c] = i0.shape;
        let err = |msg: String| Err(format!("op {}: {msg}", op.name));
        let window = |k: usize, pad: usize, stride: usize, dim: usize| -> Result<usize, String> {
            if dim + 2 * pad < k {
                return Err(format!("op {}: window {k} exceeds padded dim {dim}", op.name));
            }
            Ok((dim + 2 * pad - k) / stride + 1)
        };
        let want = match op.kind {
            OpKind::Conv2d { kh, kw, stride, pad } => {
                [window(kh, pad, stride, h)?, window(kw, pad, stride, w)?, out.shape[2]]
            }
            OpKind::DwConv2d { kh, kw, stride, pad } => {
                [window(kh, pad, stride, h)?, window(kw, pad, stride, w)?, c]
            }
            OpKind::Linear => [1, 1, out.shape[2]],
            OpKind::MaxPool { k, stride } | OpKind::AvgPool { k, stride } => {
                [window(k, 0, stride, h)?, window(k, 0, stride, w)?, c]
            }
            OpKind::Add { .. } => {
                let i1 = &self.tensors[op.inputs[1]];
                if i1.shape != i0.shape {
                    return err(format!(
                        "add inputs differ: {:?} vs {:?}",
                        i0.shape, i1.shape
                    ));
                }
                i0.shape
            }
            OpKind::Concat => {
                let i1 = &self.tensors[op.inputs[1]];
                if i1.shape[0] != h || i1.shape[1] != w {
                    return err(format!(
                        "concat inputs differ in HxW: {:?} vs {:?}",
                        i0.shape, i1.shape
                    ));
                }
                if i1.bits != i0.bits || out.bits != i0.bits {
                    return err("concat must not change bit-width".into());
                }
                [h, w, c + i1.shape[2]]
            }
        };
        if out.shape != want {
            return err(format!("out shape {:?}, geometry wants {:?}", out.shape, want));
        }
        if matches!(op.kind, OpKind::MaxPool { .. }) && out.bits != i0.bits {
            return err("maxpool must not change bit-width".into());
        }
        Ok(())
    }

    /// Deterministic topological schedule (Kahn with min-index tie-break):
    /// the returned op ids respect data dependencies, and a graph whose
    /// definition order is already topological schedules as the identity —
    /// the property that keeps `.qir`-imported networks bit-identical to
    /// the hand-coded builders.
    pub fn schedule(&self) -> Result<Vec<usize>, String> {
        let mut producer = vec![usize::MAX; self.tensors.len()];
        for (i, op) in self.ops.iter().enumerate() {
            producer[op.output] = i;
        }
        let mut done = vec![false; self.ops.len()];
        let mut order = Vec::with_capacity(self.ops.len());
        for _ in 0..self.ops.len() {
            let next = self.ops.iter().enumerate().position(|(i, op)| {
                !done[i]
                    && op.inputs.iter().all(|&t| {
                        t == self.input || (producer[t] != usize::MAX && done[producer[t]])
                    })
            });
            match next {
                Some(i) => {
                    done[i] = true;
                    order.push(i);
                }
                None => {
                    let stuck: Vec<&str> = self
                        .ops
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| !done[*i])
                        .map(|(_, op)| op.name.as_str())
                        .collect();
                    return Err(format!("graph has a cycle through {stuck:?}"));
                }
            }
        }
        Ok(order)
    }

    /// Materialize seeded synthetic weights for every weighted op, in
    /// *definition order* (the determinism contract).
    fn materialize_weights(&self) -> Vec<Option<QTensor>> {
        let mut shared = Prng::new(self.seed);
        self.ops
            .iter()
            .map(|op| {
                let shape = self.weight_shape(op)?;
                Some(match op.seed {
                    Some(s) => {
                        let mut own = Prng::new(s);
                        QTensor::random(&shape, op.w_bits, true, &mut own)
                    }
                    None => QTensor::random(&shape, op.w_bits, true, &mut shared),
                })
            })
            .collect()
    }

    /// Lower to the linear [`Network`] the deployment stack consumes:
    /// validate, schedule, materialize weights, then emit nodes in schedule
    /// order with producer indices rewritten to schedule positions.
    pub fn lower(&self) -> Result<Network, String> {
        self.validate()?;
        let order = self.schedule()?;
        let mut weights = self.materialize_weights();
        let mut producer = vec![usize::MAX; self.tensors.len()];
        for (i, op) in self.ops.iter().enumerate() {
            producer[op.output] = i;
        }
        let mut pos = vec![usize::MAX; self.ops.len()];
        for (p, &i) in order.iter().enumerate() {
            pos[i] = p;
        }
        let input = &self.tensors[self.input];
        let mut net = Network::new(&self.name, input.shape, input.bits);
        for &i in &order {
            let op = &self.ops[i];
            let out = &self.tensors[op.output];
            let layer = Layer {
                name: op.name.clone(),
                kind: op.kind.to_layer_kind(),
                in_shape: self.tensors[op.inputs[0]].shape,
                out_shape: out.shape,
                a_bits: self.tensors[op.inputs[0]].bits,
                w_bits: op.w_bits,
                weights: weights[i].take(),
                quant: out.quant.clone().expect("validated: non-input tensors carry quant"),
            };
            let inputs = op
                .inputs
                .iter()
                .map(|&t| if t == self.input { NET_INPUT } else { pos[producer[t]] })
                .collect();
            net.push_with_inputs(layer, inputs);
        }
        net.validate().map_err(|e| format!("lowered network invalid: {e}"))?;
        Ok(net)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", [8, 8, 8], 8, 7);
        let c1 = g.op(
            "c1",
            OpKind::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
            &[g.input],
            8,
            [8, 8, 16],
            QuantParams::scalar(1, 10, 0, 8, 16),
            None,
        );
        let gap = g.op(
            "gap",
            OpKind::AvgPool { k: 8, stride: 8 },
            &[c1],
            8,
            [1, 1, 16],
            QuantParams::scalar(1024, 16, 0, 8, 16),
            None,
        );
        g.op(
            "fc",
            OpKind::Linear,
            &[gap],
            4,
            [1, 1, 8],
            QuantParams::scalar(1, 7, 0, 8, 8),
            None,
        );
        g
    }

    #[test]
    fn schedule_is_identity_for_ordered_graphs() {
        let g = tiny();
        g.validate().expect("tiny graph invalid");
        assert_eq!(g.schedule().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn schedule_reorders_out_of_order_definitions() {
        let mut g = tiny();
        // Swap op definition order (c1 <-> gap): still schedulable.
        g.ops.swap(0, 1);
        assert_eq!(g.schedule().unwrap(), vec![1, 0, 2]);
        let net = g.lower().expect("lower after reorder");
        assert_eq!(net.nodes[0].layer.name, "c1");
        assert_eq!(net.nodes[1].layer.name, "gap");
    }

    #[test]
    fn schedule_detects_cycles() {
        let mut g = tiny();
        // fc pretends to consume its own output.
        let out = g.ops[2].output;
        g.ops[2].inputs = vec![out];
        assert!(g.schedule().unwrap_err().contains("cycle"));
    }

    #[test]
    fn lowering_is_deterministic() {
        let a = tiny().lower().unwrap();
        let b = tiny().lower().unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(a.nodes.len(), 3);
        assert!(a.nodes[0].layer.weights.is_some());
    }

    #[test]
    fn per_op_seed_forks_the_weight_stream() {
        let base = tiny().lower().unwrap();
        let mut g = tiny();
        g.ops[2].seed = Some(99);
        let forked = g.lower().unwrap();
        // conv weights from the shared stream are unchanged...
        assert_eq!(base.nodes[0].layer.weights, forked.nodes[0].layer.weights);
        // ...but the reseeded fc draws differently.
        assert_ne!(base.nodes[2].layer.weights, forked.nodes[2].layer.weights);
    }

    #[test]
    fn validate_rejects_bad_geometry() {
        let mut g = tiny();
        g.tensors[1].shape = [4, 4, 16]; // conv output cannot be 4x4
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_missing_producer() {
        let mut g = tiny();
        g.tensors.push(TensorDef {
            name: "orphan".into(),
            shape: [1, 1, 8],
            bits: 8,
            quant: Some(QuantParams::scalar(1, 0, 0, 8, 8)),
        });
        assert!(g.validate().unwrap_err().contains("no producer"));
    }

    #[test]
    fn concat_geometry_sums_channels() {
        let mut g = Graph::new("cat", [4, 4, 8], 8, 1);
        let a = g.op(
            "a",
            OpKind::Conv2d { kh: 1, kw: 1, stride: 1, pad: 0 },
            &[g.input],
            8,
            [4, 4, 8],
            QuantParams::scalar(1, 9, 0, 8, 8),
            None,
        );
        let b = g.op(
            "b",
            OpKind::Conv2d { kh: 1, kw: 1, stride: 1, pad: 0 },
            &[g.input],
            8,
            [4, 4, 16],
            QuantParams::scalar(1, 9, 0, 8, 16),
            None,
        );
        g.op(
            "cat",
            OpKind::Concat,
            &[a, b],
            8,
            [4, 4, 24],
            QuantParams::scalar(1, 0, 0, 8, 24),
            None,
        );
        g.validate().expect("concat graph invalid");
        let net = g.lower().expect("concat lowers");
        assert_eq!(net.nodes[2].layer.out_shape, [4, 4, 24]);
        assert_eq!(net.nodes[2].inputs, vec![0, 1]);
    }
}
