//! Disassembler: renders the instruction IR in the paper's assembly
//! notation (Fig. 5) — `pv.mlsdotusp.b s1, aw, ...`, `csrwi simd_fmt`,
//! `lp.setup` — so generated kernels can be inspected side-by-side with
//! the listing in the paper.
//!
//! The rendering is **lossless** against [`crate::isa::parse::parse`]
//! (encode→disasm→parse roundtrips to the same instruction) with two
//! documented conventions: state a real encoding cannot carry rides in
//! a trailing `#` comment (`mpc_cnt`, the fused `wb-load` target), and
//! post-modified memory ops render only their increment — the XpulpV2
//! encoding has no separate offset field, and the kernel generators
//! never emit one (asserted by the roundtrip property test).

use super::instr::{AluOp, Cond, Csr, Instr, MlChannel, MlUpdate, SimdFmt};
use super::Program;

fn fmt_suffix(f: SimdFmt) -> &'static str {
    match f {
        SimdFmt::Half => "h",
        SimdFmt::Byte => "b",
        SimdFmt::Nibble => "n",
        SimdFmt::Crumb => "c",
    }
}

/// Mnemonic suffix encoding the operand formats: one letter when both
/// operands share a format, activation-then-weight letters otherwise.
/// The single place that pins the convention
/// [`crate::isa::parse`]'s `fmts_from_mix` inverts.
pub(crate) fn mix_suffix(a_fmt: SimdFmt, w_fmt: SimdFmt) -> String {
    if a_fmt == w_fmt {
        fmt_suffix(a_fmt).to_string()
    } else {
        format!("{}{}", fmt_suffix(a_fmt), fmt_suffix(w_fmt))
    }
}

fn csr_name(c: Csr) -> &'static str {
    match c {
        Csr::SimdFmt => "simd_fmt",
        Csr::MixSkip => "mix_skip",
        Csr::SbLegacy => "sb_legacy",
        Csr::AStride => "a_stride",
        Csr::WStride => "w_stride",
        Csr::ARollback => "a_rollback",
        Csr::WRollback => "w_rollback",
        Csr::ASkip => "a_skip",
        Csr::WSkip => "w_skip",
        Csr::ABase => "a_csr",
        Csr::WBase => "w_csr",
    }
}

fn nn_slot(s: u8) -> String {
    if s < 4 { format!("w{s}") } else { format!("a{}", s - 4) }
}

/// Render one instruction.
pub fn disasm(i: &Instr) -> String {
    match *i {
        Instr::Li { rd, imm } => format!("li      x{rd}, {imm:#x}"),
        Instr::Alu { op, rd, rs1, rs2 } => {
            format!("{:<7} x{rd}, x{rs1}, x{rs2}", alu_name(op))
        }
        Instr::AluI { op, rd, rs1, imm } => {
            format!("{:<7} x{rd}, x{rs1}, {imm}", format!("{}i", alu_name(op)))
        }
        Instr::ExtractU { rd, rs1, off, len } => {
            format!("p.extractu x{rd}, x{rs1}, {len}, {off}")
        }
        Instr::Extract { rd, rs1, off, len } => {
            format!("p.extract x{rd}, x{rs1}, {len}, {off}")
        }
        Instr::Insert { rd, rs1, off, len } => {
            format!("p.insert x{rd}, x{rs1}, {len}, {off}")
        }
        Instr::Lw { rd, base, off, post_inc } => {
            if post_inc != 0 {
                format!("p.lw    x{rd}, {post_inc}(x{base}!)")
            } else {
                format!("lw      x{rd}, {off}(x{base})")
            }
        }
        Instr::Lbu { rd, base, off, post_inc } => {
            if post_inc != 0 {
                format!("p.lbu   x{rd}, {post_inc}(x{base}!)")
            } else {
                format!("lbu     x{rd}, {off}(x{base})")
            }
        }
        Instr::Sw { rs, base, off, post_inc } => {
            if post_inc != 0 {
                format!("p.sw    x{rs}, {post_inc}(x{base}!)")
            } else {
                format!("sw      x{rs}, {off}(x{base})")
            }
        }
        Instr::Sb { rs, base, off, post_inc } => {
            if post_inc != 0 {
                format!("p.sb    x{rs}, {post_inc}(x{base}!)")
            } else {
                format!("sb      x{rs}, {off}(x{base})")
            }
        }
        Instr::Mac { rd, rs1, rs2 } => format!("p.mac   x{rd}, x{rs1}, x{rs2}"),
        Instr::Clipu { rd, rs1, bits } => format!("p.clipu x{rd}, x{rs1}, {bits}"),
        Instr::Sdotp { rd, ra, rw, a_fmt, w_fmt, sub } => {
            let mix = mix_suffix(a_fmt, w_fmt);
            // mpc_cnt lives in a CSR-fed counter, not the encoding: it is
            // rendered as a comment whenever it carries information
            // (always for mixed formats, nonzero otherwise).
            if a_fmt != w_fmt || sub != 0 {
                format!("pv.sdotusp.{mix} x{rd}, x{ra}, x{rw}  # mpc_cnt={sub}")
            } else {
                format!("pv.sdotusp.{mix} x{rd}, x{ra}, x{rw}")
            }
        }
        Instr::MlSdotp { acc, a_slot, w_slot, a_fmt, w_fmt, sub, upd } => {
            let mix = mix_suffix(a_fmt, w_fmt);
            let mut notes: Vec<String> = Vec::new();
            if a_fmt != w_fmt || sub != 0 {
                notes.push(format!("mpc_cnt={sub}"));
            }
            if let MlUpdate::Load { ch, slot } = upd {
                notes.push(format!(
                    "wb-load {} <- {}",
                    nn_slot(slot),
                    match ch {
                        MlChannel::Act => "a_ch",
                        MlChannel::Wgt => "w_ch",
                    }
                ));
            }
            format!(
                "pv.mlsdotusp.{mix} x{acc}, {}, {}{}",
                nn_slot(a_slot),
                nn_slot(w_slot),
                if notes.is_empty() {
                    String::new()
                } else {
                    format!("  # {}", notes.join(", "))
                }
            )
        }
        Instr::NnLoad { ch, slot } => format!(
            "p.nnload {}, {}",
            nn_slot(slot),
            match ch {
                MlChannel::Act => "a_ch",
                MlChannel::Wgt => "w_ch",
            }
        ),
        Instr::CsrW { csr, imm } => format!("csrwi   {}, {imm:#x}", csr_name(csr)),
        Instr::LpSetup { l, count, len } => {
            format!("lp.setup l{l}, {count}, +{len}")
        }
        Instr::Branch { cond, rs1, rs2, off } => {
            let c = match cond {
                Cond::Eq => "beq",
                Cond::Ne => "bne",
                Cond::Lt => "blt",
                Cond::Ge => "bge",
            };
            format!("{c}     x{rs1}, x{rs2}, {off:+}")
        }
        Instr::Barrier => "p.barrier".into(),
        Instr::Halt => "halt".into(),
    }
}

fn alu_name(op: AluOp) -> &'static str {
    match op {
        AluOp::Add => "add",
        AluOp::Sub => "sub",
        AluOp::And => "and",
        AluOp::Or => "or",
        AluOp::Xor => "xor",
        AluOp::Sll => "sll",
        AluOp::Srl => "srl",
        AluOp::Sra => "sra",
        AluOp::Mul => "mul",
        AluOp::Min => "min",
        AluOp::Max => "max",
    }
}

/// Render a whole program with addresses.
pub fn disasm_program(p: &Program) -> String {
    let mut out = format!("# {} ({} instructions)\n", p.label, p.len());
    for (pc, i) in p.instrs.iter().enumerate() {
        out.push_str(&format!("{pc:5}:  {}\n", disasm(i)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;

    #[test]
    fn fig5_style_rendering() {
        let ml = Instr::MlSdotp {
            acc: 1,
            a_slot: 4,
            w_slot: 0,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Nibble,
            sub: 1,
            upd: MlUpdate::Load { ch: MlChannel::Wgt, slot: 2 },
        };
        let s = disasm(&ml);
        assert!(s.contains("pv.mlsdotusp.bn"), "{s}");
        assert!(s.contains("a0") && s.contains("w0") && s.contains("w2"), "{s}");
        assert_eq!(disasm(&Instr::CsrW { csr: Csr::MixSkip, imm: 2 }), "csrwi   mix_skip, 0x2");
        assert!(disasm(&Instr::LpSetup { l: 0, count: 70, len: 17 }).contains("lp.setup"));
    }

    #[test]
    fn program_listing_has_every_instruction() {
        let mut p = Program::new("demo");
        p.push(Instr::Li { rd: 1, imm: 0 });
        p.push(Instr::Halt);
        let listing = disasm_program(&p);
        assert_eq!(listing.lines().count(), 3);
        assert!(listing.contains("halt"));
    }
}
