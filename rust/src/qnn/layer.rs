//! Layer and network-graph definitions.
//!
//! A [`Network`] is a DAG of [`Layer`] nodes (chains plus residual adds —
//! enough to express the paper's benchmark networks: MobileNetV1 variants
//! and ResNet-20). Every layer carries its own precision configuration,
//! which is the whole point of *fine-grain mixed-precision* deployment:
//! DORY sizes tiles and transfers per-layer from these formats.

use super::{QTensor, QuantParams};
use crate::util::Prng;

/// The operator kinds needed by the paper's evaluation networks.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// Standard convolution, weights `[Cout, Kh, Kw, Cin]`.
    Conv2d { kh: usize, kw: usize, stride: usize, pad: usize },
    /// Depthwise convolution, weights `[C, Kh, Kw, 1]`.
    DwConv2d { kh: usize, kw: usize, stride: usize, pad: usize },
    /// Fully connected, weights `[Cout, Cin]` over flattened input.
    Linear,
    /// Max pooling (no weights).
    MaxPool { k: usize, stride: usize },
    /// Average pooling (no weights); result requantized via `quant`.
    AvgPool { k: usize, stride: usize },
    /// Residual add of two inputs with independent scale factors:
    /// `out = clip((x1*m1 + x2*m2) >> shift)`.
    Add { m1: i32, m2: i32 },
    /// Channel-wise concatenation of two inputs sharing H×W and bit-width:
    /// `out[.., ..c1] = x1`, `out[.., c1..] = x2`. Pure data movement — no
    /// requantization (`quant.out_bits` must equal `a_bits`). `in_shape`
    /// holds the *first* input; the second contributes the remaining
    /// `out C - in C` channels.
    Concat,
}

/// One node of the network graph.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input activation shape `[H, W, C]`.
    pub in_shape: [usize; 3],
    /// Output activation shape `[H, W, C]`.
    pub out_shape: [usize; 3],
    /// Input activation bit-width (unsigned).
    pub a_bits: u8,
    /// Weight bit-width (signed); meaningless for pool/add.
    pub w_bits: u8,
    /// Weights, packed; `None` for weight-less ops.
    pub weights: Option<QTensor>,
    /// Requantization parameters producing `quant.out_bits` outputs.
    pub quant: QuantParams,
}

impl Layer {
    /// Multiply-accumulate count of this layer (the paper's op metric:
    /// 1 MAC = 2 ops).
    pub fn macs(&self) -> u64 {
        let [oh, ow, oc] = self.out_shape;
        let [_, _, ic] = self.in_shape;
        match &self.kind {
            LayerKind::Conv2d { kh, kw, .. } => (oh * ow * oc * kh * kw * ic) as u64,
            LayerKind::DwConv2d { kh, kw, .. } => (oh * ow * oc * kh * kw) as u64,
            LayerKind::Linear => {
                let cin: usize = self.in_shape.iter().product();
                (oc * cin) as u64
            }
            // pooling/add/concat contribute no MACs in the paper's accounting
            LayerKind::MaxPool { .. }
            | LayerKind::AvgPool { .. }
            | LayerKind::Add { .. }
            | LayerKind::Concat => 0,
        }
    }

    /// Packed weight bytes (+ quantization parameter bytes).
    pub fn weight_bytes(&self) -> usize {
        self.weights.as_ref().map(|w| w.bytes()).unwrap_or(0) + self.quant.bytes()
    }

    /// Packed input activation bytes.
    pub fn in_bytes(&self) -> usize {
        let [h, w, c] = self.in_shape;
        h * w * c * self.a_bits as usize / 8
    }

    /// Packed output activation bytes.
    pub fn out_bytes(&self) -> usize {
        let [h, w, c] = self.out_shape;
        h * w * c * self.quant.out_bits as usize / 8
    }

    /// Convenience: build a conv layer with random weights and benign
    /// requantization parameters (used by tests/benches).
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: &str,
        in_shape: [usize; 3],
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        a_bits: u8,
        w_bits: u8,
        out_bits: u8,
        rng: &mut Prng,
    ) -> Layer {
        let [h, w, cin] = in_shape;
        let oh = (h + 2 * pad - kh) / stride + 1;
        let ow = (w + 2 * pad - kw) / stride + 1;
        let weights = QTensor::random(&[cout, kh, kw, cin], w_bits, true, rng);
        // A multiplier/shift pair that keeps outputs well-distributed:
        // sum of k*cin products of (a < 2^a) * (|w| < 2^(w-1)).
        let acc_bits =
            (a_bits as u32 + w_bits as u32 - 1) + (kh * kw * cin).next_power_of_two().trailing_zeros();
        let shift = (acc_bits as i32 - out_bits as i32).clamp(0, 31) as u8;
        let quant = QuantParams::scalar(1, shift, 0, out_bits, cout);
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv2d { kh, kw, stride, pad },
            in_shape,
            out_shape: [oh, ow, cout],
            a_bits,
            w_bits,
            weights: Some(weights),
            quant,
        }
    }
}

/// One node in the DAG: a layer plus the indices of its producer nodes.
/// Index 0 refers to the network input for the first node.
#[derive(Clone, Debug)]
pub struct Node {
    pub layer: Layer,
    /// Producer node ids; `usize::MAX` denotes the network input.
    pub inputs: Vec<usize>,
}

/// The network input sentinel.
pub const NET_INPUT: usize = usize::MAX;

/// A DAG of layers in topological order.
#[derive(Clone, Debug, Default)]
pub struct Network {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Network input shape `[H, W, C]`.
    pub input_shape: [usize; 3],
    /// Network input bit-width.
    pub input_bits: u8,
}

impl Network {
    pub fn new(name: &str, input_shape: [usize; 3], input_bits: u8) -> Self {
        Network { name: name.into(), nodes: vec![], input_shape, input_bits }
    }

    /// Append a node consuming the previous node's output (or the network
    /// input if it is the first). Returns its id.
    pub fn push(&mut self, layer: Layer) -> usize {
        let prev = if self.nodes.is_empty() { NET_INPUT } else { self.nodes.len() - 1 };
        self.push_with_inputs(layer, vec![prev])
    }

    /// Append a node with explicit producers. Returns its id.
    pub fn push_with_inputs(&mut self, layer: Layer, inputs: Vec<usize>) -> usize {
        for &i in &inputs {
            assert!(i == NET_INPUT || i < self.nodes.len(), "input {i} not yet defined");
        }
        self.nodes.push(Node { layer, inputs });
        self.nodes.len() - 1
    }

    /// Total MAC count (the paper's complexity metric).
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.layer.macs()).sum()
    }

    /// Total packed weight footprint in bytes — the paper's "model size".
    pub fn model_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.layer.weight_bytes()).sum()
    }

    /// Sanity-check graph shape consistency; returns a description of the
    /// first inconsistency, if any.
    pub fn validate(&self) -> Result<(), String> {
        for (id, node) in self.nodes.iter().enumerate() {
            for (slot, &src) in node.inputs.iter().enumerate() {
                let (shape, bits) = if src == NET_INPUT {
                    (self.input_shape, self.input_bits)
                } else {
                    if src >= id {
                        return Err(format!("node {id} consumes later node {src}"));
                    }
                    (self.nodes[src].layer.out_shape, self.nodes[src].layer.quant.out_bits)
                };
                // Concat's second input carries the channels missing from
                // the first; every other slot must match in_shape exactly.
                let want_shape = if slot == 1 && matches!(node.layer.kind, LayerKind::Concat) {
                    let [h, w, c1] = node.layer.in_shape;
                    let oc = node.layer.out_shape[2];
                    if oc <= c1 {
                        return Err(format!(
                            "node {id} ({}) concat out channels {oc} <= first input {c1}",
                            node.layer.name
                        ));
                    }
                    [h, w, oc - c1]
                } else {
                    node.layer.in_shape
                };
                if shape != want_shape {
                    return Err(format!(
                        "node {id} ({}) input {slot} shape {:?} != producer out_shape {:?}",
                        node.layer.name, want_shape, shape
                    ));
                }
                if bits != node.layer.a_bits {
                    return Err(format!(
                        "node {id} ({}) a_bits {} != producer out_bits {}",
                        node.layer.name, node.layer.a_bits, bits
                    ));
                }
            }
            if matches!(node.layer.kind, LayerKind::Concat)
                && node.layer.quant.out_bits != node.layer.a_bits
            {
                return Err(format!(
                    "node {id} ({}) concat must not requantize (out_bits {} != a_bits {})",
                    node.layer.name, node.layer.quant.out_bits, node.layer.a_bits
                ));
            }
            let want_inputs = match node.layer.kind {
                LayerKind::Add { .. } | LayerKind::Concat => 2,
                _ => 1,
            };
            if node.inputs.len() != want_inputs {
                return Err(format!(
                    "node {id} ({}) has {} inputs, wants {want_inputs}",
                    node.layer.name,
                    node.inputs.len()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_conv(rng: &mut Prng) -> Layer {
        Layer::conv("c", [16, 16, 32], 64, 3, 3, 1, 1, 8, 4, 8, rng)
    }

    #[test]
    fn conv_shapes_and_macs() {
        let mut rng = Prng::new(1);
        let l = mk_conv(&mut rng);
        assert_eq!(l.out_shape, [16, 16, 64]);
        assert_eq!(l.macs(), 16 * 16 * 64 * 3 * 3 * 32);
        // 4-bit weights: 64*3*3*32 / 2 bytes + quant params
        assert_eq!(l.weight_bytes(), 64 * 3 * 3 * 32 / 2 + 64 * 8);
    }

    #[test]
    fn network_chain_validates() {
        let mut rng = Prng::new(2);
        let mut net = Network::new("t", [16, 16, 32], 8);
        let l1 = mk_conv(&mut rng);
        let mut l2 = Layer::conv("c2", [16, 16, 64], 32, 1, 1, 1, 0, 8, 4, 8, &mut rng);
        l2.a_bits = 8;
        net.push(l1);
        net.push(l2);
        assert!(net.validate().is_ok(), "{:?}", net.validate());
        assert!(net.total_macs() > 0);
    }

    #[test]
    fn network_detects_shape_mismatch() {
        let mut rng = Prng::new(3);
        let mut net = Network::new("t", [16, 16, 32], 8);
        net.push(mk_conv(&mut rng));
        // wrong input shape on purpose
        net.push(Layer::conv("bad", [8, 8, 64], 32, 1, 1, 1, 0, 8, 4, 8, &mut rng));
        assert!(net.validate().is_err());
    }

    #[test]
    fn add_requires_two_inputs() {
        let mut rng = Prng::new(4);
        let mut net = Network::new("t", [16, 16, 32], 8);
        let c = net.push(mk_conv(&mut rng));
        let add = Layer {
            name: "add".into(),
            kind: LayerKind::Add { m1: 1, m2: 1 },
            in_shape: [16, 16, 64],
            out_shape: [16, 16, 64],
            a_bits: 8,
            w_bits: 8,
            weights: None,
            quant: QuantParams::scalar(1, 0, 0, 8, 64),
        };
        net.push_with_inputs(add, vec![c]); // only one input: invalid
        assert!(net.validate().is_err());
    }
}
