//! Bench: Table IV — end-to-end networks through the DORY flow.
//! Pass --full for 224x224 MobileNet inputs (default 96x96 quick mode).
//!
//! Pass `--artifact FILE` to also persist the `e2e` benchmark artifact
//! (via the shared `report::bench` suite builder, so these numbers and
//! `flexv bench-report` can never diverge; `--full` carries over).
//!
//!     cargo bench --bench e2e_table4 [-- --full] [-- --artifact BENCH_e2e.json]

use flexv::isa::IsaVariant;
use flexv::models::{mobilenet_v1, resnet20, Profile};
use flexv::report::workloads::e2e_macs_per_cycle;
use std::time::Instant;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let hw = if full { 224 } else { 96 };
    println!("Table IV regeneration (MNV1 input {hw}x{hw}; paper Flex-V: 6.0 / 5.8 / 11.2)");
    let nets = vec![
        ("MNV1(8b)", mobilenet_v1(Profile::Uniform8, 0.75, hw, 11)),
        ("MNV1(8b4b)", mobilenet_v1(Profile::Mixed8a4w, 0.75, hw, 11)),
        ("ResNet20(4b2b)", resnet20(Profile::Mixed4a2w, 12)),
    ];
    println!("{:<16} {:>10} {:>10} {:>10} {:>10} {:>9}", "network", "RI5CY", "MPIC", "XpulpNN", "Flex-V", "wall[s]");
    for (name, net) in &nets {
        let t0 = Instant::now();
        let vals: Vec<f64> = IsaVariant::ALL
            .iter()
            .map(|&isa| e2e_macs_per_cycle(isa, net))
            .collect();
        println!(
            "{:<16} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>9.1}",
            name, vals[0], vals[1], vals[2], vals[3],
            t0.elapsed().as_secs_f64()
        );
    }
    println!("(paper rows: XpulpV2 5.6/3.2/4.8, XpulpNN 6.0/2.7/4.4, Flex-V 6.0/5.8/11.2,");
    println!(" STM32H7 0.33/0.30/-; see EXPERIMENTS.md for the deviation discussion)");
    flexv::report::bench::write_artifact_from_args(
        "e2e",
        &flexv::report::bench::BenchOptions { full, ..Default::default() },
    );
}
