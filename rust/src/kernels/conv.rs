//! Full convolution kernels: im2col + MatMul + requantization, parallelized
//! over output pixels across the cluster (the workload of Fig. 7).
//!
//! Each core owns a contiguous range of output pixels. Per block of up to
//! `unroll.buffers` pixels it (1) builds the im2col buffers in its private
//! TCDM scratch region, then (2) runs the MatMul phase over all filter
//! blocks — reusing exactly the per-ISA inner loops of [`super::matmul`].
//! The quantization phase is fused into the MatMul blocks (§II-B).

use super::im2col::{emit_im2col_pixel, emit_zero, ConvGeom};
use super::matmul::{emit_matmul, row_range, MatMulTask};
use super::requant::RequantCfg;
use crate::isa::{Instr, IsaVariant, Program, SimdFmt};
use crate::qnn::Precision;

/// A convolution work item in TCDM.
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct ConvTask {
    pub geom: ConvGeom,
    pub prec: Precision,
    /// Input activations (HWC, packed) base address.
    pub in_base: u32,
    /// Weights `[cout, k]` rows, `w_pitch` bytes apart, zero-padded.
    pub w_base: u32,
    pub w_pitch: u32,
    /// Output (HWC, packed at `quant.out_bits`).
    pub out_base: u32,
    /// Per-core im2col scratch: core i uses
    /// `scratch_base + i * buffers * buf_pitch`.
    pub scratch_base: u32,
    pub quant: RequantCfg,
}

impl ConvTask {
    /// Buffer element width: activations are expanded to 8 bit when the
    /// ISA cannot consume the packed format (see [`super::im2col`]).
    pub fn buf_bits(&self, isa: IsaVariant) -> u8 {
        let native_a = isa
            .native_fmts()
            .contains(&SimdFmt::from_bits(self.prec.a_bits));
        if native_a {
            self.prec.a_bits
        } else {
            8
        }
    }

    /// im2col buffer pitch in bytes (word-aligned).
    pub fn buf_pitch(&self, isa: IsaVariant) -> u32 {
        let bits = self.buf_bits(isa) as usize;
        ((self.geom.k() * bits).div_ceil(32) * 4) as u32
    }

    /// Effective precision seen by the MatMul phase.
    pub fn mm_prec(&self, isa: IsaVariant) -> Precision {
        Precision::new(self.buf_bits(isa), self.prec.w_bits)
    }

    /// Total MACs (the paper's metric for Fig. 7).
    pub fn macs(&self) -> u64 {
        (self.geom.out_h() * self.geom.out_w() * self.geom.cout * self.geom.k()) as u64
    }

    /// Output byte address of pixel index `pix`, channel 0.
    pub fn out_pitch(&self) -> u32 {
        (self.geom.cout * self.quant.out_bits as usize / 8) as u32
    }
}

/// Generate the per-core convolution program.
pub fn gen_conv(isa: IsaVariant, t: &ConvTask, core: usize, n_cores: usize) -> Program {
    let g = &t.geom;
    assert!(g.cout % 4 == 0, "cout must be padded to a multiple of 4");
    let m = g.out_h() * g.out_w();
    let (lo, hi) = row_range(m, core, n_cores);
    let mut p = Program::new(format!("conv-{}-{}-c{core}", isa.name(), t.prec));
    if lo >= hi {
        p.push(Instr::Barrier);
        p.push(Instr::Halt);
        return p;
    }
    let nb_max = isa.unroll().buffers;
    let buf_pitch = t.buf_pitch(isa);
    let my_scratch = t.scratch_base + (core * nb_max) as u32 * buf_pitch;
    let mm_prec = t.mm_prec(isa);

    // Pointwise fast path: a 1x1/s1 convolution needs no im2col at all --
    // the input rows *are* the GEMM rows (PULP-NN does the same). Only
    // valid when the packed input row is word-aligned and the format is
    // directly consumable.
    let row_bytes = g.cin * g.a_bits as usize / 8;
    if g.kh == 1
        && g.kw == 1
        && g.stride == 1
        && g.pad_t + g.pad_b + g.pad_l + g.pad_r == 0
        && t.buf_bits(isa) == g.a_bits
        && row_bytes % 4 == 0
    {
        let mm = MatMulTask {
            m,
            n: g.cout,
            k: g.cin,
            prec: t.prec,
            a_base: t.in_base,
            a_pitch: row_bytes as u32,
            w_base: t.w_base,
            w_pitch: t.w_pitch,
            out_base: t.out_base,
            out_pitch: t.out_pitch(),
            quant: t.quant,
        };
        emit_matmul(&mut p, isa, &mm, lo, hi);
        p.push(Instr::Barrier);
        p.push(Instr::Halt);
        return p;
    }

    // Zero the scratch tails once (k*bits .. pitch stays zero forever).
    let used = g.k() * t.buf_bits(isa) as usize / 8;
    for b in 0..nb_max {
        let row = my_scratch + b as u32 * buf_pitch;
        emit_zero(&mut p, row + used as u32, buf_pitch as usize - used);
    }

    let mut pix = lo;
    while pix < hi {
        let nb = nb_max.min(hi - pix);
        let nb = if nb >= nb_max { nb_max } else if nb >= 2 { 2 } else { 1 };
        // Phase 1: im2col the nb pixels into the scratch rows.
        for b in 0..nb {
            let (oy, ox) = ((pix + b) / g.out_w(), (pix + b) % g.out_w());
            emit_im2col_pixel(
                &mut p,
                g,
                t.in_base,
                my_scratch + b as u32 * buf_pitch,
                oy,
                ox,
                t.buf_bits(isa),
            );
        }
        // Phase 2+3: MatMul + requant over all filter blocks.
        let mm = MatMulTask {
            m: nb,
            n: g.cout,
            k: g.k(),
            prec: mm_prec,
            a_base: my_scratch,
            a_pitch: buf_pitch,
            w_base: t.w_base,
            w_pitch: t.w_pitch,
            out_base: t.out_base + pix as u32 * t.out_pitch(),
            out_pitch: t.out_pitch(),
            quant: t.quant,
        };
        emit_matmul(&mut p, isa, &mm, 0, nb);
        pix += nb;
    }
    p.push(Instr::Barrier);
    p.push(Instr::Halt);
    p
}

/// TCDM bytes required for the per-core scratch regions of `n_cores`.
pub fn scratch_bytes(t: &ConvTask, isa: IsaVariant, n_cores: usize) -> usize {
    (n_cores * isa.unroll().buffers) * t.buf_pitch(isa) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::{golden, QTensor, QuantParams};
    use crate::sim::{Cluster, TCDM_BASE};
    use crate::util::Prng;

    /// End-to-end conv check against the golden executor for every ISA.
    fn check_conv(isa: IsaVariant, prec: Precision, geom: ConvGeom, seed: u64) {
        let mut rng = Prng::new(seed);
        let g = geom;
        let k = g.k();
        let x = QTensor::random(&[g.h, g.w, g.cin], prec.a_bits, false, &mut rng);
        // Weight rows padded to the pitch every ISA can over-read safely.
        let words_needed = (k * 8usize).div_ceil(32).max((k * prec.w_bits as usize).div_ceil(32));
        let w_pitch = (words_needed * 4) as u32;
        let kw_pad = w_pitch as usize * 8 / prec.w_bits as usize;
        let mut w = QTensor::random(&[g.cout, kw_pad], prec.w_bits, true, &mut rng);
        // zero the pad tail so every unpack path sees zeros
        for f in 0..g.cout {
            for kk in k..kw_pad {
                w.set_i(f * kw_pad + kk, 0);
            }
        }
        let out_bits = 8u8;
        let q = QuantParams {
            mult: (0..g.cout).map(|_| rng.range_i64(1, 6) as i32).collect(),
            shift: 7,
            bias: (0..g.cout).map(|_| rng.range_i64(-128, 128) as i32).collect(),
            out_bits,
        };

        let in_base = TCDM_BASE;
        let w_base = in_base + x.bytes() as u32 + 64;
        let mult_base = w_base + (g.cout as u32) * w_pitch;
        let bias_base = mult_base + 4 * g.cout as u32;
        let out_base = bias_base + 4 * g.cout as u32;
        let m = g.out_h() * g.out_w();
        let scratch_base = out_base + (m * g.cout * out_bits as usize / 8) as u32 + 64;

        let task = ConvTask {
            geom: g,
            prec,
            in_base,
            w_base,
            w_pitch,
            out_base,
            scratch_base,
            quant: RequantCfg { mult_base, bias_base, shift: q.shift, out_bits },
        };
        let n_cores = 4;
        let mut cl = Cluster::new(n_cores);
        cl.mem.write_bytes(in_base, &x.data);
        cl.mem.write_bytes(w_base, &w.data);
        for ch in 0..g.cout {
            cl.mem.store_u32(mult_base + 4 * ch as u32, q.mult[ch] as u32);
            cl.mem.store_u32(bias_base + 4 * ch as u32, q.bias[ch] as u32);
        }
        cl.load_programs((0..n_cores).map(|c| gen_conv(isa, &task, c, n_cores)).collect());
        let stats = cl.run();
        assert!(stats.total_macs() >= task.macs());

        // Golden conv2d expects weights [cout, kh, kw, cin] — rebuild from
        // the padded rows.
        let wvals: Vec<i32> = (0..g.cout)
            .flat_map(|f| (0..k).map(move |kk| (f, kk)))
            .map(|(f, kk)| w.get_i(f * kw_pad + kk))
            .collect();
        let wt = QTensor::from_signed(&[g.cout, g.kh, g.kw, g.cin], prec.w_bits, &wvals);
        let want = golden::conv2d(&x, &wt, &q, g.kh, g.kw, g.stride, g.pad_t);
        let got_bytes = cl.mem.read_bytes(out_base, want.bytes());
        assert_eq!(
            got_bytes, want.data,
            "{isa:?} {prec} conv mismatch (geom {g:?})"
        );
    }

    fn small_geom(cin: usize, cout: usize, a_bits: u8) -> ConvGeom {
        ConvGeom::square(5, 5, cin, cout, 3, 3, 1, 1, a_bits)
    }

    #[test]
    fn flexv_conv_all_precisions() {
        for prec in Precision::grid() {
            let cin = (32 / prec.a_bits as usize).max(4);
            check_conv(IsaVariant::FlexV, prec, small_geom(cin, 8, prec.a_bits), 21);
        }
    }

    #[test]
    fn all_isas_conv_a8w4() {
        let prec = Precision::new(8, 4);
        for isa in IsaVariant::ALL {
            check_conv(isa, prec, small_geom(4, 4, 8), 22);
        }
    }

    #[test]
    fn all_isas_conv_a4w4_subbyte_activations() {
        let prec = Precision::new(4, 4);
        for isa in IsaVariant::ALL {
            check_conv(isa, prec, small_geom(8, 4, 4), 23);
        }
    }

    #[test]
    fn strided_conv_and_no_padding() {
        let g = ConvGeom::square(8, 8, 4, 4, 2, 2, 2, 0, 8);
        check_conv(IsaVariant::FlexV, Precision::new(8, 8), g, 24);
        check_conv(IsaVariant::Ri5cy, Precision::new(8, 8), g, 25);
    }

    #[test]
    fn pointwise_conv_1x1() {
        let g = ConvGeom::square(4, 4, 16, 8, 1, 1, 1, 0, 8);
        check_conv(IsaVariant::FlexV, Precision::new(8, 4), g, 26);
        check_conv(IsaVariant::XpulpNn, Precision::new(8, 8), g, 27);
    }
}
