//! The trace layer's own determinism contract: the exported Chrome
//! trace JSON is a pure function of the workload, byte-identical across
//! `workers` counts and fast-path settings — because every timestamp is
//! the simulated cycle counter and the sim/serve layers only record
//! numbers they already guarantee bit-identical. Plus structural
//! properties: spans are well-nested per track and never overflow.

use flexv::coordinator::Coordinator;
use flexv::dory::deploy::deploy;
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::qnn::layer::Network;
use flexv::qnn::{Layer, QTensor};
use flexv::report::artifact::Json;
use flexv::serve::{AutoscaleConfig, Engine, ServeConfig, SloClass, TraceShape, WorkloadSpec};
use flexv::sim::WindowCache;
use flexv::trace::chrome::to_chrome_json;
use flexv::trace::{check_well_nested, Recorder};
use flexv::util::proptest::{check, Config};
use flexv::util::Prng;

fn tiny(name: &str, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new(name, [10, 10, 8], 8);
    net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

/// A bursty, SLO-classed, autoscaled serve run — the configuration that
/// exercises every trace emitter at once (batches, exec spans, sheds,
/// park/wake instants, occupancy counters) — exported as Chrome JSON.
fn serve_trace_json(workers: usize, fastpath: bool) -> String {
    let mut ac = AutoscaleConfig::range(1, 3);
    // park aggressively so the short trace actually scales down
    ac.idle_cycles_down = 200_000;
    ac.cooldown_cycles = 0;
    let cfg = ServeConfig {
        shards: 3,
        workers,
        fastpath,
        autoscale: Some(ac),
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(cfg);
    eng.register(tiny("tr-a", 61));
    eng.register(tiny("tr-b", 62));
    let mut spec = WorkloadSpec::new(TraceShape::Bursty, 12, 40_000, 2);
    spec.mix = vec![0.6, 0.4];
    spec.seed = 0x7ACE;
    // tight deadlines: the burst must shed something so shed instants
    // appear in the trace
    spec.classes = SloClass::standard_tiers(5_000_000);
    let trace = eng.workload_trace(&spec);
    eng.run_trace(trace);
    to_chrome_json(&eng.build_trace())
}

/// Tentpole guarantee: the exported bytes do not move when the host
/// execution strategy does.
#[test]
fn serve_trace_bytes_are_execution_invariant() {
    let reference = serve_trace_json(1, true);
    assert_eq!(reference, serve_trace_json(4, true), "worker count moved the trace bytes");
    assert_eq!(reference, serve_trace_json(1, false), "fast path moved the trace bytes");
    assert_eq!(reference, serve_trace_json(4, false), "workers x fastpath moved the trace bytes");
    // and the bytes are a loadable Chrome trace with actual content
    let json = Json::parse(&reference).expect("exported trace must be valid JSON");
    let events = json.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "trace exported no events");
    let complete = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    assert!(complete > 0, "no complete (span) events in the serve trace");
}

/// Fast-path replay re-emits the very same sim spans it recorded:
/// window spans are built from the returned `ClusterStats`, which all
/// replay tiers reproduce bit-exactly, and the host-scope
/// record/replay outcome instants are excluded from the default export.
#[test]
fn fastpath_replay_reemits_identical_sim_spans() {
    let net = tiny("fp", 63);
    let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
    let input = QTensor::random(&[10, 10, 8], 8, false, &mut Prng::new(7));
    let run = |cache: Option<WindowCache>| -> String {
        let mut coord = Coordinator::new(4);
        coord.memoize_tiles = false;
        if let Some(c) = cache {
            coord.cluster.enable_fastpath_shared(c);
        }
        coord.cluster.tracer = Some(Box::default());
        coord.run(&dep, &input);
        let mut rec = *coord.cluster.tracer.take().expect("tracer still attached");
        rec.canonicalize();
        to_chrome_json(&rec)
    };
    let slow = run(None);
    let cache = WindowCache::default();
    let recorded = run(Some(cache.clone()));
    assert!(cache.entries() > 0, "first fast-path run memoized nothing");
    let replayed = run(Some(cache));
    assert_eq!(slow, recorded, "recording pass diverged from the slow path");
    assert_eq!(slow, replayed, "replay pass diverged from the slow path");
}

/// Every track of a serve trace is a proper call stack: spans nest,
/// ends never precede begins.
#[test]
fn serve_trace_spans_are_well_nested() {
    let mut eng = Engine::new(ServeConfig { shards: 2, ..ServeConfig::default() });
    let a = eng.register(tiny("nest-a", 64));
    let b = eng.register(tiny("nest-b", 65));
    let trace = eng.synthetic_trace(10, 30_000, &[0.5, 0.5], 0x4E57);
    eng.run_trace(trace);
    let rec = eng.build_trace();
    assert!(a != b && !rec.is_empty());
    check_well_nested(rec.events()).expect("serve trace must be well-nested");
}

/// Property: for random single-conv networks, the sim-layer trace is
/// well-nested, overflow-free, and its canonical form is stable (a
/// second canonicalize changes nothing).
#[test]
fn sim_traces_are_well_nested_for_random_layers() {
    check(
        Config { cases: 5, base_seed: 0x7E57 },
        |rng| {
            let seed = rng.range(1, 1 << 20) as u64;
            let cout = [8usize, 16][rng.range(0, 2)];
            let wbits = [2u8, 4, 8][rng.range(0, 3)];
            (seed, cout, wbits)
        },
        |&(seed, cout, wbits)| {
            let mut rng = Prng::new(seed);
            let mut net = Network::new("prop", [8, 8, 8], 8);
            net.push(Layer::conv("p1", [8, 8, 8], cout, 3, 3, 1, 1, 8, wbits, 8, &mut rng));
            let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
            let mut coord = Coordinator::new(4);
            coord.memoize_tiles = false;
            coord.cluster.tracer = Some(Box::default());
            let input = QTensor::random(&[8, 8, 8], 8, false, &mut rng);
            coord.run(&dep, &input);
            let mut rec: Recorder = *coord.cluster.tracer.take().expect("tracer attached");
            rec.canonicalize();
            if rec.is_empty() {
                return Err("traced run recorded no events".into());
            }
            check_well_nested(rec.events())?;
            let once = to_chrome_json(&rec);
            rec.canonicalize();
            if once != to_chrome_json(&rec) {
                return Err("canonicalize is not idempotent".into());
            }
            Ok(())
        },
    );
}
