//! Serve-path determinism: a request served through the fleet (queue →
//! batcher → shard pool) must return **bit-identical outputs** and
//! **identical per-layer cycle counts** to a direct `Coordinator` run of
//! the same model on the same input — the serving layer adds scheduling,
//! never perturbation.

use flexv::coordinator::Coordinator;
use flexv::dory::deploy::deploy;
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::models::{resnet20, Profile};
use flexv::qnn::layer::Network;
use flexv::qnn::{Layer, QTensor};
use flexv::serve::{Completion, Engine, ServeConfig, TraceItem};
use flexv::util::Prng;

fn tiny(seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new("tiny-serve", [10, 10, 8], 8);
    net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

/// Direct one-shot reference: fresh coordinator, full functional sim.
fn direct(net: &Network, input: &QTensor) -> (Vec<u8>, Vec<u64>, u64, u64) {
    let dep = deploy(net, IsaVariant::FlexV, MemBudget::default());
    let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
    let res = coord.run(&dep, input);
    (res.output.clone(), res.layer_cycles(), res.total_cycles(), res.total_macs())
}

fn assert_matches(net: &Network, input: &QTensor, comp: &Completion) {
    let (output, layer_cycles, total_cycles, macs) = direct(net, input);
    assert_eq!(comp.output, output, "serve output != coordinator output ({})", net.name);
    assert_eq!(
        comp.layer_cycles, layer_cycles,
        "per-layer cycle counts differ ({})",
        net.name
    );
    assert_eq!(comp.exec_cycles, total_cycles);
    assert_eq!(comp.macs, macs);
}

#[test]
fn serve_path_matches_coordinator_bit_exactly() {
    let cfg = ServeConfig { shards: 4, exact: true, ..ServeConfig::default() };
    let mut eng = Engine::new(cfg);
    let tiny_id = eng.register(tiny(21));
    let resnet_id = eng.register(resnet20(Profile::Mixed4a2w, 5));

    let mut rng = Prng::new(22);
    let tiny_inputs: Vec<QTensor> =
        (0..3).map(|_| QTensor::random(&[10, 10, 8], 8, false, &mut rng)).collect();
    let resnet_input = QTensor::random(&[32, 32, 4], 8, false, &mut rng);

    // Interleaved arrivals, mixed priorities, repeated models — ids are
    // assigned in arrival order (0..4).
    let trace = vec![
        TraceItem {
            at: 0,
            model: tiny_id,
            class: 0,
            priority: 0,
            deadline: None,
            input: tiny_inputs[0].clone(),
        },
        TraceItem {
            at: 10,
            model: resnet_id,
            class: 0,
            priority: 0,
            deadline: None,
            input: resnet_input.clone(),
        },
        TraceItem {
            at: 20,
            model: tiny_id,
            class: 0,
            priority: 1,
            deadline: None,
            input: tiny_inputs[1].clone(),
        },
        TraceItem {
            at: 30,
            model: tiny_id,
            class: 0,
            priority: 0,
            deadline: None,
            input: tiny_inputs[2].clone(),
        },
    ];
    let m = eng.run_trace(trace);
    assert_eq!(m.served, 4);
    assert_eq!(m.rejected, 0);
    // deploy ran once per model; repeats hit the plan cache
    assert_eq!(m.cache_misses, 2);
    assert!(m.cache_hits > 0, "repeated models must hit the plan cache");

    let comps = eng.completions();
    let by_id = |id: u64| comps.iter().find(|c| c.id == id).expect("completion");
    let tiny_net = tiny(21);
    let resnet_net = resnet20(Profile::Mixed4a2w, 5);
    assert_matches(&tiny_net, &tiny_inputs[0], by_id(0));
    assert_matches(&resnet_net, &resnet_input, by_id(1));
    assert_matches(&tiny_net, &tiny_inputs[1], by_id(2));
    assert_matches(&tiny_net, &tiny_inputs[2], by_id(3));

    // Serving is also self-deterministic: replaying the identical trace
    // on a fresh fleet reproduces every completion exactly.
    let mut eng2 = Engine::new(cfg);
    assert_eq!(eng2.register(tiny(21)), tiny_id);
    assert_eq!(eng2.register(resnet20(Profile::Mixed4a2w, 5)), resnet_id);
    let trace2 = vec![
        TraceItem {
            at: 0,
            model: tiny_id,
            class: 0,
            priority: 0,
            deadline: None,
            input: tiny_inputs[0].clone(),
        },
        TraceItem {
            at: 10,
            model: resnet_id,
            class: 0,
            priority: 0,
            deadline: None,
            input: resnet_input.clone(),
        },
        TraceItem {
            at: 20,
            model: tiny_id,
            class: 0,
            priority: 1,
            deadline: None,
            input: tiny_inputs[1].clone(),
        },
        TraceItem {
            at: 30,
            model: tiny_id,
            class: 0,
            priority: 0,
            deadline: None,
            input: tiny_inputs[2].clone(),
        },
    ];
    eng2.run_trace(trace2);
    for (a, b) in eng.completions().iter().zip(eng2.completions()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output, b.output);
        assert_eq!(a.finish_cycle, b.finish_cycle);
        assert_eq!(a.layer_cycles, b.layer_cycles);
    }
}
