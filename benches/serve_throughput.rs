//! Bench: serving-engine throughput — the synthetic mixed 3-model
//! traffic trace (MobileNetV1-8b / 8b4b / ResNet-20-4b2b) replayed on
//! fleets of growing size. Scaling shards should raise req/s and cut
//! p99 latency while plan compiles stay at 3 per row (cache).
//!
//! The engine runs with its defaults: shard batches simulate on a host
//! thread pool and the sim fast path replays steady-state windows. Pass
//! `--baseline` to also run each row sequentially with the fast path
//! off; the simulated numbers must match bit-for-bit (asserted) and the
//! wall-clock ratio is reported (target: ≥ 5x combined).
//!
//!     cargo bench --bench serve_throughput [-- --full] [-- --baseline]

use flexv::serve::{standard_mix, Engine, FleetMetrics, ServeConfig};
use std::time::Instant;

fn run_row(shards: usize, workers: usize, fastpath: bool, hw: usize, requests: usize) -> (FleetMetrics, f64) {
    let cfg = ServeConfig { shards, workers, fastpath, ..ServeConfig::default() };
    let mut eng = Engine::new(cfg);
    for net in standard_mix(hw) {
        eng.register(net);
    }
    let trace = eng.synthetic_trace(requests, 1_500_000, &[0.45, 0.30, 0.25], 0xBE7C);
    let t0 = Instant::now();
    let m = eng.run_trace(trace);
    (m, t0.elapsed().as_secs_f64())
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let baseline = std::env::args().any(|a| a == "--baseline");
    let hw = if full { 224 } else { 96 };
    let requests = 24;
    println!("serve throughput: {requests} requests/row, MNV1 input {hw}x{hw}, mix 45/30/25%");
    println!(
        "{:<7} {:>8} {:>9} {:>9} {:>9} {:>7} {:>9} {:>9} {:>8}{}",
        "shards", "req/s", "p50[ms]", "p99[ms]", "MAC/cyc", "util%", "hit-rate", "switches", "wall[s]",
        if baseline { "  base[s] speedup" } else { "" }
    );
    for shards in [2usize, 4, 8] {
        let (m, wall) = run_row(shards, 0, true, hw, requests);
        let tail = if baseline {
            let (mb, wall_b) = run_row(shards, 1, false, hw, requests);
            // parallel + fast path must not move a single simulated number
            assert_eq!(m.span_cycles, mb.span_cycles, "span diverged at {shards} shards");
            assert_eq!(m.p50_cycles, mb.p50_cycles, "p50 diverged at {shards} shards");
            assert_eq!(m.p99_cycles, mb.p99_cycles, "p99 diverged at {shards} shards");
            assert_eq!(m.model_switches, mb.model_switches);
            format!(" {:>8.1} {:>7.1}x", wall_b, wall_b / wall.max(1e-9))
        } else {
            String::new()
        };
        println!(
            "{:<7} {:>8.1} {:>9.2} {:>9.2} {:>9.1} {:>7.0} {:>8.0}% {:>9} {:>8.1}{}",
            shards,
            m.requests_per_sec,
            m.p50_cycles as f64 / 250e3,
            m.p99_cycles as f64 / 250e3,
            m.aggregate_macs_per_cycle,
            m.shard_utilization * 100.0,
            m.cache_hit_rate() * 100.0,
            m.model_switches,
            wall,
            tail
        );
        assert!(m.cache_misses <= 3, "at most one deploy per model");
    }
}
