//! Quickstart: run one mixed-precision convolution on the simulated
//! Flex-V cluster and print the paper's metrics.
//!
//!     cargo run --release --example quickstart
//!
//! Builds the Fig. 7 benchmark layer (64 filters of 3x3x32 on a 16x16x32
//! input) at a8w4, executes it on the 8-core cluster, and reports
//! MAC/cycle, utilization, and the energy model's TOPS/W.

use flexv::isa::IsaVariant;
use flexv::power::EnergyModel;
use flexv::qnn::Precision;
use flexv::report::workloads::conv_fig7_stats;

fn main() {
    let isa = IsaVariant::FlexV;
    let prec = Precision::new(8, 4);
    println!("running conv 64x3x3x32 @ 16x16x32, {prec} on {isa} (8 cores)...");
    let stats = conv_fig7_stats(isa, prec);
    let em = EnergyModel::default();
    let peak = 8.0 * prec.macs_per_sdotp() as f64; // MACs/cycle at 1 sdotp/cycle/core
    println!("  cycles:        {}", stats.cycles);
    println!("  instructions:  {}", stats.total_instrs());
    println!("  MACs:          {}", stats.total_macs());
    println!("  MAC/cycle:     {:.1}  (peak {peak:.0}, utilization {:.0}%)",
        stats.macs_per_cycle(), 100.0 * stats.utilization(peak));
    println!("  energy eff.:   {:.2} TOPS/W", em.tops_per_watt(isa, &stats, prec.a_bits.max(prec.w_bits)));
    let conflicts: u64 = stats.cores.iter().map(|c| c.conflict_stalls).sum();
    println!("  TCDM conflicts: {conflicts} stall cycles across 8 cores");
}
