//! Packed quantized tensors in HWC layout.
//!
//! A [`QTensor`] owns a densely packed byte buffer (sub-byte elements packed
//! little-endian, see [`crate::qnn::packing`]) plus shape/precision metadata.
//! The innermost (channel) dimension must be byte-aligned — the same
//! constraint DORY's tiling solver enforces (§IV: "the convolutional loop's
//! innermost dimensions should always be byte-aligned") — so that rows can
//! be DMA-copied and word-loaded without cross-byte straddling.

use super::packing;
use crate::util::Prng;

/// A quantized tensor: packed data + shape + element format.
#[derive(Clone, Debug, PartialEq)]
pub struct QTensor {
    /// Packed storage, little-endian sub-byte packing.
    pub data: Vec<u8>,
    /// Shape, outermost first. Conv activations are `[H, W, C]` (HWC);
    /// conv weights are `[Cout, Kh, Kw, Cin]`; vectors are `[N]`.
    pub shape: Vec<usize>,
    /// Element bit-width: 2, 4 or 8.
    pub bits: u8,
    /// Two's-complement signed elements (weights) vs unsigned (activations).
    pub signed: bool,
}

impl QTensor {
    /// Zero-filled tensor. The total bit count must be byte-aligned (the
    /// stricter *innermost-dimension* byte alignment required for DMA'd
    /// rows is enforced by the DORY tiling solver, §IV).
    pub fn zeros(shape: &[usize], bits: u8, signed: bool) -> Self {
        assert!(super::check_bits(bits), "unsupported bits {bits}");
        let n: usize = shape.iter().product();
        assert!(n * bits as usize % 8 == 0, "{shape:?} x {bits}b not byte-aligned");
        QTensor {
            data: vec![0u8; n * bits as usize / 8],
            shape: shape.to_vec(),
            bits,
            signed,
        }
    }

    /// Random tensor with elements uniform over the full representable range.
    pub fn random(shape: &[usize], bits: u8, signed: bool, rng: &mut Prng) -> Self {
        let mut t = Self::zeros(shape, bits, signed);
        let n = t.len();
        for i in 0..n {
            if signed {
                t.set_i(i, rng.bits_signed(bits));
            } else {
                t.set_u(i, rng.bits_unsigned(bits));
            }
        }
        t
    }

    /// Build from unsigned element values.
    pub fn from_unsigned(shape: &[usize], bits: u8, vals: &[u32]) -> Self {
        let mut t = Self::zeros(shape, bits, false);
        assert_eq!(vals.len(), t.len());
        t.data = packing::pack_unsigned(vals, bits);
        t.data.resize(t.len() * bits as usize / 8, 0);
        t
    }

    /// Build from signed element values.
    pub fn from_signed(shape: &[usize], bits: u8, vals: &[i32]) -> Self {
        let mut t = Self::zeros(shape, bits, true);
        assert_eq!(vals.len(), t.len());
        t.data = packing::pack_signed(vals, bits);
        t.data.resize(t.len() * bits as usize / 8, 0);
        t
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Packed byte footprint (the paper's "model size" metric counts this).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Flat index from multi-dimensional index.
    pub fn flat(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut f = 0usize;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(x < d, "index {x} out of bound {d} at dim {i}");
            f = f * d + x;
        }
        f
    }

    /// Unsigned element at flat index.
    pub fn get_u(&self, i: usize) -> u32 {
        packing::get_unsigned(&self.data, self.bits, i)
    }

    /// Signed element at flat index.
    pub fn get_i(&self, i: usize) -> i32 {
        packing::get_signed(&self.data, self.bits, i)
    }

    /// Element at flat index as i32 regardless of signedness.
    pub fn get(&self, i: usize) -> i32 {
        if self.signed { self.get_i(i) } else { self.get_u(i) as i32 }
    }

    pub fn set_u(&mut self, i: usize, v: u32) {
        packing::set_unsigned(&mut self.data, self.bits, i, v);
    }

    pub fn set_i(&mut self, i: usize, v: i32) {
        let mask = (1u32 << self.bits) - 1;
        packing::set_unsigned(&mut self.data, self.bits, i, (v as u32) & mask);
    }

    /// All elements as i32 (sign- or zero-extended).
    pub fn to_vec_i32(&self) -> Vec<i32> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn zeros_footprint() {
        // 16x16x32 @ 2 bit = 2048 B; @ 8 bit = 8192 B
        assert_eq!(QTensor::zeros(&[16, 16, 32], 2, false).bytes(), 2048);
        assert_eq!(QTensor::zeros(&[16, 16, 32], 8, false).bytes(), 8192);
    }

    #[test]
    #[should_panic(expected = "byte-aligned")]
    fn rejects_unaligned_total_bits() {
        // 3 elements x 2 bits = 6 bits, not byte aligned
        QTensor::zeros(&[1, 3], 2, false);
    }

    #[test]
    fn subbyte_trailing_dims_allowed_when_total_aligned() {
        // depthwise weights [C, kh, kw, 1] at 4 bit: total 36*4 bits OK
        let t = QTensor::zeros(&[4, 3, 3, 1], 4, true);
        assert_eq!(t.bytes(), 18);
    }

    #[test]
    fn flat_index_hwc() {
        let t = QTensor::zeros(&[2, 3, 4], 8, false);
        assert_eq!(t.flat(&[0, 0, 0]), 0);
        assert_eq!(t.flat(&[0, 0, 3]), 3);
        assert_eq!(t.flat(&[0, 1, 0]), 4);
        assert_eq!(t.flat(&[1, 0, 0]), 12);
    }

    #[test]
    fn from_signed_roundtrip() {
        let vals: Vec<i32> = vec![-2, -1, 0, 1, -2, 1, 0, -1];
        let t = QTensor::from_signed(&[2, 4], 2, &vals);
        assert_eq!(t.to_vec_i32(), vals);
    }

    #[test]
    fn prop_random_in_range() {
        proptest::check_default(
            |rng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let c = rng.range(1, 5) * (8 / bits as usize).max(1);
                let t = QTensor::random(&[rng.range(1, 6), c], bits, rng.chance(0.5), rng);
                t
            },
            |t| {
                for i in 0..t.len() {
                    let v = t.get(i);
                    let (lo, hi) = if t.signed {
                        (-(1i32 << (t.bits - 1)), (1i32 << (t.bits - 1)) - 1)
                    } else {
                        (0, (1i32 << t.bits) - 1)
                    };
                    if v < lo || v > hi {
                        return Err(format!("elem {i}={v} outside [{lo},{hi}]"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_set_get_roundtrip() {
        proptest::check_default(
            |rng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let n = rng.range(1, 30) * (8 / bits as usize);
                let idx = rng.range(0, n);
                let v = rng.bits_signed(bits);
                (bits, n, idx, v)
            },
            |&(bits, n, idx, v)| {
                let mut t = QTensor::zeros(&[n], bits, true);
                t.set_i(idx, v);
                if t.get_i(idx) == v { Ok(()) } else { Err(format!("got {}", t.get_i(idx))) }
            },
        );
    }
}
