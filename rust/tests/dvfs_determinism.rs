//! The energy-aware serving guarantee: under a fleet power cap, an SLO
//! DVFS governor, and an active fault plan including a thermal-throttle
//! window, the entire federated fingerprint — per-region completion
//! streams (operating points and energy included), the DVFS transition
//! logs, the rendered report, and the exported Chrome-trace JSON bytes
//! — is identical across host worker counts {1, 4} × sim fast-path
//! on/off, for every router policy. Every operating-point and
//! power-cap decision happens in the sequential batch-formation half
//! from simulated state only, so host parallelism can never move a
//! joule.

use flexv::power::{operating_points, DvfsPolicy, EnergyModel, OP_EFFICIENCY};
use flexv::qnn::layer::Network;
use flexv::qnn::{Layer, QTensor};
use flexv::serve::{
    FaultPlan, Federation, FederationConfig, FederationMetrics, RouterPolicy, ServeConfig,
    TraceItem,
};
use flexv::util::Prng;

fn tiny(name: &str, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new(name, [8, 8, 8], 8);
    net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [8, 8, 8], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

fn item(at: u64, model: usize, rng: &mut Prng) -> TraceItem {
    TraceItem {
        at,
        model,
        class: 0,
        priority: (at % 3) as u8,
        deadline: None,
        input: QTensor::random(&[8, 8, 8], 8, false, rng),
    }
}

/// Bursty arrivals: tight intra-burst gaps with long valleys, so the
/// cap has to arbitrate between simultaneously-free shards.
fn bursty_trace(models: usize, n: usize, seed: u64) -> Vec<TraceItem> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let at = (i as u64 / 4) * 50_000 + (i as u64 % 4) * 40;
            item(at, i % models, &mut rng)
        })
        .collect()
}

/// Everything simulated, flattened to one string: per-region completion
/// tuples (operating point and energy included), the DVFS transition
/// logs, shed events, the rendered report, and the exported trace
/// bytes.
fn fingerprint(fed: &Federation, m: &FederationMetrics) -> String {
    let mut fp = String::new();
    for (r, engine) in fed.regions().iter().enumerate() {
        fp.push_str(&format!("region {r}\n"));
        for c in engine.completions() {
            fp.push_str(&format!(
                "  c id={} model={} shard={} start={} finish={} exec={} switch={} batch={} \
                 macs={} op={} energy={:?} out={:?}\n",
                c.id,
                c.model,
                c.shard,
                c.start_cycle,
                c.finish_cycle,
                c.exec_cycles,
                c.switch_cycles,
                c.batch_size,
                c.macs,
                c.op,
                c.energy_pj,
                c.output,
            ));
        }
        for t in engine.dvfs_log() {
            fp.push_str(&format!("  dvfs {t:?}\n"));
        }
        for s in engine.shed_events() {
            fp.push_str(&format!("  shed {s:?}\n"));
        }
    }
    fp.push_str(&m.render());
    fp.push_str(&flexv::trace::chrome::to_chrome_json(&fed.build_trace()));
    fp
}

/// Run the power-capped scenario with the given execution knobs; every
/// simulated input (cap, governor, fault plan, trace) is fixed. The
/// per-region cap funds 1.5 shards at the efficiency floor, so capped
/// rounds must defer or downgrade batches.
fn run_capped(
    workers: usize,
    fastpath: bool,
    policy: RouterPolicy,
) -> (String, FederationMetrics) {
    let mut engine = ServeConfig {
        shards: 2,
        n_cores: 4,
        queue_capacity: 64,
        max_batch: 4,
        workers,
        fastpath,
        dvfs: DvfsPolicy::Slo,
        ..ServeConfig::default()
    };
    let floor_mw = EnergyModel::default().busy_power_bound_mw(
        engine.isa,
        engine.n_cores,
        &operating_points(engine.isa)[OP_EFFICIENCY],
    );
    engine.power_cap_mw = Some(1.5 * floor_mw);
    // one pinned thermal-throttle window plus two seeded faults
    let faults = FaultPlan::parse("throttle@1500:r0.s1+60000,auto:2", 0xD7F5, 2, 2, 300_000)
        .expect("static fault spec parses");
    let cfg = FederationConfig { regions: 2, engine, policy, faults, rollout: None };
    let mut fed = Federation::new(cfg);
    fed.register(tiny("cap-a", 21));
    fed.register(tiny("cap-b", 22));
    let m = fed.run_trace(bursty_trace(2, 20, 23));
    assert_eq!(m.total_served(), 20, "the cap must delay work, never drop it");
    (fingerprint(&fed, &m), m)
}

#[test]
fn capped_fingerprint_is_identical_across_workers_and_fastpath() {
    for policy in RouterPolicy::ALL {
        let (reference, _) = run_capped(1, false, policy);
        for (workers, fastpath) in [(1usize, true), (4, false), (4, true)] {
            let (fp, _) = run_capped(workers, fastpath, policy);
            assert!(
                fp == reference,
                "capped fingerprint diverged (policy {}, workers {workers}, fastpath {fastpath})",
                policy.name(),
            );
        }
    }
}

#[test]
fn capped_run_respects_the_cap_and_reports_energy() {
    let (_, m) = run_capped(0, true, RouterPolicy::LeastLoaded);
    let fleet_cap = m.power_cap_mw().expect("cap is configured");
    assert!(
        m.fleet_avg_power_mw() <= fleet_cap,
        "fleet avg {} mW exceeds cap {} mW",
        m.fleet_avg_power_mw(),
        fleet_cap,
    );
    for (r, region) in m.regions.iter().enumerate() {
        let cap = region.power_cap_mw.expect("per-region cap is configured");
        assert!(
            region.fleet_avg_power_mw <= cap,
            "region {r} avg {} mW exceeds its cap {} mW",
            region.fleet_avg_power_mw,
            cap,
        );
    }
    assert!(m.total_energy_pj() > 0.0 && m.fleet_tops_per_watt() > 0.0);
    assert!(m.dvfs_transitions() >= 1, "the SLO governor must move between tiers");
    assert!(m.render().contains("fleet avg power"), "{}", m.render());
}
