//! Register-file allocation convention shared by all kernel generators.
//!
//! RI5CY's GP-RF has 32 registers; the paper's point is precisely that the
//! "4×2" blocking of PULP-NN saturates it, while Flex-V's NN-RF frees
//! enough registers for "4×4" (§III). The map below mirrors the PULP-NN
//! allocation with the accumulators front and center.

use crate::isa::Reg;

/// Accumulators: x1..x16 (up to 16 for the Flex-V 4×4 block).
pub fn acc(i: usize) -> Reg {
    debug_assert!(i < 16);
    (1 + i) as Reg
}

/// Activation words for non-Mac&Load kernels (two im2col buffers).
pub const A_REG: [Reg; 2] = [17, 18];
/// Packed weight words (four filters).
pub const W_REG: [Reg; 4] = [19, 20, 21, 22];
/// im2col buffer pointers.
pub const A_PTR: [Reg; 2] = [23, 24];
/// Weight pointer.
pub const W_PTR: Reg = 25;
/// Scratch temporaries (software unpack, requant).
pub const TMP: [Reg; 4] = [26, 27, 28, 29];
/// Requant: per-filter multipliers live in W_REG, biases in TMP after the
/// K-loop retires; these two extra pointers address quant arrays / output.
pub const OUT_PTR: Reg = 30;
pub const Q_PTR: Reg = 31;
