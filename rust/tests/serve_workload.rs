//! Workload-engine integration: the adversarial traffic scenarios
//! (bursty arrivals, SLO classes, load shedding, elastic scaling) obey
//! the same determinism contract as the plain fleet — every simulated
//! number, including deadline-miss counts, shed events, and the
//! shard-occupancy timeline, is bit-identical for any worker count and
//! fast-path setting. Plus the randomized fast-path soak: a seeded
//! bursty trace with crosscheck mode on (every replayed simulation
//! window is re-simulated and compared; any divergence panics).

use flexv::qnn::layer::Network;
use flexv::qnn::Layer;
use flexv::serve::{
    AutoscaleConfig, Engine, ServeConfig, SloClass, TraceShape, WorkloadSpec,
};
use flexv::util::Prng;

fn tiny(name: &str, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new(name, [8, 8, 8], 8);
    net.push(Layer::conv("c1", [8, 8, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [8, 8, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

/// The standard adversarial scenario: a bursty two-model SLO trace on
/// an autoscaled fleet (1..=3 shards, fast park/cooldown so both scale
/// directions fire within the trace).
fn bursty_spec() -> WorkloadSpec {
    WorkloadSpec {
        shape: TraceShape::Bursty,
        requests: 18,
        mean_gap: 30_000,
        mix: vec![0.6, 0.4],
        classes: SloClass::standard_tiers(250_000),
        burst_len: 6,
        seed: 0xB0B5,
    }
}

fn autoscale_cfg() -> AutoscaleConfig {
    let mut ac = AutoscaleConfig::range(1, 3);
    ac.idle_cycles_down = 120_000;
    ac.cooldown_cycles = 30_000;
    ac
}

/// Everything a run reports, flattened for equality comparison.
#[derive(PartialEq, Debug)]
struct Fingerprint {
    completions: Vec<(u64, usize, u8, usize, u64, u64, u64, u64, usize, Vec<u64>, Vec<u8>)>,
    shed: Vec<(u64, u8, u64, u64)>,
    occupancy: Vec<(u64, usize)>,
    served: usize,
    misses: u64,
    shed_count: u64,
    ups: u64,
    downs: u64,
    span: u64,
    p99: u64,
    class_p99: Vec<u64>,
    class_viol: Vec<(usize, usize)>,
}

fn run(workers: usize, fastpath: bool, crosscheck: bool) -> Fingerprint {
    run_cfg(workers, fastpath, crosscheck, false)
}

fn run_cfg(workers: usize, fastpath: bool, crosscheck: bool, tuned: bool) -> Fingerprint {
    let cfg = ServeConfig {
        shards: 3,
        n_cores: 4,
        workers,
        fastpath,
        crosscheck,
        tuned,
        autoscale: Some(autoscale_cfg()),
        ..ServeConfig::default()
    };
    let mut eng = Engine::new(cfg);
    eng.register(tiny("wl-a", 51));
    eng.register(tiny("wl-b", 52));
    let trace = eng.workload_trace(&bursty_spec());
    let m = eng.run_trace(trace);
    Fingerprint {
        completions: eng
            .completions()
            .iter()
            .map(|c| {
                (
                    c.id,
                    c.model,
                    c.class,
                    c.shard,
                    c.arrival_cycle,
                    c.start_cycle,
                    c.finish_cycle,
                    c.exec_cycles,
                    c.batch_size,
                    c.layer_cycles.clone(),
                    c.output.clone(),
                )
            })
            .collect(),
        shed: eng
            .shed_events()
            .iter()
            .map(|s| (s.id, s.class, s.deadline, s.shed_cycle))
            .collect(),
        occupancy: eng.occupancy().to_vec(),
        served: m.served,
        misses: m.deadline_misses,
        shed_count: m.shed,
        ups: m.scale_ups,
        downs: m.scale_downs,
        span: m.span_cycles,
        p99: m.p99_cycles,
        class_p99: m.class_rows.iter().map(|c| c.p99_cycles).collect(),
        class_viol: m.class_rows.iter().map(|c| (c.missed, c.shed)).collect(),
    }
}

/// Acceptance gate: the autoscaled bursty SLO scenario is bit-identical
/// for workers ∈ {1, 4} and fast path on/off — completions, deadline
/// misses, shed events, and the shard-occupancy timeline included.
#[test]
fn autoscaled_bursty_trace_is_bit_deterministic() {
    let reference = run(1, false, false);
    // the trace must actually exercise the new machinery
    assert!(reference.served > 0, "nothing served");
    assert!(reference.ups > 0, "burst never woke a shard");
    assert!(
        reference.occupancy.iter().any(|&(_, n)| n > 1),
        "occupancy never left the floor: {:?}",
        reference.occupancy
    );
    assert_eq!(reference.occupancy[0], (0, 1), "fleet must start at min");
    assert_eq!(
        reference.served + reference.shed_count as usize,
        18,
        "every request is either served or shed"
    );

    let four_workers = run(4, false, false);
    assert_eq!(reference, four_workers, "worker count changed results");
    let fastpath = run(1, true, false);
    assert_eq!(reference, fastpath, "fast path changed results");
    let both = run(4, true, false);
    assert_eq!(reference, both, "workers + fast path changed results");
}

/// Randomized fast-path soak (satellite): the same bursty trace with
/// crosscheck mode on — every replayed window is re-simulated on a
/// forked cluster and compared, so completing at all means zero
/// crosscheck divergences — and the results still match `--no-fastpath`
/// bit-for-bit.
#[test]
fn fastpath_soak_bursty_crosscheck_zero_divergence() {
    let checked = run(1, true, true);
    let reference = run(1, false, false);
    assert_eq!(checked, reference, "crosschecked fast path diverged from slow path");
}

/// Regression gate for the autotuner (satellite): the same adversarial
/// autoscaled bursty SLO scenario with **tuning enabled** — tuning runs
/// once per model on the engine thread, so the whole event stream
/// (completions, sheds, occupancy) must stay bit-identical across
/// worker counts and fast-path settings, exactly like the untuned
/// fleet.
#[test]
fn tuned_autoscaled_bursty_trace_is_bit_deterministic() {
    let reference = run_cfg(1, false, false, true);
    assert!(reference.served > 0, "nothing served");
    assert_eq!(
        reference.served + reference.shed_count as usize,
        18,
        "every request is either served or shed"
    );
    assert_eq!(reference, run_cfg(4, false, false, true), "worker count changed tuned results");
    assert_eq!(reference, run_cfg(1, true, false, true), "fast path changed tuned results");
    assert_eq!(
        reference,
        run_cfg(4, true, false, true),
        "workers + fast path changed tuned results"
    );
}

/// Fast-path crosscheck soak over **tuned plans**: every replayed
/// window of the tuned deployments is re-simulated and compared on a
/// forked cluster (any divergence panics), and the results still match
/// the tuned no-fastpath run bit-for-bit.
#[test]
fn tuned_fastpath_soak_crosscheck_zero_divergence() {
    let checked = run_cfg(1, true, true, true);
    let reference = run_cfg(1, false, false, true);
    assert_eq!(checked, reference, "crosschecked fast path diverged on tuned plans");
}

/// The workload trace generator and the engine agree end-to-end on SLO
/// semantics: the per-class rows partition every request (served or
/// shed), carry the class table's priorities/deadlines, and render.
#[test]
fn slo_classes_flow_through_to_metrics() {
    let cfg = ServeConfig { shards: 1, n_cores: 4, max_batch: 2, ..ServeConfig::default() };
    let mut eng = Engine::new(cfg);
    eng.register(tiny("slo-a", 53));
    eng.register(tiny("slo-b", 54));
    let mut spec = bursty_spec();
    spec.requests = 12;
    let trace = eng.workload_trace(&spec);
    let m = eng.run_trace(trace);
    assert_eq!(m.class_rows.len(), 3);
    let by_class: usize = m.class_rows.iter().map(|c| c.served + c.shed).sum();
    assert_eq!(by_class, m.served + m.shed as usize, "class rows must partition requests");
    for (row, class) in m.class_rows.iter().zip(&spec.classes) {
        assert_eq!(row.name, class.name);
        assert_eq!(row.priority, class.priority);
        assert_eq!(row.deadline_cycles, class.deadline_cycles);
    }
    // rendering includes the SLO table
    let rendered = m.render();
    assert!(rendered.contains("interactive") && rendered.contains("viol%"), "{rendered}");
}
