//! Minimal property-based testing harness.
//!
//! The offline build environment does not ship the `proptest` crate, so this
//! module provides the subset we rely on: run a property over many randomly
//! generated cases; on failure, re-run a simple shrinking loop (halving
//! integer case parameters) and report the smallest failing case with its
//! seed so it can be replayed deterministically.

use crate::util::Prng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // `PROPTEST_CASES` (the env var the real proptest crate honours)
        // scales every default-config property: per-PR CI keeps the small
        // default, the nightly workflow raises it for extended sweeps.
        let cases = std::env::var("PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or(64);
        Config { cases, base_seed: 0xF1E2_D3C4 }
    }
}

/// Run `prop` over `cfg.cases` generated inputs. `gen` draws one input from
/// the PRNG; `prop` returns `Err(msg)` on violation. Panics (test failure)
/// with the offending seed and message on the first violated case.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Prng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property violated (case {i}, seed {seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Convenience wrapper with the default config.
pub fn check_default<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Prng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(Config::default(), gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default(|rng| rng.range(0, 100), |&x| {
            if x < 100 { Ok(()) } else { Err("out of range".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property violated")]
    fn failing_property_panics_with_seed() {
        check_default(|rng| rng.range(0, 100), |&x| {
            if x < 40 { Ok(()) } else { Err(format!("{x} >= 40")) }
        });
    }
}
