//! Dynamic batching policy.
//!
//! When a shard frees up, the batcher picks a **lead** request from the
//! queue (priority, FIFO, shard-affinity — see
//! [`RequestQueue::pop_lead`]) and coalesces up to `max_batch - 1` more
//! queued requests for the same model behind it. A batch shares one plan
//! lookup and at most one model switch: the L3→L2 weight streaming and
//! the warm tile-timing memo are amortized over every member, exactly the
//! way PULP-NN amortizes im2col/packing setup across kernel invocations.
//!
//! Batch formation always runs on the engine thread, in shard order —
//! it is the scheduling half of the engine's determinism contract (see
//! [`crate::serve`]); only the formed batches execute in parallel.

use super::queue::RequestQueue;
use super::request::Request;

/// Batch formation knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests coalesced into one shard pass (1 = no batching).
    pub max_batch: usize,
    /// Prefer a lead request matching the shard's resident model (within
    /// the top priority level), avoiding a weight switch.
    pub prefer_resident: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, prefer_resident: true }
    }
}

/// Form the next batch for a shard whose resident model is `resident`.
/// Returns `None` when the queue is empty. The returned batch is
/// non-empty and single-model.
pub fn next_batch(
    queue: &mut RequestQueue,
    resident: Option<usize>,
    policy: &BatchPolicy,
) -> Option<Vec<Request>> {
    assert!(policy.max_batch >= 1);
    let lead = queue.pop_lead(if policy.prefer_resident { resident } else { None })?;
    let model = lead.model;
    let mut batch = vec![lead];
    if policy.max_batch > 1 {
        batch.extend(queue.drain_model(model, policy.max_batch - 1));
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QTensor;

    fn req(id: u64, model: usize, priority: u8) -> Request {
        Request {
            id,
            model,
            priority,
            arrival_cycle: id,
            input: QTensor::zeros(&[1, 1, 8], 8, false),
        }
    }

    #[test]
    fn coalesces_same_model_up_to_max() {
        let mut q = RequestQueue::new(16);
        for (id, m) in [(0, 0), (1, 1), (2, 0), (3, 0), (4, 0)] {
            q.push(req(id, m, 0));
        }
        let policy = BatchPolicy { max_batch: 3, prefer_resident: false };
        let batch = next_batch(&mut q, None, &policy).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 3]);
        assert!(batch.iter().all(|r| r.model == 0));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn affinity_keeps_shard_on_resident_model() {
        let mut q = RequestQueue::new(16);
        q.push(req(0, 0, 0));
        q.push(req(1, 1, 0));
        let policy = BatchPolicy { max_batch: 4, prefer_resident: true };
        let batch = next_batch(&mut q, Some(1), &policy).unwrap();
        assert_eq!(batch[0].model, 1);
    }

    #[test]
    fn max_batch_one_disables_coalescing() {
        let mut q = RequestQueue::new(16);
        q.push(req(0, 0, 0));
        q.push(req(1, 0, 0));
        let policy = BatchPolicy { max_batch: 1, prefer_resident: false };
        assert_eq!(next_batch(&mut q, None, &policy).unwrap().len(), 1);
        assert_eq!(q.len(), 1);
    }
}
