//! GF22FDX area / timing / energy model (§V-A, Table II), with DVFS
//! operating points.
//!
//! The paper's silicon numbers are the calibration anchors; our simulator
//! supplies the per-instruction-class activity. The model is deliberately
//! simple and fully documented:
//!
//! - **Area & fmax**: taken directly from Table II for RI5CY and Flex-V;
//!   MPIC and XpulpNN cores are placed between them using the overheads
//!   their own papers report (MPIC ~+11% vs RI5CY, XpulpNN ~+19%).
//! - **Energy**: `E_cycle = E_static + Σ_class E_class · activity_class`,
//!   with per-class energies fitted once so that (a) the 8-bit MatMul
//!   cluster power matches Table II (12.3→12.6 mW at 250 MHz typical) and
//!   (b) the Flex-V efficiency column of Table III is approached at the
//!   paper's efficiency corner. The same class energies are used for all
//!   four cores — variant differences come from their instruction mixes
//!   plus the small leakage deltas of Table II.
//!
//! # Static / dynamic split
//!
//! The two energy components scale differently and are kept separate:
//!
//! - **Dynamic energy** ([`EnergyModel::dynamic_energy_pj`]) is charged
//!   per *event* (issued instruction, dotp, TCDM access, …). Per cycle it
//!   is frequency-independent: running the same window faster spends the
//!   same dynamic energy in less time, so dynamic *power* scales linearly
//!   with frequency (and with `V²` across voltage corners).
//! - **Static (leakage) power** is the Table II per-cluster `leak_mw` —
//!   a property of the powered-on silicon, frequency-**independent**.
//!   As energy it is charged per unit *time* (`cycles × period`), so the
//!   leakage share per cycle grows as the clock slows down.
//!
//! `power_mw(.., f_mhz)` therefore is `P_dyn(f) + P_leak`, and
//! [`EnergyModel::energy_pj`] (the historical single-corner entry point)
//! equals [`EnergyModel::energy_pj_at`] at the nominal operating point.
//!
//! # Operating points
//!
//! [`operating_points`] derives three voltage/frequency pairs per variant
//! from the Table II anchors, in the same spirit as the multi-corner
//! evaluations of the related MPIC and Dustin clusters:
//!
//! - **boost**: 0.80 V at the variant's Table II worst-case `fmax`
//!   (e.g. 463 MHz for Flex-V) — the sign-off corner.
//! - **nominal**: 0.65 V at 250 MHz — the typical corner every historical
//!   number in this repo is quoted at ([`crate::report::F_TYP_MHZ`]).
//! - **efficiency**: 0.50 V at 125 MHz — the low-voltage corner where
//!   TOPS/W peaks.
//!
//! Across corners, dynamic energy scales with `(V/V_nom)²` (CV² switching)
//! and leakage power with `(V/V_nom)³` (DIBL makes leakage superlinear in
//! V), so each point is physically consistent: slower corners always cost
//! less energy per inference, faster corners always finish sooner.
//!
//! The serving fleet keeps its clock in **nominal-period ticks** (4 ns at
//! 250 MHz); [`OperatingPoint::fleet_ticks`] converts a core-cycle count
//! executed at any point into that common timebase with pure integer
//! arithmetic (exact identity at nominal), which is what keeps DVFS
//! decisions deterministic across host worker counts.
//!
//! TOPS/W for a kernel = `2 · MAC/cycle / E_cycle`, frequency-independent
//! apart from the leakage share, evaluated at the efficiency corner.

use crate::isa::IsaVariant;
use crate::sim::ClusterStats;

/// Table II anchors and derived constants for one core variant.
#[derive(Clone, Copy, Debug)]
pub struct VariantPhys {
    /// Max cluster frequency [MHz] (worst-case corner).
    pub fmax_mhz: f64,
    /// Core area [µm²].
    pub core_area_um2: f64,
    /// Cluster area [µm²] (8 cores + memories + interconnect).
    pub cluster_area_um2: f64,
    /// Cluster leakage power [mW].
    pub leak_mw: f64,
}

/// Baseline (RI5CY) cluster area minus its 8 cores = shared logic+SRAM.
const SHARED_AREA_UM2: f64 = 518_227.0 - 8.0 * 13_721.0;

/// Physical constants per variant.
pub fn phys(v: IsaVariant) -> VariantPhys {
    let (fmax, core, leak) = match v {
        // Table II, measured columns.
        IsaVariant::Ri5cy => (472.0, 13_721.0, 0.613),
        IsaVariant::FlexV => (463.0, 17_816.0, 0.710),
        // Interpolated from the MPIC [15] and XpulpNN [14] papers' reported
        // overheads over RI5CY (see DESIGN.md §2).
        IsaVariant::Mpic => (468.0, 15_230.0, 0.650),
        IsaVariant::XpulpNn => (466.0, 16_330.0, 0.680),
    };
    // Flex-V's cluster area is a measured Table II value (547211 µm²,
    // +5.59%); synthesis absorbs part of the core growth at cluster level,
    // so derived variants scale the core delta by the same absorption
    // factor observed between the two measured points.
    let absorption = (547_211.0 - 518_227.0) / (8.0 * (17_816.0 - 13_721.0));
    let cluster = match v {
        IsaVariant::Ri5cy => 518_227.0,
        IsaVariant::FlexV => 547_211.0,
        _ => SHARED_AREA_UM2 + 8.0 * 13_721.0 + 8.0 * (core - 13_721.0) * absorption,
    };
    VariantPhys {
        fmax_mhz: fmax,
        core_area_um2: core,
        cluster_area_um2: cluster,
        leak_mw: leak,
    }
}

/// Clock period of the nominal (typical, 250 MHz) corner [ps] — the
/// fleet's common timebase.
pub const NOMINAL_PERIOD_PS: u64 = 4_000;

/// Supply voltage of the nominal corner [V].
pub const NOMINAL_VDD: f64 = 0.65;

/// Index of the boost point in [`operating_points`].
pub const OP_BOOST: usize = 0;
/// Index of the nominal point in [`operating_points`].
pub const OP_NOMINAL: usize = 1;
/// Index of the efficiency point in [`operating_points`].
pub const OP_EFFICIENCY: usize = 2;

/// One voltage/frequency operating point (see the module docs for the
/// derivation from the Table II anchors).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    /// Corner name (`boost` / `nominal` / `efficiency`).
    pub name: &'static str,
    /// Supply voltage [V].
    pub vdd: f64,
    /// Clock period [ps] (integral — the deterministic timebase).
    pub period_ps: u64,
}

impl OperatingPoint {
    /// The nominal 0.65 V / 250 MHz corner (variant-independent).
    pub fn nominal() -> OperatingPoint {
        OperatingPoint { name: "nominal", vdd: NOMINAL_VDD, period_ps: NOMINAL_PERIOD_PS }
    }

    /// Clock frequency [MHz].
    pub fn f_mhz(&self) -> f64 {
        1e6 / self.period_ps as f64
    }

    /// Dynamic-energy scale vs the nominal corner (`(V/V_nom)²`).
    pub fn dyn_scale(&self) -> f64 {
        (self.vdd / NOMINAL_VDD).powi(2)
    }

    /// Leakage-power scale vs the nominal corner (`(V/V_nom)³`).
    pub fn leak_scale(&self) -> f64 {
        (self.vdd / NOMINAL_VDD).powi(3)
    }

    /// Convert `core_cycles` executed at this point into fleet ticks
    /// (nominal-period cycles), rounding up. Pure integer arithmetic —
    /// deterministic on every host — and an exact identity at the
    /// nominal point, so a fleet that never leaves nominal is
    /// tick-for-tick the fleet that predates DVFS.
    pub fn fleet_ticks(&self, core_cycles: u64) -> u64 {
        let ps = core_cycles as u128 * self.period_ps as u128;
        ps.div_ceil(NOMINAL_PERIOD_PS as u128) as u64
    }
}

/// The three operating points of one variant, ordered fastest first
/// (index with [`OP_BOOST`] / [`OP_NOMINAL`] / [`OP_EFFICIENCY`]).
pub fn operating_points(v: IsaVariant) -> [OperatingPoint; 3] {
    let boost_period_ps = (1e6 / phys(v).fmax_mhz).round() as u64;
    [
        OperatingPoint { name: "boost", vdd: 0.80, period_ps: boost_period_ps },
        OperatingPoint::nominal(),
        OperatingPoint { name: "efficiency", vdd: 0.50, period_ps: 2 * NOMINAL_PERIOD_PS },
    ]
}

/// How the serving engine picks operating points (see
/// [`crate::serve::ServeConfig`]; enforcement happens in the engine's
/// sequential scheduling step so it is deterministic by construction).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DvfsPolicy {
    /// Highest point that fits under the power cap — finish fast, idle
    /// long (minimizes latency; leakage favours it when idle power is
    /// gated away).
    RaceToIdle,
    /// Lowest-voltage point — minimal energy per request, longest
    /// latency (ignores everything but the energy bill).
    SlowAndSteady,
    /// Per-SLO-class: high-priority classes get boost, standard runs
    /// nominal, best-effort runs the efficiency corner; downgraded as
    /// needed to honour the cap.
    Slo,
    /// Pin every dispatch to one operating-point index. The default is
    /// `Fixed(OP_NOMINAL)`, which reproduces the pre-DVFS fleet exactly.
    Fixed(usize),
}

impl Default for DvfsPolicy {
    fn default() -> Self {
        DvfsPolicy::Fixed(OP_NOMINAL)
    }
}

impl DvfsPolicy {
    /// Parse a `--dvfs` CLI value.
    pub fn from_name(s: &str) -> Option<DvfsPolicy> {
        match s {
            "race" => Some(DvfsPolicy::RaceToIdle),
            "steady" => Some(DvfsPolicy::SlowAndSteady),
            "slo" => Some(DvfsPolicy::Slo),
            "boost" => Some(DvfsPolicy::Fixed(OP_BOOST)),
            "nominal" => Some(DvfsPolicy::Fixed(OP_NOMINAL)),
            "efficiency" => Some(DvfsPolicy::Fixed(OP_EFFICIENCY)),
            _ => None,
        }
    }

    /// CLI name of the policy.
    pub fn name(&self) -> &'static str {
        match self {
            DvfsPolicy::RaceToIdle => "race",
            DvfsPolicy::SlowAndSteady => "steady",
            DvfsPolicy::Slo => "slo",
            DvfsPolicy::Fixed(OP_BOOST) => "boost",
            DvfsPolicy::Fixed(OP_EFFICIENCY) => "efficiency",
            DvfsPolicy::Fixed(_) => "nominal",
        }
    }
}

/// Per-instruction-class energies [pJ], cluster-wide shared overheads
/// included via `shared_pj_per_cycle`. Fitted to the Table II / Table III
/// anchors (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Core issue/fetch/decode/RF per active cycle [pJ].
    pub base_pj: f64,
    /// Extra energy of a SIMD dotp by element width of the wider operand.
    pub dotp8_pj: f64,
    pub dotp4_pj: f64,
    pub dotp2_pj: f64,
    /// TCDM access (interconnect + bank) [pJ].
    pub mem_pj: f64,
    /// Mac&Load WB-load adder [pJ].
    pub macload_pj: f64,
    /// Shared cluster logic (icache, interconnect clocking, FC share) per
    /// cycle [pJ].
    pub shared_pj_per_cycle: f64,
    /// Clock-gated (barrier/idle) core cycle [pJ].
    pub gated_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Fit notes: with the Flex-V a8w8 MatMul mix (≈0.80 dotp/cycle/core,
        // ≈0.33 TCDM access/cycle/core) the cluster at 250 MHz must draw
        // ≈12.6 mW ⇒ ≈50 pJ/cycle; the sub-byte dotp energies then set the
        // Table III efficiency spread.
        EnergyModel {
            base_pj: 2.1,
            dotp8_pj: 2.6,
            dotp4_pj: 2.0,
            dotp2_pj: 1.6,
            mem_pj: 2.6,
            macload_pj: 0.5,
            shared_pj_per_cycle: 8.0,
            gated_pj: 0.25,
        }
    }
}

impl EnergyModel {
    /// Per-dotp energy for an element width of the supported grid.
    /// The grid is closed — 2/4/8-bit SIMD plus the 16-bit fallback the
    /// kernel generators emit — and anything else is a pricing bug, not
    /// a default: a new precision must be fitted, never silently aliased
    /// to the 8-bit energy.
    fn dotp_pj(&self, dotp_bits: u8) -> f64 {
        match dotp_bits {
            8 => self.dotp8_pj,
            4 => self.dotp4_pj,
            2 => self.dotp2_pj,
            16 => self.dotp8_pj * 1.6,
            other => panic!(
                "EnergyModel: unsupported dotp width {other} (supported grid: 2|4|8|16) — \
                 fit an energy for the new precision instead of aliasing it"
            ),
        }
    }

    /// Dynamic (switching) energy of one simulated window [pJ] at the
    /// nominal voltage — purely activity-based, frequency-independent.
    pub fn dynamic_energy_pj(&self, stats: &ClusterStats, dotp_bits: u8) -> f64 {
        let dotp_pj = self.dotp_pj(dotp_bits);
        let mut e = stats.cycles as f64 * self.shared_pj_per_cycle;
        for c in &stats.cores {
            let active = c.cycles.saturating_sub(c.barrier_cycles) as f64;
            e += active * self.base_pj;
            e += c.barrier_cycles as f64 * self.gated_pj;
            e += c.dotp_instrs as f64 * dotp_pj;
            e += c.tcdm_accesses as f64 * self.mem_pj;
            e += c.macload_instrs as f64 * self.macload_pj;
        }
        e
    }

    /// Energy of one simulated window [pJ] at the nominal operating
    /// point (0.65 V / 250 MHz): dynamic energy plus the leakage accrued
    /// over the window's wall time at that corner.
    pub fn energy_pj(&self, v: IsaVariant, stats: &ClusterStats, dotp_bits: u8) -> f64 {
        self.energy_pj_at(v, stats, dotp_bits, &OperatingPoint::nominal())
    }

    /// Energy of one simulated window [pJ] at an arbitrary operating
    /// point: dynamic energy scaled by `(V/V_nom)²`, leakage scaled by
    /// `(V/V_nom)³` and integrated over `cycles × period`.
    pub fn energy_pj_at(
        &self,
        v: IsaVariant,
        stats: &ClusterStats,
        dotp_bits: u8,
        op: &OperatingPoint,
    ) -> f64 {
        let dyn_pj = self.dynamic_energy_pj(stats, dotp_bits) * op.dyn_scale();
        // P_leak[mW] × t[ps] = E[pJ] × 1e3 ⇒ the 1e-3 below.
        let leak_pj =
            stats.cycles as f64 * op.period_ps as f64 * phys(v).leak_mw * op.leak_scale() * 1e-3;
        dyn_pj + leak_pj
    }

    /// Average cluster power [mW] at frequency `f_mhz` for a window:
    /// dynamic power (∝ f) plus the frequency-independent Table II
    /// leakage. Nominal voltage; use [`EnergyModel::power_mw_at`] for
    /// other corners.
    pub fn power_mw(&self, v: IsaVariant, stats: &ClusterStats, dotp_bits: u8, f_mhz: f64) -> f64 {
        let dyn_per_cycle = self.dynamic_energy_pj(stats, dotp_bits) / stats.cycles.max(1) as f64;
        dyn_per_cycle * 1e-12 * f_mhz * 1e6 * 1e3 + phys(v).leak_mw
    }

    /// Average cluster power [mW] of a window at an operating point.
    pub fn power_mw_at(
        &self,
        v: IsaVariant,
        stats: &ClusterStats,
        dotp_bits: u8,
        op: &OperatingPoint,
    ) -> f64 {
        let dyn_per_cycle = self.dynamic_energy_pj(stats, dotp_bits) * op.dyn_scale()
            / stats.cycles.max(1) as f64;
        dyn_per_cycle * 1e-12 * op.f_mhz() * 1e6 * 1e3 + phys(v).leak_mw * op.leak_scale()
    }

    /// Conservative upper bound on one cluster's power [mW] while busy at
    /// `op`: every core assumed to retire its most expensive possible mix
    /// every cycle (a dotp at the widest-element energy, a TCDM access
    /// and a Mac&Load WB-load — each counter is bounded by `cycles`, so
    /// no real window can exceed this). The serving engine budgets power
    /// caps against this bound, which makes "fleet average power ≤ cap"
    /// hold by construction.
    pub fn busy_power_bound_mw(&self, v: IsaVariant, n_cores: usize, op: &OperatingPoint) -> f64 {
        let dyn_per_cycle = self.shared_pj_per_cycle
            + n_cores as f64
                * (self.base_pj + self.dotp8_pj * 1.6 + self.mem_pj + self.macload_pj);
        dyn_per_cycle * op.dyn_scale() * 1e-12 * op.f_mhz() * 1e6 * 1e3
            + phys(v).leak_mw * op.leak_scale()
    }

    /// Energy efficiency [TOPS/W] = ops per joule (1 MAC = 2 ops) at the
    /// nominal operating point.
    pub fn tops_per_watt(&self, v: IsaVariant, stats: &ClusterStats, dotp_bits: u8) -> f64 {
        let ops = 2.0 * stats.total_macs() as f64;
        let e_j = self.energy_pj(v, stats, dotp_bits) * 1e-12;
        ops / e_j / 1e12
    }

    /// Energy efficiency [TOPS/W] at an arbitrary operating point — peaks
    /// at the efficiency corner, where dynamic energy shrinks with `V²`
    /// faster than the slower clock grows the leakage share.
    pub fn tops_per_watt_at(
        &self,
        v: IsaVariant,
        stats: &ClusterStats,
        dotp_bits: u8,
        op: &OperatingPoint,
    ) -> f64 {
        let ops = 2.0 * stats.total_macs() as f64;
        let e_j = self.energy_pj_at(v, stats, dotp_bits, op) * 1e-12;
        ops / e_j / 1e12
    }
}

/// GOP/s of a kernel window at `f_mhz`.
pub fn gops(stats: &ClusterStats, f_mhz: f64) -> f64 {
    2.0 * stats.macs_per_cycle() * f_mhz * 1e6 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::CoreStats;
    use crate::util::{proptest, Prng};

    fn synthetic_stats(dotp_per_core: u64, cycles: u64) -> ClusterStats {
        ClusterStats {
            cycles,
            cores: vec![
                CoreStats {
                    cycles,
                    instrs: cycles,
                    macs: dotp_per_core * 4,
                    dotp_instrs: dotp_per_core,
                    macload_instrs: dotp_per_core / 2,
                    tcdm_accesses: cycles / 3,
                    ..Default::default()
                };
                8
            ],
            ..Default::default()
        }
    }

    #[test]
    fn area_overheads_match_table2() {
        let r = phys(IsaVariant::Ri5cy);
        let f = phys(IsaVariant::FlexV);
        let core_ovh = (f.core_area_um2 - r.core_area_um2) / r.core_area_um2;
        assert!((core_ovh - 0.298).abs() < 0.01, "core overhead {core_ovh}");
        let cl_ovh = (f.cluster_area_um2 - r.cluster_area_um2) / r.cluster_area_um2;
        assert!((cl_ovh - 0.0559).abs() < 0.005, "cluster overhead {cl_ovh}");
        // fmax degradation ≈ 2%
        assert!((1.0 - f.fmax_mhz / r.fmax_mhz - 0.019).abs() < 0.01);
    }

    #[test]
    fn cluster_power_8b_matmul_near_table2() {
        // ~0.8 dotp/cycle/core on the 8b kernel.
        let stats = synthetic_stats(800, 1000);
        let m = EnergyModel::default();
        let p = m.power_mw(IsaVariant::FlexV, &stats, 8, 250.0);
        assert!(
            (10.0..16.0).contains(&p),
            "8b MatMul cluster power {p:.1} mW should be near Table II's 12.6"
        );
        // Flex-V draws slightly more than RI5CY (leakage delta)
        let pr = m.power_mw(IsaVariant::Ri5cy, &stats, 8, 250.0);
        assert!(p > pr && (p - pr) / pr < 0.05, "{p} vs {pr}");
    }

    /// Regression for the static/dynamic split: the old code derived
    /// `power_mw` from the *total* energy per cycle (leakage folded in at
    /// 250 MHz) times `f`, so halving the frequency halved the leakage
    /// power too — `p(125) == p(250)/2` exactly. The split model keeps
    /// leakage frequency-independent: `p(f) = p_dyn(250)·f/250 + leak`.
    #[test]
    fn power_mw_splits_static_and_dynamic_across_125_250_463_mhz() {
        let stats = synthetic_stats(800, 1000);
        let m = EnergyModel::default();
        let leak = phys(IsaVariant::FlexV).leak_mw;
        // The whole curve is pinned by its f→0 intercept and one slope.
        assert!((m.power_mw(IsaVariant::FlexV, &stats, 8, 0.0) - leak).abs() < 1e-12);
        let dyn250 = m.power_mw(IsaVariant::FlexV, &stats, 8, 250.0) - leak;
        for f in [125.0, 250.0, 463.0] {
            let p = m.power_mw(IsaVariant::FlexV, &stats, 8, f);
            let want = dyn250 * f / 250.0 + leak;
            assert!((p - want).abs() < 1e-9, "p({f}) = {p}, want {want}");
        }
        // The old behaviour, explicitly ruled out: scaling the leakage
        // share along with frequency.
        let p125 = m.power_mw(IsaVariant::FlexV, &stats, 8, 125.0);
        let old_p125 = (dyn250 + leak) / 2.0;
        assert!(
            (p125 - old_p125).abs() > leak / 4.0,
            "leakage must not scale with frequency ({p125} vs legacy {old_p125})"
        );
    }

    #[test]
    #[should_panic(expected = "unsupported dotp width")]
    fn unknown_dotp_width_panics_instead_of_aliasing_to_8bit() {
        let stats = synthetic_stats(100, 1000);
        EnergyModel::default().energy_pj(IsaVariant::FlexV, &stats, 3);
    }

    #[test]
    fn operating_points_are_physically_consistent() {
        let m = EnergyModel::default();
        let stats = synthetic_stats(800, 1000);
        let [boost, nominal, eff] = operating_points(IsaVariant::FlexV);
        // Table II fmax for Flex-V is 463 MHz; the ps grid holds it to <1%.
        assert!((boost.f_mhz() - 463.0).abs() < 1.0, "boost {} MHz", boost.f_mhz());
        assert!((nominal.f_mhz() - 250.0).abs() < 1e-9);
        assert!((eff.f_mhz() - 125.0).abs() < 1e-9);
        // The historical single-corner entry point IS the nominal point.
        assert_eq!(
            m.energy_pj(IsaVariant::FlexV, &stats, 8),
            m.energy_pj_at(IsaVariant::FlexV, &stats, 8, &nominal),
        );
        // Faster corners draw more power, slower corners spend less energy.
        let p: Vec<f64> = [boost, nominal, eff]
            .iter()
            .map(|op| m.power_mw_at(IsaVariant::FlexV, &stats, 8, op))
            .collect();
        assert!(p[0] > p[1] && p[1] > p[2], "power ordering {p:?}");
        let e: Vec<f64> = [boost, nominal, eff]
            .iter()
            .map(|op| m.energy_pj_at(IsaVariant::FlexV, &stats, 8, op))
            .collect();
        assert!(e[0] > e[1] && e[1] > e[2], "energy ordering {e:?}");
        // … so TOPS/W peaks at the efficiency corner.
        let tw_eff = m.tops_per_watt_at(IsaVariant::FlexV, &stats, 8, &eff);
        let tw_nom = m.tops_per_watt_at(IsaVariant::FlexV, &stats, 8, &nominal);
        assert!(tw_eff > tw_nom);
        // The busy-power bound dominates any real window at every corner.
        for op in [boost, nominal, eff] {
            let bound = m.busy_power_bound_mw(IsaVariant::FlexV, 8, &op);
            let real = m.power_mw_at(IsaVariant::FlexV, &stats, 8, &op);
            assert!(bound >= real, "bound {bound} < real {real} at {}", op.name);
        }
    }

    #[test]
    fn fleet_tick_conversion_is_exact_at_nominal_and_rounds_up() {
        let [boost, nominal, eff] = operating_points(IsaVariant::FlexV);
        assert_eq!(nominal.fleet_ticks(12_345), 12_345);
        assert_eq!(eff.fleet_ticks(1_000), 2_000);
        // boost: 2160 ps period ⇒ 1000 core cycles = 2.16 Mps = 540 ticks.
        assert_eq!(boost.period_ps, 2_160);
        assert_eq!(boost.fleet_ticks(1_000), 540);
        // ceil, never floor: a nonzero window costs at least one tick.
        assert_eq!(boost.fleet_ticks(1), 1);
        assert_eq!(boost.fleet_ticks(0), 0);
    }

    #[test]
    fn dvfs_policy_names_round_trip() {
        for p in [
            DvfsPolicy::RaceToIdle,
            DvfsPolicy::SlowAndSteady,
            DvfsPolicy::Slo,
            DvfsPolicy::Fixed(OP_BOOST),
            DvfsPolicy::Fixed(OP_NOMINAL),
            DvfsPolicy::Fixed(OP_EFFICIENCY),
        ] {
            assert_eq!(DvfsPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(DvfsPolicy::from_name("warp"), None);
        assert_eq!(DvfsPolicy::default(), DvfsPolicy::Fixed(OP_NOMINAL));
    }

    fn random_stats(rng: &mut Prng) -> ClusterStats {
        let cycles = 1 + rng.below(10_000);
        let cores = (0..8)
            .map(|_| {
                let barrier = rng.below(cycles + 1);
                CoreStats {
                    cycles,
                    instrs: cycles,
                    macs: rng.below(cycles * 4 + 1),
                    dotp_instrs: rng.below(cycles + 1),
                    macload_instrs: rng.below(cycles + 1),
                    tcdm_accesses: rng.below(cycles + 1),
                    barrier_cycles: barrier,
                    ..Default::default()
                }
            })
            .collect();
        ClusterStats { cycles, cores, ..Default::default() }
    }

    #[test]
    fn prop_energy_strictly_positive_for_nonempty_windows() {
        proptest::check_default(random_stats, |stats| {
            for op in operating_points(IsaVariant::FlexV) {
                let e = EnergyModel::default().energy_pj_at(IsaVariant::FlexV, stats, 8, &op);
                if e <= 0.0 {
                    return Err(format!("energy {e} not strictly positive at {}", op.name));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_energy_monotone_in_activity_counters() {
        proptest::check_default(random_stats, |stats| {
            let m = EnergyModel::default();
            let base = m.energy_pj(IsaVariant::FlexV, stats, 8);
            let mut bump = |f: &dyn Fn(&mut CoreStats), what: &str| -> Result<(), String> {
                let mut s = stats.clone();
                f(&mut s.cores[0]);
                let e = m.energy_pj(IsaVariant::FlexV, &s, 8);
                if e > base {
                    Ok(())
                } else {
                    Err(format!("+1 {what} did not increase energy ({e} <= {base})"))
                }
            };
            bump(&|c| c.dotp_instrs += 1, "dotp")?;
            bump(&|c| c.tcdm_accesses += 1, "tcdm access")?;
            bump(&|c| c.macload_instrs += 1, "macload")?;
            Ok(())
        });
    }

    #[test]
    fn prop_tops_per_watt_invariant_under_stats_scaling() {
        proptest::check_default(
            |rng| (random_stats(rng), 1 + rng.below(7)),
            |(stats, k)| {
                let m = EnergyModel::default();
                let scaled = ClusterStats {
                    cycles: stats.cycles * k,
                    cores: stats
                        .cores
                        .iter()
                        .map(|c| CoreStats {
                            cycles: c.cycles * k,
                            instrs: c.instrs * k,
                            macs: c.macs * k,
                            dotp_instrs: c.dotp_instrs * k,
                            macload_instrs: c.macload_instrs * k,
                            tcdm_accesses: c.tcdm_accesses * k,
                            barrier_cycles: c.barrier_cycles * k,
                            ..Default::default()
                        })
                        .collect(),
                    ..Default::default()
                };
                let a = m.tops_per_watt(IsaVariant::FlexV, stats, 8);
                let b = m.tops_per_watt(IsaVariant::FlexV, &scaled, 8);
                if (a - b).abs() <= 1e-9 * a.abs().max(1.0) {
                    Ok(())
                } else {
                    Err(format!("TOPS/W changed under x{k} scaling: {a} vs {b}"))
                }
            },
        );
    }

    #[test]
    fn efficiency_increases_with_narrower_formats() {
        let m = EnergyModel::default();
        let stats2 = {
            let mut s = synthetic_stats(900, 1000);
            for c in &mut s.cores {
                c.macs = c.dotp_instrs * 16; // a2w2: 16 MACs per sdotp
            }
            s
        };
        let stats8 = synthetic_stats(900, 1000);
        let e2 = m.tops_per_watt(IsaVariant::FlexV, &stats2, 2);
        let e8 = m.tops_per_watt(IsaVariant::FlexV, &stats8, 8);
        assert!(e2 > 2.0 * e8, "a2w2 {e2} should dwarf a8w8 {e8}");
        assert!(e2 > 2.0 && e2 < 6.0, "a2w2 eff {e2} out of plausible range");
    }

    #[test]
    fn barrier_cycles_cost_less_than_active() {
        let m = EnergyModel::default();
        let mut idle = synthetic_stats(0, 1000);
        for c in &mut idle.cores {
            c.tcdm_accesses = 0;
            c.barrier_cycles = 900;
        }
        let mut busy = synthetic_stats(0, 1000);
        for c in &mut busy.cores {
            c.tcdm_accesses = 0;
        }
        let ei = m.energy_pj(IsaVariant::FlexV, &idle, 8);
        let eb = m.energy_pj(IsaVariant::FlexV, &busy, 8);
        assert!(ei < eb);
    }
}
