//! A shard: one simulated PULP cluster plus its serving state.
//!
//! Each shard owns a [`Cluster`], a warm tile-timing memo, and tracks
//! which model's L2 image is currently **resident**. Executing a batch
//! for a non-resident model charges an explicit model-switch cost — the
//! L3→L2 weight streaming the one-shot coordinator leaves untimed (it
//! models a pre-resident flash image; a serving fleet cannot).
//!
//! Shards are self-contained (`Send`): the engine's dispatch round runs
//! `run_batch` for different shards on different host threads. With the
//! steady-state fast path enabled, each cluster also keeps a window memo
//! that survives the per-request `Cluster::reset` of exact mode, so
//! repeated requests replay instead of re-simulating — still bit-exact
//! (see [`crate::sim::fastpath`]).
//!
//! A shard can be **parked** by the autoscaler
//! ([`crate::serve::autoscale`]): an inactive shard receives no batches
//! and its L2 model image is evicted, so the first batch after a
//! [`Shard::wake`] pays the full model cold-load (switch) cost. The
//! cluster itself — including its share of the fleet window cache — is
//! kept, since parking models a scheduling decision, not a teardown.
//!
//! **Batch timing contract** (relied on by the post-hoc trace
//! reconstruction in [`crate::trace::serve`]): a batch starts at
//! `max(arrival, busy_until)`, the model-switch cost is charged once on
//! the batch's first member, and each completion's execution occupies
//! the contiguous window `[finish_cycle - exec_cycles, finish_cycle]` —
//! so `Completion`s alone suffice to rebuild the shard timeline.
//!
//! **Time base**: every shard clock is in **fleet ticks** — periods of
//! the nominal operating point ([`crate::power::NOMINAL_PERIOD_PS`],
//! i.e. 250 MHz cycles). A batch executed at a non-nominal operating
//! point has its core cycle counts converted through
//! [`crate::power::OperatingPoint::fleet_ticks`], so shards running at
//! different voltage/frequency points share one timeline and the
//! engine's completion merge stays well-ordered. At the nominal point
//! the conversion is the identity, which keeps every pre-DVFS cycle
//! number (and blessed baseline) unchanged.

use crate::coordinator::{execute_deployment, preload_deployment, TileMemo};
use crate::dory::deploy::Deployment;
use crate::dory::PlanKey;
use crate::power::{operating_points, EnergyModel};
use crate::sim::fastpath::WindowCache;
use crate::sim::{Cluster, CoreFidelity};

use super::request::{Completion, Request};

/// DMA programming overhead charged per preload segment when streaming a
/// model in (mirrors `sim::dma::DMA_SETUP_CYCLES`).
const SWITCH_SETUP_CYCLES: u64 = 16;
/// Peak bytes per cycle of the L3→L2 streaming port (mirrors the cluster
/// DMA's 64-bit port).
const SWITCH_BYTES_PER_CYCLE: u64 = 8;

pub struct Shard {
    pub id: usize,
    /// Exact mode: a pristine cluster per request (bit-identical outputs
    /// and cycle counts to a direct `Coordinator` run). Off: warm cluster
    /// + tile-timing memo for throughput (timing-only outputs).
    exact: bool,
    cluster: Cluster,
    memo: TileMemo,
    /// Plan identity of the model whose L2 image the shard holds.
    resident: Option<PlanKey>,
    /// Registry index of the resident model (batcher affinity).
    pub resident_model: Option<usize>,
    /// Eligible for dispatch. Parked (`false`) shards hold no model
    /// image; the autoscaler toggles this between dispatch rounds.
    pub active: bool,
    /// Simulated cycle at which the shard next becomes free.
    pub busy_until: u64,
    /// Total busy cycles over the shard's lifetime.
    pub busy_cycles: u64,
    pub served: u64,
    pub batches: u64,
    pub model_switches: u64,
    /// Fault injection: simulated cycle until which the shard is down
    /// (0 = healthy). A failed shard is parked and must not be woken by
    /// the autoscaler until it recovers ([`Shard::recover`]).
    pub failed_until: u64,
    /// Straggler window: batches *starting* before this cycle run
    /// `slow_factor`× slower (0 = nominal).
    pub slow_until: u64,
    /// Slowdown multiplier inside the straggler window (≥ 1).
    pub slow_factor: u64,
    /// Thermal-throttle window: batches dispatched before this tick are
    /// clamped to the efficiency operating point by the engine's DVFS
    /// governor (0 = cool). Set by the federation's `ThermalThrottle`
    /// fault; purely simulated state, so the clamp is deterministic.
    pub throttle_until: u64,
}

impl Shard {
    /// `fastpath: Some(cache)` enables the steady-state fast path on
    /// this shard's cluster; the engine passes every shard a clone of
    /// one [`WindowCache`], so recordings pool across the fleet (the
    /// window memo is fidelity-keyed, so mixed-tier fleets sharing one
    /// cache stay correct). `fidelity` picks the cluster's core timing
    /// tier ([`crate::sim::CoreFidelity`]) — outputs are
    /// tier-independent, cycle counts are not.
    pub fn new(
        id: usize,
        n_cores: usize,
        exact: bool,
        fastpath: Option<WindowCache>,
        fidelity: CoreFidelity,
    ) -> Self {
        let mut cluster = Cluster::new(n_cores);
        cluster.set_fidelity(fidelity);
        if let Some(cache) = fastpath {
            cluster.enable_fastpath_shared(cache);
        }
        Shard {
            id,
            exact,
            cluster,
            memo: TileMemo::new(),
            resident: None,
            resident_model: None,
            active: true,
            busy_until: 0,
            busy_cycles: 0,
            served: 0,
            batches: 0,
            model_switches: 0,
            failed_until: 0,
            slow_until: 0,
            slow_factor: 1,
            throttle_until: 0,
        }
    }

    pub fn is_free(&self, now: u64) -> bool {
        self.busy_until <= now
    }

    /// Cycles since the shard last finished a batch (0 while busy).
    pub fn idle_cycles(&self, now: u64) -> u64 {
        now.saturating_sub(self.busy_until)
    }

    /// Park the shard: no more dispatches, and the resident model's L2
    /// image is evicted — the next batch after [`Shard::wake`] pays the
    /// full L3→L2 cold-load cost. The cluster (and its fast-path window
    /// cache) is retained.
    pub fn park(&mut self) {
        self.active = false;
        self.resident = None;
        self.resident_model = None;
    }

    /// Reactivate a parked shard (cold: no model resident).
    pub fn wake(&mut self) {
        self.active = true;
    }

    /// Fault-inject: take the shard down until `until`. The shard is
    /// parked (its L2 model image is lost exactly like an autoscaler
    /// park) and flagged failed, which blocks autoscaler wakes until
    /// recovery. Retracting and re-queuing the work the shard had in
    /// flight is the engine's job (`Engine::fail_shard`), since the
    /// shard does not own the queue.
    pub fn fail(&mut self, until: u64) {
        self.park();
        self.failed_until = until;
    }

    /// Recover from a fault: healthy and active again, cold like any
    /// wake (the model image did not survive the failure).
    pub fn recover(&mut self) {
        self.failed_until = 0;
        self.wake();
    }

    /// Whether the shard is failed (fault-injected down) at `now`.
    pub fn is_failed(&self, now: u64) -> bool {
        self.failed_until > now
    }

    /// Straggle: batches starting before `until` run `factor`× slower
    /// (DMA contention, thermal throttling — anything that stretches
    /// service time without corrupting results). Purely a timing
    /// overlay: outputs, MACs, and energy are untouched.
    pub fn slow(&mut self, factor: u64, until: u64) {
        self.slow_factor = factor.max(1);
        self.slow_until = until;
    }

    /// Thermal-throttle: batches dispatched before `until` are clamped
    /// to the efficiency operating point (die-temperature governor
    /// emulation; see the federation's `ThermalThrottle` fault). A
    /// timing/energy overlay like [`Shard::slow`] — results untouched.
    pub fn throttle(&mut self, until: u64) {
        self.throttle_until = until;
    }

    /// Whether the thermal-throttle clamp applies at `now`.
    pub fn is_throttled(&self, now: u64) -> bool {
        self.throttle_until > now
    }

    /// Enable the fast path's crosscheck mode on this shard's cluster:
    /// every replayed window is re-simulated and compared, panicking on
    /// any divergence (soak tests only — slower than no cache). No-op
    /// when the fast path is disabled.
    pub fn set_crosscheck(&mut self, on: bool) {
        if self.cluster.fastpath().is_some() {
            self.cluster.set_fastpath_crosscheck(on);
        }
    }

    /// Fast-path counters of this shard's cluster: (pure replays,
    /// functional replays, recorded misses); zeros when disabled.
    pub fn fastpath_counts(&self) -> (u64, u64, u64) {
        self.cluster
            .fastpath()
            .map_or((0, 0, 0), |f| (f.pure_hits, f.func_hits, f.misses))
    }

    /// Simulated cycles to stream a deployment's L2 image in (weights +
    /// quant parameters, per-segment DMA setup + port bandwidth).
    pub fn switch_cycles(dep: &Deployment) -> u64 {
        dep.preload
            .iter()
            .map(|(_, b)| SWITCH_SETUP_CYCLES + (b.len() as u64).div_ceil(SWITCH_BYTES_PER_CYCLE))
            .sum()
    }

    /// Execute one single-model batch starting at `now` (the engine only
    /// dispatches to free shards). Returns one completion per request, in
    /// batch order; the shard's clock advances past the batch. `op_idx`
    /// selects the operating point (index into
    /// [`operating_points`]`(dep.isa)`, chosen by the engine's DVFS
    /// governor): core cycle counts convert to fleet ticks through it,
    /// and energy is billed at its voltage/frequency corner.
    pub fn run_batch(
        &mut self,
        model: usize,
        key: PlanKey,
        dep: &Deployment,
        batch: Vec<Request>,
        now: u64,
        em: &EnergyModel,
        op_idx: u8,
    ) -> Vec<Completion> {
        debug_assert!(self.is_free(now));
        let op = operating_points(dep.isa)[op_idx as usize];
        let start = now.max(self.busy_until);
        // Straggler overlay: a batch starting inside the slow window
        // stretches uniformly — a pure function of (start, slow_until,
        // slow_factor), all simulated state, so determinism holds.
        let slow = if start < self.slow_until { self.slow_factor.max(1) } else { 1 };
        let switching = self.resident != Some(key);
        let switch = if switching { op.fleet_ticks(Self::switch_cycles(dep) * slow) } else { 0 };
        if switching {
            self.model_switches += 1;
        }
        let batch_size = batch.len();
        let mut t = start + switch;
        let mut out = Vec::with_capacity(batch_size);
        for (i, req) in batch.into_iter().enumerate() {
            let res = if self.exact {
                // Pristine cluster per request: the run is indistinguishable
                // from a fresh direct Coordinator run (same arbiter phase,
                // same memory image), so outputs AND per-layer cycle counts
                // are bit-identical to the one-shot path. `reset` keeps the
                // fast-path window memo warm across requests.
                self.cluster.reset();
                preload_deployment(&mut self.cluster, dep);
                execute_deployment(&mut self.cluster, dep, &req.input, None)
            } else {
                // Warm path: the L2 image persists across same-model
                // requests; a different model may have clobbered our
                // regions, so re-preload exactly when switching.
                if switching && i == 0 {
                    preload_deployment(&mut self.cluster, dep);
                }
                execute_deployment(&mut self.cluster, dep, &req.input, Some(&mut self.memo))
            };
            let exec = op.fleet_ticks(res.total_cycles() * slow);
            t += exec;
            out.push(Completion {
                id: req.id,
                model,
                class: req.class,
                shard: self.id,
                arrival_cycle: req.arrival_cycle,
                deadline: req.deadline,
                start_cycle: start,
                finish_cycle: t,
                exec_cycles: exec,
                switch_cycles: if i == 0 { switch } else { 0 },
                batch_size,
                macs: res.total_macs(),
                energy_pj: res.energy_pj_at(dep.isa, em, &op),
                op: op_idx,
                layer_cycles: res.layer_cycles(),
                output: res.output,
            });
        }
        self.resident = Some(key);
        self.resident_model = Some(model);
        self.busy_cycles += t - start;
        self.busy_until = t;
        self.served += batch_size as u64;
        self.batches += 1;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dory::deploy::deploy;
    use crate::dory::MemBudget;
    use crate::isa::IsaVariant;
    use crate::power::{OP_EFFICIENCY, OP_NOMINAL};
    use crate::qnn::layer::Network;
    use crate::qnn::{Layer, QTensor};
    use crate::util::Prng;

    fn tiny(name: &str, seed: u64) -> Network {
        let mut rng = Prng::new(seed);
        let mut net = Network::new(name, [8, 8, 8], 8);
        net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net
    }

    #[test]
    fn switch_charged_once_then_amortized() {
        let net = tiny("s", 3);
        let budget = MemBudget::default();
        let dep = deploy(&net, IsaVariant::FlexV, budget);
        let key = PlanKey::for_network(&net, IsaVariant::FlexV, budget, 8);
        let mut shard =
            Shard::new(0, 8, false, Some(WindowCache::default()), CoreFidelity::Fast);
        let em = EnergyModel::default();
        let mut rng = Prng::new(4);
        let mk = |id: u64, rng: &mut Prng| Request {
            id,
            model: 0,
            class: 0,
            priority: 0,
            arrival_cycle: 0,
            deadline: None,
            input: QTensor::random(&[8, 8, 8], 8, false, rng),
        };
        let batch = vec![mk(0, &mut rng), mk(1, &mut rng)];
        let comps = shard.run_batch(0, key, &dep, batch, 0, &em, OP_NOMINAL as u8);
        assert_eq!(comps.len(), 2);
        let want_switch = Shard::switch_cycles(&dep);
        assert!(want_switch > 0);
        assert_eq!(comps[0].switch_cycles, want_switch);
        assert_eq!(comps[1].switch_cycles, 0);
        assert!(comps[1].finish_cycle > comps[0].finish_cycle);
        assert_eq!(shard.model_switches, 1);
        // same model again: resident, no switch
        let comps2 =
            shard.run_batch(0, key, &dep, vec![mk(2, &mut rng)], shard.busy_until, &em, 1);
        assert_eq!(comps2[0].switch_cycles, 0);
        assert_eq!(shard.model_switches, 1);
        assert_eq!(shard.served, 3);
    }

    /// A batch at the efficiency point takes exactly 2× the fleet ticks
    /// (8 ns period vs the 4 ns nominal tick), costs less energy at the
    /// 0.50 V corner, and produces bit-identical outputs — an operating
    /// point is a timing/energy overlay, never a functional one.
    #[test]
    fn efficiency_point_doubles_ticks_and_saves_energy() {
        let net = tiny("op", 7);
        let budget = MemBudget::default();
        let dep = deploy(&net, IsaVariant::FlexV, budget);
        let key = PlanKey::for_network(&net, IsaVariant::FlexV, budget, 8);
        let em = EnergyModel::default();
        let mut rng = Prng::new(8);
        let r = Request {
            id: 0,
            model: 0,
            class: 0,
            priority: 0,
            arrival_cycle: 0,
            deadline: None,
            input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
        };
        let mut nom = Shard::new(0, 8, false, Some(WindowCache::default()), CoreFidelity::Fast);
        let mut eff = Shard::new(1, 8, false, Some(WindowCache::default()), CoreFidelity::Fast);
        let a = nom.run_batch(0, key, &dep, vec![r.clone()], 0, &em, OP_NOMINAL as u8);
        let b = eff.run_batch(0, key, &dep, vec![r], 0, &em, OP_EFFICIENCY as u8);
        assert_eq!(b[0].output, a[0].output, "operating point must not change results");
        assert_eq!(b[0].exec_cycles, 2 * a[0].exec_cycles);
        assert_eq!(b[0].switch_cycles, 2 * a[0].switch_cycles);
        assert!(b[0].energy_pj < a[0].energy_pj, "0.50 V corner must cost less energy");
        assert_eq!((a[0].op, b[0].op), (OP_NOMINAL as u8, OP_EFFICIENCY as u8));
    }

    /// The straggler overlay stretches timing only (outputs, MACs
    /// untouched), and fail/recover round-trips through a cold park.
    #[test]
    fn straggler_stretches_timing_only_and_failure_parks() {
        let net = tiny("f", 5);
        let budget = MemBudget::default();
        let dep = deploy(&net, IsaVariant::FlexV, budget);
        let key = PlanKey::for_network(&net, IsaVariant::FlexV, budget, 8);
        let em = EnergyModel::default();
        let mut rng = Prng::new(6);
        let r = Request {
            id: 0,
            model: 0,
            class: 0,
            priority: 0,
            arrival_cycle: 0,
            deadline: None,
            input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
        };
        let mut nominal =
            Shard::new(0, 8, false, Some(WindowCache::default()), CoreFidelity::Fast);
        let mut slowed =
            Shard::new(1, 8, false, Some(WindowCache::default()), CoreFidelity::Fast);
        slowed.slow(3, u64::MAX);
        let a = nominal.run_batch(0, key, &dep, vec![r.clone()], 0, &em, OP_NOMINAL as u8);
        let b = slowed.run_batch(0, key, &dep, vec![r], 0, &em, OP_NOMINAL as u8);
        assert_eq!(b[0].output, a[0].output, "straggling must not corrupt results");
        assert_eq!(b[0].macs, a[0].macs);
        assert_eq!(b[0].exec_cycles, 3 * a[0].exec_cycles);
        assert_eq!(b[0].switch_cycles, 3 * a[0].switch_cycles);
        // fail parks the shard (model image lost) and blocks wakes
        slowed.fail(500);
        assert!(!slowed.active);
        assert!(slowed.is_failed(100));
        assert!(slowed.resident_model.is_none());
        assert!(!slowed.is_failed(500), "failure window is half-open");
        slowed.recover();
        assert!(slowed.active);
        assert_eq!(slowed.failed_until, 0);
    }
}
