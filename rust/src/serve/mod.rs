//! Multi-cluster inference **serving engine**: request queueing, dynamic
//! batching, a compiled-plan cache, and a pool of simulated cluster
//! shards (queue → batcher → shard pool → metrics; see
//! `rust/src/serve/README.md`).
//!
//! The one-shot pipeline (`dory::deploy` → `coordinator`) runs a single
//! `Deployment` on a single cluster and exits. This module is the layer
//! the ROADMAP's production north star needs on top of it:
//!
//! - a [`PlanCache`] keyed by [`crate::dory::PlanKey`] so the DORY flow
//!   (tiling solve, L2 layout, weight serialization) runs **once per
//!   model**, not once per request;
//! - a bounded priority [`RequestQueue`] with explicit rejection stats —
//!   graceful saturation instead of unbounded latency collapse;
//! - a dynamic [`batcher`] that coalesces queued same-model requests
//!   onto one shard pass, amortizing the L3→L2 model-switch cost the
//!   same way PULP-NN amortizes im2col/packing across calls;
//! - a pool of [`Shard`]s, each owning one simulated PULP cluster, driven
//!   in a deterministic discrete-event loop over **simulated cycles**
//!   (scaling one core's precision-flexible datapath to a fleet, as
//!   Dustin does on-die with 16 cores);
//! - per-request and fleet [`metrics`]: latency percentiles,
//!   requests/sec, aggregate MAC/cycle, energy per request.
//!
//! # Determinism contract
//!
//! Everything the engine reports is a function of the trace alone —
//! never of the host machine, worker count, or fast-path setting:
//!
//! - **Scheduling** (queue pops, batch formation, shard assignment) runs
//!   sequentially on the engine thread, in shard order, so the decision
//!   stream is reproducible by construction.
//! - **Execution** of the formed batches is embarrassingly parallel
//!   (each shard owns its cluster); with `workers != 1` the batches of a
//!   dispatch round run on a scoped `std::thread` pool. The round's
//!   completion events are then merged by simulated finish cycle
//!   (tie-break: shard id, then request id) — the sequential engine
//!   applies the *same* reduction, so `completions()` is bit-identical
//!   for any worker count (`rust/tests/serve_parallel_determinism.rs`).
//! - The simulator's steady-state fast path (`ServeConfig::fastpath`,
//!   see [`crate::sim::fastpath`]) replays previously-seen windows with
//!   bit-exact outputs and cycle counts; `fastpath: false` is the
//!   escape hatch and must change nothing but wall-clock time.
//!
//! With `exact: true` every request additionally runs on a pristine
//! cluster, making serve-path outputs and per-layer cycle counts
//! bit-identical to a direct [`crate::coordinator::Coordinator`] run
//! (asserted by `rust/tests/serve_determinism.rs`). The default
//! `exact: false` keeps clusters and tile-timing memos warm for
//! throughput, at the cost of timing-only outputs (see
//! `coordinator::execute_deployment`).

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod shard;

pub use batcher::BatchPolicy;
pub use cache::PlanCache;
pub use metrics::{FleetMetrics, ModelRow};
pub use queue::RequestQueue;
pub use request::{Completion, Request};
pub use shard::Shard;

use std::sync::Arc;

use crate::dory::deploy::{deploy, Deployment};
use crate::dory::{MemBudget, PlanKey};
use crate::isa::IsaVariant;
use crate::power::EnergyModel;
use crate::qnn::layer::Network;
use crate::qnn::QTensor;
use crate::util::Prng;

/// Fleet configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Number of cluster shards in the pool.
    pub shards: usize,
    /// Cores per shard cluster.
    pub n_cores: usize,
    /// Admission queue bound (requests beyond it are rejected;
    /// 0 admits nothing).
    pub queue_capacity: usize,
    /// Maximum requests coalesced into one shard pass.
    pub max_batch: usize,
    /// Lead-request shard affinity (avoid model switches when possible).
    pub prefer_resident: bool,
    /// Pristine cluster per request: bit-identical to the one-shot
    /// coordinator path (slow). Off: warm clusters + tile-timing memo.
    pub exact: bool,
    /// Host threads simulating shard batches concurrently within one
    /// dispatch round: 0 = one thread per busy shard (default), 1 =
    /// sequential. Results are bit-identical for any value — see the
    /// module-level determinism contract.
    pub workers: usize,
    /// Steady-state simulation fast path on each shard's cluster
    /// ([`crate::sim::fastpath`]); bit-exact, `false` is the escape
    /// hatch (`serve-bench --no-fastpath`).
    pub fastpath: bool,
    pub isa: IsaVariant,
    pub budget: MemBudget,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 4,
            n_cores: crate::CLUSTER_CORES,
            queue_capacity: 64,
            max_batch: 8,
            prefer_resident: true,
            exact: false,
            workers: 0,
            fastpath: true,
            isa: IsaVariant::FlexV,
            budget: MemBudget::default(),
        }
    }
}

/// One event of an arrival trace.
pub struct TraceItem {
    /// Arrival time in simulated cycles.
    pub at: u64,
    /// Index into the engine's model registry.
    pub model: usize,
    pub priority: u8,
    pub input: QTensor,
}

struct ModelEntry {
    name: String,
    net: Network,
    key: PlanKey,
}

/// One shard's work for a dispatch round: formed sequentially (so queue
/// decisions stay deterministic), executed possibly in parallel.
struct Assignment {
    shard: usize,
    model: usize,
    key: PlanKey,
    dep: Arc<Deployment>,
    batch: Vec<Request>,
}

/// The serving engine: model registry + queue + batcher + shard pool +
/// plan cache, advanced by a deterministic discrete-event loop.
pub struct Engine {
    pub cfg: ServeConfig,
    models: Vec<ModelEntry>,
    pub cache: PlanCache,
    pub queue: RequestQueue,
    shards: Vec<Shard>,
    em: EnergyModel,
    completions: Vec<Completion>,
    next_id: u64,
}

impl Engine {
    pub fn new(cfg: ServeConfig) -> Self {
        assert!(cfg.shards >= 1, "need at least one shard");
        // One window cache for the whole fleet: shard B replays windows
        // shard A recorded (wall-clock only; replay is bit-exact).
        let windows = crate::sim::fastpath::WindowCache::default();
        Engine {
            models: Vec::new(),
            cache: PlanCache::new(),
            queue: RequestQueue::new(cfg.queue_capacity),
            shards: (0..cfg.shards)
                .map(|i| {
                    Shard::new(i, cfg.n_cores, cfg.exact, cfg.fastpath.then(|| windows.clone()))
                })
                .collect(),
            em: EnergyModel::default(),
            completions: Vec::new(),
            next_id: 0,
            cfg,
        }
    }

    /// Register a model; returns its registry index. The plan itself is
    /// compiled lazily (and cached) on first dispatch.
    pub fn register(&mut self, net: Network) -> usize {
        net.validate().expect("invalid network");
        let key = PlanKey::for_network(&net, self.cfg.isa, self.cfg.budget, self.cfg.n_cores);
        self.models.push(ModelEntry { name: net.name.clone(), net, key });
        self.models.len() - 1
    }

    pub fn model_count(&self) -> usize {
        self.models.len()
    }

    pub fn model_name(&self, model: usize) -> &str {
        &self.models[model].name
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Enqueue one request arriving at `arrival_cycle`. Returns the
    /// request id, or `None` if the queue rejected it (saturation).
    pub fn submit(
        &mut self,
        model: usize,
        priority: u8,
        arrival_cycle: u64,
        input: QTensor,
    ) -> Option<u64> {
        let entry = &self.models[model];
        assert_eq!(
            input.shape,
            entry.net.input_shape.to_vec(),
            "input shape mismatch for model {}",
            entry.name
        );
        assert_eq!(input.bits, entry.net.input_bits, "input bits mismatch");
        let id = self.next_id;
        if self.queue.push(Request { id, model, priority, arrival_cycle, input }) {
            self.next_id += 1;
            Some(id)
        } else {
            None
        }
    }

    /// Hand batches to every free shard.
    ///
    /// Batch **formation** (queue pops, plan-cache lookups, shard
    /// assignment) runs sequentially in shard order, so every scheduling
    /// decision is deterministic. The formed batches are independent
    /// single-shard simulations; with `cfg.workers != 1` they **execute**
    /// on a scoped thread pool. Either way the round's completion events
    /// go through the same reduction — merged by simulated finish cycle,
    /// tie-break (shard id, request id) — so the completion stream is
    /// bit-identical for any worker count.
    fn dispatch_free_shards(&mut self, now: u64) {
        let policy = BatchPolicy {
            max_batch: self.cfg.max_batch,
            prefer_resident: self.cfg.prefer_resident,
        };
        let mut assignments: Vec<Assignment> = Vec::new();
        for si in 0..self.shards.len() {
            if !self.shards[si].is_free(now) {
                continue;
            }
            if self.queue.is_empty() {
                break;
            }
            let resident = self.shards[si].resident_model;
            let Some(batch) = batcher::next_batch(&mut self.queue, resident, &policy) else {
                break;
            };
            let model = batch[0].model;
            let (key, dep) = {
                let entry = &self.models[model];
                let (isa, budget) = (self.cfg.isa, self.cfg.budget);
                let dep = self.cache.get_or_build(entry.key, || deploy(&entry.net, isa, budget));
                (entry.key, dep)
            };
            assignments.push(Assignment { shard: si, model, key, dep, batch });
        }
        if assignments.is_empty() {
            return;
        }
        let em = self.em;
        let workers = if self.cfg.workers == 0 { assignments.len() } else { self.cfg.workers };
        let mut round: Vec<Completion> = Vec::new();
        if workers <= 1 || assignments.len() == 1 {
            for a in assignments {
                round.extend(
                    self.shards[a.shard].run_batch(a.model, a.key, &a.dep, a.batch, now, &em),
                );
            }
        } else {
            let mut assignments = assignments;
            while !assignments.is_empty() {
                let rest = assignments.split_off(workers.min(assignments.len()));
                let chunk = std::mem::replace(&mut assignments, rest);
                let shards = &mut self.shards;
                let results: Vec<Vec<Completion>> = std::thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(chunk.len());
                    // Shard indices are strictly increasing, so the pool
                    // splits into disjoint mutable borrows.
                    let mut tail: &mut [Shard] = &mut shards[..];
                    let mut consumed = 0usize;
                    for a in chunk {
                        let (_, at) = tail.split_at_mut(a.shard - consumed);
                        let (one, rest) = at.split_at_mut(1);
                        consumed = a.shard + 1;
                        tail = rest;
                        let shard = &mut one[0];
                        let em = &em;
                        handles.push(scope.spawn(move || {
                            shard.run_batch(a.model, a.key, &a.dep, a.batch, now, em)
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("shard worker panicked"))
                        .collect()
                });
                for comps in results {
                    round.extend(comps);
                }
            }
        }
        // Deterministic event-ordering reduction (see module docs).
        round.sort_by_key(|c| (c.finish_cycle, c.shard, c.id));
        self.completions.extend(round);
    }

    /// Replay an arrival trace to completion; returns the fleet report.
    /// The event loop advances a simulated clock: arrivals are admitted
    /// when due, free shards pull batches, and time jumps to the next
    /// arrival or shard-free event — O(events), independent of idle gaps.
    pub fn run_trace(&mut self, mut trace: Vec<TraceItem>) -> FleetMetrics {
        trace.sort_by_key(|t| t.at);
        let mut it = trace.into_iter().peekable();
        let mut clock = 0u64;
        loop {
            while it.peek().map_or(false, |t| t.at <= clock) {
                let t = it.next().unwrap();
                self.submit(t.model, t.priority, t.at, t.input);
            }
            self.dispatch_free_shards(clock);
            let next_arrival = it.peek().map(|t| t.at);
            let next_free = self
                .shards
                .iter()
                .map(|s| s.busy_until)
                .filter(|&b| b > clock)
                .min();
            if self.queue.is_empty() {
                // Nothing queued: jump to the next arrival, or done.
                match next_arrival {
                    Some(a) => clock = a,
                    None => break,
                }
                continue;
            }
            // Queue non-empty ⇒ every shard is busy (dispatch drains
            // otherwise). Wake at the next shard-free or arrival event.
            clock = match (next_free, next_arrival) {
                (Some(f), Some(a)) => f.min(a),
                (Some(f), None) => f,
                (None, Some(a)) => a,
                (None, None) => break, // unreachable: busy shards exist
            };
        }
        self.metrics()
    }

    /// Build the fleet report from everything served so far.
    pub fn metrics(&self) -> FleetMetrics {
        let names: Vec<String> = self.models.iter().map(|m| m.name.clone()).collect();
        FleetMetrics::collect(&self.completions, &names, &self.queue, &self.cache, &self.shards)
    }

    /// Deterministic synthetic traffic: `n` requests with uniform random
    /// inter-arrival gaps (mean `mean_gap_cycles`), models drawn from
    /// `mix` (one non-negative weight per registered model), inputs
    /// random per request.
    pub fn synthetic_trace(
        &self,
        n: usize,
        mean_gap_cycles: u64,
        mix: &[f64],
        seed: u64,
    ) -> Vec<TraceItem> {
        assert_eq!(mix.len(), self.models.len(), "one mix weight per model");
        let total: f64 = mix.iter().sum();
        assert!(total > 0.0, "mix must have positive mass");
        let mut rng = Prng::new(seed);
        let mut at = 0u64;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            at += rng.below(mean_gap_cycles.max(1) * 2);
            let mut pick = rng.next_u64() as f64 / u64::MAX as f64 * total;
            let mut model = 0;
            for (i, w) in mix.iter().enumerate() {
                model = i;
                if pick < *w {
                    break;
                }
                pick -= w;
            }
            let net = &self.models[model].net;
            out.push(TraceItem {
                at,
                model,
                priority: 0,
                input: QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng),
            });
        }
        out
    }
}

/// The paper's three evaluation networks (MobileNetV1-8b, -8b4b at
/// `input_hw`, ResNet-20-4b2b) — the standard serving mix used by the
/// `serve-bench` subcommand and the throughput bench.
pub fn standard_mix(input_hw: usize) -> Vec<Network> {
    crate::models::MODEL_NAMES
        .iter()
        .map(|n| crate::models::by_name(n, input_hw).expect("known model"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::Layer;

    fn tiny(name: &str, seed: u64) -> Network {
        let mut rng = Prng::new(seed);
        let mut net = Network::new(name, [8, 8, 8], 8);
        net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [8, 8, 8], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net
    }

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            shards: 2,
            n_cores: 4,
            queue_capacity: 32,
            max_batch: 4,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn fleet_serves_mixed_traffic_with_cache_and_batching() {
        let mut eng = Engine::new(small_cfg());
        let a = eng.register(tiny("net-a", 1));
        let b = eng.register(tiny("net-b", 2));
        let mut rng = Prng::new(3);
        let mut trace = Vec::new();
        for (i, m) in [a, a, b, a, b, a, b, b].into_iter().enumerate() {
            trace.push(TraceItem {
                at: i as u64 * 100,
                model: m,
                priority: 0,
                input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
            });
        }
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 8);
        assert_eq!(m.rejected, 0);
        // deploy ran once per model, later dispatches hit the cache
        assert_eq!(m.cache_misses, 2);
        assert!(m.cache_hits >= 1, "hits {}", m.cache_hits);
        assert_eq!(m.cache_entries, 2);
        assert!(m.p50_cycles > 0 && m.p99_cycles >= m.p50_cycles);
        assert!(m.aggregate_macs_per_cycle > 0.0);
        assert_eq!(m.rows.len(), 2);
        assert_eq!(m.rows[0].served + m.rows[1].served, 8);
        // every request completed exactly once
        let mut ids: Vec<u64> = eng.completions().iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let rendered = m.render();
        assert!(rendered.contains("net-a") && rendered.contains("plan cache"));
    }

    #[test]
    fn saturation_rejects_beyond_queue_capacity() {
        let cfg = ServeConfig { queue_capacity: 2, shards: 1, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("sat", 4));
        let mut rng = Prng::new(5);
        let trace: Vec<TraceItem> = (0..6)
            .map(|_| TraceItem {
                at: 0,
                model: a,
                priority: 0,
                input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
            })
            .collect();
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 2);
        assert_eq!(m.rejected, 4);
        assert_eq!(m.peak_queue_depth, 2);
    }

    #[test]
    fn priorities_jump_the_queue() {
        let cfg = ServeConfig { shards: 1, max_batch: 1, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("lo", 6));
        let b = eng.register(tiny("hi", 7));
        let mut rng = Prng::new(8);
        let mk = |model, priority, rng: &mut Prng| TraceItem {
            at: 0,
            model,
            priority,
            input: QTensor::random(&[8, 8, 8], 8, false, rng),
        };
        let trace = vec![mk(a, 0, &mut rng), mk(b, 2, &mut rng)];
        eng.run_trace(trace);
        assert_eq!(eng.completions()[0].model, b, "high priority first");
        assert_eq!(eng.completions()[1].model, a);
    }

    /// Worker count and fast-path setting change wall-clock time only:
    /// the completion stream and fleet metrics are bit-identical.
    #[test]
    fn worker_count_and_fastpath_do_not_change_results() {
        let run = |workers: usize, fastpath: bool| {
            let cfg = ServeConfig { workers, fastpath, ..small_cfg() };
            let mut eng = Engine::new(cfg);
            let a = eng.register(tiny("wk-a", 31));
            let b = eng.register(tiny("wk-b", 32));
            let mut rng = Prng::new(33);
            let trace: Vec<TraceItem> = (0..8)
                .map(|i| TraceItem {
                    at: i as u64 * 50,
                    model: if i % 3 == 0 { b } else { a },
                    priority: (i % 2) as u8,
                    input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
                })
                .collect();
            let m = eng.run_trace(trace);
            let comps: Vec<(u64, usize, usize, u64, u64)> = eng
                .completions()
                .iter()
                .map(|c| (c.id, c.model, c.shard, c.start_cycle, c.finish_cycle))
                .collect();
            (m.span_cycles, m.p99_cycles, comps)
        };
        let base = run(1, false);
        assert_eq!(base, run(4, false), "threading changed results");
        assert_eq!(base, run(0, true), "fast path changed results");
        assert_eq!(base, run(2, true));
    }

    #[test]
    fn batching_amortizes_model_switches() {
        // one shard, two models, interleaved arrivals all queued up-front:
        // batching must group same-model requests, so switches < requests.
        let cfg = ServeConfig { shards: 1, max_batch: 8, ..small_cfg() };
        let mut eng = Engine::new(cfg);
        let a = eng.register(tiny("m-a", 10));
        let b = eng.register(tiny("m-b", 11));
        let mut rng = Prng::new(12);
        let trace: Vec<TraceItem> = [a, b, a, b, a, b]
            .into_iter()
            .map(|m| TraceItem {
                at: 0,
                model: m,
                priority: 0,
                input: QTensor::random(&[8, 8, 8], 8, false, &mut rng),
            })
            .collect();
        let m = eng.run_trace(trace);
        assert_eq!(m.served, 6);
        assert!(
            m.model_switches <= 2,
            "batching should coalesce to one pass per model, got {} switches",
            m.model_switches
        );
        assert!(m.mean_batch >= 2.0, "mean batch {}", m.mean_batch);
    }
}
