"""AOT lowering: jit'd golden models -> HLO *text* -> artifacts/.

HLO text (NOT `.serialize()`): the image's xla_extension 0.5.1 rejects
jax>=0.5's 64-bit-instruction-id protos; the text parser reassigns ids
(see /opt/xla-example/README.md). Each artifact gets a `.meta` sidecar
(key=value) describing the baked shapes so the Rust validator can
regenerate identical inputs.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import matmul_entry

# The artifact grid: one MatMul per paper precision configuration, at a
# shape small enough to compile fast but exercising multiple Pallas tiles.
GRID = [(2, 2), (4, 2), (4, 4), (8, 2), (8, 4), (8, 8)]
M, N, K = 16, 16, 64
SHIFT, OUT_BITS = 8, 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_one(out_dir: str, a_bits: int, w_bits: int) -> str:
    name = f"mpq_matmul_a{a_bits}w{w_bits}"
    fn, args = matmul_entry(M, N, K, a_bits, w_bits, SHIFT, OUT_BITS)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    meta_path = os.path.join(out_dir, f"{name}.meta")
    with open(meta_path, "w") as f:
        f.write(
            f"name={name}\nm={M}\nn={N}\nk={K}\n"
            f"a_bits={a_bits}\nw_bits={w_bits}\n"
            f"out_bits={OUT_BITS}\nshift={SHIFT}\n"
        )
    return hlo_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="also write a marker file")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for a_bits, w_bits in GRID:
        path = build_one(args.out_dir, a_bits, w_bits)
        print(f"wrote {path}")
    if args.out:
        with open(args.out, "w") as f:
            f.write("ok\n")


if __name__ == "__main__":
    main()
