//! The PULP-cluster simulator.
//!
//! A cycle-approximate, functionally-exact model of the system in Fig. 1 of
//! the paper: eight RI5CY-class cores (parameterized by
//! [`crate::isa::IsaVariant`]) sharing a 16-bank 128 kB TCDM through a
//! one-cycle logarithmic interconnect, a non-blocking cluster DMA moving
//! data between L2 and TCDM, and a hardware synchronization unit providing
//! low-overhead barriers.
//!
//! Timing model (RI5CY 4-stage in-order single-issue pipeline):
//! - 1 instruction issued per cycle per core;
//! - 1-cycle load-use penalty (consumer immediately after a load);
//! - TCDM bank conflicts stall the losing cores (round-robin arbitration,
//!   one request per bank per cycle; DMA has lowest priority);
//! - taken branches cost 2 bubble cycles; hardware loops are free;
//! - fused Mac&Load issues the sdotp and performs its NN-RF load in the
//!   write-back stage (one issue slot, one TCDM port use);
//! - barriers clock-gate waiting cores and release one cycle after the
//!   last core arrives.
//!
//! Functional model: exact integer semantics for every instruction — kernel
//! outputs are compared bit-exactly against [`crate::qnn::golden`] and
//! against the AOT JAX/Pallas artifacts through [`crate::runtime`].

pub mod cluster;
pub mod core;
pub mod dma;
pub mod mem;
pub mod mlc;
pub mod stats;

pub use cluster::Cluster;
pub use core::Core;
pub use dma::{Dma, DmaRequest};
pub use mem::{ClusterMem, L2_BASE, TCDM_BASE};
pub use mlc::MlcChannel;
pub use stats::{ClusterStats, CoreStats};
