//! Simulator-in-the-loop autotuner for per-layer deployment plans.
//!
//! The analytic tiler minimizes DMA *traffic* and [`deploy`] pins one
//! kernel lowering and the full cluster width for a whole network — but
//! the quantity that matters is measured **cycles**, and the winner is
//! per-layer: a pointwise conv with few output pixels may run fastest on
//! 4 cores (less TCDM contention, shorter barrier tails), a degenerate
//! geometry may prefer a different tile shape than the traffic optimum,
//! and on a Flex-V core a sw-unpack lowering of a simpler variant can
//! occasionally beat the native mixed-precision kernel. This module
//! searches those axes with the simulator itself in the loop:
//!
//! 1. **Enumerate** candidates per layer: feasible tile shapes from the
//!    tiler ([`enumerate_conv_tilings`], analytic DMA cost as the
//!    search-space pruner), kernel lowerings the target core can execute
//!    ([`IsaVariant::compatible_lowerings`], including sw-unpack
//!    lowerings), and core counts (default {4, 8}).
//! 2. **Measure** each candidate by planning the layer in isolation
//!    (`deploy::plan_layer`) and running its distinct tile structures
//!    through a short [`Cluster`] simulation — exactly the serial
//!    load/kernel/store windows plus double-buffer pipeline
//!    reconstruction of [`run_layer_memoized`], with one shared
//!    [`TileMemo`] so structurally identical candidates cost
//!    identically.
//! 3. **Select** by measured cycles; the analytic DMA cost breaks ties,
//!    and the untuned default — always candidate 0 — wins full ties, so
//!    a tuned plan is *never worse than the analytic plan by the
//!    measured metric* (`tuned_cycles <= default_cycles` per layer, by
//!    construction). With [`TuneConfig::confirm_fidelity`] set, each
//!    non-default winner is additionally re-measured against the
//!    default under the pipeline-accurate core tier
//!    ([`crate::sim::CoreFidelity::Pipeline`]) and discarded if the win
//!    does not survive there — search cheap, confirm accurate.
//!
//! Results land in a [`NetworkTuning`] (one [`LayerTuning`] per node)
//! collected in a [`TuneCache`] keyed like the plan cache
//! ([`PlanKey::for_network`]); [`deploy::deploy_tuned`] consumes it and
//! stamps each plan with the matching [`crate::dory::ExecOverride`].
//!
//! # Determinism
//!
//! Tuning is a pure function of (network, target ISA, memory budget,
//! cluster width, [`TuneConfig`]): candidate order is fixed, every
//! measurement is a deterministic cycle-accurate simulation, and
//! selection is a total order — two runs produce bit-identical
//! [`NetworkTuning`]s, which is what lets the serve engine tune once
//! per model fleet-wide and keeps `serve-bench --tuned` inside the
//! engine's determinism contract. The cache serializes to a plain text
//! format ([`TuneCache::to_text`]) so a tuning can be persisted and
//! reloaded without re-measuring.

use std::collections::BTreeMap;

use super::deploy::{self, w_row_pitch, L2Alloc};
use super::tiler::{buf_bits, dma_cost, enumerate_conv_tilings};
use super::{LayerPlan, MemBudget, PlanKey, TileShape};
use crate::coordinator::{run_layer_memoized, TileMemo};
use crate::isa::IsaVariant;
use crate::kernels::im2col::ConvGeom;
use crate::qnn::layer::{Layer, LayerKind, Network};
use crate::report::artifact::{MetricRow, MetricSource};
use crate::sim::Cluster;

/// Search-space knobs of one tuning run.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// Core counts to try per layer (values above the cluster width are
    /// skipped; the default width is always a candidate).
    pub core_counts: Vec<usize>,
    /// Tile shapes per (layer, lowering), best analytic cost first —
    /// the pruner bounding how much of the tiler's feasible set is
    /// measured.
    pub max_shapes: usize,
    /// Kernel lowerings to try; `None` = everything the target core can
    /// execute ([`IsaVariant::compatible_lowerings`]).
    pub isas: Option<Vec<IsaVariant>>,
    /// Re-confirm each layer's winner under a second core timing tier
    /// ([`crate::sim::CoreFidelity`]) before accepting it. The search
    /// itself always measures on the layer cluster as built (the fast
    /// tier — cheap, memoizable); when this is `Some`, any layer whose
    /// winner is not the untuned default is re-measured against the
    /// default on a separate cluster at the confirm tier, and the win
    /// is discarded if it does not survive there. `None` (the default)
    /// skips the pass entirely. Recorded `tuned_cycles`/
    /// `default_cycles` are always the search-tier numbers, so the
    /// `tuned <= default` invariant and the cache text format are
    /// unchanged.
    pub confirm_fidelity: Option<crate::sim::CoreFidelity>,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig { core_counts: vec![4, 8], max_shapes: 2, isas: None, confirm_fidelity: None }
    }
}

/// The tuned plan of one layer, plus both sides of the measurement that
/// chose it ([`run_layer_memoized`]'s pipeline-reconstructed cycles).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct LayerTuning {
    /// Kernel lowering the layer runs (a compatible lowering of the
    /// deployment target).
    pub isa: IsaVariant,
    /// Cores the layer's programs are generated for.
    pub n_cores: usize,
    /// Conv tile-shape override (`None` = the analytic solver's choice).
    pub shape: Option<TileShape>,
    /// Measured cycles of the selected plan.
    pub tuned_cycles: u64,
    /// Measured cycles of the analytic default plan (same metric);
    /// `tuned_cycles <= default_cycles` always holds.
    pub default_cycles: u64,
}

impl LayerTuning {
    /// Measured cycles saved over the analytic default.
    pub fn gain(&self) -> u64 {
        self.default_cycles - self.tuned_cycles
    }

    /// Human-readable summary of the tuned plan, e.g. `"Flex-V x4, tile
    /// 16x16"` — the `profile --tuned` report uses it to explain each
    /// win alongside the measured stall breakdown.
    pub fn describe(&self) -> String {
        let shape =
            self.shape.map_or(String::new(), |s| format!(", tile {}x{}", s.rows, s.chs));
        format!("{} x{}{}", self.isa, self.n_cores, shape)
    }
}

/// Per-layer tunings of one network, indexed by node id.
#[derive(Clone, PartialEq, Eq, Debug, Default)]
pub struct NetworkTuning {
    pub layers: Vec<LayerTuning>,
}

impl NetworkTuning {
    /// Σ measured cycles of the tuned per-layer plans.
    pub fn total_tuned_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.tuned_cycles).sum()
    }

    /// Σ measured cycles of the analytic default plans.
    pub fn total_default_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.default_cycles).sum()
    }

    /// Layers whose tuned plan measured strictly faster than the
    /// analytic default.
    pub fn improved_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.tuned_cycles < l.default_cycles).count()
    }

    /// Fraction of the default's measured cycles saved (0.0 when
    /// nothing improved).
    pub fn gain_fraction(&self) -> f64 {
        let d = self.total_default_cycles();
        if d == 0 {
            0.0
        } else {
            (d - self.total_tuned_cycles()) as f64 / d as f64
        }
    }
}

/// A [`NetworkTuning`] labelled with its model name — the autotuner's
/// [`MetricSource`] for the `autotune` benchmark artifact. All rows are
/// exact: tuning is a deterministic cycle-accurate measurement.
pub struct TunedModelMetrics<'a> {
    /// Registry name of the tuned model ([`crate::models::MODEL_NAMES`]).
    pub model: &'a str,
    pub tuning: &'a NetworkTuning,
}

impl MetricSource for TunedModelMetrics<'_> {
    fn metric_rows(&self) -> Vec<MetricRow> {
        let p = format!("autotune/{}", self.model);
        vec![
            MetricRow::exact(format!("{p}/layers"), self.tuning.layers.len() as f64, "layers"),
            MetricRow::exact(
                format!("{p}/improved_layers"),
                self.tuning.improved_layers() as f64,
                "layers",
            ),
            MetricRow::exact(
                format!("{p}/default_cycles"),
                self.tuning.total_default_cycles() as f64,
                "cycles",
            ),
            MetricRow::exact(
                format!("{p}/tuned_cycles"),
                self.tuning.total_tuned_cycles() as f64,
                "cycles",
            ),
            MetricRow::exact(
                format!("{p}/saved_percent"),
                self.tuning.gain_fraction() * 100.0,
                "%",
            ),
        ]
    }
}

/// One candidate plan of the per-layer search.
struct Candidate {
    isa: IsaVariant,
    n_cores: usize,
    shape: Option<TileShape>,
    /// Analytic DMA cost (conv shapes only; 0 elsewhere) — the
    /// selection tie-break.
    analytic: u64,
}

/// Plan one layer in isolation: a scratch L2 allocator provides the
/// activation/weight addresses (DMA timing never depends on the L2-side
/// address, so the probe's tile windows cost exactly what the deployed
/// layer's will — see [`PlanKey::for_tile`]).
fn probe_plan(
    l: &Layer,
    isa: IsaVariant,
    budget: &MemBudget,
    shape: Option<TileShape>,
) -> LayerPlan {
    let mut l2 = L2Alloc::new(budget);
    let mut preload = vec![];
    let in_l2 = l2.alloc(l.in_bytes().max(4));
    let in2_l2 = matches!(l.kind, LayerKind::Add { .. } | LayerKind::Concat)
        .then(|| l2.alloc(l.out_bytes().max(4)));
    let out_l2 = l2.alloc(l.out_bytes().max(4));
    deploy::plan_layer(isa, budget, &mut l2, &mut preload, l, 0, in_l2, in2_l2, out_l2, shape)
}

/// Candidate plans of one layer, untuned default first.
fn layer_candidates(
    l: &Layer,
    target: IsaVariant,
    budget: &MemBudget,
    max_cores: usize,
    cfg: &TuneConfig,
) -> Vec<Candidate> {
    let mut cores: Vec<usize> = cfg
        .core_counts
        .iter()
        .copied()
        .filter(|&n| n >= 1 && n <= max_cores)
        .collect();
    if !cores.contains(&max_cores) {
        cores.push(max_cores);
    }
    cores.sort_unstable();
    cores.dedup();
    let default_isas = target.compatible_lowerings().to_vec();
    let isas: Vec<IsaVariant> = cfg
        .isas
        .clone()
        .unwrap_or(default_isas)
        .into_iter()
        .filter(|i| target.compatible_lowerings().contains(i))
        .collect();

    // The untuned default: deployment-wide lowering, full width,
    // analytic tile shape.
    let mut out = vec![Candidate { isa: target, n_cores: max_cores, shape: None, analytic: 0 }];
    // Geometry of conv layers, for per-lowering shape enumeration.
    let conv_geom = match l.kind {
        LayerKind::Conv2d { kh, kw, stride, pad } => {
            let [h, w, cin] = l.in_shape;
            Some(ConvGeom::square(h, w, cin, l.out_shape[2], kh, kw, stride, pad, l.a_bits))
        }
        _ => None,
    };
    // Lowerings only matter where the generators consume them.
    let isa_sensitive = matches!(l.kind, LayerKind::Conv2d { .. } | LayerKind::Linear);
    for &isa in &isas {
        if !isa_sensitive && isa != target {
            continue;
        }
        // Per-lowering conv shapes (the GEMM row pitch — and with it the
        // feasible set — depends on the lowering's buffer width).
        let shapes: Vec<Option<TileShape>> = match &conv_geom {
            Some(g) => {
                let w_pitch = w_row_pitch(g.k(), buf_bits(g, isa), l.w_bits) as usize;
                enumerate_conv_tilings(g, isa, w_pitch, l.quant.out_bits, budget.l1, cfg.max_shapes)
                    .into_iter()
                    .map(Some)
                    .collect()
            }
            None => vec![None],
        };
        if shapes.is_empty() {
            // Nothing fits L1 under this lowering (wider buffers).
            continue;
        }
        for &n_cores in &cores {
            for &shape in &shapes {
                // Skip the candidate structurally identical to the
                // default (for the target lowering the enumerator's
                // first shape *is* the analytic solver's choice).
                let is_default_shape = match (&conv_geom, shape) {
                    (None, None) => true,
                    (Some(_), s) => s == shapes[0],
                    _ => false,
                };
                if isa == target && n_cores == max_cores && is_default_shape {
                    continue;
                }
                let analytic = match (&conv_geom, shape) {
                    (Some(g), Some(s)) => {
                        let w_pitch = w_row_pitch(g.k(), buf_bits(g, isa), l.w_bits) as usize;
                        dma_cost(g, w_pitch, l.quant.out_bits, s)
                    }
                    _ => 0,
                };
                out.push(Candidate { isa, n_cores, shape, analytic });
            }
        }
    }
    out
}

/// Tune every layer of `net` for a `max_cores`-wide cluster of `target`
/// cores under `budget`. Deterministic (see the module docs); the
/// result feeds [`deploy::deploy_tuned`].
pub fn tune_network(
    net: &Network,
    target: IsaVariant,
    budget: MemBudget,
    max_cores: usize,
    cfg: &TuneConfig,
) -> NetworkTuning {
    net.validate().expect("invalid network");
    let mut cluster = Cluster::new(max_cores);
    let mut memo = TileMemo::new();
    // Confirm tier: a separate cluster (and a separate memo — TileMemo
    // keys assume a single timing tier per memo) that re-measures
    // non-default winners under `cfg.confirm_fidelity`.
    let mut confirm: Option<(Cluster, TileMemo)> =
        cfg.confirm_fidelity.map(|f| (Cluster::with_fidelity(max_cores, f), TileMemo::new()));
    let mut layers = Vec::with_capacity(net.nodes.len());
    for node in &net.nodes {
        let l = &node.layer;
        let cands = layer_candidates(l, target, &budget, max_cores, cfg);
        // Plans depend only on (lowering, shape) — build each once
        // (weight serialization dominates plan cost) and measure it at
        // every candidate core count.
        let mut plans: Vec<((IsaVariant, Option<TileShape>), LayerPlan)> = Vec::new();
        let mut measured = Vec::with_capacity(cands.len());
        for c in &cands {
            let key = (c.isa, c.shape);
            if !plans.iter().any(|(k, _)| *k == key) {
                plans.push((key, probe_plan(l, c.isa, &budget, c.shape)));
            }
            let plan = &plans.iter().find(|(k, _)| *k == key).expect("just inserted").1;
            let cycles =
                run_layer_memoized(&mut cluster, c.isa, plan, c.n_cores, &mut memo).cycles;
            measured.push(cycles);
        }
        // Select by (measured cycles, analytic cost); the default is
        // candidate 0, so it survives exact ties.
        let mut best = 0;
        for i in 1..cands.len() {
            if (measured[i], cands[i].analytic) < (measured[best], cands[best].analytic) {
                best = i;
            }
        }
        // Confirm pass: a non-default winner must also beat the default
        // when both are re-measured at the confirm tier, else the layer
        // keeps the untuned default (a tie at the confirm tier keeps
        // the win — the search tier already broke it).
        if best != 0 {
            if let Some((ccl, cmemo)) = confirm.as_mut() {
                let plan_of = |c: &Candidate| {
                    &plans.iter().find(|(k, _)| *k == (c.isa, c.shape)).expect("measured").1
                };
                let d =
                    run_layer_memoized(ccl, cands[0].isa, plan_of(&cands[0]), cands[0].n_cores, cmemo)
                        .cycles;
                let w = run_layer_memoized(
                    ccl,
                    cands[best].isa,
                    plan_of(&cands[best]),
                    cands[best].n_cores,
                    cmemo,
                )
                .cycles;
                if w > d {
                    best = 0;
                }
            }
        }
        let c = &cands[best];
        layers.push(LayerTuning {
            isa: c.isa,
            n_cores: c.n_cores,
            shape: c.shape,
            tuned_cycles: measured[best],
            default_cycles: measured[0],
        });
    }
    NetworkTuning { layers }
}

/// Fleet-wide store of [`NetworkTuning`]s keyed like the serve plan
/// cache ([`PlanKey::for_network`]), with hit/miss accounting and a
/// deterministic text serialization (`BTreeMap` ⇒ stable iteration and
/// output order).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TuneCache {
    map: BTreeMap<u64, NetworkTuning>,
    pub hits: u64,
    pub misses: u64,
}

/// Stable lowercase token of a variant for the text format (parsed back
/// by [`IsaVariant::from_name`]).
fn isa_token(isa: IsaVariant) -> &'static str {
    match isa {
        IsaVariant::Ri5cy => "ri5cy",
        IsaVariant::Mpic => "mpic",
        IsaVariant::XpulpNn => "xpulpnn",
        IsaVariant::FlexV => "flexv",
    }
}

impl TuneCache {
    pub fn new() -> Self {
        TuneCache::default()
    }

    /// Look up a tuning by its plan identity.
    pub fn get(&self, key: PlanKey) -> Option<&NetworkTuning> {
        self.map.get(&key.raw())
    }

    /// Look up `key`, running (and caching) the tuner on a miss — the
    /// serve engine's once-per-model entry point.
    pub fn get_or_tune(
        &mut self,
        key: PlanKey,
        tune: impl FnOnce() -> NetworkTuning,
    ) -> &NetworkTuning {
        if self.map.contains_key(&key.raw()) {
            self.hits += 1;
        } else {
            self.misses += 1;
            let t = tune();
            self.map.insert(key.raw(), t);
        }
        self.map.get(&key.raw()).expect("just inserted")
    }

    pub fn insert(&mut self, key: PlanKey, t: NetworkTuning) {
        self.map.insert(key.raw(), t);
    }

    /// Warm-migrate every tuning from `other`, overwriting same-key
    /// entries (live rollout: install tunings computed off-path without
    /// re-running the tuner). Accounting counters are untouched.
    pub fn warm_from(&mut self, other: &TuneCache) {
        for (k, t) in &other.map {
            self.map.insert(*k, t.clone());
        }
    }

    /// Distinct tuned networks resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Iterate (raw plan key, tuning) in stable key order.
    pub fn entries(&self) -> impl Iterator<Item = (u64, &NetworkTuning)> {
        self.map.iter().map(|(&k, v)| (k, v))
    }

    /// Serialize to the line-based text format:
    ///
    /// ```text
    /// flexv-tune-cache v1
    /// net <plan-key-hex> <layer-count>
    /// layer <node> <isa> <cores> <rows>x<chs>|- <tuned-cycles> <default-cycles>
    /// ```
    pub fn to_text(&self) -> String {
        let mut out = String::from("flexv-tune-cache v1\n");
        for (key, net) in &self.map {
            out.push_str(&format!("net {key:016x} {}\n", net.layers.len()));
            for (i, l) in net.layers.iter().enumerate() {
                let shape = match l.shape {
                    Some(s) => format!("{}x{}", s.rows, s.chs),
                    None => "-".to_string(),
                };
                out.push_str(&format!(
                    "layer {i} {} {} {shape} {} {}\n",
                    isa_token(l.isa),
                    l.n_cores,
                    l.tuned_cycles,
                    l.default_cycles
                ));
            }
        }
        out
    }

    /// Parse the [`TuneCache::to_text`] format (accounting counters
    /// start at zero).
    pub fn from_text(s: &str) -> Result<TuneCache, String> {
        let mut lines = s.lines().filter(|l| !l.trim().is_empty());
        match lines.next() {
            Some("flexv-tune-cache v1") => {}
            other => return Err(format!("bad header: {other:?}")),
        }
        let mut cache = TuneCache::new();
        let mut cur: Option<(u64, usize, Vec<LayerTuning>)> = None;
        fn flush(
            cur: &mut Option<(u64, usize, Vec<LayerTuning>)>,
            cache: &mut TuneCache,
        ) -> Result<(), String> {
            if let Some((key, want, layers)) = cur.take() {
                if layers.len() != want {
                    return Err(format!(
                        "net {key:016x}: {} layers, expected {want}",
                        layers.len()
                    ));
                }
                cache.map.insert(key, NetworkTuning { layers });
            }
            Ok(())
        }
        for line in lines {
            let f: Vec<&str> = line.split_whitespace().collect();
            match f.first().copied() {
                Some("net") if f.len() == 3 => {
                    flush(&mut cur, &mut cache)?;
                    let key = u64::from_str_radix(f[1], 16)
                        .map_err(|e| format!("bad plan key '{}': {e}", f[1]))?;
                    let n: usize =
                        f[2].parse().map_err(|e| format!("bad layer count '{}': {e}", f[2]))?;
                    cur = Some((key, n, Vec::with_capacity(n)));
                }
                Some("layer") if f.len() == 7 => {
                    let (_, _, layers) =
                        cur.as_mut().ok_or_else(|| "layer line before net line".to_string())?;
                    let isa = IsaVariant::from_name(f[2])
                        .ok_or_else(|| format!("unknown isa '{}'", f[2]))?;
                    let n_cores: usize =
                        f[3].parse().map_err(|e| format!("bad cores '{}': {e}", f[3]))?;
                    if n_cores == 0 {
                        return Err(format!("layer {}: zero cores", f[1]));
                    }
                    let shape = if f[4] == "-" {
                        None
                    } else {
                        let (r, c) = f[4]
                            .split_once('x')
                            .ok_or_else(|| format!("bad shape '{}'", f[4]))?;
                        Some(TileShape {
                            rows: r.parse().map_err(|e| format!("bad rows '{r}': {e}"))?,
                            chs: c.parse().map_err(|e| format!("bad chs '{c}': {e}"))?,
                        })
                    };
                    if let Some(s) = shape {
                        if s.rows == 0 || s.chs == 0 || s.chs % 4 != 0 {
                            return Err(format!("layer {}: invalid shape {s:?}", f[1]));
                        }
                    }
                    let tuned_cycles: u64 =
                        f[5].parse().map_err(|e| format!("bad cycles '{}': {e}", f[5]))?;
                    let default_cycles: u64 =
                        f[6].parse().map_err(|e| format!("bad cycles '{}': {e}", f[6]))?;
                    if tuned_cycles > default_cycles {
                        return Err(format!(
                            "layer {}: tuned {tuned_cycles} > default {default_cycles}",
                            f[1]
                        ));
                    }
                    layers.push(LayerTuning { isa, n_cores, shape, tuned_cycles, default_cycles });
                }
                other => return Err(format!("bad line: {other:?} in '{line}'")),
            }
        }
        flush(&mut cur, &mut cache)?;
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;
    use crate::dory::deploy::{deploy, deploy_tuned};
    use crate::qnn::{golden, Layer, QTensor};
    use crate::util::Prng;

    fn small_net(seed: u64) -> Network {
        let mut rng = Prng::new(seed);
        let mut net = Network::new("tune-small", [10, 10, 8], 8);
        net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net
    }

    #[test]
    fn tuning_is_deterministic_and_never_worse_per_layer() {
        let net = small_net(31);
        let cfg = TuneConfig::default();
        let a = tune_network(&net, IsaVariant::FlexV, MemBudget::default(), 8, &cfg);
        let b = tune_network(&net, IsaVariant::FlexV, MemBudget::default(), 8, &cfg);
        assert_eq!(a, b, "tuning must be a pure function of its inputs");
        assert_eq!(a.layers.len(), net.nodes.len());
        for (i, l) in a.layers.iter().enumerate() {
            assert!(
                l.tuned_cycles <= l.default_cycles,
                "layer {i}: tuned {} > default {}",
                l.tuned_cycles,
                l.default_cycles
            );
            assert!(l.n_cores >= 1 && l.n_cores <= 8);
            assert!(
                IsaVariant::FlexV.compatible_lowerings().contains(&l.isa),
                "layer {i}: {:?} not executable on Flex-V",
                l.isa
            );
        }
        assert!(a.total_tuned_cycles() <= a.total_default_cycles());
    }

    #[test]
    fn pipeline_confirm_is_deterministic_and_keeps_invariants() {
        use crate::sim::CoreFidelity;
        let net = small_net(36);
        let cfg = TuneConfig {
            confirm_fidelity: Some(CoreFidelity::Pipeline),
            ..TuneConfig::default()
        };
        let a = tune_network(&net, IsaVariant::FlexV, MemBudget::default(), 8, &cfg);
        let b = tune_network(&net, IsaVariant::FlexV, MemBudget::default(), 8, &cfg);
        assert_eq!(a, b, "confirmed tuning must stay a pure function of its inputs");
        // Recorded numbers are search-tier (fast) measurements, so the
        // cache invariant holds regardless of confirm outcomes...
        for (i, l) in a.layers.iter().enumerate() {
            assert!(l.tuned_cycles <= l.default_cycles, "layer {i}");
        }
        // ...and the text format roundtrips unchanged.
        let key = PlanKey::for_network(&net, IsaVariant::FlexV, MemBudget::default(), 8);
        let mut cache = TuneCache::new();
        cache.insert(key, a.clone());
        let parsed = TuneCache::from_text(&cache.to_text()).expect("roundtrip");
        assert_eq!(parsed.get(key), Some(&a));
        // Every confirmed winner deploys bit-exactly.
        let mut rng = Prng::new(37);
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let golden_out = golden::run_network(&net, &input);
        let dep = deploy_tuned(&net, IsaVariant::FlexV, MemBudget::default(), &a);
        let mut coord = Coordinator::new(8);
        assert_eq!(coord.run(&dep, &input).output, golden_out.last().unwrap().data);
    }

    #[test]
    fn deploy_tuned_is_bit_exact_and_carries_overrides() {
        let net = small_net(32);
        let mut rng = Prng::new(33);
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let golden_out = golden::run_network(&net, &input);
        let tuning =
            tune_network(&net, IsaVariant::FlexV, MemBudget::default(), 8, &TuneConfig::default());
        let dep = deploy_tuned(&net, IsaVariant::FlexV, MemBudget::default(), &tuning);
        for (plan, t) in dep.plans.iter().zip(&tuning.layers) {
            let e = plan.exec.expect("tuned plans carry an exec override");
            assert_eq!((e.isa, e.n_cores), (t.isa, t.n_cores), "{}", plan.name);
        }
        let mut coord = Coordinator::new(8);
        let res = coord.run(&dep, &input);
        assert_eq!(res.output, golden_out.last().unwrap().data, "tuned output != golden");
        // and the analytic deployment still matches too (sanity)
        let dep0 = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let mut coord0 = Coordinator::new(8);
        assert_eq!(coord0.run(&dep0, &input).output, res.output);
    }

    #[test]
    fn tune_cache_counts_and_roundtrips_through_text() {
        let net = small_net(34);
        let key = PlanKey::for_network(&net, IsaVariant::FlexV, MemBudget::default(), 8);
        let mut cache = TuneCache::new();
        let mut runs = 0;
        for _ in 0..3 {
            cache.get_or_tune(key, || {
                runs += 1;
                tune_network(
                    &net,
                    IsaVariant::FlexV,
                    MemBudget::default(),
                    8,
                    &TuneConfig::default(),
                )
            });
        }
        assert_eq!(runs, 1, "tuner must run once per key");
        assert_eq!((cache.hits, cache.misses, cache.len()), (2, 1, 1));

        let text = cache.to_text();
        let parsed = TuneCache::from_text(&text).expect("roundtrip");
        assert_eq!(parsed.get(key), cache.get(key));
        assert_eq!(parsed.to_text(), text);

        // malformed or semantically invalid inputs are rejected
        assert!(TuneCache::from_text("nope").is_err());
        assert!(TuneCache::from_text("flexv-tune-cache v1\nlayer 0 flexv 8 - 1 1").is_err());
        assert!(TuneCache::from_text("flexv-tune-cache v1\nnet 00 2\nlayer 0 flexv 8 - 1 1")
            .is_err());
        let bad = [
            "layer 0 flexv 0 - 1 1",    // zero cores
            "layer 0 flexv 8 0x16 1 1", // zero tile rows
            "layer 0 flexv 8 4x6 1 1",  // channel tile not a multiple of 4
            "layer 0 flexv 8 - 2 1",    // tuned worse than default
        ];
        for line in bad {
            let text = format!("flexv-tune-cache v1\nnet 00 1\n{line}");
            assert!(TuneCache::from_text(&text).is_err(), "accepted: {line}");
        }
    }

    #[test]
    fn shape_override_feeds_the_planner() {
        // A layer big enough to have several feasible channel tiles:
        // force a non-default shape through deploy_tuned and check the
        // tile structure follows it.
        let mut rng = Prng::new(35);
        let mut net = Network::new("shape-ovr", [16, 16, 16], 8);
        net.push(Layer::conv("c", [16, 16, 16], 32, 3, 3, 1, 1, 8, 8, 8, &mut rng));
        let shape = TileShape { rows: 16, chs: 16 };
        let tuning = NetworkTuning {
            layers: vec![LayerTuning {
                isa: IsaVariant::FlexV,
                n_cores: 8,
                shape: Some(shape),
                tuned_cycles: 0,
                default_cycles: 0,
            }],
        };
        let dep = deploy_tuned(&net, IsaVariant::FlexV, MemBudget::default(), &tuning);
        assert_eq!(dep.plans[0].tiles.len(), 2, "chs=16 of 32 → two channel tiles");
        // still bit-exact
        let input = QTensor::random(&[16, 16, 16], 8, false, &mut rng);
        let golden_out = golden::run_network(&net, &input);
        let mut coord = Coordinator::new(8);
        assert_eq!(coord.run(&dep, &input).output, golden_out.last().unwrap().data);
    }
}
