//! Cluster memory map and functional storage.
//!
//! | region | base        | size    | who accesses it            |
//! |--------|-------------|---------|----------------------------|
//! | TCDM   | 0x1000_0000 | 128 kB  | cores (1-cycle), DMA       |
//! | L2     | 0x1C00_0000 | 1.5 MB  | DMA only (cores never touch the request path of L2 in DORY-deployed code) |
//!
//! The byte-granular storage is shared by all cores; bank index for
//! arbitration is word-interleaved across 16 banks exactly like the PULP
//! logarithmic interconnect.

use std::collections::HashMap;

use crate::{L2_BYTES, TCDM_BANKS, TCDM_BYTES};

pub const TCDM_BASE: u32 = 0x1000_0000;
pub const L2_BASE: u32 = 0x1C00_0000;

/// Byte-granular access trace of one simulation window, recorded while
/// the steady-state fast path measures a window it has not seen before
/// (see [`crate::sim::fastpath`]).
///
/// Storage is 64-byte blocks with one mask bit per byte. `reads` holds
/// only bytes read **before** any write of the window — the window's
/// external input footprint; `read_vals` captures their pre-window
/// values so the recorded entry can later be validated against the
/// current memory image (a DMA write overlapping the footprint changes
/// the hash and invalidates pure replay). `writes` is the window's
/// functional effect delta.
#[derive(Clone, Debug, Default)]
pub struct AccessTrace {
    reads: HashMap<u32, u64>,
    read_vals: HashMap<u32, [u8; 64]>,
    writes: HashMap<u32, u64>,
}

impl AccessTrace {
    /// Record a read of `bytes` starting at `addr`. Bytes already
    /// written this window are internal and excluded from the footprint.
    pub fn record_read(&mut self, addr: u32, bytes: &[u8]) {
        for (i, &b) in bytes.iter().enumerate() {
            let a = addr + i as u32;
            let blk = a >> 6;
            let bit = 1u64 << (a & 63);
            if self.writes.get(&blk).map_or(false, |w| w & bit != 0) {
                continue;
            }
            let m = self.reads.entry(blk).or_insert(0);
            if *m & bit == 0 {
                *m |= bit;
                self.read_vals.entry(blk).or_insert([0; 64])[(a & 63) as usize] = b;
            }
        }
    }

    /// Record a write of `len` bytes starting at `addr`.
    pub fn record_write(&mut self, addr: u32, len: u32) {
        for i in 0..len {
            let a = addr + i;
            *self.writes.entry(a >> 6).or_insert(0) |= 1u64 << (a & 63);
        }
    }

    fn ranges(map: &HashMap<u32, u64>) -> Vec<(u32, u32)> {
        let mut blocks: Vec<(u32, u64)> = map.iter().map(|(b, m)| (*b, *m)).collect();
        blocks.sort_unstable_by_key(|(b, _)| *b);
        let mut out: Vec<(u32, u32)> = Vec::new();
        for (blk, mask) in blocks {
            for bit in 0..64u32 {
                if mask & (1u64 << bit) != 0 {
                    let a = (blk << 6) + bit;
                    match out.last_mut() {
                        Some((start, len)) if *start + *len == a => *len += 1,
                        _ => out.push((a, 1)),
                    }
                }
            }
        }
        out
    }

    /// Footprint byte ranges `(addr, len)`, ascending and coalesced.
    pub fn read_ranges(&self) -> Vec<(u32, u32)> {
        Self::ranges(&self.reads)
    }

    /// Written byte ranges `(addr, len)`, ascending and coalesced.
    pub fn write_ranges(&self) -> Vec<(u32, u32)> {
        Self::ranges(&self.writes)
    }

    /// Hash of the captured **pre-window** contents of the read
    /// footprint, comparable with the fast path's `hash_mem_ranges`
    /// over a live memory image.
    pub fn read_hash(&self) -> u64 {
        use std::hash::Hasher;
        let mut h = std::collections::hash_map::DefaultHasher::new();
        let mut buf = Vec::new();
        for (addr, len) in self.read_ranges() {
            h.write_u32(addr);
            h.write_u32(len);
            buf.clear();
            for a in addr..addr + len {
                buf.push(self.read_vals[&(a >> 6)][(a & 63) as usize]);
            }
            h.write(&buf);
        }
        h.finish()
    }
}

/// Functional memory of the cluster.
#[derive(Clone)]
pub struct ClusterMem {
    pub tcdm: Vec<u8>,
    pub l2: Vec<u8>,
    /// Access trace, active only while the fast path records a window.
    pub(crate) trace: Option<Box<AccessTrace>>,
}

impl Default for ClusterMem {
    fn default() -> Self {
        Self::new()
    }
}

impl ClusterMem {
    pub fn new() -> Self {
        ClusterMem { tcdm: vec![0; TCDM_BYTES], l2: vec![0; L2_BYTES], trace: None }
    }

    /// TCDM bank serving a byte address (word-interleaved).
    pub fn bank_of(addr: u32) -> usize {
        debug_assert!(Self::is_tcdm(addr), "bank_of on non-TCDM address {addr:#x}");
        ((addr - TCDM_BASE) as usize >> 2) % TCDM_BANKS
    }

    pub fn is_tcdm(addr: u32) -> bool {
        (TCDM_BASE..TCDM_BASE + TCDM_BYTES as u32).contains(&addr)
    }

    pub fn is_l2(addr: u32) -> bool {
        (L2_BASE..L2_BASE + L2_BYTES as u32).contains(&addr)
    }

    fn slice(&self, addr: u32, len: usize) -> &[u8] {
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            &self.tcdm[o..o + len]
        } else if Self::is_l2(addr) {
            let o = (addr - L2_BASE) as usize;
            &self.l2[o..o + len]
        } else {
            panic!("unmapped address {addr:#010x}");
        }
    }

    fn slice_mut(&mut self, addr: u32, len: usize) -> &mut [u8] {
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            &mut self.tcdm[o..o + len]
        } else if Self::is_l2(addr) {
            let o = (addr - L2_BASE) as usize;
            &mut self.l2[o..o + len]
        } else {
            panic!("unmapped address {addr:#010x}");
        }
    }

    #[inline]
    pub fn load_u32(&self, addr: u32) -> u32 {
        // Fast path: TCDM (every core access in DORY-deployed code).
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            let b = &self.tcdm[o..o + 4];
            return u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        let b = self.slice(addr, 4);
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    #[inline]
    pub fn store_u32(&mut self, addr: u32, v: u32) {
        if let Some(t) = self.trace.as_deref_mut() {
            t.record_write(addr, 4);
        }
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            self.tcdm[o..o + 4].copy_from_slice(&v.to_le_bytes());
            return;
        }
        self.slice_mut(addr, 4).copy_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn load_u8(&self, addr: u32) -> u8 {
        if Self::is_tcdm(addr) {
            return self.tcdm[(addr - TCDM_BASE) as usize];
        }
        self.slice(addr, 1)[0]
    }

    #[inline]
    pub fn store_u8(&mut self, addr: u32, v: u8) {
        if let Some(t) = self.trace.as_deref_mut() {
            t.record_write(addr, 1);
        }
        if Self::is_tcdm(addr) {
            self.tcdm[(addr - TCDM_BASE) as usize] = v;
            return;
        }
        self.slice_mut(addr, 1)[0] = v;
    }

    /// [`Self::load_u32`] plus fast-path read tracing (core load path).
    #[inline]
    pub(crate) fn traced_load_u32(&mut self, addr: u32) -> u32 {
        let v = self.load_u32(addr);
        if let Some(t) = self.trace.as_deref_mut() {
            t.record_read(addr, &v.to_le_bytes());
        }
        v
    }

    /// [`Self::load_u8`] plus fast-path read tracing (core load path).
    #[inline]
    pub(crate) fn traced_load_u8(&mut self, addr: u32) -> u8 {
        let v = self.load_u8(addr);
        if let Some(t) = self.trace.as_deref_mut() {
            t.record_read(addr, &[v]);
        }
        v
    }

    /// One DMA beat: copy `len` (≤ 8) bytes from `src` to `dst`,
    /// recording both sides on the active trace.
    pub(crate) fn dma_copy(&mut self, src: u32, dst: u32, len: usize) {
        debug_assert!(len <= 8);
        let mut buf = [0u8; 8];
        buf[..len].copy_from_slice(self.slice(src, len));
        if let Some(t) = self.trace.as_deref_mut() {
            t.record_read(src, &buf[..len]);
            t.record_write(dst, len as u32);
        }
        self.slice_mut(dst, len).copy_from_slice(&buf[..len]);
    }

    /// Bulk copy for the fast path's functional DMA completion (whole
    /// rows at once, no per-beat cycle model).
    pub(crate) fn copy_range(&mut self, src: u32, dst: u32, len: u32) {
        let tmp = self.slice(src, len as usize).to_vec();
        if let Some(t) = self.trace.as_deref_mut() {
            t.record_read(src, &tmp);
            t.record_write(dst, len);
        }
        self.slice_mut(dst, len as usize).copy_from_slice(&tmp);
    }

    /// Borrow `len` bytes at `addr` (fast-path hashing and recording).
    pub(crate) fn bytes(&self, addr: u32, len: usize) -> &[u8] {
        self.slice(addr, len)
    }

    /// Bulk write (test/coordinator setup path, not timed).
    pub fn write_bytes(&mut self, addr: u32, bytes: &[u8]) {
        self.slice_mut(addr, bytes.len()).copy_from_slice(bytes);
    }

    /// Bulk read (test/coordinator readback path, not timed).
    pub fn read_bytes(&self, addr: u32, len: usize) -> Vec<u8> {
        self.slice(addr, len).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_interleaved_banks() {
        assert_eq!(ClusterMem::bank_of(TCDM_BASE), 0);
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 4), 1);
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 4 * 15), 15);
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 4 * 16), 0);
        // sub-word addresses hit the same bank as their word
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 2), 0);
    }

    #[test]
    fn load_store_roundtrip_both_regions() {
        let mut m = ClusterMem::new();
        m.store_u32(TCDM_BASE + 64, 0xDEAD_BEEF);
        assert_eq!(m.load_u32(TCDM_BASE + 64), 0xDEAD_BEEF);
        m.store_u32(L2_BASE + 128, 0x1234_5678);
        assert_eq!(m.load_u32(L2_BASE + 128), 0x1234_5678);
        m.store_u8(TCDM_BASE, 0xAB);
        assert_eq!(m.load_u8(TCDM_BASE), 0xAB);
    }

    #[test]
    fn little_endian_storage() {
        let mut m = ClusterMem::new();
        m.store_u32(TCDM_BASE, 0x0403_0201);
        assert_eq!(m.load_u8(TCDM_BASE), 0x01);
        assert_eq!(m.load_u8(TCDM_BASE + 3), 0x04);
    }

    #[test]
    #[should_panic(expected = "unmapped")]
    fn unmapped_access_panics() {
        ClusterMem::new().load_u32(0x4000_0000);
    }
}
