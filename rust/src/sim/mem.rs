//! Cluster memory map and functional storage.
//!
//! | region | base        | size    | who accesses it            |
//! |--------|-------------|---------|----------------------------|
//! | TCDM   | 0x1000_0000 | 128 kB  | cores (1-cycle), DMA       |
//! | L2     | 0x1C00_0000 | 1.5 MB  | DMA only (cores never touch the request path of L2 in DORY-deployed code) |
//!
//! The byte-granular storage is shared by all cores; bank index for
//! arbitration is word-interleaved across 16 banks exactly like the PULP
//! logarithmic interconnect.

use crate::{L2_BYTES, TCDM_BANKS, TCDM_BYTES};

pub const TCDM_BASE: u32 = 0x1000_0000;
pub const L2_BASE: u32 = 0x1C00_0000;

/// Functional memory of the cluster.
#[derive(Clone)]
pub struct ClusterMem {
    pub tcdm: Vec<u8>,
    pub l2: Vec<u8>,
}

impl Default for ClusterMem {
    fn default() -> Self {
        Self::new()
    }
}

impl ClusterMem {
    pub fn new() -> Self {
        ClusterMem { tcdm: vec![0; TCDM_BYTES], l2: vec![0; L2_BYTES] }
    }

    /// TCDM bank serving a byte address (word-interleaved).
    pub fn bank_of(addr: u32) -> usize {
        debug_assert!(Self::is_tcdm(addr), "bank_of on non-TCDM address {addr:#x}");
        ((addr - TCDM_BASE) as usize >> 2) % TCDM_BANKS
    }

    pub fn is_tcdm(addr: u32) -> bool {
        (TCDM_BASE..TCDM_BASE + TCDM_BYTES as u32).contains(&addr)
    }

    pub fn is_l2(addr: u32) -> bool {
        (L2_BASE..L2_BASE + L2_BYTES as u32).contains(&addr)
    }

    fn slice(&self, addr: u32, len: usize) -> &[u8] {
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            &self.tcdm[o..o + len]
        } else if Self::is_l2(addr) {
            let o = (addr - L2_BASE) as usize;
            &self.l2[o..o + len]
        } else {
            panic!("unmapped address {addr:#010x}");
        }
    }

    fn slice_mut(&mut self, addr: u32, len: usize) -> &mut [u8] {
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            &mut self.tcdm[o..o + len]
        } else if Self::is_l2(addr) {
            let o = (addr - L2_BASE) as usize;
            &mut self.l2[o..o + len]
        } else {
            panic!("unmapped address {addr:#010x}");
        }
    }

    #[inline]
    pub fn load_u32(&self, addr: u32) -> u32 {
        // Fast path: TCDM (every core access in DORY-deployed code).
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            let b = &self.tcdm[o..o + 4];
            return u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        let b = self.slice(addr, 4);
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    #[inline]
    pub fn store_u32(&mut self, addr: u32, v: u32) {
        if Self::is_tcdm(addr) {
            let o = (addr - TCDM_BASE) as usize;
            self.tcdm[o..o + 4].copy_from_slice(&v.to_le_bytes());
            return;
        }
        self.slice_mut(addr, 4).copy_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn load_u8(&self, addr: u32) -> u8 {
        if Self::is_tcdm(addr) {
            return self.tcdm[(addr - TCDM_BASE) as usize];
        }
        self.slice(addr, 1)[0]
    }

    #[inline]
    pub fn store_u8(&mut self, addr: u32, v: u8) {
        if Self::is_tcdm(addr) {
            self.tcdm[(addr - TCDM_BASE) as usize] = v;
            return;
        }
        self.slice_mut(addr, 1)[0] = v;
    }

    /// Bulk write (test/coordinator setup path, not timed).
    pub fn write_bytes(&mut self, addr: u32, bytes: &[u8]) {
        self.slice_mut(addr, bytes.len()).copy_from_slice(bytes);
    }

    /// Bulk read (test/coordinator readback path, not timed).
    pub fn read_bytes(&self, addr: u32, len: usize) -> Vec<u8> {
        self.slice(addr, len).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_interleaved_banks() {
        assert_eq!(ClusterMem::bank_of(TCDM_BASE), 0);
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 4), 1);
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 4 * 15), 15);
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 4 * 16), 0);
        // sub-word addresses hit the same bank as their word
        assert_eq!(ClusterMem::bank_of(TCDM_BASE + 2), 0);
    }

    #[test]
    fn load_store_roundtrip_both_regions() {
        let mut m = ClusterMem::new();
        m.store_u32(TCDM_BASE + 64, 0xDEAD_BEEF);
        assert_eq!(m.load_u32(TCDM_BASE + 64), 0xDEAD_BEEF);
        m.store_u32(L2_BASE + 128, 0x1234_5678);
        assert_eq!(m.load_u32(L2_BASE + 128), 0x1234_5678);
        m.store_u8(TCDM_BASE, 0xAB);
        assert_eq!(m.load_u8(TCDM_BASE), 0xAB);
    }

    #[test]
    fn little_endian_storage() {
        let mut m = ClusterMem::new();
        m.store_u32(TCDM_BASE, 0x0403_0201);
        assert_eq!(m.load_u8(TCDM_BASE), 0x01);
        assert_eq!(m.load_u8(TCDM_BASE + 3), 0x04);
    }

    #[test]
    #[should_panic(expected = "unmapped")]
    fn unmapped_access_panics() {
        ClusterMem::new().load_u32(0x4000_0000);
    }
}
