//! The tiling solver — the Constraint-Programming piece of DORY extended
//! with the paper's sub-byte constraints (§IV):
//!
//! - the working set of a tile (input strip + weight tile + output tile +
//!   quant parameters, all double-buffered, plus the im2col scratch) must
//!   fit the L1 budget;
//! - the convolutional loop's innermost dimensions must stay byte-aligned:
//!   channel tiles are multiples of 4 (requant packing) and
//!   `chs * out_bits % 8 == 0`;
//! - objective: minimize total DMA traffic (input strips are re-fetched
//!   once per row strip; weight tiles once per (row strip × channel tile)).

use crate::isa::IsaVariant;
use crate::kernels::im2col::ConvGeom;

/// A tile shape: output rows per strip × output channels per tile.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct TileShape {
    pub rows: usize,
    pub chs: usize,
}

/// Working-set bytes of one conv tile (single-buffered).
#[derive(Clone, Copy, Debug, Default)]
pub struct TileBytes {
    pub input: usize,
    pub weights: usize,
    pub output: usize,
    pub quant: usize,
}

/// Compute the working set of a conv tile shape.
pub fn conv_tile_bytes(
    g: &ConvGeom,
    w_pitch: usize,
    out_bits: u8,
    shape: TileShape,
) -> TileBytes {
    let in_rows = (shape.rows - 1) * g.stride + g.kh; // worst case strip
    TileBytes {
        input: in_rows * g.w * g.cin * g.a_bits as usize / 8,
        weights: shape.chs * w_pitch,
        output: shape.rows * g.out_w() * shape.chs * out_bits as usize / 8,
        quant: shape.chs * 8,
    }
}

/// Total DMA bytes for a shape — the solver's analytic objective, also
/// used by [`crate::dory::autotune`] to prune and tie-break measured
/// candidates.
pub fn dma_cost(g: &ConvGeom, w_pitch: usize, out_bits: u8, shape: TileShape) -> u64 {
    let oh = g.out_h();
    let row_strips = oh.div_ceil(shape.rows) as u64;
    let ch_tiles = (g.cout.div_ceil(shape.chs)) as u64;
    let tb = conv_tile_bytes(g, w_pitch, out_bits, shape);
    // input strip loaded once per row strip; weights once per (strip × ch
    // tile); output stored once; plus the DMA programming overhead per
    // tile (16 cycles ≈ 128 streamed bytes), which breaks ties in favour
    // of fewer, larger tiles.
    row_strips * tb.input as u64
        + row_strips * ch_tiles * (tb.weights + tb.quant) as u64
        + (oh * g.out_w() * g.cout * out_bits as usize / 8) as u64
        + row_strips * ch_tiles * 128
}

/// Per-core im2col scratch bytes the conv kernel needs on `isa` (the
/// feasibility margin both the solver and the enumerator reserve).
fn conv_scratch(g: &ConvGeom, isa: IsaVariant) -> usize {
    crate::CLUSTER_CORES
        * isa.unroll().buffers
        * ((g.k() * buf_bits(g, isa) as usize).div_ceil(32) * 4)
}

/// Single-buffer working set a shape needs inside `l1_budget`, counting
/// the double-buffering and the per-core scratch.
fn l1_need(g: &ConvGeom, isa: IsaVariant, w_pitch: usize, out_bits: u8, shape: TileShape) -> usize {
    let tb = conv_tile_bytes(g, w_pitch, out_bits, shape);
    2 * (tb.input + tb.weights + tb.output + tb.quant) + conv_scratch(g, isa) + 64
}

/// Solve the conv tiling: returns the cheapest shape that fits.
pub fn solve_conv_tiling(
    g: &ConvGeom,
    isa: IsaVariant,
    w_pitch: usize,
    out_bits: u8,
    l1_budget: usize,
) -> Option<TileShape> {
    enumerate_conv_tilings(g, isa, w_pitch, out_bits, l1_budget, 1)
        .first()
        .copied()
}

/// Enumerate feasible conv tile shapes, best analytic cost first.
///
/// One shape per channel-tile width (the largest row strip that fits:
/// for a fixed `chs`, larger strips strictly dominate on DMA traffic),
/// every one satisfying the sub-byte constraints (`chs % 4 == 0`,
/// `chs * out_bits % 8 == 0`) and the L1 working-set budget. Sorted by
/// ([`dma_cost`], `chs`) and truncated to `max` entries — the
/// [`crate::dory::autotune`] candidate enumerator; `max = 1` recovers
/// exactly the analytic solver's choice.
pub fn enumerate_conv_tilings(
    g: &ConvGeom,
    isa: IsaVariant,
    w_pitch: usize,
    out_bits: u8,
    l1_budget: usize,
    max: usize,
) -> Vec<TileShape> {
    let oh = g.out_h();
    let mut found: Vec<(u64, TileShape)> = Vec::new();
    let mut chs = 4;
    while chs <= g.cout {
        if chs * out_bits as usize % 8 == 0 {
            // largest row strip that fits for this chs
            for rows in (1..=oh).rev() {
                let shape = TileShape { rows, chs };
                if l1_need(g, isa, w_pitch, out_bits, shape) <= l1_budget {
                    found.push((dma_cost(g, w_pitch, out_bits, shape), shape));
                    break; // larger rows always dominate smaller for same chs
                }
            }
        }
        chs += 4;
    }
    found.sort_by_key(|&(cost, s)| (cost, s.chs));
    found.truncate(max);
    found.into_iter().map(|(_, s)| s).collect()
}

/// Buffer width the conv kernel will use on `isa` (8 when expanding).
pub fn buf_bits(g: &ConvGeom, isa: IsaVariant) -> u8 {
    let native = isa
        .native_fmts()
        .contains(&crate::isa::SimdFmt::from_bits(g.a_bits));
    if native {
        g.a_bits
    } else {
        8
    }
}

/// Depthwise tiling: row strips only (channels stay whole — the kernel
/// walks channel groups internally).
pub fn solve_dw_tiling(
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    stride: usize,
    a_bits: u8,
    w_bits: u8,
    out_bits: u8,
    oh: usize,
    l1_budget: usize,
) -> Option<usize> {
    for rows in (1..=oh).rev() {
        let in_rows = (rows - 1) * stride + kh;
        let input = in_rows * w * c * a_bits as usize / 8;
        let weights = kh * kh * c * w_bits as usize / 8;
        let output = rows * w * c * out_bits as usize / 8;
        let quant = c * 8;
        // l1_layout double-buffers every region, so budget accordingly
        if 2 * (input + output + weights + quant) + 64 <= l1_budget {
            let _ = h;
            return Some(rows);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::IsaVariant;
    use crate::util::{proptest, Prng};

    fn fig7_geom() -> ConvGeom {
        ConvGeom::square(16, 16, 32, 64, 3, 3, 1, 1, 8)
    }

    #[test]
    fn fig7_layer_fits_untiled() {
        // The benchmark tile of Fig. 7 fits L1 whole.
        let g = fig7_geom();
        let shape = solve_conv_tiling(&g, IsaVariant::FlexV, 288, 8, 110 * 1024).unwrap();
        assert_eq!(shape.rows, 16, "whole layer should fit: {shape:?}");
        assert_eq!(shape.chs, 64);
    }

    #[test]
    fn large_layer_gets_tiled() {
        // 112x112x24 -> 48 pointwise: too big for L1, must tile rows.
        let g = ConvGeom::square(112, 112, 24, 48, 1, 1, 1, 0, 8);
        let shape = solve_conv_tiling(&g, IsaVariant::FlexV, 24, 8, 110 * 1024).unwrap();
        assert!(shape.rows < 112);
        let tb = conv_tile_bytes(&g, 24, 8, shape);
        assert!(2 * (tb.input + tb.weights + tb.output + tb.quant) <= 110 * 1024);
    }

    #[test]
    fn channel_tile_byte_alignment_subbyte() {
        // 2-bit outputs: chs*2 % 8 == 0 requires chs % 4 == 0 (always true)
        // but also chs multiples of 4 -> any solution is aligned.
        let g = ConvGeom::square(32, 32, 64, 256, 3, 3, 1, 1, 4);
        let shape = solve_conv_tiling(&g, IsaVariant::FlexV, 256 * 2 / 8 * 9, 2, 110 * 1024).unwrap();
        assert_eq!(shape.chs * 2 % 8, 0);
        assert_eq!(shape.chs % 4, 0);
    }

    #[test]
    fn enumerator_is_sorted_and_contains_solver_choice() {
        let g = ConvGeom::square(112, 112, 24, 48, 1, 1, 1, 0, 8);
        let shapes = enumerate_conv_tilings(&g, IsaVariant::FlexV, 24, 8, 110 * 1024, 8);
        assert!(!shapes.is_empty());
        let solved = solve_conv_tiling(&g, IsaVariant::FlexV, 24, 8, 110 * 1024).unwrap();
        assert_eq!(shapes[0], solved, "first candidate must be the analytic optimum");
        let costs: Vec<u64> =
            shapes.iter().map(|&s| dma_cost(&g, 24, 8, s)).collect();
        assert!(costs.windows(2).all(|w| w[0] <= w[1]), "not sorted: {costs:?}");
        // a max of 1 is exactly the solver
        let one = enumerate_conv_tilings(&g, IsaVariant::FlexV, 24, 8, 110 * 1024, 1);
        assert_eq!(one, vec![solved]);
    }

    #[test]
    fn prop_solutions_always_fit_and_align() {
        proptest::check_default(
            |rng: &mut Prng| {
                let h = rng.range(4, 64);
                let cin = rng.range(1, 16) * 4;
                let cout = rng.range(1, 32) * 4;
                let a_bits = *rng.pick(&[2u8, 4, 8]);
                let out_bits = *rng.pick(&[2u8, 4, 8]);
                let k = *rng.pick(&[1usize, 3]);
                let g = ConvGeom::square(h, h, cin, cout, k, k, 1, k / 2, a_bits);
                (g, out_bits)
            },
            |&(g, out_bits)| {
                let w_pitch = (g.k() * 8usize).div_ceil(32) * 4;
                match solve_conv_tiling(&g, IsaVariant::FlexV, w_pitch, out_bits, 110 * 1024) {
                    None => Ok(()), // nothing fits: acceptable outcome
                    Some(shape) => {
                        let tb = conv_tile_bytes(&g, w_pitch, out_bits, shape);
                        let scratch = 8 * 4 * ((g.k() * g.a_bits as usize).div_ceil(32) * 4);
                        let need = 2 * (tb.input + tb.weights + tb.output + tb.quant) + scratch;
                        if need > 110 * 1024 {
                            return Err(format!("{shape:?} does not fit: {need}"));
                        }
                        if shape.chs % 4 != 0 || shape.chs * out_bits as usize % 8 != 0 {
                            return Err(format!("{shape:?} misaligned"));
                        }
                        if shape.rows > g.out_h() || shape.chs > g.cout {
                            return Err(format!("{shape:?} exceeds layer"));
                        }
                        Ok(())
                    }
                }
            },
        );
    }
}
