//! Integration: the three-way cross-validation — AOT JAX/Pallas golden
//! (via PJRT) == Rust golden == simulated Flex-V kernels, bit-exact.
//! Requires `make artifacts`; skips (with a notice) when absent so
//! `cargo test` works before the python step.

#[test]
fn artifacts_three_way_validation() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("mpq_matmul_a8w8.meta").exists() {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts` first");
        return;
    }
    let n = flexv::runtime::validate_artifacts(dir).expect("validation failed");
    assert_eq!(n, 6, "expected all six precision artifacts");
}
