//! Perf-regression gating: compare a freshly measured [`BenchArtifact`]
//! against a committed baseline and render a per-metric drift table.
//!
//! Comparison semantics follow [`MetricKind`]:
//!
//! - `Exact` rows are bit-deterministic simulated quantities; they must
//!   match within `tol_exact_abs` **absolute** units (`--tol-cycles`,
//!   default 0 — i.e. bit-equal after the shortest-round-trip JSON
//!   round trip);
//! - `Analog` rows come from the calibrated energy model; they must
//!   match within the `tol_analog_frac` **relative** band
//!   (`--tol-power`, default 2%).
//!
//! A metric present in the baseline but missing from the current run is
//! a failure (a number silently disappeared); a new current-only metric
//! is reported but does not fail (additive evolution). Baselines marked
//! `pending` carry paper targets instead of measured values: their rows
//! never produce drift (value deltas are the reproduction-distance
//! report's job, [`paper_distance`]), but a pending baseline **fails
//! the gate itself** — an unpinned suite is an unguarded suite, and a
//! silently green gate would hide that indefinitely. Run
//! `regress --bless` and commit `baselines/` to pin measured values;
//! blessing is the only non-failing path through a pending baseline.
//!
//! Baselines are always fast-tier measurements; a pipeline-tier artifact
//! (`bench-report --fidelity pipeline`, see [`crate::sim::pipeline`]) is
//! never compared against them. Instead [`paper_distance`] renders each
//! artifact's own paper-anchored rows, so running the kernels suite once
//! per tier yields a fast-vs-pipeline-vs-paper view of every Table III
//! cell (CI's `pipeline-crosscheck` job prints both).

use std::collections::BTreeMap;

use super::artifact::{BenchArtifact, MetricKind, MetricRow};
use crate::util::table::{f, Table};

/// Comparison tolerances (CLI: `--tol-cycles`, `--tol-power`).
#[derive(Clone, Copy, Debug)]
pub struct Tolerance {
    /// Absolute slack for `Exact` rows (0 = bit-equal).
    pub exact_abs: f64,
    /// Relative slack for `Analog` rows (0.02 = ±2%).
    pub analog_frac: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance { exact_abs: 0.0, analog_frac: 0.02 }
    }
}

/// Outcome of one metric's comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftStatus {
    /// Bit-equal.
    Match,
    /// Unequal but inside the tolerance band.
    InTolerance,
    /// Outside the tolerance band — fails the gate.
    Drift,
    /// In the baseline, absent from the current run — fails the gate.
    MissingInCurrent,
    /// In the current run, absent from the baseline — reported only.
    NewInCurrent,
    /// Baseline is `pending` (paper targets, not measured values):
    /// informational only.
    Unpinned,
}

impl DriftStatus {
    pub fn name(self) -> &'static str {
        match self {
            DriftStatus::Match => "match",
            DriftStatus::InTolerance => "in-tol",
            DriftStatus::Drift => "DRIFT",
            DriftStatus::MissingInCurrent => "MISSING",
            DriftStatus::NewInCurrent => "new",
            DriftStatus::Unpinned => "unpinned",
        }
    }

    fn fails(self) -> bool {
        matches!(self, DriftStatus::Drift | DriftStatus::MissingInCurrent)
    }
}

/// One row of the drift report.
#[derive(Clone, Debug)]
pub struct DriftRow {
    pub id: String,
    pub unit: String,
    pub kind: MetricKind,
    pub baseline: Option<f64>,
    pub current: Option<f64>,
    pub status: DriftStatus,
}

impl DriftRow {
    /// Signed relative delta current vs baseline (`None` when either
    /// side is missing or the baseline is 0 while current is not).
    pub fn rel_delta(&self) -> Option<f64> {
        match (self.baseline, self.current) {
            (Some(b), Some(c)) if b != 0.0 => Some((c - b) / b),
            (Some(b), Some(c)) if b == 0.0 && c == 0.0 => Some(0.0),
            _ => None,
        }
    }
}

/// The full result of comparing one suite against its baseline.
#[derive(Clone, Debug)]
pub struct RegressReport {
    pub suite: String,
    /// The baseline was `pending` (fails the gate until blessed).
    pub pending_baseline: bool,
    /// Set when current and baseline were measured in different
    /// quick/full modes — the usual cause of a wall of drift rows, so
    /// the report names it up front (the gate itself is unaffected;
    /// meta is never compared).
    pub mode_note: Option<String>,
    pub rows: Vec<DriftRow>,
}

impl RegressReport {
    /// True when any row fails the gate — or the baseline itself is
    /// still `pending` (an unpinned suite must not pass silently; see
    /// the module docs).
    pub fn failed(&self) -> bool {
        self.pending_baseline || self.rows.iter().any(|r| r.status.fails())
    }

    pub fn count(&self, status: DriftStatus) -> usize {
        self.rows.iter().filter(|r| r.status == status).count()
    }

    /// Render the drift table (only non-matching rows, or a one-line
    /// all-clear) plus the summary line.
    pub fn render(&self) -> String {
        let interesting: Vec<&DriftRow> =
            self.rows.iter().filter(|r| r.status != DriftStatus::Match).collect();
        let mut out = String::new();
        if let Some(note) = &self.mode_note {
            out.push_str(note);
            out.push('\n');
        }
        if self.pending_baseline {
            // No drift table for a pending baseline: its rows are paper
            // targets, not measured values, so value deltas are the
            // reproduction-distance report's job, not drift. The gate
            // still fails — see `failed()`.
            out.push_str(&format!(
                "regress {}: FAIL — baseline is PENDING (paper targets, no pinned \
                 measurements); {} target rows, {} current metrics. Run `flexv regress \
                 --bless` and commit baselines/ to pin measured values\n",
                self.suite,
                self.rows.iter().filter(|r| r.baseline.is_some()).count(),
                self.rows.iter().filter(|r| r.current.is_some()).count(),
            ));
            return out;
        }
        if interesting.is_empty() {
            out.push_str(&format!(
                "regress {}: OK — {} metrics, all bit-equal to baseline\n",
                self.suite,
                self.rows.len()
            ));
            return out;
        }
        let mut t = Table::new(format!("regress {} — per-metric drift", self.suite)).header(&[
            "metric", "kind", "baseline", "current", "delta%", "status",
        ]);
        for r in &interesting {
            t.row(vec![
                r.id.clone(),
                r.kind.name().to_string(),
                r.baseline.map_or("-".to_string(), |v| f(v, 4)),
                r.current.map_or("-".to_string(), |v| f(v, 4)),
                r.rel_delta().map_or("-".to_string(), |d| f(d * 100.0, 3)),
                r.status.name().to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "regress {}: {} metrics — {} match, {} in-tolerance, {} drifted, {} missing, {} new{}\n",
            self.suite,
            self.rows.len(),
            self.count(DriftStatus::Match),
            self.count(DriftStatus::InTolerance),
            self.count(DriftStatus::Drift),
            self.count(DriftStatus::MissingInCurrent),
            self.count(DriftStatus::NewInCurrent),
            if self.failed() { " — FAIL" } else { "" },
        ));
        out
    }
}

/// Compare `current` against `baseline` under `tol`.
pub fn compare(
    current: &BenchArtifact,
    baseline: &BenchArtifact,
    tol: &Tolerance,
) -> RegressReport {
    let cur: BTreeMap<&str, &MetricRow> =
        current.rows.iter().map(|r| (r.id.as_str(), r)).collect();
    let base: BTreeMap<&str, &MetricRow> =
        baseline.rows.iter().map(|r| (r.id.as_str(), r)).collect();
    let mut rows = Vec::new();
    for (id, b) in &base {
        let status_and_cur = match cur.get(id) {
            None => (DriftStatus::MissingInCurrent, None),
            Some(c) => {
                let status = if baseline.pending {
                    DriftStatus::Unpinned
                } else if c.kind != b.kind || c.unit != b.unit {
                    // Tolerance semantics come from the *baseline*: a
                    // change that reclassifies a metric (exact → analog)
                    // or renames its unit would otherwise loosen its own
                    // gate in the very run that gates it. Re-bless to
                    // change a metric's comparison semantics.
                    DriftStatus::Drift
                } else if c.value == b.value {
                    DriftStatus::Match
                } else {
                    let within = match b.kind {
                        // `--tol-cycles` is an *absolute* slack in
                        // cycle/count units; exact ratio rows
                        // (MAC/cycle, fractions) always compare
                        // bit-exactly — an absolute cycle budget would
                        // otherwise un-gate them entirely.
                        MetricKind::Exact => {
                            let slack = if matches!(b.unit.as_str(), "cycles" | "MACs") {
                                tol.exact_abs
                            } else {
                                0.0
                            };
                            (c.value - b.value).abs() <= slack
                        }
                        MetricKind::Analog => {
                            let denom = b.value.abs().max(f64::MIN_POSITIVE);
                            (c.value - b.value).abs() / denom <= tol.analog_frac
                        }
                    };
                    if within {
                        DriftStatus::InTolerance
                    } else {
                        DriftStatus::Drift
                    }
                };
                (status, Some(c.value))
            }
        };
        rows.push(DriftRow {
            id: (*id).to_string(),
            unit: b.unit.clone(),
            kind: b.kind,
            baseline: Some(b.value),
            current: status_and_cur.1,
            status: status_and_cur.0,
        });
    }
    for (id, c) in &cur {
        if !base.contains_key(id) {
            rows.push(DriftRow {
                id: (*id).to_string(),
                unit: c.unit.clone(),
                kind: c.kind,
                baseline: None,
                current: Some(c.value),
                status: DriftStatus::NewInCurrent,
            });
        }
    }
    let mode = |quick: bool| if quick { "quick" } else { "full" };
    let mode_note = (current.meta.quick != baseline.meta.quick).then(|| {
        format!(
            "note: {} — current measured in {} mode, baseline in {} mode; every sized \
             metric will drift. Re-pin with `regress --bless{}`",
            current.suite,
            mode(current.meta.quick),
            mode(baseline.meta.quick),
            if current.meta.quick { "" } else { " --full" },
        )
    });
    RegressReport { suite: current.suite.clone(), pending_baseline: baseline.pending, mode_note, rows }
}

/// Reproduction distance from the paper: every current row that carries
/// a paper reference, with the measured/paper ratio. Informational only
/// — the gate compares against measured baselines, not the paper.
pub fn paper_distance(current: &BenchArtifact) -> Option<String> {
    let refs: Vec<&MetricRow> = current.rows.iter().filter(|r| r.paper.is_some()).collect();
    if refs.is_empty() {
        return None;
    }
    let mut t = Table::new(format!("{} — reproduction distance from the paper", current.suite))
        .header(&["metric", "paper", "measured", "measured/paper"]);
    for r in refs {
        let p = r.paper.expect("filtered on is_some");
        t.row(vec![
            r.id.clone(),
            f(p, 2),
            f(r.value, 2),
            if p != 0.0 { format!("{}x", f(r.value / p, 2)) } else { "-".to_string() },
        ]);
    }
    Some(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::artifact::RunMeta;

    fn art(suite: &str, rows: Vec<MetricRow>) -> BenchArtifact {
        let mut a = BenchArtifact::new(suite, RunMeta::default());
        a.rows = rows;
        a
    }

    #[test]
    fn identical_artifacts_match() {
        let a = art(
            "s",
            vec![
                MetricRow::exact("s/cycles", 1000.0, "cycles"),
                MetricRow::analog("s/tops_w", 3.26, "TOPS/W"),
            ],
        );
        let rep = compare(&a, &a.clone(), &Tolerance::default());
        assert!(!rep.failed());
        assert_eq!(rep.count(DriftStatus::Match), 2);
        assert!(rep.render().contains("all bit-equal"));
    }

    #[test]
    fn exact_drift_fails_with_zero_cycle_tolerance() {
        let base = art("s", vec![MetricRow::exact("s/cycles", 1000.0, "cycles")]);
        let cur = art("s", vec![MetricRow::exact("s/cycles", 1001.0, "cycles")]);
        let rep = compare(&cur, &base, &Tolerance::default());
        assert!(rep.failed());
        assert_eq!(rep.count(DriftStatus::Drift), 1);
        let rendered = rep.render();
        assert!(rendered.contains("s/cycles") && rendered.contains("DRIFT"), "{rendered}");
        // a +1 cycle slack accepts it as in-tolerance
        let rep2 = compare(&cur, &base, &Tolerance { exact_abs: 1.0, analog_frac: 0.0 });
        assert!(!rep2.failed());
        assert_eq!(rep2.count(DriftStatus::InTolerance), 1);
    }

    #[test]
    fn analog_tolerance_band_is_relative() {
        let base = art("s", vec![MetricRow::analog("s/w", 10.0, "mW")]);
        let ok = art("s", vec![MetricRow::analog("s/w", 10.19, "mW")]);
        let bad = art("s", vec![MetricRow::analog("s/w", 10.3, "mW")]);
        let tol = Tolerance::default(); // 2%
        assert!(!compare(&ok, &base, &tol).failed());
        let rep = compare(&bad, &base, &tol);
        assert!(rep.failed());
        assert_eq!(rep.count(DriftStatus::Drift), 1);
    }

    #[test]
    fn missing_fails_new_does_not() {
        let base = art(
            "s",
            vec![MetricRow::exact("s/a", 1.0, ""), MetricRow::exact("s/b", 2.0, "")],
        );
        let cur = art(
            "s",
            vec![MetricRow::exact("s/a", 1.0, ""), MetricRow::exact("s/c", 3.0, "")],
        );
        let rep = compare(&cur, &base, &Tolerance::default());
        assert!(rep.failed(), "metric vanished from the current run");
        assert_eq!(rep.count(DriftStatus::MissingInCurrent), 1);
        assert_eq!(rep.count(DriftStatus::NewInCurrent), 1);
        let only_new = compare(&cur, &art("s", vec![MetricRow::exact("s/a", 1.0, "")]), &Tolerance::default());
        assert!(!only_new.failed(), "new metrics are additive, not drift");
    }

    #[test]
    fn cycle_slack_never_ungates_ratio_rows() {
        let base = art(
            "s",
            vec![
                MetricRow::exact("s/mac", 6.0, "MAC/cycle"),
                MetricRow::exact("s/cyc", 100.0, "cycles"),
            ],
        );
        let cur = art(
            "s",
            vec![
                MetricRow::exact("s/mac", 5.0, "MAC/cycle"),
                MetricRow::exact("s/cyc", 102.0, "cycles"),
            ],
        );
        let tol = Tolerance { exact_abs: 5.0, analog_frac: 0.0 };
        let rep = compare(&cur, &base, &tol);
        assert!(rep.failed(), "a MAC/cycle drop must not hide behind --tol-cycles");
        assert!(rep
            .rows
            .iter()
            .any(|r| r.id == "s/cyc" && r.status == DriftStatus::InTolerance));
        assert!(rep.rows.iter().any(|r| r.id == "s/mac" && r.status == DriftStatus::Drift));
    }

    #[test]
    fn reclassifying_a_metric_cannot_loosen_its_own_gate() {
        // Baseline says exact cycles; the current run re-emits the same
        // id as analog with a value inside the 2% band. The comparison
        // must use the baseline's semantics and fail on the mismatch.
        let base = art("s", vec![MetricRow::exact("s/cycles", 1000.0, "cycles")]);
        let cur = art("s", vec![MetricRow::analog("s/cycles", 1010.0, "cycles")]);
        let rep = compare(&cur, &base, &Tolerance::default());
        assert!(rep.failed(), "kind reclassification must require a re-bless");
        assert_eq!(rep.count(DriftStatus::Drift), 1);
        // a unit rename is a mismatch too, even with identical values
        let cur2 = art("s", vec![MetricRow::exact("s/cycles", 1000.0, "Mcycles")]);
        assert!(compare(&cur2, &base, &Tolerance::default()).failed());
    }

    #[test]
    fn mode_mismatch_is_named_in_the_report() {
        let mut base = art("s", vec![MetricRow::exact("s/cyc", 100.0, "cycles")]);
        base.meta.quick = true;
        let cur = art("s", vec![MetricRow::exact("s/cyc", 100.0, "cycles")]);
        let rep = compare(&cur, &base, &Tolerance::default());
        let note = rep.mode_note.as_deref().expect("mode mismatch must be noted");
        assert!(note.contains("full") && note.contains("quick"), "{note}");
        assert!(rep.render().contains("note:"));
        // same-mode comparison carries no note
        assert!(compare(&cur, &cur.clone(), &Tolerance::default()).mode_note.is_none());
    }

    #[test]
    fn pending_baseline_fails_the_gate_without_drift_rows() {
        let mut base = art("s", vec![MetricRow::exact("s/a", 91.5, "MAC/cycle")]);
        base.pending = true;
        let cur = art("s", vec![MetricRow::exact("s/a", 80.0, "MAC/cycle")]);
        let rep = compare(&cur, &base, &Tolerance::default());
        // Unpinned rows never count as drift (the value came from the
        // paper, not a measurement)…
        assert_eq!(rep.count(DriftStatus::Unpinned), 1);
        assert_eq!(rep.count(DriftStatus::Drift), 0);
        // …but the gate fails anyway: a pending suite is unguarded, and
        // `regress --bless` is the only non-failing path out.
        assert!(rep.failed(), "pending baseline must fail a non-bless run");
        let rendered = rep.render();
        assert!(rendered.contains("PENDING") && rendered.contains("FAIL"), "{rendered}");
        assert!(rendered.contains("--bless"), "{rendered}");
    }

    #[test]
    fn paper_distance_lists_referenced_rows() {
        let a = art(
            "kernels",
            vec![
                MetricRow::exact("kernels/x/mac", 85.0, "MAC/cycle").with_paper(91.5),
                MetricRow::exact("kernels/x/cycles", 100.0, "cycles"),
            ],
        );
        let t = paper_distance(&a).unwrap();
        assert!(t.contains("kernels/x/mac") && t.contains("91.5"), "{t}");
        assert!(!t.contains("kernels/x/cycles"));
        assert!(paper_distance(&art("s", vec![])).is_none());
    }
}
