//! im2col phase generator (§II-B).
//!
//! For a given output pixel, the 3-D HWC input receptive field is
//! re-arranged into a 1-D buffer along (ky, kx, cin) — zero-filled where
//! the field hangs over the padding. Because the layout is HWC, the
//! `kw × cin` elements of one field row are contiguous in the input, so the
//! copy runs word-by-word (`p.lw`/`p.sw` with post-increment); ragged
//! byte tails fall back to byte copies.
//!
//! On cores whose SIMD unit cannot consume the activation format
//! (RI5CY with sub-byte activations), the im2col additionally *expands*
//! activations to 8 bit (the strategy of the PULP-NN mixed library [13]):
//! the buffer is then `u8` and only weights need in-loop unpacking.

use super::regalloc as ra;
use super::unpack;
use crate::isa::{AluOp, Instr, Program};

/// Convolution geometry (one layer or one DORY tile). Padding is
/// per-side: a row-strip tile in the middle of a feature map has no
/// vertical padding while the first/last strips keep the layer's.
#[derive(Clone, Copy, Hash, PartialEq, Eq, Debug)]
pub struct ConvGeom {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad_t: usize,
    pub pad_b: usize,
    pub pad_l: usize,
    pub pad_r: usize,
    pub a_bits: u8,
}

impl ConvGeom {
    /// Uniform-padding constructor (whole layers).
    #[allow(clippy::too_many_arguments)]
    pub fn square(
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        a_bits: u8,
    ) -> Self {
        ConvGeom { h, w, cin, cout, kh, kw, stride, pad_t: pad, pad_b: pad, pad_l: pad, pad_r: pad, a_bits }
    }

    pub fn out_h(&self) -> usize {
        (self.h + self.pad_t + self.pad_b - self.kh) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.w + self.pad_l + self.pad_r - self.kw) / self.stride + 1
    }
    /// im2col contraction length in elements.
    pub fn k(&self) -> usize {
        self.kh * self.kw * self.cin
    }
    /// Bytes of one input row of `kw*cin` elements at the *buffer* width.
    pub fn field_row_bytes(&self, buf_bits: u8) -> usize {
        self.kw * self.cin * buf_bits as usize / 8
    }
    /// Input byte address of element (y, x, 0).
    pub fn in_addr(&self, base: u32, y: usize, x: usize) -> u32 {
        base + ((y * self.w + x) * self.cin * self.a_bits as usize / 8) as u32
    }
}

/// Emit a bulk copy of `bytes` from `src` to `dst` (word loop + byte tail).
/// Uses A_PTR/A_REG scratch registers (dead outside the MatMul inner loop).
pub fn emit_copy(p: &mut Program, src: u32, dst: u32, bytes: usize) {
    if bytes == 0 {
        return;
    }
    let words = bytes / 4;
    p.push(Instr::Li { rd: ra::A_PTR[0], imm: src as i32 });
    p.push(Instr::Li { rd: ra::A_PTR[1], imm: dst as i32 });
    if words > 0 {
        if words > 1 {
            p.push(Instr::LpSetup { l: 0, count: words as u32, len: 2 });
        }
        p.push(Instr::Lw { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 4 });
        p.push(Instr::Sw { rs: ra::A_REG[0], base: ra::A_PTR[1], off: 0, post_inc: 4 });
    }
    for _ in 0..bytes % 4 {
        p.push(Instr::Lbu { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 1 });
        p.push(Instr::Sb { rs: ra::A_REG[0], base: ra::A_PTR[1], off: 0, post_inc: 1 });
    }
}

/// Emit a zero fill of `bytes` at `dst`.
pub fn emit_zero(p: &mut Program, dst: u32, bytes: usize) {
    if bytes == 0 {
        return;
    }
    let words = bytes / 4;
    p.push(Instr::Li { rd: ra::A_PTR[1], imm: dst as i32 });
    if words > 0 {
        if words > 1 {
            p.push(Instr::LpSetup { l: 0, count: words as u32, len: 1 });
        }
        p.push(Instr::Sw { rs: 0, base: ra::A_PTR[1], off: 0, post_inc: 4 });
    }
    for _ in 0..bytes % 4 {
        p.push(Instr::Sb { rs: 0, base: ra::A_PTR[1], off: 0, post_inc: 1 });
    }
}

/// Emit a copy that expands packed `src_bits` activations to 8-bit
/// unsigned at `dst` (`n_elems` elements). Word-at-a-time: one packed load
/// feeds `8/src_bits` expanded words.
pub fn emit_copy_expand(p: &mut Program, src: u32, dst: u32, n_elems: usize, src_bits: u8) {
    if n_elems == 0 {
        return;
    }
    let per_word = 32 / src_bits as usize;
    p.push(Instr::Li { rd: ra::A_PTR[0], imm: src as i32 });
    p.push(Instr::Li { rd: ra::A_PTR[1], imm: dst as i32 });
    let groups = per_word / 4; // expanded words per packed word
    let full_words = n_elems / per_word;
    if full_words > 0 {
        let setup_at = p.len();
        if full_words > 1 {
            p.push(Instr::LpSetup { l: 0, count: full_words as u32, len: 0 });
        }
        let body_start = p.len();
        p.push(Instr::Lw { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 4 });
        for g in 0..groups {
            unpack::emit_unpack_unsigned(p, ra::A_REG[1], ra::A_REG[0], src_bits, 8, g as u8);
            p.push(Instr::Sw { rs: ra::A_REG[1], base: ra::A_PTR[1], off: 0, post_inc: 4 });
        }
        if full_words > 1 {
            let len = (p.len() - body_start) as u16;
            if let Instr::LpSetup { len: l, .. } = &mut p.instrs[setup_at] {
                *l = len;
            }
        }
    }
    // Ragged tail: element-by-element.
    let rem = n_elems % per_word;
    if rem > 0 {
        p.push(Instr::Lw { rd: ra::A_REG[0], base: ra::A_PTR[0], off: 0, post_inc: 4 });
        for e in 0..rem {
            p.push(Instr::ExtractU {
                rd: ra::A_REG[1],
                rs1: ra::A_REG[0],
                off: (e * src_bits as usize) as u8,
                len: src_bits,
            });
            p.push(Instr::Sb { rs: ra::A_REG[1], base: ra::A_PTR[1], off: 0, post_inc: 1 });
        }
    }
}

/// Emit the im2col of one output pixel `(oy, ox)` into the buffer row at
/// `buf`. `buf_bits` is the buffer element width (8 when expanding).
#[allow(clippy::too_many_arguments)]
pub fn emit_im2col_pixel(
    p: &mut Program,
    g: &ConvGeom,
    in_base: u32,
    buf: u32,
    oy: usize,
    ox: usize,
    buf_bits: u8,
) {
    let expand = buf_bits != g.a_bits;
    assert!(!expand || buf_bits == 8, "expansion targets 8-bit buffers");
    let elem_row = g.kw * g.cin; // elements per field row
    let row_bytes = elem_row * buf_bits as usize / 8;
    for ky in 0..g.kh {
        let iy = (oy * g.stride + ky) as isize - g.pad_t as isize;
        let dst = buf + (ky * row_bytes) as u32;
        if iy < 0 || iy >= g.h as isize {
            emit_zero(p, dst, row_bytes);
            continue;
        }
        // x range of the field: [x0, x0 + kw)
        let x0 = (ox * g.stride) as isize - g.pad_l as isize;
        let lead = (-x0).clamp(0, g.kw as isize) as usize; // left padding pixels
        let x_hi = ((g.w as isize - x0).clamp(0, g.kw as isize)) as usize; // first kw-index past data
        let body = x_hi - lead;
        let tail = g.kw - x_hi;
        let cb = g.cin * buf_bits as usize / 8; // buffer bytes per pixel
        if lead > 0 {
            emit_zero(p, dst, lead * cb);
        }
        if body > 0 {
            let src = g.in_addr(in_base, iy as usize, (x0 + lead as isize) as usize);
            if expand {
                emit_copy_expand(p, src, dst + (lead * cb) as u32, body * g.cin, g.a_bits);
            } else {
                emit_copy(p, src, dst + (lead * cb) as u32, body * g.cin * g.a_bits as usize / 8);
            }
        }
        if tail > 0 {
            emit_zero(p, dst + ((lead + body) * cb) as u32, tail * cb);
        }
    }
    // Note: the zero padding of the buffer tail (k .. pitch) is emitted
    // once per core by the conv kernel prologue, not per pixel.
    let _ = AluOp::Add; // (silence unused import when cfg'd out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QTensor;
    use crate::sim::{ClusterMem, Core, TCDM_BASE};
    use crate::util::Prng;

    fn run(p: Program, mem: &mut ClusterMem) {
        let mut c = Core::new(0);
        c.load_program(p);
        while !c.halted() {
            let g = c.mem_request().is_some();
            c.tick(mem, g);
        }
    }

    #[test]
    fn copy_and_zero() {
        let mut mem = ClusterMem::new();
        mem.write_bytes(TCDM_BASE, &(0..23u8).collect::<Vec<_>>());
        let mut p = Program::new("t");
        emit_copy(&mut p, TCDM_BASE, TCDM_BASE + 100, 23);
        emit_zero(&mut p, TCDM_BASE + 100, 5);
        p.push(Instr::Halt);
        run(p, &mut mem);
        let got = mem.read_bytes(TCDM_BASE + 100, 23);
        let mut want: Vec<u8> = (0..23).collect();
        for b in want.iter_mut().take(5) {
            *b = 0;
        }
        assert_eq!(got, want);
    }

    #[test]
    fn copy_expand_matches_unpack() {
        let mut mem = ClusterMem::new();
        let mut rng = Prng::new(3);
        let vals: Vec<u32> = (0..40).map(|_| rng.bits_unsigned(4)).collect();
        let packed = crate::qnn::packing::pack_unsigned(&vals, 4);
        mem.write_bytes(TCDM_BASE, &packed);
        let mut p = Program::new("t");
        emit_copy_expand(&mut p, TCDM_BASE, TCDM_BASE + 512, 40, 4);
        p.push(Instr::Halt);
        run(p, &mut mem);
        let got = mem.read_bytes(TCDM_BASE + 512, 40);
        assert_eq!(got, vals.iter().map(|&v| v as u8).collect::<Vec<_>>());
    }

    /// Reference im2col for the test.
    fn golden_im2col(g: &ConvGeom, x: &QTensor, oy: usize, ox: usize) -> Vec<u32> {
        let mut out = vec![];
        for ky in 0..g.kh {
            let iy = (oy * g.stride + ky) as isize - g.pad_t as isize;
            for kx in 0..g.kw {
                let ix = (ox * g.stride + kx) as isize - g.pad_l as isize;
                for c in 0..g.cin {
                    if iy < 0 || iy >= g.h as isize || ix < 0 || ix >= g.w as isize {
                        out.push(0);
                    } else {
                        out.push(x.get_u(x.flat(&[iy as usize, ix as usize, c])));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn im2col_pixel_matches_golden_with_padding() {
        let mut rng = Prng::new(7);
        for (a_bits, cin) in [(8u8, 4usize), (4, 8), (2, 16)] {
            let g = ConvGeom::square(6, 6, cin, 4, 3, 3, 1, 1, a_bits);
            let x = QTensor::random(&[g.h, g.w, g.cin], a_bits, false, &mut rng);
            let mut mem = ClusterMem::new();
            mem.write_bytes(TCDM_BASE, &x.data);
            let buf = TCDM_BASE + 4096;
            for (oy, ox) in [(0, 0), (0, 3), (5, 5), (2, 2)] {
                let mut p = Program::new("t");
                emit_im2col_pixel(&mut p, &g, TCDM_BASE, buf, oy, ox, a_bits);
                p.push(Instr::Halt);
                run(p, &mut mem);
                let want = golden_im2col(&g, &x, oy, ox);
                let got_bytes = mem.read_bytes(buf, g.k() * a_bits as usize / 8);
                let got = crate::qnn::packing::unpack_unsigned(&got_bytes, a_bits, g.k());
                assert_eq!(got, want, "a{a_bits} pixel ({oy},{ox})");
            }
        }
    }

    #[test]
    fn im2col_pixel_expanding_subbyte() {
        let mut rng = Prng::new(9);
        let g = ConvGeom::square(5, 5, 8, 4, 3, 3, 2, 1, 4);
        let x = QTensor::random(&[g.h, g.w, g.cin], 4, false, &mut rng);
        let mut mem = ClusterMem::new();
        mem.write_bytes(TCDM_BASE, &x.data);
        let buf = TCDM_BASE + 4096;
        let mut p = Program::new("t");
        emit_im2col_pixel(&mut p, &g, TCDM_BASE, buf, 1, 1, 8);
        p.push(Instr::Halt);
        run(p, &mut mem);
        let want = golden_im2col(&g, &x, 1, 1);
        let got = mem.read_bytes(buf, g.k());
        assert_eq!(got.iter().map(|&b| b as u32).collect::<Vec<_>>(), want);
    }
}
