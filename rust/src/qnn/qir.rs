//! QIR: a line-oriented, NNEF-like text format for quantized networks.
//!
//! One line per edge (`tensor`) or node (`op`), `#` comments, explicit
//! per-op precision in the paper's `a8w4` notation, and *seeded* synthetic
//! weights — a `.qir` file carries no weight payload, only the seed of the
//! deterministic stream the importer replays (see `docs/QIR_FORMAT.md` for
//! the full grammar, determinism contract and versioning rules).
//!
//! [`print`] is canonical: for any valid [`Graph`] it emits a unique byte
//! sequence, and `parse ∘ print` is the identity, so committed `.qir` files
//! can be byte-diffed against re-exports in CI.
//!
//! Importing a three-layer network from a string literal:
//!
//! ```
//! use flexv::qnn::qir;
//!
//! let text = "\
//! qir 1
//! net tiny
//! seed 7
//! input input
//! tensor input 8x8x8 a8
//! tensor c1 8x8x16 a8 q1:10:0
//! op conv c1 input -> c1 k3 s1 p1 a8w8
//! tensor gap 1x1x16 a8 q1024:16:0
//! op avgpool gap c1 -> gap k8 s8
//! tensor fc 1x1x8 a8 q1:7:0
//! op linear fc gap -> fc a8w4
//! ";
//! let graph = qir::parse(text).unwrap();
//! let net = graph.lower().unwrap();
//! assert_eq!(net.nodes.len(), 3);
//! assert_eq!(net.total_macs(), 8 * 8 * 16 * 3 * 3 * 8 + 16 * 8);
//! // print is canonical and parse inverts it exactly
//! assert_eq!(qir::parse(&qir::print(&graph)).unwrap(), graph);
//! ```

use super::graph::{Graph, OpKind, OpNode, TensorDef};
use super::QuantParams;

/// The only format version this importer accepts (see the versioning rules
/// in `docs/QIR_FORMAT.md`: the major is bumped on any grammar change).
pub const QIR_VERSION: u32 = 1;

/// A parse failure with the 1-based source line (0 for whole-file errors).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QirError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for QirError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "qir: {}", self.msg)
        } else {
            write!(f, "qir line {}: {}", self.line, self.msg)
        }
    }
}

impl std::error::Error for QirError {}

/// Render a graph in canonical QIR text: header directives, then — in op
/// definition order — each op's output `tensor` line followed by its `op`
/// line. Panics if a quantizer is not scalar-broadcast (QIR v1 carries
/// per-tensor scalar quant only).
pub fn print(g: &Graph) -> String {
    let mut s = String::new();
    s.push_str(&format!("# flexv QIR v{QIR_VERSION}: {}\n", g.name));
    s.push_str(&format!("qir {QIR_VERSION}\n"));
    s.push_str(&format!("net {}\n", g.name));
    s.push_str(&format!("seed {}\n", g.seed));
    s.push_str(&format!("input {}\n", g.tensors[g.input].name));
    s.push_str(&tensor_line(&g.tensors[g.input]));
    for op in &g.ops {
        s.push_str(&tensor_line(&g.tensors[op.output]));
        s.push_str(&op_line(g, op));
    }
    s
}

fn tensor_line(t: &TensorDef) -> String {
    let mut s = format!(
        "tensor {} {}x{}x{} a{}",
        t.name, t.shape[0], t.shape[1], t.shape[2], t.bits
    );
    if let Some(q) = &t.quant {
        let (m, b) = (q.mult[0], q.bias[0]);
        assert!(
            q.mult.iter().all(|&x| x == m) && q.bias.iter().all(|&x| x == b),
            "QIR v1 prints scalar-broadcast quant only (tensor {})",
            t.name
        );
        s.push_str(&format!(" q{m}:{}:{b}", q.shift));
    }
    s.push('\n');
    s
}

fn op_line(g: &Graph, op: &OpNode) -> String {
    let ins: Vec<&str> = op.inputs.iter().map(|&t| g.tensors[t].name.as_str()).collect();
    let mut s = format!(
        "op {} {} {} -> {}",
        op.kind.token(),
        op.name,
        ins.join(" "),
        g.tensors[op.output].name
    );
    let a = g.tensors[op.inputs[0]].bits;
    match op.kind {
        OpKind::Conv2d { kh, kw, stride, pad } | OpKind::DwConv2d { kh, kw, stride, pad } => {
            if kh == kw {
                s.push_str(&format!(" k{kh}"));
            } else {
                s.push_str(&format!(" k{kh}x{kw}"));
            }
            s.push_str(&format!(" s{stride} p{pad} a{a}w{}", op.w_bits));
        }
        OpKind::Linear => s.push_str(&format!(" a{a}w{}", op.w_bits)),
        OpKind::MaxPool { k, stride } | OpKind::AvgPool { k, stride } => {
            s.push_str(&format!(" k{k} s{stride}"));
        }
        OpKind::Add { m1, m2 } => s.push_str(&format!(" m{m1}:{m2}")),
        OpKind::Concat => {}
    }
    if let Some(seed) = op.seed {
        s.push_str(&format!(" seed={seed}"));
    }
    s.push('\n');
    s
}

/// Parse QIR text into a validated [`Graph`].
pub fn parse(text: &str) -> Result<Graph, QirError> {
    let mut version_seen = false;
    let mut name: Option<String> = None;
    let mut seed: Option<u64> = None;
    let mut input_name: Option<String> = None;
    let mut tensors: Vec<TensorDef> = vec![];
    let mut ops: Vec<OpNode> = vec![];

    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let ln = i + 1;
        let err = |msg: String| QirError { line: ln, msg };
        let mut toks = line.split_whitespace();
        let head = toks.next().unwrap();
        if !version_seen && head != "qir" {
            return Err(err(format!("first directive must be `qir {QIR_VERSION}`")));
        }
        match head {
            "qir" => {
                let v = toks.next().ok_or_else(|| err("missing version".into()))?;
                if v.parse::<u32>() != Ok(QIR_VERSION) {
                    return Err(err(format!(
                        "unsupported QIR version {v} (this importer reads v{QIR_VERSION})"
                    )));
                }
                version_seen = true;
            }
            "net" => {
                let n = line["net".len()..].trim();
                if n.is_empty() {
                    return Err(err("empty net name".into()));
                }
                name = Some(n.to_string());
            }
            "seed" => {
                let t = toks.next().ok_or_else(|| err("missing seed value".into()))?;
                seed = Some(
                    t.parse::<u64>().map_err(|_| err(format!("bad seed {t:?}")))?,
                );
            }
            "input" => {
                let t = toks.next().ok_or_else(|| err("missing input tensor name".into()))?;
                input_name = Some(t.to_string());
            }
            "tensor" => {
                let t = parse_tensor(&mut toks, &err)?;
                if tensors.iter().any(|o| o.name == t.name) {
                    return Err(err(format!("duplicate tensor {:?}", t.name)));
                }
                tensors.push(t);
            }
            "op" => {
                let op = parse_op(&mut toks, &tensors, &err)?;
                if ops.iter().any(|o| o.name == op.name) {
                    return Err(err(format!("duplicate op {:?}", op.name)));
                }
                ops.push(op);
            }
            other => return Err(err(format!("unknown directive {other:?}"))),
        }
    }

    let whole = |msg: String| QirError { line: 0, msg };
    if !version_seen {
        return Err(whole("missing `qir` version directive".into()));
    }
    let name = name.ok_or_else(|| whole("missing `net` directive".into()))?;
    let seed = seed.ok_or_else(|| whole("missing `seed` directive".into()))?;
    let input_name = input_name.ok_or_else(|| whole("missing `input` directive".into()))?;
    let input = tensors
        .iter()
        .position(|t| t.name == input_name)
        .ok_or_else(|| whole(format!("input tensor {input_name:?} not defined")))?;
    if tensors[input].quant.is_some() {
        return Err(whole(format!(
            "input tensor {input_name:?} must not carry quant params"
        )));
    }
    let g = Graph { name, seed, input, tensors, ops };
    g.validate().map_err(whole)?;
    Ok(g)
}

fn parse_tensor<'a, I: Iterator<Item = &'a str>>(
    toks: &mut I,
    err: &dyn Fn(String) -> QirError,
) -> Result<TensorDef, QirError> {
    let name = toks.next().ok_or_else(|| err("missing tensor name".into()))?;
    let shape_tok = toks.next().ok_or_else(|| err("missing tensor shape".into()))?;
    let dims: Vec<usize> = shape_tok
        .split('x')
        .map(|d| d.parse::<usize>().map_err(|_| err(format!("bad shape {shape_tok:?}"))))
        .collect::<Result<_, _>>()?;
    if dims.len() != 3 {
        return Err(err(format!("shape {shape_tok:?} must be HxWxC")));
    }
    let bits_tok = toks.next().ok_or_else(|| err("missing tensor bits (aN)".into()))?;
    let bits = bits_tok
        .strip_prefix('a')
        .and_then(|b| b.parse::<u8>().ok())
        .ok_or_else(|| err(format!("bad bits token {bits_tok:?} (want e.g. a8)")))?;
    let quant = match toks.next() {
        None => None,
        Some(q_tok) => {
            let body = q_tok
                .strip_prefix('q')
                .ok_or_else(|| err(format!("bad quant token {q_tok:?} (want qM:S:B)")))?;
            let parts: Vec<&str> = body.split(':').collect();
            if parts.len() != 3 {
                return Err(err(format!("bad quant token {q_tok:?} (want qM:S:B)")));
            }
            let mult = parts[0]
                .parse::<i32>()
                .map_err(|_| err(format!("bad quant mult {:?}", parts[0])))?;
            let shift = parts[1]
                .parse::<u8>()
                .map_err(|_| err(format!("bad quant shift {:?}", parts[1])))?;
            let bias = parts[2]
                .parse::<i32>()
                .map_err(|_| err(format!("bad quant bias {:?}", parts[2])))?;
            Some(QuantParams::scalar(mult, shift, bias, bits, dims[2]))
        }
    };
    if let Some(extra) = toks.next() {
        return Err(err(format!("trailing token {extra:?} on tensor line")));
    }
    Ok(TensorDef { name: name.to_string(), shape: [dims[0], dims[1], dims[2]], bits, quant })
}

fn parse_op<'a, I: Iterator<Item = &'a str>>(
    toks: &mut I,
    tensors: &[TensorDef],
    err: &dyn Fn(String) -> QirError,
) -> Result<OpNode, QirError> {
    let kind_tok = toks.next().ok_or_else(|| err("missing op kind".into()))?;
    let name = toks.next().ok_or_else(|| err("missing op name".into()))?;
    let mut ins: Vec<usize> = vec![];
    loop {
        let t = toks
            .next()
            .ok_or_else(|| err(format!("op {name}: missing `->` output")))?;
        if t == "->" {
            break;
        }
        let id = tensors
            .iter()
            .position(|d| d.name == t)
            .ok_or_else(|| err(format!("op {name}: unknown input tensor {t:?}")))?;
        ins.push(id);
    }
    let out_tok = toks.next().ok_or_else(|| err(format!("op {name}: missing output")))?;
    let output = tensors
        .iter()
        .position(|d| d.name == out_tok)
        .ok_or_else(|| err(format!("op {name}: unknown output tensor {out_tok:?}")))?;

    // Attribute tokens.
    let (mut kk, mut stride, mut pad, mut prec, mut m, mut op_seed) =
        (None, None, None, None, None, None);
    for t in toks {
        if let Some(v) = t.strip_prefix("seed=") {
            op_seed =
                Some(v.parse::<u64>().map_err(|_| err(format!("op {name}: bad seed {v:?}")))?);
        } else if let Some(v) = t.strip_prefix('k') {
            let parts: Vec<&str> = v.split('x').collect();
            let parse_dim = |s: &str| {
                s.parse::<usize>().map_err(|_| err(format!("op {name}: bad kernel {t:?}")))
            };
            kk = Some(match parts.as_slice() {
                [k] => (parse_dim(k)?, parse_dim(k)?),
                [kh, kw] => (parse_dim(kh)?, parse_dim(kw)?),
                _ => return Err(err(format!("op {name}: bad kernel {t:?}"))),
            });
        } else if let Some(v) = t.strip_prefix('s') {
            stride =
                Some(v.parse::<usize>().map_err(|_| err(format!("op {name}: bad stride {t:?}")))?);
        } else if let Some(v) = t.strip_prefix('p') {
            pad = Some(v.parse::<usize>().map_err(|_| err(format!("op {name}: bad pad {t:?}")))?);
        } else if let Some(v) = t.strip_prefix('a') {
            let (a_s, w_s) = v
                .split_once('w')
                .ok_or_else(|| err(format!("op {name}: bad precision {t:?} (want aNwM)")))?;
            let a = a_s
                .parse::<u8>()
                .map_err(|_| err(format!("op {name}: bad precision {t:?}")))?;
            let w = w_s
                .parse::<u8>()
                .map_err(|_| err(format!("op {name}: bad precision {t:?}")))?;
            prec = Some((a, w));
        } else if let Some(v) = t.strip_prefix('m') {
            let (m1_s, m2_s) = v
                .split_once(':')
                .ok_or_else(|| err(format!("op {name}: bad scales {t:?} (want mM1:M2)")))?;
            let m1 = m1_s
                .parse::<i32>()
                .map_err(|_| err(format!("op {name}: bad scales {t:?}")))?;
            let m2 = m2_s
                .parse::<i32>()
                .map_err(|_| err(format!("op {name}: bad scales {t:?}")))?;
            m = Some((m1, m2));
        } else {
            return Err(err(format!("op {name}: unknown attribute {t:?}")));
        }
    }

    let need = |opt: Option<(usize, usize)>, what: &str| {
        opt.ok_or_else(|| err(format!("op {name}: missing {what}")))
    };
    let need_s = |opt: Option<usize>, what: &str| {
        opt.ok_or_else(|| err(format!("op {name}: missing {what}")))
    };
    let kind = match kind_tok {
        "conv" | "dwconv" => {
            let (kh, kw) = need(kk, "kernel (kN)")?;
            let stride = need_s(stride, "stride (sN)")?;
            let pad = need_s(pad, "pad (pN)")?;
            if kind_tok == "conv" {
                OpKind::Conv2d { kh, kw, stride, pad }
            } else {
                OpKind::DwConv2d { kh, kw, stride, pad }
            }
        }
        "linear" => OpKind::Linear,
        "maxpool" | "avgpool" => {
            let (kh, kw) = need(kk, "kernel (kN)")?;
            if kh != kw {
                return Err(err(format!("op {name}: pooling window must be square")));
            }
            let stride = need_s(stride, "stride (sN)")?;
            if kind_tok == "maxpool" {
                OpKind::MaxPool { k: kh, stride }
            } else {
                OpKind::AvgPool { k: kh, stride }
            }
        }
        "add" => {
            let (m1, m2) = m.ok_or_else(|| err(format!("op {name}: missing scales (mM1:M2)")))?;
            OpKind::Add { m1, m2 }
        }
        "concat" => OpKind::Concat,
        other => return Err(err(format!("unknown op kind {other:?}"))),
    };
    if ins.is_empty() {
        return Err(err(format!("op {name}: no inputs")));
    }
    let w_bits = if kind.weighted() {
        let (a, w) = prec.ok_or_else(|| err(format!("op {name}: missing precision (aNwM)")))?;
        let in_bits = tensors[ins[0]].bits;
        if a != in_bits {
            return Err(err(format!(
                "op {name}: precision a{a} contradicts input tensor bits a{in_bits}"
            )));
        }
        w
    } else {
        if prec.is_some() {
            return Err(err(format!("op {name}: precision on a weight-less op")));
        }
        8
    };
    Ok(OpNode { name: name.to_string(), kind, inputs: ins, output, w_bits, seed: op_seed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::graph::Graph;

    fn tiny_text() -> &'static str {
        "\
qir 1
net tiny
seed 7
input input
tensor input 8x8x8 a8
tensor c1 8x8x16 a8 q1:10:0
op conv c1 input -> c1 k3 s1 p1 a8w8
tensor gap 1x1x16 a8 q1024:16:0
op avgpool gap c1 -> gap k8 s8
tensor fc 1x1x8 a8 q1:7:0
op linear fc gap -> fc a8w4
"
    }

    #[test]
    fn parse_print_parse_is_fixed_point() {
        let g = parse(tiny_text()).expect("tiny parses");
        let once = print(&g);
        let twice = print(&parse(&once).expect("canonical text parses"));
        assert_eq!(once, twice, "print must be byte-stable");
        assert_eq!(parse(&once).unwrap(), g);
    }

    #[test]
    fn comments_and_blank_lines_are_ignored() {
        let noisy = format!("# leading comment\n\n{}\n# trailing\n", tiny_text());
        assert_eq!(parse(&noisy).unwrap(), parse(tiny_text()).unwrap());
        let inline = tiny_text().replace("seed 7", "seed 7   # the weight stream");
        assert_eq!(parse(&inline).unwrap(), parse(tiny_text()).unwrap());
    }

    #[test]
    fn version_gate() {
        let e = parse(&tiny_text().replace("qir 1", "qir 2")).unwrap_err();
        assert!(e.msg.contains("unsupported QIR version"), "{e}");
        let e = parse("net x\nqir 1\n").unwrap_err();
        assert!(e.msg.contains("first directive"), "{e}");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let bad = tiny_text().replace("op conv c1 input -> c1 k3 s1 p1 a8w8",
                                      "op conv c1 input -> c1 k3 s1 p1 a4w8");
        let e = parse(&bad).unwrap_err();
        assert_eq!(e.line, 7);
        assert!(e.msg.contains("contradicts input tensor bits"), "{e}");
    }

    #[test]
    fn rejects_unknown_tokens() {
        for (from, to) in [
            ("op avgpool gap", "op meanpool gap"),
            ("k8 s8", "k8 s8 z9"),
            ("tensor gap", "edge gap"),
        ] {
            let bad = tiny_text().replace(from, to);
            assert!(parse(&bad).is_err(), "{from} -> {to} should fail");
        }
    }

    #[test]
    fn missing_header_directives_fail() {
        for cut in ["net tiny\n", "seed 7\n", "input input\n"] {
            let bad = tiny_text().replace(cut, "");
            let e = parse(&bad).unwrap_err();
            assert_eq!(e.line, 0, "{e}");
        }
    }

    #[test]
    fn seed_override_roundtrips() {
        let with = tiny_text().replace("-> fc a8w4", "-> fc a8w4 seed=247");
        let g = parse(&with).expect("seed override parses");
        assert_eq!(g.ops[2].seed, Some(247));
        assert_eq!(parse(&print(&g)).unwrap(), g);
    }

    #[test]
    fn rectangular_kernels_roundtrip() {
        let rect = tiny_text().replace("c1 k3 s1 p1", "c1 k3x1 s1 p0");
        // 3x1 kernel, pad 0: out H = 8-3+1 = 6 -> fix the tensor line too.
        let rect = rect.replace("tensor c1 8x8x16", "tensor c1 6x8x16");
        // downstream gap no longer fits; drop those lines for this test
        let rect: String = rect
            .lines()
            .filter(|l| !l.contains("gap") && !l.contains("fc"))
            .map(|l| format!("{l}\n"))
            .collect();
        let g = parse(&rect).expect("rectangular kernel parses");
        let printed = print(&g);
        assert!(printed.contains("k3x1"), "{printed}");
        assert_eq!(parse(&printed).unwrap(), g);
    }

    #[test]
    fn lowered_tiny_matches_hand_built_graph() {
        let g = parse(tiny_text()).unwrap();
        let mut h = Graph::new("tiny", [8, 8, 8], 8, 7);
        let c1 = h.op(
            "c1",
            OpKind::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
            &[h.input],
            8,
            [8, 8, 16],
            QuantParams::scalar(1, 10, 0, 8, 16),
            None,
        );
        let gap = h.op(
            "gap",
            OpKind::AvgPool { k: 8, stride: 8 },
            &[c1],
            8,
            [1, 1, 16],
            QuantParams::scalar(1024, 16, 0, 8, 16),
            None,
        );
        h.op("fc", OpKind::Linear, &[gap], 4, [1, 1, 8], QuantParams::scalar(1, 7, 0, 8, 8), None);
        assert_eq!(g, h);
        let (a, b) = (g.lower().unwrap(), h.lower().unwrap());
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }
}
