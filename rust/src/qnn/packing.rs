//! Dense sub-byte packing/unpacking.
//!
//! Elements are packed little-endian within each byte and bytes are
//! little-endian within each 32-bit word, matching what the Flex-V
//! Slicer&Router extracts in hardware (Fig. 2b: the slicer selects the
//! first or last group of sub-words of a 32-bit input word) and what the
//! Pallas kernel (`python/compile/kernels/mpq_matmul.py`) unpacks with
//! shift/mask — the two sides must agree bit-for-bit.

/// Pack unsigned `bits`-wide values (each in `[0, 2^bits)`) into bytes.
/// `bits` must divide 8 (2, 4, or 8).
pub fn pack_unsigned(vals: &[u32], bits: u8) -> Vec<u8> {
    assert!(matches!(bits, 2 | 4 | 8), "unsupported bit width {bits}");
    let per_byte = 8 / bits as usize;
    let mask = ((1u32 << bits) - 1) as u32;
    let mut out = vec![0u8; vals.len().div_ceil(per_byte)];
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(v <= mask, "value {v} exceeds {bits}-bit range");
        let byte = i / per_byte;
        let sub = (i % per_byte) as u8;
        out[byte] |= ((v & mask) as u8) << (sub * bits);
    }
    out
}

/// Pack signed `bits`-wide values (two's complement) into bytes.
pub fn pack_signed(vals: &[i32], bits: u8) -> Vec<u8> {
    let mask = (1u32 << bits) - 1;
    let unsigned: Vec<u32> = vals
        .iter()
        .map(|&v| {
            debug_assert!(
                v >= -(1 << (bits - 1)) && v < (1 << (bits - 1)),
                "value {v} exceeds signed {bits}-bit range"
            );
            (v as u32) & mask
        })
        .collect();
    pack_unsigned(&unsigned, bits)
}

/// Unpack `n` unsigned `bits`-wide values from packed bytes.
pub fn unpack_unsigned(bytes: &[u8], bits: u8, n: usize) -> Vec<u32> {
    assert!(matches!(bits, 2 | 4 | 8), "unsupported bit width {bits}");
    let per_byte = 8 / bits as usize;
    let mask = (1u32 << bits) - 1;
    (0..n)
        .map(|i| {
            let byte = bytes[i / per_byte] as u32;
            let sub = (i % per_byte) as u8;
            (byte >> (sub * bits)) & mask
        })
        .collect()
}

/// Unpack `n` signed (two's complement) `bits`-wide values.
pub fn unpack_signed(bytes: &[u8], bits: u8, n: usize) -> Vec<i32> {
    let shift = 32 - bits as u32;
    unpack_unsigned(bytes, bits, n)
        .into_iter()
        .map(|v| ((v << shift) as i32) >> shift)
        .collect()
}

/// Extract element `idx` (unsigned) from a packed byte buffer.
pub fn get_unsigned(bytes: &[u8], bits: u8, idx: usize) -> u32 {
    let per_byte = 8 / bits as usize;
    let mask = (1u32 << bits) - 1;
    ((bytes[idx / per_byte] as u32) >> ((idx % per_byte) as u8 * bits)) & mask
}

/// Extract element `idx` (signed) from a packed byte buffer.
pub fn get_signed(bytes: &[u8], bits: u8, idx: usize) -> i32 {
    let shift = 32 - bits as u32;
    ((get_unsigned(bytes, bits, idx) << shift) as i32) >> shift
}

/// Write element `idx` (unsigned, must fit `bits`) into a packed buffer.
pub fn set_unsigned(bytes: &mut [u8], bits: u8, idx: usize, val: u32) {
    let per_byte = 8 / bits as usize;
    let mask = ((1u32 << bits) - 1) as u8;
    let sub = (idx % per_byte) as u8;
    let b = &mut bytes[idx / per_byte];
    *b = (*b & !(mask << (sub * bits))) | (((val as u8) & mask) << (sub * bits));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Prng};

    #[test]
    fn unsigned_roundtrip_exhaustive_small() {
        for bits in [2u8, 4, 8] {
            let max = 1u32 << bits;
            let vals: Vec<u32> = (0..max).collect();
            let packed = pack_unsigned(&vals, bits);
            assert_eq!(unpack_unsigned(&packed, bits, vals.len()), vals);
        }
    }

    #[test]
    fn signed_roundtrip_exhaustive_small() {
        for bits in [2u8, 4, 8] {
            let half = 1i32 << (bits - 1);
            let vals: Vec<i32> = (-half..half).collect();
            let packed = pack_signed(&vals, bits);
            assert_eq!(unpack_signed(&packed, bits, vals.len()), vals);
        }
    }

    #[test]
    fn packing_is_little_endian_in_byte() {
        // values [1, 2, 3, 0] at 2 bits -> byte 0b00_11_10_01 = 0x39
        assert_eq!(pack_unsigned(&[1, 2, 3, 0], 2), vec![0x39]);
        // values [0xA, 0x5] at 4 bits -> byte 0x5A
        assert_eq!(pack_unsigned(&[0xA, 0x5], 4), vec![0x5A]);
    }

    #[test]
    fn density_is_exact() {
        assert_eq!(pack_unsigned(&[0; 16], 2).len(), 4);
        assert_eq!(pack_unsigned(&[0; 8], 4).len(), 4);
        assert_eq!(pack_unsigned(&[0; 4], 8).len(), 4);
        // ragged tail rounds up
        assert_eq!(pack_unsigned(&[0; 5], 2).len(), 2);
    }

    #[test]
    fn prop_roundtrip_random() {
        proptest::check_default(
            |rng: &mut Prng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let n = rng.range(1, 200);
                let vals: Vec<i32> = (0..n).map(|_| rng.bits_signed(bits)).collect();
                (bits, vals)
            },
            |(bits, vals)| {
                let packed = pack_signed(vals, *bits);
                let got = unpack_signed(&packed, *bits, vals.len());
                if &got == vals { Ok(()) } else { Err(format!("got {got:?}")) }
            },
        );
    }

    #[test]
    fn prop_roundtrip_random_unsigned() {
        proptest::check_default(
            |rng: &mut Prng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let n = rng.range(1, 200);
                let vals: Vec<u32> = (0..n).map(|_| rng.bits_unsigned(bits)).collect();
                (bits, vals)
            },
            |(bits, vals)| {
                let packed = pack_unsigned(vals, *bits);
                if packed.len() != vals.len().div_ceil(8 / *bits as usize) {
                    return Err(format!("packed length {}", packed.len()));
                }
                let got = unpack_unsigned(&packed, *bits, vals.len());
                if &got == vals { Ok(()) } else { Err(format!("got {got:?}")) }
            },
        );
    }

    /// Ragged tails: lengths that are NOT a multiple of the per-byte (or
    /// per-word) lane count round up to a whole byte whose unused high
    /// lanes stay zero — the DORY L2 serializer and the DMA both rely on
    /// deterministic (zero) padding.
    #[test]
    fn prop_tail_lanes_are_zero_padded() {
        proptest::check_default(
            |rng: &mut Prng| {
                let bits = *rng.pick(&[2u8, 4]);
                let lanes = 32 / bits as usize;
                // force a ragged length: k whole words plus 1..lanes-1
                let n = rng.range(0, 3) * lanes + rng.range(1, lanes);
                let vals: Vec<u32> = (0..n).map(|_| rng.bits_unsigned(bits)).collect();
                (bits, vals)
            },
            |(bits, vals)| {
                let per_byte = 8 / *bits as usize;
                let packed = pack_unsigned(vals, *bits);
                // every element of the partial last byte beyond n reads 0
                let slots = packed.len() * per_byte;
                for idx in vals.len()..slots {
                    let v = get_unsigned(&packed, *bits, idx);
                    if v != 0 {
                        return Err(format!("tail lane {idx} = {v}, want 0"));
                    }
                }
                // and the roundtrip ignores the padding
                let got = unpack_unsigned(&packed, *bits, vals.len());
                if &got == vals { Ok(()) } else { Err(format!("got {got:?}")) }
            },
        );
    }

    /// Signed tails: same ragged-length invariant through the
    /// sign-extending path, plus per-element get consistency.
    #[test]
    fn prop_tail_roundtrip_signed_with_gets() {
        proptest::check_default(
            |rng: &mut Prng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let per_byte = 8 / bits as usize;
                let n = rng.range(1, 8) * per_byte + rng.range(0, per_byte);
                let vals: Vec<i32> = (0..n).map(|_| rng.bits_signed(bits)).collect();
                (bits, vals)
            },
            |(bits, vals)| {
                let packed = pack_signed(vals, *bits);
                for (i, &want) in vals.iter().enumerate() {
                    let got = get_signed(&packed, *bits, i);
                    if got != want {
                        return Err(format!("elem {i}: got {got} want {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_get_set_consistent() {
        proptest::check_default(
            |rng: &mut Prng| {
                let bits = *rng.pick(&[2u8, 4, 8]);
                let n = rng.range(1, 64);
                let idx = rng.range(0, n);
                let val = rng.bits_unsigned(bits);
                (bits, n, idx, val)
            },
            |&(bits, n, idx, val)| {
                let mut buf = vec![0u8; n.div_ceil(8 / bits as usize)];
                set_unsigned(&mut buf, bits, idx, val);
                let got = get_unsigned(&buf, bits, idx);
                if got == val { Ok(()) } else { Err(format!("got {got} want {val}")) }
            },
        );
    }
}
