//! Cluster DMA engine.
//!
//! Moves data between L2 and TCDM in the background while cores compute —
//! the mechanism DORY's double-buffered tiling relies on (§IV: "since the
//! DMA is not blocking, the calls to the kernels are always overlapped with
//! the asynchronous DMA calls").
//!
//! Model: 64-bit port, 8 bytes per cycle peak, 2-D transfers (row length +
//! strides on both sides, covering HWC tile extraction), a fixed programming
//! latency per request, lowest-priority access to TCDM banks (it yields the
//! cycle whenever a core was granted one of the banks it would touch).
//!
//! Busy/byte counters accumulate into `ClusterStats::dma_busy_cycles` /
//! `dma_bytes`, which is all the trace layer needs: each window's DMA
//! span ([`crate::sim::Cluster::run`]'s tracer) and the per-layer DMA
//! overlap % ([`crate::trace::profile`]) are derived from those deltas,
//! never from extra instrumentation inside the engine.

use super::mem::ClusterMem;

/// Transfer direction.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DmaDir {
    L2ToTcdm,
    TcdmToL2,
}

/// A (possibly 2-D) DMA request. 1-D transfers use `rows = 1`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct DmaRequest {
    pub dir: DmaDir,
    /// External (L2) address.
    pub ext: u32,
    /// TCDM address.
    pub loc: u32,
    /// Contiguous bytes per row.
    pub row_bytes: u32,
    /// Number of rows.
    pub rows: u32,
    /// Byte stride between row starts on the L2 side.
    pub ext_stride: u32,
    /// Byte stride between row starts on the TCDM side.
    pub loc_stride: u32,
}

impl DmaRequest {
    /// Simple contiguous transfer.
    pub fn linear(dir: DmaDir, ext: u32, loc: u32, bytes: u32) -> Self {
        DmaRequest {
            dir,
            ext,
            loc,
            row_bytes: bytes,
            rows: 1,
            ext_stride: bytes,
            loc_stride: bytes,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.row_bytes as u64 * self.rows as u64
    }
}

/// DMA programming latency in cycles (queue push, per request).
const DMA_SETUP_CYCLES: u32 = 16;
/// Peak bytes per cycle of the DMA port.
const DMA_BYTES_PER_CYCLE: u32 = 8;

/// The DMA engine state.
#[derive(Clone, Debug, Default)]
pub struct Dma {
    queue: std::collections::VecDeque<DmaRequest>,
    /// Progress within the current head request (bytes moved).
    progress: u64,
    /// Remaining setup cycles before the head request streams.
    setup_left: u32,
    pub busy_cycles: u64,
    pub bytes_moved: u64,
}

impl Dma {
    pub fn new() -> Self {
        Dma::default()
    }

    /// Enqueue a transfer (non-blocking, as in PULP's cl_dma).
    pub fn push(&mut self, req: DmaRequest) {
        if self.queue.is_empty() {
            self.setup_left = DMA_SETUP_CYCLES;
        }
        self.queue.push_back(req);
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// TCDM banks the next beat would touch (for arbitration); `None` when
    /// idle or still in setup.
    pub fn pending_banks(&self) -> Option<[usize; 2]> {
        if self.setup_left > 0 {
            return None;
        }
        let req = self.queue.front()?;
        let (row, col) = self.cursor(req);
        let tcdm_addr = req.loc + row * req.loc_stride + col;
        let b0 = ClusterMem::bank_of(tcdm_addr);
        // The 8-byte beat touches the next word's bank too when the row
        // still has more than 4 bytes to go.
        let b1 = if req.row_bytes - col > 4 { ClusterMem::bank_of(tcdm_addr + 4) } else { b0 };
        Some([b0, b1])
    }

    fn cursor(&self, req: &DmaRequest) -> (u32, u32) {
        let row = (self.progress / req.row_bytes as u64) as u32;
        let col = (self.progress % req.row_bytes as u64) as u32;
        (row, col)
    }

    /// Advance one cycle. `blocked` = a core won the bank(s) this beat
    /// needed. Returns true if the engine did work this cycle.
    pub fn tick(&mut self, mem: &mut ClusterMem, blocked: bool) -> bool {
        let Some(req) = self.queue.front().copied() else {
            return false;
        };
        if self.setup_left > 0 {
            self.setup_left -= 1;
            self.busy_cycles += 1;
            return true;
        }
        if blocked {
            self.busy_cycles += 1;
            return true;
        }
        // Move up to DMA_BYTES_PER_CYCLE bytes, not crossing a row boundary
        // per beat (row changes may change strides/banks).
        let (row, col) = self.cursor(&req);
        let n = DMA_BYTES_PER_CYCLE.min(req.row_bytes - col) as usize;
        let ext_addr = req.ext + row * req.ext_stride + col;
        let loc_addr = req.loc + row * req.loc_stride + col;
        let (src, dst) = match req.dir {
            DmaDir::L2ToTcdm => (ext_addr, loc_addr),
            DmaDir::TcdmToL2 => (loc_addr, ext_addr),
        };
        mem.dma_copy(src, dst, n);
        self.progress += n as u64;
        self.bytes_moved += n as u64;
        self.busy_cycles += 1;
        if self.progress >= req.total_bytes() {
            self.queue.pop_front();
            self.progress = 0;
            if !self.queue.is_empty() {
                self.setup_left = DMA_SETUP_CYCLES;
            }
        }
        true
    }

    /// Cycles a transfer of `bytes` takes in isolation (setup + streaming)
    /// — used by DORY's solver to estimate tile DMA cost.
    pub fn estimate_cycles(bytes: u64) -> u64 {
        DMA_SETUP_CYCLES as u64 + bytes.div_ceil(DMA_BYTES_PER_CYCLE as u64)
    }

    /// Queued transfers, front first (fast-path window signatures).
    pub fn queued(&self) -> impl Iterator<Item = &DmaRequest> {
        self.queue.iter()
    }

    /// Progress within the head request in bytes (fast-path key).
    pub(crate) fn progress(&self) -> u64 {
        self.progress
    }

    /// Remaining setup cycles of the head request (fast-path key).
    pub(crate) fn setup_left(&self) -> u32 {
        self.setup_left
    }

    /// Drop all queued transfers and reset in-flight state to the
    /// drained end-of-window state (fast-path pure replay: the memoized
    /// write delta already contains every byte these transfers moved).
    pub(crate) fn clear_queue(&mut self) {
        self.queue.clear();
        self.progress = 0;
        self.setup_left = 0;
    }

    /// Perform every queued transfer at once, functionally (fast-path
    /// timing replay: cycles and byte counters are restored from the
    /// memoized window by the caller, so none are touched here).
    pub(crate) fn complete_all_functional(&mut self, mem: &mut ClusterMem) {
        while let Some(req) = self.queue.pop_front() {
            let mut done = self.progress;
            self.progress = 0;
            while done < req.total_bytes() {
                let row = (done / req.row_bytes as u64) as u32;
                let col = (done % req.row_bytes as u64) as u32;
                let n = req.row_bytes - col;
                let ext = req.ext + row * req.ext_stride + col;
                let loc = req.loc + row * req.loc_stride + col;
                let (src, dst) = match req.dir {
                    DmaDir::L2ToTcdm => (ext, loc),
                    DmaDir::TcdmToL2 => (loc, ext),
                };
                mem.copy_range(src, dst, n);
                done += n as u64;
            }
        }
        self.setup_left = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::{L2_BASE, TCDM_BASE};

    #[test]
    fn linear_transfer_moves_bytes() {
        let mut mem = ClusterMem::new();
        let data: Vec<u8> = (0..64u8).collect();
        mem.write_bytes(L2_BASE, &data);
        let mut dma = Dma::new();
        dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE, 64));
        let mut guard = 0;
        while !dma.idle() {
            dma.tick(&mut mem, false);
            guard += 1;
            assert!(guard < 1000);
        }
        assert_eq!(mem.read_bytes(TCDM_BASE, 64), data);
        assert_eq!(dma.bytes_moved, 64);
        // 16 setup + 8 beats
        assert_eq!(dma.busy_cycles, 16 + 8);
    }

    #[test]
    fn strided_2d_transfer() {
        let mut mem = ClusterMem::new();
        // L2 image rows of 16 bytes, extract a 3-row x 8-byte tile
        for r in 0..3u32 {
            let row: Vec<u8> = (0..16u8).map(|c| (r as u8) * 16 + c).collect();
            mem.write_bytes(L2_BASE + r * 16, &row);
        }
        let mut dma = Dma::new();
        dma.push(DmaRequest {
            dir: DmaDir::L2ToTcdm,
            ext: L2_BASE,
            loc: TCDM_BASE,
            row_bytes: 8,
            rows: 3,
            ext_stride: 16,
            loc_stride: 8,
        });
        while !dma.idle() {
            dma.tick(&mut mem, false);
        }
        // tile must be the first 8 bytes of each row, packed
        let got = mem.read_bytes(TCDM_BASE, 24);
        let want: Vec<u8> =
            (0..3u8).flat_map(|r| (0..8u8).map(move |c| r * 16 + c)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn blocked_cycles_make_no_progress() {
        let mut mem = ClusterMem::new();
        let mut dma = Dma::new();
        dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE, 8));
        for _ in 0..16 {
            dma.tick(&mut mem, false); // setup
        }
        let before = dma.bytes_moved;
        dma.tick(&mut mem, true); // blocked by a core
        assert_eq!(dma.bytes_moved, before);
        dma.tick(&mut mem, false);
        assert_eq!(dma.bytes_moved, before + 8);
        assert!(dma.idle());
    }

    #[test]
    fn estimate_matches_isolated_run() {
        let mut mem = ClusterMem::new();
        let mut dma = Dma::new();
        dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE, 100));
        let mut cycles = 0;
        while !dma.idle() {
            dma.tick(&mut mem, false);
            cycles += 1;
        }
        assert_eq!(cycles, Dma::estimate_cycles(100));
    }
}
