//! Golden-artifact validation runtime.
//!
//! Cross-checks the simulated Flex-V kernels against the AOT-compiled
//! JAX/Pallas golden models (HLO text produced by
//! `python/compile/aot.py`). Each artifact `<name>.hlo.txt` ships with
//! a `<name>.meta` sidecar (`key=value` lines) describing the baked
//! shapes/precision so the validator can regenerate the exact inputs on
//! the Rust side.
//!
//! The crate is dependency-free (see the workspace `Cargo.toml`), so
//! this module carries its own minimal [`Error`]/context machinery
//! instead of an external error crate, and the XLA/PJRT leg — executing
//! the HLO on the XLA CPU client as an independent numerical oracle —
//! is compiled only under the off-by-default `pjrt` cargo feature,
//! which requires vendoring `xla` bindings. Without the feature,
//! [`validate_artifacts`] still performs the two-way check **Rust
//! golden == simulated Flex-V kernel** over every artifact in the
//! directory; with it, the check is three-way (sim == XLA == golden).
//! Interchange with XLA is HLO *text*, not serialized protos: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids.

use std::collections::HashMap;
use std::fmt;
use std::path::Path;

use crate::isa::IsaVariant;
use crate::kernels::matmul::{gen_matmul, MatMulTask};
use crate::kernels::requant::RequantCfg;
use crate::qnn::{Precision, QTensor, QuantParams};
use crate::sim::{Cluster, TCDM_BASE};
use crate::util::Prng;

/// Minimal string error of the zero-dependency build (the seed's
/// `anyhow` usage was removed in PR 1; the crate-private `Context`
/// adapters below keep the same call-site ergonomics).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error(s.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::runtime::Error::msg(format!($($arg)*)))
    };
}

/// `anyhow`-style context adapters for `Result`/`Option`.
trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Parsed `.meta` sidecar of an artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a_bits: u8,
    pub w_bits: u8,
    pub out_bits: u8,
    pub shift: u8,
}

pub fn parse_meta(path: &Path) -> Result<ArtifactMeta> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let mut kv = HashMap::new();
    for line in text.lines() {
        if let Some((k, v)) = line.split_once('=') {
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    let get = |k: &str| -> Result<usize> {
        kv.get(k)
            .with_context(|| format!("{path:?} missing key {k}"))?
            .parse::<usize>()
            .with_context(|| format!("{path:?} bad value for {k}"))
    };
    Ok(ArtifactMeta {
        name: kv.get("name").cloned().unwrap_or_default(),
        m: get("m")?,
        n: get("n")?,
        k: get("k")?,
        a_bits: get("a_bits")? as u8,
        w_bits: get("w_bits")? as u8,
        out_bits: get("out_bits")? as u8,
        shift: get("shift")? as u8,
    })
}

/// Deterministic artifact inputs (shared across all implementations):
/// activations, weights, multipliers, biases.
struct ArtifactInputs {
    a_vals: Vec<u32>,
    w_vals: Vec<i32>,
    mult: Vec<i32>,
    bias: Vec<i32>,
}

fn gen_inputs(m: &ArtifactMeta) -> ArtifactInputs {
    let mut rng = Prng::new(0x60D1 + m.a_bits as u64 * 100 + m.w_bits as u64);
    ArtifactInputs {
        a_vals: (0..m.m * m.k).map(|_| rng.bits_unsigned(m.a_bits)).collect(),
        w_vals: (0..m.n * m.k).map(|_| rng.bits_signed(m.w_bits)).collect(),
        mult: (0..m.n).map(|_| rng.range_i64(1, 6) as i32).collect(),
        bias: (0..m.n).map(|_| rng.range_i64(-64, 64) as i32).collect(),
    }
}

/// The Rust reference (golden) requantized MatMul over the artifact inputs.
fn rust_golden(m: &ArtifactMeta, inp: &ArtifactInputs) -> Vec<i32> {
    let q = QuantParams {
        mult: inp.mult.clone(),
        shift: m.shift,
        bias: inp.bias.clone(),
        out_bits: m.out_bits,
    };
    (0..m.m)
        .flat_map(|row| {
            let (a_vals, w_vals, q) = (&inp.a_vals, &inp.w_vals, &q);
            (0..m.n).map(move |ch| {
                let acc: i64 = (0..m.k)
                    .map(|kk| a_vals[row * m.k + kk] as i64 * w_vals[ch * m.k + kk] as i64)
                    .sum();
                q.requant(acc as i32, ch) as i32
            })
        })
        .collect()
}

/// Simulate the Flex-V MatMul kernel on the artifact inputs and compare
/// against `golden` bit-exactly. (The other ISAs are covered by the kernel
/// unit tests against the same Rust golden.)
fn sim_check(m: &ArtifactMeta, inp: &ArtifactInputs, golden: &[i32]) -> Result<()> {
    let prec = Precision::new(m.a_bits, m.w_bits);
    let a_pitch = (m.k.div_ceil(32 / m.a_bits as usize) * 4) as u32;
    let w_pitch = crate::dory::deploy::w_row_pitch(m.k, m.a_bits, m.w_bits);
    let a_base = TCDM_BASE;
    let w_base = a_base + (m.m as u32) * a_pitch;
    let mult_base = w_base + m.n as u32 * w_pitch;
    let bias_base = mult_base + 4 * m.n as u32;
    let out_base = bias_base + 4 * m.n as u32;
    let mut cl = Cluster::pulp();
    let ka = a_pitch as usize * 8 / m.a_bits as usize;
    let mut a_t = QTensor::zeros(&[m.m, ka], m.a_bits, false);
    for row in 0..m.m {
        for kk in 0..m.k {
            a_t.set_u(row * ka + kk, inp.a_vals[row * m.k + kk]);
        }
    }
    let kw = w_pitch as usize * 8 / m.w_bits as usize;
    let mut w_t = QTensor::zeros(&[m.n, kw], m.w_bits, true);
    for ch in 0..m.n {
        for kk in 0..m.k {
            w_t.set_i(ch * kw + kk, inp.w_vals[ch * m.k + kk]);
        }
    }
    cl.mem.write_bytes(a_base, &a_t.data);
    cl.mem.write_bytes(w_base, &w_t.data);
    for ch in 0..m.n {
        cl.mem.store_u32(mult_base + 4 * ch as u32, inp.mult[ch] as u32);
        cl.mem.store_u32(bias_base + 4 * ch as u32, inp.bias[ch] as u32);
    }
    let task = MatMulTask {
        m: m.m,
        n: m.n,
        k: m.k,
        prec,
        a_base,
        a_pitch,
        w_base,
        w_pitch,
        out_base,
        out_pitch: (m.n * m.out_bits as usize / 8) as u32,
        quant: RequantCfg { mult_base, bias_base, shift: m.shift, out_bits: m.out_bits },
    };
    cl.load_programs((0..8).map(|c| gen_matmul(IsaVariant::FlexV, &task, c, 8)).collect());
    cl.run();
    let out_bytes = cl.mem.read_bytes(out_base, m.m * m.n * m.out_bits as usize / 8);
    for row in 0..m.m {
        for ch in 0..m.n {
            let idx = row * m.n + ch;
            let want = golden[idx] as u32;
            let got = crate::qnn::packing::get_unsigned(&out_bytes, m.out_bits, idx);
            if got != want {
                bail!("simulator != golden at ({row},{ch}): {got} vs {want}");
            }
        }
    }
    Ok(())
}

/// Run the cross-check over every artifact in `dir`: simulator kernel ==
/// Rust golden (== XLA golden with the `pjrt` feature), bit-exact.
/// Returns the number of artifact checks performed.
pub fn validate_artifacts(dir: &str) -> Result<usize> {
    let dir = Path::new(dir);
    if !dir.exists() {
        bail!("artifact dir {dir:?} missing — run `make artifacts` first");
    }
    #[cfg(feature = "pjrt")]
    let rt = pjrt::GoldenRuntime::cpu()?;
    let mut checked = 0;
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "meta").unwrap_or(false))
        .collect();
    entries.sort();
    for meta_path in entries {
        let meta = parse_meta(&meta_path)?;
        let hlo_path = meta_path.with_extension("hlo.txt");
        if !hlo_path.exists() {
            bail!("{hlo_path:?} missing for {meta_path:?}");
        }
        let inputs = gen_inputs(&meta);
        let golden = rust_golden(&meta, &inputs);
        #[cfg(feature = "pjrt")]
        {
            let exe = rt.load(&hlo_path, meta.clone())?;
            pjrt::xla_check(&exe, &inputs, &golden)
                .with_context(|| format!("artifact {}", meta.name))?;
        }
        sim_check(&meta, &inputs, &golden).with_context(|| format!("artifact {}", meta.name))?;
        let legs = if cfg!(feature = "pjrt") {
            "sim == XLA == golden"
        } else {
            "sim == golden (XLA leg off: no pjrt feature)"
        };
        println!(
            "  ok: {} (m={} n={} k={} a{}w{}) [{legs}]",
            meta.name, meta.m, meta.n, meta.k, meta.a_bits, meta.w_bits
        );
        checked += 1;
    }
    if checked == 0 {
        bail!("no artifacts found in {dir:?}");
    }
    Ok(checked)
}

/// The PJRT/XLA leg. Compiles the HLO-text artifacts on the XLA CPU
/// client and runs them as an independent numerical oracle. Requires the
/// `xla` bindings crate; only built with `--features pjrt`.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;

    /// A loaded golden executable.
    pub struct GoldenExe {
        exe: xla::PjRtLoadedExecutable,
        pub meta: ArtifactMeta,
    }

    /// The PJRT CPU client plus loaded artifacts.
    pub struct GoldenRuntime {
        client: xla::PjRtClient,
    }

    impl GoldenRuntime {
        pub fn cpu() -> Result<Self> {
            Ok(GoldenRuntime { client: xla::PjRtClient::cpu().context("pjrt cpu client")? })
        }

        /// Load + compile one artifact.
        pub fn load(&self, hlo_path: &Path, meta: ArtifactMeta) -> Result<GoldenExe> {
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().context("non-utf8 path")?,
            )
            .context("parsing hlo text")?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("compiling hlo")?;
            Ok(GoldenExe { exe, meta })
        }
    }

    impl GoldenExe {
        /// Execute the golden MatMul: unpacked activations `[m, k]` (i32),
        /// packed weight words `[n, kw]` (i32), `mult[n]`, `bias[n]` →
        /// `[m, n]` requantized outputs (i32).
        pub fn run_matmul(
            &self,
            a: &[i32],
            w_words: &[i32],
            mult: &[i32],
            bias: &[i32],
        ) -> Result<Vec<i32>> {
            let m = &self.meta;
            let kw = w_words.len() / m.n;
            let a_lit = xla::Literal::vec1(a)
                .reshape(&[m.m as i64, m.k as i64])
                .context("reshape a")?;
            let w_lit = xla::Literal::vec1(w_words)
                .reshape(&[m.n as i64, kw as i64])
                .context("reshape w")?;
            let mult_lit = xla::Literal::vec1(mult);
            let bias_lit = xla::Literal::vec1(bias);
            let result = self
                .exe
                .execute::<xla::Literal>(&[a_lit, w_lit, mult_lit, bias_lit])
                .context("execute")?[0][0]
                .to_literal_sync()
                .context("fetch result")?;
            let out = result.to_tuple1().context("untuple")?;
            out.to_vec::<i32>().context("to_vec")
        }
    }

    /// XLA-vs-Rust-golden comparison (packed weights, word-wise,
    /// little-endian like the HW).
    pub fn xla_check(exe: &GoldenExe, inp: &ArtifactInputs, golden: &[i32]) -> Result<()> {
        let m = &exe.meta;
        let kw_words = (m.k * m.w_bits as usize).div_ceil(32);
        let mut w_words = vec![0i32; m.n * kw_words];
        for ch in 0..m.n {
            for kk in 0..m.k {
                let bit = kk * m.w_bits as usize;
                let (word, off) = (bit / 32, bit % 32);
                let v = (inp.w_vals[ch * m.k + kk] as u32) & ((1u32 << m.w_bits) - 1);
                w_words[ch * kw_words + word] |= (v << off) as i32;
            }
        }
        let a_i32: Vec<i32> = inp.a_vals.iter().map(|&v| v as i32).collect();
        let xla_out = exe.run_matmul(&a_i32, &w_words, &inp.mult, &inp.bias)?;
        if xla_out != golden {
            bail!(
                "XLA golden != Rust golden (first diff at {:?})",
                xla_out.iter().zip(golden).position(|(a, b)| a != b)
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn meta_roundtrip() {
        let dir = std::env::temp_dir().join("flexv_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.meta");
        let mut f = std::fs::File::create(&p).unwrap();
        writeln!(f, "name=mpq_matmul_a8w4\nm=16\nn=8\nk=64\na_bits=8\nw_bits=4\nout_bits=8\nshift=10").unwrap();
        let meta = parse_meta(&p).unwrap();
        assert_eq!(meta.m, 16);
        assert_eq!(meta.w_bits, 4);
        assert_eq!(meta.name, "mpq_matmul_a8w4");
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(validate_artifacts("/nonexistent_dir_xyz").is_err());
    }

    /// Without HLO artifacts on disk, the sim-vs-golden legs can still be
    /// exercised directly from a synthetic meta.
    #[test]
    fn sim_matches_rust_golden_synthetic_meta() {
        let meta = ArtifactMeta {
            name: "synthetic_a8w4".into(),
            m: 8,
            n: 8,
            k: 32,
            a_bits: 8,
            w_bits: 4,
            out_bits: 8,
            shift: 10,
        };
        let inputs = gen_inputs(&meta);
        let golden = rust_golden(&meta, &inputs);
        sim_check(&meta, &inputs, &golden).expect("sim == golden");
    }
}
