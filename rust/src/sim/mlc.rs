//! The Mac&Load Controller (MLC) — hardware address generation (§III,
//! Fig. 4 and Fig. 6).
//!
//! Each operand stream (activations, weights) has a channel that walks a
//! two-dimensional strided pattern: the pointer advances by `stride` for
//! each of `skip` innermost iterations, then a `rollback` is applied (the
//! rollback value encodes "undo the innermost sweep and advance one
//! outermost step", exactly as the paper describes). The paper notes this
//! pattern would cost ~30% instruction overhead in software; here it rides
//! along with the Mac&Load write-back for free.

/// One MLC address channel (there are two: activations and weights).
#[derive(Clone, Copy, Debug, Default)]
pub struct MlcChannel {
    /// Current pointer (`{w,a}_addr` register in Fig. 4).
    pub addr: u32,
    /// Innermost-direction stride (`{w,a}_stride` CSR).
    pub stride: i32,
    /// Applied after `skip` innermost steps (`{w,a}_rollback` CSR).
    pub rollback: i32,
    /// Innermost iterations per sweep (`{w,a}_skip` CSR).
    pub skip: u32,
    /// Hardware counter within the sweep.
    pub cnt: u32,
}

impl MlcChannel {
    /// Address the next Mac&Load would use, without advancing (the ISS
    /// arbitration phase peeks before committing).
    pub fn peek(&self) -> u32 {
        self.addr
    }

    /// Consume one address and advance the pattern.
    pub fn next(&mut self) -> u32 {
        let a = self.addr;
        self.cnt += 1;
        if self.skip > 0 && self.cnt >= self.skip {
            self.addr = self.addr.wrapping_add(self.rollback as u32);
            self.cnt = 0;
        } else {
            self.addr = self.addr.wrapping_add(self.stride as u32);
        }
        a
    }

    /// Program the channel (CSR writes `{w,a}_{stride,rollback,skip,base}`).
    pub fn configure(&mut self, base: u32, stride: i32, rollback: i32, skip: u32) {
        self.addr = base;
        self.stride = stride;
        self.rollback = rollback;
        self.skip = skip;
        self.cnt = 0;
    }
}

/// Reference generator for the pattern the MLC implements: `outer`
/// iterations of `skip` inner steps; inner step advances by `stride`,
/// outer step advances by `outer_stride` from the sweep start. Used by
/// tests to validate the rollback encoding.
pub fn reference_pattern(
    base: u32,
    stride: i32,
    skip: u32,
    outer_stride: i32,
    outer: u32,
) -> Vec<u32> {
    let mut out = vec![];
    for o in 0..outer {
        let sweep = base.wrapping_add((outer_stride as u32).wrapping_mul(o));
        for i in 0..skip {
            out.push(sweep.wrapping_add((stride as u32).wrapping_mul(i)));
        }
    }
    out
}

/// Compute the rollback CSR value for a (stride, skip, outer_stride)
/// pattern: undo the `skip-1` inner strides taken, then add one outer
/// stride. (The paper: "rolls back the pointer of all innermost loop
/// iterations and adds the stride of a single outermost loop iteration".)
pub fn rollback_for(stride: i32, skip: u32, outer_stride: i32) -> i32 {
    outer_stride - stride * (skip as i32 - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Prng};

    #[test]
    fn matches_fig6_pattern() {
        // Fig. 6: weights in a 4x2 MatMul: 4 filters' words visited per
        // K-chunk (inner, stride = filter pitch), then move to the next
        // K-chunk (outer, stride = 4 bytes).
        let filter_pitch = 288; // e.g. 3*3*32 bytes at 8 bit
        let mut ch = MlcChannel::default();
        ch.configure(
            0x1000_0000,
            filter_pitch,
            rollback_for(filter_pitch, 4, 4),
            4,
        );
        let got: Vec<u32> = (0..12).map(|_| ch.next()).collect();
        let want = reference_pattern(0x1000_0000, filter_pitch, 4, 4, 3);
        assert_eq!(got, want);
    }

    #[test]
    fn peek_does_not_advance() {
        let mut ch = MlcChannel::default();
        ch.configure(100, 4, 0, 0);
        assert_eq!(ch.peek(), 100);
        assert_eq!(ch.peek(), 100);
        assert_eq!(ch.next(), 100);
        assert_eq!(ch.peek(), 104);
    }

    #[test]
    fn skip_zero_is_pure_linear() {
        let mut ch = MlcChannel::default();
        ch.configure(0, 8, -100, 0);
        let got: Vec<u32> = (0..5).map(|_| ch.next()).collect();
        assert_eq!(got, vec![0, 8, 16, 24, 32]);
    }

    #[test]
    fn prop_mlc_equals_reference_nested_loops() {
        proptest::check_default(
            |rng: &mut Prng| {
                let base = 0x1000_0000u32 + rng.range(0, 1024) as u32 * 4;
                let stride = rng.range_i64(-64, 64) as i32 * 4;
                let skip = rng.range(1, 9) as u32;
                let outer_stride = rng.range_i64(-64, 64) as i32 * 4;
                let outer = rng.range(1, 8) as u32;
                (base, stride, skip, outer_stride, outer)
            },
            |&(base, stride, skip, outer_stride, outer)| {
                let mut ch = MlcChannel::default();
                ch.configure(base, stride, rollback_for(stride, skip, outer_stride), skip);
                let got: Vec<u32> =
                    (0..skip * outer).map(|_| ch.next()).collect();
                let want = reference_pattern(base, stride, skip, outer_stride, outer);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("MLC {got:?} != reference {want:?}"))
                }
            },
        );
    }
}
