//! Flex-V CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's evaluation artifacts (Tables I-IV,
//! Fig. 7), run single kernels or full networks on the simulated cluster,
//! and cross-validate the simulator against the AOT JAX/Pallas golden
//! models through PJRT.

use flexv::isa::IsaVariant;
use flexv::qnn::Precision;
use flexv::report;

fn usage() -> ! {
    eprintln!(
        "flexv — RISC-V mixed-precision QNN cluster simulator (paper reproduction)

USAGE: flexv <command> [options]

COMMANDS:
  table1            Table I   platform landscape (This-Work row measured)
  table2            Table II  area / fmax / power model
  table3            Table III MatMul kernel grid (MAC/cycle, TOPS/W)
  fig7              Fig. 7    conv-layer grid + speedup ratios
  table4 [--quick]  Table IV  end-to-end networks (use --quick for 96x96)
  all [--quick]     everything above, in order
  run-layer <isa> <aXwY>   run the benchmark conv on one ISA/precision
  dump-kernel <isa> <aXwY> [n]  disassemble the generated MatMul kernel
                           (first n instructions, default 60; cf. Fig. 5)
  run-net <isa> <model> [--quick] [--no-fastpath]
          [--fidelity fast|pipeline] [--trace-out FILE]
                    run one network end-to-end. <model> is a zoo name
                    (see `qir` below) or a path to a .qir file
                    (--model FILE.qir works too); --fidelity picks the
                    core timing tier (pipeline adds Mac&Load write-back
                    port and sub-word realignment stalls; outputs are
                    bit-identical across tiers); --trace-out writes a
                    Chrome-trace JSON (load in ui.perfetto.dev) of the
                    cycle-domain timeline: per-core kernel spans with
                    stall counters, DMA spans, per-layer spans
  profile <model> [--isa I] [--tuned] [--full]
                    per-layer cycle profile of one network: cycles,
                    MAC/cycle, stall breakdown (conflict / load-use /
                    branch / barrier %), DMA overlap %, and the chosen
                    kernel lowering. With --tuned, also runs the
                    autotuner and explains each per-layer win (what
                    changed, which stalls went away). <model> may be a
                    unique prefix, e.g. `profile resnet20`
  qir export <model> [--out FILE]
                    print (or write) the canonical .qir text of a zoo
                    model — byte-identical to the committed file under
                    models/ (CI diffs them)
  qir check FILE... parse + validate .qir files; exits 1 on the first
                    malformed file. Zoo names: mnv1-8b | mnv1-8b4b |
                    resnet20-4b2b | dscnn-8b4b | resdw-8b4b | mixer-8b4b
  tune [<model>|all] [--isa I] [--full] [--fidelity fast|pipeline]
       [--out FILE]
                    simulator-in-the-loop autotuner: per layer, measure
                    candidate plans (tile shapes, kernel lowerings incl.
                    sw-unpack, core counts 4/8) on the cluster simulator
                    and pick by measured cycles; prints the per-layer
                    wins and the measured default → tuned totals (tuned
                    is never worse — the analytic default is always a
                    candidate). --fidelity pipeline re-confirms each
                    non-default winner under the pipeline-accurate core
                    tier and drops wins that do not survive there.
                    --out persists the TuneCache as text
  serve-bench [--shards N] [--requests N] [--max-batch N] [--full] [--exact]
              [--workers N] [--sequential] [--no-fastpath] [--tuned]
              [--fidelity fast|pipeline]
              [--trace steady|poisson|bursty|diurnal] [--slo]
              [--autoscale MIN:MAX] [--mean-gap CYCLES] [--seed N]
              [--trace-out FILE]
              [--federation N] [--router hash|least-loaded|locality]
              [--faults SPEC] [--rollout [CYCLE]]
              [--power-cap MW] [--dvfs race|steady|slo|fixed-point]
              [--models a,b,c]
                    replay a mixed 3-model traffic trace on a
                    multi-cluster serving fleet; reports req/s, p50/p99
                    latency, MAC/cycle, energy/request, plan-cache hits.
                    --models swaps the default paper mix for a
                    comma-separated list of zoo models (equal weights).
                    --trace picks a generated arrival shape (default:
                    the legacy uniform-gap trace); --slo attaches the
                    standard 3-tier class mix (priorities + deadlines,
                    EDF scheduling, shed-before-simulate) and reports
                    per-class p50/p99 latency and deadline-miss rates;
                    --autoscale MIN:MAX runs the elastic shard pool
                    (queue-pressure wake, idle park, cold model load on
                    wake) and reports the occupancy timeline.
                    Shard batches simulate on a host thread pool
                    (--workers N caps it, --sequential forces 1) and
                    steady-state windows replay via the sim fast path
                    (--no-fastpath disables); both knobs change only
                    wall-clock time, never a simulated number.
                    --tuned autotunes each model's per-layer plans on
                    first dispatch (deterministic, once per model) and
                    reports the measured tuned-vs-default cycle delta.
                    --trace-out FILE writes a Chrome-trace JSON of the
                    fleet timeline (request lifecycles, batches, shard
                    occupancy, shed/park/wake events) — byte-identical
                    across --workers and fast-path settings.
                    --federation N federates N identical regions behind
                    a deterministic router (--router, default hash);
                    --faults injects a seeded fault schedule at fixed
                    simulated cycles — comma-separated tokens
                    fail@CYCLE:rR.sS+DUR (shard down, in-flight work
                    re-queued), slow@CYCLE:rR.sSxF+DUR (Fx straggler,
                    timing only), throttle@CYCLE:rR.sS+DUR (thermal
                    throttle: batches clamped to the efficiency
                    operating point), auto:K (K events from --seed) —
                    with priority-preserving failover; --rollout [CYCLE]
                    drains the last region at CYCLE (default mid-trace),
                    compiles tuned plans off-path, and switches it warm
                    with zero dropped requests.
                    --dvfs picks the operating-point governor (race =
                    race-to-idle at the boost point, steady = always
                    efficiency, slo = per-priority tier, or pin one of
                    boost|nominal|efficiency; default nominal, which
                    reproduces pre-DVFS numbers exactly); --power-cap MW
                    caps the fleet's busy-power bound — dispatch
                    downgrades or defers batches so simulated power
                    never exceeds it (with --federation the cap is split
                    evenly across regions). Reports, fault log and
                    trace stay byte-identical across --workers and
                    fast-path settings at a fixed seed and fault plan
  bench-report [--suite kernels|e2e|autotune|serve|all] [--out FILE]
               [--out-dir DIR] [--full] [--workers N]
               [--fidelity fast|pipeline]
                    run benchmark suites and write machine-readable
                    BENCH_<suite>.json artifacts (git rev, seed, sim
                    config, one row per metric: MAC/cycle, TOPS/W,
                    cycles, uJ/req, p50/p99, tuned-vs-default deltas).
                    Deterministic: two runs on one commit emit
                    identical bytes; --workers moves wall-clock only.
                    --fidelity pipeline re-measures the kernels suite
                    under the pipeline-accurate core tier (keep its
                    artifact out of baselines/ — those are fast-tier)
  regress [--suite ...] [--baseline DIR] [--current DIR]
          [--tol-cycles N] [--tol-power PCT] [--bless] [--full]
                    compare fresh artifacts (or --current DIR) against
                    committed baselines: exact (simulated-cycle) rows
                    must match within --tol-cycles (default 0), analog
                    (energy-model) rows within --tol-power (default
                    2%); prints a per-metric drift table and the
                    reproduction distance from the paper's Table III/IV
                    anchors, exits 1 on drift. --bless (re)pins the
                    baselines to the current run
  validate [dir]    cross-check simulator vs AOT golden artifacts (PJRT)

ISAs: ri5cy | mpic | xpulpnn | flexv"
    );
    std::process::exit(2);
}

/// Value of a `--name <n>` style flag.
fn flag_val(args: &[String], name: &str) -> Option<usize> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
}

/// String value of a `--name <s>` style flag.
fn flag_str<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Parse `--autoscale MIN:MAX`.
fn parse_autoscale(s: &str) -> flexv::serve::AutoscaleConfig {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() == 2 {
        if let (Ok(min), Ok(max)) = (parts[0].parse(), parts[1].parse()) {
            if min >= 1 && min <= max {
                return flexv::serve::AutoscaleConfig::range(min, max);
            }
        }
    }
    eprintln!("bad --autoscale '{s}', expected MIN:MAX with 1 <= MIN <= MAX");
    usage()
}

fn parse_isa(s: &str) -> IsaVariant {
    IsaVariant::from_name(s).unwrap_or_else(|| {
        eprintln!("unknown ISA '{s}'");
        usage()
    })
}

/// Core timing tier from `--fidelity fast|pipeline` (default fast).
fn parse_fidelity(args: &[String]) -> flexv::sim::CoreFidelity {
    match flag_str(args, "--fidelity") {
        None => flexv::sim::CoreFidelity::Fast,
        Some(s) => flexv::sim::CoreFidelity::from_name(s).unwrap_or_else(|| {
            eprintln!("unknown fidelity '{s}' (expected fast | pipeline)");
            usage()
        }),
    }
}

fn parse_prec(s: &str) -> Precision {
    let s = s.trim_start_matches('a');
    let parts: Vec<&str> = s.split('w').collect();
    if parts.len() == 2 {
        if let (Ok(a), Ok(w)) = (parts[0].parse(), parts[1].parse()) {
            return Precision::new(a, w);
        }
    }
    eprintln!("bad precision '{s}', expected e.g. a8w4");
    usage()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    match args.first().map(|s| s.as_str()) {
        Some("table1") => print!("{}", report::table1()),
        Some("table2") => print!("{}", report::table2()),
        Some("table3") => print!("{}", report::table3()),
        Some("fig7") => print!("{}", report::fig7()),
        Some("table4") => print!("{}", report::table4(quick)),
        Some("all") => {
            print!("{}", report::table1());
            println!();
            print!("{}", report::table2());
            println!();
            print!("{}", report::table3());
            println!();
            print!("{}", report::fig7());
            println!();
            print!("{}", report::table4(quick));
        }
        Some("run-layer") => {
            if args.len() < 3 {
                usage();
            }
            let isa = parse_isa(&args[1]);
            let prec = parse_prec(&args[2]);
            let stats = report::workloads::conv_fig7_stats(isa, prec);
            let em = flexv::power::EnergyModel::default();
            println!(
                "{} {} conv 64x3x3x32 @16x16x32: {:.1} MAC/cycle, {:.2} TOPS/W, {} cycles, {} instrs",
                isa,
                prec,
                stats.macs_per_cycle(),
                em.tops_per_watt(isa, &stats, prec.a_bits.max(prec.w_bits)),
                stats.cycles,
                stats.total_instrs(),
            );
        }
        Some("run-net") => {
            if args.len() < 3 {
                usage();
            }
            let isa = parse_isa(&args[1]);
            let hw = if quick { 96 } else { 224 };
            let model = flag_str(&args, "--model")
                .or_else(|| args.get(2).filter(|s| !s.starts_with("--")).map(|s| s.as_str()))
                .unwrap_or_else(|| {
                    eprintln!("run-net: missing <model>\n");
                    usage()
                });
            let net = flexv::models::by_name(model, hw).unwrap_or_else(|e| {
                eprintln!("{e}");
                usage()
            });
            let fastpath = !args.iter().any(|a| a == "--no-fastpath");
            let trace_out = flag_str(&args, "--trace-out");
            run_net_verbose(isa, &net, fastpath, parse_fidelity(&args), trace_out);
        }
        Some("profile") => run_profile(&args),
        Some("tune") => run_tune(&args),
        Some("qir") => run_qir(&args),
        Some("bench-report") => run_bench_report(&args),
        Some("regress") => run_regress(&args),
        Some("serve-bench") => {
            let full = args.iter().any(|a| a == "--full");
            let exact = args.iter().any(|a| a == "--exact");
            let fastpath = !args.iter().any(|a| a == "--no-fastpath");
            let tuned = args.iter().any(|a| a == "--tuned");
            let slo = args.iter().any(|a| a == "--slo");
            let shards = flag_val(&args, "--shards").unwrap_or(4);
            let requests = flag_val(&args, "--requests").unwrap_or(32);
            let max_batch = flag_val(&args, "--max-batch").unwrap_or(8);
            let mean_gap = flag_val(&args, "--mean-gap").unwrap_or(2_000_000) as u64;
            let seed = flag_val(&args, "--seed").map_or(0x5EEB, |s| s as u64);
            let workers = if args.iter().any(|a| a == "--sequential") {
                1
            } else {
                flag_val(&args, "--workers").unwrap_or(0)
            };
            let shape = flag_str(&args, "--trace").map(|s| {
                flexv::serve::TraceShape::from_name(s).unwrap_or_else(|| {
                    eprintln!(
                        "unknown trace shape '{s}' (expected steady | poisson | bursty | diurnal)"
                    );
                    usage()
                })
            });
            // --slo needs the workload generator; default it to steady.
            let shape = match (slo, shape) {
                (true, None) => Some(flexv::serve::TraceShape::Steady),
                (_, s) => s,
            };
            // the pool can never exceed --shards: clamp loudly rather
            // than report a ceiling the fleet cannot reach
            let autoscale = flag_str(&args, "--autoscale").map(|s| {
                let mut a = parse_autoscale(s);
                if a.max_shards > shards {
                    eprintln!(
                        "note: --autoscale max {} clamped to --shards {shards}",
                        a.max_shards
                    );
                    a.max_shards = shards;
                    a.min_shards = a.min_shards.min(shards);
                }
                a
            });
            let hw = if full { 224 } else { 96 };
            let power_cap_mw = flag_str(&args, "--power-cap").map(|s| {
                s.parse::<f64>().ok().filter(|c| *c > 0.0).unwrap_or_else(|| {
                    eprintln!("bad --power-cap '{s}', expected a positive mW value");
                    usage()
                })
            });
            let dvfs =
                flag_str(&args, "--dvfs").map_or_else(flexv::power::DvfsPolicy::default, |s| {
                    flexv::power::DvfsPolicy::from_name(s).unwrap_or_else(|| {
                        eprintln!(
                            "unknown --dvfs '{s}' (expected race | steady | slo | boost | \
                             nominal | efficiency)"
                        );
                        usage()
                    })
                });
            use flexv::serve::{standard_mix, Engine, ServeConfig, SloClass, WorkloadSpec};
            // --models swaps the paper's 3-model mix (45/30/25) for an
            // equal-weight mix over any zoo subset; the default path is
            // byte-identical to the pre---models CLI.
            let nets: Vec<flexv::qnn::Network> = match flag_str(&args, "--models") {
                None => standard_mix(hw),
                Some(list) => list
                    .split(',')
                    .map(str::trim)
                    .filter(|m| !m.is_empty())
                    .map(|m| {
                        flexv::models::by_name(m, hw).unwrap_or_else(|e| {
                            eprintln!("{e}");
                            usage()
                        })
                    })
                    .collect(),
            };
            if nets.is_empty() {
                eprintln!("--models needs at least one model name");
                usage()
            }
            let mix: Vec<f64> = if flag_str(&args, "--models").is_some() {
                vec![1.0 / nets.len() as f64; nets.len()]
            } else {
                vec![0.45, 0.30, 0.25]
            };
            let n_models = nets.len();
            let cfg = ServeConfig {
                shards,
                max_batch,
                exact,
                workers,
                fastpath,
                autoscale,
                tuned,
                fidelity: parse_fidelity(&args),
                power_cap_mw,
                dvfs,
                ..ServeConfig::default()
            };
            if let Some(regions) = flag_val(&args, "--federation") {
                run_serve_federation(
                    &args, cfg, regions, nets, &mix, hw, requests, mean_gap, seed, shape, slo,
                );
                return;
            }
            let mut eng = Engine::new(cfg);
            for net in nets {
                eng.register(net);
            }
            println!(
                "serve-bench: {requests} requests over {n_models} models on {shards} shards \
                 (MNV1 input {hw}x{hw}{}, {}, {}, trace {}{}{}{}{}{}) ...",
                if exact { ", exact mode" } else { "" },
                match workers {
                    0 => "auto workers".to_string(),
                    1 => "sequential".to_string(),
                    n => format!("{n} workers"),
                },
                if fastpath { "fast path on" } else { "fast path off" },
                shape.map_or("legacy".to_string(), |s| s.to_string()),
                if tuned { ", autotuned plans" } else { "" },
                if slo { ", 3-tier SLO" } else { "" },
                autoscale.map_or(String::new(), |a| format!(
                    ", autoscale {}:{}",
                    a.min_shards, a.max_shards
                )),
                if dvfs == flexv::power::DvfsPolicy::default() {
                    String::new()
                } else {
                    format!(", dvfs {}", dvfs.name())
                },
                power_cap_mw.map_or(String::new(), |c| format!(", power cap {c} mW")),
            );
            let trace = match shape {
                None => eng.synthetic_trace(requests, mean_gap, &mix, seed),
                Some(shape) => {
                    let mut spec = WorkloadSpec::new(shape, requests, mean_gap, n_models);
                    spec.mix = mix.clone();
                    spec.seed = seed;
                    if slo {
                        // base deadline: 25x the mean gap — tight enough to
                        // miss under bursts, slack under steady load
                        spec.classes = SloClass::standard_tiers(mean_gap.saturating_mul(25));
                    }
                    eng.workload_trace(&spec)
                }
            };
            let t0 = std::time::Instant::now();
            let m = eng.run_trace(trace);
            let wall = t0.elapsed().as_secs_f64();
            print!("{}", m.render());
            println!(
                "(host: {wall:.1}s wall, {:.1} M simulated cycles/s)",
                m.span_cycles as f64 / wall.max(1e-9) / 1e6
            );
            if let Some(path) = flag_str(&args, "--trace-out") {
                write_trace(path, &eng.build_trace());
            }
        }
        Some("dump-kernel") => {
            if args.len() < 3 {
                usage();
            }
            let isa = parse_isa(&args[1]);
            let prec = parse_prec(&args[2]);
            let n: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(60);
            use flexv::kernels::matmul::{gen_matmul, MatMulTask};
            use flexv::kernels::requant::RequantCfg;
            let task = MatMulTask {
                m: 8,
                n: 8,
                k: 32,
                prec,
                a_base: flexv::sim::TCDM_BASE,
                a_pitch: (32usize.div_ceil(32 / prec.a_bits as usize) * 4) as u32,
                w_base: flexv::sim::TCDM_BASE + 4096,
                w_pitch: 16,
                out_base: flexv::sim::TCDM_BASE + 8192,
                out_pitch: 8,
                quant: RequantCfg {
                    mult_base: flexv::sim::TCDM_BASE + 12288,
                    bias_base: flexv::sim::TCDM_BASE + 12544,
                    shift: 8,
                    out_bits: 8,
                },
            };
            let prog = gen_matmul(isa, &task, 0, 1);
            let listing = flexv::isa::disasm::disasm_program(&prog);
            for line in listing.lines().take(n + 1) {
                println!("{line}");
            }
            if prog.len() > n {
                println!("  ... ({} more instructions)", prog.len() - n);
            }
        }
        Some("validate") => {
            let dir = args.get(1).map(|s| s.as_str()).unwrap_or("artifacts");
            let legs = if cfg!(feature = "pjrt") {
                "sim == XLA == golden"
            } else {
                "sim == Rust golden; build with --features pjrt for the XLA leg"
            };
            match flexv::runtime::validate_artifacts(dir) {
                Ok(n) => println!("validate: {n} artifact checks passed ({legs})"),
                Err(e) => {
                    eprintln!("validate failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n");
            usage()
        }
        None => {
            eprintln!("missing command\n");
            usage()
        }
    }
}

/// Suites selected by `--suite` (default: all four, canonical order).
fn selected_suites(args: &[String]) -> Vec<&'static str> {
    use flexv::report::bench::SUITE_NAMES;
    match flag_str(args, "--suite") {
        None => SUITE_NAMES.to_vec(),
        Some("all") => SUITE_NAMES.to_vec(),
        Some(s) => match SUITE_NAMES.iter().copied().find(|n| *n == s) {
            Some(n) => vec![n],
            None => {
                eprintln!("unknown suite '{s}' (expected {} | all)", SUITE_NAMES.join(" | "));
                usage()
            }
        },
    }
}

/// Shared `--full` / `--workers` / `--fidelity` knobs of the artifact
/// suites (baselines are fast-tier — gate pipeline artifacts only
/// against pipeline artifacts).
fn bench_options(args: &[String]) -> flexv::report::bench::BenchOptions {
    flexv::report::bench::BenchOptions {
        full: args.iter().any(|a| a == "--full"),
        workers: if args.iter().any(|a| a == "--sequential") {
            1
        } else {
            flag_val(args, "--workers").unwrap_or(0)
        },
        fidelity: parse_fidelity(args),
    }
}

/// The `bench-report` subcommand: run the selected suites and write one
/// `BENCH_<suite>.json` per suite (deterministic bytes — CI diffs two
/// consecutive runs byte-for-byte).
fn run_bench_report(args: &[String]) {
    use flexv::report::artifact::BenchArtifact;
    use flexv::report::{bench, regress};
    let opts = bench_options(args);
    let suites = selected_suites(args);
    let out_dir = flag_str(args, "--out-dir").unwrap_or(".");
    let single_out = flag_str(args, "--out");
    if single_out.is_some() && suites.len() != 1 {
        eprintln!("--out needs a single --suite; use --out-dir for several");
        usage()
    }
    if std::fs::create_dir_all(out_dir).is_err() {
        eprintln!("cannot create --out-dir {out_dir}");
        std::process::exit(1);
    }
    for suite in suites {
        let t0 = std::time::Instant::now();
        let art = bench::run_suite(suite, &opts).expect("selected_suites validated the name");
        let path = single_out
            .map(str::to_string)
            .unwrap_or_else(|| format!("{out_dir}/{}", BenchArtifact::file_name(suite)));
        if let Err(e) = std::fs::write(&path, art.to_json()) {
            eprintln!("cannot write {path}: {e}");
            std::process::exit(1);
        }
        println!(
            "bench-report {suite}: {} metrics -> {path}  [{:.1}s]",
            art.rows.len(),
            t0.elapsed().as_secs_f64()
        );
        if let Some(t) = regress::paper_distance(&art) {
            print!("{t}");
        }
    }
}

/// Parse `--tol-power` (`2`, `2%`, `0.5%` — percent either way).
fn parse_tol_power(args: &[String]) -> f64 {
    match flag_str(args, "--tol-power") {
        None => 0.02,
        Some(s) => match s.trim_end_matches('%').parse::<f64>() {
            Ok(v) if v >= 0.0 => v / 100.0,
            _ => {
                eprintln!("bad --tol-power '{s}', expected a percentage like 2%");
                usage()
            }
        },
    }
}

/// The `regress` subcommand: gate the current run against committed
/// baselines, or `--bless` the baselines to the current run.
fn run_regress(args: &[String]) {
    use flexv::report::artifact::BenchArtifact;
    use flexv::report::{bench, regress};
    let opts = bench_options(args);
    let suites = selected_suites(args);
    let baseline_dir = flag_str(args, "--baseline").unwrap_or("baselines");
    let current_dir = flag_str(args, "--current");
    let bless = args.iter().any(|a| a == "--bless");
    let tol = regress::Tolerance {
        exact_abs: flag_val(args, "--tol-cycles").unwrap_or(0) as f64,
        analog_frac: parse_tol_power(args),
    };
    let read_artifact = |path: &str| -> BenchArtifact {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("cannot read {path}: {e}");
            std::process::exit(1);
        });
        BenchArtifact::from_json(&text).unwrap_or_else(|e| {
            eprintln!("{path}: {e}");
            std::process::exit(1);
        })
    };
    let mut failed = false;
    for suite in suites {
        let file = BenchArtifact::file_name(suite);
        let current = match current_dir {
            Some(d) => read_artifact(&format!("{d}/{file}")),
            None => bench::run_suite(suite, &opts).expect("selected_suites validated the name"),
        };
        let base_path = format!("{baseline_dir}/{file}");
        if bless {
            if std::fs::create_dir_all(baseline_dir).is_err() {
                eprintln!("cannot create baseline dir {baseline_dir}");
                std::process::exit(1);
            }
            if let Err(e) = std::fs::write(&base_path, current.to_json()) {
                eprintln!("cannot write {base_path}: {e}");
                std::process::exit(1);
            }
            println!("regress {suite}: blessed {} metrics -> {base_path}", current.rows.len());
            continue;
        }
        if !std::path::Path::new(&base_path).exists() {
            eprintln!(
                "regress {suite}: no baseline at {base_path} — run `flexv regress --bless` \
                 and commit the result"
            );
            failed = true;
            continue;
        }
        let baseline = read_artifact(&base_path);
        let report = regress::compare(&current, &baseline, &tol);
        print!("{}", report.render());
        if let Some(t) = regress::paper_distance(&current) {
            print!("{t}");
        }
        failed |= report.failed();
    }
    if failed {
        eprintln!("regress: FAILED (see drift tables above)");
        std::process::exit(1);
    }
}

/// The `tune` subcommand: run the simulator-in-the-loop autotuner over
/// the model zoo (or one model), print the per-layer wins and the
/// measured totals, and optionally persist the TuneCache. The tuned
/// total is ≤ the analytic total by construction (the analytic default
/// is always a candidate and survives ties).
fn run_tune(args: &[String]) {
    use flexv::dory::autotune::{tune_network, TuneCache, TuneConfig};
    use flexv::dory::{MemBudget, PlanKey};
    use flexv::util::table::{f, Table};
    let full = args.iter().any(|a| a == "--full");
    let hw = if full { 224 } else { 96 };
    let isa = flag_str(args, "--isa").map(parse_isa).unwrap_or(IsaVariant::FlexV);
    let which = args
        .get(1)
        .filter(|s| !s.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("all");
    let names: Vec<&str> = if which == "all" {
        flexv::models::ZOO_NAMES.to_vec()
    } else {
        vec![which]
    };
    let budget = MemBudget::default();
    let n_cores = flexv::CLUSTER_CORES;
    let fidelity = parse_fidelity(args);
    let cfg = TuneConfig {
        // Search on the fast tier, confirm non-default winners at the
        // requested tier (fast == no confirm pass).
        confirm_fidelity: (fidelity != flexv::sim::CoreFidelity::Fast).then_some(fidelity),
        ..TuneConfig::default()
    };
    let mut cache = TuneCache::new();
    for name in names {
        let net = flexv::models::by_name(name, hw).unwrap_or_else(|e| {
            eprintln!("{e}");
            usage()
        });
        let t0 = std::time::Instant::now();
        let tuning = tune_network(&net, isa, budget, n_cores, &cfg);
        let wall = t0.elapsed().as_secs_f64();
        let mut t = Table::new(format!(
            "{} on {} — tuned layers ({} of {} improved)",
            net.name,
            isa,
            tuning.improved_layers(),
            tuning.layers.len()
        ))
        .header(&["layer", "tuned plan", "default cyc", "tuned cyc", "saved%"]);
        for (node, l) in net.nodes.iter().zip(&tuning.layers) {
            if l.tuned_cycles >= l.default_cycles {
                continue;
            }
            let shape = l.shape.map_or(String::new(), |s| format!(" {}x{}", s.rows, s.chs));
            t.row(vec![
                node.layer.name.clone(),
                format!("{} x{}{}", l.isa, l.n_cores, shape),
                l.default_cycles.to_string(),
                l.tuned_cycles.to_string(),
                f((1.0 - l.tuned_cycles as f64 / l.default_cycles.max(1) as f64) * 100.0, 1),
            ]);
        }
        print!("{}", t.render());
        println!(
            "{}: measured per-inference cycles {} (analytic) → {} (tuned), {}% saved  [{wall:.1}s tune]\n",
            net.name,
            tuning.total_default_cycles(),
            tuning.total_tuned_cycles(),
            f(tuning.gain_fraction() * 100.0, 2),
        );
        cache.insert(PlanKey::for_network(&net, isa, budget, n_cores), tuning);
    }
    if let Some(path) = flag_str(args, "--out") {
        std::fs::write(path, cache.to_text()).unwrap_or_else(|e| {
            eprintln!("cannot write {path}: {e}");
            std::process::exit(1);
        });
        println!("tune cache written to {path} ({} networks)", cache.len());
    }
}

/// The `serve-bench --federation N` path: N identical regions behind a
/// deterministic router, with an optional seeded fault plan and live
/// rollout. Shares every engine knob with the single-fleet path.
#[allow(clippy::too_many_arguments)]
fn run_serve_federation(
    args: &[String],
    mut cfg: flexv::serve::ServeConfig,
    regions: usize,
    nets: Vec<flexv::qnn::Network>,
    mix: &[f64],
    hw: usize,
    requests: usize,
    mean_gap: u64,
    seed: u64,
    shape: Option<flexv::serve::TraceShape>,
    slo: bool,
) {
    use flexv::serve::{
        FaultPlan, Federation, FederationConfig, RolloutPlan, RouterPolicy, SloClass, WorkloadSpec,
    };
    if regions == 0 {
        eprintln!("--federation needs at least one region");
        usage()
    }
    // --power-cap is the fleet budget: each region enforces an even
    // share (regions are identical, so even split is the optimum).
    let fleet_cap_mw = cfg.power_cap_mw;
    cfg.power_cap_mw = fleet_cap_mw.map(|c| c / regions as f64);
    let policy = flag_str(args, "--router").map_or(RouterPolicy::ConsistentHash, |s| {
        RouterPolicy::from_name(s).unwrap_or_else(|| {
            eprintln!("unknown --router '{s}' (expected hash | least-loaded | locality)");
            usage()
        })
    });
    // `auto:K` fault cycles and the default rollout cycle scale with the
    // approximate trace span
    let span = mean_gap.saturating_mul(requests as u64).max(1);
    let faults = match flag_str(args, "--faults") {
        None => FaultPlan::none(),
        Some(spec) => {
            FaultPlan::parse(spec, seed, regions, cfg.shards, span).unwrap_or_else(|e| {
                eprintln!("bad --faults '{spec}': {e}");
                usage()
            })
        }
    };
    let rollout = args.iter().position(|a| a == "--rollout").map(|i| {
        let at = args.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or(span / 2);
        RolloutPlan { at, canary: regions - 1 }
    });
    let n_faults = faults.len();
    let n_models = nets.len();
    let mut fed =
        Federation::new(FederationConfig { regions, engine: cfg, policy, faults, rollout });
    for net in nets {
        fed.register(net);
    }
    println!(
        "serve-bench: {requests} requests over {n_models} models, federated across {regions} regions x {} \
         shards (router {}, {} fault events{}{}, MNV1 input {hw}x{hw}) ...",
        cfg.shards,
        policy.name(),
        n_faults,
        rollout.map_or(String::new(), |p| format!(", rollout canary r{} @{}", p.canary, p.at)),
        match fleet_cap_mw {
            Some(c) => format!(
                ", fleet power cap {c} mW ({:.2} mW/region, dvfs {})",
                c / regions as f64,
                cfg.dvfs.name()
            ),
            None => String::new(),
        },
    );
    let trace = match shape {
        None => fed.region(0).synthetic_trace(requests, mean_gap, mix, seed),
        Some(shape) => {
            let mut spec = WorkloadSpec::new(shape, requests, mean_gap, n_models);
            spec.mix = mix.to_vec();
            spec.seed = seed;
            if slo {
                spec.classes = SloClass::standard_tiers(mean_gap.saturating_mul(25));
            }
            fed.workload_trace(&spec)
        }
    };
    let t0 = std::time::Instant::now();
    let m = fed.run_trace(trace);
    let wall = t0.elapsed().as_secs_f64();
    print!("{}", m.render());
    let span_cycles = m.regions.iter().map(|r| r.span_cycles).max().unwrap_or(0);
    println!(
        "(host: {wall:.1}s wall, {:.1} M simulated cycles/s)",
        span_cycles as f64 / wall.max(1e-9) / 1e6
    );
    if let Some(path) = flag_str(args, "--trace-out") {
        write_trace(path, &fed.build_trace());
    }
}

/// Render a recorded trace as Chrome-trace JSON and write it to `path`.
fn write_trace(path: &str, rec: &flexv::trace::Recorder) {
    let json = flexv::trace::chrome::to_chrome_json(rec);
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("cannot write {path}: {e}");
        std::process::exit(1);
    }
    println!("trace written to {path} ({} events)", rec.len());
}

/// Resolve a model name that may be a unique prefix of one of
/// [`flexv::models::ZOO_NAMES`] (`resnet20` -> `resnet20-4b2b`).
fn resolve_model(name: &str) -> &'static str {
    let names = flexv::models::ZOO_NAMES;
    if let Some(exact) = names.iter().copied().find(|n| *n == name) {
        return exact;
    }
    let matches: Vec<&'static str> =
        names.iter().copied().filter(|n| n.starts_with(name)).collect();
    match matches.as_slice() {
        [one] => one,
        [] => {
            eprintln!("unknown network '{name}' (expected one of: {})", names.join(" | "));
            usage()
        }
        many => {
            eprintln!("ambiguous network '{name}' (matches: {})", many.join(" | "));
            usage()
        }
    }
}

/// The `qir` subcommand: `export` prints a zoo model's canonical `.qir`
/// text (byte-identical to the committed file under `models/` — CI
/// diffs the two); `check` parses and validates `.qir` files from disk.
fn run_qir(args: &[String]) {
    match args.get(1).map(|s| s.as_str()) {
        Some("export") => {
            let name = args
                .get(2)
                .filter(|s| !s.starts_with("--"))
                .map(|s| s.as_str())
                .unwrap_or_else(|| {
                    eprintln!("qir export: missing <model>\n");
                    usage()
                });
            let name = resolve_model(name);
            // Paper networks export at their canonical input resolution
            // (MobileNet 224x224); the extension models carry fixed inputs.
            let g = flexv::models::graph_by_name(name, 224).unwrap_or_else(|e| {
                eprintln!("{e}");
                usage()
            });
            let text = flexv::qnn::qir::print(&g);
            match flag_str(args, "--out") {
                None => print!("{text}"),
                Some(path) => {
                    if let Err(e) = std::fs::write(path, &text) {
                        eprintln!("cannot write {path}: {e}");
                        std::process::exit(1);
                    }
                    println!("wrote {path} ({} bytes)", text.len());
                }
            }
        }
        Some("check") => {
            let files: Vec<&str> =
                args[2..].iter().filter(|s| !s.starts_with("--")).map(|s| s.as_str()).collect();
            if files.is_empty() {
                eprintln!("qir check: missing FILE...\n");
                usage()
            }
            for path in files {
                let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                    eprintln!("cannot read {path}: {e}");
                    std::process::exit(1);
                });
                // parse + lower exercises the full validation pipeline:
                // grammar, shape/precision checks, weight synthesis.
                let lowered = flexv::qnn::qir::parse(&text)
                    .map_err(|e| e.to_string())
                    .and_then(|g| g.lower());
                match lowered {
                    Ok(net) => println!(
                        "ok: {path} — {} ({} nodes, {:.1} MMAC, {:.0} kB weights)",
                        net.name,
                        net.nodes.len(),
                        net.total_macs() as f64 / 1e6,
                        net.model_bytes() as f64 / 1024.0
                    ),
                    Err(e) => {
                        eprintln!("{path}: {e}");
                        std::process::exit(1);
                    }
                }
            }
        }
        _ => {
            eprintln!("qir: expected `export <model>` or `check FILE...`\n");
            usage()
        }
    }
}

/// The `profile` subcommand: run one network non-memoized with the
/// trace sink attached and print the per-layer cycle/stall/DMA profile.
/// With `--tuned`, run the autotuner, profile the tuned deployment too,
/// and explain each per-layer win in terms of the profile deltas.
fn run_profile(args: &[String]) {
    use flexv::coordinator::Coordinator;
    use flexv::dory::autotune::{tune_network, TuneConfig};
    use flexv::dory::deploy::{deploy, deploy_tuned};
    use flexv::dory::MemBudget;
    use flexv::qnn::QTensor;
    use flexv::trace::profile::NetworkProfile;
    use flexv::util::table::f;
    use flexv::util::Prng;
    let full = args.iter().any(|a| a == "--full");
    let tuned = args.iter().any(|a| a == "--tuned");
    let hw = if full { 224 } else { 96 };
    let isa = flag_str(args, "--isa").map(parse_isa).unwrap_or(IsaVariant::FlexV);
    let name = args
        .get(1)
        .filter(|s| !s.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or_else(|| {
            eprintln!("profile: missing <model>\n");
            usage()
        });
    let name = resolve_model(name);
    let net = flexv::models::by_name(name, hw).expect("resolve_model returned a known name");
    let n_cores = flexv::CLUSTER_CORES;
    let budget = MemBudget::default();
    let run_profiled = |dep: &flexv::dory::deploy::Deployment| -> NetworkProfile {
        let mut coord = Coordinator::with_fastpath(n_cores);
        // per-layer stall breakdowns need every tile executed, not the
        // memoized representative only
        coord.memoize_tiles = false;
        coord.cluster.tracer = Some(Box::default());
        let mut rng = Prng::new(0xE2E);
        let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
        let res = coord.run(dep, &input);
        NetworkProfile::from_run(&res, dep, n_cores)
    };
    let dep = deploy(&net, isa, budget);
    let base = run_profiled(&dep);
    print!("{}", base.render(&format!("{} on {} — per-layer profile", net.name, isa)));
    if !tuned {
        return;
    }
    println!();
    let tuning = tune_network(&net, isa, budget, n_cores, &TuneConfig::default());
    let tdep = deploy_tuned(&net, isa, budget, &tuning);
    let prof = run_profiled(&tdep);
    print!("{}", prof.render(&format!("{} on {} — tuned profile", net.name, isa)));
    println!("\nautotuner wins, explained by the profile deltas:");
    let mut wins = 0usize;
    for ((t, b), p) in tuning.layers.iter().zip(&base.layers).zip(&prof.layers) {
        if t.tuned_cycles >= t.default_cycles {
            continue;
        }
        wins += 1;
        println!(
            "  {:<12} {} ({}% fewer cycles): stall {}% -> {}%, dma-ovl {}% -> {}%",
            p.name,
            t.describe(),
            f((1.0 - t.tuned_cycles as f64 / t.default_cycles.max(1) as f64) * 100.0, 1),
            f(b.total_stall_pct(), 1),
            f(p.total_stall_pct(), 1),
            f(b.dma_overlap_pct, 1),
            f(p.dma_overlap_pct, 1),
        );
    }
    if wins == 0 {
        println!("  (none — the analytic default already matches the best measured plan)");
    }
    println!(
        "total: {} cycles (default) -> {} cycles (tuned), {}% saved",
        base.total_cycles(),
        prof.total_cycles(),
        f(tuning.gain_fraction() * 100.0, 2),
    );
}

fn run_net_verbose(
    isa: IsaVariant,
    net: &flexv::qnn::Network,
    fastpath: bool,
    fidelity: flexv::sim::CoreFidelity,
    trace_out: Option<&str>,
) {
    use flexv::coordinator::Coordinator;
    use flexv::dory::deploy::deploy;
    use flexv::dory::MemBudget;
    use flexv::qnn::QTensor;
    use flexv::util::Prng;
    println!("network: {} ({} nodes, {:.1} MMAC, {:.0} kB weights)",
        net.name, net.nodes.len(), net.total_macs() as f64 / 1e6,
        net.model_bytes() as f64 / 1024.0);
    let dep = deploy(net, isa, MemBudget::default());
    let mut coord = if fastpath {
        Coordinator::with_fastpath(flexv::CLUSTER_CORES)
    } else {
        Coordinator::new(flexv::CLUSTER_CORES)
    };
    coord.cluster.set_fidelity(fidelity);
    if fidelity != flexv::sim::CoreFidelity::Fast {
        println!("core timing tier: {fidelity}");
    }
    // tile memoization advances the clock only for measured
    // representatives — a trace needs the full cycle-domain timeline
    coord.memoize_tiles = trace_out.is_none();
    if trace_out.is_some() {
        coord.cluster.tracer = Some(Box::default());
    }
    let mut rng = Prng::new(0xE2E);
    let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
    let t0 = std::time::Instant::now();
    let res = coord.run(&dep, &input);
    let wall = t0.elapsed();
    println!("{:<12} {:>12} {:>12} {:>10}", "layer", "cycles", "MACs", "MAC/cyc");
    for l in &res.layers {
        println!(
            "{:<12} {:>12} {:>12} {:>10.2}",
            l.name,
            l.stats.cycles,
            l.macs,
            l.macs_per_cycle()
        );
    }
    println!(
        "TOTAL: {} cycles, {} MACs, {:.2} MAC/cycle  (sim wall time {:.1}s)",
        res.total_cycles(),
        res.total_macs(),
        res.macs_per_cycle(),
        wall.as_secs_f64()
    );
    if let Some(path) = trace_out {
        let mut rec = *coord.cluster.tracer.take().expect("tracer was attached above");
        rec.canonicalize();
        write_trace(path, &rec);
    }
}
