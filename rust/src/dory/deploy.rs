//! Network → execution plan: the DORY code-generation step (§IV).
//!
//! Lays out weights and activations in L2, solves per-layer tiling, and
//! produces the double-buffered [`TileExec`] sequences the coordinator
//! replays on the simulated cluster. This is the analog of DORY's
//! template-based C generation: instead of C files, we generate DMA
//! descriptors plus kernel-launch records whose programs are emitted by
//! [`crate::kernels`] at execution time.

use super::autotune::NetworkTuning;
use super::tiler::{buf_bits, solve_conv_tiling, solve_dw_tiling, TileShape};
use super::{
    conv_tiles, l1_layout, load, store, ExecOverride, KernelCall, LayerPlan, MemBudget, TileExec,
};
use crate::isa::IsaVariant;
use crate::kernels::conv::ConvTask;
use crate::kernels::im2col::ConvGeom;
use crate::kernels::layers::{AddTask, AvgPoolTask, ConcatTask, DwConvTask, MaxPoolTask};
use crate::kernels::requant::RequantCfg;
use crate::qnn::layer::{Layer, LayerKind, Network, NET_INPUT};
use crate::qnn::{Precision, QTensor};
use crate::sim::dma::{DmaDir, DmaRequest};
use crate::sim::L2_BASE;

/// A deployed network: everything the coordinator needs.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub isa: IsaVariant,
    pub plans: Vec<LayerPlan>,
    /// (L2 address, bytes) preloads: serialized weights + quant params.
    pub preload: Vec<(u32, Vec<u8>)>,
    /// L2 address of the network input tensor.
    pub input_addr: u32,
    /// L2 address of every node's output tensor.
    pub node_out: Vec<u32>,
    /// Total L2 bytes used.
    pub l2_used: usize,
}

/// Serialize conv weights `[cout, kh, kw, cin]` into padded GEMM rows.
/// Returns (bytes, w_pitch).
pub fn serialize_conv_weights(w: &QTensor, e_bits: u8) -> (Vec<u8>, u32) {
    let cout = w.shape[0];
    let k: usize = w.shape[1..].iter().product();
    let w_bits = w.bits;
    let pitch = w_row_pitch(k, e_bits, w_bits);
    let mut out = vec![0u8; cout * pitch as usize];
    for f in 0..cout {
        let row: Vec<i32> = (0..k).map(|i| w.get_i(f * k + i)).collect();
        let packed = crate::qnn::packing::pack_signed(&row, w_bits);
        out[f * pitch as usize..f * pitch as usize + packed.len()].copy_from_slice(&packed);
    }
    (out, pitch)
}

/// Weight row pitch for contraction length `k` at kernel effective width
/// `e_bits` (see the kernel generators: the inner loop reads one packed
/// word per `e/w` chunks).
pub fn w_row_pitch(k: usize, e_bits: u8, w_bits: u8) -> u32 {
    let chunks = k.div_ceil(32 / e_bits as usize);
    let u = (e_bits.max(w_bits) / w_bits) as usize;
    (chunks.div_ceil(u) * 4) as u32
}

/// Serialize depthwise weights `[C, kh, kw, 1]` into deployment order
/// `[kh, kw, C]` (tap-major, channels contiguous).
pub fn serialize_dw_weights(w: &QTensor) -> Vec<u8> {
    let (c, kh, kw) = (w.shape[0], w.shape[1], w.shape[2]);
    let mut vals = vec![0i32; c * kh * kw];
    for ch in 0..c {
        for ky in 0..kh {
            for kx in 0..kw {
                vals[(ky * kw + kx) * c + ch] = w.get_i(w.flat(&[ch, ky, kx, 0]));
            }
        }
    }
    crate::qnn::packing::pack_signed(&vals, w.bits)
}

/// Serialize the quant arrays (mult then bias, i32 little-endian).
pub fn serialize_quant(l: &Layer) -> Vec<u8> {
    let mut out = Vec::with_capacity(l.quant.bytes());
    for m in &l.quant.mult {
        out.extend_from_slice(&m.to_le_bytes());
    }
    for b in &l.quant.bias {
        out.extend_from_slice(&b.to_le_bytes());
    }
    out
}

pub(crate) struct L2Alloc {
    cur: u32,
    limit: u32,
}

impl L2Alloc {
    pub(crate) fn new(budget: &MemBudget) -> Self {
        L2Alloc { cur: L2_BASE, limit: L2_BASE + budget.l2 as u32 }
    }
    pub(crate) fn alloc(&mut self, bytes: usize) -> u32 {
        let at = self.cur;
        self.cur = (self.cur + bytes as u32).next_multiple_of(8);
        assert!(self.cur <= self.limit, "L2 exhausted ({} B)", self.cur - L2_BASE);
        at
    }
}

/// Deploy a network for `isa` with the analytic (DMA-cost) tiling
/// objective and the deployment-wide kernel lowering — the untuned
/// baseline. See [`deploy_tuned`] for the measured per-layer variant.
pub fn deploy(net: &Network, isa: IsaVariant, budget: MemBudget) -> Deployment {
    deploy_with(net, isa, budget, None)
}

/// Deploy a network with per-layer plans chosen by the autotuner
/// ([`crate::dory::autotune::tune_network`]): each layer's tile shape,
/// kernel lowering, and core count come from `tuning`, and the plans
/// carry the matching [`ExecOverride`] the coordinator honours. The
/// weight serialization follows each layer's chosen lowering (the GEMM
/// row pitch depends on the kernel's buffer width), so a tuned
/// deployment is self-consistent end to end.
pub fn deploy_tuned(
    net: &Network,
    isa: IsaVariant,
    budget: MemBudget,
    tuning: &NetworkTuning,
) -> Deployment {
    assert_eq!(
        tuning.layers.len(),
        net.nodes.len(),
        "tuning entry count does not match the network"
    );
    deploy_with(net, isa, budget, Some(tuning))
}

fn deploy_with(
    net: &Network,
    isa: IsaVariant,
    budget: MemBudget,
    tuning: Option<&NetworkTuning>,
) -> Deployment {
    net.validate().expect("invalid network");
    let mut l2 = L2Alloc::new(&budget);
    let mut preload = vec![];
    // Activations: input + one region per node output.
    let in_bytes = {
        let [h, w, c] = net.input_shape;
        h * w * c * net.input_bits as usize / 8
    };
    let input_addr = l2.alloc(in_bytes);
    let node_out: Vec<u32> = net
        .nodes
        .iter()
        .map(|n| l2.alloc(n.layer.out_bytes()))
        .collect();
    let src_addr = |src: usize| if src == NET_INPUT { input_addr } else { node_out[src] };

    let mut plans = vec![];
    for (id, node) in net.nodes.iter().enumerate() {
        let l = &node.layer;
        let in_l2 = src_addr(node.inputs[0]);
        let in2_l2 = node.inputs.get(1).map(|&s| src_addr(s));
        let out_l2 = node_out[id];
        let tune = tuning.map(|t| &t.layers[id]);
        let l_isa = tune.map_or(isa, |t| t.isa);
        let mut plan = plan_layer(
            l_isa,
            &budget,
            &mut l2,
            &mut preload,
            l,
            id,
            in_l2,
            in2_l2,
            out_l2,
            tune.and_then(|t| t.shape),
        );
        plan.exec = tune.map(|t| {
            assert!(t.n_cores >= 1, "layer {}: tuned core count must be >= 1", l.name);
            ExecOverride { isa: l_isa, n_cores: t.n_cores }
        });
        plans.push(plan);
    }
    Deployment {
        isa,
        plans,
        preload,
        input_addr,
        node_out,
        l2_used: (l2.cur - L2_BASE) as usize,
    }
}

/// Plan one layer: dispatch on the layer kind. `shape_ovr` overrides the
/// conv tiling solver's choice (autotuner candidates; must be feasible —
/// the L1 layout asserts the budget). Exposed crate-internally so the
/// autotuner can plan candidate layers in isolation with its own scratch
/// L2 allocator.
#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_layer(
    isa: IsaVariant,
    budget: &MemBudget,
    l2: &mut L2Alloc,
    preload: &mut Vec<(u32, Vec<u8>)>,
    l: &Layer,
    id: usize,
    in_l2: u32,
    in2_l2: Option<u32>,
    out_l2: u32,
    shape_ovr: Option<TileShape>,
) -> LayerPlan {
    match &l.kind {
        LayerKind::Conv2d { kh, kw, stride, pad } => plan_conv(
            isa, budget, l2, preload, l, id, in_l2, out_l2, *kh, *kw, *stride, *pad, shape_ovr,
        ),
        LayerKind::DwConv2d { kh, kw, stride, pad } => {
            plan_dw(budget, l2, preload, l, id, in_l2, out_l2, *kh, *kw, *stride, *pad)
        }
        LayerKind::Linear => plan_linear(isa, budget, l2, preload, l, id, in_l2, out_l2),
        LayerKind::MaxPool { k, stride } => plan_maxpool(budget, l, id, in_l2, out_l2, *k, *stride),
        LayerKind::AvgPool { k, stride } => {
            plan_avgpool(budget, l2, preload, l, id, in_l2, out_l2, *k, *stride)
        }
        LayerKind::Add { m1, m2 } => {
            let in2 = in2_l2.expect("Add layer needs a second input address");
            plan_add(budget, l, id, in_l2, in2, out_l2, *m1, *m2)
        }
        LayerKind::Concat => {
            let in2 = in2_l2.expect("Concat layer needs a second input address");
            plan_concat(budget, l, id, in_l2, in2, out_l2)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_conv(
    isa: IsaVariant,
    budget: &MemBudget,
    l2: &mut L2Alloc,
    preload: &mut Vec<(u32, Vec<u8>)>,
    l: &Layer,
    id: usize,
    in_l2: u32,
    out_l2: u32,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    shape_ovr: Option<TileShape>,
) -> LayerPlan {
    let [h, w, cin] = l.in_shape;
    let cout = l.out_shape[2];
    let geom = ConvGeom::square(h, w, cin, cout, kh, kw, stride, pad, l.a_bits);
    let e_bits = buf_bits(&geom, isa);
    let (wbytes, w_pitch) = serialize_conv_weights(l.weights.as_ref().unwrap(), e_bits);
    let w_l2 = l2.alloc(wbytes.len());
    preload.push((w_l2, wbytes));
    let qbytes = serialize_quant(l);
    let q_l2 = l2.alloc(qbytes.len());
    preload.push((q_l2, qbytes));
    let bias_l2 = q_l2 + 4 * cout as u32;

    let out_bits = l.quant.out_bits;
    let shape = shape_ovr
        .or_else(|| solve_conv_tiling(&geom, isa, w_pitch as usize, out_bits, budget.l1))
        .unwrap_or_else(|| panic!("layer {} does not tile into L1", l.name));
    let tiles = conv_tiles(geom.out_h(), cout, shape, h, kh, stride, pad);
    // L1 layout sized for the worst tile.
    let tb = super::tiler::conv_tile_bytes(&geom, w_pitch as usize, out_bits, shape);
    let scratch = crate::kernels::conv::scratch_bytes(
        &ConvTask {
            geom,
            prec: Precision::new(l.a_bits, l.w_bits),
            in_base: 0,
            w_base: 0,
            w_pitch,
            out_base: 0,
            scratch_base: 0,
            quant: RequantCfg { mult_base: 0, bias_base: 0, shift: l.quant.shift, out_bits },
        },
        isa,
        crate::CLUSTER_CORES,
    );
    let lay = l1_layout(
        tb.input,
        tb.weights + tb.quant,
        tb.output,
        0,
        scratch,
        budget.l1,
    );

    let in_row_bytes = (w * cin * l.a_bits as usize) / 8;
    let out_px_bytes = (cout * out_bits as usize) / 8;
    let mut execs = vec![];
    for (i, t) in tiles.iter().enumerate() {
        let b = i % 2;
        let mut loads = vec![
            // input strip (contiguous rows in HWC)
            load(in_l2 + (t.in_r0 * in_row_bytes) as u32, lay.in_buf[b], t.in_rows * in_row_bytes),
            // weight rows + quant slices into the weight buffer
            load(w_l2 + t.c0 as u32 * w_pitch, lay.w_buf[b], t.chs * w_pitch as usize),
        ];
        let mult_l1 = lay.w_buf[b] + (t.chs as u32) * w_pitch;
        let bias_l1 = mult_l1 + 4 * t.chs as u32;
        loads.push(load(q_l2 + 4 * t.c0 as u32, mult_l1, 4 * t.chs));
        loads.push(load(bias_l2 + 4 * t.c0 as u32, bias_l1, 4 * t.chs));

        let tile_geom = ConvGeom {
            h: t.in_rows,
            w,
            cin,
            cout: t.chs,
            kh,
            kw,
            stride,
            pad_t: t.pad_t,
            pad_b: t.pad_b,
            pad_l: pad,
            pad_r: pad,
            a_bits: l.a_bits,
        };
        debug_assert_eq!(tile_geom.out_h(), t.rows, "{}: tile {t:?}", l.name);
        let task = ConvTask {
            geom: tile_geom,
            prec: Precision::new(l.a_bits, l.w_bits),
            in_base: lay.in_buf[b],
            w_base: lay.w_buf[b],
            w_pitch,
            out_base: lay.out_buf[b],
            scratch_base: lay.scratch,
            quant: RequantCfg {
                mult_base: mult_l1,
                bias_base: bias_l1,
                shift: l.quant.shift,
                out_bits,
            },
        };
        let ow = geom.out_w();
        let tile_out_bytes = t.rows * ow * t.chs * out_bits as usize / 8;
        let stores = if t.chs == cout {
            vec![store(lay.out_buf[b], out_l2 + (t.r0 * ow * out_px_bytes) as u32, tile_out_bytes)]
        } else {
            // channel-sliced store: one row per output pixel
            vec![DmaRequest {
                dir: DmaDir::TcdmToL2,
                ext: out_l2
                    + (t.r0 * ow * out_px_bytes) as u32
                    + (t.c0 * out_bits as usize / 8) as u32,
                loc: lay.out_buf[b],
                row_bytes: (t.chs * out_bits as usize / 8) as u32,
                rows: (t.rows * ow) as u32,
                ext_stride: out_px_bytes as u32,
                loc_stride: (t.chs * out_bits as usize / 8) as u32,
            }]
        };
        execs.push(TileExec { loads, kernel: KernelCall::Conv(task), stores });
    }
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: execs,
        macs: l.macs(),
        dotp_bits: l.a_bits.max(l.w_bits),
        exec: None,
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_dw(
    budget: &MemBudget,
    l2: &mut L2Alloc,
    preload: &mut Vec<(u32, Vec<u8>)>,
    l: &Layer,
    id: usize,
    in_l2: u32,
    out_l2: u32,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> LayerPlan {
    let [h, w, c] = l.in_shape;
    let out_bits = l.quant.out_bits;
    let wbytes = serialize_dw_weights(l.weights.as_ref().unwrap());
    let w_l2 = l2.alloc(wbytes.len());
    let w_len = wbytes.len();
    preload.push((w_l2, wbytes));
    let qbytes = serialize_quant(l);
    let q_l2 = l2.alloc(qbytes.len());
    preload.push((q_l2, qbytes));

    let oh = l.out_shape[0];
    let rows =
        solve_dw_tiling(h, w, c, kh, stride, l.a_bits, l.w_bits, out_bits, oh, budget.l1)
            .unwrap_or_else(|| panic!("dw layer {} does not tile", l.name));
    let tiles = conv_tiles(oh, c, super::TileShape { rows, chs: c }, h, kh, stride, pad);
    let in_rows_max = (rows - 1) * stride + kh;
    let in_row_bytes = w * c * l.a_bits as usize / 8;
    let out_row_bytes = l.out_shape[1] * c * out_bits as usize / 8;
    let lay = l1_layout(
        in_rows_max * in_row_bytes,
        w_len + l.quant.bytes(),
        rows * out_row_bytes,
        0,
        0,
        budget.l1,
    );
    let mult_l1 = lay.w_buf[0] + w_len as u32;
    let bias_l1 = mult_l1 + 4 * c as u32;
    let mut execs = vec![];
    for (i, t) in tiles.iter().enumerate() {
        let b = i % 2;
        let mut loads =
            vec![load(in_l2 + (t.in_r0 * in_row_bytes) as u32, lay.in_buf[b], t.in_rows * in_row_bytes)];
        if i == 0 {
            // weights + quant are layer-constant: loaded once, buffer 0
            loads.push(load(w_l2, lay.w_buf[0], w_len));
            loads.push(load(q_l2, mult_l1, 4 * c));
            loads.push(load(q_l2 + 4 * c as u32, bias_l1, 4 * c));
        }
        let task = DwConvTask {
            h: t.in_rows,
            w,
            c,
            kh,
            kw,
            stride,
            pad_t: t.pad_t,
            pad_b: t.pad_b,
            pad_l: pad,
            pad_r: pad,
            w_bits: l.w_bits,
            in_base: lay.in_buf[b],
            w_base: lay.w_buf[0],
            out_base: lay.out_buf[b],
            quant: RequantCfg { mult_base: mult_l1, bias_base: bias_l1, shift: l.quant.shift, out_bits },
        };
        debug_assert_eq!(task.out_h(), t.rows);
        let stores = vec![store(
            lay.out_buf[b],
            out_l2 + (t.r0 * out_row_bytes) as u32,
            t.rows * out_row_bytes,
        )];
        execs.push(TileExec { loads, kernel: KernelCall::Dw(task), stores });
    }
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: execs,
        macs: l.macs(),
        dotp_bits: l.a_bits.max(l.w_bits),
        exec: None,
    }
}

fn plan_linear(
    isa: IsaVariant,
    budget: &MemBudget,
    l2: &mut L2Alloc,
    preload: &mut Vec<(u32, Vec<u8>)>,
    l: &Layer,
    id: usize,
    in_l2: u32,
    out_l2: u32,
) -> LayerPlan {
    let cin: usize = l.in_shape.iter().product();
    let cout = l.out_shape[2];
    let prec = Precision::new(l.a_bits, l.w_bits);
    let geom_e = if isa.native_fmts().contains(&crate::isa::SimdFmt::from_bits(l.a_bits)) {
        l.a_bits
    } else {
        8
    };
    let (wbytes, w_pitch) = serialize_conv_weights(l.weights.as_ref().unwrap(), geom_e);
    let w_l2 = l2.alloc(wbytes.len());
    preload.push((w_l2, wbytes));
    let qbytes = serialize_quant(l);
    let q_l2 = l2.alloc(qbytes.len());
    preload.push((q_l2, qbytes));
    let out_bits = l.quant.out_bits;

    let in_bytes = cin * l.a_bits as usize / 8;
    // channel tile: as many output channels as fit (weights dominate)
    let mut chs = cout;
    while chs > 4 {
        let need =
            2 * (chs * w_pitch as usize + chs * 8 + chs * out_bits as usize / 8 + in_bytes) + 64;
        if need <= budget.l1 && chs * out_bits as usize % 8 == 0 {
            break;
        }
        chs -= 4;
    }
    let lay = l1_layout(
        in_bytes,
        chs * w_pitch as usize + chs * 8,
        chs * out_bits as usize / 8,
        0,
        0,
        budget.l1,
    );
    let mut execs = vec![];
    let mut c0 = 0;
    let mut i = 0;
    while c0 < cout {
        let cc = chs.min(cout - c0);
        let b = i % 2;
        let mut loads = vec![];
        if i == 0 {
            loads.push(load(in_l2, lay.in_buf[0], in_bytes));
        }
        loads.push(load(w_l2 + c0 as u32 * w_pitch, lay.w_buf[b], cc * w_pitch as usize));
        let mult_l1 = lay.w_buf[b] + (cc as u32) * w_pitch;
        let bias_l1 = mult_l1 + 4 * cc as u32;
        loads.push(load(q_l2 + 4 * c0 as u32, mult_l1, 4 * cc));
        loads.push(load(q_l2 + 4 * (cout + c0) as u32, bias_l1, 4 * cc));
        let kernel = KernelCall::Linear {
            prec,
            cin,
            cout: cc,
            in_base: lay.in_buf[0],
            w_base: lay.w_buf[b],
            w_pitch,
            out_base: lay.out_buf[b],
            quant: RequantCfg { mult_base: mult_l1, bias_base: bias_l1, shift: l.quant.shift, out_bits },
        };
        let stores = vec![store(
            lay.out_buf[b],
            out_l2 + (c0 * out_bits as usize / 8) as u32,
            cc * out_bits as usize / 8,
        )];
        execs.push(TileExec { loads, kernel, stores });
        c0 += cc;
        i += 1;
    }
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: execs,
        macs: l.macs(),
        dotp_bits: l.a_bits.max(l.w_bits),
        exec: None,
    }
}

fn plan_maxpool(
    budget: &MemBudget,
    l: &Layer,
    id: usize,
    in_l2: u32,
    out_l2: u32,
    k: usize,
    stride: usize,
) -> LayerPlan {
    let [h, w, c] = l.in_shape;
    let in_bytes = h * w * c * l.a_bits as usize / 8;
    let out_bytes = l.out_bytes();
    let lay = l1_layout(in_bytes, 0, out_bytes, 0, 0, budget.l1);
    let task = MaxPoolTask {
        h,
        w,
        c,
        k,
        stride,
        in_base: lay.in_buf[0],
        out_base: lay.out_buf[0],
    };
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: vec![TileExec {
            loads: vec![load(in_l2, lay.in_buf[0], in_bytes)],
            kernel: KernelCall::MaxPool(task),
            stores: vec![store(lay.out_buf[0], out_l2, out_bytes)],
        }],
        macs: 0,
        dotp_bits: 8,
        exec: None,
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_avgpool(
    budget: &MemBudget,
    l2: &mut L2Alloc,
    preload: &mut Vec<(u32, Vec<u8>)>,
    l: &Layer,
    id: usize,
    in_l2: u32,
    out_l2: u32,
    k: usize,
    stride: usize,
) -> LayerPlan {
    let [h, w, c] = l.in_shape;
    let qbytes = serialize_quant(l);
    let q_l2 = l2.alloc(qbytes.len());
    preload.push((q_l2, qbytes));
    let in_bytes = h * w * c * l.a_bits as usize / 8;
    let out_bytes = l.out_bytes();
    let lay = l1_layout(in_bytes, l.quant.bytes(), out_bytes, 0, 0, budget.l1);
    let bias_l1 = lay.w_buf[0] + 4 * c as u32;
    let task = AvgPoolTask {
        h,
        w,
        c,
        k,
        stride,
        bits: l.a_bits,
        in_base: lay.in_buf[0],
        out_base: lay.out_buf[0],
        quant: RequantCfg {
            mult_base: lay.w_buf[0],
            bias_base: bias_l1,
            shift: l.quant.shift,
            out_bits: l.quant.out_bits,
        },
    };
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: vec![TileExec {
            loads: vec![
                load(in_l2, lay.in_buf[0], in_bytes),
                load(q_l2, lay.w_buf[0], 4 * c),
                load(q_l2 + 4 * c as u32, bias_l1, 4 * c),
            ],
            kernel: KernelCall::AvgPool(task),
            stores: vec![store(lay.out_buf[0], out_l2, out_bytes)],
        }],
        macs: 0,
        dotp_bits: 8,
        exec: None,
    }
}

fn plan_concat(
    budget: &MemBudget,
    l: &Layer,
    id: usize,
    in1_l2: u32,
    in2_l2: u32,
    out_l2: u32,
) -> LayerPlan {
    let [h, w, c1] = l.in_shape;
    let c2 = l.out_shape[2] - c1;
    let bits = l.a_bits as usize;
    let (b1, b2) = (c1 * bits / 8, c2 * bits / 8);
    let bo = b1 + b2;
    let pixels = h * w;
    // pixel-strip tiles: both inputs and the output are double buffered
    let max_px = ((budget.l1 - 64) / (4 * bo)).min(pixels).max(1);
    let lay = l1_layout(max_px * bo, 0, max_px * bo, 0, 0, budget.l1);
    let mut execs = vec![];
    let mut p0 = 0usize;
    let mut i = 0;
    while p0 < pixels {
        let pc = max_px.min(pixels - p0);
        let b = i % 2;
        let x1_l1 = lay.in_buf[b];
        let x2_l1 = lay.in_buf[b] + (max_px * b1) as u32;
        let task = ConcatTask {
            pixels: pc,
            b1,
            b2,
            x1_base: x1_l1,
            x2_base: x2_l1,
            out_base: lay.out_buf[b],
        };
        execs.push(TileExec {
            loads: vec![
                load(in1_l2 + (p0 * b1) as u32, x1_l1, pc * b1),
                load(in2_l2 + (p0 * b2) as u32, x2_l1, pc * b2),
            ],
            kernel: KernelCall::Concat(task),
            stores: vec![store(lay.out_buf[b], out_l2 + (p0 * bo) as u32, pc * bo)],
        });
        p0 += pc;
        i += 1;
    }
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: execs,
        macs: 0,
        dotp_bits: 8,
        exec: None,
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_add(
    budget: &MemBudget,
    l: &Layer,
    id: usize,
    in1_l2: u32,
    in2_l2: u32,
    out_l2: u32,
    m1: i32,
    m2: i32,
) -> LayerPlan {
    let n: usize = l.in_shape.iter().product();
    let bits = l.a_bits;
    let bytes = n * bits as usize / 8;
    // element-range tiles sized to L1 (three buffers, double buffered)
    let max_chunk = (budget.l1 / 6).min(bytes).max(1);
    let lanes = 8 / bits as usize;
    let chunk_bytes = (max_chunk / 4 * 4).max(lanes.max(4));
    let lay = l1_layout(2 * chunk_bytes, 0, chunk_bytes, 0, 0, budget.l1);
    let mut execs = vec![];
    let mut off = 0usize;
    let mut i = 0;
    while off < bytes {
        let cb = chunk_bytes.min(bytes - off);
        let b = i % 2;
        let x1_l1 = lay.in_buf[b];
        let x2_l1 = lay.in_buf[b] + chunk_bytes as u32;
        let task = AddTask {
            n: cb * lanes,
            bits,
            out_bits: l.quant.out_bits,
            m1,
            m2,
            shift: l.quant.shift,
            x1_base: x1_l1,
            x2_base: x2_l1,
            out_base: lay.out_buf[b],
        };
        execs.push(TileExec {
            loads: vec![
                load(in1_l2 + off as u32, x1_l1, cb),
                load(in2_l2 + off as u32, x2_l1, cb),
            ],
            kernel: KernelCall::Add(task),
            stores: vec![store(lay.out_buf[b], out_l2 + off as u32, cb)],
        });
        off += cb;
        i += 1;
    }
    LayerPlan {
        name: l.name.clone(),
        node: id,
        tiles: execs,
        macs: 0,
        dotp_bits: 8,
        exec: None,
    }
}
