//! The 8-core PULP cluster model: lockstep cycle simulation of the cores,
//! the 16-bank TCDM logarithmic interconnect (one request per bank per
//! cycle, rotating round-robin priority), the hardware synchronization
//! unit (barriers with clock-gated waiting) and the background DMA.
//!
//! With [`Cluster::enable_fastpath`], steady-state windows (identical
//! instruction trace, DMA schedule, and arbiter phase) are memoized and
//! replayed instead of re-simulated — bit-exact outputs and cycle
//! counts, validated by the cross-check mode (see [`super::fastpath`]).

use super::core::{Core, CoreState};
use super::dma::{Dma, DmaRequest};
use super::fastpath::{self, FastEntry, FastPath, WindowOutcome};
use super::mem::ClusterMem;
use super::pipeline::CoreFidelity;
use super::stats::{ClusterStats, CoreStats};
use crate::isa::Program;
use crate::trace::Recorder;
use crate::{CLUSTER_CORES, TCDM_BANKS};

/// The cluster simulator.
pub struct Cluster {
    pub mem: ClusterMem,
    pub cores: Vec<Core>,
    pub dma: Dma,
    /// Rotating arbitration priority offset.
    pub(crate) rr: usize,
    /// Global cycle counter.
    pub cycle: u64,
    /// Safety limit to catch runaway programs (0 = unlimited).
    pub max_cycles: u64,
    /// Reused per-cycle arbitration scratch (avoids per-cycle allocation
    /// — see EXPERIMENTS.md §Perf).
    want: Vec<Option<usize>>,
    granted: Vec<bool>,
    /// Core timing tier ([`CoreFidelity::Fast`] by default). Part of the
    /// fast-path structural key: windows recorded under one tier never
    /// replay under the other.
    fidelity: CoreFidelity,
    /// Steady-state window memo (None = every window cycle-simulated).
    fastpath: Option<Box<FastPath>>,
    /// Cycle-domain trace sink (None = tracing disabled, zero overhead).
    ///
    /// Spans are emitted per [`Cluster::run`] window *from the returned
    /// [`ClusterStats`]* — which every fast-path tier reproduces
    /// bit-exactly — so a replayed window re-emits exactly the spans its
    /// recording did and traces stay byte-identical across fast-path
    /// settings. The tracer is never part of the fast-path structural
    /// key and never affects a simulated number; [`Cluster::reset`]
    /// deliberately preserves it (a serve-style driver resets between
    /// requests without losing the trace).
    pub tracer: Option<Box<Recorder>>,
}

impl Cluster {
    pub fn new(n_cores: usize) -> Self {
        Cluster {
            mem: ClusterMem::new(),
            cores: (0..n_cores).map(Core::new).collect(),
            dma: Dma::new(),
            rr: 0,
            cycle: 0,
            max_cycles: 20_000_000_000,
            want: vec![None; n_cores],
            granted: vec![false; n_cores],
            fidelity: CoreFidelity::Fast,
            fastpath: None,
            tracer: None,
        }
    }

    /// Standard 8-core cluster.
    pub fn pulp() -> Self {
        Self::new(CLUSTER_CORES)
    }

    /// A cluster whose cores run under timing tier `f` (see
    /// [`super::pipeline`]).
    pub fn with_fidelity(n_cores: usize, f: CoreFidelity) -> Self {
        let mut cl = Self::new(n_cores);
        cl.set_fidelity(f);
        cl
    }

    /// Switch the core timing tier fleet-wide. Functional results are
    /// tier-independent; cycle counts are not — callers comparing
    /// measurements must keep the tier fixed across them (the autotuner
    /// measures on [`CoreFidelity::Fast`] and confirms winners on a
    /// separate pipeline cluster for exactly this reason).
    pub fn set_fidelity(&mut self, f: CoreFidelity) {
        self.fidelity = f;
        for c in &mut self.cores {
            c.set_fidelity(f);
        }
    }

    /// The active core timing tier.
    pub fn fidelity(&self) -> CoreFidelity {
        self.fidelity
    }

    /// Enable the steady-state fast path with a private window cache
    /// (idempotent; keeps an existing cache). See [`super::fastpath`]
    /// for the replay model.
    pub fn enable_fastpath(&mut self) {
        if self.fastpath.is_none() {
            self.fastpath = Some(Box::default());
        }
    }

    /// Enable the fast path backed by `cache`, which may be shared by
    /// many clusters (a serve fleet pools recordings across shards —
    /// cloning a [`fastpath::WindowCache`] shares the store). Replaces
    /// any existing cache; counters are per cluster either way.
    pub fn enable_fastpath_shared(&mut self, cache: fastpath::WindowCache) {
        self.fastpath = Some(Box::new(FastPath { cache, ..FastPath::default() }));
    }

    /// Drop the fast path and its cache: every subsequent window is
    /// simulated cycle-by-cycle (the `--no-fastpath` escape hatch).
    pub fn disable_fastpath(&mut self) {
        self.fastpath = None;
    }

    /// Fast-path statistics, when enabled.
    pub fn fastpath(&self) -> Option<&FastPath> {
        self.fastpath.as_deref()
    }

    /// Enable the fast path with cross-checking: every replayed window
    /// is also re-simulated on a forked cluster and all observable state
    /// is compared (tests; slower than no cache).
    pub fn set_fastpath_crosscheck(&mut self, on: bool) {
        self.enable_fastpath();
        self.fastpath.as_deref_mut().unwrap().crosscheck = on;
    }

    /// Reset architectural state (memory, cores, DMA, arbiter, clock) to
    /// power-on while **preserving** the fast-path cache — replays stay
    /// sound because entries are validated structurally and by footprint
    /// content, never by wall history. Used by serve shards in exact
    /// mode to get a pristine cluster per request without losing the
    /// steady-state memo.
    pub fn reset(&mut self) {
        self.mem.tcdm.fill(0);
        self.mem.l2.fill(0);
        self.mem.trace = None;
        let n = self.cores.len();
        self.cores = (0..n).map(Core::new).collect();
        for c in &mut self.cores {
            c.set_fidelity(self.fidelity);
        }
        self.dma = Dma::new();
        self.rr = 0;
        self.cycle = 0;
    }

    /// Load one program per core (shorter vec leaves remaining cores
    /// halted). Resets core stats for a fresh measurement window.
    pub fn load_programs(&mut self, progs: Vec<Program>) {
        assert!(progs.len() <= self.cores.len());
        for core in &mut self.cores {
            core.stats = CoreStats::default();
        }
        for (core, prog) in self.cores.iter_mut().zip(progs) {
            core.load_program(prog);
        }
    }

    /// Advance one cycle. Returns false when everything is idle.
    pub fn step(&mut self) -> bool {
        let any_core_active =
            self.cores.iter().any(|c| c.state != CoreState::Halted);
        if !any_core_active && self.dma.idle() {
            return false;
        }
        self.cycle += 1;

        // Phase 1: collect TCDM requests from cores.
        let n = self.cores.len();
        for (i, c) in self.cores.iter().enumerate() {
            self.want[i] = c.mem_request().map(ClusterMem::bank_of);
        }
        // Phase 2: arbitrate one grant per bank; rotating priority
        // (conditional wraparound — integer division is the hot path's
        // single most expensive instruction otherwise).
        let mut bank_taken = [false; TCDM_BANKS];
        let mut i = self.rr;
        for _ in 0..n {
            self.granted[i] = false;
            if let Some(b) = self.want[i] {
                if !bank_taken[b] {
                    bank_taken[b] = true;
                    self.granted[i] = true;
                }
            }
            i += 1;
            if i >= n {
                i = 0;
            }
        }
        self.rr += 1;
        if self.rr >= n {
            self.rr = 0;
        }

        // Phase 3: tick cores (collecting barrier state on the way).
        let (mut waiting, mut running) = (0usize, 0usize);
        for i in 0..n {
            let core = &mut self.cores[i];
            core.tick(&mut self.mem, self.granted[i]);
            match core.state {
                CoreState::AtBarrier => waiting += 1,
                CoreState::Running => running += 1,
                CoreState::Halted => {}
            }
        }

        // Phase 4: DMA (lowest priority — blocked if any of its banks went
        // to a core this cycle).
        let dma_blocked = match self.dma.pending_banks() {
            Some([b0, b1]) => bank_taken[b0] || bank_taken[b1],
            None => false,
        };
        self.dma.tick(&mut self.mem, dma_blocked);

        // Phase 5: barrier release — when every non-halted core waits.
        if waiting > 0 && running == 0 {
            for c in &mut self.cores {
                if c.state == CoreState::AtBarrier {
                    c.release_barrier();
                }
            }
        }
        true
    }

    /// Run until all cores halt and the DMA drains. Returns the stats of
    /// this window (cycles counted from the call). With the fast path
    /// enabled, previously-seen windows are replayed from the memo
    /// instead of re-simulated (bit-exact; see [`super::fastpath`]).
    ///
    /// With a [`Cluster::tracer`] attached, one set of spans per
    /// non-empty window is emitted from the returned stats (see the
    /// field docs for why that keeps traces replay-invariant).
    pub fn run(&mut self) -> ClusterStats {
        let start = self.cycle;
        // Captured before the window: after it, ran cores sit halted and
        // indistinguishable from cores that never started.
        let ran: Option<Vec<bool>> = self
            .tracer
            .is_some()
            .then(|| self.cores.iter().map(|c| c.state == CoreState::Running).collect());
        let (stats, outcome) = if self.fastpath.is_some() {
            self.run_fast()
        } else {
            (self.run_slow(), None)
        };
        if let Some(ran) = ran {
            if stats.cycles > 0 {
                self.trace_window(start, &ran, &stats, outcome);
            }
        }
        stats
    }

    /// Emit the spans of one completed window: a cluster-level window
    /// span, one span per core that ran (stall-breakdown args from its
    /// [`CoreStats`]), a DMA span when the window moved bytes, and — for
    /// fast-path windows — a host-scope outcome instant (excluded from
    /// the default export; see [`crate::trace::Scope::Host`]).
    fn trace_window(
        &mut self,
        start: u64,
        ran: &[bool],
        stats: &ClusterStats,
        outcome: Option<WindowOutcome>,
    ) {
        use crate::trace::{track, Arg, Scope};
        let window_name = ran
            .iter()
            .position(|&r| r)
            .map(|i| self.cores[i].program_name().to_string())
            .unwrap_or_else(|| "dma-drain".to_string());
        let names: Vec<String> =
            self.cores.iter().map(|c| c.program_name().to_string()).collect();
        let n_cores = self.cores.len();
        let crosschecked = self.fastpath.as_deref().is_some_and(|f| f.crosscheck);
        let tracer = self.tracer.as_mut().expect("caller checked");
        tracer.name_process(0, "cluster");
        tracer.name_thread(track(0, 0), "cluster");
        tracer.span(
            Scope::Sim,
            track(0, 0),
            window_name,
            start,
            stats.cycles,
            vec![
                ("macs", Arg::U64(stats.total_macs())),
                ("mac_per_cycle", Arg::F64(stats.macs_per_cycle())),
            ],
        );
        for (i, &r) in ran.iter().enumerate() {
            if !r {
                continue;
            }
            let t = track(0, i as u32 + 1);
            tracer.name_thread(t, format!("core{i}"));
            let c = stats.cores[i];
            tracer.span(
                Scope::Sim,
                t,
                names[i].clone(),
                start,
                c.cycles.min(stats.cycles),
                vec![
                    ("instrs", Arg::U64(c.instrs)),
                    ("macs", Arg::U64(c.macs)),
                    ("conflict_stalls", Arg::U64(c.conflict_stalls)),
                    ("loaduse_stalls", Arg::U64(c.loaduse_stalls)),
                    ("branch_stalls", Arg::U64(c.branch_stalls)),
                    ("wbport_stalls", Arg::U64(c.wbport_stalls)),
                    ("align_stalls", Arg::U64(c.align_stalls)),
                    ("barrier_wait", Arg::U64(c.barrier_cycles)),
                ],
            );
        }
        if stats.dma_bytes > 0 {
            let t = track(0, n_cores as u32 + 1);
            tracer.name_thread(t, "dma");
            tracer.span(
                Scope::Sim,
                t,
                "dma",
                start,
                stats.dma_busy_cycles.min(stats.cycles),
                vec![("bytes", Arg::U64(stats.dma_bytes))],
            );
        }
        if let Some(o) = outcome {
            tracer.instant(Scope::Host, track(0, 0), o.name(), start, vec![]);
            if crosschecked && o != WindowOutcome::Recorded {
                tracer.instant(Scope::Host, track(0, 0), "fastpath_crosscheck", start, vec![]);
            }
        }
    }

    /// The cycle-by-cycle simulation loop.
    ///
    /// Under [`CoreFidelity::Pipeline`], the cores charge their extra
    /// hazard bubbles (WB-port contention, sub-word realignment) into
    /// their modeled per-core cycle counts at retire time without
    /// inserting ticks (see [`super::pipeline`] for why). The window's
    /// wall cycles are then the tick span plus the *slowest* core's
    /// extra charges — the lock-step cluster finishes when its most
    /// delayed core does — and the global clock advances by the same
    /// amount so window boundaries stay consistent with the memoized
    /// replay path.
    fn run_slow(&mut self) -> ClusterStats {
        let start_cycle = self.cycle;
        let start_dma_busy = self.dma.busy_cycles;
        let start_dma_bytes = self.dma.bytes_moved;
        let pipe_base: Option<Vec<u64>> = (self.fidelity == CoreFidelity::Pipeline).then(|| {
            self.cores
                .iter()
                .map(|c| c.stats.wbport_stalls + c.stats.align_stalls)
                .collect()
        });
        while self.step() {
            if self.max_cycles > 0 && self.cycle - start_cycle > self.max_cycles {
                panic!(
                    "cluster exceeded max_cycles={} (runaway kernel?)",
                    self.max_cycles
                );
            }
        }
        let mut cycles = self.cycle - start_cycle;
        if let Some(base) = pipe_base {
            let window_extra = self
                .cores
                .iter()
                .zip(&base)
                .map(|(c, b)| c.stats.wbport_stalls + c.stats.align_stalls - b)
                .max()
                .unwrap_or(0);
            self.cycle += window_extra;
            cycles += window_extra;
        }
        ClusterStats {
            cycles,
            cores: self.cores.iter().map(|c| c.stats).collect(),
            dma_busy_cycles: self.dma.busy_cycles - start_dma_busy,
            dma_bytes: self.dma.bytes_moved - start_dma_bytes,
        }
    }

    /// Fast-path window dispatch: pure replay, functional replay, or
    /// record (see [`super::fastpath`] for the three tiers). Also
    /// returns how the window was served, for the host-scope trace.
    fn run_fast(&mut self) -> (ClusterStats, Option<WindowOutcome>) {
        let any_active = self.cores.iter().any(|c| c.state != CoreState::Halted);
        if !any_active && self.dma.idle() {
            // Idle window: nothing to memoize; mirrors run_slow exactly.
            return (self.run_slow(), None);
        }
        let key = self.structural_key();
        // Take the fast path out of self so replay methods can borrow
        // the rest of the cluster mutably.
        let mut fp = self.fastpath.take().expect("run_fast without fastpath");
        // Entries are immutable Arcs: the (possibly fleet-shared) cache
        // lock is held only for the lookup, never during replay.
        let entry = {
            let cache = fp.cache.0.read().expect("fastpath cache poisoned");
            cache.get(&key).cloned()
        };
        let (stats, outcome) = if let Some(entry) = entry {
            let shadow = if fp.crosscheck { Some(self.fork_for_crosscheck()) } else { None };
            let pure_ok = entry.arch_sig == self.arch_sig()
                && entry.dma_sig.iter().eq(self.dma.queued())
                && fastpath::hash_mem_ranges(&self.mem, &entry.reads) == entry.read_hash;
            let (stats, outcome) = if pure_ok {
                fp.note(WindowOutcome::PureReplay);
                (self.replay_pure(&entry), WindowOutcome::PureReplay)
            } else {
                fp.note(WindowOutcome::FunctionalReplay);
                (self.replay_functional(&entry), WindowOutcome::FunctionalReplay)
            };
            if let Some(shadow) = shadow {
                self.crosscheck_against(shadow, &stats);
            }
            (stats, outcome)
        } else {
            fp.note(WindowOutcome::Recorded);
            let dma_sig: Vec<DmaRequest> = self.dma.queued().copied().collect();
            let arch_sig = self.arch_sig();
            let ran: Vec<bool> =
                self.cores.iter().map(|c| c.state == CoreState::Running).collect();
            self.mem.trace = Some(Box::default());
            let stats = self.run_slow();
            let trace = self.mem.trace.take().expect("trace survived the window");
            let writes: Vec<(u32, Vec<u8>)> = trace
                .write_ranges()
                .into_iter()
                .map(|(a, l)| (a, self.mem.bytes(a, l as usize).to_vec()))
                .collect();
            let entry = FastEntry {
                dma_sig,
                arch_sig,
                reads: trace.read_ranges(),
                read_hash: trace.read_hash(),
                writes,
                ran,
                cores_end: self.cores.clone(),
                rr_end: self.rr,
                stats: stats.clone(),
            };
            fp.cache.insert_bounded(key, std::sync::Arc::new(entry));
            (stats, WindowOutcome::Recorded)
        };
        self.fastpath = Some(fp);
        (stats, Some(outcome))
    }

    /// Tier 1: the window's exact environment matches the recording —
    /// apply the memoized functional delta and timing wholesale.
    fn replay_pure(&mut self, entry: &FastEntry) -> ClusterStats {
        for (addr, bytes) in &entry.writes {
            self.mem.write_bytes(*addr, bytes);
        }
        for (i, ran) in entry.ran.iter().enumerate() {
            if *ran {
                self.cores[i] = entry.cores_end[i].clone();
            }
        }
        self.dma.clear_queue();
        self.dma.busy_cycles += entry.stats.dma_busy_cycles;
        self.dma.bytes_moved += entry.stats.dma_bytes;
        self.rr = entry.rr_end;
        self.cycle += entry.stats.cycles;
        ClusterStats {
            cycles: entry.stats.cycles,
            cores: self.cores.iter().map(|c| c.stats).collect(),
            dma_busy_cycles: entry.stats.dma_busy_cycles,
            dma_bytes: entry.stats.dma_bytes,
        }
    }

    /// Tier 2: the footprint was invalidated (different input data, e.g.
    /// a DMA write overlapping it) — replay the memoized timing, but
    /// recompute the functional effects with fast straight-line
    /// execution.
    fn replay_functional(&mut self, entry: &FastEntry) -> ClusterStats {
        // DMA first: double-buffered plans never let a window's kernel
        // read data streamed by that same window (see coordinator docs),
        // so completing transfers up front is order-equivalent.
        self.dma.complete_all_functional(&mut self.mem);
        self.dma.busy_cycles += entry.stats.dma_busy_cycles;
        self.dma.bytes_moved += entry.stats.dma_bytes;
        let guard = if self.max_cycles == 0 { u64::MAX } else { self.max_cycles };
        loop {
            for c in &mut self.cores {
                if c.state == CoreState::Running {
                    c.run_functional(&mut self.mem, guard);
                }
            }
            if !self.cores.iter().any(|c| c.state == CoreState::AtBarrier) {
                break;
            }
            // Every non-halted core reached the barrier: release, as the
            // HW sync unit would.
            for c in &mut self.cores {
                if c.state == CoreState::AtBarrier {
                    c.release_barrier();
                }
            }
        }
        // Splice the memoized timing into the functionally-counted
        // stats. Retired-instruction counts must agree — a divergence
        // means a kernel has data-dependent control flow, voiding the
        // structural-timing invariant.
        for (i, ran) in entry.ran.iter().enumerate() {
            if !*ran {
                continue;
            }
            let e = entry.stats.cores[i];
            let c = &mut self.cores[i];
            assert_eq!(
                c.stats.instrs, e.instrs,
                "fast-path invariant violated on core {i}: {} instrs retired \
                 functionally vs {} in the memo (data-dependent control \
                 flow?) — rerun with the fast path disabled",
                c.stats.instrs, e.instrs
            );
            c.stats = e;
        }
        self.rr = entry.rr_end;
        self.cycle += entry.stats.cycles;
        ClusterStats {
            cycles: entry.stats.cycles,
            cores: self.cores.iter().map(|c| c.stats).collect(),
            dma_busy_cycles: entry.stats.dma_busy_cycles,
            dma_bytes: entry.stats.dma_bytes,
        }
    }

    /// Deep copy for cross-checking (fast path and trace stripped).
    fn fork_for_crosscheck(&self) -> Cluster {
        Cluster {
            mem: ClusterMem {
                tcdm: self.mem.tcdm.clone(),
                l2: self.mem.l2.clone(),
                trace: None,
            },
            cores: self.cores.clone(),
            dma: self.dma.clone(),
            rr: self.rr,
            cycle: self.cycle,
            max_cycles: self.max_cycles,
            want: vec![None; self.cores.len()],
            granted: vec![false; self.cores.len()],
            fidelity: self.fidelity,
            fastpath: None,
            tracer: None,
        }
    }

    /// Re-simulate the window on `shadow` (forked before replay) and
    /// compare every observable against the replayed state.
    fn crosscheck_against(&self, mut shadow: Cluster, got: &ClusterStats) {
        let want = shadow.run_slow();
        assert_eq!(got, &want, "fast-path crosscheck: window stats diverge");
        assert_eq!(self.cycle, shadow.cycle, "fast-path crosscheck: clock diverges");
        assert_eq!(self.rr, shadow.rr, "fast-path crosscheck: arbiter phase diverges");
        assert!(self.mem.tcdm == shadow.mem.tcdm, "fast-path crosscheck: TCDM diverges");
        assert!(self.mem.l2 == shadow.mem.l2, "fast-path crosscheck: L2 diverges");
        assert_eq!(
            self.dma.busy_cycles, shadow.dma.busy_cycles,
            "fast-path crosscheck: DMA busy cycles diverge"
        );
        assert_eq!(
            self.dma.bytes_moved, shadow.dma.bytes_moved,
            "fast-path crosscheck: DMA bytes diverge"
        );
        for (i, (a, b)) in self.cores.iter().zip(&shadow.cores).enumerate() {
            assert_eq!(a.regs, b.regs, "fast-path crosscheck: core {i} regs diverge");
            assert_eq!(a.nnrf, b.nnrf, "fast-path crosscheck: core {i} NN-RF diverges");
            assert_eq!(a.stats, b.stats, "fast-path crosscheck: core {i} stats diverge");
            assert!(a.state == b.state, "fast-path crosscheck: core {i} state diverges");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluOp, Instr};
    use crate::sim::mem::TCDM_BASE;

    fn alu_prog(n: usize) -> Program {
        let mut p = Program::new("alu");
        p.push(Instr::LpSetup { l: 0, count: n as u32, len: 1 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p.push(Instr::Halt);
        p
    }

    #[test]
    fn independent_alu_programs_run_in_parallel() {
        let mut cl = Cluster::new(8);
        cl.load_programs((0..8).map(|_| alu_prog(100)).collect());
        let stats = cl.run();
        // no memory => no contention => all finish in lockstep
        assert_eq!(stats.cores.len(), 8);
        for c in &stats.cores {
            assert_eq!(c.instrs, 102);
            assert_eq!(c.conflict_stalls, 0);
        }
        assert_eq!(stats.cycles, 102);
    }

    #[test]
    fn same_bank_loads_conflict() {
        // all 8 cores hammer the same word -> same bank -> serialization
        let mut cl = Cluster::new(8);
        let mut progs = vec![];
        for _ in 0..8 {
            let mut p = Program::new("ld");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::LpSetup { l: 0, count: 32, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            progs.push(p);
        }
        cl.load_programs(progs);
        let stats = cl.run();
        let total_conflicts: u64 = stats.cores.iter().map(|c| c.conflict_stalls).sum();
        assert!(total_conflicts > 0, "same-bank access must conflict");
        // 256 loads through 1 bank: lower bound ~256 cycles
        assert!(stats.cycles >= 256, "cycles={} too low", stats.cycles);
    }

    #[test]
    fn striped_banks_do_not_conflict() {
        // each core loads its own bank (core i -> word i)
        let mut cl = Cluster::new(8);
        let mut progs = vec![];
        for i in 0..8 {
            let mut p = Program::new("ld");
            p.push(Instr::Li { rd: 1, imm: (TCDM_BASE + 4 * i) as i32 });
            p.push(Instr::LpSetup { l: 0, count: 32, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            progs.push(p);
        }
        cl.load_programs(progs);
        let stats = cl.run();
        for c in &stats.cores {
            assert_eq!(c.conflict_stalls, 0);
        }
    }

    #[test]
    fn rotating_priority_is_fair() {
        // two cores fight for one bank; stalls should split roughly evenly
        let mut cl = Cluster::new(2);
        let mut progs = vec![];
        for _ in 0..2 {
            let mut p = Program::new("ld");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::LpSetup { l: 0, count: 100, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            progs.push(p);
        }
        cl.load_programs(progs);
        let stats = cl.run();
        let s0 = stats.cores[0].conflict_stalls as i64;
        let s1 = stats.cores[1].conflict_stalls as i64;
        assert!((s0 - s1).abs() <= 2, "unfair arbitration: {s0} vs {s1}");
    }

    #[test]
    fn barrier_synchronizes_cores() {
        // core 0 runs long, core 1 short; both barrier then store cycle mark
        let mut cl = Cluster::new(2);
        let mut p0 = Program::new("long");
        p0.push(Instr::LpSetup { l: 0, count: 500, len: 1 });
        p0.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p0.push(Instr::Barrier);
        p0.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 0, imm: 7 });
        p0.push(Instr::Halt);
        let mut p1 = Program::new("short");
        p1.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p1.push(Instr::Barrier);
        p1.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 0, imm: 7 });
        p1.push(Instr::Halt);
        cl.load_programs(vec![p0, p1]);
        let stats = cl.run();
        // core 1 waited for core 0
        assert!(stats.cores[1].barrier_cycles >= 490, "{:?}", stats.cores[1]);
        assert!(stats.cores[0].barrier_cycles <= 5);
        assert_eq!(cl.cores[0].regs[3], 7);
        assert_eq!(cl.cores[1].regs[3], 7);
    }

    #[test]
    fn dma_overlaps_with_compute() {
        use crate::sim::dma::{DmaDir, DmaRequest};
        use crate::sim::mem::L2_BASE;
        let mut cl = Cluster::new(1);
        cl.mem.write_bytes(L2_BASE, &vec![0xAB; 4096]);
        cl.dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE + 8192, 4096));
        cl.load_programs(vec![alu_prog(2000)]);
        let stats = cl.run();
        // compute (2002 cycles) dominates the DMA (16 + 512) — full overlap
        assert!(stats.cycles < 2100, "cycles={} suggests no overlap", stats.cycles);
        assert_eq!(cl.mem.read_bytes(TCDM_BASE + 8192, 4096), vec![0xAB; 4096]);
    }

    #[test]
    fn dma_tail_extends_run() {
        use crate::sim::dma::{DmaDir, DmaRequest};
        use crate::sim::mem::L2_BASE;
        let mut cl = Cluster::new(1);
        cl.dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE, 8000));
        cl.load_programs(vec![alu_prog(10)]);
        let stats = cl.run();
        // DMA 16 + 1000 beats dominates the 12-cycle program
        assert!(stats.cycles >= 1000, "cycles={}", stats.cycles);
    }

    /// Program for the fast-path tests: load a word from `X`, add 5,
    /// store the result to `Y` (data-independent control flow, like all
    /// generated kernels).
    fn add5_prog(x: u32, y: u32) -> Program {
        let mut p = Program::new("add5");
        p.push(Instr::Li { rd: 1, imm: x as i32 });
        p.push(Instr::Li { rd: 3, imm: y as i32 });
        p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 2, rs1: 2, imm: 5 });
        p.push(Instr::Sw { rs: 2, base: 3, off: 0, post_inc: 0 });
        p.push(Instr::Halt);
        p
    }

    /// One round of the steady-state workload: reset, stream the input
    /// from L2 into TCDM by DMA (drain window), then run the kernel
    /// window. Returns (drain cycles, kernel cycles, output word).
    fn fastpath_round(cl: &mut Cluster, input: u32) -> (u64, u64, u32) {
        use crate::sim::dma::{DmaDir, DmaRequest};
        use crate::sim::mem::L2_BASE;
        let (x, y) = (TCDM_BASE, TCDM_BASE + 256);
        cl.reset();
        cl.mem.store_u32(L2_BASE, input);
        cl.dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, x, 4));
        let drain = cl.run();
        cl.load_programs(vec![add5_prog(x, y)]);
        let kernel = cl.run();
        (drain.cycles, kernel.cycles, cl.mem.load_u32(y))
    }

    #[test]
    fn fastpath_pure_replay_and_dma_overlap_invalidation() {
        let mut cl = Cluster::new(1);
        cl.set_fastpath_crosscheck(true);
        // Round 1: both windows are recorded.
        let (d1, k1, y1) = fastpath_round(&mut cl, 100);
        assert_eq!(y1, 105);
        assert_eq!(
            (cl.fastpath().unwrap().misses, cl.fastpath().unwrap().pure_hits),
            (2, 0)
        );
        // Round 2, identical input: both windows replay purely.
        let (d2, k2, y2) = fastpath_round(&mut cl, 100);
        assert_eq!((d2, k2, y2), (d1, k1, 105));
        assert_eq!(cl.fastpath().unwrap().pure_hits, 2);
        assert_eq!(cl.fastpath().unwrap().misses, 2);
        // Round 3, new input: the DMA rewrites the kernel's footprint —
        // pure replay is invalidated, timing replays, the functional
        // effect is recomputed, and the output tracks the new data.
        let (d3, k3, y3) = fastpath_round(&mut cl, 200);
        assert_eq!(y3, 205, "stale replay after a DMA overlapped the footprint");
        assert_eq!((d3, k3), (d1, k1), "replayed timing must be unchanged");
        assert_eq!(cl.fastpath().unwrap().func_hits, 2);
        assert_eq!(cl.fastpath().unwrap().misses, 2);
    }

    #[test]
    fn fastpath_matches_no_fastpath_cycles_and_memory() {
        let mut slow = Cluster::new(1);
        let mut fast = Cluster::new(1);
        fast.enable_fastpath();
        for input in [7u32, 7, 99, 7, 42] {
            let a = fastpath_round(&mut slow, input);
            let b = fastpath_round(&mut fast, input);
            assert_eq!(a, b, "fast path diverged on input {input}");
        }
        let fp = fast.fastpath().unwrap();
        assert!(fp.pure_hits > 0 && fp.func_hits > 0, "{fp:?}");
        assert!(fp.hit_rate() > 0.5);
        // The escape hatch drops the cache entirely.
        fast.disable_fastpath();
        assert!(fast.fastpath().is_none());
        let a = fastpath_round(&mut slow, 11);
        let b = fastpath_round(&mut fast, 11);
        assert_eq!(a, b);
    }

    /// Both fidelity tiers agree bit-for-bit on architectural state;
    /// the pipeline tier's window cycles are the fast tier's plus the
    /// slowest core's hazard charges, and the memo keyed per tier
    /// replays each tier's own timing.
    #[test]
    fn pipeline_fidelity_state_identical_cycles_inflated() {
        use crate::isa::{Csr, MlChannel};
        use crate::sim::pipeline::CoreFidelity;
        // Core program with both pipeline-only hazards: a sub-word
        // load-use pair and an NN-RF WB load followed by a GP load.
        fn prog(i: usize) -> Program {
            let mut p = Program::new("hazards");
            p.push(Instr::CsrW { csr: Csr::WStride, imm: 4 });
            p.push(Instr::CsrW { csr: Csr::WBase, imm: (TCDM_BASE + 4 * i as u32) as i32 });
            p.push(Instr::Li { rd: 1, imm: (TCDM_BASE + 64 + 4 * i as u32) as i32 });
            p.push(Instr::NnLoad { ch: MlChannel::Wgt, slot: 0 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Lbu { rd: 3, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::AluI { op: AluOp::Add, rd: 4, rs1: 3, imm: 1 });
            p.push(Instr::Sw { rs: 4, base: 1, off: 128, post_inc: 0 });
            p.push(Instr::Halt);
            p
        }
        let run = |fid: CoreFidelity| {
            let mut cl = Cluster::with_fidelity(2, fid);
            for i in 0..8u32 {
                cl.mem.store_u32(TCDM_BASE + 4 * i, 0x0101_0101 * (i + 1));
                cl.mem.store_u32(TCDM_BASE + 64 + 4 * i, 7 + i);
            }
            cl.load_programs(vec![prog(0), prog(1)]);
            let stats = cl.run();
            (stats, cl)
        };
        let (fast, cl_f) = run(CoreFidelity::Fast);
        let (pipe, cl_p) = run(CoreFidelity::Pipeline);
        // identical architectural state
        assert!(cl_f.mem.tcdm == cl_p.mem.tcdm, "TCDM diverged between tiers");
        for (a, b) in cl_f.cores.iter().zip(&cl_p.cores) {
            assert_eq!(a.regs, b.regs);
            assert_eq!(a.nnrf, b.nnrf);
        }
        // the hazards actually fired, and only on the pipeline tier
        for c in &fast.cores {
            assert_eq!((c.wbport_stalls, c.align_stalls), (0, 0));
        }
        for c in &pipe.cores {
            assert_eq!(c.wbport_stalls, 1, "{c:?}");
            assert_eq!(c.align_stalls, 1, "{c:?}");
        }
        // window cycles = fast tick span + slowest core's extra charges
        let extra = pipe
            .cores
            .iter()
            .map(|c| c.wbport_stalls + c.align_stalls)
            .max()
            .unwrap();
        assert_eq!(pipe.cycles, fast.cycles + extra);
        assert_eq!(cl_p.cycle, cl_f.cycle + extra, "global clock must track the charges");
        // per-core accounting identity holds on both tiers
        for s in [&fast, &pipe] {
            for c in &s.cores {
                assert_eq!(c.cycles, c.instrs + c.stall_cycles() + c.barrier_cycles);
            }
        }
        // reset preserves the tier
        let mut cl = cl_p;
        cl.reset();
        assert_eq!(cl.fidelity(), CoreFidelity::Pipeline);
    }

    /// The fast-path memo distinguishes tiers: the same window replayed
    /// under each fidelity reproduces that fidelity's own cycle count.
    #[test]
    fn fastpath_memo_is_fidelity_keyed() {
        use crate::sim::pipeline::CoreFidelity;
        let mut cl = Cluster::with_fidelity(1, CoreFidelity::Pipeline);
        cl.set_fastpath_crosscheck(true);
        let (d1, k1, y1) = fastpath_round(&mut cl, 100);
        let (d2, k2, y2) = fastpath_round(&mut cl, 100);
        assert_eq!((d1, k1, y1), (d2, k2, y2), "pipeline-tier replay must be bit-exact");
        assert!(cl.fastpath().unwrap().pure_hits >= 2);
        // A fast-tier cluster sharing nothing still yields the same
        // functional output with cycles <= the pipeline tier's.
        let mut fast = Cluster::new(1);
        let (df, kf, yf) = fastpath_round(&mut fast, 100);
        assert_eq!(yf, y1);
        assert!(df <= d1 && kf <= k1, "fast tier may never exceed pipeline cycles");
    }

    #[test]
    fn fastpath_multicore_barrier_kernel_crosschecked() {
        // Two cores, bank-conflicting loads plus a barrier: exercises the
        // functional-replay barrier phases and the conflict-timing memo.
        fn prog() -> Program {
            let mut p = Program::new("conflict");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::LpSetup { l: 0, count: 16, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Barrier);
            p.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 2, imm: 1 });
            p.push(Instr::Halt);
            p
        }
        let mut cl = Cluster::new(2);
        cl.set_fastpath_crosscheck(true);
        cl.mem.store_u32(TCDM_BASE, 41);
        // The arbiter rotation is part of the window key, so with two
        // cores an identical window must recur within three repetitions.
        // Leftover registers make these functional (not pure) replays;
        // crosscheck verifies each against a full re-simulation.
        let mut cycles = Vec::new();
        for _ in 0..3 {
            cl.load_programs(vec![prog(), prog()]);
            cycles.push(cl.run().cycles);
            assert_eq!(cl.cores[0].regs[3], 42);
            assert_eq!(cl.cores[1].regs[3], 42);
        }
        assert!(cycles.iter().all(|&c| c == cycles[0]), "{cycles:?}");
        assert!(cl.fastpath().unwrap().func_hits >= 1, "{:?}", cl.fastpath().unwrap());
    }
}
