//! Inspect the DORY tiling decisions for MobileNetV1-8b4b: per layer,
//! the solver's tile shape, L1 working set and DMA traffic.
//!
//!     cargo run --release --example dory_inspect

use flexv::dory::deploy::deploy;
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::models::{mobilenet_v1, Profile};

fn main() {
    let net = mobilenet_v1(Profile::Mixed8a4w, 0.75, 224, 11);
    let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
    println!(
        "{}: {:.0} kB weights, L2 used {:.0} kB",
        net.name,
        net.model_bytes() as f64 / 1024.0,
        dep.l2_used as f64 / 1024.0
    );
    println!("{:<10} {:>6} {:>12} {:>14}", "layer", "tiles", "DMA-in [kB]", "DMA-out [kB]");
    for plan in &dep.plans {
        let dma_in: u64 = plan.tiles.iter().flat_map(|t| t.loads.iter()).map(|r| r.total_bytes()).sum();
        let dma_out: u64 =
            plan.tiles.iter().flat_map(|t| t.stores.iter()).map(|r| r.total_bytes()).sum();
        println!(
            "{:<10} {:>6} {:>12.1} {:>14.1}",
            plan.name,
            plan.tiles.len(),
            dma_in as f64 / 1024.0,
            dma_out as f64 / 1024.0
        );
    }
}
