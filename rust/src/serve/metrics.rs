//! Per-request, per-class, and fleet-level serving metrics.
//!
//! Everything is measured in simulated cluster cycles (deterministic);
//! wall-clock figures are derived at the typical-corner frequency
//! ([`crate::report::F_TYP_MHZ`], 250 MHz). The engine's determinism
//! contract (see [`crate::serve`]) makes every **simulated** field a
//! pure function of the trace, diffable across machines, worker
//! counts, and fast-path settings — the parallelism tests assert
//! exactly that; with SLO workloads this extends to deadline-miss
//! counts, shed events, and the shard-occupancy timeline
//! (`rust/tests/serve_workload.rs`). The one exception is the
//! host-side simulator fast-path counters (`fastpath_*`): they
//! describe how the simulation was computed (and can vary with thread
//! interleaving on a shared window cache), never what it computed.

use crate::report::artifact::{MetricRow, MetricSource};
use crate::report::F_TYP_MHZ;
use crate::util::table::{f, Table};

use super::autoscale::Autoscaler;
use super::cache::PlanCache;
use super::queue::RequestQueue;
use super::request::{Completion, ShedEvent};
use super::shard::Shard;
use super::workload::SloClass;

/// Nearest-rank percentile over an ascending-sorted slice: the value at
/// 1-based rank `ceil(q·N)`, clamped to `[1, N]`. (An earlier version
/// indexed `round((N-1)·q)`, which reports the 51st of 100 samples as
/// the median and understates tail quantiles on small samples.)
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Aggregates for one registered model.
#[derive(Clone, Debug)]
pub struct ModelRow {
    pub name: String,
    pub served: usize,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_exec_cycles: f64,
    pub macs_per_cycle: f64,
    /// Mean simulated energy per request [µJ].
    pub energy_uj: f64,
}

/// Aggregates for one SLO class (see [`SloClass`]).
#[derive(Clone, Debug)]
pub struct ClassRow {
    pub name: String,
    pub priority: u8,
    /// Relative deadline of the class (`None` = best-effort).
    pub deadline_cycles: Option<u64>,
    pub served: usize,
    /// Completions that finished after their deadline.
    pub missed: usize,
    /// Requests shed before simulation (deadline unmeetable).
    pub shed: usize,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
}

impl ClassRow {
    /// Fraction of this class's admitted requests that violated their
    /// deadline (late completions + sheds, over served + shed). 0 for a
    /// best-effort class.
    pub fn violation_rate(&self) -> f64 {
        let n = self.served + self.shed;
        if n == 0 {
            0.0
        } else {
            (self.missed + self.shed) as f64 / n as f64
        }
    }
}

/// Autotune totals over the models the tuner processed this run
/// (zeroed when tuning is off): the measured per-inference cycle cost
/// of the analytic default plans vs the selected tuned plans — the
/// tuner's own metric ([`crate::dory::autotune`]), surfaced so a tuned
/// fleet report shows what tuning bought.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TunedSummary {
    /// Models autotuned (0 without `ServeConfig::tuned`).
    pub models: usize,
    /// Σ measured cycles of the analytic default per-layer plans.
    pub default_cycles: u64,
    /// Σ measured cycles of the tuned plans (≤ `default_cycles` by
    /// construction — the default is always a candidate).
    pub tuned_cycles: u64,
    /// Layers that measured strictly faster than their default plan.
    pub improved_layers: usize,
}

impl TunedSummary {
    /// Fraction of the default plans' measured cycles the tuned plans
    /// save.
    pub fn gain_fraction(&self) -> f64 {
        if self.default_cycles == 0 {
            0.0
        } else {
            (self.default_cycles - self.tuned_cycles) as f64 / self.default_cycles as f64
        }
    }
}

/// Everything [`FleetMetrics::collect`] reads, bundled (the engine owns
/// all of it; the borrow is one struct instead of ten arguments).
pub(crate) struct CollectInputs<'a> {
    pub completions: &'a [Completion],
    pub names: &'a [String],
    pub classes: &'a [SloClass],
    pub queue: &'a RequestQueue,
    pub cache: &'a PlanCache,
    pub shards: &'a [Shard],
    pub shed: &'a [ShedEvent],
    pub occupancy: &'a [(u64, usize)],
    pub scaler: Option<&'a Autoscaler>,
    pub tuned: TunedSummary,
    /// Operating-point changes logged by the engine's DVFS governor.
    pub dvfs_transitions: u64,
    /// The fleet power cap the engine scheduled under, if any [mW].
    pub power_cap_mw: Option<f64>,
}

/// The fleet-level report of one serving run.
#[derive(Clone, Debug)]
pub struct FleetMetrics {
    pub shards: usize,
    pub served: usize,
    pub enqueued: u64,
    pub rejected: u64,
    /// Requests shed before simulation (unmeetable deadlines).
    pub shed: u64,
    /// Requests retracted from a failed shard and re-queued (failover;
    /// 0 unless faults were injected — see `Engine::fail_shard`).
    pub requeued: u64,
    /// Completions that finished after their deadline.
    pub deadline_misses: u64,
    pub peak_queue_depth: usize,
    /// First arrival → last completion, simulated cycles.
    pub span_cycles: u64,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_latency_cycles: f64,
    /// Throughput at the typical corner.
    pub requests_per_sec: f64,
    /// Total MACs / span cycles — the fleet-level Table IV metric.
    pub aggregate_macs_per_cycle: f64,
    /// Total MACs / Σ busy cycles — per-shard efficiency while working.
    pub busy_macs_per_cycle: f64,
    /// Σ busy / (shards × span).
    pub shard_utilization: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: usize,
    pub batches: u64,
    pub mean_batch: f64,
    pub model_switches: u64,
    /// Shards woken / parked by the autoscaler (0 for a static fleet).
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// `(cycle, active shards)` at start plus one entry per scaling
    /// action — the shard-occupancy timeline (absolute simulated
    /// cycles).
    pub occupancy: Vec<(u64, usize)>,
    /// Time-weighted mean of `occupancy` over first arrival → last
    /// completion.
    mean_active: f64,
    /// Completions that carried a deadline (the [`FleetMetrics::miss_rate`]
    /// denominator — per-completion, so it agrees with `deadline_misses`
    /// even when requests carry deadlines their class table does not).
    deadlined_served: usize,
    /// Simulator windows replayed purely from a memoized functional
    /// delta, across all shards (host-side metric; see `sim::fastpath`).
    pub fastpath_pure: u64,
    /// Simulator windows with replayed timing + functional re-execution.
    pub fastpath_func: u64,
    /// Simulator windows cycle-simulated and recorded.
    pub fastpath_miss: u64,
    /// Autotune tuned-vs-default measured cycle deltas (zeroed without
    /// `ServeConfig::tuned`).
    pub tuned: TunedSummary,
    /// Σ simulated energy of every completion [pJ] (activity × the
    /// calibrated per-class energies, billed at each batch's operating
    /// point).
    pub total_energy_pj: f64,
    /// Σ MACs over every completion (the TOPS/W numerator).
    pub total_macs: u64,
    /// Mean simulated energy per served request [µJ].
    pub energy_uj_per_req: f64,
    /// Fleet average power over the run window [mW]: total energy over
    /// first arrival → last completion, with the span converted to time
    /// at the nominal fleet tick ([`crate::power::NOMINAL_PERIOD_PS`]).
    /// Busy-window power is what the cap governs; this time-average is
    /// ≤ it, so a capped run always reports `fleet_avg_power_mw ≤ cap`.
    pub fleet_avg_power_mw: f64,
    /// Fleet efficiency over the run: `2·MACs / total energy` — the
    /// paper's headline TOPS/W metric, measured end-to-end over the
    /// serving window instead of a single kernel.
    pub fleet_tops_per_watt: f64,
    /// Operating-point changes the DVFS governor made (0 for a fixed
    /// operating point).
    pub dvfs_transitions: u64,
    /// The fleet power cap the engine scheduled under, if any [mW].
    pub power_cap_mw: Option<f64>,
    pub rows: Vec<ModelRow>,
    /// Per-SLO-class latency and violation breakdown (single "default"
    /// row when no class table was installed).
    pub class_rows: Vec<ClassRow>,
}

impl FleetMetrics {
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }

    /// Deadline-miss rate over completions that carried a deadline
    /// (sheds are counted separately; see [`ClassRow::violation_rate`]
    /// for the combined per-class view).
    pub fn miss_rate(&self) -> f64 {
        if self.deadlined_served == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.deadlined_served as f64
        }
    }

    /// Mean active shards over the run (occupancy time-weighted across
    /// the first-arrival → last-completion window; computed in
    /// [`FleetMetrics::collect`]).
    pub fn mean_active_shards(&self) -> f64 {
        self.mean_active
    }

    pub(crate) fn collect(inp: CollectInputs<'_>) -> FleetMetrics {
        let CollectInputs {
            completions,
            names,
            classes,
            queue,
            cache,
            shards,
            shed,
            occupancy,
            scaler,
            tuned,
            dvfs_transitions,
            power_cap_mw,
        } = inp;
        let served = completions.len();
        let mut latencies: Vec<u64> = completions.iter().map(|c| c.latency_cycles()).collect();
        latencies.sort_unstable();
        let first_arrival = completions.iter().map(|c| c.arrival_cycle).min().unwrap_or(0);
        let last_finish = completions.iter().map(|c| c.finish_cycle).max().unwrap_or(0);
        let span_cycles = last_finish.saturating_sub(first_arrival);
        let total_macs: u64 = completions.iter().map(|c| c.macs).sum();
        let total_exec: u64 = completions.iter().map(|c| c.exec_cycles).sum();
        let total_busy: u64 = shards.iter().map(|s| s.busy_cycles).sum();
        let batches: u64 = shards.iter().map(|s| s.batches).sum();
        let span_secs = span_cycles as f64 / (F_TYP_MHZ * 1e6);
        let total_energy_pj: f64 = completions.iter().map(|c| c.energy_pj).sum();
        // 1 pJ/ps = 1 W, so mW = pJ / (ticks · ps/tick) · 1e3.
        let span_ps = span_cycles as f64 * crate::power::NOMINAL_PERIOD_PS as f64;
        let fleet_avg_power_mw = if span_ps > 0.0 { total_energy_pj / span_ps * 1e3 } else { 0.0 };
        // TOPS/W = ops / (J · 1e12) = 2·MACs / (pJ · 1e-12 · 1e12).
        let fleet_tops_per_watt =
            if total_energy_pj > 0.0 { 2.0 * total_macs as f64 / total_energy_pj } else { 0.0 };
        let deadline_misses = completions.iter().filter(|c| c.missed_deadline()).count() as u64;
        let deadlined_served = completions.iter().filter(|c| c.deadline.is_some()).count();
        let (mut fp_pure, mut fp_func, mut fp_miss) = (0u64, 0u64, 0u64);
        for s in shards {
            let (p, f, m) = s.fastpath_counts();
            fp_pure += p;
            fp_func += f;
            fp_miss += m;
        }

        // Time-weighted occupancy over the run window [first arrival,
        // last completion]. Occupancy entries are absolute cycles; a
        // segment straddling the window boundary contributes only its
        // inside part.
        let mean_active = if last_finish > first_arrival && !occupancy.is_empty() {
            let (start, end) = (first_arrival, last_finish);
            let mut area = 0.0;
            for (i, &(t, n)) in occupancy.iter().enumerate() {
                let seg_start = t.max(start);
                let seg_end = occupancy.get(i + 1).map_or(end, |&(t2, _)| t2).clamp(start, end);
                if seg_end > seg_start {
                    area += (seg_end - seg_start) as f64 * n as f64;
                }
            }
            area / (end - start) as f64
        } else {
            occupancy.last().map_or(0.0, |&(_, n)| n as f64)
        };

        let rows = names
            .iter()
            .enumerate()
            .map(|(m, name)| {
                let of_model: Vec<&Completion> =
                    completions.iter().filter(|c| c.model == m).collect();
                let mut lat: Vec<u64> = of_model.iter().map(|c| c.latency_cycles()).collect();
                lat.sort_unstable();
                let n = of_model.len();
                let exec: u64 = of_model.iter().map(|c| c.exec_cycles).sum();
                let macs: u64 = of_model.iter().map(|c| c.macs).sum();
                let pj: f64 = of_model.iter().map(|c| c.energy_pj).sum();
                ModelRow {
                    name: name.clone(),
                    served: n,
                    p50_cycles: percentile(&lat, 0.50),
                    p99_cycles: percentile(&lat, 0.99),
                    mean_exec_cycles: exec as f64 / n.max(1) as f64,
                    macs_per_cycle: macs as f64 / exec.max(1) as f64,
                    energy_uj: pj / n.max(1) as f64 * 1e-6,
                }
            })
            .collect();

        let class_rows = classes
            .iter()
            .enumerate()
            .map(|(ci, c)| {
                let of_class: Vec<&Completion> =
                    completions.iter().filter(|x| x.class as usize == ci).collect();
                let mut lat: Vec<u64> = of_class.iter().map(|x| x.latency_cycles()).collect();
                lat.sort_unstable();
                ClassRow {
                    name: c.name.clone(),
                    priority: c.priority,
                    deadline_cycles: c.deadline_cycles,
                    served: of_class.len(),
                    missed: of_class.iter().filter(|x| x.missed_deadline()).count(),
                    shed: shed.iter().filter(|s| s.class as usize == ci).count(),
                    p50_cycles: percentile(&lat, 0.50),
                    p99_cycles: percentile(&lat, 0.99),
                }
            })
            .collect();

        FleetMetrics {
            shards: shards.len(),
            served,
            enqueued: queue.enqueued,
            rejected: queue.rejected,
            shed: queue.shed,
            requeued: queue.requeued,
            deadline_misses,
            peak_queue_depth: queue.peak_depth,
            span_cycles,
            p50_cycles: percentile(&latencies, 0.50),
            p99_cycles: percentile(&latencies, 0.99),
            mean_latency_cycles: latencies.iter().sum::<u64>() as f64 / served.max(1) as f64,
            requests_per_sec: if span_secs > 0.0 { served as f64 / span_secs } else { 0.0 },
            aggregate_macs_per_cycle: total_macs as f64 / span_cycles.max(1) as f64,
            busy_macs_per_cycle: total_macs as f64 / total_exec.max(1) as f64,
            shard_utilization: if span_cycles > 0 && !shards.is_empty() {
                total_busy as f64 / (shards.len() as f64 * span_cycles as f64)
            } else {
                0.0
            },
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_entries: cache.len(),
            batches,
            mean_batch: served as f64 / batches.max(1) as f64,
            model_switches: shards.iter().map(|s| s.model_switches).sum(),
            scale_ups: scaler.map_or(0, |s| s.ups),
            scale_downs: scaler.map_or(0, |s| s.downs),
            occupancy: occupancy.to_vec(),
            mean_active,
            deadlined_served,
            fastpath_pure: fp_pure,
            fastpath_func: fp_func,
            fastpath_miss: fp_miss,
            tuned,
            total_energy_pj,
            total_macs,
            energy_uj_per_req: total_energy_pj * 1e-6 / served.max(1) as f64,
            fleet_avg_power_mw,
            fleet_tops_per_watt,
            dvfs_transitions,
            power_cap_mw,
            rows,
            class_rows,
        }
    }

    /// Render the throughput/latency table plus fleet summary lines
    /// (and, for SLO workloads, the per-class table and the autoscaler's
    /// occupancy line).
    pub fn render(&self) -> String {
        let ms = |cyc: u64| cyc as f64 / (F_TYP_MHZ * 1e3);
        let mut t = Table::new(format!(
            "serve fleet — {} shards, {} requests ({} rejected, {} shed), {} Mcycle span",
            self.shards,
            self.served,
            self.rejected,
            self.shed,
            self.span_cycles / 1_000_000
        ))
        .header(&["model", "served", "p50[ms]", "p99[ms]", "MAC/cyc", "uJ/req"]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.served.to_string(),
                f(ms(r.p50_cycles), 2),
                f(ms(r.p99_cycles), 2),
                f(r.macs_per_cycle, 1),
                f(r.energy_uj, 1),
            ]);
        }
        let mut out = t.render();
        // Per-class SLO table: only interesting once a class table with
        // deadlines or multiple tiers is installed.
        if self.class_rows.len() > 1
            || self.class_rows.iter().any(|c| c.deadline_cycles.is_some())
        {
            let mut ct = Table::new("SLO classes".to_string()).header(&[
                "class", "prio", "SLO[ms]", "served", "missed", "shed", "p50[ms]", "p99[ms]",
                "viol%",
            ]);
            for c in &self.class_rows {
                ct.row(vec![
                    c.name.clone(),
                    c.priority.to_string(),
                    c.deadline_cycles.map_or("-".into(), |d| f(ms(d), 1)),
                    c.served.to_string(),
                    c.missed.to_string(),
                    c.shed.to_string(),
                    f(ms(c.p50_cycles), 2),
                    f(ms(c.p99_cycles), 2),
                    f(c.violation_rate() * 100.0, 1),
                ]);
            }
            out.push_str(&ct.render());
        }
        out.push_str(&format!(
            "throughput: {} req/s @ {} MHz | latency p50/p99: {}/{} ms | mean {} ms\n",
            f(self.requests_per_sec, 1),
            f(F_TYP_MHZ, 0),
            f(ms(self.p50_cycles), 2),
            f(ms(self.p99_cycles), 2),
            f(self.mean_latency_cycles / (F_TYP_MHZ * 1e3), 2),
        ));
        out.push_str(&format!(
            "fleet: {} MAC/cyc aggregate ({} while busy), utilization {}%, peak queue {}\n",
            f(self.aggregate_macs_per_cycle, 1),
            f(self.busy_macs_per_cycle, 1),
            f(self.shard_utilization * 100.0, 0),
            self.peak_queue_depth,
        ));
        if self.total_energy_pj > 0.0 {
            out.push_str(&format!(
                "energy: {} uJ/req | fleet avg power {} mW{} | {} TOPS/W | {} DVFS transitions\n",
                f(self.energy_uj_per_req, 2),
                f(self.fleet_avg_power_mw, 2),
                self.power_cap_mw.map_or(String::new(), |c| format!(" (cap {} mW)", f(c, 1))),
                f(self.fleet_tops_per_watt, 2),
                self.dvfs_transitions,
            ));
        }
        if self.deadline_misses > 0 || self.shed > 0 {
            out.push_str(&format!(
                "SLO: {} deadline misses ({}% of deadlined completions), {} shed before simulation\n",
                self.deadline_misses,
                f(self.miss_rate() * 100.0, 1),
                self.shed,
            ));
        }
        if self.requeued > 0 {
            out.push_str(&format!(
                "failover: {} requests retracted from failed shards and re-queued\n",
                self.requeued,
            ));
        }
        if self.scale_ups + self.scale_downs > 0 || self.occupancy.len() > 1 {
            let tail: Vec<String> = self
                .occupancy
                .iter()
                .take(8)
                .map(|&(t, n)| format!("{}:{n}", f(ms(t), 1)))
                .collect();
            out.push_str(&format!(
                "autoscale: {} ups / {} downs, mean {} active shards | occupancy[ms:active] {}{}\n",
                self.scale_ups,
                self.scale_downs,
                f(self.mean_active_shards(), 1),
                tail.join(" → "),
                if self.occupancy.len() > 8 {
                    format!(" … ({} more)", self.occupancy.len() - 8)
                } else {
                    String::new()
                },
            ));
        }
        out.push_str(&format!(
            "plan cache: {} hits / {} misses ({}% hit rate), {} compiled plans | batches: {} (mean {}/batch), model switches: {}\n",
            self.cache_hits,
            self.cache_misses,
            f(self.cache_hit_rate() * 100.0, 0),
            self.cache_entries,
            self.batches,
            f(self.mean_batch, 1),
            self.model_switches,
        ));
        if self.tuned.models > 0 {
            out.push_str(&format!(
                "autotune: {} models, measured per-inference cycles {} → {} ({}% saved, {} layers improved)\n",
                self.tuned.models,
                self.tuned.default_cycles,
                self.tuned.tuned_cycles,
                f(self.tuned.gain_fraction() * 100.0, 1),
                self.tuned.improved_layers,
            ));
        }
        let fp_total = self.fastpath_pure + self.fastpath_func + self.fastpath_miss;
        if fp_total > 0 {
            out.push_str(&format!(
                "sim fast path: {} pure + {} functional replays / {} windows ({}% replayed; host-side only)\n",
                self.fastpath_pure,
                self.fastpath_func,
                fp_total,
                f((self.fastpath_pure + self.fastpath_func) as f64 / fp_total as f64 * 100.0, 0),
            ));
        }
        out
    }
}

/// Metric-id token of a model/class name: lowercase, non
/// `[a-z0-9._-]` bytes collapsed to `-` (ids are slash-separated).
fn id_token(name: &str) -> String {
    name.to_lowercase()
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '-' })
        .collect()
}

impl MetricSource for FleetMetrics {
    /// The fleet report's **simulated** fields as artifact rows.
    ///
    /// Everything here is covered by the engine's determinism contract
    /// (a pure function of the trace — identical for any worker count
    /// or fast-path setting), so the rows are `Exact` wherever they
    /// derive from cycles/counts alone. Energy rows come through the
    /// calibrated [`crate::power::EnergyModel`] and are `Analog`. The
    /// host-side fast-path counters (`fastpath_*`) are deliberately
    /// excluded: they describe how the simulation was computed, can
    /// vary with thread interleaving, and must never gate a perf check.
    fn metric_rows(&self) -> Vec<MetricRow> {
        let mut rows = vec![
            MetricRow::exact("serve/fleet/served", self.served as f64, "requests"),
            MetricRow::exact("serve/fleet/rejected", self.rejected as f64, "requests"),
            MetricRow::exact("serve/fleet/shed", self.shed as f64, "requests"),
            MetricRow::exact(
                "serve/fleet/deadline_misses",
                self.deadline_misses as f64,
                "requests",
            ),
            MetricRow::exact("serve/fleet/span_cycles", self.span_cycles as f64, "cycles"),
            MetricRow::exact("serve/fleet/p50_cycles", self.p50_cycles as f64, "cycles"),
            MetricRow::exact("serve/fleet/p99_cycles", self.p99_cycles as f64, "cycles"),
            MetricRow::exact(
                "serve/fleet/mean_latency_cycles",
                self.mean_latency_cycles,
                "cycles",
            ),
            MetricRow::exact(
                "serve/fleet/requests_per_sec",
                self.requests_per_sec,
                "req/s",
            ),
            MetricRow::exact(
                "serve/fleet/agg_mac_per_cycle",
                self.aggregate_macs_per_cycle,
                "MAC/cycle",
            ),
            MetricRow::exact(
                "serve/fleet/busy_mac_per_cycle",
                self.busy_macs_per_cycle,
                "MAC/cycle",
            ),
            MetricRow::exact("serve/fleet/utilization", self.shard_utilization, "fraction"),
            MetricRow::exact("serve/fleet/peak_queue_depth", self.peak_queue_depth as f64, "requests"),
            MetricRow::exact("serve/fleet/batches", self.batches as f64, "batches"),
            MetricRow::exact("serve/fleet/mean_batch", self.mean_batch, "requests"),
            MetricRow::exact("serve/fleet/model_switches", self.model_switches as f64, "switches"),
            MetricRow::exact("serve/fleet/cache_hits", self.cache_hits as f64, "lookups"),
            MetricRow::exact("serve/fleet/cache_misses", self.cache_misses as f64, "lookups"),
            MetricRow::exact("serve/fleet/requeued", self.requeued as f64, "requests"),
            MetricRow::exact("serve/fleet/scale_ups", self.scale_ups as f64, "actions"),
            MetricRow::exact("serve/fleet/scale_downs", self.scale_downs as f64, "actions"),
            MetricRow::exact(
                "serve/fleet/mean_active_shards",
                self.mean_active_shards(),
                "shards",
            ),
            MetricRow::analog("serve/fleet/energy_uj_per_req", self.energy_uj_per_req, "uJ/req"),
            MetricRow::analog("serve/fleet/avg_power_mw", self.fleet_avg_power_mw, "mW"),
            MetricRow::analog("serve/fleet/tops_per_watt", self.fleet_tops_per_watt, "TOPS/W"),
            MetricRow::exact(
                "serve/fleet/dvfs_transitions",
                self.dvfs_transitions as f64,
                "transitions",
            ),
        ];
        for r in &self.rows {
            let p = format!("serve/model/{}", id_token(&r.name));
            rows.push(MetricRow::exact(format!("{p}/served"), r.served as f64, "requests"));
            rows.push(MetricRow::exact(format!("{p}/p50_cycles"), r.p50_cycles as f64, "cycles"));
            rows.push(MetricRow::exact(format!("{p}/p99_cycles"), r.p99_cycles as f64, "cycles"));
            rows.push(MetricRow::exact(
                format!("{p}/mean_exec_cycles"),
                r.mean_exec_cycles,
                "cycles",
            ));
            rows.push(MetricRow::exact(
                format!("{p}/mac_per_cycle"),
                r.macs_per_cycle,
                "MAC/cycle",
            ));
            rows.push(MetricRow::analog(format!("{p}/energy_uj"), r.energy_uj, "uJ/req"));
        }
        for c in &self.class_rows {
            let p = format!("serve/class/{}", id_token(&c.name));
            rows.push(MetricRow::exact(format!("{p}/served"), c.served as f64, "requests"));
            rows.push(MetricRow::exact(format!("{p}/missed"), c.missed as f64, "requests"));
            rows.push(MetricRow::exact(format!("{p}/shed"), c.shed as f64, "requests"));
            rows.push(MetricRow::exact(format!("{p}/p50_cycles"), c.p50_cycles as f64, "cycles"));
            rows.push(MetricRow::exact(format!("{p}/p99_cycles"), c.p99_cycles as f64, "cycles"));
            rows.push(MetricRow::exact(
                format!("{p}/violation_rate"),
                c.violation_rate(),
                "fraction",
            ));
        }
        if self.tuned.models > 0 {
            rows.push(MetricRow::exact(
                "serve/autotune/models",
                self.tuned.models as f64,
                "models",
            ));
            rows.push(MetricRow::exact(
                "serve/autotune/default_cycles",
                self.tuned.default_cycles as f64,
                "cycles",
            ));
            rows.push(MetricRow::exact(
                "serve/autotune/tuned_cycles",
                self.tuned.tuned_cycles as f64,
                "cycles",
            ));
            rows.push(MetricRow::exact(
                "serve/autotune/improved_layers",
                self.tuned.improved_layers as f64,
                "layers",
            ));
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression: true nearest-rank is the value at 1-based rank
    /// `ceil(q·N)`. The old `round((N-1)·q)` index reported the 51st of
    /// 100 samples as the median — this test fails on that code.
    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 0.5), 50); // ceil(0.5*100) = rank 50, not 51
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    /// Nearest-rank degenerate sizes: a singleton answers every
    /// quantile, and a pair splits at ceil(q·2) = 1 vs 2.
    #[test]
    fn percentile_small_samples() {
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&[7], q), 7);
        }
        assert_eq!(percentile(&[3, 9], 0.25), 3);
        assert_eq!(percentile(&[3, 9], 0.5), 3); // ceil(1.0) = rank 1
        assert_eq!(percentile(&[3, 9], 0.51), 9);
        assert_eq!(percentile(&[3, 9], 0.99), 9);
        assert_eq!(percentile(&[3, 9], 1.0), 9);
    }

    #[test]
    fn class_violation_rate_combines_misses_and_sheds() {
        let c = ClassRow {
            name: "x".into(),
            priority: 1,
            deadline_cycles: Some(100),
            served: 8,
            missed: 1,
            shed: 2,
            p50_cycles: 10,
            p99_cycles: 20,
        };
        assert!((c.violation_rate() - 0.3).abs() < 1e-12);
        let be = ClassRow {
            name: "b".into(),
            priority: 0,
            deadline_cycles: None,
            served: 0,
            missed: 0,
            shed: 0,
            p50_cycles: 0,
            p99_cycles: 0,
        };
        assert_eq!(be.violation_rate(), 0.0);
    }
}
