//! Golden (reference) integer executor.
//!
//! Straightforward, obviously-correct nested-loop implementations of every
//! operator. This is the correctness oracle for (a) the kernel library
//! running on the simulated cluster and (b) the AOT-lowered JAX/Pallas
//! golden models executed through PJRT — all three must agree bit-exactly,
//! because quantized inference is exact integer arithmetic.

use super::layer::{Layer, LayerKind, Network, NET_INPUT};
use super::{QTensor, QuantParams};

/// Execute one layer on an input tensor (HWC).
pub fn run_layer(layer: &Layer, input: &QTensor) -> QTensor {
    match &layer.kind {
        LayerKind::Conv2d { kh, kw, stride, pad } => {
            conv2d(input, layer.weights.as_ref().unwrap(), &layer.quant, *kh, *kw, *stride, *pad)
        }
        LayerKind::DwConv2d { kh, kw, stride, pad } => {
            dwconv2d(input, layer.weights.as_ref().unwrap(), &layer.quant, *kh, *kw, *stride, *pad)
        }
        LayerKind::Linear => linear(input, layer.weights.as_ref().unwrap(), &layer.quant),
        LayerKind::MaxPool { k, stride } => maxpool(input, *k, *stride),
        LayerKind::AvgPool { k, stride } => avgpool(input, &layer.quant, *k, *stride),
        LayerKind::Add { m1, m2 } => panic!(
            "Add needs two inputs, use run_add (m1={m1}, m2={m2})"
        ),
        LayerKind::Concat => panic!("Concat needs two inputs, use concat"),
    }
}

/// Execute a whole network on an input, returning every node's output
/// (needed both for residual edges and for layer-by-layer validation).
pub fn run_network(net: &Network, input: &QTensor) -> Vec<QTensor> {
    net.validate().expect("invalid network");
    let mut outs: Vec<QTensor> = Vec::with_capacity(net.nodes.len());
    for node in &net.nodes {
        let fetch = |src: usize| -> &QTensor {
            if src == NET_INPUT {
                input
            } else {
                &outs[src]
            }
        };
        let out = match &node.layer.kind {
            LayerKind::Add { m1, m2 } => run_add(
                fetch(node.inputs[0]),
                fetch(node.inputs[1]),
                *m1,
                *m2,
                &node.layer.quant,
            ),
            LayerKind::Concat => concat(fetch(node.inputs[0]), fetch(node.inputs[1])),
            _ => run_layer(&node.layer, fetch(node.inputs[0])),
        };
        debug_assert_eq!(
            out.shape,
            node.layer.out_shape.to_vec(),
            "layer {} produced wrong shape",
            node.layer.name
        );
        outs.push(out);
    }
    outs
}

/// Standard convolution: activations HWC unsigned, weights `[Cout,Kh,Kw,Cin]`
/// signed, zero padding, 32-bit accumulation, PULP-NN requantization.
pub fn conv2d(
    x: &QTensor,
    w: &QTensor,
    q: &QuantParams,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> QTensor {
    let (h, wi, cin) = (x.shape[0], x.shape[1], x.shape[2]);
    let cout = w.shape[0];
    assert_eq!(w.shape, vec![cout, kh, kw, cin]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wi + 2 * pad - kw) / stride + 1;
    let mut out = QTensor::zeros(&[oh, ow, cout], q.out_bits, false);
    for oy in 0..oh {
        for ox in 0..ow {
            for oc in 0..cout {
                let mut acc: i32 = 0;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        for ic in 0..cin {
                            let a = x.get_u(x.flat(&[iy as usize, ix as usize, ic])) as i32;
                            let wv = w.get_i(w.flat(&[oc, ky, kx, ic]));
                            acc = acc.wrapping_add(a.wrapping_mul(wv));
                        }
                    }
                }
                let o = q.requant(acc, oc);
                let idx = out.flat(&[oy, ox, oc]);
                out.set_u(idx, o);
            }
        }
    }
    out
}

/// Depthwise convolution: weights `[C, Kh, Kw, 1]`.
pub fn dwconv2d(
    x: &QTensor,
    w: &QTensor,
    q: &QuantParams,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> QTensor {
    let (h, wi, c) = (x.shape[0], x.shape[1], x.shape[2]);
    assert_eq!(w.shape, vec![c, kh, kw, 1]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wi + 2 * pad - kw) / stride + 1;
    let mut out = QTensor::zeros(&[oh, ow, c], q.out_bits, false);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut acc: i32 = 0;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        let a = x.get_u(x.flat(&[iy as usize, ix as usize, ch])) as i32;
                        let wv = w.get_i(w.flat(&[ch, ky, kx, 0]));
                        acc = acc.wrapping_add(a.wrapping_mul(wv));
                    }
                }
                let o = q.requant(acc, ch);
                let idx = out.flat(&[oy, ox, ch]);
                out.set_u(idx, o);
            }
        }
    }
    out
}

/// Fully connected over the flattened input; weights `[Cout, Cin]`.
pub fn linear(x: &QTensor, w: &QTensor, q: &QuantParams) -> QTensor {
    let cin = x.len();
    let cout = w.shape[0];
    assert_eq!(w.shape[1], cin, "linear weight shape mismatch");
    let mut out = QTensor::zeros(&[1, 1, cout], q.out_bits, false);
    for oc in 0..cout {
        let mut acc: i32 = 0;
        for ic in 0..cin {
            let a = x.get_u(ic) as i32;
            let wv = w.get_i(oc * cin + ic);
            acc = acc.wrapping_add(a.wrapping_mul(wv));
        }
        out.set_u(oc, q.requant(acc, oc));
    }
    out
}

/// Max pooling over unsigned activations.
pub fn maxpool(x: &QTensor, k: usize, stride: usize) -> QTensor {
    let (h, w, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = QTensor::zeros(&[oh, ow, c], x.bits, false);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut m = 0u32;
                for ky in 0..k {
                    for kx in 0..k {
                        m = m.max(x.get_u(x.flat(&[oy * stride + ky, ox * stride + kx, ch])));
                    }
                }
                let idx = out.flat(&[oy, ox, ch]);
                out.set_u(idx, m);
            }
        }
    }
    out
}

/// Average pooling: sum then requantize (the multiplier/shift encode 1/k²).
pub fn avgpool(x: &QTensor, q: &QuantParams, k: usize, stride: usize) -> QTensor {
    let (h, w, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = QTensor::zeros(&[oh, ow, c], q.out_bits, false);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut acc = 0i32;
                for ky in 0..k {
                    for kx in 0..k {
                        acc += x.get_u(x.flat(&[oy * stride + ky, ox * stride + kx, ch])) as i32;
                    }
                }
                let idx = out.flat(&[oy, ox, ch]);
                out.set_u(idx, q.requant(acc, ch));
            }
        }
    }
    out
}

/// Residual add with independent input scales:
/// `out = clip((x1*m1 + x2*m2) >> shift)`.
pub fn run_add(x1: &QTensor, x2: &QTensor, m1: i32, m2: i32, q: &QuantParams) -> QTensor {
    assert_eq!(x1.shape, x2.shape);
    let mut out = QTensor::zeros(&x1.shape, q.out_bits, false);
    for i in 0..x1.len() {
        let acc = (x1.get_u(i) as i64 * m1 as i64 + x2.get_u(i) as i64 * m2 as i64)
            >> q.shift;
        out.set_u(i, acc.clamp(0, q.clip_hi() as i64) as u32);
    }
    out
}

/// Channel-wise concatenation: `out[y][x] = x1[y][x] ++ x2[y][x]`. Both
/// inputs must share H×W and bit-width; pure data movement, no requant.
pub fn concat(x1: &QTensor, x2: &QTensor) -> QTensor {
    assert_eq!(x1.shape[0], x2.shape[0], "concat height mismatch");
    assert_eq!(x1.shape[1], x2.shape[1], "concat width mismatch");
    assert_eq!(x1.bits, x2.bits, "concat bit-width mismatch");
    let (h, w, c1, c2) = (x1.shape[0], x1.shape[1], x1.shape[2], x2.shape[2]);
    let mut out = QTensor::zeros(&[h, w, c1 + c2], x1.bits, false);
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c1 {
                let v = x1.get_u(x1.flat(&[y, x, ch]));
                let idx = out.flat(&[y, x, ch]);
                out.set_u(idx, v);
            }
            for ch in 0..c2 {
                let v = x2.get_u(x2.flat(&[y, x, ch]));
                let idx = out.flat(&[y, x, c1 + ch]);
                out.set_u(idx, v);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity-ish weights: w[oc][0][0][ic] = 1 if oc==ic.
        let x = QTensor::from_unsigned(&[2, 2, 4], 8, &(0..16).collect::<Vec<u32>>());
        let mut wvals = vec![0i32; 4 * 4];
        for i in 0..4 {
            wvals[i * 4 + i] = 1;
        }
        let w = QTensor::from_signed(&[4, 1, 1, 4], 8, &wvals);
        let q = QuantParams::scalar(1, 0, 0, 8, 4);
        let y = conv2d(&x, &w, &q, 1, 1, 1, 0);
        assert_eq!(y.to_vec_i32(), x.to_vec_i32());
    }

    #[test]
    fn conv_padding_zeroes_border() {
        // all-ones 3x3 kernel over all-ones 3x3 single-channel input:
        // center sees 9, corners see 4 (padding contributes 0).
        let x = QTensor::from_unsigned(&[3, 3, 1], 8, &[1; 9]);
        let w = QTensor::from_signed(&[1, 3, 3, 1], 8, &[1; 9]);
        let q = QuantParams::scalar(1, 0, 0, 8, 1);
        let y = conv2d(&x, &w, &q, 3, 3, 1, 1);
        let v = y.to_vec_i32();
        assert_eq!(v[4], 9); // center
        assert_eq!(v[0], 4); // corner
        assert_eq!(v[1], 6); // edge
    }

    #[test]
    fn conv_stride_2_shape() {
        let mut rng = Prng::new(5);
        let x = QTensor::random(&[8, 8, 8], 8, false, &mut rng);
        let w = QTensor::random(&[16, 3, 3, 8], 4, true, &mut rng);
        let q = QuantParams::scalar(1, 8, 0, 8, 16);
        let y = conv2d(&x, &w, &q, 3, 3, 2, 1);
        assert_eq!(y.shape, vec![4, 4, 16]);
    }

    #[test]
    fn dwconv_channelwise() {
        // Each channel convolved independently: channel c scaled by (c+1).
        let x = QTensor::from_unsigned(&[2, 2, 2], 8, &[1, 1, 1, 1, 1, 1, 1, 1]);
        let w = QTensor::from_signed(&[2, 1, 1, 1], 8, &[1, 2]);
        let q = QuantParams::scalar(1, 0, 0, 8, 2);
        let y = dwconv2d(&x, &w, &q, 1, 1, 1, 0);
        assert_eq!(y.to_vec_i32(), vec![1, 2, 1, 2, 1, 2, 1, 2]);
    }

    #[test]
    fn maxpool_basic() {
        let x = QTensor::from_unsigned(&[2, 2, 1], 8, &[1, 7, 3, 5]);
        let y = maxpool(&x, 2, 2);
        assert_eq!(y.to_vec_i32(), vec![7]);
    }

    #[test]
    fn avgpool_via_requant() {
        // 4 values summing to 16, multiplier 1 shift 2 -> 4 (exact /4)
        let x = QTensor::from_unsigned(&[2, 2, 1], 8, &[4, 4, 4, 4]);
        let q = QuantParams::scalar(1, 2, 0, 8, 1);
        let y = avgpool(&x, &q, 2, 2);
        assert_eq!(y.to_vec_i32(), vec![4]);
    }

    #[test]
    fn add_scales_and_clips() {
        let a = QTensor::from_unsigned(&[1, 1, 4], 8, &[10, 200, 0, 255]);
        let b = QTensor::from_unsigned(&[1, 1, 4], 8, &[5, 200, 0, 255]);
        let q = QuantParams::scalar(1, 1, 0, 8, 4);
        let y = run_add(&a, &b, 1, 1, &q);
        assert_eq!(y.to_vec_i32(), vec![7, 200, 0, 255]);
    }

    #[test]
    fn concat_interleaves_channels() {
        let a = QTensor::from_unsigned(&[1, 2, 2], 8, &[1, 2, 3, 4]);
        let b = QTensor::from_unsigned(&[1, 2, 2], 8, &[5, 6, 7, 8]);
        let y = concat(&a, &b);
        assert_eq!(y.shape, vec![1, 2, 4]);
        assert_eq!(y.to_vec_i32(), vec![1, 2, 5, 6, 3, 4, 7, 8]);
    }

    #[test]
    fn concat_asymmetric_channels() {
        let a = QTensor::from_unsigned(&[1, 1, 2], 4, &[1, 2]);
        let b = QTensor::from_unsigned(&[1, 1, 4], 4, &[3, 4, 5, 6]);
        let y = concat(&a, &b);
        assert_eq!(y.shape, vec![1, 1, 6]);
        assert_eq!(y.to_vec_i32(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn linear_matches_manual() {
        let x = QTensor::from_unsigned(&[1, 1, 4], 8, &[1, 2, 3, 4]);
        let w = QTensor::from_signed(&[2, 4], 8, &[1, 1, 1, 1, -1, 0, 0, 1]);
        let q = QuantParams::scalar(1, 0, 0, 8, 2);
        let y = linear(&x, &w, &q);
        assert_eq!(y.to_vec_i32(), vec![10, 3]);
    }
}
