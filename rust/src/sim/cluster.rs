//! The 8-core PULP cluster model: lockstep cycle simulation of the cores,
//! the 16-bank TCDM logarithmic interconnect (one request per bank per
//! cycle, rotating round-robin priority), the hardware synchronization
//! unit (barriers with clock-gated waiting) and the background DMA.

use super::core::{Core, CoreState};
use super::dma::Dma;
use super::mem::ClusterMem;
use super::stats::{ClusterStats, CoreStats};
use crate::isa::Program;
use crate::{CLUSTER_CORES, TCDM_BANKS};

/// The cluster simulator.
pub struct Cluster {
    pub mem: ClusterMem,
    pub cores: Vec<Core>,
    pub dma: Dma,
    /// Rotating arbitration priority offset.
    rr: usize,
    /// Global cycle counter.
    pub cycle: u64,
    /// Safety limit to catch runaway programs (0 = unlimited).
    pub max_cycles: u64,
    /// Reused per-cycle arbitration scratch (avoids per-cycle allocation
    /// — see EXPERIMENTS.md §Perf).
    want: Vec<Option<usize>>,
    granted: Vec<bool>,
}

impl Cluster {
    pub fn new(n_cores: usize) -> Self {
        Cluster {
            mem: ClusterMem::new(),
            cores: (0..n_cores).map(Core::new).collect(),
            dma: Dma::new(),
            rr: 0,
            cycle: 0,
            max_cycles: 20_000_000_000,
            want: vec![None; n_cores],
            granted: vec![false; n_cores],
        }
    }

    /// Standard 8-core cluster.
    pub fn pulp() -> Self {
        Self::new(CLUSTER_CORES)
    }

    /// Load one program per core (shorter vec leaves remaining cores
    /// halted). Resets core stats for a fresh measurement window.
    pub fn load_programs(&mut self, progs: Vec<Program>) {
        assert!(progs.len() <= self.cores.len());
        for core in &mut self.cores {
            core.stats = CoreStats::default();
        }
        for (core, prog) in self.cores.iter_mut().zip(progs) {
            core.load_program(prog);
        }
    }

    /// Advance one cycle. Returns false when everything is idle.
    pub fn step(&mut self) -> bool {
        let any_core_active =
            self.cores.iter().any(|c| c.state != CoreState::Halted);
        if !any_core_active && self.dma.idle() {
            return false;
        }
        self.cycle += 1;

        // Phase 1: collect TCDM requests from cores.
        let n = self.cores.len();
        for (i, c) in self.cores.iter().enumerate() {
            self.want[i] = c.mem_request().map(ClusterMem::bank_of);
        }
        // Phase 2: arbitrate one grant per bank; rotating priority
        // (conditional wraparound — integer division is the hot path's
        // single most expensive instruction otherwise).
        let mut bank_taken = [false; TCDM_BANKS];
        let mut i = self.rr;
        for _ in 0..n {
            self.granted[i] = false;
            if let Some(b) = self.want[i] {
                if !bank_taken[b] {
                    bank_taken[b] = true;
                    self.granted[i] = true;
                }
            }
            i += 1;
            if i >= n {
                i = 0;
            }
        }
        self.rr += 1;
        if self.rr >= n {
            self.rr = 0;
        }

        // Phase 3: tick cores (collecting barrier state on the way).
        let (mut waiting, mut running) = (0usize, 0usize);
        for i in 0..n {
            let core = &mut self.cores[i];
            core.tick(&mut self.mem, self.granted[i]);
            match core.state {
                CoreState::AtBarrier => waiting += 1,
                CoreState::Running => running += 1,
                CoreState::Halted => {}
            }
        }

        // Phase 4: DMA (lowest priority — blocked if any of its banks went
        // to a core this cycle).
        let dma_blocked = match self.dma.pending_banks() {
            Some([b0, b1]) => bank_taken[b0] || bank_taken[b1],
            None => false,
        };
        self.dma.tick(&mut self.mem, dma_blocked);

        // Phase 5: barrier release — when every non-halted core waits.
        if waiting > 0 && running == 0 {
            for c in &mut self.cores {
                if c.state == CoreState::AtBarrier {
                    c.release_barrier();
                }
            }
        }
        true
    }

    /// Run until all cores halt and the DMA drains. Returns the stats of
    /// this window (cycles counted from the call).
    pub fn run(&mut self) -> ClusterStats {
        let start_cycle = self.cycle;
        let start_dma_busy = self.dma.busy_cycles;
        let start_dma_bytes = self.dma.bytes_moved;
        while self.step() {
            if self.max_cycles > 0 && self.cycle - start_cycle > self.max_cycles {
                panic!(
                    "cluster exceeded max_cycles={} (runaway kernel?)",
                    self.max_cycles
                );
            }
        }
        ClusterStats {
            cycles: self.cycle - start_cycle,
            cores: self.cores.iter().map(|c| c.stats).collect(),
            dma_busy_cycles: self.dma.busy_cycles - start_dma_busy,
            dma_bytes: self.dma.bytes_moved - start_dma_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluOp, Instr};
    use crate::sim::mem::TCDM_BASE;

    fn alu_prog(n: usize) -> Program {
        let mut p = Program::new("alu");
        p.push(Instr::LpSetup { l: 0, count: n as u32, len: 1 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p.push(Instr::Halt);
        p
    }

    #[test]
    fn independent_alu_programs_run_in_parallel() {
        let mut cl = Cluster::new(8);
        cl.load_programs((0..8).map(|_| alu_prog(100)).collect());
        let stats = cl.run();
        // no memory => no contention => all finish in lockstep
        assert_eq!(stats.cores.len(), 8);
        for c in &stats.cores {
            assert_eq!(c.instrs, 102);
            assert_eq!(c.conflict_stalls, 0);
        }
        assert_eq!(stats.cycles, 102);
    }

    #[test]
    fn same_bank_loads_conflict() {
        // all 8 cores hammer the same word -> same bank -> serialization
        let mut cl = Cluster::new(8);
        let mut progs = vec![];
        for _ in 0..8 {
            let mut p = Program::new("ld");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::LpSetup { l: 0, count: 32, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            progs.push(p);
        }
        cl.load_programs(progs);
        let stats = cl.run();
        let total_conflicts: u64 = stats.cores.iter().map(|c| c.conflict_stalls).sum();
        assert!(total_conflicts > 0, "same-bank access must conflict");
        // 256 loads through 1 bank: lower bound ~256 cycles
        assert!(stats.cycles >= 256, "cycles={} too low", stats.cycles);
    }

    #[test]
    fn striped_banks_do_not_conflict() {
        // each core loads its own bank (core i -> word i)
        let mut cl = Cluster::new(8);
        let mut progs = vec![];
        for i in 0..8 {
            let mut p = Program::new("ld");
            p.push(Instr::Li { rd: 1, imm: (TCDM_BASE + 4 * i) as i32 });
            p.push(Instr::LpSetup { l: 0, count: 32, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            progs.push(p);
        }
        cl.load_programs(progs);
        let stats = cl.run();
        for c in &stats.cores {
            assert_eq!(c.conflict_stalls, 0);
        }
    }

    #[test]
    fn rotating_priority_is_fair() {
        // two cores fight for one bank; stalls should split roughly evenly
        let mut cl = Cluster::new(2);
        let mut progs = vec![];
        for _ in 0..2 {
            let mut p = Program::new("ld");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::LpSetup { l: 0, count: 100, len: 1 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            progs.push(p);
        }
        cl.load_programs(progs);
        let stats = cl.run();
        let s0 = stats.cores[0].conflict_stalls as i64;
        let s1 = stats.cores[1].conflict_stalls as i64;
        assert!((s0 - s1).abs() <= 2, "unfair arbitration: {s0} vs {s1}");
    }

    #[test]
    fn barrier_synchronizes_cores() {
        // core 0 runs long, core 1 short; both barrier then store cycle mark
        let mut cl = Cluster::new(2);
        let mut p0 = Program::new("long");
        p0.push(Instr::LpSetup { l: 0, count: 500, len: 1 });
        p0.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p0.push(Instr::Barrier);
        p0.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 0, imm: 7 });
        p0.push(Instr::Halt);
        let mut p1 = Program::new("short");
        p1.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p1.push(Instr::Barrier);
        p1.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 0, imm: 7 });
        p1.push(Instr::Halt);
        cl.load_programs(vec![p0, p1]);
        let stats = cl.run();
        // core 1 waited for core 0
        assert!(stats.cores[1].barrier_cycles >= 490, "{:?}", stats.cores[1]);
        assert!(stats.cores[0].barrier_cycles <= 5);
        assert_eq!(cl.cores[0].regs[3], 7);
        assert_eq!(cl.cores[1].regs[3], 7);
    }

    #[test]
    fn dma_overlaps_with_compute() {
        use crate::sim::dma::{DmaDir, DmaRequest};
        use crate::sim::mem::L2_BASE;
        let mut cl = Cluster::new(1);
        cl.mem.write_bytes(L2_BASE, &vec![0xAB; 4096]);
        cl.dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE + 8192, 4096));
        cl.load_programs(vec![alu_prog(2000)]);
        let stats = cl.run();
        // compute (2002 cycles) dominates the DMA (16 + 512) — full overlap
        assert!(stats.cycles < 2100, "cycles={} suggests no overlap", stats.cycles);
        assert_eq!(cl.mem.read_bytes(TCDM_BASE + 8192, 4096), vec![0xAB; 4096]);
    }

    #[test]
    fn dma_tail_extends_run() {
        use crate::sim::dma::{DmaDir, DmaRequest};
        use crate::sim::mem::L2_BASE;
        let mut cl = Cluster::new(1);
        cl.dma.push(DmaRequest::linear(DmaDir::L2ToTcdm, L2_BASE, TCDM_BASE, 8000));
        cl.load_programs(vec![alu_prog(10)]);
        let stats = cl.run();
        // DMA 16 + 1000 beats dominates the 12-cycle program
        assert!(stats.cycles >= 1000, "cycles={}", stats.cycles);
    }
}
