//! Single-core instruction-set simulator with RI5CY pipeline timing.
//!
//! The core executes a [`Program`] (the IR emitted by the kernel library)
//! with exact integer semantics and a cycle cost model of the RI5CY 4-stage
//! in-order single-issue pipeline (§II-A), extended per ISA variant with the
//! Dotp unit + MPC (mixed-precision slicing, Fig. 2) and the Mac&Load
//! controller + NN-RF (§III, Fig. 4).
//!
//! The cluster drives cores through a two-phase protocol each cycle:
//! [`Core::mem_request`] peeks whether the next instruction needs a TCDM
//! port (and which bank), the cluster arbitrates, then [`Core::tick`]
//! either retires the instruction or records a conflict stall.
//!
//! Timing fidelity is tiered ([`CoreFidelity`], see [`super::pipeline`]):
//! the fast tier charges the flat RI5CY costs above; the pipeline tier
//! additionally charges Mac&Load write-back port contention and sub-word
//! realignment bubbles — as retire-time modeled-cycle charges, never as
//! extra ticks, so functional behavior and arbitration are identical
//! across tiers.

use super::mem::ClusterMem;
use super::mlc::MlcChannel;
use super::pipeline::{is_gp_lsu, is_nn_wb_load, CoreFidelity, PipeState};
use super::stats::CoreStats;
use crate::isa::{
    AluOp, Cond, Csr, Instr, MlChannel, MlUpdate, Program, SimdFmt,
};

/// Hardware-loop state (RI5CY has two nesting levels).
#[derive(Clone, Copy, Debug, Default)]
struct HwLoop {
    start: usize,
    end: usize, // exclusive: index one past the last body instruction
    remaining: u32,
    active: bool,
}

/// Core execution phase.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CoreState {
    Running,
    /// Waiting at a barrier (clock-gated by the HW sync unit).
    AtBarrier,
    Halted,
}

/// One simulated core.
#[derive(Clone, Debug)]
pub struct Core {
    pub id: usize,
    pub regs: [u32; 32],
    /// Flex-V NN register file (W0-W3 = slots 0-3, A0-A1 = slots 4-5).
    pub nnrf: [u32; 6],
    pub pc: usize,
    prog: Program,
    loops: [HwLoop; 2],
    /// MLC activation channel.
    pub mlc_a: MlcChannel,
    /// MLC weight channel.
    pub mlc_w: MlcChannel,
    /// Informational CSR values (simd_fmt etc. — the generators resolve
    /// virtual instructions statically, but writes are costed and stored).
    pub csrs: [u32; 16],
    pub state: CoreState,
    /// Extra stall cycles to consume before the next issue.
    pending_stall: u32,
    /// Destination of the load retired in the previous cycle (load-use).
    hazard_reg: Option<u8>,
    /// Timing tier this core charges (functional semantics are tier-
    /// independent; see [`super::pipeline`]).
    fidelity: CoreFidelity,
    /// Pipeline-tier micro-state (WB-port claim, sub-word hazard flavor);
    /// stays default in the fast tier.
    pipe: PipeState,
    /// Cached TCDM request of the instruction at `pc` (recomputed after
    /// every architectural change — saves a full decode per cycle, see
    /// EXPERIMENTS.md §Perf).
    cached_req: Option<u32>,
    pub stats: CoreStats,
}

impl Core {
    pub fn new(id: usize) -> Self {
        Core {
            id,
            regs: [0; 32],
            nnrf: [0; 6],
            pc: 0,
            prog: Program::new("idle"),
            loops: Default::default(),
            mlc_a: MlcChannel::default(),
            mlc_w: MlcChannel::default(),
            csrs: [0; 16],
            state: CoreState::Halted,
            pending_stall: 0,
            hazard_reg: None,
            fidelity: CoreFidelity::Fast,
            pipe: PipeState::default(),
            cached_req: None,
            stats: CoreStats::default(),
        }
    }

    /// Select the timing tier (the cluster applies it fleet-wide; see
    /// [`super::Cluster::set_fidelity`]).
    pub(crate) fn set_fidelity(&mut self, f: CoreFidelity) {
        self.fidelity = f;
    }

    /// Load a program and reset architectural state (keeps stats).
    pub fn load_program(&mut self, prog: Program) {
        self.prog = prog;
        self.pc = 0;
        self.loops = Default::default();
        self.state = CoreState::Running;
        self.pending_stall = 0;
        self.hazard_reg = None;
        self.pipe = PipeState::default();
        self.refresh_req();
    }

    pub fn halted(&self) -> bool {
        self.state == CoreState::Halted
    }

    /// Label of the loaded program (the kernel name — the cycle-domain
    /// trace uses it to name window spans).
    pub fn program_name(&self) -> &str {
        &self.prog.label
    }

    fn reg(&self, r: u8) -> u32 {
        self.regs[r as usize]
    }

    fn set_reg(&mut self, r: u8, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    fn csr_idx(c: Csr) -> usize {
        match c {
            Csr::SimdFmt => 0,
            Csr::MixSkip => 1,
            Csr::SbLegacy => 2,
            Csr::AStride => 3,
            Csr::WStride => 4,
            Csr::ARollback => 5,
            Csr::WRollback => 6,
            Csr::ASkip => 7,
            Csr::WSkip => 8,
            Csr::ABase => 9,
            Csr::WBase => 10,
        }
    }

    /// Phase 1: does the next issue need a TCDM access, and at which
    /// address? Returns `None` when stalled, halted, or non-memory.
    #[inline]
    pub fn mem_request(&self) -> Option<u32> {
        if self.state != CoreState::Running || self.pending_stall > 0 {
            return None;
        }
        self.cached_req
    }

    /// Recompute the cached TCDM request for the instruction at `pc`.
    #[inline]
    fn refresh_req(&mut self) {
        let Some(i) = self.prog.instrs.get(self.pc) else {
            self.cached_req = None;
            return;
        };
        self.cached_req = match *i {
            Instr::Lw { base, off, .. } | Instr::Lbu { base, off, .. } => {
                Some(self.reg(base).wrapping_add(off as u32))
            }
            Instr::Sw { base, off, .. } | Instr::Sb { base, off, .. } => {
                Some(self.reg(base).wrapping_add(off as u32))
            }
            Instr::NnLoad { ch, .. } => Some(self.mlc(ch).peek()),
            Instr::MlSdotp { upd: MlUpdate::Load { ch, .. }, .. } => Some(self.mlc(ch).peek()),
            _ => None,
        };
    }

    fn mlc(&self, ch: MlChannel) -> &MlcChannel {
        match ch {
            MlChannel::Act => &self.mlc_a,
            MlChannel::Wgt => &self.mlc_w,
        }
    }

    fn mlc_mut(&mut self, ch: MlChannel) -> &mut MlcChannel {
        match ch {
            MlChannel::Act => &mut self.mlc_a,
            MlChannel::Wgt => &mut self.mlc_w,
        }
    }

    /// Phase 2: advance one cycle. `mem_granted` tells whether the TCDM
    /// port requested in phase 1 was won (ignored for non-memory issues).
    /// Returns true if an instruction retired this cycle.
    #[inline]
    pub fn tick(&mut self, mem: &mut ClusterMem, mem_granted: bool) -> bool {
        match self.state {
            CoreState::Halted => return false,
            CoreState::AtBarrier => {
                self.stats.barrier_cycles += 1;
                self.stats.cycles += 1;
                return false;
            }
            CoreState::Running => {}
        }
        self.stats.cycles += 1;
        if self.pending_stall > 0 {
            self.pending_stall -= 1;
            // Branch bubbles drain the pipe; no WB-port claim survives
            // them (the claimant retired at least a cycle ago).
            self.pipe.wb_load_armed = false;
            return false;
        }
        let instr = self.prog.instrs[self.pc];
        // Load-use hazard: consumer immediately following a load stalls 1cy.
        if let Some(h) = self.hazard_reg {
            if reads_reg(&instr, h) {
                self.hazard_reg = None;
                self.stats.loaduse_stalls += 1;
                if self.fidelity == CoreFidelity::Pipeline {
                    // Sub-word loads realign in WB: their consumer pays a
                    // 2-cycle penalty. The extra cycle is charged into the
                    // modeled count only — never as a tick — so the
                    // cluster's arbitration is tier-independent (see
                    // super::pipeline).
                    if self.pipe.hazard_subword {
                        self.stats.align_stalls += 1;
                        self.stats.cycles += 1;
                    }
                    // The bubble also releases any WB-port claim.
                    self.pipe = PipeState::default();
                }
                return false;
            }
        }
        self.hazard_reg = None;
        if instr.is_mem() && !mem_granted {
            self.stats.conflict_stalls += 1;
            // A conflict bubble separates the WB slots too.
            self.pipe.wb_load_armed = false;
            return false;
        }
        if self.fidelity == CoreFidelity::Pipeline {
            // Mac&Load WB-port contention: a GP-LSU memory op retiring
            // cycle-adjacent behind an NN-RF write-back load bubbles once
            // (modeled-cycle charge; same no-tick rule as above).
            if self.pipe.wb_load_armed && is_gp_lsu(&instr) {
                self.stats.wbport_stalls += 1;
                self.stats.cycles += 1;
            }
            self.pipe.wb_load_armed = is_nn_wb_load(&instr);
            self.pipe.hazard_subword = matches!(instr, Instr::Lbu { .. });
        }
        self.execute(instr, mem);
        true
    }

    /// Execute one instruction (functional + PC/loop bookkeeping).
    fn execute(&mut self, instr: Instr, mem: &mut ClusterMem) {
        self.stats.instrs += 1;
        let mut next_pc = self.pc + 1;
        match instr {
            Instr::Li { rd, imm } => self.set_reg(rd, imm as u32),
            Instr::Alu { op, rd, rs1, rs2 } => {
                let v = alu(op, self.reg(rs1), self.reg(rs2));
                self.set_reg(rd, v);
            }
            Instr::AluI { op, rd, rs1, imm } => {
                let v = alu(op, self.reg(rs1), imm as u32);
                self.set_reg(rd, v);
            }
            Instr::ExtractU { rd, rs1, off, len } => {
                let v = (self.reg(rs1) >> off) & ((1u32 << len) - 1);
                self.set_reg(rd, v);
            }
            Instr::Extract { rd, rs1, off, len } => {
                let v = (self.reg(rs1) >> off) & ((1u32 << len) - 1);
                let sh = 32 - len as u32;
                self.set_reg(rd, (((v << sh) as i32) >> sh) as u32);
            }
            Instr::Insert { rd, rs1, off, len } => {
                let mask = ((1u32 << len) - 1) << off;
                let v = (self.reg(rd) & !mask) | ((self.reg(rs1) << off) & mask);
                self.set_reg(rd, v);
            }
            Instr::Lw { rd, base, off, post_inc } => {
                let addr = self.reg(base).wrapping_add(off as u32);
                let v = mem.traced_load_u32(addr);
                self.set_reg(rd, v);
                if post_inc != 0 {
                    let nb = self.reg(base).wrapping_add(post_inc as u32);
                    self.set_reg(base, nb);
                }
                self.stats.tcdm_accesses += 1;
                self.hazard_reg = Some(rd);
            }
            Instr::Lbu { rd, base, off, post_inc } => {
                let addr = self.reg(base).wrapping_add(off as u32);
                let v = mem.traced_load_u8(addr) as u32;
                self.set_reg(rd, v);
                if post_inc != 0 {
                    let nb = self.reg(base).wrapping_add(post_inc as u32);
                    self.set_reg(base, nb);
                }
                self.stats.tcdm_accesses += 1;
                self.hazard_reg = Some(rd);
            }
            Instr::Sw { rs, base, off, post_inc } => {
                let addr = self.reg(base).wrapping_add(off as u32);
                mem.store_u32(addr, self.reg(rs));
                if post_inc != 0 {
                    let nb = self.reg(base).wrapping_add(post_inc as u32);
                    self.set_reg(base, nb);
                }
                self.stats.tcdm_accesses += 1;
            }
            Instr::Sb { rs, base, off, post_inc } => {
                let addr = self.reg(base).wrapping_add(off as u32);
                mem.store_u8(addr, self.reg(rs) as u8);
                if post_inc != 0 {
                    let nb = self.reg(base).wrapping_add(post_inc as u32);
                    self.set_reg(base, nb);
                }
                self.stats.tcdm_accesses += 1;
            }
            Instr::Mac { rd, rs1, rs2 } => {
                let v = (self.reg(rd) as i32)
                    .wrapping_add((self.reg(rs1) as i32).wrapping_mul(self.reg(rs2) as i32));
                self.set_reg(rd, v as u32);
                self.stats.macs += 1;
            }
            Instr::Clipu { rd, rs1, bits } => {
                let hi = (1i32 << bits) - 1;
                let v = (self.reg(rs1) as i32).clamp(0, hi);
                self.set_reg(rd, v as u32);
            }
            Instr::Sdotp { rd, ra, rw, a_fmt, w_fmt, sub } => {
                let d = dotp(self.reg(ra), self.reg(rw), a_fmt, w_fmt, sub);
                let v = (self.reg(rd) as i32).wrapping_add(d);
                self.set_reg(rd, v as u32);
                self.stats.dotp_instrs += 1;
                self.stats.macs += (32 / a_fmt.bits().max(w_fmt.bits())) as u64;
            }
            Instr::MlSdotp { acc, a_slot, w_slot, a_fmt, w_fmt, sub, upd } => {
                let d = dotp(
                    self.nnrf[a_slot as usize],
                    self.nnrf[w_slot as usize],
                    a_fmt,
                    w_fmt,
                    sub,
                );
                let v = (self.reg(acc) as i32).wrapping_add(d);
                self.set_reg(acc, v as u32);
                if let MlUpdate::Load { ch, slot } = upd {
                    let addr = self.mlc_mut(ch).next();
                    let w = mem.traced_load_u32(addr);
                    self.nnrf[slot as usize] = w;
                    self.stats.tcdm_accesses += 1;
                }
                self.stats.dotp_instrs += 1;
                self.stats.macload_instrs += 1;
                self.stats.macs += (32 / a_fmt.bits().max(w_fmt.bits())) as u64;
            }
            Instr::NnLoad { ch, slot } => {
                let addr = self.mlc_mut(ch).next();
                let w = mem.traced_load_u32(addr);
                self.nnrf[slot as usize] = w;
                self.stats.tcdm_accesses += 1;
            }
            Instr::CsrW { csr, imm } => {
                self.csrs[Self::csr_idx(csr)] = imm;
                // MLC channels are (re)configured through their CSRs.
                match csr {
                    Csr::AStride => self.mlc_a.stride = imm as i32,
                    Csr::WStride => self.mlc_w.stride = imm as i32,
                    Csr::ARollback => self.mlc_a.rollback = imm as i32,
                    Csr::WRollback => self.mlc_w.rollback = imm as i32,
                    Csr::ASkip => self.mlc_a.skip = imm,
                    Csr::WSkip => self.mlc_w.skip = imm,
                    Csr::ABase => {
                        self.mlc_a.addr = imm;
                        self.mlc_a.cnt = 0;
                    }
                    Csr::WBase => {
                        self.mlc_w.addr = imm;
                        self.mlc_w.cnt = 0;
                    }
                    _ => {}
                }
                self.stats.csr_writes += 1;
            }
            Instr::LpSetup { l, count, len } => {
                debug_assert!(l < 2, "RI5CY has two hardware loops");
                debug_assert!(count > 0, "hardware loop with zero count");
                self.loops[l as usize] = HwLoop {
                    start: self.pc + 1,
                    end: self.pc + 1 + len as usize,
                    remaining: count,
                    active: true,
                };
            }
            Instr::Branch { cond, rs1, rs2, off } => {
                let a = self.reg(rs1) as i32;
                let b = self.reg(rs2) as i32;
                let taken = match cond {
                    Cond::Eq => a == b,
                    Cond::Ne => a != b,
                    Cond::Lt => a < b,
                    Cond::Ge => a >= b,
                };
                if taken {
                    next_pc = (self.pc as i64 + off as i64) as usize;
                    self.pending_stall += 2;
                    self.stats.branch_stalls += 2;
                }
            }
            Instr::Barrier => {
                self.state = CoreState::AtBarrier;
            }
            Instr::Halt => {
                self.state = CoreState::Halted;
                self.stats.cycles -= 0; // halt retires in its cycle
            }
        }
        // Hardware-loop PC redirection: innermost (0) checked first.
        self.pc = next_pc;
        for l in [0usize, 1] {
            let lp = &mut self.loops[l];
            if lp.active && self.pc == lp.end {
                lp.remaining -= 1;
                if lp.remaining > 0 {
                    self.pc = lp.start;
                    break;
                } else {
                    lp.active = false;
                }
            }
        }
        if self.state == CoreState::Running {
            self.refresh_req();
        } else {
            self.cached_req = None;
        }
    }

    /// Release from barrier (called by the cluster's sync unit).
    pub fn release_barrier(&mut self) {
        debug_assert_eq!(self.state, CoreState::AtBarrier);
        self.state = CoreState::Running;
        self.refresh_req();
    }

    /// Fast-path functional execution: retire instructions back-to-back
    /// with exact integer semantics but **no** cycle, stall, or
    /// arbitration accounting, until the core leaves `Running` (barrier
    /// or halt). Timing is replayed from the steady-state memo instead
    /// (see [`crate::sim::fastpath`]); `max_instrs` bounds runaway
    /// programs like `Cluster::max_cycles` bounds the cycle loop.
    pub(crate) fn run_functional(&mut self, mem: &mut ClusterMem, max_instrs: u64) {
        let mut n: u64 = 0;
        while self.state == CoreState::Running {
            let instr = self.prog.instrs[self.pc];
            self.execute(instr, mem);
            n += 1;
            assert!(
                n <= max_instrs,
                "fast-path functional runaway in '{}' (core {})",
                self.prog.label,
                self.id
            );
        }
        // Pipeline micro-state (branch bubbles, load-use hazards, WB-port
        // claims) is not modeled functionally; normalize it to a drained
        // pipeline.
        self.pending_stall = 0;
        self.hazard_reg = None;
        self.pipe = PipeState::default();
    }

    /// Hash the core's **structural** identity for the fast-path window
    /// key: run state, program position, and instruction stream — the
    /// inputs that (together with the DMA schedule and arbiter phase)
    /// fully determine the window's timing, since generated kernels have
    /// no data-dependent control flow or addressing.
    pub(crate) fn hash_structure(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        if self.state == CoreState::Halted {
            0u8.hash(h);
        } else {
            1u8.hash(h);
            self.pc.hash(h);
            self.prog.instrs.hash(h);
        }
    }

    /// Hash the core's architectural **data** state (registers, NN-RF,
    /// CSRs, MLC channels) — deliberately excluded from the structural
    /// key, and validated separately before a pure (functional-delta)
    /// replay.
    pub(crate) fn hash_arch_state(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        self.regs.hash(h);
        self.nnrf.hash(h);
        self.csrs.hash(h);
        for ch in [&self.mlc_a, &self.mlc_w] {
            ch.addr.hash(h);
            ch.stride.hash(h);
            ch.rollback.hash(h);
            ch.skip.hash(h);
            ch.cnt.hash(h);
        }
    }
}

/// Scalar ALU semantics.
fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Sll => a.wrapping_shl(b & 31),
        AluOp::Srl => a.wrapping_shr(b & 31),
        AluOp::Sra => ((a as i32).wrapping_shr(b & 31)) as u32,
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Min => (a as i32).min(b as i32) as u32,
        AluOp::Max => (a as i32).max(b as i32) as u32,
    }
}

/// The mixed-precision Dotp unit (Fig. 2): unsigned activations × signed
/// weights, accumulated at 32 bit. When formats differ, the MPC slicer
/// selects subgroup `sub` of the *narrower* operand's word and the router
/// feeds the dotp sub-unit of the *wider* format.
pub fn dotp(a_word: u32, w_word: u32, a_fmt: SimdFmt, w_fmt: SimdFmt, sub: u8) -> i32 {
    let a_bits = a_fmt.bits() as u32;
    let w_bits = w_fmt.bits() as u32;
    let lanes = (32 / a_bits.max(w_bits)) as u32;
    let (a_off, w_off) = if a_bits >= w_bits {
        (0, sub as u32 * lanes)
    } else {
        (sub as u32 * lanes, 0)
    };
    let mut acc: i32 = 0;
    for i in 0..lanes {
        let ai = (a_off + i) * a_bits;
        let ua = (a_word >> ai) & mask(a_bits);
        let wi = (w_off + i) * w_bits;
        let uw = (w_word >> wi) & mask(w_bits);
        let sw = sign_extend(uw, w_bits);
        acc = acc.wrapping_add((ua as i32).wrapping_mul(sw));
    }
    acc
}

fn mask(bits: u32) -> u32 {
    if bits >= 32 { u32::MAX } else { (1u32 << bits) - 1 }
}

fn sign_extend(v: u32, bits: u32) -> i32 {
    let sh = 32 - bits;
    ((v << sh) as i32) >> sh
}

/// Register-read set check for load-use hazard detection.
fn reads_reg(i: &Instr, r: u8) -> bool {
    if r == 0 {
        return false;
    }
    match *i {
        Instr::Alu { rs1, rs2, .. } => rs1 == r || rs2 == r,
        Instr::AluI { rs1, .. } => rs1 == r,
        Instr::ExtractU { rs1, .. } | Instr::Extract { rs1, .. } => rs1 == r,
        Instr::Insert { rd, rs1, .. } => rd == r || rs1 == r,
        Instr::Lw { base, .. } | Instr::Lbu { base, .. } => base == r,
        Instr::Sw { rs, base, .. } | Instr::Sb { rs, base, .. } => rs == r || base == r,
        Instr::Mac { rd, rs1, rs2 } => rd == r || rs1 == r || rs2 == r,
        Instr::Clipu { rs1, .. } => rs1 == r,
        Instr::Sdotp { rd, ra, rw, .. } => rd == r || ra == r || rw == r,
        // Mac&Load reads its accumulator from the GP-RF; NN-RF sources are
        // forwarded inside the Mac&Load datapath (no GP hazard).
        Instr::MlSdotp { acc, .. } => acc == r,
        Instr::Branch { rs1, rs2, .. } => rs1 == r || rs2 == r,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::TCDM_BASE;

    fn run_single(prog: Program) -> (Core, ClusterMem) {
        let mut mem = ClusterMem::new();
        run_single_with_mem(prog, &mut mem)
    }

    fn run_single_with_mem(prog: Program, mem: &mut ClusterMem) -> (Core, ClusterMem) {
        run_single_fid(prog, mem, CoreFidelity::Fast)
    }

    fn run_single_fid(
        prog: Program,
        mem: &mut ClusterMem,
        fid: CoreFidelity,
    ) -> (Core, ClusterMem) {
        let mut c = Core::new(0);
        c.set_fidelity(fid);
        c.load_program(prog);
        let mut guard = 0;
        while !c.halted() {
            let granted = c.mem_request().is_some();
            c.tick(mem, granted);
            guard += 1;
            assert!(guard < 1_000_000, "runaway program");
        }
        (c, mem.clone())
    }

    #[test]
    fn alu_and_li() {
        let mut p = Program::new("t");
        p.push(Instr::Li { rd: 1, imm: 5 });
        p.push(Instr::Li { rd: 2, imm: 7 });
        p.push(Instr::Alu { op: AluOp::Add, rd: 3, rs1: 1, rs2: 2 });
        p.push(Instr::Halt);
        let (c, _) = run_single(p);
        assert_eq!(c.regs[3], 12);
        assert_eq!(c.stats.instrs, 4);
    }

    #[test]
    fn x0_hardwired_zero() {
        let mut p = Program::new("t");
        p.push(Instr::Li { rd: 0, imm: 99 });
        p.push(Instr::Halt);
        let (c, _) = run_single(p);
        assert_eq!(c.regs[0], 0);
    }

    #[test]
    fn load_store_post_increment() {
        let mut mem = ClusterMem::new();
        mem.store_u32(TCDM_BASE, 0xAABB_CCDD);
        mem.store_u32(TCDM_BASE + 4, 0x1122_3344);
        let mut p = Program::new("t");
        p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
        p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 4 });
        p.push(Instr::Lw { rd: 3, base: 1, off: 0, post_inc: 4 });
        p.push(Instr::Halt);
        let (c, _) = run_single_with_mem(p, &mut mem);
        assert_eq!(c.regs[2], 0xAABB_CCDD);
        assert_eq!(c.regs[3], 0x1122_3344);
        assert_eq!(c.regs[1], TCDM_BASE + 8);
    }

    #[test]
    fn load_use_hazard_costs_one_cycle() {
        let mut mem = ClusterMem::new();
        mem.store_u32(TCDM_BASE, 3);
        // lw then immediately use -> 1 stall
        let mut p = Program::new("t");
        p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
        p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 2, imm: 1 });
        p.push(Instr::Halt);
        let (c, _) = run_single_with_mem(p, &mut mem);
        assert_eq!(c.regs[3], 4);
        assert_eq!(c.stats.loaduse_stalls, 1);
        assert_eq!(c.stats.cycles, 5); // 4 instrs + 1 stall

        // independent instruction in between -> no stall
        let mut mem2 = ClusterMem::new();
        mem2.store_u32(TCDM_BASE, 3);
        let mut p2 = Program::new("t");
        p2.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
        p2.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
        p2.push(Instr::Li { rd: 4, imm: 9 });
        p2.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 2, imm: 1 });
        p2.push(Instr::Halt);
        let (c2, _) = run_single_with_mem(p2, &mut mem2);
        assert_eq!(c2.stats.loaduse_stalls, 0);
    }

    #[test]
    fn hw_loop_zero_overhead() {
        // loop 10x over 2 ALU instructions = exactly 20 cycles + setup + halt
        let mut p = Program::new("t");
        p.push(Instr::LpSetup { l: 0, count: 10, len: 2 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 2, rs1: 2, imm: 2 });
        p.push(Instr::Halt);
        let (c, _) = run_single(p);
        assert_eq!(c.regs[1], 10);
        assert_eq!(c.regs[2], 20);
        assert_eq!(c.stats.cycles, 1 + 20 + 1);
    }

    #[test]
    fn nested_hw_loops() {
        let mut p = Program::new("t");
        p.push(Instr::LpSetup { l: 1, count: 3, len: 3 });
        p.push(Instr::LpSetup { l: 0, count: 4, len: 1 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p.push(Instr::AluI { op: AluOp::Add, rd: 2, rs1: 2, imm: 1 });
        p.push(Instr::Halt);
        let (c, _) = run_single(p);
        assert_eq!(c.regs[1], 12); // 3 * 4
        assert_eq!(c.regs[2], 3);
    }

    #[test]
    fn dotp_uniform_8bit() {
        // a = [1,2,3,4] (u8), w = [1,-1,2,-2] (i8)
        let a = u32::from_le_bytes([1, 2, 3, 4]);
        let w = u32::from_le_bytes([1u8, 0xFF, 2, 0xFE]);
        assert_eq!(dotp(a, w, SimdFmt::Byte, SimdFmt::Byte, 0), 1 - 2 + 6 - 8);
    }

    #[test]
    fn dotp_uniform_crumb() {
        // 16 lanes of a=1 (01 repeated), w=-1 (11 repeated) -> -16
        let a = 0x5555_5555;
        let w = 0xFFFF_FFFF;
        assert_eq!(dotp(a, w, SimdFmt::Crumb, SimdFmt::Crumb, 0), -16);
    }

    #[test]
    fn dotp_mixed_a8w4_subgroups() {
        // a = [10, 20, 30, 40] u8; w-word = 8 nibbles [1,2,3,4,-1,-2,-3,-4]
        let a = u32::from_le_bytes([10, 20, 30, 40]);
        let mut w = 0u32;
        for (i, v) in [1i32, 2, 3, 4, -1, -2, -3, -4].iter().enumerate() {
            w |= ((*v as u32) & 0xF) << (4 * i);
        }
        // subgroup 0: nibbles 0..4 = [1,2,3,4]
        assert_eq!(
            dotp(a, w, SimdFmt::Byte, SimdFmt::Nibble, 0),
            10 + 40 + 90 + 160
        );
        // subgroup 1: nibbles 4..8 = [-1,-2,-3,-4]
        assert_eq!(
            dotp(a, w, SimdFmt::Byte, SimdFmt::Nibble, 1),
            -(10 + 40 + 90 + 160)
        );
    }

    #[test]
    fn dotp_mixed_a4w2() {
        // 8 lanes. a nibbles all 3; w crumbs: subgroup 1 all -2 (0b10)
        let a = 0x3333_3333;
        let w = 0xAAAA_0000; // low 16 bits irrelevant (subgroup 0)
        assert_eq!(dotp(a, w, SimdFmt::Nibble, SimdFmt::Crumb, 1), 8 * 3 * -2);
    }

    #[test]
    fn mlsdotp_accumulates_and_loads() {
        let mut mem = ClusterMem::new();
        // weight stream at TCDM_BASE: two words
        mem.store_u32(TCDM_BASE, u32::from_le_bytes([1, 1, 1, 1]));
        mem.store_u32(TCDM_BASE + 4, u32::from_le_bytes([2, 2, 2, 2]));
        let mut p = Program::new("t");
        p.push(Instr::CsrW { csr: Csr::WStride, imm: 4 });
        p.push(Instr::CsrW { csr: Csr::WBase, imm: TCDM_BASE });
        // fill W0 explicitly
        p.push(Instr::NnLoad { ch: MlChannel::Wgt, slot: 0 });
        // acc += dot(A0, W0) with WB load of next w word into W1
        p.push(Instr::MlSdotp {
            acc: 5,
            a_slot: 4,
            w_slot: 0,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Byte,
            sub: 0,
            upd: MlUpdate::Load { ch: MlChannel::Wgt, slot: 1 },
        });
        p.push(Instr::Halt);
        let mut c = Core::new(0);
        c.load_program(p);
        c.nnrf[4] = u32::from_le_bytes([3, 3, 3, 3]); // A0 = [3,3,3,3]
        while !c.halted() {
            let granted = c.mem_request().is_some();
            c.tick(&mut mem, granted);
        }
        assert_eq!(c.regs[5] as i32, 4 * 3); // dot([3..],[1..])
        assert_eq!(c.nnrf[1], u32::from_le_bytes([2, 2, 2, 2])); // WB load
        assert_eq!(c.nnrf[0], u32::from_le_bytes([1, 1, 1, 1]));
    }

    #[test]
    fn branch_taken_costs_two_bubbles() {
        let mut p = Program::new("t");
        p.push(Instr::Li { rd: 1, imm: 0 });
        p.push(Instr::Li { rd: 2, imm: 3 });
        // loop: r1 += 1; if r1 != r2 goto loop
        p.push(Instr::AluI { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        p.push(Instr::Branch { cond: Cond::Ne, rs1: 1, rs2: 2, off: -1 });
        p.push(Instr::Halt);
        let (c, _) = run_single(p);
        assert_eq!(c.regs[1], 3);
        assert_eq!(c.stats.branch_stalls, 4); // 2 taken branches * 2 bubbles
    }

    /// NN-RF write-back load followed cycle-adjacent by a GP-LSU memory
    /// op: the pipeline tier charges one WB-port bubble; the fast tier
    /// charges nothing. Architectural state is identical either way.
    #[test]
    fn wbport_contention_pipeline_only() {
        let prog = || {
            let mut p = Program::new("t");
            p.push(Instr::CsrW { csr: Csr::WStride, imm: 4 });
            p.push(Instr::CsrW { csr: Csr::WBase, imm: TCDM_BASE });
            p.push(Instr::Li { rd: 1, imm: (TCDM_BASE + 64) as i32 });
            p.push(Instr::NnLoad { ch: MlChannel::Wgt, slot: 0 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::Halt);
            p
        };
        let mut m1 = ClusterMem::new();
        m1.store_u32(TCDM_BASE, 0x11223344);
        m1.store_u32(TCDM_BASE + 64, 7);
        let mut m2 = m1.clone();
        let (fast, _) = run_single_fid(prog(), &mut m1, CoreFidelity::Fast);
        let (pipe, _) = run_single_fid(prog(), &mut m2, CoreFidelity::Pipeline);
        assert_eq!(fast.regs, pipe.regs);
        assert_eq!(fast.nnrf, pipe.nnrf);
        assert_eq!(fast.stats.wbport_stalls, 0);
        assert_eq!(pipe.stats.wbport_stalls, 1);
        assert_eq!(pipe.stats.cycles, fast.stats.cycles + 1);
    }

    /// Back-to-back Mac&Load WB loads do *not* contend (the NN-RF has
    /// its own write port — the §III design point), and an intervening
    /// non-memory instruction clears the WB-port claim.
    #[test]
    fn wbport_claim_spares_macload_chains_and_expires() {
        let mut p = Program::new("t");
        p.push(Instr::CsrW { csr: Csr::WStride, imm: 4 });
        p.push(Instr::CsrW { csr: Csr::WBase, imm: TCDM_BASE });
        p.push(Instr::Li { rd: 1, imm: (TCDM_BASE + 64) as i32 });
        p.push(Instr::NnLoad { ch: MlChannel::Wgt, slot: 0 });
        p.push(Instr::NnLoad { ch: MlChannel::Wgt, slot: 1 }); // NN->NN: free
        p.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 0, imm: 1 }); // drains claim
        p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 }); // not adjacent
        p.push(Instr::Halt);
        let mut mem = ClusterMem::new();
        let (c, _) = run_single_fid(p, &mut mem, CoreFidelity::Pipeline);
        assert_eq!(c.stats.wbport_stalls, 0);
        assert_eq!(c.stats.align_stalls, 0);
    }

    /// Sub-word (`lbu`) load-use costs 2 cycles on the pipeline tier:
    /// the shared 1-cycle load-use stall plus one realignment cycle.
    #[test]
    fn subword_load_use_costs_extra_cycle_on_pipeline() {
        let prog = || {
            let mut p = Program::new("t");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::Lbu { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 2, imm: 1 });
            p.push(Instr::Halt);
            p
        };
        let mut m1 = ClusterMem::new();
        m1.store_u8(TCDM_BASE, 9);
        let mut m2 = m1.clone();
        let (fast, _) = run_single_fid(prog(), &mut m1, CoreFidelity::Fast);
        let (pipe, _) = run_single_fid(prog(), &mut m2, CoreFidelity::Pipeline);
        assert_eq!(fast.regs[3], 10);
        assert_eq!(pipe.regs[3], 10);
        assert_eq!((fast.stats.loaduse_stalls, fast.stats.align_stalls), (1, 0));
        assert_eq!((pipe.stats.loaduse_stalls, pipe.stats.align_stalls), (1, 1));
        assert_eq!(pipe.stats.cycles, fast.stats.cycles + 1);

        // word-load consumer pays no realignment cycle on either tier
        let word = || {
            let mut p = Program::new("t");
            p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
            p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
            p.push(Instr::AluI { op: AluOp::Add, rd: 3, rs1: 2, imm: 1 });
            p.push(Instr::Halt);
            p
        };
        let mut m3 = ClusterMem::new();
        let (w, _) = run_single_fid(word(), &mut m3, CoreFidelity::Pipeline);
        assert_eq!((w.stats.loaduse_stalls, w.stats.align_stalls), (1, 0));
    }

    #[test]
    fn conflict_stall_counted_when_not_granted() {
        let mut mem = ClusterMem::new();
        let mut p = Program::new("t");
        p.push(Instr::Li { rd: 1, imm: TCDM_BASE as i32 });
        p.push(Instr::Lw { rd: 2, base: 1, off: 0, post_inc: 0 });
        p.push(Instr::Halt);
        let mut c = Core::new(0);
        c.load_program(p);
        c.tick(&mut mem, false); // li
        c.tick(&mut mem, false); // lw denied -> stall
        assert_eq!(c.stats.conflict_stalls, 1);
        c.tick(&mut mem, true); // lw granted
        assert_eq!(c.regs[2], 0);
        assert_eq!(c.stats.tcdm_accesses, 1);
    }
}
