//! Reference cost models for the non-PULP comparison points of the
//! evaluation (Table IV).
//!
//! The STM32H7 row reproduces Capotondi et al.'s CMix-NN results [12]:
//! a Cortex-M7 at 480 MHz running mixed-precision CNN kernels with
//! software packing/unpacking. We model it as published per-network
//! MAC/cycle constants — re-simulating a Cortex-M7 pipeline would add
//! nothing to the comparison, since the paper itself cites these numbers.

/// STM32H7 (CMix-NN) end-to-end MAC/cycle for a MobileNetV1 profile.
/// Returns `None` where the paper reports none (ResNet-20 was not run).
pub fn stm32h7_macs_per_cycle(profile: crate::models::Profile) -> Option<f64> {
    match profile {
        crate::models::Profile::Uniform8 => Some(0.33),
        crate::models::Profile::Mixed8a4w => Some(0.30),
        crate::models::Profile::Mixed4a2w => None,
    }
}

/// STM32H7 clock [MHz].
pub const STM32H7_MHZ: f64 = 480.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Profile;

    #[test]
    fn table4_constants() {
        assert_eq!(stm32h7_macs_per_cycle(Profile::Uniform8), Some(0.33));
        assert_eq!(stm32h7_macs_per_cycle(Profile::Mixed8a4w), Some(0.30));
        assert_eq!(stm32h7_macs_per_cycle(Profile::Mixed4a2w), None);
    }
}
