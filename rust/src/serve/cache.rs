//! The compiled-plan cache.
//!
//! `dory::deploy` (tiling solve + L2 layout + weight serialization + DMA
//! schedule generation) is the expensive, input-independent part of a
//! request — the analog of DORY's offline C-code generation. The cache
//! keys it by [`PlanKey`] (model × precision config × tiling parameters ×
//! target) so it runs **once per model**, not once per request; every
//! shard then shares the same immutable [`Deployment`] through an `Arc`
//! — which is also what lets a dispatch round's shard batches execute
//! on different host threads without copying a plan.
//!
//! [`PlanKey`] is the repo-wide structural identity: the same type keys
//! this cache, the coordinator's per-tile timing memo
//! (`PlanKey::for_tile`), and model residency on shards, so all caches
//! agree on when two pieces of work are interchangeable. Lookups happen
//! during sequential batch formation, keeping hit/miss accounting
//! deterministic.

use std::collections::HashMap;
use std::sync::Arc;

use crate::dory::deploy::Deployment;
use crate::dory::PlanKey;

/// Plan cache with hit/miss accounting.
#[derive(Default)]
pub struct PlanCache {
    map: HashMap<PlanKey, Arc<Deployment>>,
    pub hits: u64,
    pub misses: u64,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    /// Look up `key`, building (and caching) the deployment on a miss.
    pub fn get_or_build(
        &mut self,
        key: PlanKey,
        build: impl FnOnce() -> Deployment,
    ) -> Arc<Deployment> {
        if let Some(dep) = self.map.get(&key) {
            self.hits += 1;
            return dep.clone();
        }
        self.misses += 1;
        let dep = Arc::new(build());
        self.map.insert(key, dep.clone());
        dep
    }

    /// Warm-migrate every plan from `other`, overwriting same-key
    /// entries (live rollout: tuned and default deployments share a
    /// [`PlanKey`], so installing a tuned plan over the default *is*
    /// the version switch — see `serve::federation::rollout`). Plans
    /// are shared by `Arc`, not copied; accounting counters are
    /// untouched (migration is not a lookup).
    pub fn warm_from(&mut self, other: &PlanCache) {
        for (k, dep) in &other.map {
            self.map.insert(*k, dep.clone());
        }
    }

    /// Distinct compiled plans resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dory::deploy::deploy;
    use crate::dory::MemBudget;
    use crate::isa::IsaVariant;
    use crate::qnn::layer::Network;
    use crate::qnn::Layer;
    use crate::util::Prng;

    #[test]
    fn builds_once_per_key() {
        let mut rng = Prng::new(9);
        let mut net = Network::new("c", [8, 8, 8], 8);
        net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        let key = PlanKey::for_network(&net, IsaVariant::FlexV, MemBudget::default(), 8);
        let mut cache = PlanCache::new();
        let mut builds = 0;
        for _ in 0..5 {
            let dep = cache.get_or_build(key, || {
                builds += 1;
                deploy(&net, IsaVariant::FlexV, MemBudget::default())
            });
            assert_eq!(dep.isa, IsaVariant::FlexV);
        }
        assert_eq!(builds, 1);
        assert_eq!((cache.hits, cache.misses, cache.len()), (4, 1, 1));
        assert!(cache.hit_rate() > 0.7);
    }
}
