//! The instruction IR.

/// General-purpose register index (x0..x31, x0 hardwired to zero).
pub type Reg = u8;

/// NN register-file slot (Flex-V has six 32-bit NN-RF registers:
/// four weight slots W0-W3 and two activation slots A0-A1, §III).
pub type NnSlot = u8;

/// Number of NN-RF slots.
pub const NN_RF_SLOTS: usize = 6;
/// NN-RF slot indices for the four weight registers.
pub const NN_W0: NnSlot = 0;
/// NN-RF slot indices for the two activation registers.
pub const NN_A0: NnSlot = 4;

/// SIMD element format of one operand of a dot-product instruction.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum SimdFmt {
    /// 16-bit halves (2 per word) — XpulpV2 `pv.sdotp.h`.
    Half,
    /// 8-bit bytes (4 per word) — XpulpV2 `pv.sdotp.b`.
    Byte,
    /// 4-bit nibbles (8 per word) — XpulpNN `pv.sdotp.n`.
    Nibble,
    /// 2-bit crumbs (16 per word) — XpulpNN `pv.sdotp.c`.
    Crumb,
}

impl SimdFmt {
    pub fn bits(self) -> u8 {
        match self {
            SimdFmt::Half => 16,
            SimdFmt::Byte => 8,
            SimdFmt::Nibble => 4,
            SimdFmt::Crumb => 2,
        }
    }

    pub fn from_bits(bits: u8) -> SimdFmt {
        match bits {
            16 => SimdFmt::Half,
            8 => SimdFmt::Byte,
            4 => SimdFmt::Nibble,
            2 => SimdFmt::Crumb,
            _ => panic!("no SIMD format for {bits} bits"),
        }
    }

    /// Elements per 32-bit word.
    pub fn lanes(self) -> usize {
        32 / self.bits() as usize
    }
}

/// Scalar ALU operations (subset used by the kernels).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum AluOp {
    Add,
    Sub,
    And,
    Or,
    Xor,
    Sll,
    Srl,
    Sra,
    Mul,
    Min,
    Max,
}

/// Branch conditions.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
}

/// Control-status registers of the Flex-V / MPIC extensions (§III).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Csr {
    /// Encoded activation+weight SIMD precision (MPC input).
    SimdFmt,
    /// Weight-reuse factor for mixed precision (MPC input).
    MixSkip,
    /// XpulpNN-compatible legacy Mac&Load mode.
    SbLegacy,
    /// MLC channel parameters (activation / weight): innermost stride,
    AStride,
    WStride,
    /// rollback applied at the end of an innermost sweep,
    ARollback,
    WRollback,
    /// number of innermost iterations between rollbacks,
    ASkip,
    WSkip,
    /// and channel base addresses.
    ABase,
    WBase,
}

/// Which MLC address channel an operation targets.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum MlChannel {
    Act,
    Wgt,
}

/// Write-back-stage update performed by a fused Mac&Load instruction:
/// load a 32-bit word from the MLC-generated address of the given channel
/// into an NN-RF slot.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum MlUpdate {
    /// No WB load (plain sdotp through the Mac&Load datapath).
    None,
    /// Load next word of the channel into NN-RF slot.
    Load { ch: MlChannel, slot: NnSlot },
}

/// One instruction of the semantic IR. Cycle costs are assigned by the ISS
/// ([`crate::sim::core`]); this enum captures *what* executes.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Instr {
    /// Load immediate (lui+addi pair or c.li — costed as one issue slot).
    Li { rd: Reg, imm: i32 },
    /// Register-register ALU op.
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    /// Register-immediate ALU op.
    AluI { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    /// XpulpV2 `p.extractu rd, rs1, len, off` — unsigned bit-field extract.
    ExtractU { rd: Reg, rs1: Reg, off: u8, len: u8 },
    /// XpulpV2 `p.extract` — sign-extending bit-field extract.
    Extract { rd: Reg, rs1: Reg, off: u8, len: u8 },
    /// XpulpV2 `p.insert rd, rs1, len, off` — bit-field insert into rd.
    Insert { rd: Reg, rs1: Reg, off: u8, len: u8 },
    /// Word load; `post_inc != 0` is the XpulpV2 post-modified `p.lw`.
    Lw { rd: Reg, base: Reg, off: i32, post_inc: i32 },
    /// Unsigned byte load (post-modified if `post_inc != 0`).
    Lbu { rd: Reg, base: Reg, off: i32, post_inc: i32 },
    /// Word store (post-modified if `post_inc != 0`).
    Sw { rs: Reg, base: Reg, off: i32, post_inc: i32 },
    /// Byte store (post-modified if `post_inc != 0`).
    Sb { rs: Reg, base: Reg, off: i32, post_inc: i32 },
    /// XpulpV2 `p.mac rd, rs1, rs2`: rd += rs1 * rs2 (32-bit).
    Mac { rd: Reg, rs1: Reg, rs2: Reg },
    /// XpulpV2 `p.clipu`: clip rd to `[0, 2^bits - 1]`.
    Clipu { rd: Reg, rs1: Reg, bits: u8 },
    /// SIMD sum-of-dot-product `rd += dot(a, w)`.
    ///
    /// `a_fmt`/`w_fmt` are the (CSR-resolved) element formats; when they
    /// differ this is a *mixed-precision* sdotp (MPIC / Flex-V only) and
    /// `sub` selects which subgroup of the narrower operand's word the
    /// MPC slicer routes into the dotp unit (Fig. 2b).
    Sdotp {
        rd: Reg,
        ra: Reg,
        rw: Reg,
        a_fmt: SimdFmt,
        w_fmt: SimdFmt,
        /// Subgroup of the narrower operand selected by MPC_CNT.
        sub: u8,
    },
    /// Fused Mac&Load `pv.mlsdot{u}sp` (§III): a SIMD sdotp whose operands
    /// come from the NN-RF, plus an optional WB-stage load from an
    /// MLC-generated address into an NN-RF slot.
    MlSdotp {
        /// Accumulator in the GP-RF.
        acc: Reg,
        /// NN-RF slot holding the activation word.
        a_slot: NnSlot,
        /// NN-RF slot holding the (packed) weight word.
        w_slot: NnSlot,
        a_fmt: SimdFmt,
        w_fmt: SimdFmt,
        /// Subgroup of the narrower operand (MPC_CNT).
        sub: u8,
        /// The fused write-back load.
        upd: MlUpdate,
    },
    /// Explicit NN-RF fill through the MLC channel pointer (used in the
    /// kernel prologue: "four weights and one activation are loaded
    /// explicitly to fill the NN-RF").
    NnLoad { ch: MlChannel, slot: NnSlot },
    /// CSR write (immediate form; kernels configure MLC/MPC before loops).
    CsrW { csr: Csr, imm: u32 },
    /// XpulpV2 hardware loop: execute the next `len` instructions `count`
    /// times with zero branch overhead. Two nesting levels (`l` ∈ {0,1}).
    LpSetup { l: u8, count: u32, len: u16 },
    /// Conditional branch by instruction offset (rarely used: hw loops
    /// cover kernel control flow; epilogues are generated statically).
    Branch { cond: Cond, rs1: Reg, rs2: Reg, off: i32 },
    /// Cluster barrier (hardware synchronization unit).
    Barrier,
    /// End of stream for this core.
    Halt,
}

impl Instr {
    /// True if the instruction performs a TCDM data access in its EX/WB
    /// stage (participates in bank arbitration).
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Instr::Lw { .. }
                | Instr::Lbu { .. }
                | Instr::Sw { .. }
                | Instr::Sb { .. }
                | Instr::NnLoad { .. }
                | Instr::MlSdotp { upd: MlUpdate::Load { .. }, .. }
        )
    }

    /// MAC operations this instruction contributes (for MAC/cycle metrics).
    pub fn macs(&self) -> usize {
        match self {
            Instr::Sdotp { a_fmt, w_fmt, .. }
            | Instr::MlSdotp { a_fmt, w_fmt, .. } => {
                32 / a_fmt.bits().max(w_fmt.bits()) as usize
            }
            Instr::Mac { .. } => 1,
            _ => 0,
        }
    }
}

/// A per-core instruction stream plus entry metadata.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub instrs: Vec<Instr>,
    /// Human-readable label for traces.
    pub label: String,
}

impl Program {
    pub fn new(label: impl Into<String>) -> Self {
        Program { instrs: vec![], label: label.into() }
    }

    pub fn push(&mut self, i: Instr) -> &mut Self {
        self.instrs.push(i);
        self
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Static MAC count of one full execution (resolving hardware loops).
    /// Used to sanity-check generators against layer geometry.
    pub fn static_macs(&self) -> u64 {
        // simulate loop structure without executing
        fn count(instrs: &[Instr], start: usize, end: usize) -> u64 {
            let mut total = 0u64;
            let mut pc = start;
            while pc < end {
                match instrs[pc] {
                    Instr::LpSetup { count: c, len, .. } => {
                        let body = count(instrs, pc + 1, pc + 1 + len as usize);
                        total += body * c as u64;
                        pc += 1 + len as usize;
                    }
                    ref i => {
                        total += i.macs() as u64;
                        pc += 1;
                    }
                }
            }
            total
        }
        count(&self.instrs, 0, self.instrs.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simd_fmt_lanes() {
        assert_eq!(SimdFmt::Byte.lanes(), 4);
        assert_eq!(SimdFmt::Nibble.lanes(), 8);
        assert_eq!(SimdFmt::Crumb.lanes(), 16);
        assert_eq!(SimdFmt::Half.lanes(), 2);
    }

    #[test]
    fn sdotp_mac_count_is_wider_operand() {
        let i = Instr::Sdotp {
            rd: 1,
            ra: 2,
            rw: 3,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Nibble,
            sub: 0,
        };
        // a8w4: 4 MACs (wider operand = 8 bit, 4 lanes)
        assert_eq!(i.macs(), 4);
    }

    #[test]
    fn mem_classification() {
        assert!(Instr::Lw { rd: 1, base: 2, off: 0, post_inc: 4 }.is_mem());
        assert!(!Instr::Li { rd: 1, imm: 3 }.is_mem());
        let ml_none = Instr::MlSdotp {
            acc: 1,
            a_slot: 4,
            w_slot: 0,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Byte,
            sub: 0,
            upd: MlUpdate::None,
        };
        assert!(!ml_none.is_mem());
        let ml_load = Instr::MlSdotp {
            acc: 1,
            a_slot: 4,
            w_slot: 0,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Byte,
            sub: 0,
            upd: MlUpdate::Load { ch: MlChannel::Act, slot: 5 },
        };
        assert!(ml_load.is_mem());
    }

    #[test]
    fn static_macs_resolves_nested_loops() {
        let mut p = Program::new("t");
        // outer loop 3x { inner loop 5x { sdotp(16 macs) } }
        p.push(Instr::LpSetup { l: 1, count: 3, len: 2 });
        p.push(Instr::LpSetup { l: 0, count: 5, len: 1 });
        p.push(Instr::Sdotp {
            rd: 1,
            ra: 2,
            rw: 3,
            a_fmt: SimdFmt::Crumb,
            w_fmt: SimdFmt::Crumb,
            sub: 0,
        });
        p.push(Instr::Halt);
        assert_eq!(p.static_macs(), 3 * 5 * 16);
    }
}
