#!/usr/bin/env python3
"""Regenerate the committed model zoo under models/*.qir.

Mirrors the Rust graph builders (`rust/src/models/mod.rs`) and the
canonical QIR printer (`rust/src/qnn/qir.rs::print`) byte-for-byte: the
`qir-zoo` CI job diffs `flexv qir export <model>` against the committed
files, so this script and the Rust side must stay in lockstep. The paper
networks are emitted at their canonical inputs (MobileNetV1 at 224x224,
ResNet-20 at 32x32); the extension models have fixed inputs.

Usage: python3 tools/gen_qir.py   (from the repo root)
"""

import os

QIR_VERSION = 1


def next_pow2_log2(k):
    """k.max(1).next_power_of_two().trailing_zeros() from the Rust side."""
    k = max(k, 1)
    return (k - 1).bit_length() if k > 1 else 0


def quant_for(k, a_bits, w_bits, out_bits):
    """models::quant_for -> (mult, shift, bias) scalar."""
    acc_bits = (a_bits + w_bits - 1) + next_pow2_log2(k)
    shift = min(max(acc_bits - out_bits - 1, 0), 31)
    return (1, shift, 0)


def avgpool_quant(window):
    return ((1 << 16) // window, 16, 0)


class Graph:
    def __init__(self, name, input_shape, input_bits, seed):
        self.name = name
        self.seed = seed
        self.lines = []  # (tensor_line, op_line) pairs, in definition order
        self.input_line = "tensor input {}x{}x{} a{}".format(*input_shape, input_bits)
        self.shapes = {"input": tuple(input_shape)}
        self.bits = {"input": input_bits}

    def op(self, kind, name, inputs, out_shape, out_bits, quant, attrs, seed=None):
        m, s, b = quant
        t = "tensor {} {}x{}x{} a{} q{}:{}:{}".format(name, *out_shape, out_bits, m, s, b)
        o = "op {} {} {} -> {}".format(kind, name, " ".join(inputs), name)
        if attrs:
            o += " " + attrs
        if seed is not None:
            o += f" seed={seed}"
        self.lines.append((t, o))
        self.shapes[name] = tuple(out_shape)
        self.bits[name] = out_bits
        return name

    def conv(self, name, src, cout, k, stride, w_bits, out_bits, seed=None):
        h, w, cin = self.shapes[src]
        pad = k // 2
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        a = self.bits[src]
        attrs = f"k{k} s{stride} p{pad} a{a}w{w_bits}"
        return self.op("conv", name, [src], (oh, ow, cout), out_bits,
                       quant_for(k * k * cin, a, w_bits, out_bits), attrs, seed)

    def dwconv(self, name, src, stride, w_bits):
        h, w, c = self.shapes[src]
        oh = (h + 2 - 3) // stride + 1
        ow = (w + 2 - 3) // stride + 1
        a = self.bits[src]
        attrs = f"k3 s{stride} p1 a{a}w{w_bits}"
        return self.op("dwconv", name, [src], (oh, ow, c), a,
                       quant_for(9, a, w_bits, a), attrs)

    def linear(self, name, src, cout, w_bits, seed=None):
        h, w, c = self.shapes[src]
        a = self.bits[src]
        return self.op("linear", name, [src], (1, 1, cout), 8,
                       quant_for(h * w * c, a, w_bits, 8), f"a{a}w{w_bits}", seed)

    def pool(self, kind, name, src, k, stride, quant, out_bits=None):
        h, w, c = self.shapes[src]
        oh = (h - k) // stride + 1
        ow = (w - k) // stride + 1
        bits = out_bits if out_bits is not None else self.bits[src]
        return self.op(kind, name, [src], (oh, ow, c), bits, quant, f"k{k} s{stride}")

    def add(self, name, a, b, m1=1, m2=1):
        shape = self.shapes[a]
        bits = self.bits[a]
        return self.op("add", name, [a, b], shape, bits, (1, 1, 0), f"m{m1}:{m2}")

    def concat(self, name, a, b):
        h, w, c1 = self.shapes[a]
        c2 = self.shapes[b][2]
        return self.op("concat", name, [a, b], (h, w, c1 + c2), self.bits[a],
                       (1, 0, 0), "")

    def render(self):
        out = [f"# flexv QIR v{QIR_VERSION}: {self.name}",
               f"qir {QIR_VERSION}",
               f"net {self.name}",
               f"seed {self.seed}",
               "input input",
               self.input_line]
        for t, o in self.lines:
            out.append(t)
            out.append(o)
        return "\n".join(out) + "\n"


MNV1_BLOCKS = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
               (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
               (1024, 1)]


def mobilenet_v1(profile, alpha=0.75, input_hw=224, seed=11):
    w4 = profile == "8b4b"
    wb = 4 if w4 else 8
    ch = lambda c: max(round(c * alpha / 8.0) * 8, 8)
    g = Graph(f"MobileNetV1-{profile}(a{alpha})", (input_hw, input_hw, 4), 8, seed)
    t = g.conv("conv1", "input", ch(32), 3, 2, 8, 8)
    for i, (cout, stride) in enumerate(MNV1_BLOCKS):
        t = g.dwconv(f"dw{i + 1}", t, stride, wb)
        t = g.conv(f"pw{i + 1}", t, ch(cout), 1, 1, wb, 8)
    h = g.shapes[t][0]
    t = g.pool("avgpool", "avgpool", t, h, h, avgpool_quant(h * h), out_bits=8)
    g.linear("fc", t, 1000, wb, seed=seed ^ 0xFC)
    return g


def resnet20(profile="4b2b", seed=12):
    a_bits, w_early, w_late = (4, 2, 4) if profile == "4b2b" else (8, 8, 8)
    g = Graph(f"ResNet20-{profile}", (32, 32, 4), 8, seed)
    t = g.conv("conv1", "input", 16, 3, 1, 8, a_bits)
    for s, c in enumerate([16, 32, 64]):
        for b in range(3):
            wb = w_late if (s == 2 and b > 0) else w_early
            stride = 2 if (s > 0 and b == 0) else 1
            entry = t
            id1 = g.conv(f"s{s}b{b}c1", entry, c, 3, stride, wb, a_bits)
            id2 = g.conv(f"s{s}b{b}c2", id1, c, 3, 1, wb, a_bits)
            if stride != 1 or g.shapes[entry][2] != c:
                short = g.conv(f"s{s}b{b}proj", entry, c, 1, stride, wb, a_bits)
            else:
                short = entry
            t = g.add(f"s{s}b{b}add", id2, short)
    h = g.shapes[t][0]
    t = g.pool("avgpool", "avgpool", t, h, h, avgpool_quant(h * h), out_bits=8)
    g.linear("fc", t, 12, 8)
    return g


def dscnn():
    """DS-CNN keyword spotting: 48x12 MFCC map, 4 ds-blocks at 64ch, a8w4."""
    g = Graph("DSCNN-8b4b", (48, 12, 4), 8, 21)
    t = g.conv("conv1", "input", 64, 3, 2, 8, 8)
    for i in range(1, 5):
        t = g.dwconv(f"dw{i}", t, 1, 4)
        t = g.conv(f"pw{i}", t, 64, 1, 1, 4, 8)
    t = g.pool("avgpool", "avgpool", t, 6, 6, avgpool_quant(36), out_bits=8)
    g.linear("fc", t, 12, 4)
    return g


def resdw():
    """Residual depthwise-separable stack: two ds-residual blocks per
    width, a maxpool + pointwise transition between them, a8w4 body."""
    g = Graph("ResDW-8b4b", (32, 32, 8), 8, 22)
    t = g.conv("conv1", "input", 32, 3, 1, 8, 8)
    for i in (1, 2):
        d = g.dwconv(f"b{i}dw", t, 1, 4)
        p = g.conv(f"b{i}pw", d, 32, 1, 1, 4, 8)
        t = g.add(f"b{i}add", p, t)
    t = g.pool("maxpool", "pool", t, 2, 2, (1, 0, 0))
    t = g.conv("trans", t, 64, 1, 1, 4, 8)
    for i in (3, 4):
        d = g.dwconv(f"b{i}dw", t, 1, 4)
        p = g.conv(f"b{i}pw", d, 64, 1, 1, 4, 8)
        t = g.add(f"b{i}add", p, t)
    t = g.pool("avgpool", "avgpool", t, 16, 16, avgpool_quant(256), out_bits=8)
    g.linear("fc", t, 16, 8)
    return g


def mixer():
    """Tiny attention-ish mixer block: a depthwise spatial branch and a
    pointwise channel branch concatenated, residual add to the input,
    then a 2-layer pointwise MLP with a second residual."""
    g = Graph("Mixer-8b4b", (8, 8, 32), 8, 23)
    da = g.dwconv("dwa", "input", 1, 4)
    pa = g.conv("pwa", da, 16, 1, 1, 4, 8)
    pb = g.conv("pwb", "input", 16, 1, 1, 8, 8)
    cat = g.concat("cat", pa, pb)
    res = g.add("res", cat, "input")
    m1 = g.conv("mlp1", res, 64, 1, 1, 4, 8)
    m2 = g.conv("mlp2", m1, 32, 1, 1, 4, 8)
    res2 = g.add("res2", res, m2)
    t = g.pool("avgpool", "avgpool", res2, 8, 8, avgpool_quant(64), out_bits=8)
    g.linear("fc", t, 8, 4)
    return g


def main():
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    out_dir = os.path.join(root, "models")
    os.makedirs(out_dir, exist_ok=True)
    zoo = {
        "mnv1-8b.qir": mobilenet_v1("8b"),
        "mnv1-8b4b.qir": mobilenet_v1("8b4b"),
        "resnet20-4b2b.qir": resnet20(),
        "dscnn-8b4b.qir": dscnn(),
        "resdw-8b4b.qir": resdw(),
        "mixer-8b4b.qir": mixer(),
    }
    for fname, g in zoo.items():
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(g.render())
        print(f"wrote {path} ({len(g.lines)} ops)")


if __name__ == "__main__":
    main()
