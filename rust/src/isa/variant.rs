//! The ISA capability matrix for the four cores the paper compares
//! (§V-B, Table III) plus helpers the kernel generators query to decide
//! which instruction sequences are legal on each core.

use super::instr::SimdFmt;
use crate::qnn::Precision;

/// Matrix-multiplication register-blocking shape (§III: RI5CY saturates the
/// GP-RF at 4×2; the Flex-V NN-RF extends it to 4×4).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct UnrollShape {
    /// Filters (output channels) per inner loop.
    pub filters: usize,
    /// im2col buffers (output pixels) per inner loop.
    pub buffers: usize,
}

impl UnrollShape {
    pub const fn new(filters: usize, buffers: usize) -> Self {
        UnrollShape { filters, buffers }
    }

    /// Accumulators this shape keeps live in the GP-RF.
    pub fn accumulators(&self) -> usize {
        self.filters * self.buffers
    }
}

/// The four evaluated cores.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum IsaVariant {
    /// RI5CY with XpulpV2: 16/8-bit SIMD, hw loops, post-inc ld/st.
    Ri5cy,
    /// MPIC (Ottavi et al.): + dynamic bit-scalable mixed-precision sdotp.
    Mpic,
    /// XpulpNN (Garofalo et al.): + uniform 4/2-bit sdotp and Mac&Load.
    XpulpNn,
    /// Flex-V (this paper): + fully-flexible mixed-precision Mac&Load,
    /// NN-RF, MLC.
    FlexV,
}

impl IsaVariant {
    pub const ALL: [IsaVariant; 4] =
        [IsaVariant::Ri5cy, IsaVariant::Mpic, IsaVariant::XpulpNn, IsaVariant::FlexV];

    pub fn name(&self) -> &'static str {
        match self {
            IsaVariant::Ri5cy => "RI5CY",
            IsaVariant::Mpic => "MPIC",
            IsaVariant::XpulpNn => "XpulpNN",
            IsaVariant::FlexV => "Flex-V",
        }
    }

    /// Parse a variant from its display or CLI name (case-insensitive:
    /// `ri5cy`/`xpulpv2`, `mpic`, `xpulpnn`, `flexv`/`flex-v`).
    pub fn from_name(s: &str) -> Option<IsaVariant> {
        match s.to_lowercase().as_str() {
            "ri5cy" | "xpulpv2" => Some(IsaVariant::Ri5cy),
            "mpic" => Some(IsaVariant::Mpic),
            "xpulpnn" => Some(IsaVariant::XpulpNn),
            "flexv" | "flex-v" => Some(IsaVariant::FlexV),
            _ => None,
        }
    }

    /// Kernel lowerings a core with this ISA can also execute: every
    /// variant whose generated instruction streams use only a subset of
    /// this core's instructions (RI5CY is the RV32IMC+XpulpV2 base all
    /// extensions share; Flex-V subsumes the MPC of MPIC and the
    /// Mac&Load of XpulpNN). The autotuner picks per layer from this
    /// set — e.g. on Flex-V a sw-unpack RI5CY lowering can beat the
    /// native mixed-precision kernel for degenerate geometries.
    pub fn compatible_lowerings(&self) -> &'static [IsaVariant] {
        match self {
            IsaVariant::Ri5cy => &[IsaVariant::Ri5cy],
            IsaVariant::Mpic => &[IsaVariant::Mpic, IsaVariant::Ri5cy],
            IsaVariant::XpulpNn => &[IsaVariant::XpulpNn, IsaVariant::Ri5cy],
            IsaVariant::FlexV => &[
                IsaVariant::FlexV,
                IsaVariant::XpulpNn,
                IsaVariant::Mpic,
                IsaVariant::Ri5cy,
            ],
        }
    }

    /// SIMD dot-product formats the core executes natively.
    pub fn native_fmts(&self) -> &'static [SimdFmt] {
        match self {
            IsaVariant::Ri5cy => &[SimdFmt::Half, SimdFmt::Byte],
            IsaVariant::Mpic | IsaVariant::XpulpNn | IsaVariant::FlexV => {
                &[SimdFmt::Half, SimdFmt::Byte, SimdFmt::Nibble, SimdFmt::Crumb]
            }
        }
    }

    /// Can one sdotp take operands of *different* formats (MPC present)?
    pub fn mixed_precision(&self) -> bool {
        matches!(self, IsaVariant::Mpic | IsaVariant::FlexV)
    }

    /// Fused Mac&Load available?
    pub fn mac_load(&self) -> bool {
        matches!(self, IsaVariant::XpulpNn | IsaVariant::FlexV)
    }

    /// Dedicated NN register file + MLC address generation?
    pub fn nn_rf(&self) -> bool {
        matches!(self, IsaVariant::FlexV)
    }

    /// Register-blocking shape used by the optimized MatMul on this core.
    /// Flex-V's NN-RF frees GP registers, enabling 4×4 (§III); all others
    /// saturate the GP-RF at 4×2 (PULP-NN's design point).
    pub fn unroll(&self) -> UnrollShape {
        if self.nn_rf() {
            UnrollShape::new(4, 4)
        } else {
            UnrollShape::new(4, 2)
        }
    }

    /// True if `p` needs *no* software pack/unpack on this core: either the
    /// formats are equal and natively supported, or the core has hardware
    /// mixed-precision support.
    pub fn supports_natively(&self, p: Precision) -> bool {
        let a = SimdFmt::from_bits(p.a_bits);
        let w = SimdFmt::from_bits(p.w_bits);
        let native = self.native_fmts();
        if !native.contains(&a) || !native.contains(&w) {
            return false;
        }
        p.uniform() || self.mixed_precision()
    }

    /// Bit-width the weights must be *software-converted to* before the
    /// MatMul inner loop when `p` is not natively supported: the narrower
    /// operand is unpacked to the wider operand's width (the paper §I:
    /// "massive software overhead necessary for packing and unpacking
    /// data"). Returns `None` when no conversion is needed.
    pub fn sw_unpack_target(&self, p: Precision) -> Option<u8> {
        if self.supports_natively(p) {
            None
        } else {
            Some(p.a_bits.max(p.w_bits))
        }
    }
}

impl std::fmt::Display for IsaVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capability_matrix_matches_paper() {
        use IsaVariant::*;
        // Table III structure: RI5CY cannot run sub-byte natively.
        assert!(!Ri5cy.supports_natively(Precision::new(4, 4)));
        assert!(Ri5cy.supports_natively(Precision::new(8, 8)));
        assert!(!Ri5cy.supports_natively(Precision::new(8, 4)));
        // MPIC handles the whole mixed grid natively but has no Mac&Load.
        for p in Precision::grid() {
            assert!(Mpic.supports_natively(p), "MPIC should support {p}");
        }
        assert!(!Mpic.mac_load());
        // XpulpNN: uniform sub-byte yes, mixed no.
        assert!(XpulpNn.supports_natively(Precision::new(2, 2)));
        assert!(XpulpNn.supports_natively(Precision::new(4, 4)));
        assert!(!XpulpNn.supports_natively(Precision::new(4, 2)));
        assert!(!XpulpNn.supports_natively(Precision::new(8, 2)));
        // Flex-V: everything.
        for p in Precision::grid() {
            assert!(FlexV.supports_natively(p), "Flex-V should support {p}");
        }
        assert!(FlexV.mac_load() && FlexV.nn_rf());
    }

    #[test]
    fn unroll_shapes() {
        assert_eq!(IsaVariant::Ri5cy.unroll(), UnrollShape::new(4, 2));
        assert_eq!(IsaVariant::FlexV.unroll(), UnrollShape::new(4, 4));
        assert_eq!(IsaVariant::FlexV.unroll().accumulators(), 16);
    }

    #[test]
    fn names_roundtrip_and_lowerings_are_reflexive() {
        for v in IsaVariant::ALL {
            assert_eq!(IsaVariant::from_name(v.name()), Some(v));
            // every core can run its own kernels, listed first
            assert_eq!(v.compatible_lowerings()[0], v);
            // the base ISA is always a legal lowering
            assert!(v.compatible_lowerings().contains(&IsaVariant::Ri5cy));
        }
        assert_eq!(IsaVariant::from_name("xpulpv2"), Some(IsaVariant::Ri5cy));
        assert_eq!(IsaVariant::from_name("nope"), None);
        assert_eq!(IsaVariant::FlexV.compatible_lowerings().len(), 4);
    }

    #[test]
    fn sw_unpack_targets() {
        // XpulpNN on a8w2 must blow weights up to 8 bit in software.
        assert_eq!(IsaVariant::XpulpNn.sw_unpack_target(Precision::new(8, 2)), Some(8));
        // RI5CY on a8w4 likewise.
        assert_eq!(IsaVariant::Ri5cy.sw_unpack_target(Precision::new(8, 4)), Some(8));
        // Flex-V never unpacks in software.
        for p in Precision::grid() {
            assert_eq!(IsaVariant::FlexV.sw_unpack_target(p), None);
        }
    }
}
