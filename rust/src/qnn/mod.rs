//! Quantized-NN substrate: the data formats and integer arithmetic the whole
//! stack is built on.
//!
//! The paper (§II-B) adopts the PULP-NN execution model: HWC data layout,
//! unsigned low-bitwidth activations (2/4/8-bit), signed low-bitwidth weights
//! (2/4/8-bit), 32-bit accumulation, and a normalization/quantization step
//! (one MAC, one shift, one clip) that brings accumulators back to the
//! low-bitwidth output format. Sub-byte elements are packed densely into
//! bytes/words (little-endian within the word), which is exactly what the
//! Flex-V Slicer&Router consumes in hardware.

pub mod golden;
pub mod graph;
pub mod layer;
pub mod packing;
pub mod qir;
pub mod quant;
pub mod tensor;

pub use graph::{Graph, OpKind, OpNode, TensorDef};
pub use layer::{Layer, LayerKind, Network};
pub use packing::{pack_signed, pack_unsigned, unpack_signed, unpack_unsigned};
pub use quant::QuantParams;
pub use tensor::QTensor;

/// Supported element bit-widths (the paper's grid: 2-, 4-, 8-bit).
pub const SUPPORTED_BITS: [u8; 3] = [2, 4, 8];

/// Check that a bit-width is one the hardware supports.
pub fn check_bits(bits: u8) -> bool {
    SUPPORTED_BITS.contains(&bits)
}

/// A (activation-bits, weight-bits) precision configuration, e.g. `a8w4`.
/// The paper's evaluation grid always has `a_bits >= w_bits`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Precision {
    pub a_bits: u8,
    pub w_bits: u8,
}

impl Precision {
    pub const fn new(a_bits: u8, w_bits: u8) -> Self {
        Precision { a_bits, w_bits }
    }

    /// True if activations and weights share the same width.
    pub fn uniform(&self) -> bool {
        self.a_bits == self.w_bits
    }

    /// Elements of the *wider* operand per 32-bit word = MACs per sdotp.
    pub fn macs_per_sdotp(&self) -> usize {
        32 / self.a_bits.max(self.w_bits) as usize
    }

    /// How many sdotp instructions one 32-bit word of the *narrower* operand
    /// feeds (the paper's weight-reuse factor, CSR `mix_skip`).
    pub fn narrow_reuse(&self) -> usize {
        (self.a_bits.max(self.w_bits) / self.a_bits.min(self.w_bits)) as usize
    }

    /// The paper's Table III / Fig. 7 grid.
    pub fn grid() -> Vec<Precision> {
        vec![
            Precision::new(2, 2),
            Precision::new(4, 2),
            Precision::new(4, 4),
            Precision::new(8, 2),
            Precision::new(8, 4),
            Precision::new(8, 8),
        ]
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a{}w{}", self.a_bits, self.w_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_grid_matches_paper() {
        let g = Precision::grid();
        assert_eq!(g.len(), 6);
        for p in &g {
            assert!(p.a_bits >= p.w_bits, "{p}: paper grid has a_bits >= w_bits");
            assert!(check_bits(p.a_bits) && check_bits(p.w_bits));
        }
    }

    #[test]
    fn macs_per_sdotp() {
        assert_eq!(Precision::new(2, 2).macs_per_sdotp(), 16);
        assert_eq!(Precision::new(4, 2).macs_per_sdotp(), 8);
        assert_eq!(Precision::new(8, 2).macs_per_sdotp(), 4);
        assert_eq!(Precision::new(8, 8).macs_per_sdotp(), 4);
    }

    #[test]
    fn narrow_reuse_matches_mix_skip() {
        // a8w2: a weight word (16 crumbs) feeds 4 sdotp of 4 MACs each.
        assert_eq!(Precision::new(8, 2).narrow_reuse(), 4);
        assert_eq!(Precision::new(8, 4).narrow_reuse(), 2);
        assert_eq!(Precision::new(4, 2).narrow_reuse(), 2);
        assert_eq!(Precision::new(8, 8).narrow_reuse(), 1);
    }

    #[test]
    fn display() {
        assert_eq!(Precision::new(8, 4).to_string(), "a8w4");
    }
}
