//! Integration: a network exercising EVERY kernel type (conv, depthwise,
//! residual add, max/avg pool, linear) deployed through DORY and executed
//! on the simulated cluster — bit-exact against the golden executor on
//! all four ISA variants.

use flexv::coordinator::Coordinator;
use flexv::dory::deploy::deploy;
use flexv::dory::MemBudget;
use flexv::isa::IsaVariant;
use flexv::qnn::layer::{Layer, LayerKind, Network};
use flexv::qnn::{golden, QTensor, QuantParams};
use flexv::util::Prng;

/// Build a compact network touching every operator.
fn all_ops_net(seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new("all-ops", [12, 12, 8], 8);
    // conv 3x3 (mixed a8w4)
    let c1 = net.push(Layer::conv("c1", [12, 12, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    // depthwise 3x3/s1
    let dw = Layer {
        name: "dw".into(),
        kind: LayerKind::DwConv2d { kh: 3, kw: 3, stride: 1, pad: 1 },
        in_shape: [12, 12, 16],
        out_shape: [12, 12, 16],
        a_bits: 8,
        w_bits: 4,
        weights: Some(QTensor::random(&[16, 3, 3, 1], 4, true, &mut rng)),
        quant: QuantParams::scalar(1, 6, 0, 8, 16),
    };
    let dw_id = net.push_with_inputs(dw, vec![c1]);
    // pointwise conv back to 16 (residual partner)
    let c2 = net.push_with_inputs(
        Layer::conv("c2", [12, 12, 16], 16, 1, 1, 1, 0, 8, 8, 8, &mut rng),
        vec![dw_id],
    );
    // residual add of dw and c2
    let add = Layer {
        name: "add".into(),
        kind: LayerKind::Add { m1: 1, m2: 1 },
        in_shape: [12, 12, 16],
        out_shape: [12, 12, 16],
        a_bits: 8,
        w_bits: 8,
        weights: None,
        quant: QuantParams::scalar(1, 1, 0, 8, 16),
    };
    let add_id = net.push_with_inputs(add, vec![dw_id, c2]);
    // max pool 2x2
    let mp = Layer {
        name: "maxpool".into(),
        kind: LayerKind::MaxPool { k: 2, stride: 2 },
        in_shape: [12, 12, 16],
        out_shape: [6, 6, 16],
        a_bits: 8,
        w_bits: 8,
        weights: None,
        quant: QuantParams::scalar(1, 0, 0, 8, 16),
    };
    let mp_id = net.push_with_inputs(mp, vec![add_id]);
    // global avg pool
    let ap = Layer {
        name: "avgpool".into(),
        kind: LayerKind::AvgPool { k: 6, stride: 6 },
        in_shape: [6, 6, 16],
        out_shape: [1, 1, 16],
        a_bits: 8,
        w_bits: 8,
        weights: None,
        quant: QuantParams::scalar(((1i64 << 16) / 36) as i32, 16, 0, 8, 16),
    };
    let ap_id = net.push_with_inputs(ap, vec![mp_id]);
    // classifier
    let fc = Layer {
        name: "fc".into(),
        kind: LayerKind::Linear,
        in_shape: [1, 1, 16],
        out_shape: [1, 1, 8],
        a_bits: 8,
        w_bits: 8,
        weights: Some(QTensor::random(&[8, 16], 8, true, &mut rng)),
        quant: QuantParams::scalar(1, 4, 0, 8, 8),
    };
    net.push_with_inputs(fc, vec![ap_id]);
    net.validate().expect("all-ops net invalid");
    net
}

#[test]
fn all_operator_kinds_bit_exact_on_every_isa() {
    let net = all_ops_net(101);
    let mut rng = Prng::new(102);
    let input = QTensor::random(&[12, 12, 8], 8, false, &mut rng);
    let golden_outs = golden::run_network(&net, &input);
    for isa in IsaVariant::ALL {
        let dep = deploy(&net, isa, MemBudget::default());
        let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
        let res = coord.run(&dep, &input);
        for (i, g) in golden_outs.iter().enumerate() {
            assert_eq!(
                res.node_outputs[i], g.data,
                "{isa}: node {i} ({}) mismatch",
                net.nodes[i].layer.name
            );
        }
    }
}

#[test]
fn tight_l1_budget_still_bit_exact() {
    // Squeeze L1 so every layer is forced into many tiles.
    let net = all_ops_net(103);
    let mut rng = Prng::new(104);
    let input = QTensor::random(&[12, 12, 8], 8, false, &mut rng);
    let golden_outs = golden::run_network(&net, &input);
    let budget = MemBudget { l1: 8 * 1024, l2: flexv::L2_BYTES };
    let dep = deploy(&net, IsaVariant::FlexV, budget);
    let total_tiles: usize = dep.plans.iter().map(|p| p.tiles.len()).sum();
    assert!(total_tiles > dep.plans.len(), "tight budget should force tiling");
    let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
    let res = coord.run(&dep, &input);
    assert_eq!(res.output, golden_outs.last().unwrap().data);
}

#[test]
fn deterministic_across_runs() {
    let net = all_ops_net(105);
    let mut rng = Prng::new(106);
    let input = QTensor::random(&[12, 12, 8], 8, false, &mut rng);
    let run = || {
        let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
        let res = coord.run(&dep, &input);
        (res.total_cycles(), res.output.clone())
    };
    let (c1, o1) = run();
    let (c2, o2) = run();
    assert_eq!(c1, c2, "cycle counts must be deterministic");
    assert_eq!(o1, o2);
}

#[test]
fn fewer_cores_same_result_more_cycles() {
    let net = all_ops_net(107);
    let mut rng = Prng::new(108);
    let input = QTensor::random(&[12, 12, 8], 8, false, &mut rng);
    let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
    let mut c8 = Coordinator::new(8);
    let r8 = c8.run(&dep, &input);
    let mut c2 = Coordinator::new(2);
    let r2 = c2.run(&dep, &input);
    assert_eq!(r8.output, r2.output, "core count must not change results");
    assert!(
        r2.total_cycles() > r8.total_cycles() * 2,
        "2 cores ({}) should be much slower than 8 ({})",
        r2.total_cycles(),
        r8.total_cycles()
    );
}
