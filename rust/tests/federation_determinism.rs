//! The federation tentpole guarantee, one layer above the engine's own
//! contract: with an **active fault plan** (shard failures + straggler
//! windows, some seeded) and a live rollout, the entire federated
//! fingerprint — per-region completion streams, shed events, the
//! rendered federation report, and the exported Chrome-trace JSON
//! bytes — is identical across host worker counts {1, 4} × sim
//! fast-path on/off, for every router policy. Routing, failover and
//! rollout decisions read only simulated state, so host parallelism can
//! never leak into a simulated number.

use flexv::qnn::layer::Network;
use flexv::qnn::{Layer, QTensor};
use flexv::serve::{
    FaultPlan, Federation, FederationConfig, FederationMetrics, RolloutPlan, RouterPolicy,
    ServeConfig, TraceItem,
};
use flexv::util::Prng;

fn tiny(name: &str, seed: u64) -> Network {
    let mut rng = Prng::new(seed);
    let mut net = Network::new(name, [8, 8, 8], 8);
    net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
    net.push(Layer::conv("c2", [8, 8, 8], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
    net
}

fn item(at: u64, model: usize, rng: &mut Prng) -> TraceItem {
    TraceItem {
        at,
        model,
        class: 0,
        priority: (at % 3) as u8,
        deadline: None,
        input: QTensor::random(&[8, 8, 8], 8, false, rng),
    }
}

fn mixed_trace(models: usize, n: usize, gap: u64, seed: u64) -> Vec<TraceItem> {
    let mut rng = Prng::new(seed);
    (0..n).map(|i| item(i as u64 * gap, i % models, &mut rng)).collect()
}

/// Everything simulated, flattened to one string: per-region completion
/// tuples (incl. outputs and per-layer cycles), shed events, the
/// rendered report, and the exported trace bytes.
fn fingerprint(fed: &Federation, m: &FederationMetrics) -> String {
    let mut fp = String::new();
    for (r, engine) in fed.regions().iter().enumerate() {
        fp.push_str(&format!("region {r}\n"));
        for c in engine.completions() {
            fp.push_str(&format!(
                "  c id={} model={} shard={} start={} finish={} exec={} switch={} batch={} \
                 macs={} layers={:?} energy={:?} out={:?}\n",
                c.id,
                c.model,
                c.shard,
                c.start_cycle,
                c.finish_cycle,
                c.exec_cycles,
                c.switch_cycles,
                c.batch_size,
                c.macs,
                c.layer_cycles,
                c.energy_pj,
                c.output,
            ));
        }
        for s in engine.shed_events() {
            fp.push_str(&format!("  shed {s:?}\n"));
        }
    }
    fp.push_str(&m.render());
    fp.push_str(&flexv::trace::chrome::to_chrome_json(&fed.build_trace()));
    fp
}

/// Run the standard federated scenario with the given execution knobs;
/// every simulated input (fault plan, trace, fleet shape) is fixed.
fn run_faulted(workers: usize, fastpath: bool, policy: RouterPolicy) -> String {
    let engine = ServeConfig {
        shards: 2,
        n_cores: 4,
        queue_capacity: 64,
        max_batch: 4,
        workers,
        fastpath,
        ..ServeConfig::default()
    };
    // two pinned faults (a mid-batch failure, a straggler window) plus
    // two seeded ones — the plan is part of the fingerprint
    let faults =
        FaultPlan::parse("fail@500:r0.s0+40000,slow@2000:r1.s1x3+60000,auto:2", 0xFED5, 2, 2, 200_000)
            .expect("static fault spec parses");
    let cfg = FederationConfig { regions: 2, engine, policy, faults, rollout: None };
    let mut fed = Federation::new(cfg);
    fed.register(tiny("det-a", 21));
    fed.register(tiny("det-b", 22));
    let m = fed.run_trace(mixed_trace(2, 20, 80, 23));
    assert_eq!(m.total_served(), 20, "faults must delay work, never drop it");
    fingerprint(&fed, &m)
}

#[test]
fn federated_fingerprint_is_identical_across_workers_and_fastpath() {
    for policy in RouterPolicy::ALL {
        let reference = run_faulted(1, false, policy);
        for (workers, fastpath) in [(1usize, true), (4, false), (4, true)] {
            let fp = run_faulted(workers, fastpath, policy);
            assert!(
                fp == reference,
                "federated fingerprint diverged (policy {}, workers {workers}, fastpath {fastpath})",
                policy.name(),
            );
        }
    }
}

/// Rollout under fire: a shard failure mid-trace plus a canary drain +
/// warm switch. Nothing is dropped, the canary's exec cycles split into
/// pre-switch (default plans) and post-switch (tuned plans) buckets, and
/// the whole thing is fingerprint-identical across execution knobs.
fn run_rollout(workers: usize, fastpath: bool) -> (String, FederationMetrics) {
    let engine = ServeConfig {
        shards: 2,
        n_cores: 4,
        queue_capacity: 64,
        max_batch: 4,
        workers,
        fastpath,
        ..ServeConfig::default()
    };
    let faults = FaultPlan::parse("fail@600:r0.s0+100000", 0, 2, 2, 0).expect("spec parses");
    let cfg = FederationConfig {
        regions: 2,
        engine,
        // locality homes model 1 on region 1 (the canary), so canary
        // traffic exists both pre-drain and post-switch
        policy: RouterPolicy::Locality,
        faults,
        rollout: Some(RolloutPlan { at: 1_000_000, canary: 1 }),
    };
    let mut fed = Federation::new(cfg);
    fed.register(tiny("ro-a", 31));
    fed.register(tiny("ro-b", 32));
    let mut rng = Prng::new(33);
    let mut trace: Vec<TraceItem> =
        (0..8u64).map(|i| item(i * 60, (i % 2) as usize, &mut rng)).collect();
    for i in 0..8u64 {
        trace.push(item(3_000_000 + i * 60, (i % 2) as usize, &mut rng));
    }
    let m = fed.run_trace(trace);
    (fingerprint(&fed, &m), m)
}

#[test]
fn rollout_under_faults_drops_nothing_and_stays_deterministic() {
    let (reference, m) = run_rollout(1, false);
    // zero dropped in-flight requests: every admitted request completes,
    // including the ones retracted from the failed shard
    assert_eq!(m.total_served(), 16, "rollout or failover dropped admitted work");
    assert!(m.requeued >= 1, "the cycle-600 failure caught in-flight work");
    // canary-vs-default cycle accounting
    let ro = m.rollout.expect("rollout must have switched");
    assert_eq!(ro.canary, 1);
    assert_eq!(ro.models_migrated, 2);
    assert!(ro.switched_at >= ro.drain_started);
    assert!(ro.canary_default_exec > 0, "canary served default plans pre-drain");
    assert!(ro.canary_tuned_exec > 0, "canary served tuned plans post-switch");
    for (workers, fastpath) in [(4usize, true), (0, true)] {
        let (fp, _) = run_rollout(workers, fastpath);
        assert!(
            fp == reference,
            "rollout fingerprint diverged (workers {workers}, fastpath {fastpath})"
        );
    }
}
