//! Steady-state fast path: memoized replay of whole simulation windows.
//!
//! A DORY-deployed network executes the same inner loop thousands of
//! times: every tile of a conv layer runs an identical per-core
//! instruction trace against an identical TCDM layout, so its cycle
//! evolution — bank conflicts, load-use hazards, barrier waits, DMA
//! interleaving — is identical too (Dustin's lockstep observation:
//! identical per-core schedules need not be re-derived per iteration).
//! The fast path exploits this at [`Cluster::run`] granularity:
//!
//! 1. **Recording (miss).** The window is simulated cycle-by-cycle as
//!    usual while an [`super::mem::AccessTrace`] captures its external read
//!    footprint (bytes read before being written, with their pre-window
//!    values) and its functional write delta. The entry stores both,
//!    plus the window's [`ClusterStats`] and the final core states.
//! 2. **Pure replay.** If a later window matches the entry's structural
//!    key *and* its exact environment — same DMA descriptors including
//!    L2 addresses, same initial register data, same footprint contents
//!    (hash-checked) — the memoized writes and timing are applied
//!    directly; no instruction is re-executed.
//! 3. **Functional replay.** If only the data differs (e.g. a DMA wrote
//!    fresh activations over the footprint — the *invalidation* case),
//!    the memoized **timing** is still exact, because generated kernels
//!    have no data-dependent control flow or addressing (the same
//!    invariant `coordinator::TileMemo` relies on). The cores are then
//!    re-executed *functionally* — straight-line retirement with exact
//!    integer semantics, no per-cycle arbitration — and the DMA queue is
//!    completed as bulk copies. Outputs stay bit-exact; only the cost of
//!    simulating stalls, arbitration, and barrier spins is saved.
//!
//! The structural key covers: core count, the core timing tier
//! ([`super::pipeline::CoreFidelity`] — memoized cycle counts are
//! tier-specific), arbiter rotation, each core's run-state + pc +
//! instruction stream, and the timing-relevant DMA descriptor fields
//! (TCDM-side layout; the L2-side address never affects a cycle). The retired-instruction invariant is asserted on
//! every functional replay, and [`FastPath::crosscheck`] re-simulates
//! each replayed window on a forked cluster and compares all observable
//! state — tests run the serve determinism suites in this mode.
//!
//! The cache is a [`WindowCache`]: cloning shares the underlying store,
//! so a fleet of clusters (serve shards on host threads) pools its
//! recordings — one shard measures a window, every shard replays it.
//!
//! Escape hatches: `Cluster::disable_fastpath`, the serve engine's
//! `ServeConfig::fastpath`, and the CLI's `--no-fastpath`.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::Hasher;
use std::sync::{Arc, RwLock};

use super::cluster::Cluster;
use super::core::Core;
use super::dma::DmaRequest;
use super::mem::ClusterMem;
use super::stats::ClusterStats;

/// Cache-size backstop: a steady-state workload settles on at most a
/// few hundred distinct windows; a runaway-diversity workload sheds a
/// bounded batch of entries per insert (see
/// [`WindowCache::insert_bounded`]) instead of re-recording everything.
pub(crate) const MAX_ENTRIES: usize = 8192;

/// Entries evicted in one batch when the cache is at [`MAX_ENTRIES`].
/// Bounded so one diverse shard can never wipe the whole fleet-shared
/// cache; 1/8th keeps the steady-state working set resident.
pub(crate) const EVICT_BATCH: usize = MAX_ENTRIES / 8;

/// One memoized simulation window.
#[derive(Clone, Debug)]
pub(crate) struct FastEntry {
    /// Exact DMA descriptors queued at window start. Unlike the
    /// structural key this includes the L2-side addresses — a pure
    /// replay applies recorded absolute writes, so the environment must
    /// match exactly.
    pub dma_sig: Vec<DmaRequest>,
    /// Hash of the initial register/NN-RF/CSR/MLC data state (pure
    /// replay gate; the structural key excludes data registers).
    pub arch_sig: u64,
    /// External input footprint: `(addr, len)` byte ranges read before
    /// being written, ascending.
    pub reads: Vec<(u32, u32)>,
    /// Hash of the footprint's pre-window contents.
    pub read_hash: u64,
    /// Functional effect delta: every byte range written, with its
    /// end-of-window contents.
    pub writes: Vec<(u32, Vec<u8>)>,
    /// Which cores were running at window start.
    pub ran: Vec<bool>,
    /// Final core states (restored on pure replay; running cores only —
    /// halted cores are untouched by a window).
    pub cores_end: Vec<Core>,
    /// Arbiter rotation at window end.
    pub rr_end: usize,
    /// Recorded window stats: cycles, per-core counters (absolute since
    /// the `load_programs` reset), DMA busy/byte deltas.
    pub stats: ClusterStats,
}

/// A shareable window cache: cloning shares the same underlying store,
/// so a fleet of clusters (serve shards, one per host thread) can pool
/// their recordings — shard B replays a window shard A measured, the
/// lockstep insight applied across the fleet. Entries are immutable
/// (`Arc`), so the lock is held only for the lookup or insert itself,
/// never during replay; cache contents affect wall-clock time only,
/// never a simulated number, so sharing cannot perturb determinism.
#[derive(Clone, Debug, Default)]
pub struct WindowCache(pub(crate) Arc<RwLock<HashMap<u64, Arc<FastEntry>>>>);

impl WindowCache {
    /// Distinct windows memoized.
    pub fn entries(&self) -> usize {
        self.0.read().expect("fastpath cache poisoned").len()
    }

    /// Insert `entry` under `key`, evicting a bounded batch of
    /// [`EVICT_BATCH`] entries first when the cache is at
    /// [`MAX_ENTRIES`]. Victims are the smallest structural keys —
    /// keys are hashes, so this is an arbitrary-but-deterministic
    /// choice that does not depend on `HashMap` iteration order, and
    /// the surviving majority keeps serving hits for every other shard
    /// sharing the cache (a wholesale `clear()` here caused fleet-wide
    /// re-record storms). Cache contents only ever affect host
    /// wall-clock time, never a simulated number, so eviction cannot
    /// perturb determinism.
    pub(crate) fn insert_bounded(&self, key: u64, entry: Arc<FastEntry>) {
        let mut map = self.0.write().expect("fastpath cache poisoned");
        if map.len() >= MAX_ENTRIES && !map.contains_key(&key) {
            let mut keys: Vec<u64> = map.keys().copied().collect();
            keys.sort_unstable();
            for k in keys.into_iter().take(EVICT_BATCH) {
                map.remove(&k);
            }
        }
        map.insert(key, entry);
    }
}

/// Fast-path state attached to a [`Cluster`] via
/// [`Cluster::enable_fastpath`] (private cache) or
/// [`Cluster::enable_fastpath_shared`] (fleet-shared cache).
/// Replay/record counters are per cluster even when the cache is
/// shared.
#[derive(Clone, Debug, Default)]
pub struct FastPath {
    pub(crate) cache: WindowCache,
    /// Re-simulate every replayed window on a forked cluster and compare
    /// all observable state (tests only — it is slower than no cache).
    pub crosscheck: bool,
    /// Windows replayed purely from the memoized functional delta.
    pub pure_hits: u64,
    /// Windows with replayed timing + fast functional re-execution
    /// (footprint invalidated, e.g. by a DMA write overlapping it).
    pub func_hits: u64,
    /// Windows simulated cycle-by-cycle and recorded.
    pub misses: u64,
}

/// How the fast path served one non-trivial window — the host-scope
/// trace event emitted per window ([`Scope::Host`], excluded from the
/// default Chrome export because record-vs-replay varies with cache
/// state across runs even though simulated results do not).
///
/// [`Scope::Host`]: crate::trace::Scope::Host
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowOutcome {
    /// Memoized writes and timing applied directly.
    PureReplay,
    /// Memoized timing + fast functional re-execution.
    FunctionalReplay,
    /// Simulated cycle-by-cycle and recorded.
    Recorded,
}

impl WindowOutcome {
    /// Stable event name of the outcome.
    pub fn name(self) -> &'static str {
        match self {
            WindowOutcome::PureReplay => "fastpath_pure_replay",
            WindowOutcome::FunctionalReplay => "fastpath_functional_replay",
            WindowOutcome::Recorded => "fastpath_record",
        }
    }
}

impl FastPath {
    /// Distinct windows memoized (in the possibly-shared cache).
    pub fn entries(&self) -> usize {
        self.cache.entries()
    }

    /// Bump the per-cluster counter matching a window outcome.
    pub(crate) fn note(&mut self, o: WindowOutcome) {
        match o {
            WindowOutcome::PureReplay => self.pure_hits += 1,
            WindowOutcome::FunctionalReplay => self.func_hits += 1,
            WindowOutcome::Recorded => self.misses += 1,
        }
    }

    /// Fraction of non-trivial windows served without cycle simulation.
    pub fn hit_rate(&self) -> f64 {
        let total = self.pure_hits + self.func_hits + self.misses;
        if total == 0 {
            0.0
        } else {
            (self.pure_hits + self.func_hits) as f64 / total as f64
        }
    }
}

/// Hash `ranges` of the live memory image, chunked identically to
/// [`super::mem::AccessTrace::read_hash`] so the two are comparable.
pub(crate) fn hash_mem_ranges(mem: &ClusterMem, ranges: &[(u32, u32)]) -> u64 {
    let mut h = DefaultHasher::new();
    for &(addr, len) in ranges {
        h.write_u32(addr);
        h.write_u32(len);
        h.write(mem.bytes(addr, len as usize));
    }
    h.finish()
}

impl Cluster {
    /// Structural identity of the window about to run: everything that
    /// determines its timing under the no-data-dependent-control-flow
    /// invariant (see the module docs).
    pub(crate) fn structural_key(&self) -> u64 {
        use std::hash::Hash;
        let mut h = DefaultHasher::new();
        self.cores.len().hash(&mut h);
        // The core timing tier changes the memoized per-core cycle
        // counts, so windows recorded under one fidelity must never
        // replay under the other.
        self.fidelity().hash(&mut h);
        self.rr.hash(&mut h);
        for c in &self.cores {
            c.hash_structure(&mut h);
        }
        self.dma.progress().hash(&mut h);
        self.dma.setup_left().hash(&mut h);
        for r in self.dma.queued() {
            (r.dir, r.loc, r.row_bytes, r.rows, r.loc_stride).hash(&mut h);
        }
        h.finish()
    }

    /// Combined data-state signature of all cores (pure-replay gate).
    pub(crate) fn arch_sig(&self) -> u64 {
        let mut h = DefaultHasher::new();
        for c in &self.cores {
            c.hash_arch_state(&mut h);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::AccessTrace;

    #[test]
    fn trace_footprint_excludes_read_after_write() {
        let mut t = AccessTrace::default();
        t.record_write(0x1000_0100, 4);
        t.record_read(0x1000_0100, &[1, 2, 3, 4]); // internal
        t.record_read(0x1000_0104, &[5, 6, 7, 8]); // external
        assert_eq!(t.read_ranges(), vec![(0x1000_0104, 4)]);
        assert_eq!(t.write_ranges(), vec![(0x1000_0100, 4)]);
    }

    #[test]
    fn trace_hash_matches_live_memory() {
        let mut mem = ClusterMem::new();
        let data: Vec<u8> = (0..32u8).collect();
        mem.write_bytes(0x1000_0040, &data);
        let mut t = AccessTrace::default();
        t.record_read(0x1000_0040, &data);
        let ranges = t.read_ranges();
        assert_eq!(ranges, vec![(0x1000_0040, 32)]);
        assert_eq!(t.read_hash(), hash_mem_ranges(&mem, &ranges));
        // perturb one footprint byte -> hash must change
        mem.store_u8(0x1000_0050, 0xFF);
        assert_ne!(t.read_hash(), hash_mem_ranges(&mem, &ranges));
    }

    #[test]
    fn trace_coalesces_across_blocks() {
        let mut t = AccessTrace::default();
        // 128 contiguous bytes spanning three 64-byte blocks
        let bytes = vec![7u8; 128];
        t.record_read(0x1000_0020, &bytes);
        assert_eq!(t.read_ranges(), vec![(0x1000_0020, 128)]);
    }

    fn blank_entry() -> Arc<FastEntry> {
        Arc::new(FastEntry {
            dma_sig: Vec::new(),
            arch_sig: 0,
            reads: Vec::new(),
            read_hash: 0,
            writes: Vec::new(),
            ran: Vec::new(),
            cores_end: Vec::new(),
            rr_end: 0,
            stats: ClusterStats::default(),
        })
    }

    #[test]
    fn full_cache_evicts_a_bounded_batch_and_keeps_serving_survivors() {
        let cache = WindowCache::default();
        for key in 0..MAX_ENTRIES as u64 {
            cache.insert_bounded(key, blank_entry());
        }
        assert_eq!(cache.entries(), MAX_ENTRIES);
        // the insert that used to clear() the whole fleet-shared cache
        let newcomer = MAX_ENTRIES as u64;
        cache.insert_bounded(newcomer, blank_entry());
        assert_eq!(cache.entries(), MAX_ENTRIES - EVICT_BATCH + 1);
        let map = cache.0.read().unwrap();
        // victims are exactly the EVICT_BATCH smallest keys...
        for k in 0..EVICT_BATCH as u64 {
            assert!(!map.contains_key(&k), "victim {k} survived");
        }
        // ...every other key keeps serving hits, and the newcomer landed
        for k in EVICT_BATCH as u64..=newcomer {
            assert!(map.contains_key(&k), "survivor {k} was evicted");
        }
        drop(map);
        // re-recording an already-cached key at capacity overwrites in
        // place without evicting anything
        let cache2 = WindowCache::default();
        for key in 0..MAX_ENTRIES as u64 {
            cache2.insert_bounded(key, blank_entry());
        }
        cache2.insert_bounded(0, blank_entry());
        assert_eq!(cache2.entries(), MAX_ENTRIES);
    }
}
