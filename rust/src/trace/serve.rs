//! Fleet-timeline builder for the serving engine.
//!
//! The serve engine never threads a sink through its worker pool —
//! instead it keeps deterministic records of everything that happened
//! ([`Completion`]s, [`ShedEvent`]s, the shard-occupancy series) and this
//! module rebuilds the timeline from them **post hoc**. Because those
//! records are already part of the engine's determinism contract (merged
//! by `(finish_cycle, shard, id)` regardless of worker count or
//! fast-path setting), the trace inherits byte-identity across
//! `workers` × fastpath for free; the CI determinism gate diffs exactly
//! this export.
//!
//! Track layout (Chrome pid/tid) for a single fleet
//! ([`build_fleet_trace`]):
//! - pid 0 `fleet` — tid 1 `arrivals` (one instant per request entering
//!   the queue, shed ones included), tid 2 `sheds` (shed decisions at
//!   the cycle they were made), tid 3 `autoscale` (park/wake instants
//!   plus an `active_shards` counter), tid 4 `caches` (plan/tune cache
//!   hit/miss totals as end-of-run counters), tid 5 `dvfs` (one
//!   `dvfs_transition` instant per operating-point change the governor
//!   made, with shard and from/to point indices).
//! - pid `s+1` `shard{s}` — tid 1 `exec`: one `batch` span per dispatch
//!   with the `model_switch` span and per-request exec spans nested
//!   inside it (the batch timeline of [`crate::serve::shard`]: switch
//!   charged up front, per-request windows contiguous to the batch end).
//!
//! A federated run ([`build_federation_trace`]) stacks one such block
//! per region at a pid offset, plus a control process:
//! - pid 0 `federation` — tid 1 `faults` (`shard_fail` /
//!   `shard_recover` / `straggler_start` / `straggler_end` instants),
//!   tid 2 `rollout` (`rollout_drain_start` / `rollout_switch`).
//! - region `r` occupies pids `1 + r*(shards+1) ..`: its `r{r}/fleet`
//!   process followed by its `r{r}/shard{s}` processes, with the exact
//!   same intra-region layout as the single-fleet trace.

use std::collections::BTreeMap;

use super::{track, Arg, Recorder, Scope};
use crate::serve::request::{Completion, ShedEvent};
use crate::serve::workload::SloClass;

/// Everything the builder needs, borrowed from the engine's records
/// (see [`crate::serve::Engine::build_trace`] for the assembly).
pub struct FleetTraceInputs<'a> {
    pub completions: &'a [Completion],
    pub shed: &'a [ShedEvent],
    /// `(cycle, active shard count)` series, one entry per change.
    pub occupancy: &'a [(u64, usize)],
    /// Registry-ordered model names (`Completion::model` indexes it).
    pub model_names: &'a [String],
    /// SLO class table (`Completion::class` indexes it).
    pub classes: &'a [SloClass],
    /// Total shard slots of the fleet.
    pub shards: usize,
    /// Plan-cache `(hits, misses)` totals.
    pub plan_cache: (u64, u64),
    /// Tune-cache `(hits, misses)` totals.
    pub tune_cache: (u64, u64),
    /// DVFS transition log: `(cycle, shard, from, to)` operating-point
    /// indices, in the governor's decision order.
    pub dvfs: &'a [(u64, usize, u8, u8)],
}

const TID_ARRIVALS: u32 = 1;
const TID_SHEDS: u32 = 2;
const TID_AUTOSCALE: u32 = 3;
const TID_CACHES: u32 = 4;
const TID_DVFS: u32 = 5;

fn model_name(names: &[String], idx: usize) -> &str {
    names.get(idx).map_or("?", |s| s.as_str())
}

fn class_name(classes: &[SloClass], idx: u8) -> &str {
    classes.get(idx as usize).map_or("?", |c| c.name.as_str())
}

/// Build the fleet timeline. All events are [`Scope::Sim`] — every
/// timestamp is a simulated cycle from the deterministic record stream.
/// The caller should [`Recorder::canonicalize`] before export.
pub fn build_fleet_trace(inp: &FleetTraceInputs) -> Recorder {
    let mut rec = Recorder::new();
    emit_fleet_trace(&mut rec, inp, 0, "");
    rec
}

/// Emit one fleet's timeline into `rec` with its pid block starting at
/// `pid_base` and every process name prefixed (federation stacks one
/// block per region; the single-fleet layout is `pid_base = 0`,
/// empty prefix).
fn emit_fleet_trace(rec: &mut Recorder, inp: &FleetTraceInputs, pid_base: u32, prefix: &str) {
    rec.name_process(pid_base, format!("{prefix}fleet"));
    rec.name_thread(track(pid_base, TID_ARRIVALS), "arrivals");
    rec.name_thread(track(pid_base, TID_SHEDS), "sheds");
    rec.name_thread(track(pid_base, TID_AUTOSCALE), "autoscale");
    rec.name_thread(track(pid_base, TID_CACHES), "caches");
    rec.name_thread(track(pid_base, TID_DVFS), "dvfs");
    for s in 0..inp.shards {
        rec.name_process(pid_base + s as u32 + 1, format!("{prefix}shard{s}"));
        rec.name_thread(track(pid_base + s as u32 + 1, 1), "exec");
    }

    // Arrivals: every request that entered the queue, completed or shed.
    for c in inp.completions {
        rec.instant(
            Scope::Sim,
            track(pid_base, TID_ARRIVALS),
            model_name(inp.model_names, c.model),
            c.arrival_cycle,
            vec![
                ("id", Arg::U64(c.id)),
                ("class", Arg::Str(class_name(inp.classes, c.class).to_string())),
            ],
        );
    }
    for s in inp.shed {
        rec.instant(
            Scope::Sim,
            track(pid_base, TID_ARRIVALS),
            model_name(inp.model_names, s.model),
            s.arrival_cycle,
            vec![
                ("id", Arg::U64(s.id)),
                ("class", Arg::Str(class_name(inp.classes, s.class).to_string())),
            ],
        );
        rec.instant(
            Scope::Sim,
            track(pid_base, TID_SHEDS),
            "shed",
            s.shed_cycle,
            vec![
                ("id", Arg::U64(s.id)),
                ("model", Arg::Str(model_name(inp.model_names, s.model).to_string())),
                ("missed_deadline", Arg::U64(s.deadline)),
            ],
        );
    }

    // Autoscale: park/wake instants at occupancy changes, plus the
    // active-shard counter series.
    for (cycle, n) in inp.occupancy {
        rec.counter(Scope::Sim, track(pid_base, TID_AUTOSCALE), "active_shards", *cycle, *n as f64);
    }
    for w in inp.occupancy.windows(2) {
        let ((_, from), (cycle, to)) = (w[0], w[1]);
        if to != from {
            let name = if to > from { "wake_shards" } else { "park_shards" };
            rec.instant(
                Scope::Sim,
                track(pid_base, TID_AUTOSCALE),
                name,
                cycle,
                vec![("from", Arg::U64(from as u64)), ("to", Arg::U64(to as u64))],
            );
        }
    }

    // DVFS: one instant per operating-point transition, at the dispatch
    // cycle the governor made the decision.
    for &(cycle, shard, from, to) in inp.dvfs {
        rec.instant(
            Scope::Sim,
            track(pid_base, TID_DVFS),
            "dvfs_transition",
            cycle,
            vec![
                ("shard", Arg::U64(shard as u64)),
                ("from_op", Arg::U64(from as u64)),
                ("to_op", Arg::U64(to as u64)),
            ],
        );
    }

    // Cache totals as end-of-run counters (the end of the last batch; 0
    // on an empty run).
    let end = inp.completions.iter().map(|c| c.finish_cycle).max().unwrap_or(0);
    for (name, v) in [
        ("plan_cache_hits", inp.plan_cache.0),
        ("plan_cache_misses", inp.plan_cache.1),
        ("tune_cache_hits", inp.tune_cache.0),
        ("tune_cache_misses", inp.tune_cache.1),
    ] {
        rec.counter(Scope::Sim, track(pid_base, TID_CACHES), name, end, v as f64);
    }

    // Per-shard batches: group completions by (shard, batch start); the
    // BTreeMap makes emission order deterministic.
    let mut batches: BTreeMap<(usize, u64), Vec<&Completion>> = BTreeMap::new();
    for c in inp.completions {
        batches.entry((c.shard, c.start_cycle)).or_default().push(c);
    }
    for ((shard, start), mut group) in batches {
        group.sort_by_key(|c| (c.finish_cycle, c.id));
        let t = track(pid_base + shard as u32 + 1, 1);
        let end = group.last().expect("non-empty group").finish_cycle;
        let first = group[0];
        rec.span(
            Scope::Sim,
            t,
            "batch",
            start,
            end - start,
            vec![
                ("size", Arg::U64(first.batch_size as u64)),
                ("model", Arg::Str(model_name(inp.model_names, first.model).to_string())),
            ],
        );
        if first.switch_cycles > 0 {
            rec.span(Scope::Sim, t, "model_switch", start, first.switch_cycles, vec![]);
        }
        for c in group {
            let mut args = vec![
                ("id", Arg::U64(c.id)),
                ("class", Arg::Str(class_name(inp.classes, c.class).to_string())),
                ("batch_size", Arg::U64(c.batch_size as u64)),
                ("queue_cycles", Arg::U64(c.queue_cycles())),
                ("macs", Arg::U64(c.macs)),
            ];
            if let Some(d) = c.deadline {
                args.push(("deadline", Arg::U64(d)));
                args.push(("missed", Arg::U64(c.missed_deadline() as u64)));
            }
            rec.span(
                Scope::Sim,
                t,
                model_name(inp.model_names, c.model),
                c.finish_cycle - c.exec_cycles,
                c.exec_cycles,
                args,
            );
        }
    }
}

/// One federation-control instant (fault or rollout event) at an
/// absolute simulated cycle; args become `U64` trace args.
pub struct ControlInstant {
    pub at: u64,
    pub name: &'static str,
    pub args: Vec<(&'static str, u64)>,
}

const TID_FAULTS: u32 = 1;
const TID_ROLLOUT: u32 = 2;

/// Build the federated timeline: a `federation` control process (fault
/// + rollout instants) at pid 0, then each region's full fleet layout
/// at its own pid block (see module docs). The caller should
/// [`Recorder::canonicalize`] before export; determinism is inherited
/// from the per-region record streams exactly as in
/// [`build_fleet_trace`].
pub fn build_federation_trace(
    regions: &[FleetTraceInputs],
    faults: &[ControlInstant],
    rollout: &[ControlInstant],
) -> Recorder {
    let mut rec = Recorder::new();
    rec.name_process(0, "federation");
    rec.name_thread(track(0, TID_FAULTS), "faults");
    rec.name_thread(track(0, TID_ROLLOUT), "rollout");
    for (tid, instants) in [(TID_FAULTS, faults), (TID_ROLLOUT, rollout)] {
        for c in instants {
            let args = c.args.iter().map(|&(k, v)| (k, Arg::U64(v))).collect();
            rec.instant(Scope::Sim, track(0, tid), c.name, c.at, args);
        }
    }
    let mut pid_base = 1u32;
    for (r, inp) in regions.iter().enumerate() {
        emit_fleet_trace(&mut rec, inp, pid_base, &format!("r{r}/"));
        pid_base += inp.shards as u32 + 1;
    }
    rec
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{check_well_nested, Payload};

    fn completion(
        id: u64,
        shard: usize,
        start: u64,
        finish: u64,
        exec: u64,
        switch: u64,
    ) -> Completion {
        Completion {
            id,
            model: 0,
            class: 0,
            shard,
            arrival_cycle: start.saturating_sub(5),
            deadline: Some(finish + 100),
            start_cycle: start,
            finish_cycle: finish,
            exec_cycles: exec,
            switch_cycles: switch,
            batch_size: 2,
            macs: 1000,
            energy_pj: 1.0,
            op: 1,
            layer_cycles: vec![exec],
            output: vec![],
        }
    }

    fn inputs<'a>(
        completions: &'a [Completion],
        shed: &'a [ShedEvent],
        occupancy: &'a [(u64, usize)],
        names: &'a [String],
    ) -> FleetTraceInputs<'a> {
        FleetTraceInputs {
            completions,
            shed,
            occupancy,
            model_names: names,
            classes: &[],
            shards: 2,
            plan_cache: (3, 1),
            tune_cache: (0, 0),
            dvfs: &[],
        }
    }

    #[test]
    fn batch_switch_and_exec_spans_nest() {
        // One batch on shard 0: switch 10 cycles, then two contiguous
        // 40-cycle exec windows.
        let comps = vec![
            completion(1, 0, 100, 150, 40, 10),
            completion(2, 0, 100, 190, 40, 0),
        ];
        let names = vec!["mnv1".to_string()];
        let mut rec = build_fleet_trace(&inputs(&comps, &[], &[(0, 2)], &names));
        rec.canonicalize();
        check_well_nested(rec.events()).expect("spans must nest");
        let spans: Vec<_> = rec
            .events()
            .iter()
            .filter(|e| matches!(e.payload, Payload::Span { .. }))
            .collect();
        // batch + model_switch + 2 exec
        assert_eq!(spans.len(), 4);
        assert!(spans.iter().any(|e| e.name == "batch"));
        assert!(spans.iter().any(|e| e.name == "model_switch"));
        assert_eq!(spans.iter().filter(|e| e.name == "mnv1").count(), 2);
    }

    #[test]
    fn federation_trace_stacks_regions_at_pid_blocks_with_control_instants() {
        let comps = vec![completion(1, 0, 100, 150, 40, 10)];
        let names = vec!["mnv1".to_string()];
        let occ = [(0u64, 2usize)];
        let regions = [inputs(&comps, &[], &occ, &names), inputs(&[], &[], &occ, &names)];
        let faults = [ControlInstant {
            at: 500,
            name: "shard_fail",
            args: vec![("region", 0), ("shard", 1), ("until", 900)],
        }];
        let rollout = [ControlInstant {
            at: 700,
            name: "rollout_switch",
            args: vec![("canary", 1)],
        }];
        let mut rec = build_federation_trace(&regions, &faults, &rollout);
        rec.canonicalize();
        check_well_nested(rec.events()).expect("spans must nest");
        // pid layout: 0 = federation, region 0 at 1..=3, region 1 at 4..=6
        // (2 shards each => stride 3).
        let procs = rec.processes();
        let find = |pid: u32| procs.iter().find(|(p, _)| *p == pid).map(|(_, n)| n.as_str());
        assert_eq!(find(0), Some("federation"));
        assert_eq!(find(1), Some("r0/fleet"));
        assert_eq!(find(2), Some("r0/shard0"));
        assert_eq!(find(4), Some("r1/fleet"));
        assert_eq!(find(6), Some("r1/shard1"));
        let instants: Vec<&str> = rec
            .events()
            .iter()
            .filter(|e| matches!(e.payload, Payload::Instant))
            .map(|e| e.name.as_str())
            .collect();
        assert!(instants.contains(&"shard_fail"));
        assert!(instants.contains(&"rollout_switch"));
        // region 0 shard 0's batch span landed in its own pid block (pid 2)
        assert!(rec.events().iter().any(|e| e.name == "batch" && e.track.pid == 2));
    }

    #[test]
    fn sheds_and_autoscale_become_instants_and_counters() {
        let shed = vec![ShedEvent {
            id: 7,
            model: 0,
            class: 0,
            priority: 1,
            arrival_cycle: 50,
            deadline: 80,
            shed_cycle: 60,
        }];
        let names = vec!["mnv1".to_string()];
        let occ = [(0u64, 1usize), (500, 2), (900, 1)];
        let mut rec = build_fleet_trace(&inputs(&[], &shed, &occ, &names));
        rec.canonicalize();
        let names_of = |p: fn(&Payload) -> bool| -> Vec<&str> {
            rec.events()
                .iter()
                .filter(|e| p(&e.payload))
                .map(|e| e.name.as_str())
                .collect()
        };
        let instants = names_of(|p| matches!(p, Payload::Instant));
        assert!(instants.contains(&"shed"));
        assert!(instants.contains(&"wake_shards"));
        assert!(instants.contains(&"park_shards"));
        let counters = names_of(|p| matches!(p, Payload::Counter { .. }));
        assert_eq!(counters.iter().filter(|n| *n == "active_shards").count(), 3);
        assert!(counters.contains(&"plan_cache_hits"));
    }

    #[test]
    fn dvfs_transitions_become_instants_on_their_own_track() {
        let names = vec!["mnv1".to_string()];
        let dvfs = [(200u64, 1usize, 1u8, 2u8), (900, 1, 2, 0)];
        let mut inp = inputs(&[], &[], &[(0, 2)], &names);
        inp.dvfs = &dvfs;
        let mut rec = build_fleet_trace(&inp);
        rec.canonicalize();
        let transitions: Vec<_> = rec
            .events()
            .iter()
            .filter(|e| e.name == "dvfs_transition" && matches!(e.payload, Payload::Instant))
            .collect();
        assert_eq!(transitions.len(), 2);
        assert!(transitions.iter().all(|e| e.track == track(0, TID_DVFS)));
        assert_eq!(transitions[0].at, 200);
    }
}
