//! The evaluation workloads (§V-B, §V-C): the synthetic MatMul/conv
//! benchmark tile — "64×3×3×32 filters on a 16×16×32 input tensor" — and
//! the end-to-end network runner.

use crate::coordinator::Coordinator;
use crate::dory::deploy::deploy;
use crate::dory::MemBudget;
use crate::isa::IsaVariant;
use crate::kernels::conv::{gen_conv, ConvTask};
use crate::kernels::im2col::ConvGeom;
use crate::kernels::matmul::{gen_matmul, MatMulTask};
use crate::kernels::requant::RequantCfg;
use crate::qnn::{Network, Precision, QTensor};
use crate::sim::{Cluster, ClusterStats, CoreFidelity, TCDM_BASE};
use crate::util::Prng;

/// Benchmark tile geometry of Fig. 7 / Table III.
pub fn bench_geom(a_bits: u8) -> ConvGeom {
    ConvGeom::square(16, 16, 32, 64, 3, 3, 1, 1, a_bits)
}

/// Table III: the conv expressed as its MatMul (im2col'd A resident in
/// TCDM): M = 256 output pixels, K = 288, N = 64 filters.
pub fn matmul_table3_stats(isa: IsaVariant, prec: Precision) -> ClusterStats {
    matmul_table3_stats_fid(isa, prec, CoreFidelity::Fast)
}

/// [`matmul_table3_stats`] under an explicit core timing tier (the
/// `bench-report --fidelity` path; [`CoreFidelity::Fast`] is
/// bit-identical to the plain form).
pub fn matmul_table3_stats_fid(
    isa: IsaVariant,
    prec: Precision,
    fid: CoreFidelity,
) -> ClusterStats {
    let mut cl = Cluster::pulp();
    cl.set_fidelity(fid);
    matmul_table3_stats_on(&mut cl, isa, prec)
}

/// [`matmul_table3_stats`] on a caller-owned cluster, reset first. A
/// fast-path cache on `cl` survives the reset, so repeated invocations
/// replay the steady-state window instead of re-simulating it — the
/// `sim_speed` bench measures exactly that ratio.
pub fn matmul_table3_stats_on(cl: &mut Cluster, isa: IsaVariant, prec: Precision) -> ClusterStats {
    let mut rng = Prng::new(0x7AB3 + prec.a_bits as u64 * 10 + prec.w_bits as u64);
    let (m, n, k) = (256usize, 64usize, 288usize);
    // Effective kernel width decides padding needs (see kernels::matmul).
    let e_bits = if isa.native_fmts().contains(&crate::isa::SimdFmt::from_bits(prec.a_bits)) {
        prec.a_bits
    } else {
        8
    };
    let a_pitch = (k.div_ceil(32 / prec.a_bits as usize) * 4) as u32;
    let w_pitch = crate::dory::deploy::w_row_pitch(k, e_bits, prec.w_bits);
    let out_bits = 8u8;
    let a_base = TCDM_BASE;
    let w_base = a_base + m as u32 * a_pitch;
    let mult_base = w_base + n as u32 * w_pitch;
    let bias_base = mult_base + 4 * n as u32;
    let out_base = bias_base + 4 * n as u32;
    assert!(
        (out_base - TCDM_BASE) as usize + m * n <= crate::TCDM_BYTES,
        "table3 workload must fit TCDM ({prec})"
    );
    cl.reset();
    let a = QTensor::random(&[m, a_pitch as usize * 8 / prec.a_bits as usize], prec.a_bits, false, &mut rng);
    let w = QTensor::random(&[n, w_pitch as usize * 8 / prec.w_bits as usize], prec.w_bits, true, &mut rng);
    cl.mem.write_bytes(a_base, &a.data);
    cl.mem.write_bytes(w_base, &w.data);
    for ch in 0..n {
        cl.mem.store_u32(mult_base + 4 * ch as u32, 1);
        cl.mem.store_u32(bias_base + 4 * ch as u32, 0);
    }
    let task = MatMulTask {
        m,
        n,
        k,
        prec,
        a_base,
        a_pitch,
        w_base,
        w_pitch,
        out_base,
        out_pitch: n as u32,
        quant: RequantCfg { mult_base, bias_base, shift: 10, out_bits },
    };
    cl.load_programs((0..8).map(|c| gen_matmul(isa, &task, c, 8)).collect());
    cl.run()
}

/// Fig. 7: the full convolution (im2col + MatMul + requant) on the
/// benchmark tile.
pub fn conv_fig7_stats(isa: IsaVariant, prec: Precision) -> ClusterStats {
    conv_fig7_stats_fid(isa, prec, CoreFidelity::Fast)
}

/// [`conv_fig7_stats`] under an explicit core timing tier (the
/// `bench-report --fidelity` path; [`CoreFidelity::Fast`] is
/// bit-identical to the plain form).
pub fn conv_fig7_stats_fid(isa: IsaVariant, prec: Precision, fid: CoreFidelity) -> ClusterStats {
    let mut rng = Prng::new(0xF160 + prec.a_bits as u64 * 10 + prec.w_bits as u64);
    let g = bench_geom(prec.a_bits);
    let e_bits = crate::dory::tiler::buf_bits(&g, isa);
    let w_pitch = crate::dory::deploy::w_row_pitch(g.k(), e_bits, prec.w_bits);
    let out_bits = 8u8;
    let in_base = TCDM_BASE;
    let in_bytes = g.h * g.w * g.cin * g.a_bits as usize / 8;
    let w_base = in_base + in_bytes as u32;
    let mult_base = w_base + g.cout as u32 * w_pitch;
    let bias_base = mult_base + 4 * g.cout as u32;
    let out_base = bias_base + 4 * g.cout as u32;
    let out_bytes = g.out_h() * g.out_w() * g.cout * out_bits as usize / 8;
    let scratch_base = out_base + out_bytes as u32;
    let task = ConvTask {
        geom: g,
        prec,
        in_base,
        w_base,
        w_pitch,
        out_base,
        scratch_base,
        quant: RequantCfg { mult_base, bias_base, shift: 10, out_bits },
    };
    let scratch = crate::kernels::conv::scratch_bytes(&task, isa, 8);
    assert!(
        (scratch_base - TCDM_BASE) as usize + scratch <= crate::TCDM_BYTES,
        "fig7 workload must fit TCDM ({isa:?} {prec})"
    );
    let mut cl = Cluster::pulp();
    cl.set_fidelity(fid);
    let x = QTensor::random(&[g.h, g.w, g.cin], prec.a_bits, false, &mut rng);
    let w = QTensor::random(
        &[g.cout, w_pitch as usize * 8 / prec.w_bits as usize],
        prec.w_bits,
        true,
        &mut rng,
    );
    cl.mem.write_bytes(in_base, &x.data);
    cl.mem.write_bytes(w_base, &w.data);
    for ch in 0..g.cout {
        cl.mem.store_u32(mult_base + 4 * ch as u32, 1);
        cl.mem.store_u32(bias_base + 4 * ch as u32, 0);
    }
    cl.load_programs((0..8).map(|c| gen_conv(isa, &task, c, 8)).collect());
    cl.run()
}

/// Deploy + run a network end-to-end, returning the total simulated
/// `(cycles, MACs, energy [pJ])` of one inference — the raw Table IV
/// measurement shared by the rendered table and the `e2e` benchmark
/// artifact. Energy is billed at the nominal operating point
/// ([`crate::power::OperatingPoint::nominal`]).
pub fn e2e_stats(isa: IsaVariant, net: &Network) -> (u64, u64, f64) {
    let dep = deploy(net, isa, MemBudget::default());
    let mut coord = Coordinator::new(crate::CLUSTER_CORES);
    coord.memoize_tiles = true;
    let mut rng = Prng::new(0xE2E);
    let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
    let res = coord.run(&dep, &input);
    let energy_pj = res.energy_pj(isa, &crate::power::EnergyModel::default());
    (res.total_cycles(), res.total_macs(), energy_pj)
}

/// Deploy + run a network end-to-end, returning cluster MAC/cycle
/// (Table IV's metric).
pub fn e2e_macs_per_cycle(isa: IsaVariant, net: &Network) -> f64 {
    let (cycles, macs, _) = e2e_stats(isa, net);
    macs as f64 / cycles.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_flexv_shape_matches_paper() {
        // The core Table III ordering: a2w2 > a4w2 > a4w4 > a8w2 ≈ a8w4 ≈ a8w8,
        // peak in the right range, and Flex-V beats everyone per column.
        let g = |p: Precision| matmul_table3_stats(IsaVariant::FlexV, p).macs_per_cycle();
        let a2w2 = g(Precision::new(2, 2));
        let a4w4 = g(Precision::new(4, 4));
        let a8w8 = g(Precision::new(8, 8));
        assert!(a2w2 > 70.0 && a2w2 < 128.0, "a2w2 {a2w2} (paper 91.5)");
        assert!(a4w4 > 35.0 && a4w4 < 64.0, "a4w4 {a4w4} (paper 50.6)");
        assert!(a8w8 > 20.0 && a8w8 < 32.0, "a8w8 {a8w8} (paper 26.9)");
        assert!(a2w2 > a4w4 && a4w4 > a8w8);
    }

    #[test]
    fn pipeline_tier_never_speeds_up_table3() {
        // Mac&Load inner loops dodge both pipeline-only hazards by
        // design (§III: the NN-RF has its own write port), so the
        // refined tier can only add cycles — and the functional result
        // (MAC count) is tier-independent.
        for prec in [Precision::new(2, 2), Precision::new(4, 4), Precision::new(8, 8)] {
            let f = matmul_table3_stats(IsaVariant::FlexV, prec);
            let p = matmul_table3_stats_fid(IsaVariant::FlexV, prec, CoreFidelity::Pipeline);
            assert_eq!(f.total_macs(), p.total_macs(), "{prec}");
            assert!(p.cycles >= f.cycles, "{prec}: pipeline {} < fast {}", p.cycles, f.cycles);
        }
    }

    #[test]
    fn table3_mixed_collapse_on_xpulpnn() {
        // XpulpNN's a4w2 collapses below 12 MAC/cycle (paper: 7.62) while
        // Flex-V stays above 40 (paper: 51.9).
        let xnn = matmul_table3_stats(IsaVariant::XpulpNn, Precision::new(4, 2)).macs_per_cycle();
        let flx = matmul_table3_stats(IsaVariant::FlexV, Precision::new(4, 2)).macs_per_cycle();
        assert!(xnn < 12.0, "XpulpNN a4w2 {xnn}");
        assert!(flx > 40.0, "Flex-V a4w2 {flx}");
        assert!(flx / xnn > 4.0, "collapse ratio {}", flx / xnn);
    }
}
