//! Core timing-fidelity tiers: the flat cost model vs. the 4-stage
//! pipeline model.
//!
//! The default tier ([`CoreFidelity::Fast`]) charges the RI5CY costs the
//! cluster has always modeled — one issue per cycle, a 1-cycle load-use
//! penalty, 2 taken-branch bubbles, TCDM conflict stalls — as flat
//! per-instruction costs. [`CoreFidelity::Pipeline`] refines this into
//! an explicit 4-stage in-order pipeline (IF/ID/EX/WB) with a register
//! scoreboard and forwarding paths:
//!
//! ```text
//!        IF ──► ID ──► EX ──► WB
//!               │      │      │
//!               │      └──────┴── EX/WB → ID forwarding (ALU results
//!               │                 bypass the RF; no hazard)
//!               ├── scoreboard: a load's rd is busy for one cycle
//!               │   (consumer in ID stalls — load-use, both tiers);
//!               │   sub-word loads realign in WB, so their consumer
//!               │   stalls one cycle longer (Pipeline tier only)
//!               └── Mac&Load WB port: an NN-RF write-back load occupies
//!                   the LSU write-back port; a GP-LSU memory op retiring
//!                   back-to-back behind it bubbles once (Pipeline only)
//! ```
//!
//! Two hazards exist only in the pipeline model:
//!
//! - **Write-back port contention** ([`CoreStats::wbport_stalls`]): the
//!   Mac&Load controller performs its NN-RF load in the WB stage (§III,
//!   Fig. 4), sharing the LSU write-back port. Consecutive Mac&Load ops
//!   do *not* contend (the NN-RF has its own write port — that is the
//!   point of the design), but a regular GP-LSU memory instruction
//!   (`lw`/`lbu`/`sw`/`sb`) issued cycle-adjacent behind an NN-RF
//!   write-back load loses the port for one cycle.
//! - **Sub-word realignment** ([`CoreStats::align_stalls`]): `lbu`
//!   results pass through the byte-align/extend network in WB, so a
//!   dependent consumer pays a 2-cycle load-use penalty instead of 1.
//!   The first cycle is charged as the regular load-use stall (both
//!   tiers agree on it); the extra cycle lands in `align_stalls`.
//!
//! # Why the tiers are bit-identical by construction
//!
//! The pipeline tier does **not** insert extra stall ticks into the
//! lock-step cluster simulation — it charges its hazard bubbles into the
//! per-core [`CoreStats`] (and the window's cycle total) at retire time.
//! Tick-domain behavior — instruction order, TCDM requests, arbitration,
//! barrier release — is therefore *identical* between tiers, which makes
//! two properties structural rather than empirical:
//!
//! 1. **Bit-identical architectural state.** Both tiers execute the same
//!    instructions in the same order against the same memory; registers,
//!    NN-RF, TCDM, L2 and outputs cannot diverge.
//! 2. **`pipeline_cycles >= fast_cycles`.** Pipeline cycles are the fast
//!    tier's tick count plus non-negative hazard charges.
//!
//! The alternative — real inserted bubbles — would shift multi-core
//! arbitration phase, could *reduce* cluster cycles through accidental
//! conflict avoidance, and would break the window-memo equivalence the
//! steady-state fast path relies on. The retire-time model keeps one
//! tick-domain simulation shared by both tiers; the fidelity only
//! selects which charges are accounted. Windows are still memoized per
//! fidelity (the knob is part of the fast-path structural key), so
//! replayed timing always matches the tier that recorded it.
//!
//! [`CoreStats::wbport_stalls`]: super::stats::CoreStats::wbport_stalls
//! [`CoreStats::align_stalls`]: super::stats::CoreStats::align_stalls
//! [`CoreStats`]: super::stats::CoreStats

use crate::isa::{Instr, MlUpdate};

/// Which timing model a core (and the cluster owning it) runs under.
/// Functional semantics are identical across tiers; only cycle
/// accounting differs (see the module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum CoreFidelity {
    /// Flat per-instruction cost model (the historical default):
    /// load-use, branch and conflict stalls only.
    #[default]
    Fast,
    /// 4-stage IF/ID/EX/WB pipeline model: adds Mac&Load write-back
    /// port contention and sub-word realignment stalls on top of the
    /// fast tier's charges.
    Pipeline,
}

impl CoreFidelity {
    /// Parse a CLI token (`"fast"` / `"pipeline"`).
    pub fn from_name(s: &str) -> Option<CoreFidelity> {
        match s {
            "fast" => Some(CoreFidelity::Fast),
            "pipeline" => Some(CoreFidelity::Pipeline),
            _ => None,
        }
    }

    /// Stable lowercase token (inverse of [`CoreFidelity::from_name`]).
    pub fn name(self) -> &'static str {
        match self {
            CoreFidelity::Fast => "fast",
            CoreFidelity::Pipeline => "pipeline",
        }
    }
}

impl std::fmt::Display for CoreFidelity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Pipeline micro-state carried between retires (Pipeline tier only;
/// stays default in the fast tier). Like `pending_stall`/`hazard_reg`
/// this is timing micro-state, not architectural state: it is reset by
/// `load_program`, normalized by the fast path's functional execution,
/// and excluded from the architectural hash.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct PipeState {
    /// The instruction retired last cycle performed an NN-RF write-back
    /// load (`NnLoad`, or `MlSdotp` with a `Load` update) — the WB port
    /// is claimed for the cycle behind it. Any intervening bubble
    /// (stall, barrier) drains the pipe and clears the claim.
    pub wb_load_armed: bool,
    /// The pending load-use hazard (`hazard_reg`) came from a sub-word
    /// load, whose consumer pays the extra realignment cycle. Set and
    /// cleared in lockstep with `hazard_reg`.
    pub hazard_subword: bool,
}

/// GP-LSU memory instructions — the class that contends with an NN-RF
/// write-back load for the WB port. NN-RF loads themselves are excluded:
/// back-to-back Mac&Load issue is the §III design point.
pub(crate) fn is_gp_lsu(i: &Instr) -> bool {
    matches!(
        i,
        Instr::Lw { .. } | Instr::Lbu { .. } | Instr::Sw { .. } | Instr::Sb { .. }
    )
}

/// Instructions that load into the NN-RF during write-back: `NnLoad`
/// and the fused Mac&Load (`MlSdotp` with a `Load` update).
pub(crate) fn is_nn_wb_load(i: &Instr) -> bool {
    matches!(
        i,
        Instr::NnLoad { .. } | Instr::MlSdotp { upd: MlUpdate::Load { .. }, .. }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluOp, MlChannel, SimdFmt};

    #[test]
    fn fidelity_token_roundtrip() {
        for f in [CoreFidelity::Fast, CoreFidelity::Pipeline] {
            assert_eq!(CoreFidelity::from_name(f.name()), Some(f));
            assert_eq!(format!("{f}"), f.name());
        }
        assert_eq!(CoreFidelity::from_name("cycle"), None);
        assert_eq!(CoreFidelity::default(), CoreFidelity::Fast);
    }

    #[test]
    fn hazard_classes_partition_the_memory_instructions() {
        let gp = [
            Instr::Lw { rd: 1, base: 2, off: 0, post_inc: 0 },
            Instr::Lbu { rd: 1, base: 2, off: 0, post_inc: 0 },
            Instr::Sw { rs: 1, base: 2, off: 0, post_inc: 0 },
            Instr::Sb { rs: 1, base: 2, off: 0, post_inc: 0 },
        ];
        for i in &gp {
            assert!(is_gp_lsu(i), "{i:?}");
            assert!(!is_nn_wb_load(i), "{i:?}");
        }
        let nn_load = Instr::NnLoad { ch: MlChannel::Wgt, slot: 0 };
        let ml_load = Instr::MlSdotp {
            acc: 5,
            a_slot: 4,
            w_slot: 0,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Byte,
            sub: 0,
            upd: MlUpdate::Load { ch: MlChannel::Wgt, slot: 1 },
        };
        let ml_none = Instr::MlSdotp {
            acc: 5,
            a_slot: 4,
            w_slot: 0,
            a_fmt: SimdFmt::Byte,
            w_fmt: SimdFmt::Byte,
            sub: 0,
            upd: MlUpdate::None,
        };
        assert!(is_nn_wb_load(&nn_load) && is_nn_wb_load(&ml_load));
        assert!(!is_nn_wb_load(&ml_none), "plain MlSdotp has no WB load");
        assert!(!is_gp_lsu(&nn_load) && !is_gp_lsu(&ml_load));
        let alu = Instr::Alu { op: AluOp::Add, rd: 1, rs1: 2, rs2: 3 };
        assert!(!is_gp_lsu(&alu) && !is_nn_wb_load(&alu));
    }
}
