"""pytest: L2 model (qconv2d) shapes + semantics vs a direct lax conv
reference, and AOT lowering sanity (HLO text is produced and contains an
entry computation)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.mpq_matmul import pack_weights
from compile.model import im2col, qconv2d, matmul_entry
from compile.aot import to_hlo_text


def conv_ref(x, w, mult, bias, stride, pad, shift, out_bits):
    """Direct integer conv reference (nested loops via lax.conv)."""
    xf = x.astype(np.int64)
    cout, kh, kw, cin = w.shape
    h, ww, _ = x.shape
    xp = np.pad(xf, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (ww + 2 * pad - kw) // stride + 1
    out = np.zeros((oh, ow, cout), dtype=np.int64)
    for oy in range(oh):
        for ox in range(ow):
            patch = xp[oy * stride : oy * stride + kh, ox * stride : ox * stride + kw, :]
            for oc in range(cout):
                acc = int((patch * w[oc].astype(np.int64)).sum()) + int(bias[oc])
                out[oy, ox, oc] = np.clip((acc * int(mult[oc])) >> shift, 0, (1 << out_bits) - 1)
    return out.astype(np.int32)


@pytest.mark.parametrize("a_bits,w_bits,stride,pad", [(8, 8, 1, 1), (8, 4, 2, 1), (4, 2, 1, 0)])
def test_qconv2d_matches_reference(a_bits, w_bits, stride, pad):
    rng = np.random.default_rng(a_bits + w_bits)
    h = w = 6
    cin, cout, k = 4, 8, 3
    x = rng.integers(0, 1 << a_bits, size=(h, w, cin)).astype(np.int32)
    wt = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1), size=(cout, k, k, cin)).astype(np.int32)
    mult = rng.integers(1, 5, size=(cout,)).astype(np.int32)
    bias = rng.integers(-50, 50, size=(cout,)).astype(np.int32)
    w_rows = wt.reshape(cout, -1)
    got = np.asarray(
        qconv2d(
            jnp.asarray(x),
            pack_weights(w_rows, w_bits),
            jnp.asarray(mult),
            jnp.asarray(bias),
            kh=k, kw=k, stride=stride, pad=pad,
            a_bits=a_bits, w_bits=w_bits, shift=6, out_bits=8,
        )
    )
    want = conv_ref(x, wt, mult, bias, stride, pad, 6, 8)
    np.testing.assert_array_equal(got, want)


def test_im2col_layout_is_ky_kx_c():
    x = jnp.arange(2 * 2 * 3, dtype=jnp.int32).reshape(2, 2, 3)
    rows = im2col(x, 1, 2, 1, 0)  # 1x2 kernel, no pad: out 2x1
    assert rows.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(rows[0]), np.asarray(x[0].reshape(-1)))


def test_aot_lowering_produces_hlo_text():
    fn, args = matmul_entry(8, 8, 16, 8, 4, 8, 8)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "s32" in text
    # the kernel lowers to plain HLO (interpret mode), no custom-calls that
    # the CPU PJRT client can't run
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
