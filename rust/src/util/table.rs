//! Plain-text table rendering for the report generators (Tables I-IV,
//! Fig. 7 series). Produces aligned ASCII tables comparable side-by-side
//! with the paper's.

/// A simple column-aligned text table.
#[derive(Default, Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |row: &[String]| -> String {
            let mut line = String::new();
            for (i, cell) in row.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:w$}", cell, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1))));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `d` decimals, trimming to a compact cell.
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["a", "bbbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn float_fmt() {
        assert_eq!(f(3.25678, 2), "3.26");
    }
}
