"""Pure-jnp correctness oracle for the mixed-precision quantized MatMul.

The simplest possible expression of the semantics — no packing, no tiling:
`out = clip(((a @ w.T) + bias) * mult >> shift, 0, 2^out_bits - 1)`.
"""

import jax.numpy as jnp


def mpq_matmul_ref(a, w, mult, bias, *, shift, out_bits):
    """Reference mixed-precision quantized MatMul.

    a:    (M, K) int32 unsigned activation values
    w:    (N, K) int32 signed weight values (unpacked)
    mult: (N,) int32
    bias: (N,) int32
    """
    acc = a.astype(jnp.int32) @ w.astype(jnp.int32).T  # (M, N)
    acc = acc + bias[None, :]
    scaled = jnp.right_shift(acc * mult[None, :], shift)
    return jnp.clip(scaled, 0, (1 << out_bits) - 1)


def requant_ref(acc, mult, bias, *, shift, out_bits):
    """Scalar requantization used by layer-level references."""
    scaled = jnp.right_shift((acc + bias) * mult, shift)
    return jnp.clip(scaled, 0, (1 << out_bits) - 1)
