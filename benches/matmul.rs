//! Bench: Table III — the MatMul kernel grid. Regenerates the paper's
//! rows (MAC/cycle / TOPS/W per precision × core) and reports simulator
//! wall-time per cell.
//!
//! Pass `--artifact FILE` to also persist the `kernels` benchmark
//! artifact (via the shared `report::bench` suite builder, so these
//! numbers and `flexv bench-report` can never diverge).
//!
//!     cargo bench --bench matmul [-- --artifact BENCH_kernels.json]

use flexv::isa::IsaVariant;
use flexv::power::EnergyModel;
use flexv::qnn::Precision;
use flexv::report::workloads::matmul_table3_stats;
use std::time::Instant;

fn main() {
    let em = EnergyModel::default();
    println!("Table III regeneration (paper values in brackets; Flex-V peak 91.5 / 3.26)");
    println!("{:<6} {:<8} {:>10} {:>9} {:>12} {:>10}", "prec", "core", "MAC/cyc", "TOPS/W", "sim-cycles", "wall[ms]");
    let paper_flexv = [(2, 2, 91.5, 3.26), (4, 2, 51.9, 1.87), (4, 4, 50.6, 1.71),
                       (8, 2, 27.8, 1.01), (8, 4, 27.6, 0.96), (8, 8, 26.9, 0.87)];
    for prec in Precision::grid() {
        for isa in IsaVariant::ALL {
            let t0 = Instant::now();
            let stats = matmul_table3_stats(isa, prec);
            let wall = t0.elapsed().as_secs_f64() * 1e3;
            let eff = em.tops_per_watt(isa, &stats, prec.a_bits.max(prec.w_bits));
            let paper = paper_flexv
                .iter()
                .find(|&&(a, w, _, _)| isa == IsaVariant::FlexV && a == prec.a_bits && w == prec.w_bits)
                .map(|&(_, _, mc, ef)| format!("  [paper {mc} / {ef}]"))
                .unwrap_or_default();
            println!(
                "{:<6} {:<8} {:>10.1} {:>9.2} {:>12} {:>10.1}{}",
                prec.to_string(), isa.name(), stats.macs_per_cycle(), eff, stats.cycles, wall, paper
            );
        }
    }
    flexv::report::bench::write_artifact_from_args(
        "kernels",
        &flexv::report::bench::BenchOptions::default(),
    );
}
