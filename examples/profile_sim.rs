// quick profiling harness: separate setup cost from execution cost
use flexv::isa::IsaVariant;
use flexv::qnn::Precision;
use std::time::Instant;
fn main() {
    // setup-only timing
    let t0 = Instant::now();
    for _ in 0..10 {
        let _ = flexv::report::workloads::matmul_table3_stats(IsaVariant::FlexV, Precision::new(8, 8));
    }
    println!("full (setup+run) x10: {:.2}s", t0.elapsed().as_secs_f64());
}
