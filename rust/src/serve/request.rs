//! Request/response records of the serving engine.
//!
//! All times are **simulated cluster cycles** — the serve layer runs a
//! discrete-event simulation over the fleet, so latency percentiles and
//! throughput are deterministic and directly comparable across runs
//! (convert to wall time at the typical corner, 250 MHz, for seconds).
//!
//! [`Completion`]s are the engine's canonical event stream: each
//! dispatch round's completions are merged by `finish_cycle` with
//! `(shard, id)` tie-breaks, so the stream is identical whether shard
//! batches were simulated sequentially or on a thread pool (the
//! determinism contract in [`crate::serve`]).
//!
//! Requests carry an optional **deadline** (absolute simulated cycle by
//! which the response must be complete) and an SLO **class** index (into
//! the engine's class table, see [`crate::serve::workload::SloClass`]).
//! Deadlines drive the queue's earliest-deadline-first ordering and the
//! engine's shed-before-simulate load shedding; classes drive the
//! per-class latency/miss accounting in
//! [`crate::serve::FleetMetrics`].

use crate::qnn::QTensor;

/// One inference request: a registered model plus its input payload.
#[derive(Clone, Debug)]
pub struct Request {
    /// Engine-assigned id (monotonic per engine).
    pub id: u64,
    /// Index into the engine's model registry.
    pub model: usize,
    /// SLO class index (per-class metrics; 0 = default class).
    pub class: u8,
    /// Higher wins; EDF then FIFO within a priority level.
    pub priority: u8,
    /// Simulated cycle at which the request entered the queue.
    pub arrival_cycle: u64,
    /// Absolute simulated cycle by which the request must finish to meet
    /// its SLO; `None` = best-effort (never shed, never counted missed).
    pub deadline: Option<u64>,
    /// Input activation tensor (must match the model's input shape/bits).
    pub input: QTensor,
}

impl Request {
    /// Deadline as a sortable key: best-effort requests order last.
    pub fn deadline_key(&self) -> u64 {
        self.deadline.unwrap_or(u64::MAX)
    }
}

/// A finished request with its measured cost breakdown.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    /// Index into the engine's model registry.
    pub model: usize,
    /// SLO class index of the originating request.
    pub class: u8,
    /// Shard that executed the request.
    pub shard: usize,
    pub arrival_cycle: u64,
    /// Deadline carried by the request (miss accounting).
    pub deadline: Option<u64>,
    /// Cycle at which the shard began the batch containing this request.
    pub start_cycle: u64,
    pub finish_cycle: u64,
    /// Simulated compute cycles of this inference alone.
    pub exec_cycles: u64,
    /// Model-switch (L3→L2 weight streaming) cycles charged to this
    /// request; non-zero only on the first request of a switching batch.
    pub switch_cycles: u64,
    /// Size of the batch this request was coalesced into.
    pub batch_size: usize,
    /// MACs executed.
    pub macs: u64,
    /// Simulated energy of the inference [pJ] (activity-based model,
    /// billed at the batch's operating point).
    pub energy_pj: f64,
    /// Operating-point index the batch ran at (see
    /// [`crate::power::operating_points`]; [`crate::power::OP_NOMINAL`]
    /// unless a DVFS policy or power cap moved the shard).
    pub op: u8,
    /// Per-layer cycle counts, in plan order (determinism checks).
    pub layer_cycles: Vec<u64>,
    /// Raw packed bytes of the network output. Only fully valid when the
    /// engine runs in `exact` mode (timing-only mode skips re-executing
    /// structurally repeated tiles).
    pub output: Vec<u8>,
}

impl Completion {
    /// End-to-end latency: queue wait + switch + position in batch + exec.
    pub fn latency_cycles(&self) -> u64 {
        self.finish_cycle - self.arrival_cycle
    }

    /// Cycles spent queued before the shard started the batch.
    pub fn queue_cycles(&self) -> u64 {
        self.start_cycle.saturating_sub(self.arrival_cycle)
    }

    /// True when the request carried a deadline and finished after it.
    pub fn missed_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| self.finish_cycle > d)
    }
}

/// A request shed before simulation because its deadline could no longer
/// be met (see [`crate::serve::queue::RequestQueue::shed_expired`]).
/// Sheds are part of the deterministic event stream: the engine records
/// them in queue order at the cycle the decision was made.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShedEvent {
    pub id: u64,
    pub model: usize,
    pub class: u8,
    pub priority: u8,
    pub arrival_cycle: u64,
    /// The deadline that could no longer be met.
    pub deadline: u64,
    /// Simulated cycle at which the engine shed the request.
    pub shed_cycle: u64,
}
