//! Sweep the paper's precision grid across all four cores — a miniature
//! Table III + Fig. 7 in one run.
//!
//!     cargo run --release --example precision_sweep

use flexv::isa::IsaVariant;
use flexv::power::EnergyModel;
use flexv::qnn::Precision;
use flexv::report::workloads::{conv_fig7_stats, matmul_table3_stats};

fn main() {
    let em = EnergyModel::default();
    println!("{:<6} {:>10} {:>22} {:>22}", "", "", "MatMul (Table III)", "conv (Fig. 7)");
    println!("{:<6} {:>10} {:>11} {:>10} {:>11} {:>10}", "prec", "core", "MAC/cyc", "TOPS/W", "MAC/cyc", "TOPS/W");
    for prec in Precision::grid() {
        for isa in IsaVariant::ALL {
            let mm = matmul_table3_stats(isa, prec);
            let cv = conv_fig7_stats(isa, prec);
            let bits = prec.a_bits.max(prec.w_bits);
            println!(
                "{:<6} {:>10} {:>11.1} {:>10.2} {:>11.1} {:>10.2}",
                prec.to_string(),
                isa.name(),
                mm.macs_per_cycle(),
                em.tops_per_watt(isa, &mm, bits),
                cv.macs_per_cycle(),
                em.tops_per_watt(isa, &cv, bits),
            );
        }
        println!();
    }
}
