//! Bench: serving-engine throughput — the synthetic mixed 3-model
//! traffic trace (MobileNetV1-8b / 8b4b / ResNet-20-4b2b) replayed on
//! fleets of growing size, plus the trace-shape scenario matrix
//! (steady / poisson / bursty / diurnal SLO workloads with per-class
//! p99 and deadline-miss reporting, static vs autoscaled fleets), plus
//! the federated-fleet row (2 regions behind the least-loaded router
//! with a pinned shard failure, straggler window and live rollout —
//! report asserted byte-identical across worker counts).
//!
//! The engine runs with its defaults: shard batches simulate on a host
//! thread pool and the sim fast path replays steady-state windows. Pass
//! `--baseline` to also run each scaling row sequentially with the fast
//! path off; the simulated numbers must match bit-for-bit (asserted)
//! and the wall-clock ratio is reported (target: ≥ 5x combined).
//!
//! Pass `--tuned` to add an autotuned-fleet row: the same trace served
//! with `ServeConfig::tuned` (simulator-in-the-loop per-layer plans),
//! reporting the tuner's measured default → tuned cycle totals — which
//! can never regress, since the analytic plan is always a candidate.
//!
//! Pass `--artifact FILE` to also persist the `serve` benchmark
//! artifact (via the shared `report::bench` suite builder, so these
//! numbers and `flexv bench-report` can never diverge; `--full`
//! carries over).
//!
//!     cargo bench --bench serve_throughput [-- --full] [-- --baseline] [-- --tuned]
//!                                          [-- --artifact BENCH_serve.json]

use flexv::serve::{
    standard_mix, AutoscaleConfig, Engine, FleetMetrics, ServeConfig, SloClass, TraceShape,
    WorkloadSpec,
};
use std::time::Instant;

/// Simulated cycles → milliseconds at the typical corner (the same
/// conversion FleetMetrics::render uses).
fn ms(cyc: u64) -> f64 {
    cyc as f64 / (flexv::report::F_TYP_MHZ * 1e3)
}

const MIX: [f64; 3] = [0.45, 0.30, 0.25];

fn run_row(shards: usize, workers: usize, fastpath: bool, hw: usize, requests: usize) -> (FleetMetrics, f64) {
    let cfg = ServeConfig { shards, workers, fastpath, ..ServeConfig::default() };
    let mut eng = Engine::new(cfg);
    for net in standard_mix(hw) {
        eng.register(net);
    }
    let trace = eng.synthetic_trace(requests, 1_500_000, &MIX, 0xBE7C);
    let t0 = Instant::now();
    let m = eng.run_trace(trace);
    (m, t0.elapsed().as_secs_f64())
}

/// One SLO scenario: `shape` traffic over the 3-model zoo, either a
/// static `shards`-wide fleet or an autoscaled 1..=`shards` pool.
fn run_scenario(
    shape: TraceShape,
    shards: usize,
    autoscale: bool,
    hw: usize,
    requests: usize,
) -> (FleetMetrics, f64) {
    let autoscale_cfg = autoscale.then(|| {
        let mut ac = AutoscaleConfig::range(1, shards);
        // park quickly relative to the trace's mean gap so valleys show
        ac.idle_cycles_down = 20_000_000;
        ac.cooldown_cycles = 2_000_000;
        ac
    });
    let cfg = ServeConfig { shards, autoscale: autoscale_cfg, ..ServeConfig::default() };
    let mut eng = Engine::new(cfg);
    for net in standard_mix(hw) {
        eng.register(net);
    }
    let mut spec = WorkloadSpec::new(shape, requests, 1_500_000, 3);
    spec.mix = MIX.to_vec();
    spec.classes = SloClass::standard_tiers(40_000_000);
    spec.seed = 0x51_0;
    let trace = eng.workload_trace(&spec);
    let t0 = Instant::now();
    let m = eng.run_trace(trace);
    (m, t0.elapsed().as_secs_f64())
}

fn scenario_matrix(hw: usize, requests: usize) {
    println!();
    println!(
        "scenario matrix: {requests} requests/shape, 3-tier SLO (interactive/standard/batch), \
         static 4-shard fleet vs autoscaled 1:4"
    );
    println!(
        "{:<9} {:<6} {:>7} {:>9} {:>9} {:>6} {:>5} {:>6} {:>7} {:>8}",
        "trace", "fleet", "req/s", "p99[ms]", "int-p99", "miss%", "shed", "occ", "ups/dn", "wall[s]"
    );
    let mut bursty: Vec<FleetMetrics> = Vec::new();
    for shape in TraceShape::ALL {
        for autoscale in [false, true] {
            let (m, wall) = run_scenario(shape, 4, autoscale, hw, requests);
            let interactive = &m.class_rows[0];
            println!(
                "{:<9} {:<6} {:>7.1} {:>9.1} {:>9.1} {:>6.1} {:>5} {:>6.1} {:>4}/{:<2} {:>8.1}",
                shape.name(),
                if autoscale { "auto" } else { "static" },
                m.requests_per_sec,
                ms(m.p99_cycles),
                ms(interactive.p99_cycles),
                m.miss_rate() * 100.0,
                m.shed,
                m.mean_active_shards(),
                m.scale_ups,
                m.scale_downs,
                wall
            );
            assert_eq!(m.class_rows.len(), 3, "per-class reporting missing");
            assert_eq!(
                m.served + m.shed as usize + m.rejected as usize,
                requests,
                "{shape}: requests must be served, shed, or rejected"
            );
            if shape == TraceShape::Bursty {
                bursty.push(m);
            }
        }
    }
    // Elasticity gate: under the bursty trace, the autoscaled pool must
    // track the static max-shard fleet's tail latency (the cold model
    // loads it pays on wake are bounded by the switch costs the static
    // fleet also pays on first use).
    let (stat, auto) = (&bursty[0], &bursty[1]);
    println!(
        "bursty p99: static {:.1} ms vs autoscaled {:.1} ms (mean occupancy {:.1} vs {:.1} shards)",
        ms(stat.p99_cycles),
        ms(auto.p99_cycles),
        stat.mean_active_shards(),
        auto.mean_active_shards(),
    );
    assert!(
        auto.p99_cycles <= stat.p99_cycles,
        "autoscaled bursty p99 ({}) worse than static max fleet ({})",
        auto.p99_cycles,
        stat.p99_cycles
    );
    assert!(
        auto.mean_active_shards() <= stat.mean_active_shards(),
        "autoscaling should not use more shard-time than the static fleet"
    );
}

/// `--tuned`: serve the standard trace once with analytic plans and
/// once with autotuned plans on the same 4-shard fleet; report both and
/// the tuner's own measured delta.
fn tuned_row(hw: usize, requests: usize) {
    println!();
    let run = |tuned: bool| {
        let cfg = ServeConfig { shards: 4, tuned, ..ServeConfig::default() };
        let mut eng = Engine::new(cfg);
        for net in standard_mix(hw) {
            eng.register(net);
        }
        let trace = eng.synthetic_trace(requests, 1_500_000, &MIX, 0xBE7C);
        let t0 = Instant::now();
        let m = eng.run_trace(trace);
        (m, t0.elapsed().as_secs_f64())
    };
    let (md, wall_d) = run(false);
    let (mt, wall_t) = run(true);
    println!(
        "autotuned fleet (4 shards): analytic p99 {:.1} ms, {:.1} MAC/cyc busy ({wall_d:.1}s) \
         vs tuned p99 {:.1} ms, {:.1} MAC/cyc busy ({wall_t:.1}s incl. tuning)",
        ms(md.p99_cycles),
        md.busy_macs_per_cycle,
        ms(mt.p99_cycles),
        mt.busy_macs_per_cycle,
    );
    println!(
        "autotune: {} models, measured per-inference cycles {} → {} ({:.1}% saved, {} layers improved)",
        mt.tuned.models,
        mt.tuned.default_cycles,
        mt.tuned.tuned_cycles,
        mt.tuned.gain_fraction() * 100.0,
        mt.tuned.improved_layers,
    );
    // every model the trace actually dispatched was tuned exactly once
    assert!(
        mt.tuned.models >= 1 && mt.tuned.models <= 3,
        "unexpected tuned-model count {}",
        mt.tuned.models
    );
    assert!(
        mt.tuned.tuned_cycles <= mt.tuned.default_cycles,
        "tuned plans measured worse than the analytic default"
    );
}

/// Federated-fleet row: the shared `report::bench` federation scenario
/// (2 least-loaded regions x 2 shards with a pinned shard failure, a
/// straggler window and a live rollout), run once on the auto worker
/// pool and once sequentially — the rendered report must match
/// byte-for-byte (the fingerprint the CI `federation` job re-checks
/// across worker counts and fast-path settings).
fn federation_row(full: bool) {
    use flexv::report::bench::{federation_scenario, BenchOptions};
    println!();
    let t0 = Instant::now();
    let m = federation_scenario(&BenchOptions { full, ..Default::default() });
    let wall = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let seq = federation_scenario(&BenchOptions { full, workers: 1, ..Default::default() });
    let wall_seq = t1.elapsed().as_secs_f64();
    assert_eq!(m.render(), seq.render(), "federation report diverged across worker counts");
    assert!(m.failovers >= 1, "the pinned shard failure was not applied");
    assert!(m.straggler_windows >= 1, "the pinned straggler was not applied");
    let ro = m.rollout.as_ref().expect("the scenario always rolls out");
    println!(
        "federation: 2 regions x 2 shards (least-loaded), {} served, {} re-queued across {} \
         fault events; rollout drained {} cycles, {} models migrated \
         ({wall:.1}s auto-workers vs {wall_seq:.1}s sequential, identical report)",
        m.total_served(),
        m.requeued,
        m.faults_injected,
        ro.drain_cycles(),
        ro.models_migrated,
    );
}

/// Tracing-overhead figure: run one ResNet-20 inference with the trace
/// sink detached (the no-op default) and once with a recording sink
/// attached, and report cycles/sec for both. The sink lives outside the
/// simulated machine, so it must cost **zero simulated cycles** — the
/// cycle totals and outputs are asserted bit-equal; only host wall
/// clock may move.
fn tracing_overhead(hw: usize) {
    use flexv::coordinator::Coordinator;
    use flexv::dory::deploy::deploy;
    use flexv::dory::MemBudget;
    use flexv::qnn::QTensor;
    use flexv::util::Prng;
    let net = flexv::models::by_name("resnet20-4b2b", hw).expect("known model");
    let dep = deploy(&net, flexv::isa::IsaVariant::FlexV, MemBudget::default());
    let run = |traced: bool| {
        let mut coord = Coordinator::new(flexv::CLUSTER_CORES);
        coord.memoize_tiles = false;
        if traced {
            coord.cluster.tracer = Some(Box::default());
        }
        let mut rng = Prng::new(0xE2E);
        let input = QTensor::random(&net.input_shape.to_vec(), net.input_bits, false, &mut rng);
        let t0 = Instant::now();
        let res = coord.run(&dep, &input);
        let wall = t0.elapsed().as_secs_f64();
        let events = coord.cluster.tracer.as_ref().map_or(0, |r| r.len());
        (res.total_cycles(), res.output, wall, events)
    };
    let (cyc_off, out_off, wall_off, _) = run(false);
    let (cyc_on, out_on, wall_on, events) = run(true);
    assert_eq!(cyc_off, cyc_on, "tracing changed simulated cycles");
    assert_eq!(out_off, out_on, "tracing changed the network output");
    println!();
    println!(
        "tracing overhead: {:.1} M cyc/s sink off vs {:.1} M cyc/s sink on \
         ({events} events, 0 simulated-cycle cost)",
        cyc_off as f64 / wall_off.max(1e-9) / 1e6,
        cyc_on as f64 / wall_on.max(1e-9) / 1e6,
    );
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let baseline = std::env::args().any(|a| a == "--baseline");
    let tuned = std::env::args().any(|a| a == "--tuned");
    let hw = if full { 224 } else { 96 };
    let requests = 24;
    println!("serve throughput: {requests} requests/row, MNV1 input {hw}x{hw}, mix 45/30/25%");
    println!(
        "{:<7} {:>8} {:>9} {:>9} {:>9} {:>7} {:>9} {:>9} {:>8}{}",
        "shards", "req/s", "p50[ms]", "p99[ms]", "MAC/cyc", "util%", "hit-rate", "switches", "wall[s]",
        if baseline { "  base[s] speedup" } else { "" }
    );
    for shards in [2usize, 4, 8] {
        let (m, wall) = run_row(shards, 0, true, hw, requests);
        let tail = if baseline {
            let (mb, wall_b) = run_row(shards, 1, false, hw, requests);
            // parallel + fast path must not move a single simulated number
            assert_eq!(m.span_cycles, mb.span_cycles, "span diverged at {shards} shards");
            assert_eq!(m.p50_cycles, mb.p50_cycles, "p50 diverged at {shards} shards");
            assert_eq!(m.p99_cycles, mb.p99_cycles, "p99 diverged at {shards} shards");
            assert_eq!(m.model_switches, mb.model_switches);
            format!(" {:>8.1} {:>7.1}x", wall_b, wall_b / wall.max(1e-9))
        } else {
            String::new()
        };
        println!(
            "{:<7} {:>8.1} {:>9.2} {:>9.2} {:>9.1} {:>7.0} {:>8.0}% {:>9} {:>8.1}{}",
            shards,
            m.requests_per_sec,
            ms(m.p50_cycles),
            ms(m.p99_cycles),
            m.aggregate_macs_per_cycle,
            m.shard_utilization * 100.0,
            m.cache_hit_rate() * 100.0,
            m.model_switches,
            wall,
            tail
        );
        assert!(m.cache_misses <= 3, "at most one deploy per model");
    }
    if tuned {
        tuned_row(hw, requests);
    }
    scenario_matrix(hw, requests);
    federation_row(full);
    tracing_overhead(hw);
    flexv::report::bench::write_artifact_from_args(
        "serve",
        &flexv::report::bench::BenchOptions { full, ..Default::default() },
    );
}
