//! Bounded admission queue with priorities, EDF ordering, and
//! rejection/shed accounting.
//!
//! The queue is the engine's saturation mechanism: when the fleet falls
//! behind the arrival process, depth grows to `capacity` and further
//! arrivals are **rejected** (counted, never silently dropped) — bounded
//! memory and an explicit load-shedding signal instead of unbounded
//! latency collapse.
//!
//! Admission/service policy notes (tested below):
//! - rejection is priority-blind: a full queue rejects a high-priority
//!   arrival rather than evicting a queued low-priority request —
//!   admitted work is never preempted, so acceptance is monotone in
//!   arrival order and the engine stays deterministic;
//! - `capacity == 0` is valid and admits nothing (drain/canary
//!   configurations);
//! - service order is priority-first, then **earliest deadline first**
//!   within a level (best-effort requests, `deadline == None`, order
//!   after every deadlined request), then FIFO; an optional
//!   resident-model affinity breaks *equal-deadline* ties only and never
//!   crosses priority levels ([`RequestQueue::pop_lead`]);
//! - requests whose deadline can provably no longer be met are **shed**
//!   before they reach a shard ([`RequestQueue::shed_expired`],
//!   shed-before-simulate) and counted separately from rejections;
//! - requests retracted from a failed shard are **re-queued** past the
//!   capacity bound ([`RequestQueue::requeue`]) — failover never drops
//!   admitted work, and the retracted request keeps its priority and
//!   deadline so it re-enters service in exactly the slot its SLO earns.

use std::collections::VecDeque;

use super::request::Request;

/// Priority + EDF + FIFO bounded queue.
pub struct RequestQueue {
    capacity: usize,
    items: VecDeque<Request>,
    /// Requests accepted over the queue's lifetime.
    pub enqueued: u64,
    /// Requests refused because the queue was full.
    pub rejected: u64,
    /// Admitted requests later shed because their deadline became
    /// unmeetable (see [`RequestQueue::shed_expired`]).
    pub shed: u64,
    /// Requests re-admitted after being retracted from a failed shard
    /// (see [`RequestQueue::requeue`]).
    pub requeued: u64,
    /// High-water mark of the depth.
    pub peak_depth: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        RequestQueue {
            capacity,
            items: VecDeque::new(),
            enqueued: 0,
            rejected: 0,
            shed: 0,
            requeued: 0,
            peak_depth: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit a request; returns false (and counts a rejection) when full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            return false;
        }
        self.items.push_back(req);
        self.enqueued += 1;
        self.peak_depth = self.peak_depth.max(self.items.len());
        true
    }

    /// Re-admit a request retracted from a failed shard. Failover must
    /// never drop admitted work, so this bypasses the capacity bound —
    /// the depth may transiently exceed `capacity` (new arrivals are
    /// still bounded by [`RequestQueue::push`]). The request keeps its
    /// original priority, deadline, and arrival cycle, so
    /// [`RequestQueue::pop_lead`] re-serves it in exactly the slot its
    /// SLO earns: failover is priority-preserving by construction.
    /// Counted in `requeued`, not `enqueued` (it was admitted once
    /// already).
    pub fn requeue(&mut self, req: Request) {
        self.items.push_back(req);
        self.requeued += 1;
        self.peak_depth = self.peak_depth.max(self.items.len());
    }

    /// Remove and return the request that should lead the next batch:
    /// highest priority first; within that level, earliest deadline
    /// first (best-effort requests order after all deadlined ones), FIFO
    /// among equal deadlines. When `affinity` names a model, it breaks
    /// equal-`(priority, deadline)` ties in favor of the resident model —
    /// keeping a shard on its model avoids the L3→L2 weight-switch cost
    /// without ever letting residency trump a tighter SLO.
    pub fn pop_lead(&mut self, affinity: Option<usize>) -> Option<Request> {
        let pmax = self.items.iter().map(|r| r.priority).max()?;
        // Sort key: (deadline, non-affine, arrival position). The queue
        // holds arrivals in admission order, so the position is the FIFO
        // tie-break.
        let idx = self
            .items
            .iter()
            .enumerate()
            .filter(|(_, r)| r.priority == pmax)
            .min_by_key(|(pos, r)| {
                (r.deadline_key(), affinity != Some(r.model), *pos)
            })
            .map(|(pos, _)| pos)?;
        self.items.remove(idx)
    }

    /// Remove up to `max` queued requests for `model`, earliest deadline
    /// first (FIFO among equal deadlines, any priority) — the
    /// batch-coalescing primitive. Within a batch the shard executes
    /// members in the returned order, so EDF ordering here is what makes
    /// a coalesced batch respect its members' deadlines.
    pub fn drain_model(&mut self, model: usize, max: usize) -> Vec<Request> {
        self.drain_model_where(model, max, |_| true)
    }

    /// [`RequestQueue::drain_model`] restricted to requests satisfying
    /// `keep` — the batcher's DVFS-tier filter uses it so a coalesced
    /// batch never mixes SLO tiers that run at different operating
    /// points (see [`crate::serve::batcher::BatchPolicy::tier_of`]).
    pub fn drain_model_where(
        &mut self,
        model: usize,
        max: usize,
        keep: impl Fn(&Request) -> bool,
    ) -> Vec<Request> {
        let mut picks: Vec<(u64, usize)> = self
            .items
            .iter()
            .enumerate()
            .filter(|(_, r)| r.model == model && keep(r))
            .map(|(pos, r)| (r.deadline_key(), pos))
            .collect();
        picks.sort_unstable();
        picks.truncate(max);
        // Remove by descending position so earlier indices stay valid.
        let mut order: Vec<usize> = picks.iter().map(|&(_, pos)| pos).collect();
        let mut by_pos = order.clone();
        by_pos.sort_unstable_by(|a, b| b.cmp(a));
        let mut removed: Vec<(usize, Request)> = by_pos
            .into_iter()
            .map(|pos| (pos, self.items.remove(pos).unwrap()))
            .collect();
        // Re-emit in EDF pick order.
        let mut out = Vec::with_capacity(removed.len());
        for pos in order.drain(..) {
            let at = removed.iter().position(|&(p, _)| p == pos).unwrap();
            out.push(removed.swap_remove(at).1);
        }
        out
    }

    /// Shed every queued request that can no longer meet its deadline:
    /// a request is removed (and counted in `shed`) when
    /// `now + est(model) > deadline`, where `est` is a lower bound on the
    /// remaining service cycles for that model (the engine passes the
    /// minimum execution time observed so far, or 0 when the model has
    /// never run — then only already-expired requests are shed).
    /// Best-effort requests (`deadline == None`) are never shed. Returns
    /// the shed requests in queue (admission) order — the deterministic
    /// shed event stream.
    pub fn shed_expired(&mut self, now: u64, est: impl Fn(usize) -> u64) -> Vec<Request> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.items.len() {
            let r = &self.items[i];
            let dead = match r.deadline {
                Some(d) => now.saturating_add(est(r.model)) > d,
                None => false,
            };
            if dead {
                out.push(self.items.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        self.shed += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QTensor;
    use crate::util::{proptest, Prng};

    fn req(id: u64, model: usize, priority: u8) -> Request {
        Request {
            id,
            model,
            class: 0,
            priority,
            arrival_cycle: id,
            deadline: None,
            input: QTensor::zeros(&[1, 1, 8], 8, false),
        }
    }

    fn req_slo(id: u64, model: usize, priority: u8, deadline: u64) -> Request {
        Request { deadline: Some(deadline), ..req(id, model, priority) }
    }

    #[test]
    fn bounded_with_rejections() {
        let mut q = RequestQueue::new(2);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 0)));
        assert!(!q.push(req(2, 0, 0)));
        assert_eq!((q.enqueued, q.rejected, q.peak_depth), (2, 1, 2));
    }

    #[test]
    fn priority_then_fifo() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 0, 0));
        q.push(req(1, 1, 2));
        q.push(req(2, 2, 2));
        q.push(req(3, 0, 1));
        assert_eq!(q.pop_lead(None).unwrap().id, 1); // oldest of prio 2
        assert_eq!(q.pop_lead(None).unwrap().id, 2);
        assert_eq!(q.pop_lead(None).unwrap().id, 3); // prio 1 before prio 0
        assert_eq!(q.pop_lead(None).unwrap().id, 0);
        assert!(q.pop_lead(None).is_none());
    }

    #[test]
    fn edf_within_priority_level() {
        let mut q = RequestQueue::new(8);
        q.push(req_slo(0, 0, 1, 900)); // later deadline, arrived first
        q.push(req_slo(1, 0, 1, 300)); // tightest deadline
        q.push(req(2, 0, 1)); // best-effort: after all deadlined peers
        q.push(req_slo(3, 0, 0, 10)); // tighter but lower priority
        assert_eq!(q.pop_lead(None).unwrap().id, 1, "EDF within level");
        assert_eq!(q.pop_lead(None).unwrap().id, 0);
        assert_eq!(q.pop_lead(None).unwrap().id, 2, "best-effort last");
        assert_eq!(q.pop_lead(None).unwrap().id, 3, "priority still wins");
    }

    #[test]
    fn affinity_prefers_resident_model_within_top_priority() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 0, 0));
        q.push(req(1, 1, 0));
        // same priority, no deadlines: affinity to model 1 overrides FIFO
        assert_eq!(q.pop_lead(Some(1)).unwrap().id, 1);
        // but never crosses priority levels
        q.push(req(2, 1, 0));
        q.push(req(3, 0, 1));
        assert_eq!(q.pop_lead(Some(1)).unwrap().id, 3);
        // and never trumps a tighter deadline
        q.push(req_slo(4, 0, 0, 100));
        assert_eq!(q.pop_lead(Some(1)).unwrap().id, 4);
    }

    /// A full queue rejects newcomers regardless of priority: admitted
    /// work is never preempted, even by a higher-priority arrival, and
    /// the queued order is untouched by the rejected push.
    #[test]
    fn full_queue_rejects_high_priority_without_preemption() {
        let mut q = RequestQueue::new(3);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 1)));
        assert!(q.push(req(2, 0, 0)));
        // queue full: top-priority arrival is rejected, not swapped in
        assert!(!q.push(req(3, 0, 7)));
        assert!(!q.push(req(4, 0, 0)));
        assert_eq!((q.enqueued, q.rejected, q.len()), (3, 2, 3));
        // service order of the admitted requests is unchanged
        assert_eq!(q.pop_lead(None).unwrap().id, 1);
        assert_eq!(q.pop_lead(None).unwrap().id, 0);
        assert_eq!(q.pop_lead(None).unwrap().id, 2);
        // rejections freed no capacity accounting
        assert!(q.is_empty());
        assert_eq!(q.capacity(), 3);
    }

    /// `capacity == 0` is a valid drain configuration: every push is
    /// rejected and counted, and every consumer sees an empty queue.
    #[test]
    fn zero_capacity_queue_admits_nothing() {
        let mut q = RequestQueue::new(0);
        for id in 0..4 {
            assert!(!q.push(req(id, 0, (id % 3) as u8)));
        }
        assert_eq!((q.enqueued, q.rejected, q.peak_depth), (0, 4, 0));
        assert!(q.is_empty());
        assert!(q.pop_lead(None).is_none());
        assert!(q.pop_lead(Some(0)).is_none());
        assert!(q.drain_model(0, 8).is_empty());
    }

    /// Failover re-admission: bypasses the capacity bound, keeps the
    /// retracted request's priority/deadline service slot, and is
    /// counted separately from first admissions.
    #[test]
    fn requeue_bypasses_capacity_and_preserves_priority() {
        let mut q = RequestQueue::new(2);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 0)));
        // full: a failover retraction must still get back in
        q.requeue(req_slo(2, 0, 2, 50));
        assert_eq!((q.len(), q.requeued, q.enqueued, q.rejected), (3, 1, 2, 0));
        assert_eq!(q.peak_depth, 3);
        // its priority/deadline still lead the queue
        assert_eq!(q.pop_lead(None).unwrap().id, 2);
        // new arrivals remain bounded
        assert!(!q.push(req(3, 0, 0)));
    }

    #[test]
    fn drain_model_coalesces_in_order() {
        let mut q = RequestQueue::new(8);
        for (id, m) in [(0, 0), (1, 1), (2, 0), (3, 0), (4, 1)] {
            q.push(req(id, m, 0));
        }
        let batch = q.drain_model(0, 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.drain_model(0, 9).len(), 1); // id 3 remains
    }

    #[test]
    fn drain_model_orders_by_deadline_first() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 0, 0)); // best-effort, oldest
        q.push(req_slo(1, 0, 0, 500));
        q.push(req_slo(2, 0, 0, 100));
        q.push(req(3, 1, 0)); // other model, untouched
        let batch = q.drain_model(0, 8);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 1, 0]);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn shed_expired_removes_only_unmeetable_deadlines() {
        let mut q = RequestQueue::new(8);
        q.push(req_slo(0, 0, 0, 50)); // expired at now=100
        q.push(req_slo(1, 0, 0, 130)); // unmeetable with est 50
        q.push(req_slo(2, 0, 0, 200)); // meetable
        q.push(req(3, 0, 0)); // best-effort, never shed
        let shed = q.shed_expired(100, |_| 50);
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(q.shed, 2);
        assert_eq!(q.len(), 2);
        // shedding frees capacity for new admissions
        for id in 4..10 {
            q.push(req(id, 0, 0));
        }
        assert_eq!(q.len(), 8);
    }

    /// Property: pops drain in (priority desc, deadline asc, FIFO) order,
    /// depth never exceeds capacity, and the admission accounting is
    /// consistent with the number of successful pushes.
    #[test]
    fn prop_pop_order_and_capacity() {
        proptest::check_default(
            |rng: &mut Prng| {
                let capacity = rng.range(0, 12);
                let n = rng.range(1, 32);
                let reqs: Vec<(u8, Option<u64>)> = (0..n)
                    .map(|_| {
                        let prio = rng.range(0, 3) as u8;
                        let dl = rng.chance(0.6).then(|| rng.below(1000));
                        (prio, dl)
                    })
                    .collect();
                (capacity, reqs)
            },
            |(capacity, reqs)| {
                let mut q = RequestQueue::new(*capacity);
                let mut admitted = 0u64;
                for (id, &(prio, dl)) in reqs.iter().enumerate() {
                    let mut r = req(id as u64, 0, prio);
                    r.deadline = dl;
                    if q.push(r) {
                        admitted += 1;
                    }
                    if q.len() > *capacity {
                        return Err(format!("depth {} > capacity {capacity}", q.len()));
                    }
                }
                if q.enqueued != admitted || q.rejected != reqs.len() as u64 - admitted {
                    return Err(format!(
                        "accounting: enqueued {} rejected {} admits {admitted}",
                        q.enqueued, q.rejected
                    ));
                }
                let mut popped = Vec::new();
                while let Some(r) = q.pop_lead(None) {
                    popped.push(r);
                }
                if popped.len() as u64 != admitted {
                    return Err("pop count != admits".into());
                }
                for w in popped.windows(2) {
                    let a = (std::cmp::Reverse(w[0].priority), w[0].deadline_key(), w[0].id);
                    let b = (std::cmp::Reverse(w[1].priority), w[1].deadline_key(), w[1].id);
                    if a > b {
                        return Err(format!(
                            "order violated: {:?} before {:?}",
                            (w[0].id, w[0].priority, w[0].deadline),
                            (w[1].id, w[1].priority, w[1].deadline)
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: shedding removes exactly the unmeetable-deadline subset,
    /// keeps everything else in place, and the shed/enqueued counters
    /// stay consistent.
    #[test]
    fn prop_shed_partitions_queue() {
        proptest::check_default(
            |rng: &mut Prng| {
                let n = rng.range(1, 24);
                let now = rng.below(500);
                let est = rng.below(100);
                let dls: Vec<Option<u64>> =
                    (0..n).map(|_| rng.chance(0.7).then(|| rng.below(700))).collect();
                (now, est, dls)
            },
            |(now, est, dls)| {
                let mut q = RequestQueue::new(64);
                for (id, &dl) in dls.iter().enumerate() {
                    let mut r = req(id as u64, id % 3, 0);
                    r.deadline = dl;
                    q.push(r);
                }
                let shed = q.shed_expired(*now, |_| *est);
                let should_shed = |dl: &Option<u64>| dl.is_some_and(|d| now + est > d);
                let want: Vec<u64> = dls
                    .iter()
                    .enumerate()
                    .filter(|(_, dl)| should_shed(dl))
                    .map(|(id, _)| id as u64)
                    .collect();
                let got: Vec<u64> = shed.iter().map(|r| r.id).collect();
                if got != want {
                    return Err(format!("shed {got:?} want {want:?}"));
                }
                if q.shed != want.len() as u64 || q.len() + want.len() != dls.len() {
                    return Err("shed accounting inconsistent".into());
                }
                if q.shed_expired(*now, |_| *est).len() != 0 {
                    return Err("shed not idempotent".into());
                }
                Ok(())
            },
        );
    }
}
