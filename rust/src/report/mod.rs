//! Regeneration of every table and figure of the paper's evaluation
//! (§V): Tables I-IV and Fig. 7. Each function runs the corresponding
//! workload on the simulator and renders rows directly comparable with
//! the paper's.
//!
//! Beyond the pretty-printed tables, the same measurements feed the
//! machine-readable benchmark-artifact pipeline: [`artifact`] defines
//! the `BENCH_<suite>.json` schema and the [`artifact::MetricSource`]
//! trait, [`bench`] runs the four suites (kernels / e2e / autotune /
//! serve) through the *same* cell functions the tables render from, and
//! [`regress`] gates a fresh run against committed baselines.

pub mod artifact;
pub mod bench;
pub mod regress;
pub mod workloads;

use crate::isa::IsaVariant;
use crate::power::{gops, phys, EnergyModel};
use crate::qnn::Precision;
use crate::util::table::{f, Table};
use workloads::{conv_fig7_stats, matmul_table3_stats};

/// Efficiency-corner frequency [MHz] used for Gop/s numbers.
pub const F_TYP_MHZ: f64 = 250.0;

/// One Table III / Fig. 7 cell.
#[derive(Clone, Copy, Debug)]
pub struct KernelCell {
    pub macs_per_cycle: f64,
    pub tops_per_watt: f64,
}

/// Run the Table III MatMul grid for one ISA. Cells the paper leaves
/// blank (RI5CY sub-byte activations) are still measured but flagged.
pub fn table3_cells(isa: IsaVariant) -> Vec<(Precision, KernelCell)> {
    let em = EnergyModel::default();
    Precision::grid()
        .into_iter()
        .map(|prec| {
            let stats = matmul_table3_stats(isa, prec);
            let cell = KernelCell {
                macs_per_cycle: stats.macs_per_cycle(),
                tops_per_watt: em.tops_per_watt(isa, &stats, prec.a_bits.max(prec.w_bits)),
            };
            (prec, cell)
        })
        .collect()
}

/// Table III: performance / energy efficiency of MatMul kernels.
pub fn table3() -> String {
    let mut t = Table::new(
        "Table III — MatMul kernels: MAC/cycle / TOPS/W (paper: Flex-V peaks 91.5 / 3.26)",
    )
    .header(&["Inputs", "RI5CY", "MPIC", "XpulpNN", "Flex-V"]);
    let per_isa: Vec<Vec<(Precision, KernelCell)>> =
        IsaVariant::ALL.iter().map(|&isa| table3_cells(isa)).collect();
    for (pi, prec) in Precision::grid().into_iter().enumerate() {
        let mut row = vec![prec.to_string()];
        for (ii, isa) in IsaVariant::ALL.iter().enumerate() {
            let (_, cell) = per_isa[ii][pi];
            // The paper leaves RI5CY sub-byte-activation cells blank.
            if *isa == IsaVariant::Ri5cy && prec.a_bits < 8 {
                row.push(format!("({} / {})", f(cell.macs_per_cycle, 1), f(cell.tops_per_watt, 2)));
            } else {
                row.push(format!("{} / {}", f(cell.macs_per_cycle, 1), f(cell.tops_per_watt, 2)));
            }
        }
        t.row(row);
    }
    t.render() + "(parenthesised cells are '-' in the paper: RI5CY lacks sub-byte support)\n"
}

/// Fig. 7 data: per-ISA per-precision conv-layer performance + efficiency.
pub fn fig7_cells() -> Vec<(IsaVariant, Vec<(Precision, KernelCell)>)> {
    let em = EnergyModel::default();
    IsaVariant::ALL
        .iter()
        .map(|&isa| {
            let cells = Precision::grid()
                .into_iter()
                .map(|prec| {
                    let stats = conv_fig7_stats(isa, prec);
                    (
                        prec,
                        KernelCell {
                            macs_per_cycle: stats.macs_per_cycle(),
                            tops_per_watt: em.tops_per_watt(
                                isa,
                                &stats,
                                prec.a_bits.max(prec.w_bits),
                            ),
                        },
                    )
                })
                .collect();
            (isa, cells)
        })
        .collect()
}

/// Fig. 7: convolution layers (64×3×3×32 filters on a 16×16×32 input).
pub fn fig7() -> String {
    let data = fig7_cells();
    let mut t = Table::new(
        "Fig. 7(a) — conv layer performance [MAC/cycle] (paper: Flex-V up to 38.2, speedups 1.4×/4.5×/8.5× vs MPIC/XpulpNN/XpulpV2 on mixed)",
    )
    .header(&["Inputs", "RI5CY", "MPIC", "XpulpNN", "Flex-V", "FlexV/RI5CY", "FlexV/XpulpNN", "FlexV/MPIC"]);
    for (pi, prec) in Precision::grid().into_iter().enumerate() {
        let get = |ii: usize| data[ii].1[pi].1.macs_per_cycle;
        let (r, m, x, fl) = (get(0), get(1), get(2), get(3));
        t.row(vec![
            prec.to_string(),
            f(r, 1),
            f(m, 1),
            f(x, 1),
            f(fl, 1),
            format!("{}x", f(fl / r, 1)),
            format!("{}x", f(fl / x, 1)),
            format!("{}x", f(fl / m, 1)),
        ]);
    }
    let mut e = Table::new("Fig. 7(b) — conv layer energy efficiency [TOPS/W]")
        .header(&["Inputs", "RI5CY", "MPIC", "XpulpNN", "Flex-V"]);
    for (pi, prec) in Precision::grid().into_iter().enumerate() {
        let get = |ii: usize| data[ii].1[pi].1.tops_per_watt;
        e.row(vec![
            prec.to_string(),
            f(get(0), 2),
            f(get(1), 2),
            f(get(2), 2),
            f(get(3), 2),
        ]);
    }
    t.render() + "\n" + &e.render()
}

/// Table II: area / frequency / power of the physical implementation.
pub fn table2() -> String {
    let em = EnergyModel::default();
    let mut t = Table::new("Table II — physical implementation (GF22FDX model, anchors from the paper)")
        .header(&["Metric", "RI5CY", "Flex-V", "Overhead"]);
    let r = phys(IsaVariant::Ri5cy);
    let fl = phys(IsaVariant::FlexV);
    t.row(vec![
        "fmax [MHz]".into(),
        f(r.fmax_mhz, 0),
        f(fl.fmax_mhz, 0),
        format!("{}%", f((1.0 - fl.fmax_mhz / r.fmax_mhz) * 100.0, 1)),
    ]);
    t.row(vec![
        "Core area [um2]".into(),
        f(r.core_area_um2, 0),
        f(fl.core_area_um2, 0),
        format!("{}%", f((fl.core_area_um2 / r.core_area_um2 - 1.0) * 100.0, 1)),
    ]);
    t.row(vec![
        "Cluster area [um2]".into(),
        f(r.cluster_area_um2, 0),
        f(fl.cluster_area_um2, 0),
        format!("{}%", f((fl.cluster_area_um2 / r.cluster_area_um2 - 1.0) * 100.0, 2)),
    ]);
    // 8-bit MatMul cluster power at 250 MHz. As in the paper (§V-A), the
    // overhead is measured with the Flex-V extensions *disabled*: both
    // cores run the identical XpulpV2-only kernel, so the delta is the
    // extension logic's leakage + clock-tree load on otherwise idle CSRs.
    let p8 = Precision::new(8, 8);
    let s_r = matmul_table3_stats(IsaVariant::Ri5cy, p8);
    let pw_r = em.power_mw(IsaVariant::Ri5cy, &s_r, 8, F_TYP_MHZ);
    let pw_f = em.power_mw(IsaVariant::FlexV, &s_r, 8, F_TYP_MHZ) + 0.12; // gated-CSR clock load
    t.row(vec![
        "Cluster power, 8b MatMul, ext. disabled [mW]".into(),
        f(pw_r, 1),
        f(pw_f, 1),
        format!("{}%", f((pw_f / pw_r - 1.0) * 100.0, 2)),
    ]);
    t.row(vec![
        "Cluster leakage [mW]".into(),
        f(r.leak_mw, 3),
        f(fl.leak_mw, 3),
        format!("{}%", f((fl.leak_mw / r.leak_mw - 1.0) * 100.0, 1)),
    ]);
    t.render()
        + "(paper: fmax 472->463 MHz, core 13721->17816 um2 (+29.8%), cluster +5.59%, power 12.3->12.6 mW (+2.04%))\n"
}

/// Table I: the platform-landscape overview with "This Work" measured.
pub fn table1() -> String {
    let em = EnergyModel::default();
    // Measured bounds over the Table III grid on Flex-V.
    let cells = table3_cells(IsaVariant::FlexV);
    let mut gops_lo = f64::MAX;
    let mut gops_hi: f64 = 0.0;
    let mut eff_lo = f64::MAX;
    let mut eff_hi: f64 = 0.0;
    for (prec, cell) in &cells {
        let stats = matmul_table3_stats(IsaVariant::FlexV, *prec);
        let g = gops(&stats, phys(IsaVariant::FlexV).fmax_mhz);
        gops_lo = gops_lo.min(g);
        gops_hi = gops_hi.max(g);
        eff_lo = eff_lo.min(cell.tops_per_watt * 1000.0);
        eff_hi = eff_hi.max(cell.tops_per_watt * 1000.0);
        let _ = em;
    }
    let mut t = Table::new("Table I — QNN embedded computing platforms (literature rows cited; This Work measured)")
        .header(&["Platform", "Throughput [Gop/s]", "Energy Eff. [Gop/s/W]", "Power [mW]", "Flexibility"]);
    t.row(vec!["ASICs [4]".into(), "1K - 50K".into(), "10K - 100K".into(), "1 - 1K".into(), "Low".into()]);
    t.row(vec!["FPGAs [8]".into(), "10 - 200".into(), "1 - 10".into(), "1 - 1K".into(), "Medium".into()]);
    t.row(vec!["MCUs [13]".into(), "0.1 - 2".into(), "1 - 50".into(), "1 - 1K".into(), "High".into()]);
    t.row(vec![
        "This Work (measured)".into(),
        format!("{} - {}", f(gops_lo, 0), f(gops_hi, 0)),
        format!("{} - {}", f(eff_lo, 0), f(eff_hi, 0)),
        "1 - 100".into(),
        "High".into(),
    ]);
    t.render() + "(paper This-Work row: 25 - 85 Gop/s, 610 - 3K Gop/s/W)\n"
}

/// One measured Table IV cell: a full network deployed and run
/// end-to-end on one ISA (the data behind both [`table4`] and the `e2e`
/// benchmark artifact — `bench-report` and the rendered table can never
/// diverge because both read these cells).
#[derive(Clone, Debug)]
pub struct E2eCell {
    /// Registry name ([`crate::models::MODEL_NAMES`]).
    pub model: &'static str,
    pub isa: IsaVariant,
    /// Total simulated cycles of one inference.
    pub cycles: u64,
    /// Total MACs of one inference.
    pub macs: u64,
    /// Simulated energy of one inference [pJ] at the nominal operating
    /// point (energy-model output — analog, not exact).
    pub energy_pj: f64,
}

impl E2eCell {
    pub fn macs_per_cycle(&self) -> f64 {
        self.macs as f64 / self.cycles.max(1) as f64
    }

    /// End-to-end efficiency: `2·MACs / energy` [TOPS/W].
    pub fn tops_per_watt(&self) -> f64 {
        if self.energy_pj > 0.0 { 2.0 * self.macs as f64 / self.energy_pj } else { 0.0 }
    }
}

/// The ISAs of Table IV's measured rows (the paper omits MPIC there).
pub const TABLE4_ISAS: [IsaVariant; 3] =
    [IsaVariant::Ri5cy, IsaVariant::XpulpNn, IsaVariant::FlexV];

/// Measure every Table IV cell (model-major, ISA-minor). `quick`
/// shrinks MobileNet's input to 96×96 (MAC/cycle is
/// input-size-insensitive).
pub fn table4_cells(quick: bool) -> Vec<E2eCell> {
    let hw = if quick { 96 } else { 224 };
    let mut out = Vec::new();
    for model in crate::models::MODEL_NAMES {
        let net = crate::models::by_name(model, hw).expect("registry model");
        for isa in TABLE4_ISAS {
            let (cycles, macs, energy_pj) = workloads::e2e_stats(isa, &net);
            out.push(E2eCell { model, isa, cycles, macs, energy_pj });
        }
    }
    out
}

/// Table IV: end-to-end networks. `quick` shrinks MobileNet's input to
/// 96×96 to keep the run short (MAC/cycle is input-size-insensitive).
pub fn table4(quick: bool) -> String {
    use crate::models::{cited_accuracy, mobilenet_v1, resnet20, Profile};
    let input_hw = if quick { 96 } else { 224 };
    let nets = vec![
        ("MNV1 (8b)", mobilenet_v1(Profile::Uniform8, 0.75, input_hw, 11), Profile::Uniform8),
        ("MNV1 (8b4b)", mobilenet_v1(Profile::Mixed8a4w, 0.75, input_hw, 11), Profile::Mixed8a4w),
        ("ResNet20 (4b2b)", resnet20(Profile::Mixed4a2w, 12), Profile::Mixed4a2w),
    ];
    let mut t = Table::new(format!(
        "Table IV — end-to-end networks{} (paper Flex-V row: 6.0 / 5.8 / 11.2 MAC/cycle)",
        if quick { " [quick: 96x96 MNV1 input]" } else { "" }
    ))
    .header(&["", "MNV1 (8b)", "MNV1 (8b4b)", "ResNet20 (4b2b)"]);
    // Accuracy (cited) + footprint rows.
    t.row(vec![
        "Top-1 Acc. (cited)".into(),
        format!("{}%", cited_accuracy("MobileNetV1-8b").unwrap()),
        format!("{}%", cited_accuracy("MobileNetV1-8b4b").unwrap()),
        format!("{}%", cited_accuracy("ResNet20-4b2b").unwrap()),
    ]);
    let sizes: Vec<f64> = nets.iter().map(|(_, n, _)| n.model_bytes() as f64 / 1024.0).collect();
    t.row(vec![
        "Model size [kB]".into(),
        f(sizes[0], 0),
        f(sizes[1], 0),
        f(sizes[2], 0),
    ]);
    t.row(vec![
        "Mem. saved".into(),
        "-".into(),
        format!("{}%", f((1.0 - sizes[1] / sizes[0]) * 100.0, 0)),
        {
            let full8 = resnet20(Profile::Uniform8, 12).model_bytes() as f64 / 1024.0;
            format!("{}%", f((1.0 - sizes[2] / full8) * 100.0, 0))
        },
    ]);
    // STM32H7 cited row.
    t.row(vec![
        "STM32H7 [12] (cited)".into(),
        "0.33".into(),
        "0.30".into(),
        "-".into(),
    ]);
    // Measured MAC/cycle rows per ISA — the same cells the `e2e`
    // benchmark artifact serializes ([`table4_cells`]).
    let cells = table4_cells(quick);
    for isa in TABLE4_ISAS {
        let mut row = vec![match isa {
            IsaVariant::Ri5cy => "XpulpV2 (RI5CY)".to_string(),
            other => other.name().to_string(),
        }];
        for model in crate::models::MODEL_NAMES {
            let cell = cells
                .iter()
                .find(|c| c.model == model && c.isa == isa)
                .expect("every (model, isa) cell is measured");
            row.push(f(cell.macs_per_cycle(), 1));
        }
        t.row(row);
    }
    t.render()
}
