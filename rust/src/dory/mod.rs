//! DORY-style memory-aware deployment flow (§IV).
//!
//! Extends the open-source DORY tool's approach to sub-byte tensors exactly
//! as the paper describes: a Constraint-Programming-flavoured **tiling
//! solver** splits every layer into tiles whose working set fits L1, under
//! the new sub-byte constraints (innermost tensor dimensions byte-aligned,
//! channel tiles multiples of 4); the produced **plan** carries, per tile,
//! the double-buffered DMA transfers and the kernel launch descriptor the
//! coordinator executes on the simulated cluster. CSR setup common to all
//! tiles is hoisted into the kernel programs (the "templates").
//!
//! Loop order per layer: output-row strips outermost, output-channel tiles
//! inner; the input strip is loaded once per row strip, weight tiles are
//! streamed per channel tile, everything ping-pongs between two L1 buffers.

pub mod autotune;
pub mod deploy;
pub mod tiler;

pub use autotune::{LayerTuning, NetworkTuning, TuneCache, TuneConfig};
pub use tiler::{solve_conv_tiling, solve_dw_tiling, TileShape};

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use crate::isa::IsaVariant;
use crate::kernels::conv::ConvTask;
use crate::kernels::layers::{AddTask, AvgPoolTask, ConcatTask, DwConvTask, MaxPoolTask};
use crate::kernels::requant::RequantCfg;
use crate::qnn::layer::{LayerKind, Network};
use crate::qnn::Precision;
use crate::sim::dma::{DmaDir, DmaRequest};

/// A structural cache key for compiled plans and tile programs.
///
/// Two users share this type (so their caches agree on identity):
///
/// - the **coordinator**'s tile-timing memo ([`PlanKey::for_tile`]): the
///   kernel-launch descriptor plus the TCDM-side DMA layout — program
///   generation and cycle-accurate timing are pure functions of it;
/// - the **serve** plan cache ([`PlanKey::for_network`]): the full
///   (model, precision config, tiling parameters) identity, so
///   [`deploy::deploy`] runs once per model instead of once per request.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PlanKey(u64);

impl PlanKey {
    /// The raw 64-bit hash value.
    pub fn raw(self) -> u64 {
        self.0
    }

    /// Structural key of one tile: the kernel-launch descriptor (program
    /// generation is a pure function of it, the ISA, and the core count)
    /// plus the DMA descriptors. L1 addresses are part of the descriptor,
    /// so the double-buffer parity — which shifts bank-conflict patterns —
    /// is captured. DMA timing depends on sizes, the TCDM-side layout
    /// (bank patterns) and strides — NOT on the L2-side address, which
    /// differs per tile without affecting a single cycle.
    pub fn for_tile(isa: IsaVariant, tile: &TileExec, n_cores: usize) -> Self {
        let mut h = DefaultHasher::new();
        (isa as u8).hash(&mut h);
        n_cores.hash(&mut h);
        tile.kernel.hash(&mut h);
        for r in tile.loads.iter().chain(tile.stores.iter()) {
            (r.dir, r.loc, r.row_bytes, r.rows, r.loc_stride).hash(&mut h);
        }
        PlanKey(h.finish())
    }

    /// Identity of a compiled deployment: the network (topology, per-layer
    /// precisions, quantization parameters, weight bytes) together with
    /// everything else `deploy` depends on — target ISA, memory budget
    /// (the tiling parameters follow from it) and cluster width.
    pub fn for_network(net: &Network, isa: IsaVariant, budget: MemBudget, n_cores: usize) -> Self {
        let mut h = DefaultHasher::new();
        (isa as u8).hash(&mut h);
        n_cores.hash(&mut h);
        budget.l1.hash(&mut h);
        budget.l2.hash(&mut h);
        net.name.hash(&mut h);
        net.input_shape.hash(&mut h);
        net.input_bits.hash(&mut h);
        net.nodes.len().hash(&mut h);
        for node in &net.nodes {
            node.inputs.hash(&mut h);
            let l = &node.layer;
            hash_kind(&l.kind, &mut h);
            l.in_shape.hash(&mut h);
            l.out_shape.hash(&mut h);
            l.a_bits.hash(&mut h);
            l.w_bits.hash(&mut h);
            match &l.weights {
                Some(w) => {
                    1u8.hash(&mut h);
                    w.bits.hash(&mut h);
                    w.shape.hash(&mut h);
                    w.data.hash(&mut h);
                }
                None => 0u8.hash(&mut h),
            }
            l.quant.mult.hash(&mut h);
            l.quant.bias.hash(&mut h);
            l.quant.shift.hash(&mut h);
            l.quant.out_bits.hash(&mut h);
        }
        PlanKey(h.finish())
    }
}

fn hash_kind<H: Hasher>(kind: &LayerKind, h: &mut H) {
    match kind {
        LayerKind::Conv2d { kh, kw, stride, pad } => (0u8, kh, kw, stride, pad).hash(h),
        LayerKind::DwConv2d { kh, kw, stride, pad } => (1u8, kh, kw, stride, pad).hash(h),
        LayerKind::Linear => 2u8.hash(h),
        LayerKind::MaxPool { k, stride } => (3u8, k, stride).hash(h),
        LayerKind::AvgPool { k, stride } => (4u8, k, stride).hash(h),
        LayerKind::Add { m1, m2 } => (5u8, m1, m2).hash(h),
        LayerKind::Concat => 6u8.hash(h),
    }
}

/// Memory budgets of the deployment target.
#[derive(Clone, Copy, Debug)]
pub struct MemBudget {
    /// Usable L1 (TCDM) bytes for tile buffers (the rest is stack/runtime).
    pub l1: usize,
    /// L2 bytes for weights + ping-pong activations.
    pub l2: usize,
}

impl Default for MemBudget {
    fn default() -> Self {
        MemBudget { l1: 110 * 1024, l2: crate::L2_BYTES }
    }
}

/// A kernel launch on the cluster (L1 addresses already resolved).
#[derive(Clone, Hash, PartialEq, Eq, Debug)]
pub enum KernelCall {
    Conv(ConvTask),
    Dw(DwConvTask),
    Linear {
        prec: Precision,
        cin: usize,
        cout: usize,
        in_base: u32,
        w_base: u32,
        w_pitch: u32,
        out_base: u32,
        quant: RequantCfg,
    },
    Add(AddTask),
    AvgPool(AvgPoolTask),
    MaxPool(MaxPoolTask),
    Concat(ConcatTask),
}

/// One tile: loads to issue before compute, the kernel, stores after.
#[derive(Clone, Debug)]
pub struct TileExec {
    pub loads: Vec<DmaRequest>,
    pub kernel: KernelCall,
    pub stores: Vec<DmaRequest>,
}

/// Per-layer execution override chosen by the autotuner: the kernel
/// lowering ([`IsaVariant::compatible_lowerings`]) and the core count
/// this layer's programs are generated for. `None` on a plan means the
/// deployment-wide defaults (the deployment's ISA, the cluster width).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ExecOverride {
    pub isa: IsaVariant,
    pub n_cores: usize,
}

/// Execution plan of one layer.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub name: String,
    pub node: usize,
    pub tiles: Vec<TileExec>,
    /// MACs of the layer (for per-layer MAC/cycle reporting).
    pub macs: u64,
    /// The dotp element width for the energy model.
    pub dotp_bits: u8,
    /// Autotuned per-layer kernel lowering + core count (see
    /// [`crate::dory::autotune`]); `None` = deployment defaults.
    pub exec: Option<ExecOverride>,
}

/// L1 double-buffer allocator: lays out the per-layer tile buffers.
/// Returns base offsets inside TCDM for (in[2], w[2], out[2], quant, scratch).
pub struct L1Layout {
    pub in_buf: [u32; 2],
    pub w_buf: [u32; 2],
    pub out_buf: [u32; 2],
    pub quant: u32,
    pub scratch: u32,
    pub total: usize,
}

/// Compute the double-buffered layout; panics if over budget (the tiler
/// guarantees it fits).
pub fn l1_layout(
    in_bytes: usize,
    w_bytes: usize,
    out_bytes: usize,
    quant_bytes: usize,
    scratch_bytes: usize,
    budget: usize,
) -> L1Layout {
    let base = crate::sim::TCDM_BASE;
    let mut cur = 0usize;
    let mut alloc = |sz: usize| {
        let at = cur;
        cur = (cur + sz).next_multiple_of(8);
        base + at as u32
    };
    let l = L1Layout {
        in_buf: [alloc(in_bytes), alloc(in_bytes)],
        w_buf: [alloc(w_bytes), alloc(w_bytes)],
        out_buf: [alloc(out_bytes), alloc(out_bytes)],
        quant: alloc(quant_bytes),
        scratch: alloc(scratch_bytes),
        total: 0,
    };
    assert!(cur <= budget, "L1 layout {cur} exceeds budget {budget}");
    L1Layout { total: cur, ..l }
}

/// Helper: a 1-D L2→L1 load.
pub fn load(l2: u32, l1: u32, bytes: usize) -> DmaRequest {
    DmaRequest::linear(DmaDir::L2ToTcdm, l2, l1, bytes as u32)
}

/// Helper: a 1-D L1→L2 store.
pub fn store(l1: u32, l2: u32, bytes: usize) -> DmaRequest {
    DmaRequest::linear(DmaDir::TcdmToL2, l2, l1, bytes as u32)
}

/// Tile descriptor for a row-strip × channel-tile of a convolution.
#[derive(Clone, Copy, Debug)]
pub struct ConvTile {
    /// First output row and row count of this tile.
    pub r0: usize,
    pub rows: usize,
    /// First output channel and channel count.
    pub c0: usize,
    pub chs: usize,
    /// Input rows [in_r0, in_r0+in_rows) needed from L2.
    pub in_r0: usize,
    pub in_rows: usize,
    /// Vertical padding seen by this tile.
    pub pad_t: usize,
    pub pad_b: usize,
}

/// Enumerate the tiles of a (out_h, cout) layer for a tile shape.
pub fn conv_tiles(
    oh: usize,
    cout: usize,
    shape: TileShape,
    h: usize,
    kh: usize,
    stride: usize,
    pad: usize,
) -> Vec<ConvTile> {
    let mut tiles = vec![];
    let mut r0 = 0;
    while r0 < oh {
        let rows = shape.rows.min(oh - r0);
        let top = r0 * stride;
        let in_r0 = top.saturating_sub(pad);
        let pad_t = pad.saturating_sub(top);
        let need_bot = (r0 + rows - 1) * stride + kh; // exclusive, padded coords
        let in_end = (need_bot.saturating_sub(pad)).min(h);
        let pad_b = need_bot.saturating_sub(pad).saturating_sub(h);
        let mut c0 = 0;
        while c0 < cout {
            let chs = shape.chs.min(cout - c0);
            tiles.push(ConvTile {
                r0,
                rows,
                c0,
                chs,
                in_r0,
                in_rows: in_end - in_r0,
                pad_t,
                pad_b,
            });
            c0 += chs;
        }
        r0 += rows;
    }
    tiles
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_tiles_cover_layer_exactly() {
        // 16x16 output, 64 channels, 3x3/s1/p1 conv on 16 input rows
        let tiles = conv_tiles(16, 64, TileShape { rows: 5, chs: 16 }, 16, 3, 1, 1);
        let mut covered = vec![vec![false; 64]; 16];
        for t in &tiles {
            for r in t.r0..t.r0 + t.rows {
                for c in t.c0..t.c0 + t.chs {
                    assert!(!covered[r][c], "tile overlap at ({r},{c})");
                    covered[r][c] = true;
                }
            }
            // input rows must cover the receptive field
            assert!(t.in_r0 + t.in_rows <= 16);
            assert_eq!(t.in_rows + t.pad_t + t.pad_b, (t.rows - 1) + 3);
        }
        assert!(covered.iter().all(|r| r.iter().all(|&c| c)));
    }

    #[test]
    fn conv_tiles_strided_padding() {
        // 8x8 in, 3x3/s2/p1 -> 4x4 out, strips of 2 rows
        let tiles = conv_tiles(4, 4, TileShape { rows: 2, chs: 4 }, 8, 3, 2, 1);
        assert_eq!(tiles.len(), 2);
        assert_eq!((tiles[0].pad_t, tiles[0].pad_b), (1, 0));
        assert_eq!((tiles[1].pad_t, tiles[1].pad_b), (0, 0));
        // strip 2: rows 2..4 -> input rows 3..8
        assert_eq!(tiles[1].in_r0, 3);
        assert_eq!(tiles[1].in_rows, 5);
    }

    #[test]
    fn l1_layout_fits_and_aligns() {
        let l = l1_layout(1000, 2000, 500, 64, 4096, 110 * 1024);
        assert_eq!(l.in_buf[0] % 8, 0);
        assert!(l.total <= 110 * 1024);
        assert!(l.w_buf[0] > l.in_buf[1]);
        assert!(l.scratch > l.quant);
    }

    #[test]
    #[should_panic(expected = "exceeds budget")]
    fn l1_layout_rejects_over_budget() {
        l1_layout(60 * 1024, 10 * 1024, 10 * 1024, 64, 0, 110 * 1024);
    }

    #[test]
    fn plan_key_is_stable_and_discriminating() {
        let mut rng = crate::util::Prng::new(5);
        let mut net = Network::new("k", [10, 10, 8], 8);
        net.push(crate::qnn::Layer::conv("c", [10, 10, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        let base = PlanKey::for_network(&net, IsaVariant::FlexV, MemBudget::default(), 8);
        // deterministic
        assert_eq!(base, PlanKey::for_network(&net, IsaVariant::FlexV, MemBudget::default(), 8));
        // target ISA, budget (tiling parameters) and core count all key
        assert_ne!(base, PlanKey::for_network(&net, IsaVariant::Ri5cy, MemBudget::default(), 8));
        let small = MemBudget { l1: 40 * 1024, l2: crate::L2_BYTES };
        assert_ne!(base, PlanKey::for_network(&net, IsaVariant::FlexV, small, 8));
        assert_ne!(base, PlanKey::for_network(&net, IsaVariant::FlexV, MemBudget::default(), 4));
        // precision config keys
        let mut net2 = net.clone();
        net2.nodes[0].layer.w_bits = 8;
        assert_ne!(base, PlanKey::for_network(&net2, IsaVariant::FlexV, MemBudget::default(), 8));
    }
}
