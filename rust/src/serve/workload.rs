//! Trace-driven workload engine: deterministic open-loop arrival
//! processes with SLO classes.
//!
//! The paper evaluates end-to-end QNNs one inference at a time
//! (Table IV); a serving fleet instead faces an **arrival process** —
//! requests show up on their own clock whether or not the fleet keeps
//! up (open-loop). This module generates such traces purely from a
//! seeded [`Prng`] over **simulated cycles** (no wall clock anywhere),
//! so a trace is a deterministic function of its [`WorkloadSpec`] and
//! every downstream number stays bit-reproducible.
//!
//! Four arrival shapes cover the standard serving regimes:
//!
//! - [`TraceShape::Steady`] — constant inter-arrival gap; the
//!   closed-form baseline (utilization = offered load).
//! - [`TraceShape::Poisson`] — exponential inter-arrival gaps (memoryless
//!   traffic, the M/G/k textbook case); tail latency comes from random
//!   clumping.
//! - [`TraceShape::Bursty`] — on/off traffic: tight bursts separated by
//!   long silences at the same average rate; the adversarial case for a
//!   fixed fleet and the reason the autoscaler exists.
//! - [`TraceShape::Diurnal`] — the inter-arrival gap ramps 1.75× →
//!   0.25× → 1.75× of the mean (instantaneous rate peaks at 4× the
//!   mean mid-trace, exactly load-matched on average — a day of
//!   traffic compressed into one trace); exercises slow scale-up/down
//!   rather than burst response.
//!
//! Every request draws a model from the per-model `mix` weights and an
//! [`SloClass`] from the per-class `share` weights; the class assigns
//! the request's priority and (optionally) a relative deadline, which
//! the queue turns into EDF ordering and the engine into
//! shed-before-simulate load shedding (see [`crate::serve::queue`]).

use crate::qnn::QTensor;
use crate::util::Prng;

use super::TraceItem;

/// Arrival-process shape of a generated trace.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TraceShape {
    /// Constant inter-arrival gap.
    Steady,
    /// Exponential (memoryless) inter-arrival gaps.
    Poisson,
    /// On/off: bursts of `burst_len` back-to-back requests, then silence.
    Bursty,
    /// Gap ramps 1.75× → 0.25× → 1.75× of the mean (rate peaks at 4×
    /// mid-trace; mean offered load matches the other shapes exactly).
    Diurnal,
}

impl TraceShape {
    pub const ALL: [TraceShape; 4] =
        [TraceShape::Steady, TraceShape::Poisson, TraceShape::Bursty, TraceShape::Diurnal];

    pub fn name(&self) -> &'static str {
        match self {
            TraceShape::Steady => "steady",
            TraceShape::Poisson => "poisson",
            TraceShape::Bursty => "bursty",
            TraceShape::Diurnal => "diurnal",
        }
    }

    /// Parse a CLI name (`serve-bench --trace <name>`).
    pub fn from_name(s: &str) -> Option<TraceShape> {
        TraceShape::ALL.iter().copied().find(|t| t.name() == s)
    }
}

impl std::fmt::Display for TraceShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One service class: a share of the traffic with a priority and an
/// optional relative deadline (its SLO).
#[derive(Clone, Debug)]
pub struct SloClass {
    pub name: String,
    /// Queue priority (higher wins).
    pub priority: u8,
    /// Relative deadline in cycles from arrival; `None` = best-effort.
    pub deadline_cycles: Option<u64>,
    /// Non-negative mix weight of this class in the trace.
    pub share: f64,
}

impl SloClass {
    /// The single default class: best-effort, priority 0.
    pub fn best_effort() -> Vec<SloClass> {
        vec![SloClass {
            name: "default".into(),
            priority: 0,
            deadline_cycles: None,
            share: 1.0,
        }]
    }

    /// A standard three-tier SLO mix around a base deadline:
    /// `interactive` (20%, priority 2, deadline = base),
    /// `standard` (50%, priority 1, deadline = 4× base),
    /// `batch` (30%, priority 0, best-effort).
    pub fn standard_tiers(base_deadline_cycles: u64) -> Vec<SloClass> {
        vec![
            SloClass {
                name: "interactive".into(),
                priority: 2,
                deadline_cycles: Some(base_deadline_cycles),
                share: 0.2,
            },
            SloClass {
                name: "standard".into(),
                priority: 1,
                deadline_cycles: Some(base_deadline_cycles.saturating_mul(4)),
                share: 0.5,
            },
            SloClass { name: "batch".into(), priority: 0, deadline_cycles: None, share: 0.3 },
        ]
    }
}

/// Everything that determines a generated trace. Two specs with equal
/// fields produce bit-identical traces.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub shape: TraceShape,
    /// Number of requests in the trace.
    pub requests: usize,
    /// Mean inter-arrival gap in simulated cycles (the offered load is
    /// one request per `mean_gap` cycles for every shape).
    pub mean_gap: u64,
    /// Per-model mix weights (one non-negative weight per registered
    /// model; at least one positive).
    pub mix: Vec<f64>,
    /// Service classes with their traffic shares (at least one).
    pub classes: Vec<SloClass>,
    /// Requests per burst (only [`TraceShape::Bursty`]).
    pub burst_len: usize,
    pub seed: u64,
}

impl WorkloadSpec {
    /// A single-class best-effort spec over `models` equal-weighted
    /// models (the pre-SLO engine behavior).
    pub fn new(shape: TraceShape, requests: usize, mean_gap: u64, models: usize) -> Self {
        WorkloadSpec {
            shape,
            requests,
            mean_gap: mean_gap.max(1),
            mix: vec![1.0; models],
            classes: SloClass::best_effort(),
            burst_len: 8,
            seed: 0x70AD,
        }
    }
}

/// Draw an index from non-negative `weights` (at least one positive).
/// Shared with [`crate::serve::Engine::synthetic_trace`] so the two
/// generators cannot drift on edge behavior.
pub(crate) fn weighted_pick(rng: &mut Prng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    debug_assert!(total > 0.0, "weights must have positive mass");
    let mut pick = rng.next_u64() as f64 / u64::MAX as f64 * total;
    let mut idx = 0;
    for (i, w) in weights.iter().enumerate() {
        idx = i;
        if pick < *w {
            break;
        }
        pick -= w;
    }
    idx
}

/// Exponential gap with the given mean (inverse-CDF over a uniform
/// draw; clamped to ≥ 1 cycle).
fn exp_gap(rng: &mut Prng, mean: u64) -> u64 {
    // 53 uniform bits in (0, 1]: never ln(0).
    let u = ((rng.next_u64() >> 11) + 1) as f64 / (1u64 << 53) as f64;
    (-(mean as f64) * u.ln()).round().max(1.0) as u64
}

/// Generate the arrival trace for `spec`. `model_io[m]` is the input
/// `(shape, bits)` of registered model `m` (the engine passes its
/// registry; see [`crate::serve::Engine::workload_trace`]). Arrival
/// times are non-decreasing by construction.
pub fn generate(spec: &WorkloadSpec, model_io: &[(Vec<usize>, u8)]) -> Vec<TraceItem> {
    assert_eq!(spec.mix.len(), model_io.len(), "one mix weight per model");
    assert!(!spec.classes.is_empty(), "need at least one SLO class");
    let mut rng = Prng::new(spec.seed);
    let class_shares: Vec<f64> = spec.classes.iter().map(|c| c.share).collect();
    let mean = spec.mean_gap.max(1);
    let burst = spec.burst_len.max(1);
    let mut at = 0u64;
    let mut out = Vec::with_capacity(spec.requests);
    for i in 0..spec.requests {
        // Advance the arrival clock per the shape (skip before the first
        // request so every shape starts at cycle 0).
        if i > 0 {
            at += match spec.shape {
                TraceShape::Steady => mean,
                TraceShape::Poisson => exp_gap(&mut rng, mean),
                TraceShape::Bursty => {
                    if i % burst == 0 {
                        // silence between bursts: the burst's share of the
                        // mean load, minus what the tight gaps consumed
                        let tight = mean / 10;
                        mean * burst as u64 - tight * (burst as u64 - 1)
                    } else {
                        mean / 10 // tight intra-burst gap
                    }
                }
                TraceShape::Diurnal => {
                    // gap factor ramps 1.75 → 0.25 → 1.75 (triangle):
                    // the rate peaks at 4× mid-trace while the average
                    // gap factor is exactly 1.75 - 1.5·E[tri] = 1, so
                    // the mean offered load matches the other shapes.
                    let n = spec.requests.max(2) as f64;
                    let tri = 1.0 - ((2.0 * i as f64 / (n - 1.0)) - 1.0).abs();
                    let g = 1.75 - 1.5 * tri;
                    ((mean as f64 * g).round() as u64).max(1)
                }
            };
        }
        let model = weighted_pick(&mut rng, &spec.mix);
        let class = weighted_pick(&mut rng, &class_shares);
        let c = &spec.classes[class];
        let (shape, bits) = &model_io[model];
        out.push(TraceItem {
            at,
            model,
            class: class as u8,
            priority: c.priority,
            deadline: c.deadline_cycles.map(|d| at + d),
            input: QTensor::random(shape, *bits, false, &mut rng),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io() -> Vec<(Vec<usize>, u8)> {
        vec![(vec![8, 8, 8], 8), (vec![4, 4, 8], 8)]
    }

    fn spec(shape: TraceShape) -> WorkloadSpec {
        WorkloadSpec {
            shape,
            requests: 64,
            mean_gap: 1000,
            mix: vec![0.7, 0.3],
            classes: SloClass::standard_tiers(5_000),
            burst_len: 8,
            seed: 7,
        }
    }

    #[test]
    fn every_shape_generates_a_well_formed_trace() {
        for shape in TraceShape::ALL {
            let s = spec(shape);
            let trace = generate(&s, &io());
            assert_eq!(trace.len(), 64, "{shape}");
            // arrivals non-decreasing, models/classes in range,
            // deadlines after arrival
            for w in trace.windows(2) {
                assert!(w[0].at <= w[1].at, "{shape}: arrivals must be sorted");
            }
            for t in &trace {
                assert!(t.model < 2);
                assert!((t.class as usize) < s.classes.len());
                if let Some(d) = t.deadline {
                    assert!(d > t.at, "{shape}: deadline before arrival");
                }
                let c = &s.classes[t.class as usize];
                assert_eq!(t.priority, c.priority);
                assert_eq!(t.deadline, c.deadline_cycles.map(|d| t.at + d));
            }
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let s = spec(TraceShape::Poisson);
        let (a, b) = (generate(&s, &io()), generate(&s, &io()));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.model, y.model);
            assert_eq!(x.class, y.class);
            assert_eq!(x.deadline, y.deadline);
            assert_eq!(x.input.data, y.input.data);
        }
        let mut s2 = spec(TraceShape::Poisson);
        s2.seed ^= 1;
        let c = generate(&s2, &io());
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at != y.at || x.input.data != y.input.data),
            "different seeds must differ"
        );
    }

    #[test]
    fn mean_offered_load_is_matched_across_shapes() {
        // All shapes target one request per mean_gap cycles; bursty and
        // diurnal redistribute load in time without changing the mean
        // (the band covers Poisson sampling noise at 256 draws).
        for shape in TraceShape::ALL {
            let mut s = spec(shape);
            s.requests = 256;
            let trace = generate(&s, &io());
            let span = trace.last().unwrap().at - trace[0].at;
            let mean = span as f64 / (s.requests - 1) as f64;
            assert!(
                mean > 0.75 * s.mean_gap as f64 && mean < 1.35 * s.mean_gap as f64,
                "{shape}: mean gap {mean} vs target {}",
                s.mean_gap
            );
        }
    }

    #[test]
    fn bursty_alternates_tight_and_long_gaps() {
        let s = spec(TraceShape::Bursty);
        let trace = generate(&s, &io());
        let gaps: Vec<u64> = trace.windows(2).map(|w| w[1].at - w[0].at).collect();
        let tight = gaps.iter().filter(|&&g| g <= s.mean_gap / 10).count();
        let long = gaps.iter().filter(|&&g| g >= s.mean_gap).count();
        assert!(tight >= gaps.len() / 2, "most gaps are intra-burst ({tight}/{})", gaps.len());
        assert_eq!(long, 64 / 8 - 1, "one silence per burst boundary");
    }

    #[test]
    fn shape_names_roundtrip() {
        for shape in TraceShape::ALL {
            assert_eq!(TraceShape::from_name(shape.name()), Some(shape));
        }
        assert_eq!(TraceShape::from_name("nope"), None);
    }
}
