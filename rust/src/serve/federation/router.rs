//! Deterministic request routing across federation regions.
//!
//! Three policies, all pure functions of simulated state (the arrival
//! counter, queue depths, busy shards, fault/drain eligibility) — never
//! of the host, worker count, or fast-path setting, so the routing
//! decision stream is part of the federation determinism contract:
//!
//! - [`RouterPolicy::ConsistentHash`] — a classic virtual-node hash
//!   ring over the arrival counter: each region owns
//!   [`VNODES`] pseudo-random arcs of the 64-bit ring, a request lands
//!   on the first owner clockwise of its hash, and an ineligible
//!   (failed / draining) region only remaps *its own* arcs — the rest
//!   of the fleet keeps its assignments, which is the property that
//!   makes failover cheap.
//! - [`RouterPolicy::LeastLoaded`] — global shortest-queue: route to
//!   the eligible region with the fewest queued + executing requests
//!   (tie-break: lowest region index).
//! - [`RouterPolicy::Locality`] — model affinity: each model has a home
//!   region (`model % regions`, a stand-in for "the region whose L3
//!   already holds the weights"); route home while it is eligible,
//!   fall back to the hash ring otherwise. Maximizes warm model
//!   residency at the cost of load balance.

use super::super::Engine;

/// Virtual nodes per region on the consistent-hash ring: enough that
/// region arcs interleave (removals shed load to *several* survivors,
/// not one neighbour), small enough that ring construction is free.
pub(crate) const VNODES: usize = 16;

/// Region-selection policy (`serve-bench --router POLICY`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    ConsistentHash,
    LeastLoaded,
    Locality,
}

impl RouterPolicy {
    pub fn name(self) -> &'static str {
        match self {
            RouterPolicy::ConsistentHash => "hash",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::Locality => "locality",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "hash" => Some(RouterPolicy::ConsistentHash),
            "least-loaded" => Some(RouterPolicy::LeastLoaded),
            "locality" => Some(RouterPolicy::Locality),
            _ => None,
        }
    }

    pub const ALL: [RouterPolicy; 3] =
        [RouterPolicy::ConsistentHash, RouterPolicy::LeastLoaded, RouterPolicy::Locality];
}

/// SplitMix64 — the same finalizer family as [`crate::util::Prng`];
/// good 64-bit avalanche, no state.
pub(crate) fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The consistent-hash ring: `(point, region)` pairs sorted by point.
#[derive(Clone, Debug)]
pub(crate) struct Ring {
    points: Vec<(u64, usize)>,
}

impl Ring {
    pub(crate) fn new(regions: usize) -> Self {
        assert!(regions >= 1, "ring needs at least one region");
        let mut points = Vec::with_capacity(regions * VNODES);
        for r in 0..regions {
            for v in 0..VNODES {
                points.push((splitmix64(((r as u64) << 16) | v as u64), r));
            }
        }
        points.sort_unstable();
        Ring { points }
    }

    /// First eligible owner clockwise of `key`'s hash. Falls back to
    /// the raw owner when nothing is eligible (the caller treats an
    /// all-ineligible fleet as all-eligible before asking).
    pub(crate) fn route(&self, key: u64, eligible: &[bool]) -> usize {
        let h = splitmix64(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        for i in 0..self.points.len() {
            let (_, region) = self.points[(start + i) % self.points.len()];
            if eligible.get(region).copied().unwrap_or(false) {
                return region;
            }
        }
        self.points[start % self.points.len()].1
    }
}

/// Queued + executing requests of one region at `now` — the
/// least-loaded signal.
fn load(engine: &Engine, now: u64) -> usize {
    let busy = engine.shards().iter().filter(|s| s.active && s.busy_until > now).count();
    engine.queue.len() + busy
}

/// Route one arrival. `key` is the federation's arrival counter (stable
/// across runs), `model` the registry index, `eligible` the per-region
/// admission mask (healthy and not draining; at least one `true`).
pub(crate) fn route(
    policy: RouterPolicy,
    ring: &Ring,
    key: u64,
    model: usize,
    engines: &[Engine],
    eligible: &[bool],
    now: u64,
) -> usize {
    debug_assert!(eligible.iter().any(|&e| e), "route needs an eligible region");
    match policy {
        RouterPolicy::ConsistentHash => ring.route(key, eligible),
        RouterPolicy::LeastLoaded => engines
            .iter()
            .enumerate()
            .filter(|(r, _)| eligible.get(*r).copied().unwrap_or(false))
            .min_by_key(|(r, e)| (load(e, now), *r))
            .map(|(r, _)| r)
            .expect("at least one eligible region"),
        RouterPolicy::Locality => {
            let home = model % engines.len();
            if eligible.get(home).copied().unwrap_or(false) {
                home
            } else {
                ring.route(key, eligible)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeConfig;

    #[test]
    fn policy_names_round_trip() {
        for p in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(RouterPolicy::from_name("bogus"), None);
    }

    #[test]
    fn ring_spreads_keys_and_only_remaps_the_removed_region() {
        let ring = Ring::new(3);
        let all = [true, true, true];
        let routes: Vec<usize> = (0..300).map(|k| ring.route(k, &all)).collect();
        for r in 0..3 {
            assert!(routes.iter().any(|&x| x == r), "region {r} never routed");
        }
        // Remove region 1: its keys move, everyone else's stay put.
        let without = [true, false, true];
        let mut moved = 0;
        for (k, &before) in routes.iter().enumerate() {
            let after = ring.route(k as u64, &without);
            if before == 1 {
                assert_ne!(after, 1);
                moved += 1;
            } else {
                assert_eq!(after, before, "key {k} remapped although its region survived");
            }
        }
        assert!(moved > 0, "region 1 owned no keys — VNODES too small");
    }

    #[test]
    fn least_loaded_prefers_empty_regions_and_breaks_ties_low() {
        let cfg = ServeConfig { shards: 1, n_cores: 4, ..ServeConfig::default() };
        let engines = vec![Engine::new(cfg), Engine::new(cfg)];
        let ring = Ring::new(2);
        // Equal (empty) load: tie-break picks region 0.
        assert_eq!(
            route(RouterPolicy::LeastLoaded, &ring, 9, 0, &engines, &[true, true], 0),
            0
        );
        // Region 0 ineligible: routed past it regardless of load.
        assert_eq!(
            route(RouterPolicy::LeastLoaded, &ring, 9, 0, &engines, &[false, true], 0),
            1
        );
    }

    #[test]
    fn locality_routes_home_until_home_is_ineligible() {
        let cfg = ServeConfig { shards: 1, n_cores: 4, ..ServeConfig::default() };
        let engines = vec![Engine::new(cfg), Engine::new(cfg), Engine::new(cfg)];
        let ring = Ring::new(3);
        let all = [true, true, true];
        for model in 0..6 {
            assert_eq!(
                route(RouterPolicy::Locality, &ring, 0, model, &engines, &all, 0),
                model % 3
            );
        }
        // Home (model 1 -> region 1) down: falls back to the hash ring,
        // which never picks an ineligible region.
        let r = route(RouterPolicy::Locality, &ring, 77, 1, &engines, &[true, false, true], 0);
        assert_ne!(r, 1);
    }
}
