//! Instruction-set definitions.
//!
//! The four cores compared in the paper share the RV32IMC + XpulpV2 base
//! (hardware loops, post-increment load/store, 16/8-bit SIMD) and differ in
//! their QNN extensions:
//!
//! | core    | SIMD formats        | mixed-precision | Mac&Load | NN-RF + MLC | max unroll |
//! |---------|---------------------|-----------------|----------|-------------|-----------|
//! | RI5CY   | 16/8-bit            | no (SW unpack)  | no       | no          | 4×2       |
//! | MPIC    | 16/8/4/2, CSR-coded | **yes**         | no       | no          | 4×2       |
//! | XpulpNN | 16/8/4/2 uniform    | no (SW unpack)  | yes (GP) | no          | 4×2       |
//! | Flex-V  | 16/8/4/2, CSR-coded | **yes**         | **yes**  | **yes**     | **4×4**   |
//!
//! Instructions are represented as a semantic IR, not encoded words: the
//! kernel generators ([`crate::kernels`]) emit exactly the instruction
//! *sequences* of the paper's assembly (Fig. 5), and the ISS costs them with
//! RI5CY pipeline rules. *Virtual* SIMD instructions (§III, Fig. 3) carry
//! their CSR-resolved format inline — the resolution a real Flex-V decoder
//! performs from `simd_fmt`/`mix_skip` status bits is static per kernel, so
//! the generator bakes it in; the MLC address generation, which is genuinely
//! stateful, *is* simulated (see [`crate::sim::mlc`]).

pub mod disasm;
pub mod instr;
pub mod parse;
pub mod variant;

pub use instr::{AluOp, Cond, Csr, Instr, MlChannel, MlUpdate, NnSlot, Program, Reg, SimdFmt};
pub use variant::{IsaVariant, UnrollShape};
