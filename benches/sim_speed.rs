//! Bench: simulator throughput (the §Perf L3 metric) — simulated
//! instructions and cycles per wall-second on the Table III workload,
//! in both execution modes:
//!
//! - **cycle-exact**: every window simulated cycle by cycle (the
//!   seed-era baseline);
//! - **steady-state**: the same workload on one persistent cluster with
//!   the fast path enabled, so repetitions replay the memoized window
//!   (`sim::fastpath`) — the regime a serving fleet runs in.
//!
//! Simulated cycle/instruction counts must be identical in both modes
//! (asserted); only wall-clock time may differ. Target: ≥ 5x effective
//! speed-up in steady state.
//!
//! A third row measures the **pipeline-accurate core tier**
//! (`CoreFidelity::Pipeline`, cycle-exact mode): its host-side
//! throughput ratio vs the fast tier is the cost of the refined timing
//! model — a wall-clock analog printed for tracking, never gated.
//! Simulated *instruction* counts must still match the fast tier
//! exactly, and window cycles may only grow (both asserted — the
//! cross-tier contract of `sim::pipeline`).
//!
//! Pass `--artifact FILE` to also persist the `kernels` benchmark
//! artifact (only the deterministic simulated quantities — wall-clock
//! rates never enter an artifact).
//!
//!     cargo bench --bench sim_speed [-- --artifact BENCH_kernels.json]

use flexv::isa::IsaVariant;
use flexv::qnn::Precision;
use flexv::report::workloads::matmul_table3_stats_on;
use flexv::sim::{Cluster, CoreFidelity};
use std::time::Instant;

/// Repeat the Table III a8w8 kernel on `cl` for ~`secs`, returning
/// (reps, wall, instrs, core-cycles, per-rep window cycles).
fn measure(cl: &mut Cluster, secs: f64) -> (u64, f64, u64, u64, u64) {
    let (mut reps, mut instrs, mut core_cycles, mut window) = (0u64, 0u64, 0u64, 0u64);
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < secs {
        let stats = matmul_table3_stats_on(cl, IsaVariant::FlexV, Precision::new(8, 8));
        instrs += stats.total_instrs();
        core_cycles += stats.cycles * stats.cores.len() as u64;
        if window == 0 {
            window = stats.cycles;
        } else {
            assert_eq!(window, stats.cycles, "simulated cycles drifted across reps");
        }
        reps += 1;
    }
    (reps, t0.elapsed().as_secs_f64(), instrs, core_cycles, window)
}

fn main() {
    let mut slow = Cluster::pulp();
    let (reps_s, wall_s, instr_s, cyc_s, window_s) = measure(&mut slow, 3.0);

    let mut fast = Cluster::pulp();
    fast.enable_fastpath();
    // one cold rep records the window, then measure pure steady state
    let cold = matmul_table3_stats_on(&mut fast, IsaVariant::FlexV, Precision::new(8, 8));
    assert_eq!(cold.cycles, window_s, "fast path changed simulated cycles");
    let (reps_f, wall_f, instr_f, cyc_f, window_f) = measure(&mut fast, 3.0);
    assert_eq!(window_f, window_s, "fast path changed simulated cycles");
    let fp = fast.fastpath().unwrap();
    assert!(fp.pure_hits + fp.func_hits >= reps_f, "steady state never replayed: {fp:?}");

    let rate_s = cyc_s as f64 / wall_s / 1e6;
    let rate_f = cyc_f as f64 / wall_f / 1e6;
    println!("Table III a8w8 kernel, {window_s} simulated cycles per rep:");
    println!(
        "  cycle-exact : {reps_s:>6} reps in {wall_s:.2}s  {:>8.1} M instr/s  {rate_s:>8.1} M core-cycles/s",
        instr_s as f64 / wall_s / 1e6
    );
    println!(
        "  steady-state: {reps_f:>6} reps in {wall_f:.2}s  {:>8.1} M instr/s  {rate_f:>8.1} M core-cycles/s",
        instr_f as f64 / wall_f / 1e6
    );
    println!(
        "  fast-path speed-up: {:.1}x effective ({} pure / {} functional replays)",
        rate_f / rate_s.max(1e-9),
        fp.pure_hits,
        fp.func_hits
    );

    // Pipeline-accurate core tier, cycle-exact: same instructions, more
    // simulated cycles, and a host-side throughput analog (not gated).
    let mut pipe = Cluster::pulp();
    pipe.set_fidelity(CoreFidelity::Pipeline);
    let (reps_p, wall_p, instr_p, _cyc_p, window_p) = measure(&mut pipe, 3.0);
    assert!(window_p >= window_s, "pipeline tier sped up the kernel: {window_p} < {window_s}");
    assert_eq!(
        instr_p / reps_p,
        instr_s / reps_s,
        "tiers must retire identical instruction streams"
    );
    let (ips_s, ips_p) = (instr_s as f64 / wall_s, instr_p as f64 / wall_p);
    println!(
        "  pipeline tier: {reps_p:>6} reps in {wall_p:.2}s  {:>8.1} M instr/s  ({window_p} sim cycles/rep, +{} vs fast tier; {:.2}x host cost — analog, not gated)",
        ips_p / 1e6,
        window_p - window_s,
        ips_s / ips_p.max(1e-9),
    );
    println!("  (§Perf target: >= 50 M instr/s cycle-exact; >= 5x steady-state speed-up)");
    flexv::report::bench::write_artifact_from_args(
        "kernels",
        &flexv::report::bench::BenchOptions::default(),
    );
}
