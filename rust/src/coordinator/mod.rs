//! The end-to-end inference coordinator.
//!
//! Executes a [`Deployment`] on the simulated cluster: preloads weights
//! into L2, then replays every layer's tile sequence with DORY's
//! double-buffering discipline — while the cores compute tile *i*, the DMA
//! streams tile *i+1*'s inputs in and tile *i−1*'s outputs out (§IV: "the
//! calls to the kernels are always overlapped with the asynchronous DMA
//! calls"). Per-layer cycle/energy metrics are collected for Table IV.
//!
//! The building blocks are exposed as free, `Cluster`-parameterized
//! functions ([`preload_deployment`], [`execute_deployment`]) so other
//! drivers — notably the [`crate::serve`] fleet engine, which owns many
//! clusters — can reuse the exact same execution path; [`Coordinator`]
//! is the one-cluster convenience wrapper around them.

use std::collections::HashMap;

use crate::dory::deploy::Deployment;
use crate::dory::{KernelCall, LayerPlan, PlanKey, TileExec};
use crate::isa::{IsaVariant, Program};
use crate::kernels::conv::gen_conv;
use crate::kernels::layers::{gen_add, gen_avgpool, gen_concat, gen_dwconv, gen_linear, gen_maxpool};
use crate::power::{EnergyModel, OperatingPoint};
use crate::qnn::QTensor;
use crate::sim::{Cluster, ClusterStats};

/// Per-layer execution metrics.
#[derive(Clone, Debug)]
pub struct LayerMetrics {
    pub name: String,
    pub macs: u64,
    pub stats: ClusterStats,
    pub dotp_bits: u8,
}

impl LayerMetrics {
    pub fn macs_per_cycle(&self) -> f64 {
        if self.stats.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.stats.cycles as f64
        }
    }
}

/// Result of one end-to-end inference.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub layers: Vec<LayerMetrics>,
    /// Raw packed bytes of the final node's output tensor.
    pub output: Vec<u8>,
    /// All node outputs (for layer-by-layer validation).
    pub node_outputs: Vec<Vec<u8>>,
}

impl RunResult {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.stats.cycles).sum()
    }
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
    /// The paper's Table IV metric.
    pub fn macs_per_cycle(&self) -> f64 {
        self.total_macs() as f64 / self.total_cycles().max(1) as f64
    }
    /// Per-layer cycle counts, in plan order (the serve determinism test
    /// compares these across execution paths).
    pub fn layer_cycles(&self) -> Vec<u64> {
        self.layers.iter().map(|l| l.stats.cycles).collect()
    }
    /// Total energy of the inference [pJ], per-layer activity × the
    /// calibrated per-class energies (each layer billed at its dotp
    /// element width).
    pub fn energy_pj(&self, isa: IsaVariant, em: &EnergyModel) -> f64 {
        self.layers
            .iter()
            .map(|l| em.energy_pj(isa, &l.stats, l.dotp_bits))
            .sum()
    }
    /// [`RunResult::energy_pj`] billed at an explicit voltage/frequency
    /// operating point (see [`EnergyModel::energy_pj_at`]); the serving
    /// shard uses this to price DVFS'd batches.
    pub fn energy_pj_at(&self, isa: IsaVariant, em: &EnergyModel, op: &OperatingPoint) -> f64 {
        self.layers
            .iter()
            .map(|l| em.energy_pj_at(isa, &l.stats, l.dotp_bits, op))
            .sum()
    }
}

/// Generate the per-core programs of one kernel call.
pub fn programs_for(isa: IsaVariant, call: &KernelCall, n_cores: usize) -> Vec<Program> {
    match call {
        KernelCall::Conv(t) => (0..n_cores).map(|c| gen_conv(isa, t, c, n_cores)).collect(),
        KernelCall::Dw(t) => (0..n_cores).map(|c| gen_dwconv(isa, t, c, n_cores)).collect(),
        KernelCall::Linear { prec, cin, cout, in_base, w_base, w_pitch, out_base, quant } => {
            (0..n_cores)
                .map(|c| {
                    gen_linear(
                        isa, *prec, *cin, *cout, *in_base, *w_base, *w_pitch, *out_base,
                        *quant, c, n_cores,
                    )
                })
                .collect()
        }
        KernelCall::Add(t) => (0..n_cores).map(|c| gen_add(t, c, n_cores)).collect(),
        KernelCall::Concat(t) => (0..n_cores).map(|c| gen_concat(t, c, n_cores)).collect(),
        KernelCall::AvgPool(t) => (0..n_cores).map(|c| gen_avgpool(t, c, n_cores)).collect(),
        KernelCall::MaxPool(t) => (0..n_cores).map(|c| gen_maxpool(t, c, n_cores)).collect(),
    }
}

/// Memoized per-tile timing (see [`run_layer_memoized`]).
#[derive(Clone)]
struct TileCost {
    kernel: ClusterStats,
    load_cycles: u64,
    store_cycles: u64,
}

/// Cross-layer (and, in the serve engine, cross-request) memo of tile
/// timings for timing-only execution, keyed by [`PlanKey::for_tile`].
/// ResNet's repeated blocks share tile structures across layers; repeated
/// requests for the same model share all of them.
#[derive(Default)]
pub struct TileMemo {
    map: HashMap<PlanKey, TileCost>,
}

impl TileMemo {
    pub fn new() -> Self {
        TileMemo::default()
    }
    /// Number of distinct tile structures measured so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Write a deployment's L2 image (weights, quant parameters) into the
/// cluster memory. Not timed — it models the flash/L3 image already
/// resident in L2; the serving layer charges an explicit model-switch
/// cost instead (see `serve::shard`).
pub fn preload_deployment(cluster: &mut Cluster, dep: &Deployment) {
    for (addr, bytes) in &dep.preload {
        cluster.mem.write_bytes(*addr, bytes);
    }
}

/// Run one inference of `dep` on `cluster`. `input` must match the
/// deployed network's input shape/bits. The deployment's L2 image must
/// already be resident (see [`preload_deployment`]).
///
/// With `memo: Some(..)`, layers run in **timing-only** mode: structurally
/// identical tiles are simulated once and their (data-independent) timing
/// replayed — node outputs are then only valid for the measured
/// representatives. Pass `None` for full functional execution.
pub fn execute_deployment(
    cluster: &mut Cluster,
    dep: &Deployment,
    input: &QTensor,
    mut memo: Option<&mut TileMemo>,
) -> RunResult {
    cluster.mem.write_bytes(dep.input_addr, &input.data);
    let n_cores = cluster.cores.len();
    let mut layers = Vec::with_capacity(dep.plans.len());
    for plan in &dep.plans {
        // Autotuned plans carry a per-layer kernel lowering + core
        // count; cores beyond the override stay halted for the layer.
        let (isa, nc) = plan
            .exec
            .map_or((dep.isa, n_cores), |e| (e.isa, e.n_cores.min(n_cores)));
        let layer_start = cluster.cycle;
        let stats = match memo.as_mut() {
            Some(m) => run_layer_memoized(cluster, isa, plan, nc, &mut **m),
            None => run_layer_full(cluster, isa, plan, nc),
        };
        if cluster.tracer.is_some() {
            trace_layer_span(cluster, plan, isa, nc, layer_start, &stats);
        }
        layers.push(LayerMetrics {
            name: plan.name.clone(),
            macs: plan.macs,
            stats,
            dotp_bits: plan.dotp_bits,
        });
    }
    let node_outputs: Vec<Vec<u8>> = dep
        .node_out
        .iter()
        .enumerate()
        .map(|(i, &addr)| {
            let bytes = dep_plan_out_bytes(dep, i);
            cluster.mem.read_bytes(addr, bytes)
        })
        .collect();
    RunResult {
        output: node_outputs.last().cloned().unwrap_or_default(),
        node_outputs,
        layers,
    }
}

/// Execute one layer's tiles with double buffering; returns the layer's
/// cycle window.
fn run_layer_full(
    cluster: &mut Cluster,
    isa: IsaVariant,
    plan: &LayerPlan,
    n_cores: usize,
) -> ClusterStats {
    let mut total = ClusterStats::default();
    let tiles = &plan.tiles;
    if tiles.is_empty() {
        return total;
    }
    // Prologue: stream tile 0's inputs.
    for req in &tiles[0].loads {
        cluster.dma.push(*req);
    }
    total.extend_serial(&cluster.run());
    for i in 0..tiles.len() {
        // Launch kernel i; prefetch tile i+1 while it runs.
        let progs = programs_for(isa, &tiles[i].kernel, n_cores);
        cluster.load_programs(progs);
        if i + 1 < tiles.len() {
            for req in &tiles[i + 1].loads {
                cluster.dma.push(*req);
            }
        }
        let w = cluster.run();
        total.extend_serial(&w);
        // Stream out tile i's results (overlaps with kernel i+1).
        for req in &tiles[i].stores {
            cluster.dma.push(*req);
        }
    }
    // Drain the last stores.
    total.extend_serial(&cluster.run());
    total
}

/// Timing-only layer execution with **tile memoization** (DESIGN.md §7):
/// structurally identical tiles (same per-core instruction streams, same
/// DMA descriptors modulo the double-buffer parity that the key includes
/// via the L1 addresses) have identical, data-independent cycle counts —
/// kernels contain no data-dependent control flow. Each distinct structure
/// is simulated cycle-accurately once; repeats replay its timing. The
/// layer window is reconstructed with DORY's double-buffer pipeline model:
///
/// `cycles = load_0 + Σ_i max(kernel_i, load_{i+1} + store_{i-1}) + store_last`
///
/// NOTE: repeated tiles are *not* functionally executed, so node outputs
/// are only valid for the measured representatives — use the
/// non-memoized path for numerical validation. The equivalence of the
/// reconstructed timing is asserted (<3%) by `memoized_timing_matches_full`
/// below.
///
/// Public because it is also the autotuner's measurement primitive
/// ([`crate::dory::autotune`]): candidate layer plans are costed with
/// exactly the metric the memoized executor will later reproduce, and a
/// shared [`TileMemo`] makes structurally identical candidates cost
/// identically (so selection ties are exact, not noisy).
pub fn run_layer_memoized(
    cluster: &mut Cluster,
    isa: IsaVariant,
    plan: &LayerPlan,
    n_cores: usize,
    memo: &mut TileMemo,
) -> ClusterStats {
    let mut costs: Vec<TileCost> = Vec::with_capacity(plan.tiles.len());
    for tile in &plan.tiles {
        let key = tile_key(isa, tile, n_cores);
        let cost = if let Some(c) = memo.map.get(&key) {
            c.clone()
        } else {
            let progs = programs_for(isa, &tile.kernel, n_cores);
            // Measure this structure in isolation (serial phases so the
            // windows are attributable), with real functional effects.
            for req in &tile.loads {
                cluster.dma.push(*req);
            }
            let ld = cluster.run();
            cluster.load_programs(progs);
            let ks = cluster.run();
            for req in &tile.stores {
                cluster.dma.push(*req);
            }
            let st = cluster.run();
            let c = TileCost {
                kernel: ks,
                load_cycles: ld.cycles,
                store_cycles: st.cycles,
            };
            memo.map.insert(key, c.clone());
            c
        };
        costs.push(cost);
    }
    // Pipeline reconstruction.
    let mut total = ClusterStats::default();
    let n = costs.len();
    for (i, c) in costs.iter().enumerate() {
        let incoming = if i + 1 < n { costs[i + 1].load_cycles } else { 0 };
        let outgoing = if i > 0 { costs[i - 1].store_cycles } else { 0 };
        let window = c.kernel.cycles.max(incoming + outgoing);
        total.cycles += window;
        if total.cores.len() < c.kernel.cores.len() {
            total.cores.resize(c.kernel.cores.len(), Default::default());
        }
        // Same discipline as `ClusterStats::extend_serial`: event
        // counters sum, per-core `cycles` stays the longest window.
        for (a, b) in total.cores.iter_mut().zip(&c.kernel.cores) {
            a.merge_parallel(b);
        }
        total.dma_busy_cycles += c.kernel.dma_busy_cycles;
    }
    if let Some(first) = costs.first() {
        total.cycles += first.load_cycles;
    }
    if let Some(last) = costs.last() {
        total.cycles += last.store_cycles;
    }
    total
}

/// Emit the enclosing layer span onto the cluster's trace, covering the
/// window `[start, cluster.cycle]` the layer advanced the clock by.
///
/// In full (non-memoized) execution that window equals the layer's
/// `stats.cycles`, so the layer span exactly encloses the per-window
/// kernel/DMA spans the cluster emitted inside it. Memoized execution
/// advances the clock only for measured representatives (repeated tiles
/// replay timing without running), so profiling/tracing drivers run with
/// memoization off — `run-net --trace-out` and `profile` do.
fn trace_layer_span(
    cluster: &mut Cluster,
    plan: &LayerPlan,
    isa: IsaVariant,
    n_cores: usize,
    start: u64,
    stats: &ClusterStats,
) {
    use crate::trace::{track, Arg, Scope};
    let wall = cluster.cycle - start;
    let dma_overlap = if wall == 0 {
        0.0
    } else {
        stats.dma_busy_cycles.min(wall) as f64 / wall as f64
    };
    let tracer = cluster.tracer.as_mut().expect("caller checked");
    tracer.span(
        Scope::Sim,
        track(0, 0),
        plan.name.clone(),
        start,
        wall,
        vec![
            ("macs", Arg::U64(plan.macs)),
            ("mac_per_cycle", Arg::F64(stats.macs_per_cycle())),
            ("isa", Arg::Str(isa.to_string())),
            ("n_cores", Arg::U64(n_cores as u64)),
            ("dma_busy", Arg::U64(stats.dma_busy_cycles)),
            ("dma_overlap", Arg::F64(dma_overlap)),
        ],
    );
}

/// Structural key of a tile (see [`PlanKey::for_tile`]).
fn tile_key(isa: IsaVariant, tile: &TileExec, n_cores: usize) -> PlanKey {
    PlanKey::for_tile(isa, tile, n_cores)
}

/// Output byte size of node `i` in a deployment (from the plan's stores).
fn dep_plan_out_bytes(dep: &Deployment, node: usize) -> usize {
    dep.plans
        .iter()
        .filter(|p| p.node == node)
        .flat_map(|p| p.tiles.iter())
        .flat_map(|t| t.stores.iter())
        .map(|s| s.total_bytes() as usize)
        .sum()
}

/// The coordinator owns one cluster and drives deployments end-to-end.
pub struct Coordinator {
    pub cluster: Cluster,
    /// Cross-layer memo for timing-only mode (ResNet's repeated blocks
    /// share tile structures across layers).
    memo: TileMemo,
    /// Enable tile memoization: structurally identical tiles within a
    /// layer are simulated once and their (data-independent) timing is
    /// replayed (DESIGN.md §7). Functional outputs are still produced for
    /// every tile.
    pub memoize_tiles: bool,
}

impl Coordinator {
    pub fn new(n_cores: usize) -> Self {
        Coordinator { cluster: Cluster::new(n_cores), memo: TileMemo::new(), memoize_tiles: false }
    }

    /// A coordinator with the steady-state simulation fast path enabled:
    /// repeated windows (identical instruction trace, DMA schedule and
    /// arbiter phase) are replayed from a memo instead of re-simulated.
    /// Outputs **and** cycle counts stay bit-identical to [`Self::new`]
    /// (unlike `memoize_tiles`, which is timing-only); see
    /// [`crate::sim::fastpath`].
    pub fn with_fastpath(n_cores: usize) -> Self {
        let mut c = Self::new(n_cores);
        c.cluster.enable_fastpath();
        c
    }

    /// A coordinator whose cluster runs under core timing tier `f`
    /// (functional results are tier-independent; cycle counts are not —
    /// see [`crate::sim::pipeline`]).
    pub fn with_fidelity(n_cores: usize, f: crate::sim::CoreFidelity) -> Self {
        let mut c = Self::new(n_cores);
        c.cluster.set_fidelity(f);
        c
    }

    /// Run one inference. `input` must match the deployed network's input
    /// shape/bits.
    pub fn run(&mut self, dep: &Deployment, input: &QTensor) -> RunResult {
        preload_deployment(&mut self.cluster, dep);
        let memo = if self.memoize_tiles { Some(&mut self.memo) } else { None };
        execute_deployment(&mut self.cluster, dep, input, memo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dory::deploy::deploy;
    use crate::dory::MemBudget;
    use crate::models::Profile;
    use crate::qnn::golden;
    use crate::qnn::layer::{Layer, Network};
    use crate::util::Prng;

    /// A small two-conv network runs end-to-end and matches golden.
    #[test]
    fn small_chain_bit_exact_all_isas() {
        let mut rng = Prng::new(77);
        let mut net = Network::new("tiny", [10, 10, 8], 8);
        net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net.validate().unwrap();
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let golden_outs = golden::run_network(&net, &input);

        for isa in IsaVariant::ALL {
            let dep = deploy(&net, isa, MemBudget::default());
            let mut coord = Coordinator::new(4);
            let res = coord.run(&dep, &input);
            assert_eq!(
                res.output,
                golden_outs.last().unwrap().data,
                "{isa:?} output mismatch"
            );
            assert!(res.total_cycles() > 0);
            assert!(res.macs_per_cycle() > 0.1, "{isa:?} {}", res.macs_per_cycle());
        }
    }

    /// A layer big enough to force row tiling still matches golden.
    #[test]
    fn tiled_layer_bit_exact() {
        let mut rng = Prng::new(78);
        let mut net = Network::new("tiled", [24, 24, 32], 8);
        net.push(Layer::conv("big", [24, 24, 32], 32, 3, 3, 1, 1, 8, 8, 8, &mut rng));
        // shrink L1 to force tiling
        let budget = MemBudget { l1: 40 * 1024, l2: crate::L2_BYTES };
        let dep = deploy(&net, IsaVariant::FlexV, budget);
        assert!(
            dep.plans[0].tiles.len() > 1,
            "expected multiple tiles, got {}",
            dep.plans[0].tiles.len()
        );
        let input = QTensor::random(&[24, 24, 32], 8, false, &mut rng);
        let golden_outs = golden::run_network(&net, &input);
        let mut coord = Coordinator::new(8);
        let res = coord.run(&dep, &input);
        assert_eq!(res.output, golden_outs.last().unwrap().data);
    }

    /// Memoized (timing-only) execution reproduces the full simulation's
    /// cycle count within 3% (the pipeline-reconstruction error bound).
    #[test]
    fn memoized_timing_matches_full() {
        let net = crate::models::resnet20(Profile::Mixed4a2w, 5);
        let mut rng = Prng::new(80);
        let input = QTensor::random(&[32, 32, 4], 8, false, &mut rng);
        let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let mut full = Coordinator::new(8);
        let rf = full.run(&dep, &input);
        let mut memo = Coordinator::new(8);
        memo.memoize_tiles = true;
        let rm = memo.run(&dep, &input);
        let (a, b) = (rf.total_cycles() as f64, rm.total_cycles() as f64);
        let err = (a - b).abs() / a;
        assert!(err < 0.03, "memoized {b} vs full {a}: {:.1}% error", err * 100.0);
        // MAC counters must agree exactly (same per-tile stats replayed)
        assert_eq!(rf.total_macs(), rm.total_macs());
    }

    /// ResNet-20 4b2b end-to-end on Flex-V matches the golden executor
    /// (residual adds, mixed per-layer precisions, pooling, classifier).
    #[test]
    fn resnet20_e2e_bit_exact_flexv() {
        let net = crate::models::resnet20(Profile::Mixed4a2w, 5);
        let mut rng = Prng::new(79);
        let input = QTensor::random(&[32, 32, 4], 8, false, &mut rng);
        let golden_outs = golden::run_network(&net, &input);
        let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let mut coord = Coordinator::new(8);
        let res = coord.run(&dep, &input);
        assert_eq!(res.output, golden_outs.last().unwrap().data, "ResNet20 output");
        // every intermediate too
        for (i, g) in golden_outs.iter().enumerate() {
            assert_eq!(res.node_outputs[i], g.data, "node {i} ({})", net.nodes[i].layer.name);
        }
    }

    /// The steady-state fast path is bit-exact on a real tiled conv:
    /// outputs and per-layer cycle counts match the plain coordinator,
    /// with every replayed window cross-checked against a full
    /// re-simulation, across repeated runs with fresh inputs.
    #[test]
    fn fastpath_bit_exact_on_tiled_conv_crosschecked() {
        let mut rng = Prng::new(82);
        let mut net = Network::new("fp", [16, 16, 16], 8);
        net.push(Layer::conv("c1", [16, 16, 16], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [16, 16, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net.validate().unwrap();
        // shrink L1 so c1 tiles (multiple structurally identical windows)
        let budget = MemBudget { l1: 24 * 1024, l2: crate::L2_BYTES };
        let dep = deploy(&net, IsaVariant::FlexV, budget);
        let mut plain = Coordinator::new(8);
        let mut fast = Coordinator::with_fastpath(8);
        fast.cluster.set_fastpath_crosscheck(true);
        for seed in [90u64, 91, 90] {
            let mut r = Prng::new(seed);
            let input = QTensor::random(&[16, 16, 16], 8, false, &mut r);
            let golden_out = golden::run_network(&net, &input);
            // Pristine cluster per run (the serve exact-mode discipline);
            // reset keeps the fast-path cache, so runs 2+ replay.
            plain.cluster.reset();
            fast.cluster.reset();
            let a = plain.run(&dep, &input);
            let b = fast.run(&dep, &input);
            assert_eq!(b.output, golden_out.last().unwrap().data, "seed {seed}");
            assert_eq!(a.layer_cycles(), b.layer_cycles(), "seed {seed}");
            assert_eq!(a.total_macs(), b.total_macs());
        }
        let fp = fast.cluster.fastpath().unwrap();
        // run 2 (fresh input) replays timing functionally; run 3 repeats
        // run 1's data exactly and replays pure deltas.
        assert!(fp.func_hits > 0, "no functional replays: {fp:?}");
        assert!(fp.pure_hits > 0, "no pure replays: {fp:?}");
    }

    /// Per-layer exec overrides (autotuner output) stay bit-exact: a
    /// layer lowered to a narrower core count and another lowered to a
    /// simpler ISA still reproduce the golden outputs, in both full and
    /// memoized execution.
    #[test]
    fn exec_overrides_stay_bit_exact_across_isa_and_core_count() {
        use crate::dory::autotune::{LayerTuning, NetworkTuning};
        use crate::dory::deploy::deploy_tuned;
        let mut rng = Prng::new(83);
        let mut net = Network::new("ovr", [10, 10, 8], 8);
        net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [10, 10, 16], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net.validate().unwrap();
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let golden_outs = golden::run_network(&net, &input);
        let t = |isa, n_cores| LayerTuning {
            isa,
            n_cores,
            shape: None,
            tuned_cycles: 0,
            default_cycles: 0,
        };
        let tuning = NetworkTuning {
            layers: vec![t(IsaVariant::FlexV, 4), t(IsaVariant::Ri5cy, 8)],
        };
        let dep = deploy_tuned(&net, IsaVariant::FlexV, MemBudget::default(), &tuning);
        let mut coord = Coordinator::new(8);
        let res = coord.run(&dep, &input);
        assert_eq!(res.output, golden_outs.last().unwrap().data, "override output");
        // memoized timing-only mode resolves the same overrides (the
        // per-tile key includes the overridden ISA and core count)
        let mut memo = Coordinator::new(8);
        memo.memoize_tiles = true;
        let rm = memo.run(&dep, &input);
        assert_eq!(rm.total_macs(), res.total_macs());
        assert!(rm.total_cycles() > 0);
    }

    /// The free-function path (preload + execute) is exactly the
    /// Coordinator path — the serve engine relies on this equivalence.
    #[test]
    fn free_functions_match_coordinator() {
        let mut rng = Prng::new(81);
        let mut net = Network::new("ff", [10, 10, 8], 8);
        net.push(Layer::conv("c1", [10, 10, 8], 16, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.validate().unwrap();
        let input = QTensor::random(&[10, 10, 8], 8, false, &mut rng);
        let dep = deploy(&net, IsaVariant::FlexV, MemBudget::default());
        let mut coord = Coordinator::new(8);
        let a = coord.run(&dep, &input);
        let mut cl = Cluster::new(8);
        preload_deployment(&mut cl, &dep);
        let b = execute_deployment(&mut cl, &dep, &input, None);
        assert_eq!(a.output, b.output);
        assert_eq!(a.layer_cycles(), b.layer_cycles());
        assert!(b.energy_pj(IsaVariant::FlexV, &EnergyModel::default()) > 0.0);
    }
}
