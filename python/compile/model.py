"""L2 — JAX model: quantized convolution layers built on the L1 kernel.

Mirrors the PULP-NN three-phase execution model (§II-B of the paper):
im2col -> MatMul (the Pallas kernel) -> requantization. This is the golden
compute graph that gets AOT-lowered to HLO text and executed from the Rust
coordinator via PJRT to cross-validate the simulator's kernels.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.mpq_matmul import mpq_matmul, TM, TN


def im2col(x, kh, kw, stride, pad):
    """HWC im2col: (H, W, C) -> (OH*OW, KH*KW*C), zero padding."""
    h, w, c = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    rows = []
    for oy in range(oh):
        for ox in range(ow):
            patch = jax.lax.dynamic_slice(
                xp, (oy * stride, ox * stride, 0), (kh, kw, c)
            )
            rows.append(patch.reshape(-1))
    return jnp.stack(rows)


@partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "pad", "a_bits", "w_bits", "shift", "out_bits"),
)
def qconv2d(x, w_words, mult, bias, *, kh, kw, stride, pad, a_bits, w_bits, shift, out_bits):
    """Quantized conv: x (H, W, C) int32 activations; w_words packed rows
    (COUT, KW). Returns (OH, OW, COUT) int32."""
    h, w, _c = x.shape
    cout = w_words.shape[0]
    a = im2col(x, kh, kw, stride, pad)  # (M, K)
    m, _k = a.shape
    # pad M/N up to the Pallas tile grid
    m_pad = -(-m // TM) * TM
    a = jnp.pad(a, ((0, m_pad - m), (0, 0)))
    n_pad = -(-cout // TN) * TN
    w_words = jnp.pad(w_words, ((0, n_pad - cout), (0, 0)))
    mult = jnp.pad(mult, (0, n_pad - cout))
    bias = jnp.pad(bias, (0, n_pad - cout))
    out = mpq_matmul(
        a, w_words, mult, bias, a_bits=a_bits, w_bits=w_bits, shift=shift, out_bits=out_bits
    )
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    return out[:m, :cout].reshape(oh, ow, cout)


def matmul_entry(m, n, k, a_bits, w_bits, shift, out_bits):
    """Build the jittable (a, w_words, mult, bias) -> (out,) MatMul entry
    point with static shapes, for AOT lowering. Returns (fn, example_args)."""
    lanes = 32 // w_bits
    kw = -(-k // lanes)

    def fn(a, w_words, mult, bias):
        return (
            mpq_matmul(
                a,
                w_words,
                mult,
                bias,
                a_bits=a_bits,
                w_bits=w_bits,
                shift=shift,
                out_bits=out_bits,
            ),
        )

    args = (
        jax.ShapeDtypeStruct((m, k), jnp.int32),
        jax.ShapeDtypeStruct((n, kw), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    return fn, args
