//! Fleet **federation**: multiple serving-engine regions behind a
//! deterministic router, with seeded fault injection and live rollouts.
//!
//! One [`Engine`] is a region — a pool of cluster shards with its own
//! queue, plan cache and autoscaler. A [`Federation`] stacks several
//! regions behind a [`RouterPolicy`] and drives them from **one
//! sequential event loop** over simulated cycles:
//!
//! 1. apply fault-timeline events due at the clock
//!    ([`FaultPlan::timeline`] → [`Engine::fail_shard`] /
//!    [`Engine::recover_shard`] / [`Engine::slow_shard`] /
//!    [`Engine::throttle_shard`]);
//! 2. step the rollout controller ([`rollout`]): start draining the
//!    canary at its cycle, switch it to warm tuned caches the moment it
//!    is idle;
//! 3. admit due arrivals, each routed by the policy over the current
//!    eligibility mask (healthy, not draining);
//! 4. pump every region ([`Engine::pump`]: shed → autoscale →
//!    dispatch);
//! 5. jump the clock to the next arrival, fault event, region wake, or
//!    drain-complete cycle — O(events), independent of idle gaps.
//!
//! # Determinism, one layer up
//!
//! Every input to a routing, fault, or rollout decision is simulated
//! state produced by the sequential loop (queue depths, busy-until
//! cycles, the arrival counter, the fault plan) — never host state. The
//! engines' own determinism contract (completion streams bit-identical
//! across `workers` × `fastpath`) therefore lifts to the whole
//! federation: per-region completions, [`FederationMetrics`] (render
//! and rows), and the exported trace are byte-identical across those
//! settings at a fixed seed and fault plan
//! (`rust/tests/federation_determinism.rs`, CI `federation` job).

pub mod fault;
pub mod rollout;
pub mod router;

pub use fault::{FaultAction, FaultEvent, FaultKind, FaultPlan, FaultRecord};
pub use rollout::{RolloutPlan, RolloutReport};
pub use router::RouterPolicy;

use rollout::RolloutPhase;

use super::workload::{self, SloClass, WorkloadSpec};
use super::{Engine, FleetMetrics, ServeConfig, TraceItem};
use crate::qnn::layer::Network;
use crate::report::artifact::{MetricRow, MetricSource};

/// Federation-level configuration: identical regions behind one router.
#[derive(Clone, Debug)]
pub struct FederationConfig {
    /// Number of regions (each one [`Engine`] built from `engine`).
    pub regions: usize,
    /// Per-region engine configuration.
    /// [`ServeConfig::track_inflight`] is forced on — failover needs
    /// the retraction pool.
    pub engine: ServeConfig,
    pub policy: RouterPolicy,
    /// Deterministic fault schedule (empty = healthy run).
    pub faults: FaultPlan,
    /// Optional live rollout (canary drain → warm switch).
    pub rollout: Option<RolloutPlan>,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            regions: 2,
            engine: ServeConfig::default(),
            policy: RouterPolicy::ConsistentHash,
            faults: FaultPlan::none(),
            rollout: None,
        }
    }
}

/// The federated fleet: regions + router + fault timeline + rollout
/// controller, advanced by one sequential discrete-event loop.
pub struct Federation {
    cfg: FederationConfig,
    regions: Vec<Engine>,
    ring: router::Ring,
    /// Applied-event schedule from the fault plan, cycle-ordered.
    timeline: Vec<FaultRecord>,
    next_event: usize,
    /// Events applied so far (the run's fault fingerprint).
    fault_log: Vec<FaultRecord>,
    failovers: u64,
    straggler_windows: u64,
    throttle_windows: u64,
    /// Global arrival counter — the router's hash key, so routing is
    /// independent of per-region request ids.
    arrivals: u64,
    /// Arrivals handed to each region (admitted or rejected there).
    routed: Vec<u64>,
    phase: RolloutPhase,
    rollout_models: usize,
    drain_started: u64,
}

impl Federation {
    pub fn new(cfg: FederationConfig) -> Self {
        assert!(cfg.regions >= 1, "need at least one region");
        if let Some(p) = cfg.rollout {
            assert!(p.canary < cfg.regions, "rollout canary {} out of range", p.canary);
        }
        let engine_cfg = ServeConfig { track_inflight: true, ..cfg.engine };
        let regions: Vec<Engine> = (0..cfg.regions).map(|_| Engine::new(engine_cfg)).collect();
        let timeline = cfg.faults.timeline();
        for r in &timeline {
            assert!(
                r.region < cfg.regions && r.shard < cfg.engine.shards,
                "fault at cycle {} targets r{}.s{} but the fleet is {} regions x {} shards",
                r.at,
                r.region,
                r.shard,
                cfg.regions,
                cfg.engine.shards,
            );
        }
        let ring = router::Ring::new(cfg.regions);
        let routed = vec![0; cfg.regions];
        Federation {
            regions,
            ring,
            timeline,
            next_event: 0,
            fault_log: Vec::new(),
            failovers: 0,
            straggler_windows: 0,
            throttle_windows: 0,
            arrivals: 0,
            routed,
            phase: RolloutPhase::Pending,
            rollout_models: 0,
            drain_started: 0,
            cfg,
        }
    }

    /// Register a model in **every** region; returns the (shared)
    /// registry index.
    pub fn register(&mut self, net: Network) -> usize {
        let mut idx = 0;
        for engine in &mut self.regions {
            idx = engine.register(net.clone());
        }
        idx
    }

    /// Install the SLO class table fleet-wide.
    pub fn set_classes(&mut self, classes: Vec<SloClass>) {
        for engine in &mut self.regions {
            engine.set_classes(classes.clone());
        }
    }

    pub fn model_count(&self) -> usize {
        self.regions[0].model_count()
    }

    /// One region's engine (read-only: completions, metrics, shards).
    pub fn region(&self, r: usize) -> &Engine {
        &self.regions[r]
    }

    pub fn regions(&self) -> &[Engine] {
        &self.regions
    }

    /// Faults applied so far, in application order.
    pub fn fault_log(&self) -> &[FaultRecord] {
        &self.fault_log
    }

    /// Generate a deterministic arrival trace from `spec` over the
    /// registered models and install `spec.classes` fleet-wide (the
    /// federated analog of [`Engine::workload_trace`]).
    pub fn workload_trace(&mut self, spec: &WorkloadSpec) -> Vec<TraceItem> {
        assert_eq!(spec.mix.len(), self.model_count(), "one mix weight per model");
        self.set_classes(spec.classes.clone());
        let io: Vec<(Vec<usize>, u8)> = (0..self.model_count())
            .map(|m| {
                let (net, _) = self.regions[0].model_entry(m);
                (net.input_shape.to_vec(), net.input_bits)
            })
            .collect();
        workload::generate(spec, &io)
    }

    /// Per-region admission mask: healthy (some shard not failed) and
    /// not draining. Degrades gracefully: if draining masks everything,
    /// health alone decides; if the whole fleet is down, everything is
    /// eligible (requests queue and wait for recovery).
    fn eligibility(&self, now: u64) -> Vec<bool> {
        let canary = match self.phase {
            RolloutPhase::Draining { .. } => self.cfg.rollout.map(|p| p.canary),
            _ => None,
        };
        let healthy: Vec<bool> = self
            .regions
            .iter()
            .map(|e| e.shards().iter().any(|s| !s.is_failed(now)))
            .collect();
        let mut elig: Vec<bool> = healthy
            .iter()
            .enumerate()
            .map(|(r, &h)| h && Some(r) != canary)
            .collect();
        if !elig.iter().any(|&e| e) {
            elig = if healthy.iter().any(|&h| h) {
                healthy
            } else {
                vec![true; self.regions.len()]
            };
        }
        elig
    }

    fn admit(&mut self, t: TraceItem, now: u64) {
        let eligible = self.eligibility(now);
        let region = router::route(
            self.cfg.policy,
            &self.ring,
            self.arrivals,
            t.model,
            &self.regions,
            &eligible,
            now,
        );
        self.arrivals += 1;
        self.routed[region] += 1;
        self.regions[region].submit(t);
    }

    fn apply_fault(&mut self, rec: FaultRecord) {
        match rec.action {
            FaultAction::Fail { until } => {
                self.regions[rec.region].fail_shard(rec.shard, rec.at, until);
                self.failovers += 1;
            }
            FaultAction::Recover => {
                self.regions[rec.region].recover_shard(rec.shard, rec.at);
            }
            FaultAction::Slow { factor, until } => {
                self.regions[rec.region].slow_shard(rec.shard, factor, until);
                self.straggler_windows += 1;
            }
            FaultAction::Throttle { until } => {
                self.regions[rec.region].throttle_shard(rec.shard, until);
                self.throttle_windows += 1;
            }
        }
        self.fault_log.push(rec);
    }

    /// One rollout-controller step (see [`rollout`] for the phases).
    fn rollout_step(&mut self, now: u64) {
        let Some(plan) = self.cfg.rollout else { return };
        match self.phase {
            RolloutPhase::Pending if now >= plan.at => {
                self.drain_started = now;
                self.phase = RolloutPhase::Draining { since: now };
                // an already-idle canary switches at the drain cycle
                // itself (one recursion level, Draining never recurses)
                self.rollout_step(now);
            }
            RolloutPhase::Draining { .. } if self.regions[plan.canary].is_idle(now) => {
                let (plans, tunes) = rollout::stage_tuned_caches(&self.regions[plan.canary]);
                let canary = &mut self.regions[plan.canary];
                canary.warm_caches(&plans, &tunes);
                canary.set_tuned(true);
                self.rollout_models = canary.model_count();
                self.phase = RolloutPhase::Live { switched: now };
            }
            _ => {}
        }
    }

    /// While draining, the cycle the canary's last busy shard frees up
    /// — the loop must visit it to run the switch even though the
    /// canary's queue is empty.
    fn drain_wake(&self, now: u64) -> Option<u64> {
        let RolloutPhase::Draining { .. } = self.phase else { return None };
        let canary = self.cfg.rollout?.canary;
        self.regions[canary]
            .shards()
            .iter()
            .map(|s| s.busy_until)
            .filter(|&b| b > now)
            .max()
    }

    /// Replay an arrival trace to completion across the fleet; returns
    /// the federation report. See the module docs for the loop order.
    pub fn run_trace(&mut self, mut trace: Vec<TraceItem>) -> FederationMetrics {
        trace.sort_by_key(|t| t.at);
        let mut it = trace.into_iter().peekable();
        let mut clock = 0u64;
        loop {
            while self.next_event < self.timeline.len() && self.timeline[self.next_event].at <= clock
            {
                let rec = self.timeline[self.next_event];
                self.next_event += 1;
                self.apply_fault(rec);
            }
            self.rollout_step(clock);
            while it.peek().map_or(false, |t| t.at <= clock) {
                let t = it.next().unwrap();
                self.admit(t, clock);
            }
            for engine in &mut self.regions {
                engine.pump(clock);
            }
            // a pending rollout is a wake source too: the drain (and
            // switch) must happen even if the trace finished earlier
            let rollout_wake = match self.phase {
                RolloutPhase::Pending => self.cfg.rollout.map(|p| p.at).filter(|&a| a > clock),
                _ => None,
            };
            let candidates = [
                it.peek().map(|t| t.at),
                self.timeline.get(self.next_event).map(|r| r.at),
                self.regions.iter().filter_map(|e| e.next_wake(clock)).min(),
                self.drain_wake(clock),
                rollout_wake,
            ];
            match candidates.into_iter().flatten().min() {
                // `max(clock)`: region wakes may be `<= clock` (see
                // `Engine::run_trace`); each same-cycle pass strictly
                // shrinks pending work, so the loop terminates.
                Some(c) => clock = c.max(clock),
                None => break,
            }
        }
        self.metrics()
    }

    /// Build the federation report (per-region fleet reports + fault
    /// and rollout accounting).
    pub fn metrics(&self) -> FederationMetrics {
        let rollout = match self.phase {
            RolloutPhase::Live { switched } => {
                let canary = self.cfg.rollout.expect("live rollout has a plan").canary;
                let (mut default_exec, mut tuned_exec) = (0u64, 0u64);
                for c in self.regions[canary].completions() {
                    if c.start_cycle >= switched {
                        tuned_exec += c.exec_cycles;
                    } else {
                        default_exec += c.exec_cycles;
                    }
                }
                Some(RolloutReport {
                    canary,
                    drain_started: self.drain_started,
                    switched_at: switched,
                    models_migrated: self.rollout_models,
                    canary_default_exec: default_exec,
                    canary_tuned_exec: tuned_exec,
                })
            }
            _ => None,
        };
        FederationMetrics {
            policy: self.cfg.policy,
            regions: self.regions.iter().map(|e| e.metrics()).collect(),
            routed: self.routed.clone(),
            faults_injected: self.cfg.faults.len(),
            failovers: self.failovers,
            straggler_windows: self.straggler_windows,
            throttle_windows: self.throttle_windows,
            requeued: self.regions.iter().map(|e| e.queue.requeued).sum(),
            fault_log: self.fault_log.clone(),
            rollout,
        }
    }

    /// Build the federated timeline as a canonicalized trace recorder:
    /// every region's fleet timeline at its own pid block, plus a
    /// `federation` control process carrying fault and rollout instants
    /// (layout in [`crate::trace::serve`]). Deterministic for the same
    /// reasons as [`Engine::build_trace`].
    pub fn build_trace(&self) -> crate::trace::Recorder {
        use crate::trace::serve::{build_federation_trace, ControlInstant, FleetTraceInputs};
        let names: Vec<String> =
            (0..self.model_count()).map(|m| self.regions[0].model_name(m).to_string()).collect();
        let inputs: Vec<FleetTraceInputs> = self
            .regions
            .iter()
            .map(|e| FleetTraceInputs {
                completions: e.completions(),
                shed: e.shed_events(),
                occupancy: e.occupancy(),
                model_names: &names,
                classes: e.classes(),
                shards: e.shards().len(),
                plan_cache: (e.cache.hits, e.cache.misses),
                tune_cache: (e.tuning().hits, e.tuning().misses),
                dvfs: e.dvfs_log(),
            })
            .collect();
        let mut faults: Vec<ControlInstant> = Vec::new();
        for rec in &self.fault_log {
            let (r, s) = (rec.region as u64, rec.shard as u64);
            match rec.action {
                FaultAction::Fail { until } => faults.push(ControlInstant {
                    at: rec.at,
                    name: "shard_fail",
                    args: vec![("region", r), ("shard", s), ("until", until)],
                }),
                FaultAction::Recover => faults.push(ControlInstant {
                    at: rec.at,
                    name: "shard_recover",
                    args: vec![("region", r), ("shard", s)],
                }),
                FaultAction::Slow { factor, until } => {
                    faults.push(ControlInstant {
                        at: rec.at,
                        name: "straggler_start",
                        args: vec![("region", r), ("shard", s), ("factor", factor)],
                    });
                    faults.push(ControlInstant {
                        at: until,
                        name: "straggler_end",
                        args: vec![("region", r), ("shard", s)],
                    });
                }
                FaultAction::Throttle { until } => {
                    faults.push(ControlInstant {
                        at: rec.at,
                        name: "throttle_start",
                        args: vec![("region", r), ("shard", s), ("until", until)],
                    });
                    faults.push(ControlInstant {
                        at: until,
                        name: "throttle_end",
                        args: vec![("region", r), ("shard", s)],
                    });
                }
            }
        }
        let mut rollout_instants: Vec<ControlInstant> = Vec::new();
        if let Some(plan) = self.cfg.rollout {
            match self.phase {
                RolloutPhase::Draining { since } => rollout_instants.push(ControlInstant {
                    at: since,
                    name: "rollout_drain_start",
                    args: vec![("canary", plan.canary as u64)],
                }),
                RolloutPhase::Live { switched } => {
                    rollout_instants.push(ControlInstant {
                        at: self.drain_started,
                        name: "rollout_drain_start",
                        args: vec![("canary", plan.canary as u64)],
                    });
                    rollout_instants.push(ControlInstant {
                        at: switched,
                        name: "rollout_switch",
                        args: vec![
                            ("canary", plan.canary as u64),
                            ("models", self.rollout_models as u64),
                        ],
                    });
                }
                RolloutPhase::Pending => {}
            }
        }
        let mut rec = build_federation_trace(&inputs, &faults, &rollout_instants);
        rec.canonicalize();
        rec
    }
}

/// The federation-level report: per-region fleet reports plus routing,
/// fault and rollout accounting. Renders deterministically (part of the
/// cross-worker fingerprint) and exports per-region / failure-mode /
/// rollout metric rows for the bench artifact.
#[derive(Clone, Debug)]
pub struct FederationMetrics {
    pub policy: RouterPolicy,
    pub regions: Vec<FleetMetrics>,
    /// Arrivals handed to each region by the router.
    pub routed: Vec<u64>,
    /// Planned fault events (failures + stragglers).
    pub faults_injected: usize,
    /// Shard failures applied.
    pub failovers: u64,
    /// Straggler windows applied.
    pub straggler_windows: u64,
    /// Thermal-throttle windows applied.
    pub throttle_windows: u64,
    /// Requests retracted from failed shards and re-queued, fleet-wide.
    pub requeued: u64,
    /// Events applied, in application order.
    pub fault_log: Vec<FaultRecord>,
    /// Present once the rollout switched.
    pub rollout: Option<RolloutReport>,
}

impl FederationMetrics {
    /// Requests served fleet-wide.
    pub fn total_served(&self) -> usize {
        self.regions.iter().map(|r| r.served).sum()
    }

    /// Total simulated energy billed fleet-wide [pJ].
    pub fn total_energy_pj(&self) -> f64 {
        self.regions.iter().map(|r| r.total_energy_pj).sum()
    }

    /// Fleet average power [mW]: total energy over the longest region
    /// span (regions run concurrently on one simulated clock, so the
    /// longest span is the fleet's wall-clock window).
    pub fn fleet_avg_power_mw(&self) -> f64 {
        let span = self.regions.iter().map(|r| r.span_cycles).max().unwrap_or(0);
        let span_ps = span as f64 * crate::power::NOMINAL_PERIOD_PS as f64;
        if span_ps > 0.0 { self.total_energy_pj() / span_ps * 1e3 } else { 0.0 }
    }

    /// Fleet efficiency over the run: `2·MACs / total energy` [TOPS/W].
    pub fn fleet_tops_per_watt(&self) -> f64 {
        let e = self.total_energy_pj();
        let macs: u64 = self.regions.iter().map(|r| r.total_macs).sum();
        if e > 0.0 { 2.0 * macs as f64 / e } else { 0.0 }
    }

    /// Fleet power cap [mW]: the sum of per-region caps (`serve-bench
    /// --power-cap` splits the fleet cap evenly across regions).
    pub fn power_cap_mw(&self) -> Option<f64> {
        let caps: Vec<f64> = self.regions.iter().filter_map(|r| r.power_cap_mw).collect();
        if caps.is_empty() { None } else { Some(caps.iter().sum()) }
    }

    /// Operating-point transitions fleet-wide.
    pub fn dvfs_transitions(&self) -> u64 {
        self.regions.iter().map(|r| r.dvfs_transitions).sum()
    }

    /// Human-readable federation report (regions, routing, faults,
    /// rollout, then each region's fleet report).
    pub fn render(&self) -> String {
        let shards = self.regions.first().map_or(0, |r| r.shards);
        let mut out = format!(
            "=== federation: {} regions x {} shards, router {} ===\n",
            self.regions.len(),
            shards,
            self.policy.name(),
        );
        out.push_str("routed:");
        for (r, n) in self.routed.iter().enumerate() {
            out.push_str(&format!(" r{r}={n}"));
        }
        out.push('\n');
        if self.total_energy_pj() > 0.0 {
            let cap = self.power_cap_mw().map_or(String::new(), |c| format!(" (cap {c:.2} mW)"));
            out.push_str(&format!(
                "energy: fleet avg power {:.2} mW{} | {:.2} TOPS/W | {} DVFS transitions\n",
                self.fleet_avg_power_mw(),
                cap,
                self.fleet_tops_per_watt(),
                self.dvfs_transitions(),
            ));
        }
        if self.faults_injected > 0 {
            out.push_str(&format!(
                "faults: {} injected ({} failovers, {} straggler windows, {} throttle windows); \
                 {} requests re-queued\n",
                self.faults_injected,
                self.failovers,
                self.straggler_windows,
                self.throttle_windows,
                self.requeued,
            ));
            for rec in &self.fault_log {
                let what = match rec.action {
                    FaultAction::Fail { until } => format!("fail until {until}"),
                    FaultAction::Recover => "recover".to_string(),
                    FaultAction::Slow { factor, until } => format!("slow x{factor} until {until}"),
                    FaultAction::Throttle { until } => format!("throttle until {until}"),
                };
                out.push_str(&format!("  @{} r{}.s{} {}\n", rec.at, rec.region, rec.shard, what));
            }
        }
        if let Some(ro) = &self.rollout {
            out.push_str(&format!(
                "rollout: canary r{} drained {}..{} ({} cycles), {} models migrated; \
                 exec cycles default {} -> tuned {}\n",
                ro.canary,
                ro.drain_started,
                ro.switched_at,
                ro.drain_cycles(),
                ro.models_migrated,
                ro.canary_default_exec,
                ro.canary_tuned_exec,
            ));
        }
        for (r, m) in self.regions.iter().enumerate() {
            out.push_str(&format!("--- region {r} ---\n"));
            out.push_str(&m.render());
        }
        out
    }
}

impl MetricSource for FederationMetrics {
    /// Per-region, failure-mode, and rollout rows (all exact: products
    /// of the deterministic simulation, never host state).
    fn metric_rows(&self) -> Vec<MetricRow> {
        let mut rows = Vec::new();
        for (r, m) in self.regions.iter().enumerate() {
            let p = format!("serve/region{r}");
            rows.push(MetricRow::exact(format!("{p}/served"), m.served as f64, "requests"));
            rows.push(MetricRow::exact(format!("{p}/p99_cycles"), m.p99_cycles as f64, "cycles"));
            rows.push(MetricRow::exact(format!("{p}/requeued"), m.requeued as f64, "requests"));
        }
        rows.push(MetricRow::exact(
            "serve/faults/injected",
            self.faults_injected as f64,
            "events",
        ));
        rows.push(MetricRow::exact("serve/faults/failovers", self.failovers as f64, "events"));
        rows.push(MetricRow::exact(
            "serve/faults/straggler_windows",
            self.straggler_windows as f64,
            "events",
        ));
        rows.push(MetricRow::exact(
            "serve/faults/throttle_windows",
            self.throttle_windows as f64,
            "events",
        ));
        rows.push(MetricRow::exact("serve/faults/requeued", self.requeued as f64, "requests"));
        if self.total_energy_pj() > 0.0 {
            rows.push(MetricRow::analog(
                "serve/federation/avg_power_mw",
                self.fleet_avg_power_mw(),
                "mW",
            ));
            rows.push(MetricRow::analog(
                "serve/federation/tops_per_watt",
                self.fleet_tops_per_watt(),
                "TOPS/W",
            ));
            rows.push(MetricRow::exact(
                "serve/federation/dvfs_transitions",
                self.dvfs_transitions() as f64,
                "transitions",
            ));
        }
        if let Some(ro) = &self.rollout {
            rows.push(MetricRow::exact(
                "serve/rollout/models_migrated",
                ro.models_migrated as f64,
                "models",
            ));
            rows.push(MetricRow::exact(
                "serve/rollout/drain_cycles",
                ro.drain_cycles() as f64,
                "cycles",
            ));
            rows.push(MetricRow::exact(
                "serve/rollout/canary_default_exec_cycles",
                ro.canary_default_exec as f64,
                "cycles",
            ));
            rows.push(MetricRow::exact(
                "serve/rollout/canary_tuned_exec_cycles",
                ro.canary_tuned_exec as f64,
                "cycles",
            ));
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::{Layer, QTensor};
    use crate::util::Prng;

    fn tiny(name: &str, seed: u64) -> Network {
        let mut rng = Prng::new(seed);
        let mut net = Network::new(name, [8, 8, 8], 8);
        net.push(Layer::conv("c1", [8, 8, 8], 8, 3, 3, 1, 1, 8, 4, 8, &mut rng));
        net.push(Layer::conv("c2", [8, 8, 8], 8, 1, 1, 1, 0, 8, 8, 8, &mut rng));
        net
    }

    fn small_engine() -> ServeConfig {
        ServeConfig {
            shards: 2,
            n_cores: 4,
            queue_capacity: 64,
            max_batch: 4,
            ..ServeConfig::default()
        }
    }

    fn item(at: u64, model: usize, rng: &mut Prng) -> TraceItem {
        TraceItem {
            at,
            model,
            class: 0,
            priority: 0,
            deadline: None,
            input: QTensor::random(&[8, 8, 8], 8, false, rng),
        }
    }

    fn mixed_trace(models: usize, n: usize, gap: u64, seed: u64) -> Vec<TraceItem> {
        let mut rng = Prng::new(seed);
        (0..n).map(|i| item(i as u64 * gap, i % models, &mut rng)).collect()
    }

    #[test]
    fn every_policy_serves_the_whole_trace_across_regions() {
        for policy in RouterPolicy::ALL {
            let cfg = FederationConfig {
                regions: 2,
                engine: small_engine(),
                policy,
                ..FederationConfig::default()
            };
            let mut fed = Federation::new(cfg);
            fed.register(tiny("fed-a", 1));
            fed.register(tiny("fed-b", 2));
            let m = fed.run_trace(mixed_trace(2, 10, 100, 3));
            assert_eq!(m.total_served(), 10, "policy {} lost work", policy.name());
            assert_eq!(m.routed.iter().sum::<u64>(), 10);
            assert_eq!(m.requeued, 0);
            assert!(m.render().contains("router"));
            if policy == RouterPolicy::Locality {
                // model m homes on region m % 2; with both regions
                // healthy every arrival routes home.
                for r in 0..2 {
                    assert!(
                        fed.region(r).completions().iter().all(|c| c.model % 2 == r),
                        "locality sent a model away from home"
                    );
                }
            }
        }
    }

    #[test]
    fn shard_failure_requeues_and_the_fleet_still_serves_everything() {
        // Least-loaded routes the first arrival to region 0 (tie-break
        // low), so its shard 0 is mid-batch when the fault lands at
        // cycle 600 and stays down past the whole trace; the in-flight
        // work re-queues.
        let faults = FaultPlan::parse("fail@600:r0.s0+100000000", 0, 2, 2, 0).unwrap();
        let cfg = FederationConfig {
            regions: 2,
            engine: small_engine(),
            policy: RouterPolicy::LeastLoaded,
            faults,
            rollout: None,
        };
        let mut fed = Federation::new(cfg);
        fed.register(tiny("flt-a", 4));
        fed.register(tiny("flt-b", 5));
        let m = fed.run_trace(mixed_trace(2, 12, 50, 6));
        assert_eq!(m.total_served(), 12, "failover dropped admitted work");
        assert!(m.requeued >= 1, "shard 0 had in-flight work at the fault");
        assert_eq!((m.faults_injected, m.failovers), (1, 1));
        // fail + recover are both in the applied log.
        assert_eq!(fed.fault_log().len(), 2);
        assert_eq!(fed.fault_log()[0].action, FaultAction::Fail { until: 100_000_600 });
        let rendered = m.render();
        assert!(rendered.contains("faults: 1 injected"), "{rendered}");
        assert!(rendered.contains("re-queued"), "{rendered}");
        // no completion is attributed to the failed shard during its
        // down window (it recovers long after the last arrival).
        assert!(m.regions[0].requeued >= 1);
        let rows = m.metric_rows();
        let ids: Vec<&str> = rows.iter().map(|r| r.id.as_str()).collect();
        assert!(ids.contains(&"serve/faults/failovers"));
        assert!(ids.contains(&"serve/region0/requeued"));
    }

    #[test]
    fn straggler_stretches_latency_without_changing_what_is_served() {
        let run = |faults: FaultPlan| {
            let cfg = FederationConfig {
                regions: 1,
                engine: small_engine(),
                policy: RouterPolicy::ConsistentHash,
                faults,
                rollout: None,
            };
            let mut fed = Federation::new(cfg);
            fed.register(tiny("str-a", 7));
            let m = fed.run_trace(mixed_trace(1, 6, 50, 8));
            let outs: Vec<(u64, Vec<u8>)> =
                fed.region(0).completions().iter().map(|c| (c.id, c.output.clone())).collect();
            (m, outs)
        };
        let (healthy, outs_h) = run(FaultPlan::none());
        let slow = FaultPlan::parse("slow@0:r0.s0x4+100000000", 0, 1, 2, 0).unwrap();
        let (straggled, outs_s) = run(slow);
        assert_eq!(straggled.total_served(), healthy.total_served());
        assert_eq!(straggled.straggler_windows, 1);
        assert!(
            straggled.regions[0].span_cycles > healthy.regions[0].span_cycles,
            "a 4x straggler on half the fleet must stretch the span ({} vs {})",
            straggled.regions[0].span_cycles,
            healthy.regions[0].span_cycles,
        );
        // functional results are untouched by the timing overlay
        let sorted = |mut v: Vec<(u64, Vec<u8>)>| {
            v.sort();
            v
        };
        assert_eq!(sorted(outs_h), sorted(outs_s));
    }

    #[test]
    fn thermal_throttle_clamps_the_shard_to_the_efficiency_point() {
        use crate::power::{DvfsPolicy, OP_BOOST, OP_EFFICIENCY};
        let run = |faults: FaultPlan| {
            let engine = ServeConfig { shards: 1, dvfs: DvfsPolicy::RaceToIdle, ..small_engine() };
            let cfg = FederationConfig {
                regions: 1,
                engine,
                policy: RouterPolicy::ConsistentHash,
                faults,
                rollout: None,
            };
            let mut fed = Federation::new(cfg);
            fed.register(tiny("thr-a", 12));
            let m = fed.run_trace(mixed_trace(1, 6, 50, 13));
            (fed, m)
        };
        let plan = FaultPlan::parse("throttle@0:r0.s0+100000000", 0, 1, 1, 0).unwrap();
        let (hot_fed, hot) = run(plan);
        assert_eq!(hot.total_served(), 6);
        assert_eq!((hot.faults_injected, hot.throttle_windows), (1, 1));
        assert!(
            hot_fed.region(0).completions().iter().all(|c| c.op == OP_EFFICIENCY as u8),
            "throttled shard must run every batch at the efficiency point"
        );
        assert!(hot.render().contains("throttle until"), "{}", hot.render());
        let names: Vec<String> =
            hot_fed.build_trace().events().iter().map(|e| e.name.clone()).collect();
        assert!(names.iter().any(|n| n == "throttle_start"));
        assert!(names.iter().any(|n| n == "throttle_end"));
        // Control: the same run without the fault boosts (race-to-idle),
        // and the throttled run costs less energy for identical outputs.
        let (cool_fed, cool) = run(FaultPlan::none());
        assert!(cool_fed.region(0).completions().iter().all(|c| c.op == OP_BOOST as u8));
        assert!(hot.total_energy_pj() < cool.total_energy_pj());
        assert_eq!(hot.total_served(), cool.total_served());
    }

    #[test]
    fn rollout_drains_switches_warm_and_drops_nothing() {
        // Locality policy homes model 1 on region 1 (the canary), so
        // pre-drain and post-switch canary traffic is guaranteed.
        let cfg = FederationConfig {
            regions: 2,
            engine: small_engine(),
            policy: RouterPolicy::Locality,
            faults: FaultPlan::none(),
            rollout: Some(RolloutPlan { at: 1_000_000, canary: 1 }),
        };
        let mut fed = Federation::new(cfg);
        fed.register(tiny("ro-a", 9));
        fed.register(tiny("ro-b", 10));
        let mut rng = Prng::new(11);
        let mut trace: Vec<TraceItem> =
            (0..8u64).map(|i| item(i * 60, (i % 2) as usize, &mut rng)).collect();
        for i in 0..8u64 {
            trace.push(item(3_000_000 + i * 60, (i % 2) as usize, &mut rng));
        }
        let m = fed.run_trace(trace);
        assert_eq!(m.total_served(), 16, "rollout dropped admitted work");
        let ro = m.rollout.expect("rollout must have switched");
        assert_eq!(ro.canary, 1);
        assert_eq!(ro.models_migrated, 2);
        assert!(ro.drain_started >= 1_000_000);
        assert!(ro.switched_at >= ro.drain_started);
        assert!(ro.canary_default_exec > 0, "canary served default traffic pre-drain");
        assert!(ro.canary_tuned_exec > 0, "canary served tuned traffic post-switch");
        // the canary's report now carries the autotune summary; the
        // default region's does not.
        assert!(m.regions[1].tuned.models > 0);
        assert_eq!(m.regions[0].tuned.models, 0);
        let rendered = m.render();
        assert!(rendered.contains("rollout: canary r1"), "{rendered}");
        let ids: Vec<String> = m.metric_rows().into_iter().map(|r| r.id).collect();
        assert!(ids.iter().any(|i| i == "serve/rollout/drain_cycles"));
        // exported trace carries the control instants
        let rec = fed.build_trace();
        let names: Vec<&str> = rec.events().iter().map(|e| e.name.as_str()).collect();
        assert!(names.contains(&"rollout_drain_start"));
        assert!(names.contains(&"rollout_switch"));
    }
}
